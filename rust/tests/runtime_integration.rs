//! Integration tests: the model-compute runtime end to end, against
//! whichever backend the build selects — the AOT artifacts × PJRT when
//! `--features pjrt` and `make artifacts` have run, the pure-Rust native
//! backend otherwise. Both expose the same flat-parameter ABI, and the
//! numerics must behave like training either way.

use marfl::data::synth;
use marfl::models::default_artifact_dir;
use marfl::rng::Rng;
use marfl::runtime::Runtime;
use marfl::testing::assert_allclose;

fn runtime() -> Runtime {
    Runtime::new(&default_artifact_dir()).expect("runtime")
}

#[test]
fn meta_lists_both_models() {
    let rt = runtime();
    assert!(rt.meta.models.contains_key("cnn"));
    assert!(rt.meta.models.contains_key("head"));
    for m in rt.meta.models.values() {
        assert_eq!(m.padded_len % rt.meta.strip, 0);
        assert!(m.param_count <= m.padded_len);
    }
}

#[test]
fn init_params_match_padded_len_and_zero_tail() {
    let rt = runtime();
    for name in ["cnn", "head"] {
        let m = rt.meta.model(name).unwrap();
        let theta = rt.init_params(name).unwrap();
        assert_eq!(theta.len(), m.padded_len);
        assert!(theta[m.param_count..].iter().all(|&v| v == 0.0));
        // not all zeros overall
        assert!(theta.iter().any(|&v| v != 0.0));
    }
}

#[test]
fn train_step_learns_on_head_task() {
    let rt = runtime();
    let m = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(11);
    let data = synth::newsgroups_like(m.batch * 4, &mut rng);
    let mut theta = rt.init_params("head").unwrap();
    let mut mom = vec![0.0; theta.len()];
    let idx: Vec<usize> = (0..m.batch).collect();
    let (x, y) = data.gather(&idx);
    let mut losses = Vec::new();
    for _ in 0..30 {
        let out = rt.train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
        theta = out.theta;
        mom = out.momentum;
        losses.push(out.loss);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.5),
        "loss did not halve: {losses:?}"
    );
    // padding invariant survives execution
    assert!(theta[m.param_count..].iter().all(|&v| v == 0.0));
}

#[test]
fn train_step_learns_on_cnn_task() {
    let rt = runtime();
    let m = rt.meta.model("cnn").unwrap().clone();
    let mut rng = Rng::new(13);
    let data = synth::mnist_like(m.batch, &mut rng);
    let mut theta = rt.init_params("cnn").unwrap();
    let mut mom = vec![0.0; theta.len()];
    let idx: Vec<usize> = (0..m.batch).collect();
    let (x, y) = data.gather(&idx);
    let mut losses = Vec::new();
    for _ in 0..20 {
        let out = rt.train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
        theta = out.theta;
        mom = out.momentum;
        losses.push(out.loss);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.6),
        "cnn loss did not drop: first {} last {}",
        losses[0],
        losses.last().unwrap()
    );
}

#[test]
fn evaluate_returns_sane_untrained_metrics() {
    let rt = runtime();
    let m = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(17);
    let test = synth::newsgroups_like(m.eval_chunk * 2, &mut rng);
    let theta = rt.init_params("head").unwrap();
    let (loss, acc) = rt.evaluate(&m, &theta, &test.x, &test.y).unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    // untrained 20-class model ~ 5% accuracy, generously below 30%
    assert!((0.0..0.3).contains(&acc), "untrained acc {acc}");
}

#[test]
fn evaluate_rejects_non_chunk_multiple() {
    let rt = runtime();
    let m = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(19);
    let test = synth::newsgroups_like(m.eval_chunk + 1, &mut rng);
    let theta = rt.init_params("head").unwrap();
    assert!(rt.evaluate(&m, &theta, &test.x, &test.y).is_err());
}

#[test]
fn logits_shape_and_determinism() {
    let rt = runtime();
    let m = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(23);
    let data = synth::newsgroups_like(m.batch, &mut rng);
    let theta = rt.init_params("head").unwrap();
    let idx: Vec<usize> = (0..m.batch).collect();
    let (x, _) = data.gather(&idx);
    let z1 = rt.logits(&m, &theta, &x).unwrap();
    let z2 = rt.logits(&m, &theta, &x).unwrap();
    assert_eq!(z1.len(), m.batch * m.classes);
    assert_eq!(z1, z2, "PJRT execution must be deterministic");
}

#[test]
fn kd_step_with_lambda_zero_matches_train_step() {
    let rt = runtime();
    let m = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(29);
    let data = synth::newsgroups_like(m.batch, &mut rng);
    let theta = rt.init_params("head").unwrap();
    let mom = vec![0.0; theta.len()];
    let idx: Vec<usize> = (0..m.batch).collect();
    let (x, y) = data.gather(&idx);
    let zbar = vec![0.0f32; m.batch * m.classes];
    let a = rt.train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
    let b = rt
        .kd_step(&m, &theta, &mom, &x, &y, &zbar, 0.0, 0.1, 0.9)
        .unwrap();
    assert_allclose(&a.theta, &b.theta, 1e-5, 1e-6);
    assert!((a.loss - b.loss).abs() < 1e-5);
}

#[test]
fn group_mean_artifact_matches_native_mean() {
    let rt = runtime();
    let m = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(31);
    for &k in &[2usize, 5, 8] {
        let stack: Vec<f32> =
            (0..k * m.padded_len).map(|_| rng.normal() as f32).collect();
        let got = rt.group_mean(&m, &stack, k).unwrap();
        let mut want = vec![0.0f64; m.padded_len];
        for row in 0..k {
            for (w, &v) in want
                .iter_mut()
                .zip(&stack[row * m.padded_len..(row + 1) * m.padded_len])
            {
                *w += v as f64;
            }
        }
        let want: Vec<f32> =
            want.iter().map(|&v| (v / k as f64) as f32).collect();
        assert_allclose(&got, &want, 1e-5, 1e-6);
    }
}

#[test]
fn group_mean_rejects_unlowered_size() {
    let rt = runtime();
    let m = rt.meta.model("head").unwrap().clone();
    let stack = vec![0.0f32; 9 * m.padded_len];
    assert!(rt.group_mean(&m, &stack, 9).is_err());
}

#[test]
fn executable_cache_compiles_once() {
    let rt = runtime();
    let m = rt.meta.model("head").unwrap().clone();
    let theta = rt.init_params("head").unwrap();
    let mut rng = Rng::new(37);
    let data = synth::newsgroups_like(m.batch, &mut rng);
    let idx: Vec<usize> = (0..m.batch).collect();
    let (x, _) = data.gather(&idx);
    for _ in 0..3 {
        rt.logits(&m, &theta, &x).unwrap();
    }
    assert_eq!(rt.call_counts()["head_logits"], 3);
}
