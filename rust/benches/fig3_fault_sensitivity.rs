//! Figure 3 extension — recovery cost under bursty (Gilbert–Elliott)
//! link faults.
//!
//! Sweeps burst length at a fixed stationary bad fraction
//! (π = ge_p / (ge_p + ge_r) = 0.2) and compares what recovery costs
//! each topology: MAR's bounded retry budget + survivor quorums versus
//! ring (RDFL) and butterfly (BAR), whose chunk/step ownership forces
//! persistent delivery (retry until the burst ends), versus gossip,
//! which never retries but silently skips merges. The paper's
//! reliability pitch (§3) predicts MAR's *relative* byte surcharge
//! stays at or below the ownership topologies at matched loss.
//!
//! Emits `fig3_fault_sensitivity.csv` and `BENCH_faults.json`.
//! `MARFL_BENCH_FULL=1` lengthens the run; `MARFL_BENCH_NO_ASSERT=1`
//! records results without enforcing the surcharge ordering.

#[path = "common/mod.rs"]
mod common;

use common::{assert_stable_columns, emit_csv, iters, mib, results_dir, runtime, timed};
use marfl::config::{ExperimentConfig, Strategy};
use marfl::fl::Trainer;
use marfl::net::FaultConfig;
use marfl::telemetry::BenchReport;
use marfl::util::json::{arr, num, obj, s};

/// Fixed stationary bad fraction for the whole sweep.
const PI_BAD: f64 = 0.2;

fn bursty(ge_r: f64) -> FaultConfig {
    // π = p/(p+r) = 0.2  ⇔  p = r·π/(1−π) = 0.25·r
    let ge_p = ge_r * PI_BAD / (1.0 - PI_BAD);
    FaultConfig {
        loss: 0.02,
        ge_p,
        ge_r,
        ge_loss: 0.5,
        ge_bw: 0.25,
        ge_lat: 4.0,
        ..FaultConfig::default()
    }
}

fn main() {
    let peers = 16; // 4² MAR grid; 2⁴ keeps the butterfly complete
    let t = iters(10, 30);
    println!(
        "Fault sensitivity — burst-length sweep at π={PI_BAD} \
         (peers={peers}, T={t})\n"
    );
    let rt = runtime();
    let base = ExperimentConfig {
        model: "head".into(),
        peers,
        group_size: 4,
        mar_rounds: 2, // 16 = 4^2
        iterations: t,
        samples_per_peer: 32,
        test_samples: 1000,
        eval_every: t,
        seed: 20260,
        ..Default::default()
    };

    let strategies =
        [Strategy::MarFl, Strategy::Rdfl, Strategy::Bar, Strategy::Gossip];
    // mean burst length is 1/ge_r schedule ticks: short → long bursts
    let sweep = [0.6f64, 0.3, 0.1];

    let mut rows = vec![vec![
        "strategy".into(),
        "ge_r".into(),
        "ge_p".into(),
        "burst_len".into(),
        "data_mib".into(),
        "surcharge_mib".into(),
        "rel_surcharge".into(),
        "surcharge_time_s".into(),
        "retries".into(),
        "timeouts".into(),
        "degraded_rounds".into(),
        "ge_bad_transitions".into(),
        "bursty_losses".into(),
        "final_accuracy".into(),
        "acc_drop".into(),
    ]];
    let mut json_rows = Vec::new();
    // per-strategy relative byte surcharge at the longest burst setting
    let mut rel_at_longest = std::collections::BTreeMap::new();

    for &strategy in &strategies {
        let name = strategy.name();
        let clean_cfg =
            ExperimentConfig { strategy, ..base.clone() };
        let clean = timed(&format!("{name} clean"), || {
            Trainer::new(clean_cfg, &rt).unwrap().run().unwrap()
        });
        println!(
            "    acc {:.3}  data {:.1} MiB  time {:.1}s",
            clean.final_accuracy,
            mib(clean.comm.data_bytes),
            clean.sim_time_s
        );
        rows.push(vec![
            name.into(),
            "0".into(),
            "0".into(),
            "0".into(),
            format!("{:.3}", mib(clean.comm.data_bytes)),
            "0".into(),
            "0".into(),
            "0".into(),
            "0".into(),
            "0".into(),
            "0".into(),
            "0".into(),
            "0".into(),
            format!("{:.4}", clean.final_accuracy),
            "0".into(),
        ]);
        for &ge_r in &sweep {
            let plan = bursty(ge_r);
            let label = format!("{name} ge_r={ge_r} (burst {:.1})", 1.0 / ge_r);
            let cfg = ExperimentConfig {
                strategy,
                faults: plan.clone(),
                ..base.clone()
            };
            let run = timed(&label, || {
                Trainer::new(cfg, &rt).unwrap().run().unwrap()
            });
            let f = run.faults;
            let surcharge =
                run.comm.data_bytes.saturating_sub(clean.comm.data_bytes);
            let rel = surcharge as f64 / clean.comm.data_bytes.max(1) as f64;
            let dt = run.sim_time_s - clean.sim_time_s;
            let acc_drop = clean.final_accuracy - run.final_accuracy;
            println!(
                "    +{:.1} MiB ({:.1}%)  +{dt:.1}s  retries {}  timeouts {}  \
                 degraded {}  bursts {}  acc {:.3} ({acc_drop:+.3} drop)",
                mib(surcharge),
                rel * 100.0,
                f.retries,
                f.timeouts,
                f.quorum_degraded_rounds,
                f.ge_bad_transitions,
                run.final_accuracy
            );
            rows.push(vec![
                name.into(),
                ge_r.to_string(),
                format!("{:.3}", plan.ge_p),
                format!("{:.1}", 1.0 / ge_r),
                format!("{:.3}", mib(run.comm.data_bytes)),
                format!("{:.3}", mib(surcharge)),
                format!("{rel:.4}"),
                format!("{dt:.3}"),
                f.retries.to_string(),
                f.timeouts.to_string(),
                f.quorum_degraded_rounds.to_string(),
                f.ge_bad_transitions.to_string(),
                f.bursty_losses.to_string(),
                format!("{:.4}", run.final_accuracy),
                format!("{acc_drop:.4}"),
            ]);
            json_rows.push(obj(vec![
                ("strategy", s(name)),
                ("ge_r", num(ge_r)),
                ("ge_p", num(plan.ge_p)),
                ("burst_len", num(1.0 / ge_r)),
                ("data_bytes", num(run.comm.data_bytes as f64)),
                ("surcharge_bytes", num(surcharge as f64)),
                ("rel_surcharge", num(rel)),
                ("surcharge_time_s", num(dt)),
                ("retries", num(f.retries as f64)),
                ("timeouts", num(f.timeouts as f64)),
                ("quorum_degraded_rounds", num(f.quorum_degraded_rounds as f64)),
                ("ge_bad_transitions", num(f.ge_bad_transitions as f64)),
                ("bursty_losses", num(f.bursty_losses as f64)),
                ("final_accuracy", num(run.final_accuracy)),
                ("acc_drop", num(acc_drop)),
            ]));
            assert!(
                f.ge_bad_transitions > 0,
                "an active chain must record burst onsets ({label})"
            );
            if (ge_r - sweep[sweep.len() - 1]).abs() < 1e-12 {
                rel_at_longest.insert(name.to_string(), rel);
            }
        }
    }
    assert_stable_columns(
        "fig3_fault_sensitivity.csv",
        &rows,
        &[
            "strategy",
            "ge_r",
            "ge_p",
            "burst_len",
            "data_mib",
            "surcharge_mib",
            "rel_surcharge",
            "surcharge_time_s",
            "retries",
            "timeouts",
            "degraded_rounds",
            "ge_bad_transitions",
            "bursty_losses",
            "final_accuracy",
            "acc_drop",
        ],
    );
    emit_csv("fig3_fault_sensitivity.csv", &rows);

    let path = BenchReport::new("faults")
        .field("kind", s("fault_sensitivity"))
        .field("peers", num(peers as f64))
        .field("iterations", num(t as f64))
        .field("pi_bad", num(PI_BAD))
        .field("results", arr(json_rows))
        .write(&results_dir())
        .expect("write BENCH_faults.json");
    println!("  -> {}", path.display());

    // ---- paper-shape assertion -------------------------------------
    // MAR's bounded retry budget must not cost more (relative to its
    // own clean traffic) than the persistent-delivery topologies at the
    // harshest burst setting.
    let mar = rel_at_longest["marfl"];
    let ring = rel_at_longest["rdfl"];
    let bar = rel_at_longest["bar"];
    println!(
        "\nrelative surcharge at burst {:.0}: MAR {:.1}% | ring {:.1}% | \
         butterfly {:.1}%",
        1.0 / sweep[sweep.len() - 1],
        mar * 100.0,
        ring * 100.0,
        bar * 100.0
    );
    if std::env::var("MARFL_BENCH_NO_ASSERT").is_err() {
        assert!(
            mar <= ring * 1.05 && mar <= bar * 1.05,
            "MAR recovery surcharge ({mar:.4}) must stay at or below \
             ring ({ring:.4}) and butterfly ({bar:.4})"
        );
    }
}
