//! Byzantine-robustness verification: an inert `attack.*` block must be
//! bit-identical to the seed behaviour (zero extra RNG draws), attacked
//! aggregation must stay bit-identical across the serial and
//! group-parallel engines for every robust estimator (with the
//! reputation ledger agreeing too), the trimmed mean must respect its
//! breakdown point coordinate-wise, and the Trainer must surface the
//! attack/defence scorecard through `RunSummary` deterministically.

use std::sync::Arc;

use marfl::aggregation::robust::{RobustEstimator, RobustPolicy};
use marfl::aggregation::{
    robust_average_group_native, AggCtx, AggReport, GroupExchange, PeerState,
};
use marfl::attack::{AttackConfig, AttackMode, Reputation};
use marfl::config::ExperimentConfig;
use marfl::coordinator::MarAggregator;
use marfl::fl::Trainer;
use marfl::metrics::{CommLedger, CommSnapshot};
use marfl::net::{BwDist, Fabric, FaultConfig};
use marfl::rng::Rng;
use marfl::runtime::Runtime;
use marfl::sim::SimClock;

fn toy_model(p: usize) -> marfl::models::ModelMeta {
    marfl::models::ModelMeta {
        name: "toy".into(),
        param_count: p,
        padded_len: p,
        input_shape: vec![4],
        classes: 3,
        batch: 8,
        eval_chunk: 8,
        init_file: String::new(),
        artifacts: Default::default(),
    }
}

fn random_states(n: usize, p: usize, seed: u64) -> Vec<PeerState> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| PeerState {
            theta: (0..p).map(|_| rng.normal() as f32).collect(),
            momentum: (0..p).map(|_| rng.normal() as f32 * 0.1).collect(),
        })
        .collect()
}

/// Flip the sign of every attacker's full state — the same corruption
/// `attack::AttackPlan` applies under `sign_flip`, inlined here so the
/// MAR-level tests control exactly who attacks when.
fn flip(states: &mut [PeerState], attackers: &[usize]) {
    for &a in attackers {
        for v in states[a].theta.make_mut_slice() {
            *v = -*v;
        }
        for v in states[a].momentum.make_mut_slice() {
            *v = -*v;
        }
    }
}

/// Three MAR iterations with re-corrupted attackers between calls;
/// returns (states, ledger, clock, reports, reputation ledger).
fn run_attacked_mar(
    est: RobustEstimator,
    exchange: GroupExchange,
    parallel: bool,
) -> (Vec<PeerState>, CommSnapshot, f64, Vec<AggReport>, Reputation) {
    let (n, m, g, p) = (16, 4, 2, 97);
    let attackers = [3usize, 7, 12];
    let mut states = random_states(n, p, 0xB124);
    let agg: Vec<usize> = (0..n).collect();
    let ledger = Arc::new(CommLedger::new());
    let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
    let mut clock = SimClock::new();
    let mut rng = Rng::new(404);
    let model = toy_model(p);
    let mut mar = MarAggregator::new(n, m, g, ledger.clone(), 7)
        .with_exchange(exchange)
        .with_parallel(parallel)
        .with_robust(RobustPolicy { est, trim: 0.25 })
        .with_reputation(0.4);
    ledger.reset(); // drop DHT join traffic
    let mut reports = Vec::new();
    for _ in 0..3 {
        flip(&mut states, &attackers);
        let mut ctx = AggCtx {
            fabric: &fabric,
            clock: &mut clock,
            rng: &mut rng,
            runtime: None,
            model: &model,
            faults: &FaultConfig::OFF,
            links: None,
        };
        reports.push(mar.aggregate(&mut states, &agg, &mut ctx).unwrap());
    }
    let rep = mar.reputation().unwrap().clone();
    (states, ledger.snapshot(), clock.now(), reports, rep)
}

/// (a) Inert attack block ⇒ bit-identical to the seed path: with
/// `frac = 0`, a `mean` estimator and reputation off, every other
/// `attack.*` knob may be set arbitrarily and the run must not change
/// by a single bit (no `AttackPlan`, no fork(4), no score passes).
#[test]
fn inert_attack_config_is_bit_identical_to_seed() {
    let rt = Runtime::new(&marfl::models::default_artifact_dir()).unwrap();
    let base = ExperimentConfig {
        model: "head".into(),
        peers: 9,
        group_size: 3,
        iterations: 4,
        samples_per_peer: 32,
        test_samples: 250,
        eval_every: 4,
        local_batches: 2,
        seed: 991,
        ..Default::default()
    };
    let run = |cfg: ExperimentConfig| {
        let mut t = Trainer::new(cfg, &rt).unwrap();
        let summary = t.run().unwrap();
        let states: Vec<PeerState> = t.states().to_vec();
        (states, summary)
    };
    let (plain_states, plain) = run(base.clone());

    let mut inert = base;
    inert.attack = AttackConfig {
        frac: 0.0, // off — everything below must be dead weight
        mode: AttackMode::Scale,
        scale: 7.0,
        collude: true,
        robust: RobustEstimator::Mean,
        trim: 0.4,
        rep_threshold: 0.0,
    };
    inert.validate().unwrap();
    let (inert_states, irun) = run(inert);

    for (a, b) in plain_states.iter().zip(&inert_states) {
        assert_eq!(a.theta, b.theta, "inert attack block perturbed states");
        assert_eq!(a.momentum, b.momentum);
    }
    assert_eq!(plain.comm, irun.comm, "inert attack block changed traffic");
    assert_eq!(plain.sim_time_s.to_bits(), irun.sim_time_s.to_bits());
    assert_eq!(
        plain.final_loss.to_bits(),
        irun.final_loss.to_bits(),
        "inert attack block changed the model"
    );
    assert_eq!(irun.attackers_active, 0);
    assert_eq!(irun.flagged_peers, 0);
    assert_eq!(irun.flag_precision, 1.0);
    assert_eq!(irun.flag_recall, 1.0);
}

/// (b) Attacked aggregation stays bit-identical across engines for
/// every estimator: the robust kernels and the outlier-score pass all
/// run (or are folded) in deterministic group order, so serial and
/// group-parallel runs agree on states, ledger, clock, flag counters —
/// and on the reputation ledger itself.
#[test]
fn attacked_aggregation_parallel_matches_serial() {
    for est in [
        RobustEstimator::Mean,
        RobustEstimator::TrimmedMean,
        RobustEstimator::Median,
        RobustEstimator::NormClip,
    ] {
        for exchange in
            [GroupExchange::FullGather, GroupExchange::ReduceScatter]
        {
            let (s_states, s_snap, s_clock, s_reps, s_rep) =
                run_attacked_mar(est, exchange, false);
            let (p_states, p_snap, p_clock, p_reps, p_rep) =
                run_attacked_mar(est, exchange, true);
            let tag = format!("{}/{exchange:?}", est.name());
            for (i, (a, b)) in s_states.iter().zip(&p_states).enumerate() {
                assert_eq!(a.theta, b.theta, "{tag}: peer {i} theta diverged");
                assert_eq!(a.momentum, b.momentum, "{tag}: peer {i} momentum");
            }
            assert_eq!(s_snap, p_snap, "{tag}: ledger diverged");
            assert_eq!(s_clock.to_bits(), p_clock.to_bits(), "{tag}: clock");
            assert_eq!(s_reps, p_reps, "{tag}: reports diverged");
            assert_eq!(s_rep, p_rep, "{tag}: reputation ledgers diverged");
        }
    }
}

/// (c) Breakdown point: with `f <= drop_count` corrupted rows, the
/// trimmed-mean center stays within the honest rows' coordinate-wise
/// envelope no matter how extreme the corruption — and the plain mean
/// (sanity check) does not.
#[test]
fn trimmed_mean_respects_breakdown_point() {
    let p = 33;
    let members: Vec<usize> = (0..4).collect();
    let build = || {
        let mut states = random_states(4, p, 0xCAFE);
        // one attacker (== drop_count for k=4, trim=0.25), arbitrarily hot
        for (j, v) in states[2].theta.make_mut_slice().iter_mut().enumerate() {
            *v = if j % 2 == 0 { 1e6 } else { -1e6 };
        }
        states
    };
    let honest = [0usize, 1, 3];
    let pristine = build();
    let (lo, hi): (Vec<f32>, Vec<f32>) = (0..p)
        .map(|j| {
            let vals: Vec<f32> =
                honest.iter().map(|&k| pristine[k].theta.as_slice()[j]).collect();
            (
                vals.iter().copied().fold(f32::INFINITY, f32::min),
                vals.iter().copied().fold(f32::NEG_INFINITY, f32::max),
            )
        })
        .unzip();

    let policy =
        RobustPolicy { est: RobustEstimator::TrimmedMean, trim: 0.25 };
    assert_eq!(policy.drop_count(4), 1);
    let mut states = build();
    robust_average_group_native(&mut states, &members, policy, false);
    for (j, &c) in states[0].theta.as_slice().iter().enumerate() {
        assert!(
            c >= lo[j] - 1e-4 && c <= hi[j] + 1e-4,
            "coordinate {j}: trimmed center {c} left honest envelope \
             [{}, {}]",
            lo[j],
            hi[j]
        );
    }

    // the undefended mean is dragged out of the envelope by the same row
    let mut states = build();
    robust_average_group_native(&mut states, &members, RobustPolicy::MEAN, false);
    let escaped = states[0]
        .theta
        .as_slice()
        .iter()
        .enumerate()
        .filter(|&(j, &c)| c < lo[j] - 1e-4 || c > hi[j] + 1e-4)
        .count();
    assert!(escaped > p / 2, "plain mean must be dominated by the attacker");
}

/// (d) End-to-end scorecard determinism: two identical byzantine runs
/// (sign-flip attackers, trimmed mean + reputation, slow bandwidth
/// redraws) report the exact same attack/defence counters and finish in
/// bit-identical states.
#[test]
fn byzantine_trainer_runs_are_reproducible() {
    let rt = Runtime::new(&marfl::models::default_artifact_dir()).unwrap();
    let mut cfg = ExperimentConfig {
        model: "head".into(),
        peers: 9,
        group_size: 3,
        iterations: 6,
        samples_per_peer: 32,
        test_samples: 250,
        eval_every: 6,
        local_batches: 2,
        seed: 2468,
        ..Default::default()
    };
    cfg.attack = AttackConfig {
        frac: 0.3, // round(0.3 * 9) = 3 ground-truth attackers
        robust: RobustEstimator::TrimmedMean,
        trim: 0.25,
        rep_threshold: 0.4,
        ..AttackConfig::default()
    };
    cfg.faults = FaultConfig {
        bw_dist: BwDist::Uniform,
        bw_min: 0.3,
        bw_max: 0.9,
        bw_redraw_rounds: 2,
        ..FaultConfig::default()
    };
    cfg.validate().unwrap();
    let run = |cfg: ExperimentConfig| {
        let mut t = Trainer::new(cfg, &rt).unwrap();
        let summary = t.run().unwrap();
        let states: Vec<PeerState> = t.states().to_vec();
        (states, summary)
    };
    let (a_states, a) = run(cfg.clone());
    let (b_states, b) = run(cfg);

    assert_eq!(a.attackers_active, 3, "all 3 planted attackers must fire");
    // redraw schedule: iterations 2 and 4 (t % 2 == 0, t > 0)
    assert_eq!(a.bw_redraws, 2);
    assert_eq!(a.attackers_active, b.attackers_active);
    assert_eq!(a.flagged_peers, b.flagged_peers);
    assert_eq!(a.flag_precision.to_bits(), b.flag_precision.to_bits());
    assert_eq!(a.flag_recall.to_bits(), b.flag_recall.to_bits());
    assert_eq!(a.bw_redraws, b.bw_redraws);
    assert_eq!(a.comm, b.comm);
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits());
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    for (x, y) in a_states.iter().zip(&b_states) {
        assert_eq!(x.theta, y.theta);
        assert_eq!(x.momentum, y.momentum);
    }
}
