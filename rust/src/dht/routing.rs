//! Kademlia routing table: 160 k-buckets with LRU eviction.

use super::id::{Key, KEY_BITS};

/// Default bucket capacity (Kademlia's k).
pub const K: usize = 8;

/// One k-bucket: most-recently-seen last.
#[derive(Clone, Debug, Default)]
pub struct KBucket {
    entries: Vec<Key>,
}

impl KBucket {
    fn touch(&mut self, peer: Key, k: usize) {
        if let Some(pos) = self.entries.iter().position(|e| *e == peer) {
            let e = self.entries.remove(pos);
            self.entries.push(e);
        } else if self.entries.len() < k {
            self.entries.push(peer);
        } else {
            // full: drop least-recently-seen (head) — simulation has no
            // liveness pings, so LRU eviction stands in for stale eviction
            self.entries.remove(0);
            self.entries.push(peer);
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Key> {
        self.entries.iter()
    }
}

/// Per-node routing state.
#[derive(Clone, Debug)]
pub struct RoutingTable {
    pub own: Key,
    buckets: Vec<KBucket>,
    /// occupancy bitmap: bit i set ⇔ bucket i non-empty. With ~N=125
    /// nodes only ~⌈log₂N⌉ buckets are populated; `closest` walks set
    /// bits instead of all 160 bucket headers (EXPERIMENTS.md §Perf).
    occupied: [u64; 3],
    k: usize,
}

impl RoutingTable {
    pub fn new(own: Key) -> Self {
        RoutingTable {
            own,
            buckets: vec![KBucket::default(); KEY_BITS],
            occupied: [0; 3],
            k: K,
        }
    }

    /// Record contact with `peer`.
    pub fn insert(&mut self, peer: Key) {
        if let Some(idx) = self.own.bucket_index(&peer) {
            self.buckets[idx].touch(peer, self.k);
            self.occupied[idx / 64] |= 1u64 << (idx % 64);
        }
    }

    /// The `n` known peers closest to `target` (XOR metric).
    ///
    /// Hot path of every DHT lookup (matchmaking issues O(N·G·α·hops) of
    /// these per FL iteration): only occupied buckets are visited,
    /// distances are computed once per contact (not per comparison) and
    /// selection uses `select_nth_unstable` instead of a full sort — see
    /// EXPERIMENTS.md §Perf.
    pub fn closest(&self, target: &Key, n: usize) -> Vec<Key> {
        let mut all: Vec<(crate::dht::id::Distance, Key)> =
            Vec::with_capacity(n * 2);
        for (word_idx, &word) in self.occupied.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let bit = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let bucket = &self.buckets[word_idx * 64 + bit];
                all.extend(bucket.iter().map(|p| (p.distance(target), *p)));
            }
        }
        if all.len() > n {
            all.select_nth_unstable(n - 1);
            all.truncate(n);
        }
        all.sort_unstable();
        all.into_iter().map(|(_, p)| p).collect()
    }

    pub fn contact_count(&self) -> usize {
        self.buckets.iter().map(|b| b.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn insert_and_closest_ordering() {
        let mut rng = Rng::new(3);
        let me = Key::random(&mut rng);
        let mut rt = RoutingTable::new(me);
        let peers: Vec<Key> = (0..50).map(|_| Key::random(&mut rng)).collect();
        for p in &peers {
            rt.insert(*p);
        }
        let target = Key::random(&mut rng);
        let closest = rt.closest(&target, 5);
        assert_eq!(closest.len(), 5);
        for w in closest.windows(2) {
            assert!(w[0].distance(&target) <= w[1].distance(&target));
        }
    }

    #[test]
    fn self_never_inserted() {
        let mut rng = Rng::new(4);
        let me = Key::random(&mut rng);
        let mut rt = RoutingTable::new(me);
        rt.insert(me);
        assert_eq!(rt.contact_count(), 0);
    }

    #[test]
    fn bucket_eviction_bounds_size() {
        let mut rng = Rng::new(5);
        let me = Key([0; 20]);
        let mut rt = RoutingTable::new(me);
        // flood with far peers (mostly land in the top bucket)
        for _ in 0..1000 {
            rt.insert(Key::random(&mut rng));
        }
        for b in &rt.buckets {
            assert!(b.len() <= K);
        }
    }

    #[test]
    fn reinsert_moves_to_tail_not_grows() {
        let mut rng = Rng::new(6);
        let me = Key::random(&mut rng);
        let mut rt = RoutingTable::new(me);
        let p = Key::random(&mut rng);
        rt.insert(p);
        rt.insert(p);
        assert_eq!(rt.contact_count(), 1);
    }
}
