//! PJRT execution backend (`--features pjrt`): loads AOT HLO-text
//! artifacts and executes them through a PJRT CPU client. Pattern follows
//! /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` (cached per entry
//! point) → `execute`.
//!
//! Until concurrent use of the xla binding is measured safe (see ROADMAP
//! "Open items"), EVERY interaction with it — literal construction,
//! compile, execute, literal conversion and drop — happens while holding
//! the single backend lock: each public method acquires the lock first
//! and releases it after all `xla::Literal` temporaries are dropped. The
//! peer-parallel trainer still overlaps its native work (batch gather,
//! state copies) across threads; only the XLA section is serial.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::literal::{lit_f32, lit_i32, to_f32_vec};
use super::StepOut;
use crate::models::ModelMeta;

pub(super) struct PjrtBackend {
    /// client + compiled-executable cache, one lock: conservative
    /// serialization of all XLA calls (compile exactly once per entry,
    /// no concurrent binding use)
    inner: Mutex<PjrtInner>,
    /// artifact directory the HLO text is loaded from
    dir: std::path::PathBuf,
}

struct PjrtInner {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

// SAFETY: the xla binding types are raw-pointer wrappers without auto
// Send/Sync. This backend serializes EVERY interaction with the binding
// — literal construction, compile, execute, literal conversion and drop
// — behind the single `inner` Mutex: each entry point locks before the
// first `xla::Literal` is created and the guard outlives all xla
// temporaries. Cross-thread use therefore reduces to moving pointers
// between threads with externally-synchronized access; no concurrent
// entry into the binding occurs. Revisit (per ROADMAP) once
// shared-client concurrent Execute has been measured safe.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    pub(super) fn new(dir: &std::path::Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtBackend {
            inner: Mutex::new(PjrtInner { client, exes: HashMap::new() }),
            dir: dir.to_path_buf(),
        })
    }

    /// Compile + execute one entry point. Caller holds the backend lock.
    fn execute_locked(
        &self,
        inner: &mut PjrtInner,
        entry: &str,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.compile_locked(inner, entry)?;
        let exe = inner.exes.get(entry).unwrap();
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("execute {entry}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("sync {entry}"))?;
        // every entry point returns a tuple (aot.py lowers return_tuple=True)
        out.to_tuple().with_context(|| format!("untuple {entry}"))
    }

    /// Compile `entry` into the cache if absent. Runs under the backend
    /// lock, so each entry point compiles exactly once even when many
    /// workers hit it simultaneously.
    fn compile_locked(&self, inner: &mut PjrtInner, entry: &str) -> Result<()> {
        if inner.exes.contains_key(entry) {
            return Ok(());
        }
        let path = self.dir.join(format!("{entry}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse {path:?} — run `make artifacts`"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = inner
            .client
            .compile(&comp)
            .with_context(|| format!("compile {entry}"))?;
        inner.exes.insert(entry.to_string(), exe);
        Ok(())
    }

    pub(super) fn warmup(&self, entries: &[String]) -> Result<()> {
        let mut inner = self.inner.lock().expect("pjrt lock");
        for e in entries {
            self.compile_locked(&mut inner, e)?;
        }
        Ok(())
    }

    /// Run a `(theta', mom', loss)` entry point (train_step / kd_step)
    /// over freshly-marshalled literals, entirely under the lock.
    fn step_entry(&self, entry: &str, args: &[xla::Literal], inner: &mut PjrtInner) -> Result<StepOut> {
        let out = self.execute_locked(inner, entry, args)?;
        anyhow::ensure!(out.len() == 3, "{entry} returned {} leaves", out.len());
        Ok(StepOut {
            theta: to_f32_vec(&out[0])?,
            momentum: to_f32_vec(&out[1])?,
            loss: out[2].to_vec::<f32>()?[0],
        })
    }

    pub(super) fn train_step(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        eta: f32,
        mu: f32,
    ) -> Result<StepOut> {
        // lock before any literal is created; every xla temporary below
        // drops before the guard does
        let mut inner = self.inner.lock().expect("pjrt lock");
        let mut dims = vec![m.batch];
        dims.extend(&m.input_shape);
        let args = [
            lit_f32(theta, &[m.padded_len])?,
            lit_f32(momentum, &[m.padded_len])?,
            lit_f32(x, &dims)?,
            lit_i32(y, &[m.batch])?,
            lit_f32(&[eta], &[1])?,
            lit_f32(&[mu], &[1])?,
        ];
        self.step_entry(&format!("{}_train_step", m.name), &args, &mut inner)
    }

    /// In-place variant of [`Self::train_step`]: executes the lowered
    /// step and copies the result back into the caller's buffers, so
    /// PJRT builds satisfy the same `*_into` facade contract the native
    /// backend serves allocation-free.
    pub(super) fn train_step_into(
        &self,
        m: &ModelMeta,
        theta: &mut [f32],
        momentum: &mut [f32],
        x: &[f32],
        y: &[i32],
        eta: f32,
        mu: f32,
    ) -> Result<f32> {
        let out = self.train_step(m, theta, momentum, x, y, eta, mu)?;
        anyhow::ensure!(
            out.theta.len() == theta.len() && out.momentum.len() == momentum.len(),
            "pjrt train_step output shape mismatch"
        );
        theta.copy_from_slice(&out.theta);
        momentum.copy_from_slice(&out.momentum);
        Ok(out.loss)
    }

    /// In-place variant of [`Self::kd_step`] (see
    /// [`Self::train_step_into`]).
    #[allow(clippy::too_many_arguments)]
    pub(super) fn kd_step_into(
        &self,
        m: &ModelMeta,
        theta: &mut [f32],
        momentum: &mut [f32],
        x: &[f32],
        y: &[i32],
        zbar: &[f32],
        lambda: f32,
        eta: f32,
        mu: f32,
    ) -> Result<f32> {
        let out = self.kd_step(m, theta, momentum, x, y, zbar, lambda, eta, mu)?;
        anyhow::ensure!(
            out.theta.len() == theta.len() && out.momentum.len() == momentum.len(),
            "pjrt kd_step output shape mismatch"
        );
        theta.copy_from_slice(&out.theta);
        momentum.copy_from_slice(&out.momentum);
        Ok(out.loss)
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn kd_step(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        zbar: &[f32],
        lambda: f32,
        eta: f32,
        mu: f32,
    ) -> Result<StepOut> {
        let mut inner = self.inner.lock().expect("pjrt lock");
        let mut dims = vec![m.batch];
        dims.extend(&m.input_shape);
        let args = [
            lit_f32(theta, &[m.padded_len])?,
            lit_f32(momentum, &[m.padded_len])?,
            lit_f32(x, &dims)?,
            lit_i32(y, &[m.batch])?,
            lit_f32(zbar, &[m.batch, m.classes])?,
            lit_f32(&[lambda], &[1])?,
            lit_f32(&[eta], &[1])?,
            lit_f32(&[mu], &[1])?,
        ];
        self.step_entry(&format!("{}_kd_step", m.name), &args, &mut inner)
    }

    pub(super) fn logits(&self, m: &ModelMeta, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let mut inner = self.inner.lock().expect("pjrt lock");
        let b = x.len() / m.input_elems();
        let mut dims = vec![b];
        dims.extend(&m.input_shape);
        let args = [lit_f32(theta, &[m.padded_len])?, lit_f32(x, &dims)?];
        let out = self.execute_locked(&mut inner, &format!("{}_logits", m.name), &args)?;
        to_f32_vec(&out[0])
    }

    /// One eval chunk: (summed NLL, correct count).
    pub(super) fn eval_chunk(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(f64, f64)> {
        let mut inner = self.inner.lock().expect("pjrt lock");
        let mut dims = vec![m.eval_chunk];
        dims.extend(&m.input_shape);
        let args = [
            lit_f32(theta, &[m.padded_len])?,
            lit_f32(x, &dims)?,
            lit_i32(y, &[m.eval_chunk])?,
        ];
        let out = self.execute_locked(&mut inner, &format!("{}_eval", m.name), &args)?;
        Ok((
            out[0].to_vec::<f32>()?[0] as f64,
            out[1].to_vec::<f32>()?[0] as f64,
        ))
    }

    pub(super) fn group_mean(&self, m: &ModelMeta, stack: &[f32], k: usize) -> Result<Vec<f32>> {
        let mut inner = self.inner.lock().expect("pjrt lock");
        let args = [lit_f32(stack, &[k, m.padded_len])?];
        let out =
            self.execute_locked(&mut inner, &format!("group_mean_{}_{k}", m.name), &args)?;
        to_f32_vec(&out[0])
    }
}
