//! Communication ledger: the paper's primary measurement instrument.
//!
//! Counters are sharded per thread (cache-line-padded atomic stripes,
//! merged at snapshot) so the ledger can be shared (`Arc`) between the
//! coordinator, the DHT, the fabric and — since the parallel round engine
//! (`exec`) — many worker threads booking concurrently, without the hot
//! path ever bouncing one contended cache line between cores. Totals are
//! exact: booking is commutative addition, so parallel and serial
//! executions of the same schedule produce identical snapshots.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Which plane a message belongs to. The paper's claim is that control
/// traffic (DHT barriers/announcements, O(N log N) small messages) is
/// negligible next to data traffic (model exchange).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// DHT lookups, stores, barrier metadata.
    Control,
    /// Model / momentum / logits payloads.
    Data,
}

/// Number of counter stripes. Power of two, sized a little above typical
/// core counts; threads hash onto stripes, so two workers only share a
/// stripe (never a problem for correctness) when the pool outgrows it.
const LEDGER_SHARDS: usize = 16;

/// One cache-line-aligned stripe of counters (all four live on the same
/// line so a booking thread touches exactly one line).
#[derive(Default)]
#[repr(align(64))]
struct LedgerShard {
    data_bytes: AtomicU64,
    data_msgs: AtomicU64,
    control_bytes: AtomicU64,
    control_msgs: AtomicU64,
}

/// Contention-free byte/message accounting.
pub struct CommLedger {
    shards: [LedgerShard; LEDGER_SHARDS],
}

/// A point-in-time merge of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommSnapshot {
    pub data_bytes: u64,
    pub data_msgs: u64,
    pub control_bytes: u64,
    pub control_msgs: u64,
}

/// Stable per-thread stripe assignment (round-robin at first use).
fn shard_index() -> usize {
    crate::exec::thread_stripe(LEDGER_SHARDS)
}

impl CommLedger {
    pub fn new() -> Self {
        CommLedger { shards: std::array::from_fn(|_| LedgerShard::default()) }
    }

    /// Book one message of `bytes` on `plane`.
    pub fn record(&self, plane: Plane, bytes: u64) {
        self.record_many(plane, 1, bytes);
    }

    /// Book `msgs` messages totalling `bytes` on `plane` in one shot —
    /// the batched form the fabric uses for sequential sends (2 atomic
    /// adds instead of 2·k).
    pub fn record_many(&self, plane: Plane, msgs: u64, bytes: u64) {
        let shard = &self.shards[shard_index()];
        match plane {
            Plane::Data => {
                shard.data_bytes.fetch_add(bytes, Ordering::Relaxed);
                shard.data_msgs.fetch_add(msgs, Ordering::Relaxed);
            }
            Plane::Control => {
                shard.control_bytes.fetch_add(bytes, Ordering::Relaxed);
                shard.control_msgs.fetch_add(msgs, Ordering::Relaxed);
            }
        }
    }

    pub fn snapshot(&self) -> CommSnapshot {
        let mut s = CommSnapshot::default();
        for shard in &self.shards {
            s.data_bytes += shard.data_bytes.load(Ordering::Relaxed);
            s.data_msgs += shard.data_msgs.load(Ordering::Relaxed);
            s.control_bytes += shard.control_bytes.load(Ordering::Relaxed);
            s.control_msgs += shard.control_msgs.load(Ordering::Relaxed);
        }
        s
    }

    pub fn reset(&self) {
        for shard in &self.shards {
            shard.data_bytes.store(0, Ordering::Relaxed);
            shard.data_msgs.store(0, Ordering::Relaxed);
            shard.control_bytes.store(0, Ordering::Relaxed);
            shard.control_msgs.store(0, Ordering::Relaxed);
        }
    }
}

impl Default for CommLedger {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Debug for CommLedger {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CommLedger").field("snapshot", &self.snapshot()).finish()
    }
}

impl CommSnapshot {
    pub fn total_bytes(&self) -> u64 {
        self.data_bytes + self.control_bytes
    }

    /// Delta between two snapshots (e.g. one FL iteration).
    pub fn since(&self, earlier: &CommSnapshot) -> CommSnapshot {
        CommSnapshot {
            data_bytes: self.data_bytes - earlier.data_bytes,
            data_msgs: self.data_msgs - earlier.data_msgs,
            control_bytes: self.control_bytes - earlier.control_bytes,
            control_msgs: self.control_msgs - earlier.control_msgs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn records_per_plane() {
        let l = CommLedger::new();
        l.record(Plane::Data, 100);
        l.record(Plane::Data, 50);
        l.record(Plane::Control, 8);
        let s = l.snapshot();
        assert_eq!(s.data_bytes, 150);
        assert_eq!(s.data_msgs, 2);
        assert_eq!(s.control_bytes, 8);
        assert_eq!(s.control_msgs, 1);
        assert_eq!(s.total_bytes(), 158);
    }

    #[test]
    fn record_many_matches_repeated_record() {
        let a = CommLedger::new();
        for _ in 0..7 {
            a.record(Plane::Data, 33);
        }
        let b = CommLedger::new();
        b.record_many(Plane::Data, 7, 7 * 33);
        assert_eq!(a.snapshot(), b.snapshot());
    }

    #[test]
    fn since_computes_deltas() {
        let l = CommLedger::new();
        l.record(Plane::Data, 10);
        let a = l.snapshot();
        l.record(Plane::Data, 32);
        l.record(Plane::Control, 4);
        let d = l.snapshot().since(&a);
        assert_eq!(d.data_bytes, 32);
        assert_eq!(d.data_msgs, 1);
        assert_eq!(d.control_bytes, 4);
    }

    #[test]
    fn concurrent_recording_is_consistent() {
        let l = Arc::new(CommLedger::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        l.record(Plane::Data, 3);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = l.snapshot();
        assert_eq!(s.data_bytes, 12_000);
        assert_eq!(s.data_msgs, 4_000);
    }

    #[test]
    fn pool_parallel_recording_is_exact() {
        use rayon::prelude::*;
        let l = CommLedger::new();
        crate::exec::pool().install(|| {
            (0..1000u64).into_par_iter().for_each(|i| {
                l.record(Plane::Control, i);
            });
        });
        let s = l.snapshot();
        assert_eq!(s.control_msgs, 1000);
        assert_eq!(s.control_bytes, 999 * 1000 / 2);
    }

    #[test]
    fn reset_zeroes() {
        let l = CommLedger::new();
        l.record(Plane::Control, 9);
        l.reset();
        assert_eq!(l.snapshot(), CommSnapshot::default());
    }
}
