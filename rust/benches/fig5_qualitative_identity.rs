//! Figure 5 — qualitative identity: MAR-FL yields the same test accuracy
//! as client-server FedAvg, RDFL and AR-FL under exact aggregation.
//!
//! Paper claim: all four techniques produce identical global model
//! averages under the given configurations (e.g. 125 = 5³ for MAR), so
//! their accuracy curves coincide. Runs both tasks at a 16-peer grid and
//! asserts the curves match pointwise.

#[path = "common/mod.rs"]
mod common;

use common::{assert_stable_columns, emit_bench_report, emit_csv, iters, runtime, timed};
use marfl::config::{ExperimentConfig, Strategy};
use marfl::fl::Trainer;

fn main() {
    let rt = runtime();
    let t = iters(16, 40);
    let mut rows = vec![vec![
        "model".into(),
        "strategy".into(),
        "iteration".into(),
        "accuracy".into(),
    ]];
    for model in ["head", "cnn"] {
        println!("Figure 5 — {model}: 16 peers (4² grid), T={t}");
        let base = ExperimentConfig {
            model: model.into(),
            peers: 16,
            group_size: 4,
            mar_rounds: 2,
            iterations: t,
            samples_per_peer: 64,
            test_samples: 1000,
            eval_every: 4,
            seed: 3141,
            ..Default::default()
        };
        let mut curves = Vec::new();
        for strategy in [
            Strategy::MarFl,
            Strategy::FedAvg,
            Strategy::Rdfl,
            Strategy::ArFl,
        ] {
            let cfg = ExperimentConfig { strategy, ..base.clone() };
            let run = timed(strategy.name(), || {
                Trainer::new(cfg, &rt).unwrap().run().unwrap()
            });
            for p in &run.curve.points {
                rows.push(vec![
                    model.into(),
                    strategy.name().into(),
                    p.iteration.to_string(),
                    format!("{:.4}", p.accuracy),
                ]);
            }
            curves.push((strategy.name(), run.curve));
        }
        // pointwise identity vs the MAR-FL curve
        let (ref_name, ref_curve) = &curves[0];
        for (name, curve) in &curves[1..] {
            for (a, b) in ref_curve.points.iter().zip(&curve.points) {
                assert!(
                    (a.accuracy - b.accuracy).abs() < 0.02,
                    "{model}: {name} diverges from {ref_name} at iter {}: {} vs {}",
                    a.iteration,
                    b.accuracy,
                    a.accuracy
                );
            }
            println!("  {name} matches {ref_name} pointwise (±2%)");
        }
        println!();
    }
    assert_stable_columns(
        "fig5_qualitative_identity.csv",
        &rows,
        &[
            "model",
            "strategy",
            "iteration",
            "accuracy",
        ],
    );
    emit_csv("fig5_qualitative_identity.csv", &rows);
    emit_bench_report("identity", "qualitative_identity", &rows);
    println!("qualitative identity holds on both tasks");
}
