//! `marfl` — MAR-FL launcher.
//!
//! Subcommands:
//!   train        run one experiment (preset file + key=value overrides)
//!   sweep        compare aggregation strategies on one configuration
//!   info         inspect the artifact registry
//!   trace-check  validate a round_trace.jsonl against marfl-trace/v1
//!   trajectory   fold results/BENCH_*.json into BENCH_trajectory.json
//!
//! CLI parsing is hand-rolled (offline environment: no clap); see
//! `marfl train --help`.

use std::path::PathBuf;
use std::process::ExitCode;

use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;
use marfl::metrics::{write_csv, write_json};
use marfl::models::default_artifact_dir;
use marfl::runtime::Runtime;
use marfl::util::json::{arr, num, obj, s, Json};

const TRAIN_HELP: &str = "\
marfl — MAR-FL launcher

USAGE:
  marfl train [--config <preset.toml>] [--set key=value]... \\
              [--artifacts <dir>] [--csv <out.csv>] [--json <out.json>] \\
              [--trace <round_trace.jsonl>]
  marfl sweep --strategies marfl,rdfl,arfl,fedavg [--set key=value]... \\
              [--csv <out.csv>]
  marfl info  [--artifacts <dir>]
  marfl trace-check <round_trace.jsonl>
  marfl trajectory [--dir <results>]

Common keys for --set:
  strategy=marfl|rdfl|arfl|fedavg|bar|gossip|saps   model=cnn|head
  peers=125  iterations=50  group_size=5  mar_rounds=0  reduce_scatter=true
  mar.rs_drop=0.0 (chunk-owner drop probability under reduce_scatter)
  participation=1.0  dropout=0.0  churn.model=markov
  kd.enabled=true  dp.enabled=true  dp.noise_multiplier=0.3
";

fn main() -> ExitCode {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    init_logging();
    if args.is_empty() {
        eprintln!(
            "usage: marfl <train|sweep|info|trace-check|trajectory> [options]\n\n{TRAIN_HELP}"
        );
        return ExitCode::from(2);
    }
    let cmd = args.remove(0);
    let result = match cmd.as_str() {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "info" => cmd_info(&args),
        "trace-check" => cmd_trace_check(&args),
        "trajectory" => cmd_trajectory(&args),
        "--help" | "-h" | "help" => {
            println!("{TRAIN_HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{TRAIN_HELP}");
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn init_logging() {
    struct StderrLog;
    impl log::Log for StderrLog {
        fn enabled(&self, md: &log::Metadata) -> bool {
            md.level() <= log::Level::Info
        }
        fn log(&self, rec: &log::Record) {
            if self.enabled(rec.metadata()) {
                eprintln!("[{}] {}", rec.level(), rec.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: StderrLog = StderrLog;
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(if std::env::var_os("MARFL_QUIET").is_some() {
        log::LevelFilter::Warn
    } else {
        log::LevelFilter::Info
    });
}

struct Flags {
    config: Option<PathBuf>,
    sets: Vec<String>,
    artifacts: PathBuf,
    csv: Option<PathBuf>,
    json: Option<PathBuf>,
    trace: Option<PathBuf>,
    dir: Option<PathBuf>,
    strategies: Vec<String>,
}

fn parse_flags(args: &[String]) -> anyhow::Result<Flags> {
    let mut f = Flags {
        config: None,
        sets: Vec::new(),
        artifacts: default_artifact_dir(),
        csv: None,
        json: None,
        trace: None,
        dir: None,
        strategies: Vec::new(),
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut value = |name: &str| -> anyhow::Result<String> {
            it.next()
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("{name} requires a value"))
        };
        match a.as_str() {
            "--config" => f.config = Some(PathBuf::from(value("--config")?)),
            "--set" => f.sets.push(value("--set")?),
            "--artifacts" => f.artifacts = PathBuf::from(value("--artifacts")?),
            "--csv" => f.csv = Some(PathBuf::from(value("--csv")?)),
            "--json" => f.json = Some(PathBuf::from(value("--json")?)),
            "--trace" => f.trace = Some(PathBuf::from(value("--trace")?)),
            "--dir" => f.dir = Some(PathBuf::from(value("--dir")?)),
            "--strategies" => {
                f.strategies = value("--strategies")?
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect()
            }
            "--help" | "-h" => {
                println!("{TRAIN_HELP}");
                std::process::exit(0);
            }
            other => anyhow::bail!("unknown flag {other:?}"),
        }
    }
    Ok(f)
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let flags = parse_flags(args)?;
    let cfg = match &flags.config {
        Some(path) => ExperimentConfig::load(path, &flags.sets)?,
        None => {
            let mut c = ExperimentConfig::default();
            c.apply_overrides(&flags.sets)?;
            c.validate()?;
            c
        }
    };
    log::info!(
        "training: strategy={} model={} peers={} T={} M={} G={}",
        cfg.strategy.name(),
        cfg.model,
        cfg.peers,
        cfg.iterations,
        cfg.group_size,
        cfg.effective_mar_rounds(),
    );
    let rt = Runtime::new(&flags.artifacts)?;
    let mut trainer =
        Trainer::builder(cfg, &rt).trace(flags.trace.is_some()).build()?;
    let summary = trainer.run()?;
    if let Some(path) = &flags.trace {
        trainer.write_trace(path)?;
        log::info!("round-event trace written to {path:?}");
    }

    println!(
        "final: acc={:.4} loss={:.4} iterations={} data={:.2} MiB control={:.2} MiB sim_time={:.1}s{}",
        summary.final_accuracy,
        summary.final_loss,
        summary.iterations_run,
        summary.comm.data_bytes as f64 / (1 << 20) as f64,
        summary.comm.control_bytes as f64 / (1 << 20) as f64,
        summary.sim_time_s,
        summary
            .dp
            .epsilon
            .map(|e| format!(" epsilon={e:.2}"))
            .unwrap_or_default(),
    );
    if let Some(path) = &flags.csv {
        write_csv(path, &summary.curve.csv_rows())?;
        log::info!("curve written to {path:?}");
    }
    if let Some(path) = &flags.json {
        let points: Vec<Json> = summary
            .curve
            .points
            .iter()
            .map(|p| {
                obj(vec![
                    ("iteration", num(p.iteration as f64)),
                    ("data_bytes", num(p.data_bytes as f64)),
                    ("control_bytes", num(p.control_bytes as f64)),
                    ("loss", num(p.loss)),
                    ("accuracy", num(p.accuracy)),
                    ("sim_time_s", num(p.sim_time_s)),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("label", s(&summary.curve.label)),
            ("final_accuracy", num(summary.final_accuracy)),
            ("data_bytes", num(summary.comm.data_bytes as f64)),
            ("control_bytes", num(summary.comm.control_bytes as f64)),
            ("sim_time_s", num(summary.sim_time_s)),
            ("epsilon", summary.dp.epsilon.map(num).unwrap_or(Json::Null)),
            ("curve", arr(points)),
        ]);
        write_json(path, &doc)?;
        log::info!("summary written to {path:?}");
    }
    Ok(())
}

/// Run the same configuration under several aggregation strategies and
/// print a comparison table (the paper's core experimental move).
fn cmd_sweep(args: &[String]) -> anyhow::Result<()> {
    let flags = parse_flags(args)?;
    let strategies = if flags.strategies.is_empty() {
        vec!["marfl".into(), "fedavg".into(), "rdfl".into(), "arfl".into()]
    } else {
        flags.strategies.clone()
    };
    let rt = Runtime::new(&flags.artifacts)?;
    let mut rows = vec![vec![
        "strategy".into(),
        "final_accuracy".into(),
        "data_bytes".into(),
        "control_bytes".into(),
        "sim_time_s".into(),
        "epsilon".into(),
    ]];
    println!(
        "{:<8} {:>10} {:>12} {:>12} {:>10} {:>8}",
        "strategy", "accuracy", "data(MiB)", "ctrl(MiB)", "sim(s)", "epsilon"
    );
    for name in &strategies {
        let mut cfg = match &flags.config {
            Some(path) => ExperimentConfig::load(path, &flags.sets)?,
            None => {
                let mut c = ExperimentConfig::default();
                c.apply_overrides(&flags.sets)?;
                c
            }
        };
        cfg.strategy = marfl::config::Strategy::parse(name)?;
        cfg.validate()?;
        let mut trainer = Trainer::new(cfg, &rt)?;
        let s = trainer.run()?;
        println!(
            "{:<8} {:>10.4} {:>12.1} {:>12.2} {:>10.1} {:>8}",
            name,
            s.final_accuracy,
            s.comm.data_bytes as f64 / (1 << 20) as f64,
            s.comm.control_bytes as f64 / (1 << 20) as f64,
            s.sim_time_s,
            s.dp.epsilon.map(|e| format!("{e:.1}")).unwrap_or_else(|| "-".into()),
        );
        rows.push(vec![
            name.clone(),
            format!("{:.4}", s.final_accuracy),
            s.comm.data_bytes.to_string(),
            s.comm.control_bytes.to_string(),
            format!("{:.2}", s.sim_time_s),
            s.dp.epsilon.map(|e| format!("{e:.3}")).unwrap_or_default(),
        ]);
    }
    if let Some(path) = &flags.csv {
        write_csv(path, &rows)?;
        log::info!("sweep written to {path:?}");
    }
    Ok(())
}

fn cmd_info(args: &[String]) -> anyhow::Result<()> {
    let flags = parse_flags(args)?;
    let meta = marfl::models::ArtifactMeta::load(&flags.artifacts)?;
    println!("artifacts: {:?}", meta.dir);
    println!(
        "strip={} kd_tau={} group_sizes={:?}",
        meta.strip, meta.kd_tau, meta.group_sizes
    );
    for (name, m) in &meta.models {
        println!(
            "  model {name}: P={} P_pad={} input={:?} classes={} batch={} eval_chunk={} ({} artifacts)",
            m.param_count,
            m.padded_len,
            m.input_shape,
            m.classes,
            m.batch,
            m.eval_chunk,
            m.artifacts.len()
        );
    }
    Ok(())
}

/// Validate a round-event trace file against the `marfl-trace/v1`
/// schema (header, per-line events, count). Exit 0 iff valid — the CI
/// traced-run step gates on this.
fn cmd_trace_check(args: &[String]) -> anyhow::Result<()> {
    let path = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .ok_or_else(|| anyhow::anyhow!("usage: marfl trace-check <round_trace.jsonl>"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {path:?}: {e}"))?;
    let trace = marfl::telemetry::RoundTrace::parse_jsonl(&text)?;
    println!(
        "{path}: valid {} trace, {} events",
        marfl::telemetry::TRACE_SCHEMA,
        trace.len()
    );
    Ok(())
}

/// Fold every `BENCH_*.json` in the results dir into one
/// `BENCH_trajectory.json` (schema `marfl-trajectory/v1`) — the single
/// document perf-trajectory tooling reads. `--dir` overrides `results/`.
fn cmd_trajectory(args: &[String]) -> anyhow::Result<()> {
    let flags = parse_flags(args)?;
    let dir = flags.dir.unwrap_or_else(|| PathBuf::from("results"));
    let path = marfl::telemetry::write_trajectory(&dir)?;
    println!("-> {}", path.display());
    Ok(())
}
