//! BrainTorrent-style gossip (Roy et al. 2019) — Table 1 related work.
//!
//! Serverless P2P flexibility through dynamic model fetching and merging:
//! each round, every peer pulls the model of one uniformly random other
//! peer and merges by (weighted) averaging. No synchronized global
//! aggregation exists — information spreads epidemically, which is why the
//! paper calls gossip's global propagation "inefficient" and excludes it
//! from the evaluation: reaching consensus takes Θ(log N) *iterations*
//! (each a full local-update round), versus MAR's G rounds *within* one
//! iteration, and progress is sensitive to churn.
//!
//! Implemented with `fanout` pulls per peer per iteration (BrainTorrent's
//! dynamic fetching ≈ fanout 1).

use anyhow::Result;

use super::robust::{l2_norm, RobustEstimator, RobustPolicy};
use super::{payload_bytes, AggCtx, AggReport, Aggregate, PeerState};
use crate::metrics::Plane;
use crate::net::{FaultCounters, LinkFault};

#[derive(Debug)]
pub struct Gossip {
    /// models pulled per peer per iteration
    pub fanout: usize,
    /// Robust merge policy. Gossip merges are pairwise (k = 2), where
    /// coordinate-wise trimming and the median both degenerate to the
    /// plain mean — only `norm_clip` changes behaviour, scaling a
    /// pulled state whose θ norm exceeds the puller's own down to that
    /// norm before merging (the epidemic analogue of clipping at the
    /// group median). `Mean` keeps the exact legacy merge.
    robust: RobustPolicy,
}

impl Default for Gossip {
    fn default() -> Self {
        Gossip { fanout: 1, robust: RobustPolicy::MEAN }
    }
}

impl Gossip {
    /// Select the pairwise merge policy.
    pub fn with_robust(mut self, robust: RobustPolicy) -> Self {
        self.robust = robust;
        self
    }
}

impl Aggregate for Gossip {
    fn name(&self) -> &'static str {
        "gossip"
    }

    fn aggregate(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport> {
        let fp = ctx.faults;
        let mut faults = FaultCounters::default();
        // fault plan: crashed peers sit the round out entirely (draws
        // gated — the fault-free path consumes no extra randomness)
        let live: Vec<usize> = if fp.crash_prob > 0.0 {
            agg.iter()
                .copied()
                .filter(|_| {
                    if ctx.rng.chance(fp.crash_prob) {
                        faults.crashes += 1;
                        false
                    } else {
                        true
                    }
                })
                .collect()
        } else {
            agg.to_vec()
        };
        let agg = &live[..];
        let n = agg.len();
        if n < 2 {
            return Ok(AggReport { faults, ..Default::default() });
        }
        let bytes = payload_bytes(states, agg);
        // pull targets are drawn serially (deterministic rng schedule),
        // then the per-peer merges fan out: each lane mutates only its
        // own peer and reads the shared round-start snapshot
        let pulls: Vec<Vec<usize>> = (0..n)
            .map(|slot| {
                (0..self.fanout)
                    // pull from a uniformly random *other* peer
                    .map(|_| (slot + 1 + ctx.rng.below(n - 1)) % n)
                    .collect()
            })
            .collect();
        // per-pull link draws (serial, pull order): a pull whose
        // transfer times out books its attempts and probes but merges
        // nothing — epidemic spread just misses that edge this round
        let pull_links: Vec<Vec<LinkFault>> = if fp.link_faults_enabled() {
            pulls
                .iter()
                .enumerate()
                .map(|(slot, ps)| {
                    ps.iter()
                        .map(|&other| {
                            // a pull transfers other → slot; that directed
                            // link keys the Gilbert–Elliott chain
                            let lf = fp.draw_directed(
                                agg[other],
                                agg[slot],
                                1,
                                false,
                                ctx.links.as_deref_mut(),
                                ctx.rng,
                            );
                            faults.absorb(&lf);
                            lf
                        })
                        .collect()
                })
                .collect()
        } else {
            Vec::new()
        };
        // snapshot: pulls within one round all see round-start models —
        // shared handles, zero copies; the per-peer make_mut below
        // detaches each merger from its own snapshot entry on first write
        let snapshot: Vec<(super::Theta, super::Theta)> = agg
            .iter()
            .map(|&i| (states[i].theta.clone(), states[i].momentum.clone()))
            .collect();
        let fabric = ctx.fabric;
        let clip = self.robust.est == RobustEstimator::NormClip;
        let lane_times =
            crate::exec::par_map_at(states, agg, |slot, st| {
                let mut lane = 0.0;
                for (pi, &other) in pulls[slot].iter().enumerate() {
                    match pull_links.get(slot).map(|ls| ls[pi]) {
                        Some(lf) => {
                            lane += fabric.send_faulty(bytes, Plane::Data, &lf);
                            if lf.lost() {
                                continue; // booked, never arrived
                            }
                        }
                        None => lane += fabric.send(bytes, Plane::Data),
                    }
                    let (ot, om) = &snapshot[other];
                    // norm-clip: damp a pulled state louder than our own
                    // (f32 factor so the clean 1.0 path stays bit-exact)
                    let w = if clip {
                        let own = l2_norm(&st.theta);
                        let pulled = l2_norm(ot);
                        if pulled > own && pulled > 0.0 {
                            (own / pulled) as f32
                        } else {
                            1.0
                        }
                    } else {
                        1.0
                    };
                    // merge: equal-weight average of own and pulled state
                    for (dst, &v) in st.theta.make_mut().iter_mut().zip(ot) {
                        *dst = 0.5 * (*dst + w * v);
                    }
                    for (dst, &v) in st.momentum.make_mut().iter_mut().zip(om) {
                        *dst = 0.5 * (*dst + w * v);
                    }
                }
                lane
            })?;
        ctx.clock.parallel(lane_times);
        Ok(AggReport { rounds: 1, groups: n, faults, ..Default::default() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::test_support::*;
    use crate::coordinator::mixing::avg_distortion;

    fn thetas(states: &[PeerState]) -> Vec<crate::params::Theta> {
        states.iter().map(|s| s.theta.clone()).collect()
    }

    #[test]
    fn linear_traffic_per_iteration() {
        let n = 20;
        let mut states = random_states(n, 16, 50);
        let agg: Vec<usize> = (0..n).collect();
        let mut tc = TestCtx::new(16);
        let mut ctx = tc.ctx();
        Gossip::default().aggregate(&mut states, &agg, &mut ctx).unwrap();
        // fanout 1: exactly N transfers — O(N), cheap per iteration
        assert_eq!(tc.ledger.snapshot().data_msgs as usize, n);
    }

    #[test]
    fn gossip_reduces_distortion_but_slower_than_mar() {
        let n = 27;
        let p = 32;
        let agg: Vec<usize> = (0..n).collect();

        // gossip: one iteration of fanout-1 pulls
        let mut g_states = random_states(n, p, 51);
        let before = avg_distortion(&thetas(&g_states));
        let mut tc = TestCtx::new(p);
        let mut ctx = tc.ctx();
        Gossip::default().aggregate(&mut g_states, &agg, &mut ctx).unwrap();
        let after_gossip = avg_distortion(&thetas(&g_states));

        // MAR: one iteration (G=3 rounds) from the identical start
        let mut m_states = random_states(n, p, 51);
        let mut tc2 = TestCtx::new(p);
        let mut mar = crate::coordinator::MarAggregator::new(
            n,
            3,
            3,
            tc2.ledger.clone(),
            52,
        );
        let mut ctx2 = tc2.ctx();
        mar.aggregate(&mut m_states, &agg, &mut ctx2).unwrap();
        let after_mar = avg_distortion(&thetas(&m_states));

        assert!(after_gossip < before, "gossip must mix at least a little");
        assert!(
            after_mar < after_gossip * 1e-3,
            "MAR must mix orders of magnitude faster per iteration: \
             gossip {after_gossip:.3e} vs MAR {after_mar:.3e}"
        );
    }

    #[test]
    fn gossip_preserves_mean_in_expectation_only() {
        // single pull-merge is NOT mean-preserving per round (pull
        // weights are asymmetric); over many rounds it concentrates near
        // the mean. Verify long-run consensus lands within the initial
        // spread of the true mean.
        let n = 16;
        let p = 4;
        let mut states = random_states(n, p, 53);
        let agg: Vec<usize> = (0..n).collect();
        let (want, _) = crate::aggregation::mean_of(&states, &agg);
        let mut tc = TestCtx::new(p);
        let mut g = Gossip::default();
        for _ in 0..60 {
            let mut ctx = tc.ctx();
            g.aggregate(&mut states, &agg, &mut ctx).unwrap();
        }
        let spread = avg_distortion(&thetas(&states));
        assert!(spread < 1e-4, "gossip should reach near-consensus: {spread}");
        // consensus point is within ~1 sigma of the true mean
        for (got, want) in states[0].theta.iter().zip(&want) {
            assert!((got - want).abs() < 1.0, "{got} vs {want}");
        }
    }

    #[test]
    fn norm_clip_damps_amplified_pulls() {
        // two peers: each pulls the other. Peer 1's state is amplified
        // 100×; a clipped merge keeps peer 0 inside its own norm, while
        // the plain merge blows it up ~50×.
        let mk = || {
            let mut states = random_states(2, 16, 55);
            for v in states[1].theta.make_mut_slice() {
                *v *= 100.0;
            }
            states
        };
        let own_norm = l2_norm(&mk()[0].theta);
        let clip_policy =
            RobustPolicy { est: RobustEstimator::NormClip, trim: 0.25 };
        let mut clipped = mk();
        let mut tc = TestCtx::new(16);
        Gossip::default()
            .with_robust(clip_policy)
            .aggregate(&mut clipped, &[0, 1], &mut tc.ctx())
            .unwrap();
        assert!(l2_norm(&clipped[0].theta) <= own_norm * 1.01);
        let mut plain = mk();
        let mut tc2 = TestCtx::new(16);
        Gossip::default()
            .aggregate(&mut plain, &[0, 1], &mut tc2.ctx())
            .unwrap();
        assert!(l2_norm(&plain[0].theta) > 10.0 * own_norm);
    }

    #[test]
    fn fanout_increases_traffic_linearly() {
        let n = 10;
        let mut states = random_states(n, 8, 54);
        let agg: Vec<usize> = (0..n).collect();
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        Gossip { fanout: 3, ..Default::default() }
            .aggregate(&mut states, &agg, &mut ctx)
            .unwrap();
        assert_eq!(tc.ledger.snapshot().data_msgs as usize, 3 * n);
    }
}
