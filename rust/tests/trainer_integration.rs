//! End-to-end trainer integration: the paper's qualitative claims at
//! miniature scale (head task, 8–16 peers, a few iterations).

use marfl::config::{ExperimentConfig, Strategy};
use marfl::fl::Trainer;
use marfl::models::default_artifact_dir;
use marfl::runtime::Runtime;
use marfl::testing::assert_allclose;

fn runtime() -> Runtime {
    // runs against the lowered artifacts when present, the native backend
    // otherwise — trainer behaviour must hold for both
    Runtime::new(&default_artifact_dir()).expect("runtime")
}

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        model: "head".into(),
        peers: 8,
        iterations: 4,
        group_size: 2,
        mar_rounds: 0, // auto: 2^3 = 8 -> 3 rounds
        samples_per_peer: 32,
        test_samples: 250,
        eval_every: 2,
        seed: 99,
        ..Default::default()
    }
}

/// Figure 5 in miniature: with full participation and exact-grid MAR, all
/// four techniques yield identical global averages, hence identical
/// consensus models given identical local updates.
#[test]
fn all_strategies_identical_under_exact_aggregation() {
    let rt = runtime();
    let mut finals: Vec<(String, Vec<f32>)> = Vec::new();
    for strategy in [
        Strategy::MarFl,
        Strategy::FedAvg,
        Strategy::Rdfl,
        Strategy::ArFl,
    ] {
        let cfg = ExperimentConfig { strategy, ..base_cfg() };
        let mut trainer = Trainer::new(cfg, &rt).unwrap();
        trainer.run().unwrap();
        let consensus = {
            let states = trainer.states();
            let all: Vec<usize> = (0..states.len()).collect();
            marfl::aggregation::mean_of(states, &all).0
        };
        finals.push((strategy.name().to_string(), consensus));
    }
    let (ref_name, ref_theta) = &finals[0];
    for (name, theta) in &finals[1..] {
        assert_allclose(theta, ref_theta, 1e-3, 1e-4);
        eprintln!("{name} matches {ref_name}");
    }
}

/// Figure 1 in miniature: per-iteration data bytes obey
/// FedAvg < MAR-FL << RDFL ≈ AR-FL.
#[test]
fn communication_ordering_matches_paper() {
    let rt = runtime();
    let mut bytes = std::collections::BTreeMap::new();
    for strategy in [
        Strategy::MarFl,
        Strategy::FedAvg,
        Strategy::Rdfl,
        Strategy::ArFl,
    ] {
        let cfg = ExperimentConfig {
            strategy,
            peers: 16,
            group_size: 4, // 16 = 4^2
            iterations: 2,
            ..base_cfg()
        };
        let mut trainer = Trainer::new(cfg, &rt).unwrap();
        let summary = trainer.run().unwrap();
        bytes.insert(strategy.name(), summary.comm.data_bytes);
    }
    assert!(bytes["fedavg"] < bytes["marfl"], "{bytes:?}");
    assert!(bytes["marfl"] < bytes["rdfl"], "{bytes:?}");
    assert!(bytes["marfl"] < bytes["arfl"], "{bytes:?}");
    // N=16, M=4, G=2: MAR = N·G·(M−1) = 96 transfers vs N(N−1) = 240
    let ratio = bytes["rdfl"] as f64 / bytes["marfl"] as f64;
    assert!(
        (1.5..6.0).contains(&ratio),
        "RDFL/MAR ratio {ratio} out of range (expect ~2.5 at N=16)"
    );
}

/// Training makes progress: accuracy well above chance after a few
/// iterations on the head task.
#[test]
fn marfl_training_beats_chance() {
    let rt = runtime();
    let cfg = ExperimentConfig {
        iterations: 10,
        samples_per_peer: 64,
        ..base_cfg()
    };
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let summary = trainer.run().unwrap();
    // 20 classes -> chance 5%
    assert!(
        summary.final_accuracy > 0.25,
        "accuracy {} barely above chance",
        summary.final_accuracy
    );
    // loss decreased along the curve
    let first = summary.curve.points.first().unwrap().loss;
    let last = summary.curve.points.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last}");
}

/// Dropout resilience (Figure 3): 20% dropout must not collapse accuracy
/// relative to the no-churn run.
#[test]
fn dropout_does_not_collapse_training() {
    let rt = runtime();
    let clean = {
        let cfg = ExperimentConfig { iterations: 8, ..base_cfg() };
        Trainer::new(cfg, &rt).unwrap().run().unwrap()
    };
    let churned = {
        let cfg = ExperimentConfig { iterations: 8, dropout: 0.2, ..base_cfg() };
        Trainer::new(cfg, &rt).unwrap().run().unwrap()
    };
    assert!(
        churned.final_accuracy > clean.final_accuracy - 0.15,
        "dropout collapsed training: {} vs {}",
        churned.final_accuracy,
        clean.final_accuracy
    );
}

/// Moshpit-KD runs and the trainer still learns (Figure 2 machinery).
#[test]
fn kd_enabled_trains_and_books_extra_comm() {
    let rt = runtime();
    let mut plain_cfg = ExperimentConfig { iterations: 4, ..base_cfg() };
    plain_cfg.kd.enabled = false;
    let plain = Trainer::new(plain_cfg, &rt).unwrap().run().unwrap();

    let mut kd_cfg = ExperimentConfig { iterations: 4, ..base_cfg() };
    kd_cfg.kd.enabled = true;
    kd_cfg.kd.k_iterations = 2;
    let kd = Trainer::new(kd_cfg, &rt).unwrap().run().unwrap();

    assert!(
        kd.comm.data_bytes > plain.comm.data_bytes,
        "MKD must increase per-iteration load: {} vs {}",
        kd.comm.data_bytes,
        plain.comm.data_bytes
    );
    assert!(kd.final_accuracy > 0.10, "KD run failed to learn");
}

/// DP runs end to end: ε accounted, training degrades gracefully rather
/// than diverging (Figure 4 machinery).
#[test]
fn dp_training_accounts_epsilon() {
    let rt = runtime();
    let mut cfg = ExperimentConfig { iterations: 6, ..base_cfg() };
    cfg.dp.enabled = true;
    cfg.dp.noise_multiplier = 0.3;
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let summary = trainer.run().unwrap();
    let eps = summary.dp.epsilon.expect("epsilon must be reported");
    assert!(eps > 0.0 && eps.is_finite());
    assert!(summary.final_loss.is_finite());
    // same T, more noise -> smaller ε
    let mut cfg2 = ExperimentConfig { iterations: 6, ..base_cfg() };
    cfg2.dp.enabled = true;
    cfg2.dp.noise_multiplier = 0.6;
    let summary2 = Trainer::new(cfg2, &rt).unwrap().run().unwrap();
    assert!(
        summary2.dp.epsilon.unwrap() < eps,
        "more noise must mean less privacy loss"
    );
}

/// Partial participation degrades utility but the system keeps working
/// (Figure 3's main axis).
#[test]
fn partial_participation_trains_with_less_comm() {
    let rt = runtime();
    let full = {
        let cfg = ExperimentConfig { iterations: 6, ..base_cfg() };
        Trainer::new(cfg, &rt).unwrap().run().unwrap()
    };
    let half = {
        let cfg = ExperimentConfig {
            iterations: 6,
            participation: 0.5,
            ..base_cfg()
        };
        Trainer::new(cfg, &rt).unwrap().run().unwrap()
    };
    assert!(
        half.comm.data_bytes < full.comm.data_bytes,
        "fewer participants must mean less traffic"
    );
    assert!(half.final_loss.is_finite());
}

/// MAR control plane exists and stays far below the data plane.
#[test]
fn control_plane_negligible_in_real_run() {
    let rt = runtime();
    let cfg = ExperimentConfig { iterations: 4, model: "cnn".into(), ..base_cfg() };
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let summary = trainer.run().unwrap();
    assert!(summary.comm.control_bytes > 0);
    assert!(summary.dht_hops.unwrap() > 0);
    assert!(
        summary.comm.control_bytes * 5 < summary.comm.data_bytes,
        "control {} vs data {}",
        summary.comm.control_bytes,
        summary.comm.data_bytes
    );
}

/// BAR (Appendix B.3): byte-optimal but leaves the non-power-of-two
/// remainder of A_t stale — measurably less traffic than MAR-FL, and
/// with 12 peers only 8 aggregate.
#[test]
fn bar_cheap_but_excludes_stragglers() {
    let rt = runtime();
    let run = |strategy| {
        let cfg = ExperimentConfig {
            strategy,
            peers: 12, // not a power of two: butterfly covers 8
            group_size: 2,
            mar_rounds: 4,
            iterations: 3,
            ..base_cfg()
        };
        let mut t = Trainer::new(cfg, &rt).unwrap();
        let s = t.run().unwrap();
        // spread of per-peer states: BAR leaves 4 peers un-aggregated
        let states = t.states();
        let all: Vec<usize> = (0..states.len()).collect();
        let thetas: Vec<_> =
            states.iter().map(|st| st.theta.clone()).collect();
        let _ = all;
        (s, marfl::coordinator::mixing::avg_distortion(&thetas))
    };
    let (bar, bar_spread) = run(Strategy::Bar);
    let (mar, mar_spread) = run(Strategy::MarFl);
    assert!(
        bar.comm.data_bytes < mar.comm.data_bytes,
        "BAR must be cheaper on the wire: {} vs {}",
        bar.comm.data_bytes,
        mar.comm.data_bytes
    );
    // MAR reaches (near-)consensus across ALL peers; BAR leaves the
    // stragglers far from it
    assert!(
        bar_spread > mar_spread * 5.0,
        "BAR should leave stragglers dispersed: {bar_spread:.2e} vs {mar_spread:.2e}"
    );
}

/// Kitchen sink: KD + DP + partial participation + dropout + approximate
/// aggregation all composed in one run — everything stays finite and the
/// books balance.
#[test]
fn kitchen_sink_composition() {
    let rt = runtime();
    let mut cfg = ExperimentConfig {
        peers: 20, // no perfect grid -> approximate mode
        group_size: 3,
        mar_rounds: 3,
        iterations: 6,
        participation: 0.8,
        dropout: 0.1,
        ..base_cfg()
    };
    cfg.kd.enabled = true;
    cfg.kd.k_iterations = 2;
    cfg.dp.enabled = true;
    cfg.dp.noise_multiplier = 0.3;
    let mut trainer = Trainer::new(cfg, &rt).unwrap();
    let summary = trainer.run().unwrap();
    assert!(summary.final_loss.is_finite());
    assert!(summary.dp.epsilon.unwrap().is_finite());
    assert!(summary.comm.data_bytes > 0);
    assert!(summary.comm.control_bytes > 0);
    assert!(summary.sim_time_s > 0.0);
    // every peer state stayed finite and correctly shaped
    for st in trainer.states() {
        assert_eq!(st.theta.len(), trainer.model().padded_len);
        assert_eq!(st.momentum.len(), trainer.model().padded_len);
        assert!(st.theta.iter().all(|v| v.is_finite()));
    }
}

/// Reduce-scatter ablation: same exactness, ~M/2 x less group traffic.
#[test]
fn reduce_scatter_mode_trains_identically() {
    let rt = runtime();
    // M=4 groups: RS moves 2(k−1)/k = 1.5 state-equivalents per member
    // vs full-gather's k−1 = 3 (M=2 would be the degenerate break-even)
    let cfg16 = ExperimentConfig {
        peers: 16,
        group_size: 4,
        mar_rounds: 2,
        iterations: 4,
        ..base_cfg()
    };
    let full = {
        let cfg = cfg16.clone();
        Trainer::new(cfg, &rt).unwrap().run().unwrap()
    };
    let rs = {
        let cfg = ExperimentConfig { reduce_scatter: true, ..cfg16 };
        Trainer::new(cfg, &rt).unwrap().run().unwrap()
    };
    assert!(
        rs.comm.data_bytes < full.comm.data_bytes,
        "reduce-scatter must cut traffic"
    );
    // exact aggregation either way -> same learning trajectory
    assert!((rs.final_accuracy - full.final_accuracy).abs() < 1e-6);
}

/// Deterministic reproducibility: same seed, same run.
#[test]
fn runs_are_reproducible() {
    let rt = runtime();
    let run = |seed: u64| {
        let cfg = ExperimentConfig { iterations: 4, seed, ..base_cfg() };
        Trainer::new(cfg, &rt).unwrap().run().unwrap()
    };
    let a = run(7);
    let b = run(7);
    let c = run(8);
    assert_eq!(a.comm.data_bytes, b.comm.data_bytes);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    // a different seed changes the data -> different outcome
    assert!(
        (a.final_accuracy - c.final_accuracy).abs() > 1e-9
            || a.final_loss != c.final_loss
    );
}
