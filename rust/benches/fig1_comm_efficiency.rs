//! Figure 1 — Performance gap: communication cost per FL iteration vs
//! number of peers, MAR-FL against FedAvg / RDFL / AR-FL.
//!
//! Paper claims: MAR-FL needs up to 10× less communication than RDFL/AR-FL
//! at 125 peers; scales O(N log N) vs the baselines' O(N²); FedAvg (O(N))
//! stays below MAR-FL. Bytes are measured from the ledger by running each
//! aggregator once over synthetic peer states of the CNN task's size —
//! communication volume is independent of parameter values, so no PJRT is
//! needed here and the sweep is exact.

#[path = "common/mod.rs"]
mod common;

use common::{assert_stable_columns, emit_csv, mib, SynthBundle};
use marfl::aggregation::{
    Aggregate, AllToAll, Butterfly, FedAvgServer, GroupExchange, RingRdfl,
};
use marfl::coordinator::{AggOptions, MarAggregator};
use marfl::testing::rel_err;

/// (peer count, MAR group size, MAR rounds) — paper's sweep points with
/// their exact grids (16 = 4², 64 = 4³, 125 = 5³).
const SWEEP: &[(usize, usize, usize)] = &[(16, 4, 2), (64, 4, 3), (125, 5, 3)];
/// cnn task padded parameter count (state transfer = 2·P·4 bytes)
const P: usize = 18432;

fn measure(n: usize, m: usize, g: usize, which: &str) -> u64 {
    let mut b = SynthBundle::new(P);
    let mut states = b.states(n);
    let agg: Vec<usize> = (0..n).collect();
    let before = b.ledger.snapshot();
    match which {
        "marfl" | "marfl-rs" => {
            let exchange = if which == "marfl-rs" {
                GroupExchange::ReduceScatter
            } else {
                GroupExchange::FullGather
            };
            let mut mar = MarAggregator::with_options(
                n,
                m,
                g,
                b.ledger.clone(),
                11,
                AggOptions { exchange, ..AggOptions::default() },
            );
            // exclude one-time DHT join traffic from the per-iteration cost
            let joined = b.ledger.snapshot();
            let mut ctx = b.ctx();
            mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
            let s = b.ledger.snapshot();
            return s.data_bytes - joined.data_bytes + (s.control_bytes - joined.control_bytes);
        }
        "bar" => {
            let mut ctx = b.ctx();
            Butterfly.aggregate(&mut states, &agg, &mut ctx).unwrap();
        }
        "fedavg" => {
            let mut ctx = b.ctx();
            FedAvgServer::default()
                .aggregate(&mut states, &agg, &mut ctx)
                .unwrap();
        }
        "rdfl" => {
            let mut ctx = b.ctx();
            RingRdfl.aggregate(&mut states, &agg, &mut ctx).unwrap();
        }
        "arfl" => {
            let mut ctx = b.ctx();
            AllToAll.aggregate(&mut states, &agg, &mut ctx).unwrap();
        }
        _ => unreachable!(),
    }
    let s = b.ledger.snapshot();
    s.total_bytes() - before.total_bytes()
}

fn main() {
    println!("Figure 1 — communication per FL iteration (cnn-size states)\n");
    println!(
        "{:>5} {:>12} {:>12} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "N", "FedAvg", "MAR-FL", "MAR-RS", "BAR*", "RDFL", "AR-FL", "RDFL/MAR"
    );

    let mut rows = vec![vec![
        "peers".into(),
        "fedavg_bytes".into(),
        "marfl_bytes".into(),
        "marfl_rs_bytes".into(),
        "bar_bytes".into(),
        "rdfl_bytes".into(),
        "arfl_bytes".into(),
    ]];
    let mut results = Vec::new();
    for &(n, m, g) in SWEEP {
        let fedavg = measure(n, m, g, "fedavg");
        let marfl = measure(n, m, g, "marfl");
        let marfl_rs = measure(n, m, g, "marfl-rs");
        let bar = measure(n, m, g, "bar");
        let rdfl = measure(n, m, g, "rdfl");
        let arfl = measure(n, m, g, "arfl");
        println!(
            "{:>5} {:>11.1}M {:>11.1}M {:>11.1}M {:>11.1}M {:>11.1}M {:>9.1}M {:>9.1}x",
            n,
            mib(fedavg),
            mib(marfl),
            mib(marfl_rs),
            mib(bar),
            mib(rdfl),
            mib(arfl),
            rdfl as f64 / marfl as f64
        );
        rows.push(vec![
            n.to_string(),
            fedavg.to_string(),
            marfl.to_string(),
            marfl_rs.to_string(),
            bar.to_string(),
            rdfl.to_string(),
            arfl.to_string(),
        ]);
        results.push((n, fedavg, marfl, rdfl, arfl));
    }
    println!(
        "  (* BAR aggregates only the largest 2^k subset — Appendix B.3 excludes it as unreliable)"
    );
    assert_stable_columns(
        "fig1_comm_efficiency.csv",
        &rows,
        &[
            "peers",
            "fedavg_bytes",
            "marfl_bytes",
            "marfl_rs_bytes",
            "bar_bytes",
            "rdfl_bytes",
            "arfl_bytes",
        ],
    );
    emit_csv("fig1_comm_efficiency.csv", &rows);
    common::emit_bench_report("comm", "comm_efficiency", &rows);

    // ---- paper-shape assertions ------------------------------------
    let (_, fedavg, marfl, rdfl, arfl) = results[results.len() - 1];
    let ratio = rdfl as f64 / marfl as f64;
    assert!(fedavg < marfl, "FedAvg must undercut MAR-FL");
    assert!(
        ratio >= 7.0,
        "paper: ~10x at 125 peers; measured {ratio:.1}x"
    );
    assert!(
        rel_err(arfl as f64, rdfl as f64) < 0.05,
        "RDFL and AR-FL should both be ~N(N-1) transfers"
    );
    // O(N log N) vs O(N^2): growth from 16 -> 125 peers
    let mar_growth = results[2].2 as f64 / results[0].2 as f64;
    let quad_growth = (125.0 * 124.0) / (16.0 * 15.0);
    assert!(
        mar_growth < quad_growth / 3.0,
        "MAR growth {mar_growth:.1}x should be far below quadratic {quad_growth:.1}x"
    );
    println!(
        "\nshape holds: RDFL/MAR at 125 peers = {ratio:.1}x (paper: up to 10x); \
         MAR growth 16->125 = {mar_growth:.1}x vs quadratic {quad_growth:.1}x"
    );
}
