# MAR-FL build orchestration.
#
# Tier-1 verify: `make verify` (== cargo build --release && cargo test -q).
# Artifacts (AOT-lowered HLO for the optional PJRT backend) are built by
# `make artifacts`; the default cargo build needs neither Python nor XLA —
# it runs the pure-Rust native backend (see EXPERIMENTS.md §Perf).

CARGO ?= cargo
PYTHON ?= python3
ARTIFACTS ?= artifacts

.PHONY: build test verify bench bench-micro trajectory artifacts fmt clippy doc clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

verify: build test

bench:
	$(CARGO) bench

# Hot-path micro benchmark; writes rust/results/BENCH_micro.json
# (machine-readable perf trajectory, tracked across PRs).
bench-micro:
	$(CARGO) bench --bench micro_hotpath

# Fold every rust/results/BENCH_*.json the benches emitted into a single
# rust/results/BENCH_trajectory.json (schema marfl-trajectory/v1) — the
# one artifact trend dashboards diff across PRs.
trajectory:
	$(CARGO) run --release --bin marfl -- trajectory --dir rust/results

artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS)

fmt:
	$(CARGO) fmt --all --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# API docs for the marfl crate; warnings (broken links, missing code
# fences) are errors, matching the CI gate.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

clean:
	$(CARGO) clean
