//! Byzantine-robust group estimators.
//!
//! MAR's small groups make robust statistics cheap: a k-member group can
//! afford a coordinate-wise sort (k ≤ group size, typically 4–8), so the
//! classic estimators — trimmed mean, coordinate-wise median, norm
//! clipping — run at a small constant factor over the plain mean. All
//! kernels here follow the `mean_indexed_into` contract: f64
//! accumulation, strip-mined over [`super::MEAN_STRIPE`]-wide output
//! chunks, every element combining its inputs in a fixed order — so
//! results are bit-identical regardless of strip width or thread count,
//! and the chunk-owned reduce-scatter path (which applies the same
//! estimator per owned stripe) assembles the exact same vector as the
//! full-gather path.
//!
//! `RobustEstimator::Mean` is *the* existing averaging path: callers
//! that select it delegate to `mean_indexed_into` bit-exactly, so a run
//! with `attack.robust = "mean"` is indistinguishable from a build
//! without this module.

use super::MEAN_STRIPE;

/// Which center a group computes from its members' states.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RobustEstimator {
    /// Plain element-wise mean — bit-exact delegation to the existing
    /// averaging kernels (the determinism-contract default).
    #[default]
    Mean,
    /// Coordinate-wise trimmed mean: drop the `⌊trim·k⌋` smallest and
    /// largest values per coordinate, average the rest. Tolerates up to
    /// `⌊trim·k⌋` Byzantine members per group.
    TrimmedMean,
    /// Coordinate-wise median (the trimmed mean at maximal trim: one
    /// survivor per coordinate for odd k, two averaged for even k).
    Median,
    /// Norm clipping: scale each member's contribution down to the
    /// median L2 norm before averaging — defeats model-replacement
    /// amplification while leaving honest updates untouched.
    NormClip,
    /// Krum (Blanchard et al.): score every member by the summed squared
    /// distance to its `k − f − 2` nearest neighbours and take the
    /// single lowest-scored member's state as the center — a full
    /// selection, so a Byzantine row is either chosen or contributes
    /// nothing (no coordinate-wise leakage).
    Krum,
    /// Multi-Krum: average the `k − f` lowest-Krum-scored members —
    /// Krum's selection robustness with (most of) the mean's variance
    /// reduction.
    MultiKrum,
}

impl RobustEstimator {
    /// Parse a config-file name (`attack.robust`).
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        Ok(match name {
            "mean" => RobustEstimator::Mean,
            "trimmed_mean" => RobustEstimator::TrimmedMean,
            "median" => RobustEstimator::Median,
            "norm_clip" => RobustEstimator::NormClip,
            "krum" => RobustEstimator::Krum,
            "multi_krum" => RobustEstimator::MultiKrum,
            other => anyhow::bail!(
                "unknown robust estimator '{other}' \
                 (mean|trimmed_mean|median|norm_clip|krum|multi_krum)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            RobustEstimator::Mean => "mean",
            RobustEstimator::TrimmedMean => "trimmed_mean",
            RobustEstimator::Median => "median",
            RobustEstimator::NormClip => "norm_clip",
            RobustEstimator::Krum => "krum",
            RobustEstimator::MultiKrum => "multi_krum",
        }
    }
}

/// An estimator plus its trim fraction — the value threaded through the
/// aggregation call tree.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RobustPolicy {
    pub est: RobustEstimator,
    /// Fraction trimmed from EACH side under `TrimmedMean` (ignored by
    /// the other estimators). Must stay below 0.5.
    pub trim: f64,
}

impl Default for RobustPolicy {
    fn default() -> Self {
        RobustPolicy::MEAN
    }
}

impl RobustPolicy {
    /// The bit-exact legacy averaging policy.
    pub const MEAN: RobustPolicy =
        RobustPolicy { est: RobustEstimator::Mean, trim: 0.25 };

    pub fn is_mean(&self) -> bool {
        self.est == RobustEstimator::Mean
    }

    /// Values dropped from each side of a sorted k-member coordinate.
    /// Clamped so at least one value survives (two for even k under
    /// `Median`).
    pub fn drop_count(&self, k: usize) -> usize {
        match self.est {
            RobustEstimator::Mean
            | RobustEstimator::NormClip
            | RobustEstimator::Krum
            | RobustEstimator::MultiKrum => 0,
            RobustEstimator::TrimmedMean => {
                ((self.trim * k as f64).floor() as usize).min(k.saturating_sub(1) / 2)
            }
            RobustEstimator::Median => k.saturating_sub(1) / 2,
        }
    }

    /// Selection-based estimator (Krum / Multi-Krum)?
    pub fn is_selection(&self) -> bool {
        matches!(self.est, RobustEstimator::Krum | RobustEstimator::MultiKrum)
    }

    /// Byzantine allowance `f` for Krum selection: the trim fraction of
    /// the group (`⌊trim·k⌋`, the same knob the trimmed mean uses),
    /// clamped so the score still has `k − f − 2 ≥ 1` neighbours.
    /// Groups with `k < 3` have no meaningful selection — callers fall
    /// back to the plain mean there.
    pub fn krum_f(&self, k: usize) -> usize {
        ((self.trim * k as f64).floor() as usize).min(k.saturating_sub(3))
    }
}

/// Per-group outlier evidence returned by the robust averaging wrappers
/// when the caller wants reputation scores: each member's L2 distance to
/// the group center, plus the center's own norm (the absolute scale the
/// outlier rule normalizes against).
#[derive(Clone, Debug, PartialEq)]
pub struct GroupScores {
    /// `dists[k]` = ‖θ_k − center‖₂, f64, index order (member order).
    pub dists: Vec<f64>,
    /// ‖center‖₂.
    pub center_norm: f64,
}

/// One trimmed strip: sort each coordinate's k values, drop `drop` from
/// each side, average the rest in sorted order (fixed order ⇒ the result
/// is independent of strip width).
fn trimmed_stripe_into<'a, F: Fn(usize) -> &'a [f32]>(
    rows: usize,
    row: &F,
    off: usize,
    out: &mut [f32],
    drop: usize,
) {
    let srcs: Vec<&[f32]> = (0..rows).map(|r| &row(r)[off..off + out.len()]).collect();
    let keep = rows - 2 * drop;
    let inv = 1.0 / keep as f64;
    let mut vals = vec![0.0f32; rows];
    for (i, dst) in out.iter_mut().enumerate() {
        for (v, s) in vals.iter_mut().zip(&srcs) {
            *v = s[i];
        }
        vals.sort_unstable_by(|a, b| a.total_cmp(b));
        let acc: f64 = vals[drop..rows - drop].iter().map(|&v| v as f64).sum();
        *dst = (acc * inv) as f32;
    }
}

/// Write the coordinate-wise `drop`-trimmed mean of `rows` vectors into
/// `out`. `drop = 0` is the plain mean computed through the sort kernel;
/// callers wanting the bit-exact legacy mean use
/// [`super::mean_indexed_into`] instead. With `parallel`, strips fan out
/// across the `exec` pool (bit-identical: coordinates are independent).
pub fn trimmed_indexed_into<'a, F>(
    rows: usize,
    row: F,
    out: &mut [f32],
    drop: usize,
    parallel: bool,
) where
    F: Fn(usize) -> &'a [f32] + Sync,
{
    assert!(rows > 0, "trimmed mean of zero rows");
    assert!(2 * drop < rows, "trim {drop} leaves no survivors of {rows}");
    if parallel && out.len() >= 2 * MEAN_STRIPE && crate::exec::threads() > 1 {
        use rayon::prelude::*;
        crate::exec::pool().install(|| {
            out.par_chunks_mut(MEAN_STRIPE).enumerate().for_each(|(ci, chunk)| {
                trimmed_stripe_into(rows, &row, ci * MEAN_STRIPE, chunk, drop);
            });
        });
    } else {
        for (ci, chunk) in out.chunks_mut(MEAN_STRIPE).enumerate() {
            trimmed_stripe_into(rows, &row, ci * MEAN_STRIPE, chunk, drop);
        }
    }
}

/// One weighted strip, accumulated in the shared per-thread f64 scratch.
fn weighted_stripe_into<'a, F: Fn(usize) -> &'a [f32]>(
    rows: usize,
    row: &F,
    weights: &[f64],
    off: usize,
    out: &mut [f32],
    inv: f64,
) {
    super::MEAN_ACC.with(|acc| {
        let mut acc = acc.borrow_mut();
        acc.clear();
        acc.resize(out.len(), 0.0);
        for r in 0..rows {
            let w = weights[r];
            let src = &row(r)[off..off + out.len()];
            for (a, &v) in acc.iter_mut().zip(src) {
                *a += w * v as f64;
            }
        }
        for (dst, &a) in out.iter_mut().zip(acc.iter()) {
            *dst = (a * inv) as f32;
        }
    });
}

/// Weighted mean `out = (1/rows) Σ_r weights[r]·row(r)` — the norm-clip
/// combiner. Member-order f64 accumulation, strip-mined like
/// [`super::mean_indexed_into`]; weights come from full-vector norms
/// ([`clip_weights`]), so applying this kernel per owned stripe yields
/// the same result as over the full vector.
pub fn weighted_mean_indexed_into<'a, F>(
    rows: usize,
    row: F,
    weights: &[f64],
    out: &mut [f32],
    parallel: bool,
) where
    F: Fn(usize) -> &'a [f32] + Sync,
{
    assert!(rows > 0, "weighted mean of zero rows");
    assert_eq!(weights.len(), rows);
    let inv = 1.0 / rows as f64;
    if parallel && out.len() >= 2 * MEAN_STRIPE && crate::exec::threads() > 1 {
        use rayon::prelude::*;
        crate::exec::pool().install(|| {
            out.par_chunks_mut(MEAN_STRIPE).enumerate().for_each(|(ci, chunk)| {
                weighted_stripe_into(rows, &row, weights, ci * MEAN_STRIPE, chunk, inv);
            });
        });
    } else {
        for (ci, chunk) in out.chunks_mut(MEAN_STRIPE).enumerate() {
            weighted_stripe_into(rows, &row, weights, ci * MEAN_STRIPE, chunk, inv);
        }
    }
}

/// Norm-clip weights: `min(1, c / ‖row_r‖)` where `c` is the median of
/// the rows' L2 norms. Norms accumulate in f64, index order, over the
/// FULL vectors — the caller passes full-row accessors even on the
/// chunk-owned path, which is what makes stripe-wise clipping exact.
pub fn clip_weights<'a, F: Fn(usize) -> &'a [f32]>(rows: usize, row: F) -> Vec<f64> {
    assert!(rows > 0, "clip weights of zero rows");
    let norms: Vec<f64> = (0..rows)
        .map(|r| {
            row(r)
                .iter()
                .map(|&v| {
                    let x = v as f64;
                    x * x
                })
                .sum::<f64>()
                .sqrt()
        })
        .collect();
    let mut sorted = norms.clone();
    sorted.sort_unstable_by(|a, b| a.total_cmp(b));
    let c = if rows % 2 == 1 {
        sorted[rows / 2]
    } else {
        0.5 * (sorted[rows / 2 - 1] + sorted[rows / 2])
    };
    norms
        .iter()
        .map(|&n| if n <= c || n == 0.0 { 1.0 } else { c / n })
        .collect()
}

/// Krum / Multi-Krum selection over FULL member vectors. Pairwise
/// squared L2 distances accumulate in f64, index order; member `i`'s
/// Krum score is the sum of its `k − f − 2` smallest distances (at
/// least one), ordered by `total_cmp` with an index tie-break so the
/// selection is fully deterministic. Returns the selected member
/// indices in ascending order — one for Krum, `k − f` for Multi-Krum.
/// Like [`clip_weights`], selection always reads full rows: the caller
/// precomputes it once and the chunk-owned path then averages the same
/// selected rows per owned stripe, assembling exactly the full-gather
/// vector.
pub fn krum_select<'a, F: Fn(usize) -> &'a [f32]>(
    rows: usize,
    row: F,
    f: usize,
    multi: bool,
) -> Vec<usize> {
    assert!(rows >= 3, "krum selection needs at least 3 rows");
    assert!(f + 2 < rows, "krum allowance f={f} leaves no neighbours of {rows}");
    let mut d2 = vec![0.0f64; rows * rows];
    for i in 0..rows {
        for j in (i + 1)..rows {
            let d = l2_distance(row(i), row(j));
            let dd = d * d;
            d2[i * rows + j] = dd;
            d2[j * rows + i] = dd;
        }
    }
    let near = rows - f - 2; // neighbours per score, ≥ 1 by the assert
    let mut scored: Vec<(f64, usize)> = (0..rows)
        .map(|i| {
            let mut ds: Vec<f64> =
                (0..rows).filter(|&j| j != i).map(|j| d2[i * rows + j]).collect();
            ds.sort_unstable_by(|a, b| a.total_cmp(b));
            (ds[..near].iter().sum::<f64>(), i)
        })
        .collect();
    scored.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let take = if multi { rows - f } else { 1 };
    let mut sel: Vec<usize> = scored[..take].iter().map(|&(_, i)| i).collect();
    sel.sort_unstable();
    sel
}

/// L2 norm of an f32 vector, f64 index-order accumulation.
pub fn l2_norm(a: &[f32]) -> f64 {
    a.iter()
        .map(|&v| {
            let x = v as f64;
            x * x
        })
        .sum::<f64>()
        .sqrt()
}

/// L2 distance between two equal-length f32 vectors, f64 index-order
/// accumulation — the reputation outlier score.
pub fn l2_distance(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows_of<'a>(
        data: &'a [Vec<f32>],
    ) -> impl Fn(usize) -> &'a [f32] + Sync + 'a {
        move |r| data[r].as_slice()
    }

    #[test]
    fn parse_round_trips_every_estimator() {
        for est in [
            RobustEstimator::Mean,
            RobustEstimator::TrimmedMean,
            RobustEstimator::Median,
            RobustEstimator::NormClip,
            RobustEstimator::Krum,
            RobustEstimator::MultiKrum,
        ] {
            assert_eq!(RobustEstimator::parse(est.name()).unwrap(), est);
        }
        assert!(RobustEstimator::parse("bulyan").is_err());
    }

    #[test]
    fn krum_f_clamps_to_neighbourhood() {
        let kp = |trim| RobustPolicy { est: RobustEstimator::Krum, trim };
        assert_eq!(kp(0.25).krum_f(4), 1); // one neighbour per score
        assert_eq!(kp(0.25).krum_f(8), 2);
        assert_eq!(kp(0.45).krum_f(4), 1); // floor(1.8)=1 == k-3
        assert_eq!(kp(0.45).krum_f(10), 4);
        assert_eq!(kp(0.25).krum_f(3), 0); // k=3 admits no allowance
        assert_eq!(kp(0.0).krum_f(6), 0);
    }

    #[test]
    fn krum_rejects_the_far_outlier() {
        // four tight rows + one far row: the outlier's nearest-neighbour
        // sums dominate, so Krum never selects it and Multi-Krum drops
        // exactly it
        let data = vec![
            vec![0.0f32, 0.0],
            vec![0.1, 0.0],
            vec![0.0, 0.1],
            vec![100.0, 100.0],
            vec![0.1, 0.1],
        ];
        let sel = krum_select(5, rows_of(&data), 1, false);
        assert_eq!(sel.len(), 1);
        assert_ne!(sel[0], 3, "krum must not pick the planted outlier");
        let msel = krum_select(5, rows_of(&data), 1, true);
        assert_eq!(msel, vec![0, 1, 2, 4], "multi-krum keeps the tight cluster");
    }

    #[test]
    fn drop_count_clamps_to_survivors() {
        let tm = |trim| RobustPolicy { est: RobustEstimator::TrimmedMean, trim };
        assert_eq!(tm(0.25).drop_count(4), 1);
        assert_eq!(tm(0.25).drop_count(8), 2);
        assert_eq!(tm(0.49).drop_count(4), 1); // floor(1.96) = 1
        assert_eq!(tm(0.4).drop_count(5), 2);
        let med = RobustPolicy { est: RobustEstimator::Median, trim: 0.0 };
        assert_eq!(med.drop_count(5), 2); // 1 survivor
        assert_eq!(med.drop_count(4), 1); // 2 survivors
        assert_eq!(med.drop_count(2), 0);
        assert_eq!(RobustPolicy::MEAN.drop_count(9), 0);
    }

    #[test]
    fn trimmed_mean_matches_sorted_reference() {
        let data = vec![
            vec![1.0f32, -9.0, 0.5],
            vec![2.0, 1.0, 0.5],
            vec![100.0, 2.0, 0.5],
            vec![3.0, 3.0, -0.5],
        ];
        let mut out = vec![0.0f32; 3];
        trimmed_indexed_into(4, rows_of(&data), &mut out, 1, false);
        // col 0: sorted [1,2,3,100] → (2+3)/2; col 1: [-9,1,2,3] → 1.5
        assert_eq!(out, vec![2.5, 1.5, 0.5]);
    }

    #[test]
    fn median_odd_and_even() {
        let data = vec![vec![1.0f32], vec![5.0], vec![-3.0]];
        let mut out = vec![0.0f32];
        let med = RobustPolicy { est: RobustEstimator::Median, trim: 0.0 };
        trimmed_indexed_into(3, rows_of(&data), &mut out, med.drop_count(3), false);
        assert_eq!(out, vec![1.0]);
        let data = vec![vec![1.0f32], vec![5.0], vec![-3.0], vec![2.0]];
        trimmed_indexed_into(4, rows_of(&data), &mut out, med.drop_count(4), false);
        assert_eq!(out, vec![1.5]); // (1+2)/2
    }

    #[test]
    fn trimmed_parallel_strips_bit_identical() {
        let p = 3 * MEAN_STRIPE + 41;
        let mut rng = crate::rng::Rng::new(71);
        let data: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut serial = vec![0.0f32; p];
        let mut par = vec![0.0f32; p];
        trimmed_indexed_into(6, rows_of(&data), &mut serial, 2, false);
        trimmed_indexed_into(6, rows_of(&data), &mut par, 2, true);
        assert_eq!(serial, par);
    }

    #[test]
    fn clip_weights_scale_only_above_median_norm() {
        let data = vec![
            vec![3.0f32, 4.0],   // norm 5
            vec![0.6, 0.8],      // norm 1
            vec![30.0, 40.0],    // norm 50
        ];
        let w = clip_weights(3, rows_of(&data));
        assert_eq!(w[0], 1.0); // at the median
        assert_eq!(w[1], 1.0); // below
        assert!((w[2] - 0.1).abs() < 1e-12); // 5 / 50
        // weighted mean bounds the amplified row's pull
        let mut out = vec![0.0f32; 2];
        weighted_mean_indexed_into(3, rows_of(&data), &w, &mut out, false);
        assert!((out[0] - (3.0 + 0.6 + 3.0) / 3.0).abs() < 1e-6);
    }

    #[test]
    fn weighted_mean_with_unit_weights_matches_mean() {
        let p = 2 * MEAN_STRIPE + 17;
        let mut rng = crate::rng::Rng::new(72);
        let data: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut want = vec![0.0f32; p];
        super::super::mean_indexed_into(5, rows_of(&data), &mut want, false);
        let mut got = vec![0.0f32; p];
        weighted_mean_indexed_into(5, rows_of(&data), &[1.0; 5], &mut got, false);
        assert_eq!(got, want, "unit weights must reproduce the exact mean");
    }

    #[test]
    fn l2_distance_basics() {
        assert_eq!(l2_distance(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        assert_eq!(l2_distance(&[1.0], &[1.0]), 0.0);
    }
}
