"""Group-mean aggregation Pallas kernel (L1).

The MAR aggregation hot-spot: a Moshpit group of `k` peers averages their
flat parameter (and momentum) vectors. The kernel reduces a `[k, S]` stack
to `mean[S]`, strip-mined over S.

TPU mapping: each grid step loads a `[k, STRIP]` tile into VMEM, reduces
over the (small, <=8) peer axis, and writes one strip. On hardware this is
double-buffered — the HBM->VMEM copy of strip i+1 overlaps the reduce of
strip i — which BlockSpec's sequential grid expresses. `interpret=True` on
CPU; the Rust coordinator also has a native fallback and `micro_hotpath`
benches both (DESIGN.md §Perf).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

STRIP = 1024


def _group_mean_kernel(stack_ref, out_ref):
    out_ref[...] = jnp.mean(stack_ref[...], axis=0)


def group_mean(stack: jax.Array) -> jax.Array:
    """Mean over axis 0 of a `[k, S]` stack, `S % STRIP == 0`."""
    k, s = stack.shape
    assert s % STRIP == 0, f"stack width {s} not a multiple of {STRIP}"
    grid = (s // STRIP,)
    return pl.pallas_call(
        _group_mean_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, STRIP), lambda i: (0, i))],
        out_specs=pl.BlockSpec((STRIP,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((s,), jnp.float32),
        interpret=True,
    )(stack)
