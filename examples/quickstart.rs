//! Quickstart: train a 16-peer MAR-FL federation on the 20NG-like task.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;
use marfl::models::default_artifact_dir;
use marfl::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (HLO text lowered once by `make artifacts`)
    //    into a PJRT CPU runtime. Python is not involved from here on.
    let rt = Runtime::new(&default_artifact_dir())?;

    // 2. Describe the federation: 16 peers, exact MAR grid 16 = 4²,
    //    non-iid LDA(α=1.0) shards of the 20NG-like task.
    let cfg = ExperimentConfig {
        model: "head".into(),
        peers: 16,
        group_size: 4,
        iterations: 20,
        samples_per_peer: 64,
        test_samples: 500,
        ..Default::default()
    };

    // 3. Train.
    let mut trainer = Trainer::new(cfg, &rt)?;
    let summary = trainer.run()?;

    // 4. Inspect the curve and the communication ledger.
    println!("\niter  data(MiB)  loss    accuracy");
    for p in &summary.curve.points {
        println!(
            "{:>4}  {:>9.2}  {:.4}  {:.4}",
            p.iteration,
            p.data_bytes as f64 / (1 << 20) as f64,
            p.loss,
            p.accuracy
        );
    }
    println!(
        "\nfinal accuracy {:.1}% | data plane {:.1} MiB | control plane {:.2} MiB | simulated {:.1}s | DHT hops {}",
        summary.final_accuracy * 100.0,
        summary.comm.data_bytes as f64 / (1 << 20) as f64,
        summary.comm.control_bytes as f64 / (1 << 20) as f64,
        summary.sim_time_s,
        summary.dht_hops.unwrap_or(0),
    );
    Ok(())
}
