//! Minimal JSON substrate (offline environment: no serde).
//!
//! Parses the artifact `meta.json` emitted by `python/compile/aot.py` and
//! serializes experiment results. Supports the full JSON value model minus
//! exotic escapes; numbers are f64 (meta.json only carries small ints).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for result emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or("eof in \\u")? as char;
                            code = code * 16
                                + d.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..self.pos)
                        .ok_or("eof in utf8 sequence")?;
                    out.push_str(
                        std::str::from_utf8(chunk).map_err(|e| e.to_string())?,
                    );
                }
                None => return Err("eof in string".into()),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_meta_like_document() {
        let doc = r#"{
            "strip": 1024,
            "kd_tau": 3.0,
            "group_sizes": [2, 3, 4],
            "models": {"cnn": {"param_count": 18346, "init": "cnn_init.bin"}}
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("strip").unwrap().as_usize(), Some(1024));
        assert_eq!(j.get("kd_tau").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("group_sizes").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("models")
                .and_then(|m| m.get("cnn"))
                .and_then(|c| c.get("init"))
                .and_then(|i| i.as_str()),
            Some("cnn_init.bin")
        );
    }

    #[test]
    fn round_trip() {
        let v = obj(vec![
            ("a", num(1.5)),
            ("b", s("hi \"there\"\n")),
            ("c", arr([Json::Bool(true), Json::Null])),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{\"a\": 1} x").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(num(125.0).to_string(), "125");
        assert_eq!(num(0.5).to_string(), "0.5");
    }
}
