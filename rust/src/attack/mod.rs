//! Byzantine adversary subsystem: deterministic attacker selection,
//! update corruption, and reputation-gated peer exclusion.
//!
//! The fault fabric (net::faults) models peers that *fail*; this module
//! models peers that *participate and lie*. It follows the repo's
//! determinism contract end-to-end:
//!
//! * every random draw (attacker selection, noise vectors) happens in
//!   the serial schedule phase from a dedicated RNG fork, gated on
//!   `attack.frac > 0` — an attack-off run makes ZERO extra draws and is
//!   bit-identical to a build without this module;
//! * corruption rewrites states through [`Theta::make_mut_slice`], so
//!   copy-on-write aliasing (group-mean broadcasts, KD snapshots) stays
//!   correct — an attacker sharing a post-average handle detaches
//!   instead of poisoning its groupmates retroactively;
//! * attacked runs stay bit-identical serial-vs-parallel because the
//!   corruption pass completes before any aggregation lane fans out.
//!
//! Defenses live next door: robust group estimators in
//! [`crate::aggregation::robust`], and the [`Reputation`] ledger here,
//! which folds per-round outlier scores into an EWMA and lets the MAR
//! matchmaker exclude peers whose reputation falls below
//! `attack.rep_threshold`.

use crate::aggregation::robust::{GroupScores, RobustEstimator, RobustPolicy};
use crate::aggregation::PeerState;
use crate::rng::Rng;

/// How an attacker corrupts its update before the group exchange.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AttackMode {
    /// Send `−scale · θ` (and flipped momentum): the classic
    /// sign-flipping attack that drags a plain mean toward zero or
    /// beyond.
    #[default]
    SignFlip,
    /// Add `scale · N(0, 1)` noise per coordinate of θ — an unreliable /
    /// corrupted-node model rather than a directed attack.
    GaussNoise,
    /// Multiply the state by `scale` — model-replacement-style
    /// amplification (a boosted update that dominates a plain mean).
    Scale,
}

impl AttackMode {
    /// Parse a config-file name (`attack.mode`).
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        Ok(match name {
            "sign_flip" => AttackMode::SignFlip,
            "gauss_noise" => AttackMode::GaussNoise,
            "scale" => AttackMode::Scale,
            other => anyhow::bail!(
                "unknown attack mode '{other}' (sign_flip|gauss_noise|scale)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            AttackMode::SignFlip => "sign_flip",
            AttackMode::GaussNoise => "gauss_noise",
            AttackMode::Scale => "scale",
        }
    }
}

/// The validated `attack.*` config block: adversary knobs plus the
/// defense selection (robust estimator + reputation threshold).
#[derive(Clone, Debug, PartialEq)]
pub struct AttackConfig {
    /// Fraction of peers that are Byzantine (ground truth, drawn once
    /// per run). `0.0` disables the whole subsystem.
    pub frac: f64,
    /// Corruption applied to attacker updates each iteration.
    pub mode: AttackMode,
    /// Mode-specific magnitude: flip/amplification factor, or noise σ.
    pub scale: f64,
    /// Colluding attackers all send ONE identical corrupted state (the
    /// lowest-indexed attacker's), sharing a single `Theta` allocation —
    /// harder for coordinate-wise trimming, cheaper for us to simulate.
    pub collude: bool,
    /// Group center estimator (`mean` = bit-exact legacy averaging).
    pub robust: RobustEstimator,
    /// Per-side trim fraction for `trimmed_mean`.
    pub trim: f64,
    /// Reputation ban threshold in `(0, 1)`; `0.0` disables
    /// reputation-gated matchmaking.
    pub rep_threshold: f64,
}

impl Default for AttackConfig {
    fn default() -> Self {
        AttackConfig {
            frac: 0.0,
            mode: AttackMode::SignFlip,
            scale: 1.0,
            collude: false,
            robust: RobustEstimator::Mean,
            trim: 0.25,
            rep_threshold: 0.0,
        }
    }
}

impl AttackConfig {
    /// Attack injection active? (Defenses may run without attackers —
    /// e.g. a robust estimator hardening an honest run.)
    pub fn enabled(&self) -> bool {
        self.frac > 0.0
    }

    /// Reputation-gated matchmaking active?
    pub fn rep_enabled(&self) -> bool {
        self.rep_threshold > 0.0
    }

    /// Anything here that departs from the bit-exact legacy path?
    pub fn any_active(&self) -> bool {
        self.enabled() || self.rep_enabled() || !self.policy().is_mean()
    }

    /// The estimator policy threaded through aggregation.
    pub fn policy(&self) -> RobustPolicy {
        RobustPolicy { est: self.robust, trim: self.trim }
    }

    /// Range checks (called from `config::ExperimentConfig::validate`).
    pub fn validate(&self) -> anyhow::Result<()> {
        if !(0.0..0.5).contains(&self.frac) {
            anyhow::bail!("attack.frac must be in [0, 0.5), got {}", self.frac);
        }
        if !self.scale.is_finite() || self.scale <= 0.0 {
            anyhow::bail!("attack.scale must be finite and > 0, got {}", self.scale);
        }
        if !(0.0..0.5).contains(&self.trim) {
            anyhow::bail!("attack.trim must be in [0, 0.5), got {}", self.trim);
        }
        if !(0.0..1.0).contains(&self.rep_threshold) {
            anyhow::bail!(
                "attack.rep_threshold must be in [0, 1), got {}",
                self.rep_threshold
            );
        }
        Ok(())
    }
}

/// The per-run ground truth: which peers are Byzantine, and what they
/// have done so far. Drawn ONCE at trainer setup from a dedicated RNG
/// fork (tag 4), gated on `attack.frac > 0`.
#[derive(Clone, Debug, PartialEq)]
pub struct AttackPlan {
    attacker: Vec<bool>,
    mode: AttackMode,
    scale: f64,
    collude: bool,
    /// Attackers that corrupted an update at least once this run.
    active: Vec<bool>,
}

impl AttackPlan {
    /// Select `round(frac · n)` attackers (clamped below half) from a
    /// forked RNG. Deterministic per (seed, n, frac).
    pub fn new(cfg: &AttackConfig, n: usize, rng: &mut Rng) -> Self {
        let want = (cfg.frac * n as f64).round() as usize;
        let count = want.min(n.saturating_sub(1) / 2);
        let mut attacker = vec![false; n];
        for i in rng.sample_indices(n, count) {
            attacker[i] = true;
        }
        AttackPlan {
            attacker,
            mode: cfg.mode,
            scale: cfg.scale,
            collude: cfg.collude,
            active: vec![false; n],
        }
    }

    pub fn is_attacker(&self, peer: usize) -> bool {
        self.attacker[peer]
    }

    /// Ground-truth attacker count.
    pub fn count(&self) -> usize {
        self.attacker.iter().filter(|&&a| a).count()
    }

    /// Attackers that actually corrupted an update this run.
    pub fn active_count(&self) -> u64 {
        self.active.iter().filter(|&&a| a).count() as u64
    }

    pub fn attacker_flags(&self) -> &[bool] {
        &self.attacker
    }

    /// Corrupt every attacking participant's state in place, in
    /// participant order (serial schedule phase — `rng` draws happen
    /// here and nowhere else). Sign-flip and scale rewrite θ and
    /// momentum (no draws); Gaussian noise perturbs θ only, one draw per
    /// coordinate (one shared vector when colluding). Colluders all end
    /// up holding ONE shared corrupted allocation.
    pub fn corrupt(
        &mut self,
        states: &mut [PeerState],
        participants: &[usize],
        rng: &mut Rng,
    ) {
        let attackers: Vec<usize> = participants
            .iter()
            .copied()
            .filter(|&p| self.attacker[p])
            .collect();
        if attackers.is_empty() {
            return;
        }
        if self.collude {
            let lead = attackers[0];
            self.corrupt_one(states, lead, rng);
            let theta = states[lead].theta.clone();
            let mom = states[lead].momentum.clone();
            for &p in &attackers[1..] {
                states[p].theta = theta.clone();
                states[p].momentum = mom.clone();
                self.active[p] = true;
            }
        } else {
            for &p in &attackers {
                self.corrupt_one(states, p, rng);
            }
        }
    }

    fn corrupt_one(&mut self, states: &mut [PeerState], p: usize, rng: &mut Rng) {
        self.active[p] = true;
        let st = &mut states[p];
        match self.mode {
            AttackMode::SignFlip => {
                let f = -self.scale as f32;
                for v in st.theta.make_mut_slice() {
                    *v *= f;
                }
                for v in st.momentum.make_mut_slice() {
                    *v *= f;
                }
            }
            AttackMode::Scale => {
                let f = self.scale as f32;
                for v in st.theta.make_mut_slice() {
                    *v *= f;
                }
                for v in st.momentum.make_mut_slice() {
                    *v *= f;
                }
            }
            AttackMode::GaussNoise => {
                let s = self.scale;
                for v in st.theta.make_mut_slice() {
                    *v += (s * rng.normal()) as f32;
                }
            }
        }
    }
}

/// Ban length once a peer's reputation crosses the threshold.
const BAN_ITERS: u64 = 4;
/// EWMA smoothing factor for per-iteration health observations.
const REP_ALPHA: f64 = 0.5;
/// A member is an outlier when its distance to the group center exceeds
/// BOTH `OUTLIER_REL · median(dists)` and `OUTLIER_ABS · ‖center‖` — the
/// relative test finds the odd one out, the absolute floor keeps a
/// converged group's tiny jitter from flagging honest peers.
const OUTLIER_REL: f64 = 3.0;
const OUTLIER_ABS: f64 = 0.05;
/// Never ban more than this fraction of the population — the
/// matchmaker must always retain a working majority.
const MAX_BANNED_FRAC: f64 = 0.45;

/// EWMA reputation ledger with bounded bans and rejoin probation.
///
/// Scores arrive per aggregation round via [`Reputation::observe_group`]
/// (serial fold, group/member order); [`Reputation::fold_iteration`]
/// applies each peer's WORST observation of the iteration to its EWMA
/// once, then bans peers below the threshold for [`BAN_ITERS`]
/// iterations (probation: an expired ban resets the reputation exactly
/// to the threshold, so one more bad iteration re-bans). The worst-of
/// staging matters: after round 1 of a MAR iteration an attacker holds
/// the shared group mean and looks perfectly healthy in rounds 2+, so
/// averaging observations would wash the round-1 evidence out.
#[derive(Clone, Debug, PartialEq)]
pub struct Reputation {
    rep: Vec<f64>,
    /// Worst observation this iteration: `None` = unobserved.
    staged: Vec<Option<bool>>,
    /// Ban expiry (iteration index); 0 = not banned.
    banned_until: Vec<u64>,
    ever_flagged: Vec<bool>,
    threshold: f64,
    max_banned: usize,
    iter: u64,
}

impl Reputation {
    pub fn new(n: usize, threshold: f64) -> Self {
        Reputation {
            rep: vec![1.0; n],
            staged: vec![None; n],
            banned_until: vec![0; n],
            ever_flagged: vec![false; n],
            threshold,
            max_banned: (MAX_BANNED_FRAC * n as f64).floor() as usize,
            iter: 0,
        }
    }

    /// Fold one group's outlier evidence (member order).
    pub fn observe_group(&mut self, members: &[usize], scores: &GroupScores) {
        debug_assert_eq!(members.len(), scores.dists.len());
        if members.len() < 3 {
            return; // no meaningful "odd one out" below 3 members
        }
        let mut sorted = scores.dists.clone();
        sorted.sort_unstable_by(|a, b| a.total_cmp(b));
        let k = sorted.len();
        let med = if k % 2 == 1 {
            sorted[k / 2]
        } else {
            0.5 * (sorted[k / 2 - 1] + sorted[k / 2])
        };
        let floor = OUTLIER_ABS * scores.center_norm.max(1e-12);
        for (&peer, &d) in members.iter().zip(&scores.dists) {
            let outlier = d > OUTLIER_REL * med && d > floor;
            let healthy = !outlier;
            self.staged[peer] = Some(match self.staged[peer] {
                Some(prev) => prev && healthy,
                None => healthy,
            });
        }
    }

    /// Apply the staged observations, expire old bans (probation), issue
    /// new ones (bounded, ascending peer order). Returns the number of
    /// newly banned peers. Call exactly once per aggregation call, after
    /// all rounds folded.
    pub fn fold_iteration(&mut self) -> u64 {
        self.iter += 1;
        for (rep, staged) in self.rep.iter_mut().zip(self.staged.iter_mut()) {
            if let Some(healthy) = staged.take() {
                let obs = if healthy { 1.0 } else { 0.0 };
                *rep = (1.0 - REP_ALPHA) * *rep + REP_ALPHA * obs;
            }
        }
        let mut newly = 0u64;
        for p in 0..self.rep.len() {
            if self.banned_until[p] > 0 {
                if self.iter >= self.banned_until[p] {
                    self.banned_until[p] = 0;
                    self.rep[p] = self.threshold; // probation
                }
                continue;
            }
            if self.rep[p] < self.threshold && self.banned() < self.max_banned {
                self.banned_until[p] = self.iter + BAN_ITERS;
                self.ever_flagged[p] = true;
                newly += 1;
            }
        }
        newly
    }

    pub fn is_banned(&self, peer: usize) -> bool {
        self.banned_until[peer] > 0
    }

    /// Currently banned peers.
    pub fn banned(&self) -> usize {
        self.banned_until.iter().filter(|&&b| b > 0).count()
    }

    /// Peers flagged (banned) at least once this run.
    pub fn ever_flagged(&self) -> &[bool] {
        &self.ever_flagged
    }

    pub fn score(&self, peer: usize) -> f64 {
        self.rep[peer]
    }
}

/// Flagging quality against the ground-truth attacker set:
/// `(flagged, precision, recall)`. Precision/recall are 1.0 when their
/// denominator is empty (nothing flagged / no attackers).
pub fn flag_quality(flagged: &[bool], attacker: &[bool]) -> (u64, f64, f64) {
    debug_assert_eq!(flagged.len(), attacker.len());
    let n_flag = flagged.iter().filter(|&&f| f).count();
    let n_atk = attacker.iter().filter(|&&a| a).count();
    let hit = flagged
        .iter()
        .zip(attacker)
        .filter(|&(&f, &a)| f && a)
        .count();
    let precision = if n_flag == 0 { 1.0 } else { hit as f64 / n_flag as f64 };
    let recall = if n_atk == 0 { 1.0 } else { hit as f64 / n_atk as f64 };
    (n_flag as u64, precision, recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_mode() {
        for mode in [AttackMode::SignFlip, AttackMode::GaussNoise, AttackMode::Scale]
        {
            assert_eq!(AttackMode::parse(mode.name()).unwrap(), mode);
        }
        assert!(AttackMode::parse("backdoor").is_err());
    }

    #[test]
    fn config_validation_rejects_bad_ranges() {
        let ok = AttackConfig::default();
        ok.validate().unwrap();
        assert!(AttackConfig { frac: 0.5, ..ok.clone() }.validate().is_err());
        assert!(AttackConfig { frac: -0.1, ..ok.clone() }.validate().is_err());
        assert!(AttackConfig { scale: 0.0, ..ok.clone() }.validate().is_err());
        assert!(AttackConfig { trim: 0.5, ..ok.clone() }.validate().is_err());
        assert!(
            AttackConfig { rep_threshold: 1.0, ..ok.clone() }.validate().is_err()
        );
        AttackConfig { frac: 0.3, rep_threshold: 0.6, ..ok }.validate().unwrap();
    }

    #[test]
    fn plan_selection_is_deterministic_and_clamped() {
        let cfg = AttackConfig { frac: 0.3, ..Default::default() };
        let a = AttackPlan::new(&cfg, 20, &mut Rng::new(9));
        let b = AttackPlan::new(&cfg, 20, &mut Rng::new(9));
        assert_eq!(a, b);
        assert_eq!(a.count(), 6); // round(0.3 · 20)
        assert_eq!(a.active_count(), 0);
        // clamp: never half or more, even with an aggressive frac
        let cfg = AttackConfig { frac: 0.49, ..Default::default() };
        let plan = AttackPlan::new(&cfg, 4, &mut Rng::new(9));
        assert!(plan.count() <= 1);
    }

    fn states(n: usize, p: usize) -> Vec<PeerState> {
        (0..n)
            .map(|i| PeerState {
                theta: vec![i as f32 + 1.0; p].into(),
                momentum: vec![0.5; p].into(),
            })
            .collect()
    }

    #[test]
    fn sign_flip_rewrites_theta_and_momentum() {
        let cfg = AttackConfig { frac: 0.4, scale: 2.0, ..Default::default() };
        let mut rng = Rng::new(3);
        let mut plan = AttackPlan::new(&cfg, 5, &mut rng);
        let mut st = states(5, 4);
        let before: Vec<_> = st.iter().map(|s| s.theta.to_vec()).collect();
        plan.corrupt(&mut st, &[0, 1, 2, 3, 4], &mut rng);
        for p in 0..5 {
            if plan.is_attacker(p) {
                assert_eq!(st[p].theta[0], -2.0 * before[p][0]);
                assert_eq!(st[p].momentum[0], -1.0);
            } else {
                assert_eq!(st[p].theta.to_vec(), before[p]);
            }
        }
        assert_eq!(plan.active_count(), plan.count() as u64);
    }

    #[test]
    fn corrupt_detaches_shared_storage() {
        // an attacker aliasing a group mean must CoW-detach, never
        // poison the peers sharing the allocation
        let cfg = AttackConfig { frac: 0.4, ..Default::default() };
        let mut rng = Rng::new(5);
        let mut plan = AttackPlan::new(&cfg, 5, &mut rng);
        let atk = (0..5).find(|&p| plan.is_attacker(p)).unwrap();
        let honest = (0..5).find(|&p| !plan.is_attacker(p)).unwrap();
        let mut st = states(5, 4);
        let shared = st[honest].theta.clone();
        st[atk].theta = shared.clone();
        assert!(st[atk].theta.shares_storage(&st[honest].theta));
        plan.corrupt(&mut st, &[atk], &mut rng);
        assert!(!st[atk].theta.shares_storage(&st[honest].theta));
        assert_eq!(st[honest].theta, shared);
    }

    #[test]
    fn colluders_share_one_corrupted_allocation() {
        let cfg = AttackConfig {
            frac: 0.45,
            collude: true,
            mode: AttackMode::GaussNoise,
            ..Default::default()
        };
        let mut rng = Rng::new(7);
        let mut plan = AttackPlan::new(&cfg, 9, &mut rng);
        let mut st = states(9, 8);
        let participants: Vec<usize> = (0..9).collect();
        let draws_before = rng.clone();
        plan.corrupt(&mut st, &participants, &mut rng);
        let atks: Vec<usize> =
            (0..9).filter(|&p| plan.is_attacker(p)).collect();
        assert!(atks.len() >= 2);
        for w in atks.windows(2) {
            assert!(st[w[0]].theta.shares_storage(&st[w[1]].theta));
        }
        // collusion draws ONE noise vector total (8 coords)
        let mut replay = draws_before;
        for _ in 0..8 {
            replay.normal();
        }
        assert_eq!(replay.next_u64(), rng.next_u64());
    }

    #[test]
    fn reputation_bans_persistent_outliers_with_probation() {
        let mut rep = Reputation::new(6, 0.5);
        let members = [0usize, 1, 2, 3];
        // peer 3 is a strong outlier every iteration
        let scores = GroupScores {
            dists: vec![0.1, 0.12, 0.09, 50.0],
            center_norm: 10.0,
        };
        rep.observe_group(&members, &scores);
        assert_eq!(rep.fold_iteration(), 0); // rep 0.5, not yet below
        rep.observe_group(&members, &scores);
        assert_eq!(rep.fold_iteration(), 1); // rep 0.25 < 0.5 → ban
        assert!(rep.is_banned(3));
        assert!(!rep.is_banned(0));
        assert_eq!(rep.banned(), 1);
        // ban expires after BAN_ITERS folds; probation resets to the
        // threshold, so one more bad iteration re-bans immediately
        for _ in 0..BAN_ITERS {
            rep.fold_iteration();
        }
        assert!(!rep.is_banned(3));
        assert_eq!(rep.score(3), 0.5);
        rep.observe_group(&members, &scores);
        assert_eq!(rep.fold_iteration(), 1);
        assert!(rep.is_banned(3));
        assert_eq!(rep.ever_flagged(), &[false, false, false, true, false, false]);
    }

    #[test]
    fn worst_observation_of_iteration_wins() {
        let mut rep = Reputation::new(4, 0.5);
        let bad = GroupScores {
            dists: vec![0.1, 0.1, 0.1, 40.0],
            center_norm: 10.0,
        };
        let clean = GroupScores {
            dists: vec![0.1, 0.1, 0.1, 0.1],
            center_norm: 10.0,
        };
        // round 1 catches the outlier, rounds 2-3 (post-average alias)
        // look clean — the round-1 evidence must survive the fold
        rep.observe_group(&[0, 1, 2, 3], &bad);
        rep.observe_group(&[0, 1, 2, 3], &clean);
        rep.observe_group(&[0, 1, 2, 3], &clean);
        rep.fold_iteration();
        assert_eq!(rep.score(3), 0.5);
        assert_eq!(rep.score(0), 1.0);
    }

    #[test]
    fn converged_groups_never_flag_anyone() {
        // tiny absolute distances (relative spread is huge, absolute is
        // noise) must not produce outliers
        let mut rep = Reputation::new(4, 0.5);
        let scores = GroupScores {
            dists: vec![1e-9, 1e-9, 1e-9, 1e-6],
            center_norm: 10.0,
        };
        for _ in 0..10 {
            rep.observe_group(&[0, 1, 2, 3], &scores);
            rep.fold_iteration();
        }
        assert_eq!(rep.banned(), 0);
    }

    #[test]
    fn ban_count_is_bounded() {
        // pathological evidence: a different peer looks like a strong
        // outlier every iteration — the active-ban set must stay capped
        let mut rep = Reputation::new(10, 0.9);
        let scores = GroupScores {
            dists: vec![50.0, 0.1, 0.1],
            center_norm: 10.0,
        };
        for p in 0..8usize {
            rep.observe_group(&[p, 8, 9], &scores);
            rep.fold_iteration();
            assert!(rep.banned() <= 4, "cap is floor(0.45 · 10) = 4");
        }
        assert!(rep.ever_flagged().iter().filter(|&&f| f).count() >= 4);
    }

    #[test]
    fn flag_quality_counts() {
        let flagged = [true, false, true, false];
        let attacker = [true, false, false, true];
        let (n, p, r) = flag_quality(&flagged, &attacker);
        assert_eq!(n, 2);
        assert_eq!(p, 0.5);
        assert_eq!(r, 0.5);
        let (n, p, r) = flag_quality(&[false; 4], &[false; 4]);
        assert_eq!((n, p, r), (0, 1.0, 1.0));
    }
}
