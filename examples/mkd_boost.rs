//! Scenario: cutting communication with Moshpit-KD (paper §2.2 + Figure 2).
//! Trains the 20NG-like head task with and without MKD and reports the
//! total bytes each needs to reach the target accuracy.
//!
//! ```bash
//! cargo run --release --example mkd_boost
//! ```

use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;
use marfl::models::default_artifact_dir;
use marfl::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&default_artifact_dir())?;
    let target = 0.5;
    let base = ExperimentConfig {
        model: "head".into(),
        peers: 27,
        group_size: 3,
        mar_rounds: 3, // 27 = 3^3, exact grid
        iterations: 40,
        samples_per_peer: 64,
        test_samples: 1000,
        eval_every: 2,
        target_accuracy: target,
        seed: 313,
        ..Default::default()
    };

    println!("27-peer MAR-FL, 20NG-like task, stop at {:.0}% accuracy\n", target * 100.0);

    let plain = Trainer::new(base.clone(), &rt)?.run()?;
    let mut kd_cfg = base.clone();
    kd_cfg.kd.enabled = true;
    kd_cfg.kd.k_iterations = 6;
    let kd = Trainer::new(kd_cfg, &rt)?.run()?;

    let fmt = |b: Option<u64>| {
        b.map(|b| format!("{:.1} MiB", b as f64 / (1 << 20) as f64))
            .unwrap_or_else(|| "not reached".into())
    };
    println!("variant          iters-to-target   bytes-to-target");
    println!(
        "MAR-FL           {:>15}   {:>15}",
        plain
            .curve
            .iterations_to_accuracy(target)
            .map(|i| i.to_string())
            .unwrap_or_else(|| "—".into()),
        fmt(plain.curve.bytes_to_accuracy(target))
    );
    println!(
        "MAR-FL + MKD     {:>15}   {:>15}",
        kd.curve
            .iterations_to_accuracy(target)
            .map(|i| i.to_string())
            .unwrap_or_else(|| "—".into()),
        fmt(kd.curve.bytes_to_accuracy(target))
    );
    if let (Some(p), Some(k)) = (
        plain.curve.bytes_to_accuracy(target),
        kd.curve.bytes_to_accuracy(target),
    ) {
        println!(
            "\nMKD reaches the target with {:.2}x less communication \
             (paper: >2x on 20NG); per-iteration load is higher, convergence faster.",
            p as f64 / k as f64
        );
    }
    Ok(())
}
