//! Deterministic PRNG substrate (offline environment: no `rand` crate).
//!
//! xoshiro256** seeded through SplitMix64, plus the distributions the
//! system needs: uniform ranges, Gaussian (Box–Muller), Dirichlet (via
//! Marsaglia–Tsang gamma), Fisher–Yates shuffle. Every stochastic component
//! in MAR-FL (data synthesis, LDA partitioning, participation sampling,
//! dropout, DP noise) draws from this generator so experiments are
//! reproducible from a single seed.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug, PartialEq)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent child stream (e.g. one per peer).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. Lemire-style rejection-free for our
    /// (non-cryptographic) purposes.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (caches the spare variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let t = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * t.sin());
            return r * t.cos();
        }
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u: f64 = self.f64().max(f64::EPSILON);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k): the paper's LDA(alpha = 1.0) partitioner
    /// draws per-class peer mixtures from this.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one_and_uniform_alpha_mean() {
        let mut r = Rng::new(13);
        let k = 10;
        let mut acc = vec![0.0; k];
        for _ in 0..2_000 {
            let d = r.dirichlet(1.0, k);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            for (a, v) in acc.iter_mut().zip(&d) {
                *a += v;
            }
        }
        for a in &acc {
            let m = a / 2_000.0;
            assert!((m - 0.1).abs() < 0.02, "component mean {m}");
        }
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(17);
        for &shape in &[0.5, 1.0, 3.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.05 * shape.max(1.0), "shape {shape} mean {mean}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        for _ in 0..100 {
            let s = r.sample_indices(50, 20);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 20);
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
