//! Moshpit All-Reduce aggregator — the paper's system contribution.
//!
//! Per FL iteration, `aggregate` runs G MAR rounds. Each round:
//!
//! 1. **Matchmaking** — every aggregator announces itself on the Kademlia
//!    DHT under its reduced group key (`store`), then collects its group
//!    (`get`). Only lightweight metadata crosses the DHT; model weights
//!    never do (control plane, O(N log N) small messages per round).
//! 2. **Group exchange** — each group performs a full-gather of member
//!    states ((k−1) state transfers per member, data plane) and averages
//!    via the Pallas `group_mean` artifact (native fallback otherwise).
//! 3. **Key update** — each member's round-g coordinate becomes its chunk
//!    index within its group (no-revisit; see `group_key`).
//!
//! With `|A_t| = M^d` the schedule is the exact hypercube all-reduce; any
//! other count runs the approximate mode that converges across iterations
//! (Eq. 1 / `mixing.rs`).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use super::group_key::{grid_keys, perfect_grid, random_keys, GroupKey};
use crate::aggregation::{
    average_group, average_views, book_group_exchange_fabric,
    book_group_exchange_mode, payload_bytes, AggCtx, AggReport, Aggregate,
    GroupExchange, PeerState,
};
use crate::exec;
use crate::dht::{decode_peer, encode_peer, Key, SimDht};
use crate::metrics::CommLedger;
use crate::rng::Rng;

/// MAR-FL's aggregator: owns the DHT control plane and the group-key
/// schedule.
pub struct MarAggregator {
    /// group size M
    pub group_size: usize,
    /// MAR rounds G per FL iteration
    pub rounds: usize,
    /// within-group wire protocol (full-gather default; reduce-scatter
    /// is the Moshpit-SGD chunked mode, `mar.reduce_scatter` ablation)
    pub exchange: GroupExchange,
    /// run each round's groups concurrently on the `exec` pool (default).
    /// The serial path is kept as the bit-identical reference for the
    /// determinism tests and the serial-vs-parallel scaling bench.
    pub parallel: bool,
    dht: SimDht,
    /// peer index -> DHT node id
    node_ids: Vec<Key>,
    /// FL-iteration counter (scopes DHT announcement keys)
    iteration: usize,
}

impl MarAggregator {
    /// Build the control plane: every peer joins the DHT once at startup.
    pub fn new(
        n_peers: usize,
        group_size: usize,
        rounds: usize,
        ledger: Arc<CommLedger>,
        seed: u64,
    ) -> Self {
        assert!(group_size >= 2);
        assert!(rounds >= 1);
        let mut dht = SimDht::new(ledger);
        let mut rng = Rng::new(seed ^ 0xD47);
        let node_ids: Vec<Key> =
            (0..n_peers).map(|_| Key::random(&mut rng)).collect();
        for id in &node_ids {
            dht.join(*id);
        }
        MarAggregator {
            group_size,
            rounds,
            exchange: GroupExchange::FullGather,
            parallel: true,
            dht,
            node_ids,
            iteration: 0,
        }
    }

    /// Switch the within-group wire protocol.
    pub fn with_exchange(mut self, exchange: GroupExchange) -> Self {
        self.exchange = exchange;
        self
    }

    /// Force the serial reference engine (benchmark/verification aid).
    pub fn with_parallel(mut self, parallel: bool) -> Self {
        self.parallel = parallel;
        self
    }

    /// DHT-mediated matchmaking for one round. `positions[i]` announces
    /// under `keys[i].reduced(round)`; groups are peers sharing a reduced
    /// key, split into chunks of at most M (sorted by peer id for
    /// determinism). Returns groups as lists of *positions* into `agg`.
    fn matchmake(
        &mut self,
        agg: &[usize],
        keys: &[GroupKey],
        round: usize,
        scope: &str,
    ) -> Vec<Vec<usize>> {
        // announce: one DHT store per aggregator
        let mut content_keys: Vec<Key> = Vec::with_capacity(agg.len());
        for (pos, &peer) in agg.iter().enumerate() {
            let content =
                Key::hash_of(&format!("{scope}:r{round}:{}", keys[pos].reduced(round)));
            content_keys.push(content);
            self.dht.store(self.node_ids[peer], content, encode_peer(pos));
        }
        // collect: every aggregator issues its own get (the paper's
        // dispatcher scans peer announcements — O(N) lookups per round);
        // all members of a group see the same set, which doubles as the
        // paper's "group symmetry" cross-check
        let mut by_key: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (pos, &peer) in agg.iter().enumerate() {
            let got = self.dht.get(self.node_ids[peer], content_keys[pos]);
            let mut members: Vec<usize> =
                got.iter().filter_map(|v| decode_peer(v)).collect();
            members.sort_unstable();
            members.dedup();
            debug_assert!(members.contains(&pos), "announcer missing from own group");
            let reduced = keys[pos].reduced(round);
            match by_key.get(&reduced) {
                Some(existing) => debug_assert_eq!(
                    existing, &members,
                    "group symmetry violated for key {reduced}"
                ),
                None => {
                    by_key.insert(reduced, members);
                }
            }
        }
        // clear ephemeral announcements (dispatcher stale-entry sweep)
        for ck in content_keys {
            self.dht.clear(ck);
        }
        // split oversize collections into chunks of at most M
        let mut groups = Vec::new();
        for (_, members) in by_key {
            for chunk in members.chunks(self.group_size) {
                groups.push(chunk.to_vec());
            }
        }
        groups
    }

    /// Cumulative DHT lookup hops (diagnostics / control-plane model).
    pub fn dht_hops(&self) -> u64 {
        self.dht.hops_total()
    }

    /// One standalone DHT-matchmade grouping round over `agg` with fresh
    /// uniform keys — Moshpit-KD collects candidate teachers "using the
    /// same procedure MAR uses for global model averaging" (paper §2.2).
    /// `tag` must be unique per call (it scopes the DHT announcements).
    /// Returns groups of *positions into `agg`*.
    pub fn form_groups_once(
        &mut self,
        agg: &[usize],
        rng: &mut Rng,
        tag: &str,
    ) -> Vec<Vec<usize>> {
        let keys = random_keys(agg.len(), self.group_size, 1, rng);
        self.matchmake(agg, &keys, 0, tag)
    }
}

impl Aggregate for MarAggregator {
    fn name(&self) -> &'static str {
        "marfl"
    }

    fn aggregate(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport> {
        let n = agg.len();
        if n < 2 {
            return Ok(AggReport::default());
        }
        self.iteration += 1;
        let m = self.group_size;
        let d = self.rounds;
        // exact grid when possible (paper's default configuration),
        // otherwise uniform random keys (approximate mode)
        let mut keys = if perfect_grid(n, m, d) {
            grid_keys(n, m, d)
        } else {
            random_keys(n, m, d, ctx.rng)
        };

        let bytes = payload_bytes(states, agg);
        let scope = format!("agg{}", self.iteration);
        let mut groups_formed = 0;
        // the Pallas artifact path runs through the (non-Sync-friendly)
        // runtime dispatch; keep it on the serial reference engine
        let run_parallel = self.parallel
            && !(ctx.runtime.is_some()
                && crate::aggregation::pjrt_group_mean_enabled());
        for g in 0..d {
            let hops_before = self.dht.hops_total();
            let groups = self.matchmake(agg, &keys, g, &scope);
            // control-plane latency: announcements and collects run in
            // parallel across peers; charge the per-peer average lookup
            // depth (2 RTTs per hop: request+response)
            let hops = self.dht.hops_total() - hops_before;
            let avg_hops = hops as f64 / n as f64;
            ctx.clock.advance(2.0 * ctx.fabric.latency * (1.0 + avg_hops));

            // positions -> peer indices; groups within a round are
            // disjoint index sets over `states` by construction
            let member_groups: Vec<Vec<usize>> = groups
                .iter()
                .map(|grp| grp.iter().map(|&pos| agg[pos]).collect())
                .collect();
            let lane_times: Vec<f64> = if run_parallel {
                // every group books its exchange and averages
                // concurrently; lane order (and thus the clock) matches
                // the serial path because results come back in group order
                let exchange = self.exchange;
                let fabric = ctx.fabric;
                exec::par_disjoint_map(states, &member_groups, |_, views| {
                    let t = book_group_exchange_fabric(
                        views.len(),
                        bytes,
                        exchange,
                        fabric,
                    );
                    average_views(views);
                    t
                })?
            } else {
                let mut lane_times = Vec::with_capacity(member_groups.len());
                for members in &member_groups {
                    lane_times.push(book_group_exchange_mode(
                        members.len(),
                        bytes,
                        self.exchange,
                        ctx,
                    ));
                    average_group(states, members, ctx)?;
                }
                lane_times
            };
            for group in &groups {
                for (chunk, &pos) in group.iter().enumerate() {
                    keys[pos].set_chunk(g, chunk);
                }
                if group.len() >= 2 {
                    groups_formed += 1;
                }
            }
            // groups communicate concurrently
            ctx.clock.parallel(lane_times);
        }
        Ok(AggReport { rounds: d, groups: groups_formed })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::test_support::*;
    use crate::aggregation::mean_of;
    use crate::metrics::CommLedger;

    /// Build a MarAggregator sharing the TestCtx ledger (as the Trainer
    /// does), so control and data traffic land on the same counters.
    fn mar_on(tc: &TestCtx, n: usize, m: usize, g: usize) -> MarAggregator {
        MarAggregator::new(n, m, g, tc.ledger.clone(), 7)
    }

    fn mar(n: usize, m: usize, g: usize) -> (MarAggregator, Arc<CommLedger>) {
        let ledger = Arc::new(CommLedger::new());
        (MarAggregator::new(n, m, g, ledger.clone(), 7), ledger)
    }

    #[test]
    fn perfect_grid_gives_exact_global_average() {
        // 8 = 2^3
        let n = 8;
        let mut states = random_states(n, 64, 20);
        let agg: Vec<usize> = (0..n).collect();
        let (want_t, want_m) = mean_of(&states, &agg);
        let (mut mar, _) = mar(n, 2, 3);
        let mut tc = TestCtx::new(64);
        let mut ctx = tc.ctx();
        let rep = mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        assert_eq!(rep.rounds, 3);
        for s in &states {
            crate::testing::assert_allclose(&s.theta, &want_t, 1e-5, 1e-6);
            crate::testing::assert_allclose(&s.momentum, &want_m, 1e-5, 1e-6);
        }
    }

    #[test]
    fn perfect_grid_27_peers() {
        let n = 27;
        let mut states = random_states(n, 16, 21);
        let agg: Vec<usize> = (0..n).collect();
        let (want_t, _) = mean_of(&states, &agg);
        let (mut mar, _) = mar(n, 3, 3);
        let mut tc = TestCtx::new(16);
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        for s in &states {
            crate::testing::assert_allclose(&s.theta, &want_t, 1e-5, 1e-6);
        }
    }

    #[test]
    fn transfer_count_is_n_g_m_minus_one_on_grid() {
        let n = 27;
        let mut states = random_states(n, 8, 22);
        let agg: Vec<usize> = (0..n).collect();
        let mut tc = TestCtx::new(8);
        let mut mar = mar_on(&tc, n, 3, 3);
        let before = tc.ledger.snapshot();
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        // exact grid: every round has n/m groups of m; per group m(m-1)
        // transfers -> total n*g*(m-1)
        let delta = tc.ledger.snapshot().since(&before);
        assert_eq!(delta.data_msgs as usize, n * 3 * 2);
    }

    #[test]
    fn approximate_mode_reduces_distortion() {
        // 20 peers, M=3, G=3: no perfect grid; one aggregate call must
        // strictly shrink the average distance to the global mean
        let n = 20;
        let mut states = random_states(n, 32, 23);
        let agg: Vec<usize> = (0..n).collect();
        let (want_t, _) = mean_of(&states, &agg);
        let before: f64 = states
            .iter()
            .map(|s| crate::util::mse(&s.theta, &want_t))
            .sum::<f64>();
        let (mut mar, _) = mar(n, 3, 3);
        let mut tc = TestCtx::new(32);
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        let after: f64 = states
            .iter()
            .map(|s| crate::util::mse(&s.theta, &want_t))
            .sum::<f64>();
        assert!(
            after < before * 0.2,
            "distortion barely reduced: {before} -> {after}"
        );
        // mean must be preserved by averaging (up to fp noise)
        let (new_mean, _) = mean_of(&states, &agg);
        crate::testing::assert_allclose(&new_mean, &want_t, 1e-4, 1e-5);
    }

    #[test]
    fn aggregates_only_the_aggregator_subset() {
        let n = 10;
        let mut states = random_states(n, 8, 24);
        let before9 = states[9].theta.clone();
        let agg: Vec<usize> = (0..8).collect(); // 8 = 2^3 grid
        let (mut mar, _) = mar(n, 2, 3);
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        assert_eq!(states[9].theta, before9);
    }

    #[test]
    fn no_revisit_within_iteration() {
        // on a perfect grid, track groupmates across rounds: no pair may
        // meet twice within one aggregate() call
        let n = 16;
        let m = 4;
        let d = 2;
        let keys = grid_keys(n, m, d);
        let mut met = std::collections::HashSet::new();
        let mut keys = keys;
        for g in 0..d {
            let mut by_key: BTreeMap<String, Vec<usize>> = BTreeMap::new();
            for (pos, k) in keys.iter().enumerate() {
                by_key.entry(k.reduced(g)).or_default().push(pos);
            }
            for (_, group) in by_key {
                for i in 0..group.len() {
                    for j in i + 1..group.len() {
                        let pair = (group[i], group[j]);
                        assert!(
                            met.insert(pair),
                            "pair {pair:?} met twice (round {g})"
                        );
                    }
                }
                for (chunk, &pos) in group.iter().enumerate() {
                    keys[pos].set_chunk(g, chunk);
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_cuts_group_traffic() {
        let n = 27;
        let mut tc = TestCtx::new(1024);
        let run = |exchange, tc: &mut TestCtx| {
            let mut states = random_states(n, 1024, 26);
            let agg: Vec<usize> = (0..n).collect();
            let mut mar = MarAggregator::new(n, 3, 3, tc.ledger.clone(), 7)
                .with_exchange(exchange);
            tc.ledger.reset();
            let mut ctx = tc.ctx();
            mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
            // exactness must be identical in both modes
            let (mean, _) = mean_of(&states, &agg);
            for s in &states {
                crate::testing::assert_allclose(&s.theta, &mean, 1e-4, 1e-5);
            }
            tc.ledger.snapshot().data_bytes
        };
        let full = run(crate::aggregation::GroupExchange::FullGather, &mut tc);
        let rs = run(crate::aggregation::GroupExchange::ReduceScatter, &mut tc);
        // M=3: reduce-scatter moves 2(k-1)/k = 4/3 chunks vs (k-1) = 2
        // full states per member -> ratio 2/(4/3) = 1.5
        let ratio = full as f64 / rs as f64;
        assert!((1.3..1.7).contains(&ratio), "RS saving ratio {ratio}");
    }

    #[test]
    fn control_plane_books_bytes_but_far_less_than_data() {
        // realistic model size (the cnn task's P_pad): control traffic is
        // size-independent, so the paper's "negligible" claim is about
        // real models, not toy vectors
        let n = 27;
        let p = 18432;
        let mut states = random_states(n, p, 25);
        let agg: Vec<usize> = (0..n).collect();
        let mut tc = TestCtx::new(p);
        let mut mar = mar_on(&tc, n, 3, 3);
        tc.ledger.reset(); // drop DHT join traffic; measure one iteration
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        let s = tc.ledger.snapshot();
        assert!(s.control_bytes > 0, "no control traffic booked");
        assert!(
            s.control_bytes * 10 < s.data_bytes,
            "control plane ({}) not negligible vs data ({})",
            s.control_bytes,
            s.data_bytes
        );
    }
}
