"""Layer-2 JAX model definitions for MAR-FL (build-time only).

Two models, matching the paper's two tasks:

* ``cnn``  — the MNIST-like vision task: a small two-block convolutional
  network with an MLP head over 16x16x1 synthetic digit images, 10 classes
  (paper: two-block CNN on MNIST).
* ``head`` — the 20NG-like language task: a trainable MLP classification
  head over frozen-encoder embeddings (d=64), 20 classes (paper: frozen
  DistilBERT + head; the frozen encoder is simulated by the Rust data
  substrate, which emits CLS-like embeddings directly — DESIGN.md
  §Substitutions).

Flat-parameter ABI (DESIGN.md): every entry point sees parameters as a
single ``f32[P_pad]`` vector, ``P_pad`` a multiple of the momentum kernel's
STRIP so the fused update strip-mines cleanly. Rust never learns the pytree
structure.

Entry points lowered by aot.py, per model:
  train_step(theta, mom, x, y, eta, mu)          -> (theta', mom', loss)
  eval_step(theta, x, y)                         -> (loss_sum, correct)
  logits(theta, x)                               -> z[B,C]
  kd_step(theta, mom, x, y, zbar, lam, eta, mu)  -> (theta', mom', loss)

All training losses run through the fused Pallas softmax-XENT kernel; all
updates through the fused Pallas momentum kernel.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.flatten_util import ravel_pytree

from compile.kernels.momentum import STRIP, fused_momentum
from compile.kernels.softmax_xent import softmax_xent

# KD temperature (paper: tau = 3.0, Hinton et al. 2015). Fixed at lowering.
KD_TAU = 3.0


# --------------------------------------------------------------------------
# Model zoo
# --------------------------------------------------------------------------

class ModelSpec:
    """Static description of one model variant."""

    def __init__(self, name, input_shape, classes, batch, eval_chunk):
        self.name = name
        self.input_shape = tuple(input_shape)  # per-example
        self.classes = classes
        self.batch = batch          # local-update minibatch (paper: 64 / 16)
        self.eval_chunk = eval_chunk

    def batched(self, n):
        return (n,) + self.input_shape


MODELS = {
    # paper: MNIST, 64 samples per peer per round
    "cnn": ModelSpec("cnn", (16, 16, 1), 10, 64, 250),
    # paper: 20NG, 16 samples per peer per round
    "head": ModelSpec("head", (64,), 20, 16, 250),
}


def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def init_params(name: str, seed: int = 0):
    """Initial parameter pytree (identical across peers, paper §2.2)."""
    key = jax.random.PRNGKey(seed)
    if name == "cnn":
        k = jax.random.split(key, 4)
        return {
            "conv1_w": _he(k[0], (3, 3, 1, 8), 9),
            "conv1_b": jnp.zeros((8,), jnp.float32),
            "conv2_w": _he(k[1], (3, 3, 8, 16), 72),
            "conv2_b": jnp.zeros((16,), jnp.float32),
            "fc1_w": _he(k[2], (256, 64), 256),
            "fc1_b": jnp.zeros((64,), jnp.float32),
            "fc2_w": _he(k[3], (64, 10), 64),
            "fc2_b": jnp.zeros((10,), jnp.float32),
        }
    if name == "head":
        k = jax.random.split(key, 2)
        return {
            "fc1_w": _he(k[0], (64, 128), 64),
            "fc1_b": jnp.zeros((128,), jnp.float32),
            "fc2_w": _he(k[1], (128, 20), 128),
            "fc2_b": jnp.zeros((20,), jnp.float32),
        }
    raise ValueError(f"unknown model {name!r}")


def _maxpool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(name: str, params, x):
    """Logits for a batch. cnn: x[B,16,16,1]; head: x[B,64]."""
    if name == "cnn":
        h = lax.conv_general_dilated(
            x, params["conv1_w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + params["conv1_b"]
        h = jax.nn.relu(h)
        h = _maxpool2(h)  # 8x8x8
        h = lax.conv_general_dilated(
            h, params["conv2_w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + params["conv2_b"]
        h = jax.nn.relu(h)
        h = _maxpool2(h)  # 4x4x16
        h = h.reshape((h.shape[0], -1))  # 256
        h = jax.nn.relu(h @ params["fc1_w"] + params["fc1_b"])
        return h @ params["fc2_w"] + params["fc2_b"]
    if name == "head":
        h = jax.nn.relu(x @ params["fc1_w"] + params["fc1_b"])
        return h @ params["fc2_w"] + params["fc2_b"]
    raise ValueError(f"unknown model {name!r}")


# --------------------------------------------------------------------------
# Flat-parameter ABI
# --------------------------------------------------------------------------

def flat_info(name: str):
    """(param_count P, padded length P_pad, unflatten fn)."""
    params = init_params(name)
    flat, unflatten = ravel_pytree(params)
    p = flat.shape[0]
    p_pad = ((p + STRIP - 1) // STRIP) * STRIP
    return p, p_pad, unflatten


def pad_flat(flat: jax.Array, p_pad: int) -> jax.Array:
    return jnp.concatenate(
        [flat, jnp.zeros((p_pad - flat.shape[0],), jnp.float32)]
    )


def init_flat(name: str, seed: int = 0) -> jax.Array:
    """Initial parameters as the padded flat vector Rust loads from disk."""
    _, p_pad, _ = flat_info(name)
    flat, _ = ravel_pytree(init_params(name, seed))
    return pad_flat(flat, p_pad)


# --------------------------------------------------------------------------
# Entry points (lowered by aot.py)
# --------------------------------------------------------------------------

def _mean_xent(z, y, classes):
    onehot = jax.nn.one_hot(y, classes, dtype=jnp.float32)
    return jnp.mean(softmax_xent(z, onehot))


def make_train_step(name: str):
    spec = MODELS[name]
    p, p_pad, unflatten = flat_info(name)

    def train_step(theta, mom, x, y, eta, mu):
        params = unflatten(theta[:p])

        def loss_fn(params):
            return _mean_xent(forward(name, params, x), y, spec.classes)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        gflat = pad_flat(ravel_pytree(grads)[0], p_pad)
        theta2, mom2 = fused_momentum(theta, mom, gflat, eta, mu)
        return theta2, mom2, loss

    return train_step


def make_eval_step(name: str):
    spec = MODELS[name]
    p, _, unflatten = flat_info(name)

    def eval_step(theta, x, y):
        params = unflatten(theta[:p])
        z = forward(name, params, x)
        logp = jax.nn.log_softmax(z, axis=-1)
        onehot = jax.nn.one_hot(y, spec.classes, dtype=jnp.float32)
        loss_sum = -jnp.sum(onehot * logp)
        correct = jnp.sum((jnp.argmax(z, axis=-1) == y).astype(jnp.float32))
        return loss_sum, correct

    return eval_step


def make_logits(name: str):
    p, _, unflatten = flat_info(name)

    def logits(theta, x):
        return forward(name, unflatten(theta[:p]), x)

    return logits


def make_kd_step(name: str, tau: float = KD_TAU):
    """Moshpit-KD student step (Algorithm 2): L = (1-lam)*CE + lam*tau^2*KL,
    lam the linearly-decayed KL weight, zbar the averaged top-ell teacher
    ensemble logits."""
    spec = MODELS[name]
    p, p_pad, unflatten = flat_info(name)

    def kd_step(theta, mom, x, y, zbar, lam, eta, mu):
        params = unflatten(theta[:p])
        l = lam[0]

        def loss_fn(params):
            s = forward(name, params, x)
            onehot = jax.nn.one_hot(y, spec.classes, dtype=jnp.float32)
            ce = jnp.mean(softmax_xent(s, onehot))
            # KL(p_teacher || p_student) at temperature tau, Hinton rescaling
            pt = jax.nn.softmax(zbar / tau, axis=-1)
            log_pt = jax.nn.log_softmax(zbar / tau, axis=-1)
            log_ps = jax.nn.log_softmax(s / tau, axis=-1)
            kl = jnp.mean(jnp.sum(pt * (log_pt - log_ps), axis=-1))
            return (1.0 - l) * ce + l * (tau ** 2) * kl

        loss, grads = jax.value_and_grad(loss_fn)(params)
        gflat = pad_flat(ravel_pytree(grads)[0], p_pad)
        theta2, mom2 = fused_momentum(theta, mom, gflat, eta, mu)
        return theta2, mom2, loss

    return kd_step
