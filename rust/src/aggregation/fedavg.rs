//! Client-server FedAvg reference (McMahan et al. 2017).
//!
//! A virtual server collects every aggregator's state, averages, and
//! broadcasts — 2N state transfers per iteration, O(N) bytes, but all of
//! them crossing the single server link: the simulated clock charges
//! uploads and broadcasts sequentially at the server, reproducing the
//! coordinator bottleneck the paper's P2P pitch targets.

use anyhow::Result;

use super::robust::RobustPolicy;
use super::{
    payload_bytes, robust_mean_of, AggCtx, AggReport, Aggregate, PeerState,
    Theta,
};
use crate::metrics::Plane;
use crate::net::LinkFault;

#[derive(Debug, Default)]
pub struct FedAvgServer {
    /// Server-side center estimator over ALL received uploads (`Mean`
    /// delegates to the bit-exact legacy average). A trusted server is
    /// the easiest place to run robust statistics — the baseline the
    /// Byzantine bench compares MAR's in-group defenses against.
    robust: RobustPolicy,
}

impl FedAvgServer {
    /// Select the server's center estimator.
    pub fn with_robust(mut self, robust: RobustPolicy) -> Self {
        self.robust = robust;
        self
    }
}

impl Aggregate for FedAvgServer {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn aggregate(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport> {
        if agg.len() < 2 {
            return Ok(AggReport::default());
        }
        let bytes = payload_bytes(states, agg);
        if ctx.faults.enabled() {
            return self.aggregate_faulty(states, agg, bytes, ctx);
        }
        // N uploads through the server's ingress link (sequential at the
        // server — the bottleneck), then the average, then N broadcasts.
        let upload = ctx.fabric.sequential(agg.len(), bytes, Plane::Data);
        let (theta, mom) = robust_mean_of(states, agg, self.robust);
        let (theta, mom) = (Theta::new(theta), Theta::new(mom));
        let broadcast = ctx.fabric.sequential(agg.len(), bytes, Plane::Data);
        ctx.clock.advance(upload + broadcast);
        // the broadcast hands every aggregator a shared handle on the one
        // server-side mean (zero-copy)
        for &i in agg {
            states[i].theta = theta.clone();
            states[i].momentum = mom.clone();
        }
        Ok(AggReport { rounds: 1, groups: 1, ..Default::default() })
    }
}

impl FedAvgServer {
    /// Fault-plan round: crashed clients never contact the server, lost
    /// uploads (timeouts after the retry budget) are excluded from the
    /// mean, and a lost broadcast leaves that client stale — every
    /// attempt and probe is booked either way. Only reached when the
    /// plan is live; the fault-free path above stays draw-free.
    fn aggregate_faulty(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        bytes: u64,
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport> {
        let fp = ctx.faults;
        let mut report =
            AggReport { rounds: 1, groups: 1, ..Default::default() };
        // mid-round crash draws (serial, aggregator order)
        let mut live: Vec<usize> = Vec::with_capacity(agg.len());
        if fp.crash_prob > 0.0 {
            for &i in agg {
                if ctx.rng.chance(fp.crash_prob) {
                    report.faults.crashes += 1;
                } else {
                    live.push(i);
                }
            }
        } else {
            live.extend_from_slice(agg);
        }
        let link_on = fp.link_faults_enabled();
        // uploads: one message per live client through the server's
        // sequential ingress link
        let mut upload = 0.0f64;
        let mut received: Vec<usize> = Vec::with_capacity(live.len());
        for &i in &live {
            let lf = if link_on {
                // the client's single radio channel carries both its
                // upload and its download, so both legs key the same
                // diagonal (i, i) Gilbert–Elliott chain
                let lf = fp.draw_directed(
                    i,
                    i,
                    1,
                    false,
                    ctx.links.as_deref_mut(),
                    ctx.rng,
                );
                report.faults.absorb(&lf);
                lf
            } else {
                LinkFault::CLEAN
            };
            upload += ctx.fabric.send_faulty(bytes, Plane::Data, &lf);
            if !lf.lost() {
                received.push(i);
            }
        }
        if received.len() < 2 {
            // not enough surviving uploads to average
            ctx.clock.advance(upload);
            return Ok(report);
        }
        if received.len() < agg.len() {
            report.faults.quorum_degraded_rounds += 1;
        }
        let (theta, mom) = robust_mean_of(states, &received, self.robust);
        let (theta, mom) = (Theta::new(theta), Theta::new(mom));
        // broadcasts: every live client gets a download attempt; a lost
        // broadcast leaves that client on its pre-round state
        let mut broadcast = 0.0f64;
        for &i in &live {
            let lf = if link_on {
                let lf = fp.draw_directed(
                    i,
                    i,
                    1,
                    false,
                    ctx.links.as_deref_mut(),
                    ctx.rng,
                );
                report.faults.absorb(&lf);
                lf
            } else {
                LinkFault::CLEAN
            };
            broadcast += ctx.fabric.send_faulty(bytes, Plane::Data, &lf);
            if !lf.lost() {
                states[i].theta = theta.clone();
                states[i].momentum = mom.clone();
            }
        }
        ctx.clock.advance(upload + broadcast);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::robust::RobustEstimator;
    use crate::aggregation::test_support::*;
    use crate::aggregation::mean_of;

    #[test]
    fn produces_exact_global_average() {
        let mut states = random_states(6, 32, 3);
        let agg: Vec<usize> = (0..6).collect();
        let (want_t, _) = mean_of(&states, &agg);
        let mut tc = TestCtx::new(32);
        let mut ctx = tc.ctx();
        FedAvgServer::default().aggregate(&mut states, &agg, &mut ctx).unwrap();
        for s in &states {
            crate::testing::assert_allclose(&s.theta, &want_t, 1e-6, 1e-7);
        }
    }

    #[test]
    fn robust_server_bounds_one_amplified_upload() {
        // one client uploads a 100×-amplified state; the trimmed server
        // mean must land inside the honest envelope while the plain mean
        // is dragged far outside it
        let n = 6;
        let mk = || {
            let mut states = random_states(n, 16, 7);
            for v in states[3].theta.make_mut_slice() {
                *v *= 100.0;
            }
            states
        };
        let agg: Vec<usize> = (0..n).collect();
        let honest_max = mk()
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != 3)
            .flat_map(|(_, s)| s.theta.iter().map(|v| v.abs()))
            .fold(0.0f32, f32::max);
        let mut plain = mk();
        let mut tc = TestCtx::new(16);
        FedAvgServer::default()
            .aggregate(&mut plain, &agg, &mut tc.ctx())
            .unwrap();
        let mut robust = mk();
        let mut tc2 = TestCtx::new(16);
        FedAvgServer::default()
            .with_robust(RobustPolicy {
                est: RobustEstimator::TrimmedMean,
                trim: 0.25,
            })
            .aggregate(&mut robust, &agg, &mut tc2.ctx())
            .unwrap();
        let plain_max =
            plain[0].theta.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        let robust_max =
            robust[0].theta.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        assert!(robust_max <= honest_max, "{robust_max} vs {honest_max}");
        assert!(plain_max > 2.0 * honest_max, "{plain_max} vs {honest_max}");
    }

    #[test]
    fn books_2n_transfers() {
        let mut states = random_states(10, 16, 4);
        let agg: Vec<usize> = (0..10).collect();
        let mut tc = TestCtx::new(16);
        let mut ctx = tc.ctx();
        FedAvgServer::default().aggregate(&mut states, &agg, &mut ctx).unwrap();
        let snap = tc.ledger.snapshot();
        assert_eq!(snap.data_msgs, 20);
        assert_eq!(snap.data_bytes, 20 * 2 * 16 * 4);
        assert!(tc.clock.now() > 0.0);
    }

    #[test]
    fn respects_aggregator_subset() {
        let mut states = random_states(5, 8, 5);
        let before2 = states[2].theta.clone();
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        FedAvgServer::default()
            .aggregate(&mut states, &[0, 1, 3], &mut ctx)
            .unwrap();
        assert_eq!(states[2].theta, before2, "non-aggregator was touched");
    }

    #[test]
    fn single_peer_is_noop() {
        let mut states = random_states(3, 8, 6);
        let before = states[1].theta.clone();
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        let rep = FedAvgServer::default()
            .aggregate(&mut states, &[1], &mut ctx)
            .unwrap();
        assert_eq!(rep, AggReport::default());
        assert_eq!(states[1].theta, before);
    }
}
