//! Parallel Moshpit-KD verification: the student-lane engine must be
//! *bit-identical* to the serial reference — same peer states, same
//! ledger totals, same simulated clock, same report — and the zero-copy
//! `Theta` snapshots must alias peer state without ever being perturbed
//! by a student's distillation updates.

use std::sync::Arc;

use marfl::aggregation::{AggCtx, PeerState, Theta};
use marfl::config::KdConfig;
use marfl::coordinator::MarAggregator;
use marfl::data::{build as build_data, FlData};
use marfl::fl::Trainer;
use marfl::kd::{KdEngine, KdReport};
use marfl::metrics::{CommLedger, CommSnapshot};
use marfl::models::default_artifact_dir;
use marfl::net::Fabric;
use marfl::rng::Rng;
use marfl::runtime::Runtime;
use marfl::sim::SimClock;

const PEERS: usize = 12;
const GROUP: usize = 4;
const ROUNDS: usize = 2;

fn data(rng: &mut Rng) -> FlData {
    build_data("head", PEERS, 32, 250, true, 1.0, rng)
}

/// One full MKD pass on a fresh, identically seeded world; returns
/// (states, ledger snapshot, simulated clock, report).
fn run_mkd(
    parallel: bool,
) -> (Vec<PeerState>, CommSnapshot, f64, KdReport) {
    let rt = Runtime::new(&default_artifact_dir()).unwrap();
    let model = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(0x5EED);
    let mut fl = data(&mut rng.fork(1));
    let theta0 = rt.init_params("head").unwrap();
    let mut states = vec![PeerState::new(theta0); PEERS];
    let agg: Vec<usize> = (0..PEERS).collect();
    let ledger = Arc::new(CommLedger::new());
    let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
    let mut mar = MarAggregator::new(PEERS, GROUP, ROUNDS, ledger.clone(), 7);
    ledger.reset(); // drop DHT join traffic
    let kd = KdEngine::new(
        KdConfig { enabled: true, k_iterations: 6, rho_ell: 0.4, epochs: 2 },
        rt.meta.kd_tau,
        0.1,
        0.9,
    )
    .with_parallel(parallel);
    let mut clock = SimClock::new();
    let mut kd_rng = rng.fork(2);
    let mut ctx = AggCtx {
        fabric: &fabric,
        clock: &mut clock,
        rng: &mut kd_rng,
        runtime: Some(&rt),
        model: &model,
        faults: &marfl::net::FaultConfig::OFF,
        links: None,
    };
    let report = kd
        .run_mkd(
            1,
            &rt,
            &model,
            &fl.train,
            &mut fl.shards,
            &mut states,
            &agg,
            &mut mar,
            &mut ctx,
        )
        .unwrap();
    (states, ledger.snapshot(), clock.now(), report)
}

/// The headline determinism guarantee: student-parallel MKD yields the
/// exact same peer states, byte/message totals, simulated time and
/// report as the serial reference.
#[test]
fn parallel_and_serial_mkd_bit_identical() {
    let (s_states, s_ledger, s_clock, s_report) = run_mkd(false);
    let (p_states, p_ledger, p_clock, p_report) = run_mkd(true);
    for (i, (a, b)) in s_states.iter().zip(&p_states).enumerate() {
        assert_eq!(a.theta, b.theta, "peer {i} theta diverged");
        assert_eq!(a.momentum, b.momentum, "peer {i} momentum diverged");
    }
    assert_eq!(s_ledger, p_ledger, "ledger totals diverged");
    assert_eq!(
        s_clock.to_bits(),
        p_clock.to_bits(),
        "simulated clock diverged"
    );
    assert_eq!(s_report.kd_steps, p_report.kd_steps);
    assert_eq!(s_report.teacher_transfers, p_report.teacher_transfers);
    assert_eq!(
        s_report.mean_loss.to_bits(),
        p_report.mean_loss.to_bits(),
        "mean loss diverged"
    );
    // the pass actually did work
    assert!(s_report.kd_steps > 0);
    assert!(s_report.teacher_transfers > 0);
}

/// Zero-copy snapshot aliasing: handles cloned before the MKD pass alias
/// peer state (no buffer copies), and a student's distillation updates
/// must never leak through them — exactly the guarantee the in-pass
/// round-start teacher snapshots rely on.
#[test]
fn mkd_updates_never_perturb_aliased_snapshots() {
    let rt = Runtime::new(&default_artifact_dir()).unwrap();
    let model = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(0xA11A5);
    let mut fl = data(&mut rng.fork(1));
    let theta0 = rt.init_params("head").unwrap();
    let mut states = vec![PeerState::new(theta0.clone()); PEERS];
    // every peer starts from one shared θ⁰ allocation (zero-copy init)
    assert!(states[0].theta.shares_storage(&states[PEERS - 1].theta));
    // alias every peer's θ the same way run_mkd snapshots teachers
    let snapshots: Vec<Theta> =
        states.iter().map(|s| s.theta.clone()).collect();
    let frozen: Vec<Vec<f32>> =
        snapshots.iter().map(|s| s.to_vec()).collect();
    let agg: Vec<usize> = (0..PEERS).collect();
    let ledger = Arc::new(CommLedger::new());
    let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
    let mut mar = MarAggregator::new(PEERS, GROUP, ROUNDS, ledger.clone(), 7);
    let kd = KdEngine::new(
        KdConfig { enabled: true, k_iterations: 6, rho_ell: 0.4, epochs: 1 },
        rt.meta.kd_tau,
        0.1,
        0.9,
    );
    let mut clock = SimClock::new();
    let mut kd_rng = rng.fork(2);
    let mut ctx = AggCtx {
        fabric: &fabric,
        clock: &mut clock,
        rng: &mut kd_rng,
        runtime: Some(&rt),
        model: &model,
        faults: &marfl::net::FaultConfig::OFF,
        links: None,
    };
    kd.run_mkd(
        1,
        &rt,
        &model,
        &fl.train,
        &mut fl.shards,
        &mut states,
        &agg,
        &mut mar,
        &mut ctx,
    )
    .unwrap();
    // the students moved...
    let moved = states
        .iter()
        .zip(&snapshots)
        .filter(|(st, snap)| st.theta != **snap)
        .count();
    assert!(moved > 0, "MKD pass did not update any student");
    // ...but every aliased snapshot still holds the exact pre-pass bytes
    for (i, (snap, want)) in snapshots.iter().zip(&frozen).enumerate() {
        assert_eq!(snap, want, "aliased snapshot {i} was perturbed");
    }
}

/// End-to-end reproducibility with MKD active on the thread pool: two
/// identical trainer runs finish in bit-identical states.
#[test]
fn trainer_with_mkd_bit_reproducible() {
    let rt = Runtime::new(&default_artifact_dir()).unwrap();
    let run = || {
        let mut cfg = marfl::config::ExperimentConfig {
            model: "head".into(),
            peers: 9,
            group_size: 3,
            iterations: 3,
            samples_per_peer: 32,
            test_samples: 250,
            eval_every: 3,
            local_batches: 2,
            seed: 4321,
            ..Default::default()
        };
        cfg.kd.enabled = true;
        cfg.kd.k_iterations = 2;
        let mut t = Trainer::new(cfg, &rt).unwrap();
        let summary = t.run().unwrap();
        let states: Vec<PeerState> = t.states().to_vec();
        (states, summary.comm, summary.sim_time_s)
    };
    let (a_states, a_comm, a_time) = run();
    let (b_states, b_comm, b_time) = run();
    assert_eq!(a_comm, b_comm);
    assert_eq!(a_time.to_bits(), b_time.to_bits());
    for (a, b) in a_states.iter().zip(&b_states) {
        assert_eq!(a.theta, b.theta);
        assert_eq!(a.momentum, b.momentum);
    }
}
