//! Scenario: capacity planning — how does each aggregation technique's
//! per-iteration traffic grow with the federation size? Measures the
//! ledger for N ∈ {8, 16, 27, 64, 125, 216} (no training needed: traffic
//! is independent of parameter values) and prints the scaling table that
//! motivates the paper (O(N log N) vs O(N²)). A second sweep drives the
//! parallel round engine against the serial reference (wall-clock per
//! MAR aggregate, `MARFL_THREADS` sizes the pool) and the chunk-owned
//! reduce-scatter wire protocol (per-phase ledger bytes vs full-gather).
//!
//! ```bash
//! cargo run --release --example scaling_sweep
//! MARFL_THREADS=4 cargo run --release --example scaling_sweep
//! ```

use std::sync::Arc;
use std::time::Instant;

use marfl::aggregation::{
    AggCtx, Aggregate, AllToAll, FedAvgServer, GroupExchange, PeerState, RingRdfl,
};
use marfl::coordinator::{AggOptions, MarAggregator};
use marfl::metrics::{CommLedger, CommSnapshot};
use marfl::net::Fabric;
use marfl::rng::Rng;
use marfl::sim::SimClock;

const P: usize = 18432; // cnn-size states

/// (N, M, G) sweep points: perfect grids where available.
const SWEEP: &[(usize, usize, usize)] =
    &[(8, 2, 3), (16, 4, 2), (27, 3, 3), (64, 4, 3), (125, 5, 3), (216, 6, 3)];

fn model() -> marfl::models::ModelMeta {
    marfl::models::ModelMeta {
        name: "cnn".into(),
        param_count: P,
        padded_len: P,
        input_shape: vec![16, 16, 1],
        classes: 10,
        batch: 64,
        eval_chunk: 250,
        init_file: String::new(),
        artifacts: Default::default(),
    }
}

fn states(n: usize, rng: &mut Rng) -> Vec<PeerState> {
    (0..n)
        .map(|_| PeerState {
            theta: (0..P).map(|_| rng.normal() as f32).collect(),
            momentum: marfl::params::Theta::zeros(P),
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    println!("per-iteration data traffic (MiB), cnn-size states (2·{P}·4 B each)\n");
    println!(
        "{:>5} {:>10} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "N", "FedAvg", "MAR-FL", "RDFL", "AR-FL", "MAR msgs", "N(N-1)"
    );
    for &(n, m, g) in SWEEP {
        let measure = |which: &str| -> (u64, u64) {
            let ledger = Arc::new(CommLedger::new());
            let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
            let mut clock = SimClock::new();
            let mut rng = Rng::new(9);
            let mut st = states(n, &mut rng);
            let agg: Vec<usize> = (0..n).collect();
            let mdl = model();
            let mut mar;
            let aggregator: &mut dyn Aggregate = match which {
                "marfl" => {
                    mar = MarAggregator::new(n, m, g, ledger.clone(), 3);
                    ledger.reset(); // exclude one-time join traffic
                    &mut mar
                }
                "fedavg" => &mut FedAvgServer,
                "rdfl" => &mut RingRdfl,
                _ => &mut AllToAll,
            };
            let mut ctx = AggCtx {
                fabric: &fabric,
                clock: &mut clock,
                rng: &mut rng,
                runtime: None,
                model: &mdl,
                faults: &marfl::net::FaultConfig::OFF,
                links: None,
            };
            aggregator.aggregate(&mut st, &agg, &mut ctx).unwrap();
            let s = ledger.snapshot();
            (s.data_bytes, s.data_msgs)
        };
        let (fedavg, _) = measure("fedavg");
        let (marfl, mar_msgs) = measure("marfl");
        let (rdfl, _) = measure("rdfl");
        let (arfl, _) = measure("arfl");
        let mib = |b: u64| b as f64 / (1 << 20) as f64;
        println!(
            "{:>5} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>12} {:>12}",
            n,
            mib(fedavg),
            mib(marfl),
            mib(rdfl),
            mib(arfl),
            mar_msgs,
            n * (n - 1)
        );
    }
    println!(
        "\nMAR-FL transfers ≈ N·G·(M−1) = O(N log_M N); ring/all-to-all = N(N−1) = O(N²)."
    );

    // ---- round engine + wire protocol sweep -------------------------
    // serial vs parallel: wall-clock of one MAR aggregate on this host
    // (record the columns in EXPERIMENTS.md §Reduce-scatter);
    // full-gather vs reduce-scatter: ledger bytes, split by phase
    println!(
        "\nMAR round engine ({} threads) and wire protocol\n",
        marfl::exec::threads()
    );
    println!(
        "{:>5} {:>11} {:>13} {:>8} {:>9} {:>9} {:>7}",
        "N", "serial(ms)", "parallel(ms)", "speedup", "RS(MiB)", "AG(MiB)", "FG/RS"
    );
    let mib = |b: u64| b as f64 / (1 << 20) as f64;
    for &(n, m, g) in SWEEP {
        let time_engine = |parallel: bool| -> f64 {
            let ledger = Arc::new(CommLedger::new());
            let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
            let mut clock = SimClock::new();
            let mut rng = Rng::new(9);
            let mut st = states(n, &mut rng);
            let agg: Vec<usize> = (0..n).collect();
            let mdl = model();
            let mut mar = MarAggregator::with_options(
                n,
                m,
                g,
                ledger.clone(),
                3,
                AggOptions { parallel, ..AggOptions::default() },
            );
            let mut ctx = AggCtx {
                fabric: &fabric,
                clock: &mut clock,
                rng: &mut rng,
                runtime: None,
                model: &mdl,
                faults: &marfl::net::FaultConfig::OFF,
                links: None,
            };
            // warm the pool and the scratch buffers, then time one call
            mar.aggregate(&mut st, &agg, &mut ctx).unwrap();
            let t0 = Instant::now();
            mar.aggregate(&mut st, &agg, &mut ctx).unwrap();
            t0.elapsed().as_secs_f64() * 1e3
        };
        let serial_ms = time_engine(false);
        let parallel_ms = time_engine(true);
        let measure_mode = |exchange: GroupExchange| -> CommSnapshot {
            let ledger = Arc::new(CommLedger::new());
            let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
            let mut clock = SimClock::new();
            let mut rng = Rng::new(9);
            let mut st = states(n, &mut rng);
            let agg: Vec<usize> = (0..n).collect();
            let mdl = model();
            let mut mar = MarAggregator::with_options(
                n,
                m,
                g,
                ledger.clone(),
                3,
                AggOptions { exchange, ..AggOptions::default() },
            );
            ledger.reset(); // exclude one-time join traffic
            let mut ctx = AggCtx {
                fabric: &fabric,
                clock: &mut clock,
                rng: &mut rng,
                runtime: None,
                model: &mdl,
                faults: &marfl::net::FaultConfig::OFF,
                links: None,
            };
            mar.aggregate(&mut st, &agg, &mut ctx).unwrap();
            ledger.snapshot()
        };
        let fg = measure_mode(GroupExchange::FullGather);
        let rs = measure_mode(GroupExchange::ReduceScatter);
        println!(
            "{:>5} {:>11.1} {:>13.1} {:>7.2}x {:>9.1} {:>9.1} {:>6.2}x",
            n,
            serial_ms,
            parallel_ms,
            serial_ms / parallel_ms,
            mib(rs.rs_bytes),
            mib(rs.ag_bytes),
            fg.data_bytes as f64 / rs.data_bytes as f64
        );
    }
    println!(
        "\nreduce-scatter moves 2(M−1)/M state transfers per member (M/2× less \
         than full-gather) and cuts per-member averaging FLOPs ~M×."
    );
    Ok(())
}
