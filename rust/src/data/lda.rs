//! Latent-Dirichlet-Allocation partitioner (paper §3.1: α = 1.0).
//!
//! Standard FL heterogeneity protocol: for every class, draw peer
//! proportions from Dirichlet(α·1_N) and deal that class's examples to
//! peers accordingly. Small α ⇒ each class concentrates on few peers
//! (strong non-iid); large α ⇒ approaches iid.

use super::Dataset;
use crate::rng::Rng;

/// Non-iid split: one index list per peer.
pub fn partition_lda(
    data: &Dataset,
    peers: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert!(peers > 0);
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); data.classes];
    for i in 0..data.len() {
        by_class[data.y[i] as usize].push(i);
    }
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); peers];
    for idxs in by_class.iter_mut() {
        rng.shuffle(idxs);
        let props = rng.dirichlet(alpha, peers);
        // convert proportions to cumulative example counts
        let n = idxs.len();
        let mut cuts = Vec::with_capacity(peers);
        let mut acc = 0.0;
        for p in &props {
            acc += p;
            cuts.push(((acc * n as f64).round() as usize).min(n));
        }
        let mut start = 0;
        for (peer, &cut) in cuts.iter().enumerate() {
            if cut > start {
                shards[peer].extend_from_slice(&idxs[start..cut]);
                start = cut;
            }
        }
        // rounding remainder to the last peer
        if start < n {
            shards[peers - 1].extend_from_slice(&idxs[start..]);
        }
    }
    rebalance_empty(&mut shards, rng);
    shards
}

/// iid split: random equal-size deal.
pub fn partition_iid(data: &Dataset, peers: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut idxs: Vec<usize> = (0..data.len()).collect();
    rng.shuffle(&mut idxs);
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); peers];
    for (i, idx) in idxs.into_iter().enumerate() {
        shards[i % peers].push(idx);
    }
    shards
}

/// No peer may end up with an empty shard (it could not run a local
/// update); steal one example from the largest shard if needed.
fn rebalance_empty(shards: &mut [Vec<usize>], _rng: &mut Rng) {
    loop {
        let Some(empty) = shards.iter().position(|s| s.is_empty()) else {
            return;
        };
        let donor = shards
            .iter()
            .enumerate()
            .max_by_key(|(_, s)| s.len())
            .map(|(i, _)| i)
            .unwrap();
        if shards[donor].len() <= 1 {
            return; // nothing to steal; degenerate input
        }
        let moved = shards[donor].pop().unwrap();
        shards[empty].push(moved);
    }
}

/// Heterogeneity diagnostic: mean total-variation distance between each
/// peer's class distribution and the global one (0 = iid).
pub fn heterogeneity(data: &Dataset, shards: &[Vec<usize>]) -> f64 {
    let global = class_dist(data, &(0..data.len()).collect::<Vec<_>>());
    let mut tv = 0.0;
    let mut counted = 0;
    for s in shards {
        if s.is_empty() {
            continue;
        }
        let local = class_dist(data, s);
        tv += global
            .iter()
            .zip(&local)
            .map(|(g, l)| (g - l).abs())
            .sum::<f64>()
            / 2.0;
        counted += 1;
    }
    tv / counted.max(1) as f64
}

fn class_dist(data: &Dataset, idxs: &[usize]) -> Vec<f64> {
    let mut counts = vec![0.0f64; data.classes];
    for &i in idxs {
        counts[data.y[i] as usize] += 1.0;
    }
    let n: f64 = counts.iter().sum();
    if n > 0.0 {
        for c in &mut counts {
            *c /= n;
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn dataset(n: usize, seed: u64) -> Dataset {
        synth::newsgroups_like(n, &mut Rng::new(seed))
    }

    #[test]
    fn lda_partition_is_exact_cover() {
        let d = dataset(1000, 1);
        let shards = partition_lda(&d, 16, 1.0, &mut Rng::new(2));
        assert_eq!(shards.len(), 16);
        let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn no_empty_shards() {
        let d = dataset(500, 3);
        // very non-iid: alpha = 0.05 would naturally starve peers
        let shards = partition_lda(&d, 25, 0.05, &mut Rng::new(4));
        assert!(shards.iter().all(|s| !s.is_empty()));
    }

    #[test]
    fn iid_partition_balanced() {
        let d = dataset(1000, 5);
        let shards = partition_iid(&d, 8, &mut Rng::new(6));
        for s in &shards {
            assert_eq!(s.len(), 125);
        }
    }

    #[test]
    fn smaller_alpha_more_heterogeneous() {
        let d = dataset(4000, 7);
        let iid = partition_iid(&d, 20, &mut Rng::new(8));
        let mild = partition_lda(&d, 20, 1.0, &mut Rng::new(8));
        let harsh = partition_lda(&d, 20, 0.1, &mut Rng::new(8));
        let h_iid = heterogeneity(&d, &iid);
        let h_mild = heterogeneity(&d, &mild);
        let h_harsh = heterogeneity(&d, &harsh);
        assert!(h_iid < h_mild, "iid {h_iid} vs lda(1.0) {h_mild}");
        assert!(h_mild < h_harsh, "lda(1.0) {h_mild} vs lda(0.1) {h_harsh}");
    }

    #[test]
    fn partition_deterministic_for_seed() {
        let d = dataset(300, 9);
        let a = partition_lda(&d, 10, 1.0, &mut Rng::new(10));
        let b = partition_lda(&d, 10, 1.0, &mut Rng::new(10));
        assert_eq!(a, b);
    }
}
