//! Simulated wall clock.
//!
//! The simulation executes serially on one core, but the system it models
//! is parallel: within one round every peer (or group) communicates
//! concurrently. The clock therefore advances by the *maximum* over
//! parallel lanes, and by the sum across sequential phases — giving the
//! simulated round/iteration times reported in EXPERIMENTS.md.

/// Accumulating simulated clock.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    time_s: f64,
    /// cumulative time attributed to reduce-scatter phases
    rs_time_s: f64,
    /// cumulative time attributed to all-gather phases
    ag_time_s: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock::default()
    }

    pub fn now(&self) -> f64 {
        self.time_s
    }

    /// A sequential phase of duration `dt`.
    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0, "negative phase duration {dt}");
        self.time_s += dt;
    }

    /// A parallel phase: lanes run concurrently, the phase lasts as long
    /// as the slowest lane.
    pub fn parallel(&mut self, lane_times: impl IntoIterator<Item = f64>) {
        let max = lane_times.into_iter().fold(0.0f64, f64::max);
        self.time_s += max;
    }

    /// A two-phase parallel exchange — each lane is a `(first, second)`
    /// pair (reduce-scatter, then all-gather): within a lane the second
    /// phase starts only after the first completes; lanes are concurrent
    /// with no cross-lane barrier, so the exchange lasts as long as the
    /// slowest lane's phase *sum*. The advance is attributed to the
    /// per-phase accumulators ([`Self::phase_times`]) with the slowest
    /// single first phase as the reduce-scatter share — the breakdown the
    /// reduce-scatter ablation reports. When either phase is all-zero the
    /// advance degenerates to [`Self::parallel`] over the other phase,
    /// bit-exactly (full-gather books its whole duration as the gather
    /// phase this way).
    pub fn parallel_two_phase(
        &mut self,
        lanes: impl IntoIterator<Item = (f64, f64)>,
    ) {
        let mut max_total = 0.0f64;
        let mut max_first = 0.0f64;
        for (first, second) in lanes {
            max_total = max_total.max(first + second);
            max_first = max_first.max(first);
        }
        let first_share = max_first.min(max_total);
        self.rs_time_s += first_share;
        self.ag_time_s += max_total - first_share;
        self.time_s += max_total;
    }

    /// Cumulative `(reduce_scatter_s, all_gather_s)` attribution from
    /// [`Self::parallel_two_phase`] exchanges.
    pub fn phase_times(&self) -> (f64, f64) {
        (self.rs_time_s, self.ag_time_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_sum_sequentially() {
        let mut c = SimClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_takes_max() {
        let mut c = SimClock::new();
        c.parallel([0.2, 0.9, 0.4]);
        assert!((c.now() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn empty_parallel_is_free() {
        let mut c = SimClock::new();
        c.parallel([]);
        assert_eq!(c.now(), 0.0);
    }

    #[test]
    fn two_phase_advances_by_slowest_lane_sum() {
        let mut c = SimClock::new();
        // lane 1 has the slowest RS, lane 2 the slowest sum
        c.parallel_two_phase([(0.5, 0.1), (0.2, 0.7)]);
        assert!((c.now() - 0.9).abs() < 1e-12);
        let (rs, ag) = c.phase_times();
        assert!((rs - 0.5).abs() < 1e-12);
        assert!((ag - 0.4).abs() < 1e-12);
    }

    #[test]
    fn two_phase_with_zero_first_matches_parallel_bitwise() {
        let times = [0.25f64, 0.75, 0.5];
        let mut a = SimClock::new();
        a.parallel(times);
        let mut b = SimClock::new();
        b.parallel_two_phase(times.iter().map(|&t| (0.0, t)));
        assert_eq!(a.now().to_bits(), b.now().to_bits());
        assert_eq!(b.phase_times().0, 0.0);
    }

    #[test]
    fn empty_two_phase_is_free() {
        let mut c = SimClock::new();
        c.parallel_two_phase([]);
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.phase_times(), (0.0, 0.0));
    }
}
