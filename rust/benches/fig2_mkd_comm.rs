//! Figures 2 & 9 — Moshpit-KD communication efficiency.
//!
//! Paper claims: with MKD, MAR-FL reaches 50% accuracy on 20NG with >2×
//! less total communication (Fig. 2), and 95% on MNIST with up to 3× less
//! (Fig. 9), despite the higher per-iteration load.
//!
//! Default: the 20NG-like head task (Fig. 2). Set MARFL_DATASET=cnn for
//! the MNIST-like series (Fig. 9 — slower; use MARFL_BENCH_FULL=1 for the
//! paper-scale peer count).

#[path = "common/mod.rs"]
mod common;

use common::{assert_stable_columns, emit_bench_report, emit_csv, full_mode, iters, mib, runtime, timed};
use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;

fn main() {
    let dataset =
        std::env::var("MARFL_DATASET").unwrap_or_else(|_| "head".into());
    let (target, label) = match dataset.as_str() {
        "cnn" => (0.80, "MNIST-like (Fig. 9 analogue, target 80%)"),
        _ => (0.50, "20NG-like (Fig. 2, target 50%)"),
    };
    let peers = if full_mode() { 125 } else { 64 };
    let (m, g) = if peers == 125 { (5, 3) } else { (4, 3) };
    let t = iters(40, 80);
    println!("Figure 2/9 — MKD communication efficiency on {label}");
    println!("peers={peers} M={m} G={g} T={t}\n");

    let rt = runtime();
    let base = ExperimentConfig {
        model: dataset.clone(),
        peers,
        group_size: m,
        mar_rounds: g,
        iterations: t,
        samples_per_peer: 64,
        test_samples: 1000,
        eval_every: 2,
        target_accuracy: target,
        seed: 1234,
        ..Default::default()
    };

    let plain = timed("MAR-FL (no MKD)", || {
        Trainer::new(base.clone(), &rt).unwrap().run().unwrap()
    });
    let mut kd_cfg = base.clone();
    kd_cfg.kd.enabled = true;
    kd_cfg.kd.k_iterations = 6;
    let kd = timed("MAR-FL + MKD (K=6)", || {
        Trainer::new(kd_cfg, &rt).unwrap().run().unwrap()
    });

    let mut rows = vec![vec![
        "variant".into(),
        "iteration".into(),
        "data_bytes".into(),
        "accuracy".into(),
    ]];
    for (name, run) in [("marfl", &plain), ("marfl+mkd", &kd)] {
        for p in &run.curve.points {
            rows.push(vec![
                name.into(),
                p.iteration.to_string(),
                p.data_bytes.to_string(),
                format!("{:.4}", p.accuracy),
            ]);
        }
    }
    assert_stable_columns(
        "fig2_mkd_comm.csv",
        &rows,
        &[
            "variant",
            "iteration",
            "data_bytes",
            "accuracy",
        ],
    );
    emit_csv("fig2_mkd_comm.csv", &rows);
    emit_bench_report("mkd_comm", "mkd_comm", &rows);

    let plain_bytes = plain.curve.bytes_to_accuracy(target);
    let kd_bytes = kd.curve.bytes_to_accuracy(target);
    println!("\nbytes to {:.0}% accuracy:", target * 100.0);
    println!(
        "  MAR-FL        : {}",
        plain_bytes.map(|b| format!("{:.1} MiB", mib(b))).unwrap_or_else(|| "not reached".into())
    );
    println!(
        "  MAR-FL + MKD  : {}",
        kd_bytes.map(|b| format!("{:.1} MiB", mib(b))).unwrap_or_else(|| "not reached".into())
    );
    if let (Some(p), Some(k)) = (plain_bytes, kd_bytes) {
        let speedup = p as f64 / k as f64;
        println!("  MKD communication advantage: {speedup:.2}x (paper: >2x on 20NG)");
        assert!(
            speedup > 1.0,
            "MKD must reduce total communication to target accuracy"
        );
    } else {
        println!("  (target not reached in {t} iterations — rerun with MARFL_BENCH_FULL=1)");
    }
}
