//! In-repo property-testing harness (offline environment: no proptest).
//!
//! `check` runs a property over `cases` randomly generated inputs from a
//! seeded [`crate::rng::Rng`]; on failure it retries with progressively
//! "smaller" regenerated inputs (shrink-by-regeneration: the generator is
//! re-run with a shrinking size hint), then reports the failing seed so the
//! case is reproducible. Used by the coordinator/aggregation invariant
//! tests (routing, exactness, no-revisit, mixing bound).

use crate::rng::Rng;

/// Size hint passed to generators; properties shrink by lowering it.
#[derive(Clone, Copy, Debug)]
pub struct Size(pub usize);

/// Run `property(rng, size)` for `cases` random cases. The property returns
/// `Err(description)` on violation. Panics with a reproducible report on
/// the first failure that survives shrinking.
pub fn check<F>(name: &str, cases: usize, max_size: usize, mut property: F)
where
    F: FnMut(&mut Rng, Size) -> Result<(), String>,
{
    let base_seed = 0xC0FFEE ^ fxhash(name);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64 * 0x9E37);
        // ramp the size up over the run: early cases are small
        let size = 1 + (case * max_size) / cases.max(1);
        let mut rng = Rng::new(seed);
        if let Err(err) = property(&mut rng, Size(size)) {
            // shrink by regenerating at smaller sizes with the same seed
            let mut minimal = (size, err);
            let mut s = size;
            while s > 1 {
                s /= 2;
                let mut rng = Rng::new(seed);
                match property(&mut rng, Size(s)) {
                    Err(e) => minimal = (s, e),
                    Ok(()) => break,
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x}, \
                 size {}): {}",
                minimal.0, minimal.1
            );
        }
    }
}

/// FNV-style string hash for stable per-property seeds.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "element {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

/// Relative-error helper for scalar comparisons in experiment assertions.
pub fn rel_err(measured: f64, expected: f64) -> f64 {
    if expected == 0.0 {
        measured.abs()
    } else {
        ((measured - expected) / expected).abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always_ok", 50, 10, |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_report() {
        check("always_fails", 10, 10, |_, _| Err("nope".into()));
    }

    #[test]
    fn sizes_ramp_up() {
        let mut max_seen = 0;
        check("sizes", 20, 100, |_, sz| {
            max_seen = max_seen.max(sz.0);
            Ok(())
        });
        assert!(max_seen > 50, "max size seen {max_seen}");
    }

    #[test]
    fn allclose_accepts_close_vectors() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0 - 1e-7], 1e-5, 1e-6);
    }

    #[test]
    #[should_panic(expected = "element")]
    fn allclose_rejects_distant_vectors() {
        assert_allclose(&[1.0], &[2.0], 1e-5, 1e-6);
    }
}
