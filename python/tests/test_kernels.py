"""L1 correctness: every Pallas kernel vs its pure-jnp oracle (ref.py).

hypothesis sweeps shapes/dtypes/seeds; numpy.testing.assert_allclose is the
acceptance criterion. These tests are the core correctness signal for the
kernels that end up inside every AOT artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.group_mean import group_mean
from compile.kernels.momentum import STRIP, fused_momentum
from compile.kernels.softmax_xent import softmax_xent, _fused_fwd

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _rng(seed):
    return np.random.default_rng(seed)


# --------------------------------------------------------------------------
# softmax-XENT
# --------------------------------------------------------------------------

@given(
    batch=st.sampled_from([8, 16, 24, 64]),
    classes=st.sampled_from([2, 10, 20, 37]),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_matches_ref(batch, classes, seed):
    r = _rng(seed)
    logits = jnp.asarray(r.normal(0, 3, (batch, classes)), jnp.float32)
    labels = r.integers(0, classes, batch)
    onehot = jax.nn.one_hot(labels, classes, dtype=jnp.float32)
    loss, dz = _fused_fwd(logits, onehot)
    loss_ref, dz_ref = ref.softmax_xent_ref(logits, onehot)
    np.testing.assert_allclose(loss, loss_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dz, dz_ref, rtol=1e-5, atol=1e-6)


@given(
    batch=st.sampled_from([8, 16]),
    classes=st.sampled_from([5, 10]),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_gradient_matches_autodiff_of_ref(batch, classes, seed):
    """jax.grad through the custom VJP must equal autodiff of the oracle."""
    r = _rng(seed)
    logits = jnp.asarray(r.normal(0, 2, (batch, classes)), jnp.float32)
    labels = r.integers(0, classes, batch)
    onehot = jax.nn.one_hot(labels, classes, dtype=jnp.float32)

    g_kernel = jax.grad(lambda z: jnp.mean(softmax_xent(z, onehot)))(logits)
    g_ref = jax.grad(lambda z: jnp.mean(ref.softmax_xent_ref(z, onehot)[0]))(logits)
    np.testing.assert_allclose(g_kernel, g_ref, rtol=1e-5, atol=1e-6)


def test_softmax_xent_extreme_logits_stable():
    """Large-magnitude logits must not overflow (max-subtraction in-kernel)."""
    logits = jnp.asarray([[1000.0, 0.0], [-1000.0, 0.0]], jnp.float32)
    onehot = jnp.asarray([[1.0, 0.0], [0.0, 1.0]], jnp.float32)
    loss, dz = _fused_fwd(logits, onehot)
    assert np.isfinite(np.asarray(loss)).all()
    assert np.isfinite(np.asarray(dz)).all()
    np.testing.assert_allclose(loss[0], 0.0, atol=1e-5)


def test_softmax_xent_uniform_logits():
    """Zero logits -> loss = log C exactly."""
    batch, classes = 8, 10
    onehot = jax.nn.one_hot(jnp.arange(batch) % classes, classes)
    loss, _ = _fused_fwd(jnp.zeros((batch, classes), jnp.float32), onehot)
    np.testing.assert_allclose(loss, np.log(classes), rtol=1e-6)


# --------------------------------------------------------------------------
# fused momentum
# --------------------------------------------------------------------------

@given(
    strips=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
    eta=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
)
def test_momentum_matches_ref(strips, seed, eta, mu):
    p = strips * STRIP
    r = _rng(seed)
    theta = jnp.asarray(r.normal(0, 1, p), jnp.float32)
    m = jnp.asarray(r.normal(0, 0.1, p), jnp.float32)
    g = jnp.asarray(r.normal(0, 1, p), jnp.float32)
    t2, m2 = fused_momentum(theta, m, g,
                            jnp.asarray([eta], jnp.float32),
                            jnp.asarray([mu], jnp.float32))
    t_ref, m_ref = ref.momentum_ref(theta, m, g, eta, mu)
    np.testing.assert_allclose(t2, t_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m2, m_ref, rtol=1e-5, atol=1e-6)


def test_momentum_zero_gradient_decays_momentum():
    p = STRIP
    theta = jnp.ones((p,), jnp.float32)
    m = jnp.ones((p,), jnp.float32)
    g = jnp.zeros((p,), jnp.float32)
    t2, m2 = fused_momentum(theta, m, g,
                            jnp.asarray([0.1], jnp.float32),
                            jnp.asarray([0.9], jnp.float32))
    np.testing.assert_allclose(m2, 0.9, rtol=1e-6)
    np.testing.assert_allclose(t2, 1.0 - 0.1 * 0.9, rtol=1e-6)


def test_momentum_mu_zero_is_damped_sgd():
    """mu = 0 reduces to plain SGD (damping factor (1-mu) = 1)."""
    p = STRIP
    r = _rng(7)
    theta = jnp.asarray(r.normal(0, 1, p), jnp.float32)
    g = jnp.asarray(r.normal(0, 1, p), jnp.float32)
    t2, m2 = fused_momentum(theta, jnp.zeros_like(theta), g,
                            jnp.asarray([0.5], jnp.float32),
                            jnp.asarray([0.0], jnp.float32))
    np.testing.assert_allclose(t2, theta - 0.5 * g, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m2, g, rtol=1e-6)


def test_momentum_rejects_unaligned_length():
    bad = jnp.zeros((STRIP + 1,), jnp.float32)
    with pytest.raises(AssertionError):
        fused_momentum(bad, bad, bad,
                       jnp.asarray([0.1], jnp.float32),
                       jnp.asarray([0.9], jnp.float32))


# --------------------------------------------------------------------------
# group mean
# --------------------------------------------------------------------------

@given(
    k=st.integers(2, 8),
    strips=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_group_mean_matches_ref(k, strips, seed):
    r = _rng(seed)
    stack = jnp.asarray(r.normal(0, 1, (k, strips * STRIP)), jnp.float32)
    got = group_mean(stack)
    np.testing.assert_allclose(got, ref.group_mean_ref(stack),
                               rtol=1e-6, atol=1e-7)


def test_group_mean_identical_rows_is_identity():
    row = jnp.arange(STRIP, dtype=jnp.float32)
    stack = jnp.stack([row] * 5)
    np.testing.assert_allclose(group_mean(stack), row, rtol=1e-7)


def test_group_mean_permutation_invariant():
    r = _rng(3)
    stack = jnp.asarray(r.normal(0, 1, (4, STRIP)), jnp.float32)
    perm = stack[jnp.asarray([2, 0, 3, 1])]
    # summation order differs -> f32 rounding differs; allow ulp-scale slack
    np.testing.assert_allclose(group_mean(stack), group_mean(perm),
                               rtol=1e-5, atol=1e-6)
