//! Byzantine adversary subsystem: deterministic attacker selection,
//! update corruption, and reputation-gated peer exclusion.
//!
//! The fault fabric (net::faults) models peers that *fail*; this module
//! models peers that *participate and lie*. It follows the repo's
//! determinism contract end-to-end:
//!
//! * every random draw (attacker selection, noise vectors) happens in
//!   the serial schedule phase from a dedicated RNG fork, gated on
//!   `attack.frac > 0` — an attack-off run makes ZERO extra draws and is
//!   bit-identical to a build without this module;
//! * corruption rewrites states through [`Theta::make_mut_slice`], so
//!   copy-on-write aliasing (group-mean broadcasts, KD snapshots) stays
//!   correct — an attacker sharing a post-average handle detaches
//!   instead of poisoning its groupmates retroactively;
//! * attacked runs stay bit-identical serial-vs-parallel because the
//!   corruption pass completes before any aggregation lane fans out.
//!
//! Defenses live next door: robust group estimators in
//! [`crate::aggregation::robust`], and the [`Reputation`] ledger here,
//! which folds per-round outlier scores into an EWMA and lets the MAR
//! matchmaker exclude peers whose reputation falls below
//! `attack.rep_threshold`.

use crate::aggregation::robust::{GroupScores, RobustEstimator, RobustPolicy};
use crate::aggregation::PeerState;
use crate::rng::Rng;

/// How an attacker corrupts its update before the group exchange.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AttackMode {
    /// Send `−scale · θ` (and flipped momentum): the classic
    /// sign-flipping attack that drags a plain mean toward zero or
    /// beyond.
    #[default]
    SignFlip,
    /// Add `scale · N(0, 1)` noise per coordinate of θ — an unreliable /
    /// corrupted-node model rather than a directed attack.
    GaussNoise,
    /// Multiply the state by `scale` — model-replacement-style
    /// amplification (a boosted update that dominates a plain mean).
    Scale,
    /// Adaptive sign-flip blend: attacker `p` sends `(1 − 2·s_p)·θ`
    /// where `s_p` starts at `scale` (a full flip at `scale = 1`) and is
    /// re-dialed every iteration from the attacker's own outlier ratio
    /// in the previous round's `GroupScores` — shrinking when the
    /// detector flagged it, probing back up when it passed, aiming to
    /// sit just under the reputation threshold. The controller is
    /// purely deterministic (zero RNG draws) and advances only in the
    /// serial schedule phase ([`AttackPlan::adapt`]).
    AdaptiveScale,
    /// "A little is enough"-style collusion: every attacker sends the
    /// SAME small perturbation of the honest population — the
    /// coordinate-wise participant mean shifted by `scale` standard
    /// deviations — hiding inside the natural cross-peer spread.
    /// Inherently collusive (one shared allocation); zero RNG draws.
    Alie,
}

impl AttackMode {
    /// Parse a config-file name (`attack.mode`).
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        Ok(match name {
            "sign_flip" => AttackMode::SignFlip,
            "gauss_noise" => AttackMode::GaussNoise,
            "scale" => AttackMode::Scale,
            "adaptive_scale" => AttackMode::AdaptiveScale,
            "alie" => AttackMode::Alie,
            other => anyhow::bail!(
                "unknown attack mode '{other}' \
                 (sign_flip|gauss_noise|scale|adaptive_scale|alie)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            AttackMode::SignFlip => "sign_flip",
            AttackMode::GaussNoise => "gauss_noise",
            AttackMode::Scale => "scale",
            AttackMode::AdaptiveScale => "adaptive_scale",
            AttackMode::Alie => "alie",
        }
    }
}

/// The validated `attack.*` config block: adversary knobs plus the
/// defense selection (robust estimator + reputation threshold).
#[derive(Clone, Debug, PartialEq)]
pub struct AttackConfig {
    /// Fraction of peers that are Byzantine (ground truth, drawn once
    /// per run). `0.0` disables the whole subsystem.
    pub frac: f64,
    /// Corruption applied to attacker updates each iteration.
    pub mode: AttackMode,
    /// Mode-specific magnitude: flip/amplification factor, or noise σ.
    pub scale: f64,
    /// Colluding attackers all send ONE identical corrupted state (the
    /// lowest-indexed attacker's), sharing a single `Theta` allocation —
    /// harder for coordinate-wise trimming, cheaper for us to simulate.
    pub collude: bool,
    /// Group center estimator (`mean` = bit-exact legacy averaging).
    pub robust: RobustEstimator,
    /// Per-side trim fraction for `trimmed_mean`.
    pub trim: f64,
    /// Reputation ban threshold in `(0, 1)`; `0.0` disables
    /// reputation-gated matchmaking.
    pub rep_threshold: f64,
    /// Per-iteration EWMA drift back toward the neutral reputation
    /// (1.0), in `[0, 1)`. `0.0` (default) keeps scores sticky — the
    /// exact pre-parole behaviour. Dead weight unless `rep_threshold`
    /// is set.
    pub rep_decay: f64,
    /// Ban length in iterations before a banned peer re-enters
    /// matchmaking *on parole* (a tighter re-ban threshold for a
    /// bounded window). `0` (default) disables parole and keeps the
    /// fixed legacy ban length bit-exactly.
    pub parole_rounds: u64,
}

impl Default for AttackConfig {
    fn default() -> Self {
        AttackConfig {
            frac: 0.0,
            mode: AttackMode::SignFlip,
            scale: 1.0,
            collude: false,
            robust: RobustEstimator::Mean,
            trim: 0.25,
            rep_threshold: 0.0,
            rep_decay: 0.0,
            parole_rounds: 0,
        }
    }
}

impl AttackConfig {
    /// Attack injection active? (Defenses may run without attackers —
    /// e.g. a robust estimator hardening an honest run.)
    pub fn enabled(&self) -> bool {
        self.frac > 0.0
    }

    /// Reputation-gated matchmaking active?
    pub fn rep_enabled(&self) -> bool {
        self.rep_threshold > 0.0
    }

    /// Anything here that departs from the bit-exact legacy path?
    pub fn any_active(&self) -> bool {
        self.enabled() || self.rep_enabled() || !self.policy().is_mean()
    }

    /// The estimator policy threaded through aggregation.
    pub fn policy(&self) -> RobustPolicy {
        RobustPolicy { est: self.robust, trim: self.trim }
    }

    /// Range checks (called from `config::ExperimentConfig::validate`).
    pub fn validate(&self) -> anyhow::Result<()> {
        if !(0.0..0.5).contains(&self.frac) {
            anyhow::bail!("attack.frac must be in [0, 0.5), got {}", self.frac);
        }
        if !self.scale.is_finite() || self.scale <= 0.0 {
            anyhow::bail!("attack.scale must be finite and > 0, got {}", self.scale);
        }
        if !(0.0..0.5).contains(&self.trim) {
            anyhow::bail!("attack.trim must be in [0, 0.5), got {}", self.trim);
        }
        if !(0.0..1.0).contains(&self.rep_threshold) {
            anyhow::bail!(
                "attack.rep_threshold must be in [0, 1), got {}",
                self.rep_threshold
            );
        }
        if !(0.0..1.0).contains(&self.rep_decay) {
            anyhow::bail!(
                "attack.rep_decay must be in [0, 1), got {}",
                self.rep_decay
            );
        }
        Ok(())
    }
}

/// Adaptive-scale controller constants: the attacker steers its worst
/// observed outlier ratio (`distance / flag threshold`) toward
/// `ADAPT_TARGET` — just under the detector's trip point — moving its
/// scale multiplicatively by at most `ADAPT_STEP_MAX` up or down to
/// `ADAPT_STEP_MIN` per iteration, never above the configured `scale`
/// and never below `ADAPT_FLOOR · scale` (the probe stays alive).
const ADAPT_TARGET: f64 = 0.9;
const ADAPT_STEP_MIN: f64 = 0.25;
const ADAPT_STEP_MAX: f64 = 1.25;
const ADAPT_FLOOR: f64 = 1e-3;

/// The per-run ground truth: which peers are Byzantine, and what they
/// have done so far. Drawn ONCE at trainer setup from a dedicated RNG
/// fork (tag 4), gated on `attack.frac > 0`.
#[derive(Clone, Debug, PartialEq)]
pub struct AttackPlan {
    attacker: Vec<bool>,
    mode: AttackMode,
    scale: f64,
    collude: bool,
    /// Attackers that corrupted an update at least once this run.
    active: Vec<bool>,
    /// Per-peer adapted scale (`adaptive_scale` only; attacker slots
    /// start at `scale` and are re-dialed by [`AttackPlan::adapt`]).
    adapt: Vec<f64>,
}

impl AttackPlan {
    /// Select `round(frac · n)` attackers (clamped below half) from a
    /// forked RNG. Deterministic per (seed, n, frac).
    pub fn new(cfg: &AttackConfig, n: usize, rng: &mut Rng) -> Self {
        let want = (cfg.frac * n as f64).round() as usize;
        let count = want.min(n.saturating_sub(1) / 2);
        let mut attacker = vec![false; n];
        for i in rng.sample_indices(n, count) {
            attacker[i] = true;
        }
        AttackPlan {
            attacker,
            mode: cfg.mode,
            scale: cfg.scale,
            collude: cfg.collude,
            active: vec![false; n],
            adapt: vec![cfg.scale; n],
        }
    }

    /// Adaptive attack (needs last-round detector feedback)?
    pub fn adaptive(&self) -> bool {
        self.mode == AttackMode::AdaptiveScale
    }

    /// The current adapted scale of `peer` (attacker slots only move).
    pub fn adapted_scale(&self, peer: usize) -> f64 {
        self.adapt[peer]
    }

    /// Serial-phase controller step for `adaptive_scale`: each attacker
    /// reads its own worst outlier ratio from the PREVIOUS iteration
    /// (`Reputation::last_ratios`; `0.0` = unobserved, e.g. banned or
    /// in a sub-3 group — the scale holds) and multiplies its scale
    /// toward the [`ADAPT_TARGET`] trip-point ratio. Deterministic, no
    /// RNG draws; other modes ignore the call entirely.
    pub fn adapt(&mut self, last_ratio: &[f64]) {
        if self.mode != AttackMode::AdaptiveScale {
            return;
        }
        debug_assert_eq!(last_ratio.len(), self.attacker.len());
        for (p, s) in self.adapt.iter_mut().enumerate() {
            if !self.attacker[p] {
                continue;
            }
            let r = last_ratio[p];
            if r > 0.0 {
                let step = (ADAPT_TARGET / r).clamp(ADAPT_STEP_MIN, ADAPT_STEP_MAX);
                *s = (*s * step).clamp(ADAPT_FLOOR * self.scale, self.scale);
            }
        }
    }

    pub fn is_attacker(&self, peer: usize) -> bool {
        self.attacker[peer]
    }

    /// Ground-truth attacker count.
    pub fn count(&self) -> usize {
        self.attacker.iter().filter(|&&a| a).count()
    }

    /// Attackers that actually corrupted an update this run.
    pub fn active_count(&self) -> u64 {
        self.active.iter().filter(|&&a| a).count() as u64
    }

    pub fn attacker_flags(&self) -> &[bool] {
        &self.attacker
    }

    /// Corrupt every attacking participant's state in place, in
    /// participant order (serial schedule phase — `rng` draws happen
    /// here and nowhere else). Sign-flip, scale and the adaptive blend
    /// rewrite θ and momentum (no draws); Gaussian noise perturbs θ
    /// only, one draw per coordinate (one shared vector when
    /// colluding); `alie` computes the participant mean/σ once and is
    /// always collusive (no draws). Colluders all end up holding ONE
    /// shared corrupted allocation.
    pub fn corrupt(
        &mut self,
        states: &mut [PeerState],
        participants: &[usize],
        rng: &mut Rng,
    ) {
        let attackers: Vec<usize> = participants
            .iter()
            .copied()
            .filter(|&p| self.attacker[p])
            .collect();
        if attackers.is_empty() {
            return;
        }
        if self.mode == AttackMode::Alie {
            self.corrupt_alie(states, participants, &attackers);
            return;
        }
        if self.collude {
            let lead = attackers[0];
            self.corrupt_one(states, lead, rng);
            let theta = states[lead].theta.clone();
            let mom = states[lead].momentum.clone();
            for &p in &attackers[1..] {
                states[p].theta = theta.clone();
                states[p].momentum = mom.clone();
                self.active[p] = true;
            }
        } else {
            for &p in &attackers {
                self.corrupt_one(states, p, rng);
            }
        }
    }

    fn corrupt_one(&mut self, states: &mut [PeerState], p: usize, rng: &mut Rng) {
        self.active[p] = true;
        let st = &mut states[p];
        match self.mode {
            AttackMode::SignFlip => {
                let f = -self.scale as f32;
                for v in st.theta.make_mut_slice() {
                    *v *= f;
                }
                for v in st.momentum.make_mut_slice() {
                    *v *= f;
                }
            }
            AttackMode::Scale => {
                let f = self.scale as f32;
                for v in st.theta.make_mut_slice() {
                    *v *= f;
                }
                for v in st.momentum.make_mut_slice() {
                    *v *= f;
                }
            }
            AttackMode::GaussNoise => {
                let s = self.scale;
                for v in st.theta.make_mut_slice() {
                    *v += (s * rng.normal()) as f32;
                }
            }
            AttackMode::AdaptiveScale => {
                // (1 − 2s)·θ: s = 1 is the full sign flip, s → 0 an
                // arbitrarily small (undetectable) pull toward zero —
                // the blend the controller dials along
                let f = (1.0 - 2.0 * self.adapt[p]) as f32;
                for v in st.theta.make_mut_slice() {
                    *v *= f;
                }
                for v in st.momentum.make_mut_slice() {
                    *v *= f;
                }
            }
            AttackMode::Alie => unreachable!("alie handled in corrupt()"),
        }
    }

    /// "A little is enough": every attacker sends the coordinate-wise
    /// participant mean shifted DOWN by `scale` cross-peer standard
    /// deviations (θ and momentum alike) — a colluding bloc hiding
    /// inside the honest spread. Statistics accumulate in f64 over the
    /// pre-corruption states in participant order; all attackers share
    /// ONE corrupted allocation. Zero RNG draws.
    fn corrupt_alie(
        &mut self,
        states: &mut [PeerState],
        participants: &[usize],
        attackers: &[usize],
    ) {
        let theta = crate::params::Theta::new(alie_center(
            participants,
            |i| states[i].theta.as_slice(),
            self.scale,
        ));
        let mom = crate::params::Theta::new(alie_center(
            participants,
            |i| states[i].momentum.as_slice(),
            self.scale,
        ));
        for &p in attackers {
            states[p].theta = theta.clone();
            states[p].momentum = mom.clone();
            self.active[p] = true;
        }
    }
}

/// Coordinate-wise `mean − z·σ` over the participants' vectors (f64,
/// participant order) — the ALIE corruption direction.
fn alie_center<'a, F: Fn(usize) -> &'a [f32]>(
    participants: &[usize],
    row: F,
    z: f64,
) -> Vec<f32> {
    let len = row(participants[0]).len();
    let n = participants.len() as f64;
    let mut mean = vec![0.0f64; len];
    for &i in participants {
        for (a, &v) in mean.iter_mut().zip(row(i)) {
            *a += v as f64;
        }
    }
    for a in &mut mean {
        *a /= n;
    }
    let mut var = vec![0.0f64; len];
    for &i in participants {
        for ((s, &m), &v) in var.iter_mut().zip(&mean).zip(row(i)) {
            let d = v as f64 - m;
            *s += d * d;
        }
    }
    mean.iter()
        .zip(&var)
        .map(|(&m, &s2)| (m - z * (s2 / n).sqrt()) as f32)
        .collect()
}

/// Ban length once a peer's reputation crosses the threshold (the
/// legacy fixed term, used whenever parole is off).
const BAN_ITERS: u64 = 4;
/// Length of the parole window that follows a `parole_rounds`-long ban:
/// the re-entered peer is re-banned at the tighter parole threshold for
/// this many iterations, then fully reinstated.
const PAROLE_WINDOW: u64 = 4;
/// EWMA smoothing factor for per-iteration health observations.
const REP_ALPHA: f64 = 0.5;
/// A member is an outlier when its distance to the group center exceeds
/// BOTH `OUTLIER_REL · median(dists)` and `OUTLIER_ABS · ‖center‖` — the
/// relative test finds the odd one out, the absolute floor keeps a
/// converged group's tiny jitter from flagging honest peers.
const OUTLIER_REL: f64 = 3.0;
const OUTLIER_ABS: f64 = 0.05;
/// Never ban more than this fraction of the population — the
/// matchmaker must always retain a working majority.
const MAX_BANNED_FRAC: f64 = 0.45;

/// EWMA reputation ledger with bounded bans, rejoin probation, and
/// (optionally) score decay + parole.
///
/// Scores arrive per aggregation round via [`Reputation::observe_group`]
/// (serial fold, group/member order); [`Reputation::fold_iteration`]
/// applies each peer's WORST observation of the iteration to its EWMA
/// once, then bans peers below the threshold for [`BAN_ITERS`]
/// iterations (probation: an expired ban resets the reputation exactly
/// to the threshold, so one more bad iteration re-bans). The worst-of
/// staging matters: after round 1 of a MAR iteration an attacker holds
/// the shared group mean and looks perfectly healthy in rounds 2+, so
/// averaging observations would wash the round-1 evidence out.
///
/// [`Reputation::with_parole`] arms the forgiveness layer: scores decay
/// toward neutral at `decay` per iteration (a false positive is no
/// longer sticky for the whole run), bans last `parole_rounds` instead
/// of [`BAN_ITERS`], and an expiring ban enters a [`PAROLE_WINDOW`]-long
/// parole in which the peer rejoins matchmaking under the tighter
/// [`Reputation::parole_threshold`] — one bad iteration there re-bans
/// it (`reban_count`). `decay = 0` and `parole_rounds = 0` keep every
/// legacy code path bit-exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct Reputation {
    rep: Vec<f64>,
    /// Worst observation this iteration: `None` = unobserved.
    staged: Vec<Option<bool>>,
    /// Ban expiry (iteration index); 0 = not banned.
    banned_until: Vec<u64>,
    ever_flagged: Vec<bool>,
    /// Bans that actually gated ≥ 1 matchmaking pass (a ban issued in
    /// the last iteration never gates — the scorecard only counts the
    /// ones that did).
    effective: Vec<bool>,
    /// Parole expiry (iteration index); 0 = not on parole.
    parole_until: Vec<u64>,
    /// Worst outlier ratio (`distance / flag threshold`) staged this
    /// iteration; `0.0` = unobserved.
    ratio_staged: Vec<f64>,
    /// The staged ratios of the last FOLDED iteration — the detector
    /// signal an adaptive attacker steers by ([`AttackPlan::adapt`]).
    last_ratio: Vec<f64>,
    threshold: f64,
    max_banned: usize,
    iter: u64,
    /// Per-iteration drift toward neutral; 0 = sticky legacy scores.
    decay: f64,
    /// Ban length under parole; 0 = parole off ([`BAN_ITERS`] bans).
    parole_rounds: u64,
    paroles_granted: u64,
    reban_count: u64,
    /// Stage ban/parole/reban transitions for the round-event trace.
    /// Off by default — with nobody draining, the log would only grow.
    log_events: bool,
    /// Transitions staged by the last folds, in the fold's own
    /// deterministic ascending-peer order ([`Self::drain_events`]).
    events: Vec<RepEvent>,
}

/// One reputation transition, staged during [`Reputation::fold_iteration`]
/// (ascending peer order) and drained into the round-event trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RepEvent {
    /// A fresh ban crossed the threshold.
    Ban(usize),
    /// An expiring ban re-entered matchmaking on parole.
    Parole(usize),
    /// A peer on parole tripped the tighter threshold again.
    Reban(usize),
}

impl Reputation {
    pub fn new(n: usize, threshold: f64) -> Self {
        Reputation {
            rep: vec![1.0; n],
            staged: vec![None; n],
            banned_until: vec![0; n],
            ever_flagged: vec![false; n],
            effective: vec![false; n],
            parole_until: vec![0; n],
            ratio_staged: vec![0.0; n],
            last_ratio: vec![0.0; n],
            threshold,
            max_banned: (MAX_BANNED_FRAC * n as f64).floor() as usize,
            iter: 0,
            decay: 0.0,
            parole_rounds: 0,
            paroles_granted: 0,
            reban_count: 0,
            log_events: false,
            events: Vec::new(),
        }
    }

    /// Arm transition logging for the round-event trace. The ledger's
    /// scoring behaviour is untouched — only [`Self::drain_events`]
    /// starts returning the staged transitions.
    pub fn log_events(&mut self, on: bool) {
        self.log_events = on;
    }

    /// Drain the transitions staged since the last drain (empty unless
    /// [`Self::log_events`] armed logging).
    pub fn drain_events(&mut self) -> Vec<RepEvent> {
        std::mem::take(&mut self.events)
    }

    /// Arm reputation decay and/or parole (both default off — the
    /// bit-exact legacy ledger).
    pub fn with_parole(mut self, decay: f64, parole_rounds: u64) -> Self {
        self.decay = decay;
        self.parole_rounds = parole_rounds;
        self
    }

    /// The tighter ban threshold applied while a peer is on parole:
    /// halfway between the base threshold and neutral.
    pub fn parole_threshold(&self) -> f64 {
        self.threshold + 0.5 * (1.0 - self.threshold)
    }

    /// Fold one group's outlier evidence (member order).
    pub fn observe_group(&mut self, members: &[usize], scores: &GroupScores) {
        debug_assert_eq!(members.len(), scores.dists.len());
        if members.len() < 3 {
            return; // no meaningful "odd one out" below 3 members
        }
        let mut sorted = scores.dists.clone();
        sorted.sort_unstable_by(|a, b| a.total_cmp(b));
        let k = sorted.len();
        let med = if k % 2 == 1 {
            sorted[k / 2]
        } else {
            0.5 * (sorted[k / 2 - 1] + sorted[k / 2])
        };
        let floor = OUTLIER_ABS * scores.center_norm.max(1e-12);
        // the flag trip point: outlier ⟺ d > max(rel·med, floor); the
        // ratio against it is the signal adaptive attackers observe
        let trip = (OUTLIER_REL * med).max(floor).max(1e-12);
        for (&peer, &d) in members.iter().zip(&scores.dists) {
            let outlier = d > OUTLIER_REL * med && d > floor;
            let healthy = !outlier;
            self.staged[peer] = Some(match self.staged[peer] {
                Some(prev) => prev && healthy,
                None => healthy,
            });
            self.ratio_staged[peer] = self.ratio_staged[peer].max(d / trip);
        }
    }

    /// Apply the staged observations, expire old bans (probation /
    /// parole), issue new ones (bounded, ascending peer order). Returns
    /// the number of newly banned peers. Call exactly once per
    /// aggregation call, after all rounds folded.
    pub fn fold_iteration(&mut self) -> u64 {
        self.iter += 1;
        // publish this iteration's detector signal for the (next)
        // serial schedule phase, then clear the staging
        for (last, staged) in
            self.last_ratio.iter_mut().zip(self.ratio_staged.iter_mut())
        {
            *last = std::mem::take(staged);
        }
        for (rep, staged) in self.rep.iter_mut().zip(self.staged.iter_mut()) {
            if let Some(healthy) = staged.take() {
                let obs = if healthy { 1.0 } else { 0.0 };
                *rep = (1.0 - REP_ALPHA) * *rep + REP_ALPHA * obs;
            }
        }
        if self.decay > 0.0 {
            // forgiveness drift: every score relaxes toward neutral, so
            // one false positive stops shadowing a peer forever
            for rep in self.rep.iter_mut() {
                *rep += self.decay * (1.0 - *rep);
            }
        }
        let parole_threshold = self.parole_threshold();
        let ban_len =
            if self.parole_rounds > 0 { self.parole_rounds } else { BAN_ITERS };
        let mut newly = 0u64;
        for p in 0..self.rep.len() {
            if self.banned_until[p] > 0 {
                if self.iter >= self.banned_until[p] {
                    self.banned_until[p] = 0;
                    if self.parole_rounds > 0 {
                        // parole: rejoin matchmaking, but for a window
                        // the tighter threshold applies — and the score
                        // re-enters exactly AT it, so one bad iteration
                        // re-bans
                        self.parole_until[p] = self.iter + PAROLE_WINDOW;
                        self.rep[p] = parole_threshold;
                        self.paroles_granted += 1;
                        if self.log_events {
                            self.events.push(RepEvent::Parole(p));
                        }
                    } else {
                        self.rep[p] = self.threshold; // probation
                    }
                }
                continue;
            }
            let thresh = if self.parole_until[p] > self.iter {
                parole_threshold
            } else {
                self.threshold
            };
            if self.rep[p] < thresh && self.banned() < self.max_banned {
                self.banned_until[p] = self.iter + ban_len;
                self.ever_flagged[p] = true;
                let rebanned = self.parole_until[p] > self.iter;
                if rebanned {
                    self.parole_until[p] = 0;
                    self.reban_count += 1;
                }
                if self.log_events {
                    self.events.push(if rebanned {
                        RepEvent::Reban(p)
                    } else {
                        RepEvent::Ban(p)
                    });
                }
                newly += 1;
            }
        }
        newly
    }

    pub fn is_banned(&self, peer: usize) -> bool {
        self.banned_until[peer] > 0
    }

    /// Peer currently inside its parole window?
    pub fn on_parole(&self, peer: usize) -> bool {
        self.parole_until[peer] > self.iter
    }

    /// Currently banned peers.
    pub fn banned(&self) -> usize {
        self.banned_until.iter().filter(|&&b| b > 0).count()
    }

    /// Peers flagged (banned) at least once this run.
    pub fn ever_flagged(&self) -> &[bool] {
        &self.ever_flagged
    }

    /// Record that `peer`'s ban actually excluded it from a matchmaking
    /// pass (called by the matchmaker when it drops a banned peer).
    pub fn note_gated(&mut self, peer: usize) {
        self.effective[peer] = true;
    }

    /// Bans that gated ≥ 1 matchmaking pass — the effective flag set
    /// the precision/recall scorecard is computed over (a ban issued on
    /// the final iteration never gates anything and must not count).
    pub fn effective_flags(&self) -> &[bool] {
        &self.effective
    }

    /// Worst per-peer outlier ratios of the last folded iteration
    /// (`0.0` = unobserved) — the adaptive attacker's feedback channel.
    pub fn last_ratios(&self) -> &[f64] {
        &self.last_ratio
    }

    /// Paroles granted this run (ban → parole re-entries).
    pub fn paroles_granted(&self) -> u64 {
        self.paroles_granted
    }

    /// Peers re-banned while on parole.
    pub fn reban_count(&self) -> u64 {
        self.reban_count
    }

    pub fn score(&self, peer: usize) -> f64 {
        self.rep[peer]
    }
}

/// Flagging quality against the ground-truth attacker set:
/// `(flagged, precision, recall)`. Precision/recall are 1.0 when their
/// denominator is empty (nothing flagged / no attackers).
pub fn flag_quality(flagged: &[bool], attacker: &[bool]) -> (u64, f64, f64) {
    debug_assert_eq!(flagged.len(), attacker.len());
    let n_flag = flagged.iter().filter(|&&f| f).count();
    let n_atk = attacker.iter().filter(|&&a| a).count();
    let hit = flagged
        .iter()
        .zip(attacker)
        .filter(|&(&f, &a)| f && a)
        .count();
    let precision = if n_flag == 0 { 1.0 } else { hit as f64 / n_flag as f64 };
    let recall = if n_atk == 0 { 1.0 } else { hit as f64 / n_atk as f64 };
    (n_flag as u64, precision, recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_mode() {
        for mode in [
            AttackMode::SignFlip,
            AttackMode::GaussNoise,
            AttackMode::Scale,
            AttackMode::AdaptiveScale,
            AttackMode::Alie,
        ] {
            assert_eq!(AttackMode::parse(mode.name()).unwrap(), mode);
        }
        assert!(AttackMode::parse("backdoor").is_err());
    }

    #[test]
    fn config_validation_rejects_bad_ranges() {
        let ok = AttackConfig::default();
        ok.validate().unwrap();
        assert!(AttackConfig { frac: 0.5, ..ok.clone() }.validate().is_err());
        assert!(AttackConfig { frac: -0.1, ..ok.clone() }.validate().is_err());
        assert!(AttackConfig { scale: 0.0, ..ok.clone() }.validate().is_err());
        assert!(AttackConfig { trim: 0.5, ..ok.clone() }.validate().is_err());
        assert!(
            AttackConfig { rep_threshold: 1.0, ..ok.clone() }.validate().is_err()
        );
        assert!(AttackConfig { rep_decay: 1.0, ..ok.clone() }.validate().is_err());
        assert!(
            AttackConfig { rep_decay: -0.1, ..ok.clone() }.validate().is_err()
        );
        AttackConfig {
            frac: 0.3,
            rep_threshold: 0.6,
            rep_decay: 0.1,
            parole_rounds: 3,
            ..ok
        }
        .validate()
        .unwrap();
    }

    #[test]
    fn plan_selection_is_deterministic_and_clamped() {
        let cfg = AttackConfig { frac: 0.3, ..Default::default() };
        let a = AttackPlan::new(&cfg, 20, &mut Rng::new(9));
        let b = AttackPlan::new(&cfg, 20, &mut Rng::new(9));
        assert_eq!(a, b);
        assert_eq!(a.count(), 6); // round(0.3 · 20)
        assert_eq!(a.active_count(), 0);
        // clamp: never half or more, even with an aggressive frac
        let cfg = AttackConfig { frac: 0.49, ..Default::default() };
        let plan = AttackPlan::new(&cfg, 4, &mut Rng::new(9));
        assert!(plan.count() <= 1);
    }

    fn states(n: usize, p: usize) -> Vec<PeerState> {
        (0..n)
            .map(|i| PeerState {
                theta: vec![i as f32 + 1.0; p].into(),
                momentum: vec![0.5; p].into(),
            })
            .collect()
    }

    #[test]
    fn sign_flip_rewrites_theta_and_momentum() {
        let cfg = AttackConfig { frac: 0.4, scale: 2.0, ..Default::default() };
        let mut rng = Rng::new(3);
        let mut plan = AttackPlan::new(&cfg, 5, &mut rng);
        let mut st = states(5, 4);
        let before: Vec<_> = st.iter().map(|s| s.theta.to_vec()).collect();
        plan.corrupt(&mut st, &[0, 1, 2, 3, 4], &mut rng);
        for p in 0..5 {
            if plan.is_attacker(p) {
                assert_eq!(st[p].theta[0], -2.0 * before[p][0]);
                assert_eq!(st[p].momentum[0], -1.0);
            } else {
                assert_eq!(st[p].theta.to_vec(), before[p]);
            }
        }
        assert_eq!(plan.active_count(), plan.count() as u64);
    }

    #[test]
    fn corrupt_detaches_shared_storage() {
        // an attacker aliasing a group mean must CoW-detach, never
        // poison the peers sharing the allocation
        let cfg = AttackConfig { frac: 0.4, ..Default::default() };
        let mut rng = Rng::new(5);
        let mut plan = AttackPlan::new(&cfg, 5, &mut rng);
        let atk = (0..5).find(|&p| plan.is_attacker(p)).unwrap();
        let honest = (0..5).find(|&p| !plan.is_attacker(p)).unwrap();
        let mut st = states(5, 4);
        let shared = st[honest].theta.clone();
        st[atk].theta = shared.clone();
        assert!(st[atk].theta.shares_storage(&st[honest].theta));
        plan.corrupt(&mut st, &[atk], &mut rng);
        assert!(!st[atk].theta.shares_storage(&st[honest].theta));
        assert_eq!(st[honest].theta, shared);
    }

    #[test]
    fn colluders_share_one_corrupted_allocation() {
        let cfg = AttackConfig {
            frac: 0.45,
            collude: true,
            mode: AttackMode::GaussNoise,
            ..Default::default()
        };
        let mut rng = Rng::new(7);
        let mut plan = AttackPlan::new(&cfg, 9, &mut rng);
        let mut st = states(9, 8);
        let participants: Vec<usize> = (0..9).collect();
        let draws_before = rng.clone();
        plan.corrupt(&mut st, &participants, &mut rng);
        let atks: Vec<usize> =
            (0..9).filter(|&p| plan.is_attacker(p)).collect();
        assert!(atks.len() >= 2);
        for w in atks.windows(2) {
            assert!(st[w[0]].theta.shares_storage(&st[w[1]].theta));
        }
        // collusion draws ONE noise vector total (8 coords)
        let mut replay = draws_before;
        for _ in 0..8 {
            replay.normal();
        }
        assert_eq!(replay.next_u64(), rng.next_u64());
    }

    #[test]
    fn reputation_bans_persistent_outliers_with_probation() {
        let mut rep = Reputation::new(6, 0.5);
        let members = [0usize, 1, 2, 3];
        // peer 3 is a strong outlier every iteration
        let scores = GroupScores {
            dists: vec![0.1, 0.12, 0.09, 50.0],
            center_norm: 10.0,
        };
        rep.observe_group(&members, &scores);
        assert_eq!(rep.fold_iteration(), 0); // rep 0.5, not yet below
        rep.observe_group(&members, &scores);
        assert_eq!(rep.fold_iteration(), 1); // rep 0.25 < 0.5 → ban
        assert!(rep.is_banned(3));
        assert!(!rep.is_banned(0));
        assert_eq!(rep.banned(), 1);
        // ban expires after BAN_ITERS folds; probation resets to the
        // threshold, so one more bad iteration re-bans immediately
        for _ in 0..BAN_ITERS {
            rep.fold_iteration();
        }
        assert!(!rep.is_banned(3));
        assert_eq!(rep.score(3), 0.5);
        rep.observe_group(&members, &scores);
        assert_eq!(rep.fold_iteration(), 1);
        assert!(rep.is_banned(3));
        assert_eq!(rep.ever_flagged(), &[false, false, false, true, false, false]);
    }

    #[test]
    fn worst_observation_of_iteration_wins() {
        let mut rep = Reputation::new(4, 0.5);
        let bad = GroupScores {
            dists: vec![0.1, 0.1, 0.1, 40.0],
            center_norm: 10.0,
        };
        let clean = GroupScores {
            dists: vec![0.1, 0.1, 0.1, 0.1],
            center_norm: 10.0,
        };
        // round 1 catches the outlier, rounds 2-3 (post-average alias)
        // look clean — the round-1 evidence must survive the fold
        rep.observe_group(&[0, 1, 2, 3], &bad);
        rep.observe_group(&[0, 1, 2, 3], &clean);
        rep.observe_group(&[0, 1, 2, 3], &clean);
        rep.fold_iteration();
        assert_eq!(rep.score(3), 0.5);
        assert_eq!(rep.score(0), 1.0);
    }

    #[test]
    fn converged_groups_never_flag_anyone() {
        // tiny absolute distances (relative spread is huge, absolute is
        // noise) must not produce outliers
        let mut rep = Reputation::new(4, 0.5);
        let scores = GroupScores {
            dists: vec![1e-9, 1e-9, 1e-9, 1e-6],
            center_norm: 10.0,
        };
        for _ in 0..10 {
            rep.observe_group(&[0, 1, 2, 3], &scores);
            rep.fold_iteration();
        }
        assert_eq!(rep.banned(), 0);
    }

    #[test]
    fn ban_count_is_bounded() {
        // pathological evidence: a different peer looks like a strong
        // outlier every iteration — the active-ban set must stay capped
        let mut rep = Reputation::new(10, 0.9);
        let scores = GroupScores {
            dists: vec![50.0, 0.1, 0.1],
            center_norm: 10.0,
        };
        for p in 0..8usize {
            rep.observe_group(&[p, 8, 9], &scores);
            rep.fold_iteration();
            assert!(rep.banned() <= 4, "cap is floor(0.45 · 10) = 4");
        }
        assert!(rep.ever_flagged().iter().filter(|&&f| f).count() >= 4);
    }

    #[test]
    fn adaptive_controller_steers_toward_the_trip_point() {
        let cfg = AttackConfig {
            frac: 0.4,
            mode: AttackMode::AdaptiveScale,
            scale: 1.0,
            ..Default::default()
        };
        let mut plan = AttackPlan::new(&cfg, 5, &mut Rng::new(11));
        let atk = (0..5).find(|&p| plan.is_attacker(p)).unwrap();
        let honest = (0..5).find(|&p| !plan.is_attacker(p)).unwrap();
        assert_eq!(plan.adapted_scale(atk), 1.0);
        // flagged hard (ratio 3 ≫ target): shrink by target/ratio
        let mut ratios = vec![0.0; 5];
        ratios[atk] = 3.0;
        ratios[honest] = 3.0; // non-attacker slots must never move
        plan.adapt(&ratios);
        assert_eq!(plan.adapted_scale(atk), ADAPT_TARGET / 3.0);
        assert_eq!(plan.adapted_scale(honest), 1.0);
        // sitting exactly on target: hold
        ratios[atk] = ADAPT_TARGET;
        plan.adapt(&ratios);
        assert_eq!(plan.adapted_scale(atk), ADAPT_TARGET / 3.0);
        // passing clean (tiny ratio): probe back up, capped per step...
        ratios[atk] = 1e-6;
        plan.adapt(&ratios);
        assert_eq!(plan.adapted_scale(atk), ADAPT_TARGET / 3.0 * ADAPT_STEP_MAX);
        // ...and never above the configured scale
        for _ in 0..64 {
            plan.adapt(&ratios);
        }
        assert_eq!(plan.adapted_scale(atk), 1.0);
        // hammered every round: bounded below by the probe floor
        ratios[atk] = 1e9;
        for _ in 0..64 {
            plan.adapt(&ratios);
        }
        assert_eq!(plan.adapted_scale(atk), ADAPT_FLOOR);
        // unobserved (banned / sub-3 group): hold
        ratios[atk] = 0.0;
        plan.adapt(&ratios);
        assert_eq!(plan.adapted_scale(atk), ADAPT_FLOOR);
    }

    #[test]
    fn adaptive_corruption_is_a_dialable_flip_with_zero_draws() {
        let cfg = AttackConfig {
            frac: 0.4,
            mode: AttackMode::AdaptiveScale,
            scale: 1.0,
            ..Default::default()
        };
        let mut rng = Rng::new(13);
        let mut plan = AttackPlan::new(&cfg, 5, &mut rng);
        let atk = (0..5).find(|&p| plan.is_attacker(p)).unwrap();
        let mut st = states(5, 4);
        let before = st[atk].theta.to_vec();
        let frozen = rng.clone();
        // full scale ⇒ (1 − 2·1)·θ = −θ, the classic sign flip
        plan.corrupt(&mut st, &[0, 1, 2, 3, 4], &mut rng);
        assert_eq!(st[atk].theta[0], -before[0]);
        assert_eq!(st[atk].momentum[0], -0.5);
        // dialed down ⇒ the blend shrinks toward identity
        let mut ratios = vec![0.0; 5];
        ratios[atk] = 3.0;
        plan.adapt(&ratios);
        let s = plan.adapted_scale(atk);
        let prev = st[atk].theta.to_vec();
        plan.corrupt(&mut st, &[0, 1, 2, 3, 4], &mut rng);
        assert_eq!(st[atk].theta[0], (1.0 - 2.0 * s) as f32 * prev[0]);
        // the whole adaptive path made zero RNG draws
        let mut replay = frozen;
        assert_eq!(replay.next_u64(), rng.next_u64());
    }

    #[test]
    fn alie_colludes_inside_the_honest_spread_with_zero_draws() {
        let cfg = AttackConfig {
            frac: 0.45,
            mode: AttackMode::Alie,
            scale: 1.0,
            collude: false, // alie colludes regardless
            ..Default::default()
        };
        let mut rng = Rng::new(17);
        let mut plan = AttackPlan::new(&cfg, 9, &mut rng);
        let mut st = states(9, 8);
        let participants: Vec<usize> = (0..9).collect();
        let frozen = rng.clone();
        plan.corrupt(&mut st, &participants, &mut rng);
        let atks: Vec<usize> = (0..9).filter(|&p| plan.is_attacker(p)).collect();
        assert!(atks.len() >= 2);
        for w in atks.windows(2) {
            assert!(st[w[0]].theta.shares_storage(&st[w[1]].theta));
            assert!(st[w[0]].momentum.shares_storage(&st[w[1]].momentum));
        }
        // θ_i = i+1 per row ⇒ mean 5, σ = sqrt(60/9); the corrupted
        // upload is mean − scale·σ in every coordinate
        let expect = (5.0 - (60.0f64 / 9.0).sqrt()) as f32;
        for &v in st[atks[0]].theta.as_slice() {
            assert_eq!(v, expect);
        }
        // momentum is constant 0.5 across peers ⇒ σ = 0, center survives
        assert_eq!(st[atks[0]].momentum[0], 0.5);
        assert_eq!(plan.active_count(), atks.len() as u64);
        let mut replay = frozen;
        assert_eq!(replay.next_u64(), rng.next_u64());
    }

    #[test]
    fn parole_grants_probation_then_rebans_at_the_tight_threshold() {
        let mut rep = Reputation::new(6, 0.5).with_parole(0.1, 2);
        let members = [0usize, 1, 2, 3];
        let scores = GroupScores {
            dists: vec![0.1, 0.12, 0.09, 50.0],
            center_norm: 10.0,
        };
        rep.observe_group(&members, &scores);
        assert_eq!(rep.fold_iteration(), 0); // 0.5 decays to 0.55 ≥ 0.5
        rep.observe_group(&members, &scores);
        assert_eq!(rep.fold_iteration(), 1); // 0.3475 < 0.5 → ban
        assert!(rep.is_banned(3));
        // parole_rounds = 2: one more fold still banned, then parole
        rep.fold_iteration();
        assert!(rep.is_banned(3));
        rep.fold_iteration();
        assert!(!rep.is_banned(3), "ban must expire into parole");
        assert!(rep.on_parole(3));
        assert_eq!(rep.paroles_granted(), 1);
        assert_eq!(rep.score(3), rep.parole_threshold());
        // one bad iteration inside the window re-bans immediately
        // under the tighter parole bar and bumps the re-ban counter
        rep.observe_group(&members, &scores);
        assert_eq!(rep.fold_iteration(), 1);
        assert!(rep.is_banned(3));
        assert!(!rep.on_parole(3));
        assert_eq!(rep.reban_count(), 1);
        // honest peers never wobble through any of it
        assert!(!rep.is_banned(0));
        assert_eq!(rep.score(0), 1.0);
    }

    #[test]
    fn decay_forgives_instead_of_shadowing_forever() {
        let mut sticky = Reputation::new(4, 0.5);
        let mut forgiving = Reputation::new(4, 0.5).with_parole(0.5, 0);
        let members = [0usize, 1, 2, 3];
        let bad = GroupScores {
            dists: vec![0.1, 0.12, 0.09, 50.0],
            center_norm: 10.0,
        };
        // one bad iteration (the false positive), then silence
        for rep in [&mut sticky, &mut forgiving] {
            rep.observe_group(&members, &bad);
            rep.fold_iteration();
        }
        assert_eq!(sticky.score(3), 0.5);
        assert_eq!(forgiving.score(3), 0.75); // 0.5 + 0.5·(1 − 0.5)
        for _ in 0..6 {
            sticky.fold_iteration();
            forgiving.fold_iteration();
        }
        assert_eq!(sticky.score(3), 0.5, "sticky scores never recover");
        assert!(forgiving.score(3) > 0.99, "decay drifts back to neutral");
        assert_eq!(forgiving.banned(), 0);
    }

    #[test]
    fn effective_flags_require_a_gated_matchmaking_pass() {
        let mut rep = Reputation::new(4, 0.5);
        let members = [0usize, 1, 2, 3];
        let scores = GroupScores {
            dists: vec![0.1, 0.12, 0.09, 50.0],
            center_norm: 10.0,
        };
        for _ in 0..2 {
            rep.observe_group(&members, &scores);
            rep.fold_iteration();
        }
        assert!(rep.is_banned(3));
        assert!(rep.ever_flagged()[3]);
        // banned, but no matchmaking pass has dropped it yet — the
        // scorecard set stays empty (a final-iteration ban never gates)
        assert!(!rep.effective_flags()[3]);
        assert_eq!(flag_quality(rep.effective_flags(), &[false, false, false, true]).0, 0);
        rep.note_gated(3);
        assert!(rep.effective_flags()[3]);
        let (n, p, r) =
            flag_quality(rep.effective_flags(), &[false, false, false, true]);
        assert_eq!((n, p, r), (1, 1.0, 1.0));
    }

    #[test]
    fn last_ratios_publish_then_clear_the_detector_signal() {
        let mut rep = Reputation::new(4, 0.5);
        let members = [0usize, 1, 2, 3];
        let scores = GroupScores {
            dists: vec![0.1, 0.12, 0.09, 50.0],
            center_norm: 10.0,
        };
        assert!(rep.last_ratios().iter().all(|&r| r == 0.0));
        rep.observe_group(&members, &scores);
        // staged but not yet folded: the attacker cannot see this round
        assert!(rep.last_ratios().iter().all(|&r| r == 0.0));
        rep.fold_iteration();
        assert!(rep.last_ratios()[3] > 1.0, "outlier sits past the trip point");
        assert!(rep.last_ratios()[0] < 1.0 && rep.last_ratios()[0] > 0.0);
        // an unobserved iteration clears the signal (ratio 0 = hold)
        rep.fold_iteration();
        assert!(rep.last_ratios().iter().all(|&r| r == 0.0));
    }

    #[test]
    fn flag_quality_counts() {
        let flagged = [true, false, true, false];
        let attacker = [true, false, false, true];
        let (n, p, r) = flag_quality(&flagged, &attacker);
        assert_eq!(n, 2);
        assert_eq!(p, 0.5);
        assert_eq!(r, 0.5);
        let (n, p, r) = flag_quality(&[false; 4], &[false; 4]);
        assert_eq!((n, p, r), (0, 1.0, 1.0));
    }
}
