//! Figure 8 — heterogeneous peer data: iid vs LDA(α=1.0) non-iid splits.
//!
//! Paper claim: non-iid splits barely affect MAR-FL on MNIST but noticeably
//! impair it on 20NG. With exact global averaging the impairment shows up
//! as *slower convergence* (the paper plots training curves), so the
//! comparison metric here is the mean accuracy over the whole curve
//! (area-under-curve) alongside the final accuracy.

#[path = "common/mod.rs"]
mod common;

use common::{assert_stable_columns, emit_bench_report, emit_csv, iters, runtime, timed};
use marfl::config::ExperimentConfig;
use marfl::data::lda;
use marfl::fl::Trainer;

fn main() {
    let rt = runtime();
    let t = iters(24, 60);
    let peers = 64;
    let mut rows = vec![vec![
        "model".into(),
        "split".into(),
        "heterogeneity_tv".into(),
        "final_accuracy".into(),
        "curve_mean_accuracy".into(),
    ]];
    let mut gaps = Vec::new();
    for model in ["cnn", "head"] {
        println!("Figure 8 — {model} (peers={peers}, T={t})");
        let base = ExperimentConfig {
            model: model.into(),
            peers,
            group_size: 4,
            mar_rounds: 3,
            iterations: t,
            samples_per_peer: 64,
            test_samples: 1000,
            eval_every: 2,
            seed: 8888,
            ..Default::default()
        };
        let mut aucs = Vec::new();
        let mut accs = Vec::new();
        for iid in [true, false] {
            let cfg = ExperimentConfig { iid, ..base.clone() };
            // report the realized heterogeneity of this split
            let mut rng = marfl::rng::Rng::new(cfg.seed);
            let data = marfl::data::build(
                model,
                peers,
                cfg.samples_per_peer,
                100,
                iid,
                cfg.lda_alpha,
                &mut rng.fork(1),
            );
            let shards: Vec<Vec<usize>> =
                data.shards.iter().map(|s| s.indices.clone()).collect();
            let tv = lda::heterogeneity(&data.train, &shards);
            let label = if iid { "iid" } else { "lda(1.0)" };
            let run = timed(&format!("{model} {label}"), || {
                Trainer::new(cfg, &rt).unwrap().run().unwrap()
            });
            let auc = run.curve.points.iter().map(|p| p.accuracy).sum::<f64>()
                / run.curve.points.len() as f64;
            println!(
                "    TV {tv:.3}  final acc {:.3}  curve mean {auc:.3}",
                run.final_accuracy
            );
            rows.push(vec![
                model.into(),
                label.into(),
                format!("{tv:.4}"),
                format!("{:.4}", run.final_accuracy),
                format!("{auc:.4}"),
            ]);
            accs.push(run.final_accuracy);
            aucs.push(auc);
        }
        let gap = aucs[0] - aucs[1]; // iid - noniid, convergence-speed view
        println!(
            "  iid -> non-iid: curve-mean gap {gap:+.3}, final gap {:+.3}\n",
            accs[0] - accs[1]
        );
        gaps.push((model, gap));
    }
    assert_stable_columns(
        "fig8_heterogeneity.csv",
        &rows,
        &[
            "model",
            "split",
            "heterogeneity_tv",
            "final_accuracy",
            "curve_mean_accuracy",
        ],
    );
    emit_csv("fig8_heterogeneity.csv", &rows);
    emit_bench_report("heterogeneity", "heterogeneity", &rows);

    // paper shape: the language task suffers more from heterogeneity than
    // the vision task (in convergence speed — exact averaging makes the
    // asymptote robust)
    let cnn_gap = gaps.iter().find(|(m, _)| *m == "cnn").unwrap().1;
    let head_gap = gaps.iter().find(|(m, _)| *m == "head").unwrap().1;
    println!("cnn curve-mean gap {cnn_gap:+.3} vs head curve-mean gap {head_gap:+.3}");
    assert!(
        head_gap > cnn_gap - 0.02,
        "20NG-like should be at least as heterogeneity-sensitive as MNIST-like"
    );
}
