//! The FL training loop — the paper's "dispatcher" (§B.2), modelling a
//! parallel deployment and, since the parallel round engine (`exec`),
//! executing it in parallel too: per iteration it samples participants,
//! dispatches local momentum-SGD updates across the thread pool (batch
//! schedules drawn serially, so results are bit-identical to the serial
//! path), runs Moshpit-KD when active, privatizes when DP is on,
//! aggregates with the configured technique (groups averaged
//! concurrently), evaluates every `eval_every` iterations, and books every
//! byte, hop and simulated second.
//!
//! Counters live in the trainer's [`MetricRegistry`] (handles resolved
//! once at construction — see [`TrainerMetrics`]); the [`RunSummary`]
//! scorecards are end-of-run views over that registry. An optional
//! round-event trace ([`TrainerBuilder::trace`]) records the iteration
//! timeline; telemetry-off runs are bit-identical to the untraced seed.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::aggregation::{
    baseline_for_robust, AggCtx, Aggregate, GroupExchange, PeerState,
};
use crate::attack::AttackPlan;
use crate::config::{ExperimentConfig, Strategy};
use crate::coordinator::{AggOptions, MarAggregator};
use crate::data::{build as build_data, FlData};
use crate::dp::DpEngine;
use crate::kd::KdEngine;
use crate::metrics::{CommLedger, CommSnapshot, Plane, TrainCurve};
use crate::models::ModelMeta;
use crate::net::{ChurnModel, Fabric, LinkState, MarkovChurn};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::sim::SimClock;
use crate::telemetry::{
    trace_handle, ByzantineScorecard, DpScorecard, EventKind, FaultScorecard,
    MetricRegistry, ReliabilityScorecard, TraceHandle, TrainerMetrics,
};

/// Simulated local-compute time per mini-batch (seconds). The paper's
/// claims are about communication; compute merely anchors the simulated
/// clock so comm/compute ratios are plausible for edge devices.
pub const LOCAL_BATCH_COMPUTE_S: f64 = 0.05;

/// Which aggregator the trainer drives.
enum Agg {
    Mar(MarAggregator),
    Baseline(Box<dyn Aggregate>),
}

impl Agg {
    fn as_dyn(&mut self) -> &mut dyn Aggregate {
        match self {
            Agg::Mar(m) => m,
            Agg::Baseline(b) => b.as_mut(),
        }
    }
}

/// Outcome of a full training run: headline numbers at the top level,
/// subsystem counters grouped into typed scorecards
/// ([`ReliabilityScorecard`], [`FaultScorecard`], [`ByzantineScorecard`],
/// [`DpScorecard`]) read back from the trainer's metric registry.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub curve: TrainCurve,
    pub comm: CommSnapshot,
    pub sim_time_s: f64,
    pub iterations_run: usize,
    /// cumulative DHT hops (MAR only)
    pub dht_hops: Option<u64>,
    /// churn / reduce-scatter recovery counters (`summary.reliability.
    /// rs_fallbacks` is the axis `fig3_churn` plots against `mar.rs_drop`)
    pub reliability: ReliabilityScorecard,
    /// fault-injection outcomes, straggler exposure, and the
    /// heterogeneous-bandwidth observations — all-zero / `None` when the
    /// fault plan is off
    pub faults: FaultScorecard,
    /// attack pressure and defense quality (`attack.*` knobs)
    pub byzantine: ByzantineScorecard,
    /// differential-privacy budget (`dp.*` knobs)
    pub dp: DpScorecard,
    pub final_accuracy: f64,
    pub final_loss: f64,
}

/// Staged construction for [`Trainer`] — the single place the
/// aggregator options, engine parallelism, and telemetry sinks are
/// decided. `Trainer::new` is shorthand for the all-defaults build.
pub struct TrainerBuilder<'rt> {
    cfg: ExperimentConfig,
    rt: &'rt Runtime,
    label: Option<String>,
    parallel: bool,
    trace: bool,
}

impl<'rt> TrainerBuilder<'rt> {
    /// Override the curve label (strategy name by default).
    pub fn label(mut self, label: impl Into<String>) -> Self {
        self.label = Some(label.into());
        self
    }

    /// Run MAR group lanes and KD distillation lanes on the serial
    /// reference engine (`false`) instead of the thread pool (`true`,
    /// default). Results are bit-identical either way — the serial
    /// engine exists as the determinism reference and benchmark arm.
    pub fn parallel(mut self, parallel: bool) -> Self {
        self.parallel = parallel;
        self
    }

    /// Record the per-iteration round-event timeline (off by default;
    /// off is bit-identical to the seed). Read it back via
    /// [`Trainer::trace`] / [`Trainer::write_trace`].
    pub fn trace(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    pub fn build(self) -> Result<Trainer<'rt>> {
        let TrainerBuilder { cfg, rt, label, parallel, trace } = self;
        cfg.validate()?;
        let model = rt.meta.model(&cfg.model)?.clone();
        let mut rng = Rng::new(cfg.seed);
        let data = build_data(
            &cfg.model,
            cfg.peers,
            cfg.samples_per_peer,
            cfg.test_samples,
            cfg.iid,
            cfg.lda_alpha,
            &mut rng.fork(1),
        );
        anyhow::ensure!(
            cfg.test_samples % model.eval_chunk == 0,
            "test_samples {} must be a multiple of the eval chunk {}",
            cfg.test_samples,
            model.eval_chunk
        );
        // every peer starts from the same θ⁰ (paper §2.2) — one shared
        // allocation until a peer's first local update (copy-on-write)
        let theta0 = rt.init_params(&cfg.model)?;
        let states = vec![PeerState::new(theta0); cfg.peers];
        let ledger = Arc::new(CommLedger::new());
        let fabric =
            Fabric::new(ledger.clone(), cfg.link_bandwidth, cfg.link_latency);
        let registry = Arc::new(MetricRegistry::new());
        let metrics = TrainerMetrics::register(&registry)?;
        let trace = trace.then(trace_handle);
        // one robust policy threads through every averaging surface: MAR
        // groups, the MKD teacher-logit ensemble, and the baselines that
        // have a trimming analogue (`Mean` keeps each bit-identical)
        let policy = cfg.attack.policy();
        let agg = match cfg.strategy {
            Strategy::MarFl => {
                let mut opts = AggOptions {
                    parallel,
                    robust: policy,
                    rep_threshold: cfg.attack.rep_threshold,
                    rep_decay: cfg.attack.rep_decay,
                    parole_rounds: cfg.attack.parole_rounds,
                    trace: trace.clone(),
                    ..AggOptions::default()
                };
                if cfg.reduce_scatter {
                    opts.exchange = GroupExchange::ReduceScatter;
                    opts.rs_drop = cfg.rs_drop;
                    opts.rs_retry_budget = cfg.rs_retry_budget;
                }
                Agg::Mar(MarAggregator::with_options(
                    cfg.peers,
                    cfg.group_size,
                    cfg.effective_mar_rounds(),
                    ledger.clone(),
                    cfg.seed,
                    opts,
                ))
            }
            s => Agg::Baseline(
                baseline_for_robust(s, policy)
                    .context("baseline construction")?,
            ),
        };
        let kd = if cfg.kd.enabled && cfg.strategy == Strategy::MarFl {
            Some(
                KdEngine::new(cfg.kd.clone(), rt.meta.kd_tau, cfg.eta, cfg.mu)
                    .with_robust(policy)
                    .with_parallel(parallel),
            )
        } else {
            None
        };
        let dp = if cfg.dp.enabled {
            Some(DpEngine::new(cfg.dp.clone(), cfg.peers))
        } else {
            None
        };
        let churn = ChurnModel::new(cfg.participation, cfg.dropout);
        let markov = (cfg.churn_model == "markov").then(|| {
            MarkovChurn::new(
                cfg.peers,
                cfg.markov_p_down,
                cfg.markov_p_up,
                &mut rng.fork(2),
            )
        });
        // dedicated fork (tag 3 — tags 1/2 are data/markov, iteration
        // forks start at 32) so the chain/bandwidth draws never shift the
        // schedule streams; gated exactly like the markov chain above
        let links = cfg
            .faults
            .time_correlated()
            .then(|| LinkState::new(&cfg.faults, cfg.peers, &mut rng.fork(3)));
        // ground-truth attacker set: one gated fork (tag 4) draws it once
        // per run, so attack-free configs consume zero extra randomness
        let attack = cfg
            .attack
            .enabled()
            .then(|| AttackPlan::new(&cfg.attack, cfg.peers, &mut rng.fork(4)));
        let label = label.unwrap_or_else(|| cfg.strategy.name().to_string());
        let peers = cfg.peers;
        Ok(Trainer {
            cfg,
            rt,
            model,
            data,
            states,
            agg,
            churn,
            markov,
            ledger,
            fabric,
            clock: SimClock::new(),
            rng,
            kd,
            dp,
            registry,
            metrics,
            trace,
            links,
            attack,
            stale: vec![false; peers],
            label,
        })
    }
}

/// End-to-end MAR-FL trainer.
pub struct Trainer<'rt> {
    pub cfg: ExperimentConfig,
    rt: &'rt Runtime,
    model: ModelMeta,
    data: FlData,
    states: Vec<PeerState>,
    agg: Agg,
    churn: ChurnModel,
    markov: Option<MarkovChurn>,
    ledger: Arc<CommLedger>,
    fabric: Fabric,
    clock: SimClock,
    rng: Rng,
    kd: Option<KdEngine>,
    dp: Option<DpEngine>,
    /// the trainer's metric registry — every counter previously
    /// hand-threaded as a flat field books through a handle in `metrics`
    registry: Arc<MetricRegistry>,
    /// pre-resolved handles into `registry` (see [`TrainerMetrics`])
    metrics: TrainerMetrics,
    /// round-event trace sink, shared with the MAR aggregator
    /// ([`TrainerBuilder::trace`]); `None` = telemetry off
    trace: Option<TraceHandle>,
    /// time-correlated link state (Gilbert–Elliott chains + per-peer
    /// bandwidths), present only when `faults.time_correlated()` — the
    /// gated construction keeps time-uncorrelated plans draw-identical
    /// to the seed
    links: Option<LinkState>,
    /// ground-truth Byzantine plan, present only when `attack.frac > 0`
    /// — gated exactly like the fault RNG so clean runs stay
    /// bit-identical
    attack: Option<AttackPlan>,
    /// peers that crash-faulted and have not yet rejoined: they resume
    /// with a booked fresh-θ pull the next time they participate
    stale: Vec<bool>,
    /// label used for the curve (strategy name by default)
    pub label: String,
}

impl<'rt> Trainer<'rt> {
    /// Staged construction ([`TrainerBuilder`]).
    pub fn builder(cfg: ExperimentConfig, rt: &'rt Runtime) -> TrainerBuilder<'rt> {
        TrainerBuilder { cfg, rt, label: None, parallel: true, trace: false }
    }

    /// All-defaults build: parallel engines, telemetry trace off.
    pub fn new(cfg: ExperimentConfig, rt: &'rt Runtime) -> Result<Self> {
        Self::builder(cfg, rt).build()
    }

    /// Record one trace event at simulated time `t` (no-op untraced).
    fn trace_ev(&self, iter: u64, t: f64, kind: EventKind) {
        if let Some(tr) = &self.trace {
            tr.lock().unwrap().record(iter, t, kind);
        }
    }

    /// Run T iterations (or until `target_accuracy`); returns the curve
    /// and the final accounting.
    pub fn run(&mut self) -> Result<RunSummary> {
        let mut curve = TrainCurve::new(self.label.clone());
        let mut iterations_run = 0;
        let mut last = (f64::NAN, 0.0);
        for t in 1..=self.cfg.iterations {
            self.iteration(t)?;
            iterations_run = t;
            if t % self.cfg.eval_every == 0 || t == self.cfg.iterations {
                let (loss, acc) = self.evaluate()?;
                last = (loss, acc);
                curve.push(t, self.ledger.snapshot(), loss, acc, self.clock.now());
                self.trace_ev(
                    t as u64,
                    self.clock.now(),
                    EventKind::Eval { loss, accuracy: acc },
                );
                log::info!(
                    "[{}] iter {t}: loss {loss:.4} acc {acc:.4} data {} MiB",
                    self.label,
                    self.ledger.snapshot().data_bytes / (1 << 20),
                );
                if self.cfg.target_accuracy > 0.0 && acc >= self.cfg.target_accuracy
                {
                    break;
                }
            }
        }
        // end-of-run folds into the registry: the Markov revival count
        // and the link-state chain totals live outside the per-round
        // counters (single shared structures), so their run totals land
        // here exactly once
        self.metrics
            .markov_revivals
            .add(self.markov.as_ref().map(|c| c.revivals()).unwrap_or(0));
        if let Some(ls) = &self.links {
            self.metrics.ge_bad_transitions.add(ls.ge_bad_transitions);
            self.metrics.bursty_losses.add(ls.bursty_losses);
            self.metrics.bw_redraws.add(ls.bw_redraws);
        }
        let reliability = self.metrics.reliability();
        let faults = self
            .metrics
            .faults(self.links.as_ref().and_then(|ls| ls.bw_percentiles()));
        if reliability.churn_rescues > 0
            || reliability.markov_revivals > 0
            || faults.ge_bad_transitions > 0
        {
            log::info!(
                "[{}] liveness: {} aggregator keep-alive rescues, \
                 {} Markov revivals, {} link bursts ({} bursty losses)",
                self.label,
                reliability.churn_rescues,
                reliability.markov_revivals,
                faults.ge_bad_transitions,
                faults.bursty_losses,
            );
        }
        // attack/defence scorecard: ground truth from the plan, flags
        // from the MAR reputation ledger (empty-set conventions give
        // 1.0/1.0 so clean runs read as "nothing wrongly flagged")
        self.metrics
            .attackers_active
            .add(self.attack.as_ref().map(|p| p.active_count()).unwrap_or(0));
        self.metrics.flag_precision.set(1.0);
        self.metrics.flag_recall.set(1.0);
        if let Agg::Mar(m) = &self.agg {
            if let Some(rep) = m.reputation() {
                let honest = vec![false; self.cfg.peers];
                let attacker = self
                    .attack
                    .as_ref()
                    .map(|p| p.attacker_flags())
                    .unwrap_or(&honest);
                // score over *effective* bans — those that gated at
                // least one matchmaking pass — so a ban landed on the
                // final fold (which never removed anyone from a group)
                // cannot distort precision/recall
                let (f, p, r) = crate::attack::flag_quality(
                    rep.effective_flags(),
                    attacker,
                );
                self.metrics.flagged_peers.add(f);
                self.metrics.flag_precision.set(p);
                self.metrics.flag_recall.set(r);
                self.metrics.paroles_granted.add(rep.paroles_granted());
                self.metrics.reban_count.add(rep.reban_count());
            }
        }
        Ok(RunSummary {
            comm: self.ledger.snapshot(),
            sim_time_s: self.clock.now(),
            iterations_run,
            dht_hops: match &self.agg {
                Agg::Mar(m) => Some(m.dht_hops()),
                _ => None,
            },
            reliability,
            faults,
            byzantine: self.metrics.byzantine(),
            dp: DpScorecard { epsilon: self.dp.as_ref().map(|d| d.epsilon()) },
            final_loss: last.0,
            final_accuracy: last.1,
            curve,
        })
    }

    /// One FL iteration (Algorithm 1 body).
    fn iteration(&mut self, t: usize) -> Result<()> {
        // slow-schedule heterogeneous-bandwidth re-draw: capacities shift
        // every `faults.bw_redraw_rounds` iterations from the LinkState's
        // own dedicated RNG, so the schedule streams below never move
        // (0 = static, bit-identical to the previous behaviour)
        if let Some(ls) = self.links.as_mut() {
            ls.maybe_redraw(&self.cfg.faults, t as u64);
        }
        // U_t: participants for the entire iteration. Bernoulli sampling
        // (paper §3.1) or the bursty Markov availability trace.
        let mut churn_rng = self.rng.fork(t as u64 * 31 + 1);
        let participants = match &mut self.markov {
            Some(chain) => chain.step(&mut churn_rng),
            None => self.churn.sample_participants(self.cfg.peers, &mut churn_rng),
        };
        self.trace_ev(
            t as u64,
            self.clock.now(),
            EventKind::IterStart { participants: participants.len() as u64 },
        );

        // fault plan RNG: forked only when the plan is live, so the
        // fault-free path consumes exactly the draws it always did and
        // stays bit-identical (pinned by `tests/fault_injection.rs`)
        let mut fault_rng = self
            .cfg
            .faults
            .enabled()
            .then(|| self.rng.fork(t as u64 * 31 + 5));

        // crash-faulted peers rejoin here: a stale participant pulls a
        // fresh θ from a live donor before training (one state-sized
        // transfer each, booked on the data plane; pulls run as parallel
        // lanes). With no live donor this iteration, the peer resumes
        // from its stale θ — the pull would have nothing fresher to offer.
        if self.stale.iter().any(|&s| s) {
            let donor = participants.iter().copied().find(|&p| !self.stale[p]);
            let bytes = crate::aggregation::state_bytes(&self.model);
            let mut lanes = Vec::new();
            for &p in &participants {
                if !self.stale[p] {
                    continue;
                }
                if let Some(d) = donor {
                    self.states[p] = self.states[d].clone();
                    lanes.push(self.fabric.send(bytes, Plane::Data));
                    self.metrics.rejoin_pulls.inc();
                    self.trace_ev(
                        t as u64,
                        self.clock.now(),
                        EventKind::CrashRejoin { peer: p as u64 },
                    );
                }
                self.stale[p] = false;
            }
            self.clock.parallel(lanes);
        }

        // local momentum-SGD updates — run truly in parallel across peers
        // on the exec pool, matching the parallel deployment the clock
        // models. Batch indices are drawn serially first (the shard
        // cursors are schedule state), so every peer consumes exactly the
        // batches it would under serial execution and results are
        // bit-identical regardless of thread interleaving.
        let batch_plans: Vec<Vec<Vec<usize>>> = participants
            .iter()
            .map(|&i| {
                (0..self.cfg.local_batches)
                    .map(|_| self.data.shards[i].next_batch(self.model.batch))
                    .collect()
            })
            .collect();
        {
            let rt = self.rt;
            let model = &self.model;
            let train = &self.data.train;
            let (eta, mu) = (self.cfg.eta, self.cfg.mu);
            let plans = &batch_plans;
            let results = crate::exec::par_map_at(
                &mut self.states,
                &participants,
                |pos, st| -> Result<()> {
                    // batches gather into the worker's scratch buffers —
                    // after each worker's first batch, the schedule runs
                    // with zero batch allocations
                    crate::exec::with_scratch::<crate::data::BatchBuf, _, _>(
                        |buf| {
                            for idx in &plans[pos] {
                                train.gather_into_buf(idx, buf);
                                // in-place step through the copy-on-write
                                // handles: a θ shared with a group mean or
                                // snapshot detaches once on the first
                                // batch, then the whole schedule mutates
                                // one buffer — no per-step state
                                // allocations
                                rt.train_step_into(
                                    model,
                                    st.theta.make_mut_slice(),
                                    st.momentum.make_mut_slice(),
                                    &buf.x,
                                    &buf.y,
                                    eta,
                                    mu,
                                )?;
                            }
                            Ok(())
                        },
                    )
                },
            )?;
            for r in results {
                r?;
            }
        }
        // simulated local-compute time: peers run concurrently in the
        // modelled deployment, so an iteration costs one peer's batches —
        // and nothing at all when nobody participated
        if !participants.is_empty() {
            let base = self.cfg.local_batches as f64 * LOCAL_BATCH_COMPUTE_S;
            // straggler faults: every participant draws a compute
            // multiplier (serially — the fault RNG is schedule state);
            // lanes run concurrently, so the slowest straggler gates the
            // iteration. `base * 1.0` is exact, so the fault-free clock
            // is bit-identical.
            let mut mult_max = 1.0f64;
            if let Some(frng) = fault_rng.as_mut() {
                if self.cfg.faults.straggler_prob > 0.0 {
                    for _ in &participants {
                        if frng.chance(self.cfg.faults.straggler_prob) {
                            mult_max =
                                mult_max.max(self.cfg.faults.straggler_mult);
                        }
                    }
                }
            }
            self.clock.advance(base * mult_max);
            self.metrics.straggler_exposed_s.add(base * (mult_max - 1.0));
            self.trace_ev(
                t as u64,
                self.clock.now(),
                EventKind::LocalCompute {
                    dt: base * mult_max,
                    straggler_dt: base * (mult_max - 1.0),
                },
            );
        }

        // A_t: aggregators (participants that survive dropout)
        let (aggers, rescued) =
            self.churn.sample_aggregators_counted(&participants, &mut churn_rng);
        if rescued {
            self.metrics.churn_rescues.inc();
        }
        if aggers.len() < 2 {
            return Ok(());
        }

        // Moshpit-KD (first K iterations, MAR only)
        if let (Some(kd), Agg::Mar(mar)) = (&self.kd, &mut self.agg) {
            if kd.active(t) {
                let mut rng = self.rng.fork(t as u64 * 31 + 2);
                let mut ctx = AggCtx {
                    fabric: &self.fabric,
                    clock: &mut self.clock,
                    rng: &mut rng,
                    runtime: Some(self.rt),
                    model: &self.model,
                    faults: &self.cfg.faults,
                    links: self.links.as_mut(),
                };
                let kd_rep = kd.run_mkd(
                    t,
                    self.rt,
                    &self.model,
                    &self.data.train,
                    &mut self.data.shards,
                    &mut self.states,
                    &aggers,
                    mar,
                    &mut ctx,
                )?;
                self.metrics.add_faults(&kd_rep.faults);
                self.metrics
                    .straggler_exposed_s
                    .add(kd_rep.straggler_exposed_s);
                self.trace_ev(
                    t as u64,
                    self.clock.now(),
                    EventKind::Mkd {
                        rounds: kd_rep.rounds as u64,
                        kd_steps: kd_rep.kd_steps,
                        teacher_transfers: kd_rep.teacher_transfers,
                        mean_loss: kd_rep.mean_loss,
                    },
                );
            }
        }

        // Byzantine corruption: attackers replace their uploads after
        // local training and distillation, just before privatization and
        // exchange — the group sees the corrupted state, exactly what a
        // malicious peer controls. Draws come from a dedicated gated fork
        // (tag +6) so clean runs consume zero extra randomness.
        if let Some(plan) = &mut self.attack {
            // Adaptive attackers steer on last iteration's published
            // distance ratios (a black-box read of the defender's own
            // ledger) strictly in this serial phase, before any lane
            // forks — zero RNG draws, so determinism pins are untouched.
            if plan.adaptive() {
                if let Agg::Mar(m) = &self.agg {
                    if let Some(rep) = m.reputation() {
                        plan.adapt(rep.last_ratios());
                    }
                }
            }
            let mut atk_rng = self.rng.fork(t as u64 * 31 + 6);
            plan.corrupt(&mut self.states, &aggers, &mut atk_rng);
        }

        // DP privatization before aggregation (Algorithm 4)
        let mut dp_rng = self.rng.fork(t as u64 * 31 + 3);
        if let Some(dp) = &mut self.dp {
            dp.prepare(&mut self.states, &aggers, &mut dp_rng);
        }

        // global aggregation
        let mut agg_rng = self.rng.fork(t as u64 * 31 + 4);
        let mut ctx = AggCtx {
            fabric: &self.fabric,
            clock: &mut self.clock,
            rng: &mut agg_rng,
            runtime: Some(self.rt),
            model: &self.model,
            faults: &self.cfg.faults,
            links: self.links.as_mut(),
        };
        let report =
            self.agg.as_dyn().aggregate(&mut self.states, &aggers, &mut ctx)?;
        self.metrics.rs_fallbacks.add(report.rs_fallbacks as u64);
        self.metrics.rs_retries.add(report.rs_retries as u64);
        self.metrics.add_faults(&report.faults);

        // crash-faulted MAR members leave mid-exchange: their θ stays
        // stale until the next iteration they participate in (the
        // fresh-θ rejoin pull above), and the Markov availability chain —
        // when driving churn — sees them go Down so the rejoin follows
        // the chain's own Up transition.
        if self.cfg.faults.crash_prob > 0.0 {
            let crashed = match &mut self.agg {
                Agg::Mar(m) => m.take_crashed(),
                _ => Vec::new(),
            };
            for peer in crashed {
                self.stale[peer] = true;
                if let Some(chain) = &mut self.markov {
                    chain.set_down(peer);
                }
            }
        }

        if let Some(dp) = &mut self.dp {
            dp.finalize(&mut self.states, &aggers, &mut dp_rng);
        }
        Ok(())
    }

    /// Evaluate the consensus model (mean of all peer parameters — under
    /// exact aggregation every peer already holds it) on the shared test
    /// set. Diagnostic only: books no communication.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let all: Vec<usize> = (0..self.cfg.peers).collect();
        let (theta, _) = crate::aggregation::mean_of(&self.states, &all);
        self.rt
            .evaluate(&self.model, &theta, &self.data.test.x, &self.data.test.y)
    }

    /// Accuracy of a single peer's local model (divergence diagnostics).
    pub fn evaluate_peer(&self, i: usize) -> Result<(f64, f64)> {
        self.rt.evaluate(
            &self.model,
            &self.states[i].theta,
            &self.data.test.x,
            &self.data.test.y,
        )
    }

    pub fn ledger(&self) -> &Arc<CommLedger> {
        &self.ledger
    }

    pub fn states(&self) -> &[PeerState] {
        &self.states
    }

    pub fn model(&self) -> &ModelMeta {
        &self.model
    }

    /// The trainer's metric registry (scorecard source of truth).
    pub fn registry(&self) -> &Arc<MetricRegistry> {
        &self.registry
    }

    /// Pre-resolved metric handles (mid-run diagnostics).
    pub fn metrics(&self) -> &TrainerMetrics {
        &self.metrics
    }

    /// The recorded round-event trace (`Some` iff built with
    /// [`TrainerBuilder::trace`]).
    pub fn trace(&self) -> Option<&TraceHandle> {
        self.trace.as_ref()
    }

    /// Write the recorded trace as JSONL; errors when the trainer was
    /// built without tracing.
    pub fn write_trace(&self, path: &Path) -> Result<()> {
        let tr = self
            .trace
            .as_ref()
            .context("trainer built without .trace(true)")?;
        tr.lock().unwrap().write_jsonl(path)
    }
}
