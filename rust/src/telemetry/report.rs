//! Shared bench-report schema and the trajectory folder.
//!
//! Every bench emits its `BENCH_*.json` through [`BenchReport`]: the
//! bench's own fields stay at the top level (existing dashboards keep
//! their key paths), and the envelope stamps two extra keys — `schema`
//! ([`BENCH_SCHEMA`]) and `bench` (the bench's name). `fold_trajectory`
//! then folds every `BENCH_*.json` in a results directory into one
//! `BENCH_trajectory.json` (`make trajectory`), which CI uploads as the
//! cross-PR perf trajectory artifact.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::metrics::write_json;
use crate::util::json::{obj, parse, s, Json};

/// Bench-report schema identifier, bumped on any envelope change.
pub const BENCH_SCHEMA: &str = "marfl-bench/v1";

/// Trajectory schema identifier.
pub const TRAJECTORY_SCHEMA: &str = "marfl-trajectory/v1";

/// Builder for one bench's `BENCH_<name>.json` document.
#[derive(Clone, Debug)]
pub struct BenchReport {
    bench: String,
    fields: Vec<(String, Json)>,
}

impl BenchReport {
    /// `name` is the file stem suffix: `BenchReport::new("churn")`
    /// writes `BENCH_churn.json`.
    pub fn new(name: &str) -> Self {
        BenchReport { bench: name.to_string(), fields: Vec::new() }
    }

    /// Add one top-level field. `schema` and `bench` are reserved for
    /// the envelope.
    pub fn field(mut self, key: &str, value: Json) -> Self {
        assert!(key != "schema" && key != "bench", "reserved envelope key {key:?}");
        self.fields.push((key.to_string(), value));
        self
    }

    /// The full document: bench fields plus the envelope keys.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> =
            vec![("schema", s(BENCH_SCHEMA)), ("bench", s(&self.bench))];
        for (k, v) in &self.fields {
            pairs.push((k.as_str(), v.clone()));
        }
        obj(pairs)
    }

    /// Write `BENCH_<name>.json` into `dir`, returning the path.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        write_json(&path, &self.to_json())?;
        Ok(path)
    }
}

/// Validate that `doc` is a schema-stamped bench report.
pub fn validate_bench_doc(doc: &Json) -> Result<()> {
    match doc.get("schema").and_then(|v| v.as_str()) {
        Some(BENCH_SCHEMA) => {}
        Some(other) => bail!("unsupported bench schema {other:?} (want {BENCH_SCHEMA})"),
        None => bail!("bench report missing \"schema\" key"),
    }
    if doc.get("bench").and_then(|v| v.as_str()).is_none() {
        bail!("bench report missing \"bench\" key");
    }
    Ok(())
}

/// Fold every `BENCH_*.json` in `dir` (except the trajectory itself)
/// into one trajectory document, keyed by bench file stem in sorted
/// order. Unstamped legacy documents are folded as-is — the trajectory
/// records what was actually emitted.
pub fn fold_trajectory(dir: &Path) -> Result<Json> {
    let mut names: Vec<String> = Vec::new();
    for entry in fs::read_dir(dir).with_context(|| format!("read {dir:?}"))? {
        let name = entry?.file_name().to_string_lossy().into_owned();
        if name.starts_with("BENCH_") && name.ends_with(".json") && name != "BENCH_trajectory.json" {
            names.push(name);
        }
    }
    if names.is_empty() {
        bail!("no BENCH_*.json files in {dir:?}");
    }
    names.sort();
    let mut benches: Vec<(&str, Json)> = Vec::new();
    let stems: Vec<String> = names
        .iter()
        .map(|n| n.trim_start_matches("BENCH_").trim_end_matches(".json").to_string())
        .collect();
    for (name, stem) in names.iter().zip(&stems) {
        let text = fs::read_to_string(dir.join(name)).with_context(|| format!("read {name}"))?;
        let doc = parse(&text).map_err(|e| anyhow::anyhow!("parse {name}: {e}"))?;
        benches.push((stem.as_str(), doc));
    }
    Ok(obj(vec![
        ("schema", s(TRAJECTORY_SCHEMA)),
        ("benches", obj(benches)),
    ]))
}

/// Fold and write `BENCH_trajectory.json` into `dir`, returning the path.
pub fn write_trajectory(dir: &Path) -> Result<PathBuf> {
    let doc = fold_trajectory(dir)?;
    let path = dir.join("BENCH_trajectory.json");
    write_json(&path, &doc)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::num;

    fn tempdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("marfl_report_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn report_stamps_envelope_and_keeps_fields_top_level() {
        let r = BenchReport::new("demo").field("ns_per_step", num(42.0));
        let doc = r.to_json();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(BENCH_SCHEMA));
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("demo"));
        assert_eq!(doc.get("ns_per_step").unwrap().as_f64(), Some(42.0));
        validate_bench_doc(&doc).unwrap();
    }

    #[test]
    #[should_panic(expected = "reserved envelope key")]
    fn reserved_keys_rejected() {
        let _ = BenchReport::new("demo").field("schema", num(1.0));
    }

    #[test]
    fn validate_rejects_unstamped_docs() {
        assert!(validate_bench_doc(&obj(vec![("x", num(1.0))])).is_err());
        assert!(validate_bench_doc(&obj(vec![("schema", s("other/v9")), ("bench", s("x"))])).is_err());
        assert!(validate_bench_doc(&obj(vec![("schema", s(BENCH_SCHEMA))])).is_err());
    }

    #[test]
    fn trajectory_folds_all_bench_docs() {
        let dir = tempdir("fold");
        BenchReport::new("alpha").field("v", num(1.0)).write(&dir).unwrap();
        BenchReport::new("beta").field("v", num(2.0)).write(&dir).unwrap();
        let path = write_trajectory(&dir).unwrap();
        assert_eq!(path.file_name().unwrap(), "BENCH_trajectory.json");
        let doc = parse(&fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(TRAJECTORY_SCHEMA));
        let benches = doc.get("benches").unwrap();
        assert_eq!(benches.get("alpha").unwrap().get("v").unwrap().as_f64(), Some(1.0));
        assert_eq!(benches.get("beta").unwrap().get("v").unwrap().as_f64(), Some(2.0));
        // refolding must not ingest the trajectory file itself
        let again = fold_trajectory(&dir).unwrap();
        assert_eq!(again.get("benches").unwrap().as_obj().unwrap().len(), 2);
    }

    #[test]
    fn trajectory_of_empty_dir_errors() {
        let dir = tempdir("empty");
        assert!(fold_trajectory(&dir).is_err());
    }
}
