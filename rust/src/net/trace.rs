//! Bursty wireless availability traces (Gilbert–Elliott model).
//!
//! The paper motivates MAR-FL with wireless deployments where "devices
//! join and leave unpredictably" — availability is *bursty* (fading,
//! mobility), not i.i.d. per iteration. This two-state Markov model gives
//! each peer an Up/Down chain:
//!
//! ```text
//!   P(Up -> Down) = p_down        mean Up sojourn  = 1/p_down iterations
//!   P(Down -> Up) = p_up          mean Down sojourn = 1/p_up
//!   stationary availability      = p_up / (p_up + p_down)
//! ```
//!
//! Selected via `churn.model = "markov"`; the Bernoulli model
//! (`net::churn`) remains the paper's §3.1 configuration.

use crate::rng::Rng;

/// Per-peer two-state availability chains.
#[derive(Clone, Debug)]
pub struct MarkovChurn {
    up: Vec<bool>,
    /// P(Up -> Down) per iteration
    pub p_down: f64,
    /// P(Down -> Up) per iteration
    pub p_up: f64,
    /// times the never-empty guard resurrected a random peer
    revivals: u64,
}

impl MarkovChurn {
    /// Start every chain from its stationary distribution.
    pub fn new(n: usize, p_down: f64, p_up: f64, rng: &mut Rng) -> Self {
        assert!((0.0..=1.0).contains(&p_down) && (0.0..=1.0).contains(&p_up));
        assert!(p_up > 0.0, "peers must be able to return");
        let stationary = p_up / (p_up + p_down);
        let up = (0..n).map(|_| rng.chance(stationary)).collect();
        MarkovChurn { up, p_down, p_up, revivals: 0 }
    }

    /// Long-run fraction of available peers.
    pub fn stationary_availability(&self) -> f64 {
        self.p_up / (self.p_up + self.p_down)
    }

    /// Advance every chain one FL iteration; returns the available set
    /// (sorted peer indices). Guarantees at least one peer (a fully-down
    /// network would stall the dispatcher; the paper's simulator skips
    /// such iterations, we resurrect a random peer instead).
    pub fn step(&mut self, rng: &mut Rng) -> Vec<usize> {
        for state in self.up.iter_mut() {
            *state = if *state {
                !rng.chance(self.p_down)
            } else {
                rng.chance(self.p_up)
            };
        }
        let mut avail: Vec<usize> = self
            .up
            .iter()
            .enumerate()
            .filter_map(|(i, &u)| u.then_some(i))
            .collect();
        if avail.is_empty() {
            let lucky = rng.below(self.up.len());
            self.up[lucky] = true;
            self.revivals += 1;
            avail.push(lucky);
        }
        avail
    }

    pub fn is_up(&self, peer: usize) -> bool {
        self.up[peer]
    }

    /// Force a peer's chain Down (a mid-exchange crash observed by the
    /// fault model); it rejoins through the normal `p_up` transition.
    pub fn set_down(&mut self, peer: usize) {
        self.up[peer] = false;
    }

    /// How many times the never-empty guard silently resurrected a peer.
    pub fn revivals(&self) -> u64 {
        self.revivals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stationary_fraction_matches_theory() {
        let mut rng = Rng::new(70);
        // availability = 0.8/(0.8+0.2) = 0.8
        let mut chain = MarkovChurn::new(200, 0.2, 0.8, &mut rng);
        let mut total = 0usize;
        let iters = 500;
        for _ in 0..iters {
            total += chain.step(&mut rng).len();
        }
        let frac = total as f64 / (200.0 * iters as f64);
        assert!(
            (frac - 0.8).abs() < 0.03,
            "measured availability {frac} vs stationary 0.8"
        );
    }

    #[test]
    fn sojourns_are_bursty_not_iid() {
        // with p_down = 0.05, mean Up run length should be ~20 iterations
        // — far longer than the ~1/(1-0.8)=5 of an i.i.d. 80% model
        let mut rng = Rng::new(71);
        // 10 chains so the never-empty resurrection guard (which would
        // distort a single-peer trace) practically never fires for peer 0
        let mut chain = MarkovChurn::new(10, 0.05, 0.2, &mut rng);
        let mut runs = Vec::new();
        let mut current = 0usize;
        for _ in 0..20_000 {
            chain.step(&mut rng);
            let up = chain.is_up(0);
            if up {
                current += 1;
            } else if current > 0 {
                runs.push(current);
                current = 0;
            }
        }
        let mean_run = runs.iter().sum::<usize>() as f64 / runs.len() as f64;
        assert!(
            (mean_run - 20.0).abs() < 4.0,
            "mean Up sojourn {mean_run} vs theoretical 20"
        );
    }

    #[test]
    fn never_returns_empty_set() {
        let mut rng = Rng::new(72);
        // pathological: peers almost never up
        let mut chain = MarkovChurn::new(5, 0.99, 0.01, &mut rng);
        for _ in 0..200 {
            assert!(!chain.step(&mut rng).is_empty());
        }
        // the guard must have fired — and been counted — at least once
        assert!(chain.revivals() > 0);
    }

    #[test]
    fn set_down_takes_a_peer_offline() {
        let mut rng = Rng::new(74);
        let mut chain = MarkovChurn::new(4, 0.0, 1.0, &mut rng);
        chain.set_down(2);
        assert!(!chain.is_up(2));
        // p_up = 1.0: rejoins on the next step
        assert!(chain.step(&mut rng).contains(&2));
    }

    #[test]
    fn available_sets_sorted_and_in_range() {
        let mut rng = Rng::new(73);
        let mut chain = MarkovChurn::new(50, 0.3, 0.5, &mut rng);
        for _ in 0..50 {
            let a = chain.step(&mut rng);
            assert!(a.windows(2).all(|w| w[0] < w[1]));
            assert!(a.iter().all(|&i| i < 50));
        }
    }
}
