//! Deterministic observability layer: typed metric registry, round-event
//! tracing, and the shared bench-report schema.
//!
//! Three pieces, one contract:
//!
//! * [`MetricRegistry`] — typed [`Counter`] / [`Gauge`] / [`Histogram`]
//!   handles, resolved **once** at registration so hot paths never format
//!   a key string. Counters are sharded per thread exactly like
//!   `CommLedger` (cache-line-padded atomic stripes merged at read), so
//!   parallel lanes book without bouncing a contended line and serial ≡
//!   parallel totals exactly (commutative addition).
//! * [`RoundTrace`] (see [`trace`]) — the per-iteration event timeline,
//!   keyed to `SimClock` simulated seconds, recorded only in serial
//!   schedule phases or folded in deterministic group order.
//! * [`BenchReport`] (see [`report`]) — the schema-versioned JSON
//!   envelope every bench emits through, plus the trajectory folder.
//!
//! Nothing in this module touches an RNG, the `SimClock`, the ledger, or
//! model state: telemetry-off runs are bit-identical to telemetry-on runs
//! by construction, and the registry itself is always cheap enough to
//! leave on (see the micro_hotpath telemetry-overhead ablation).

pub mod report;
pub mod trace;

pub use report::{fold_trajectory, write_trajectory, BenchReport, BENCH_SCHEMA, TRAJECTORY_SCHEMA};
pub use trace::{trace_handle, EventKind, RoundTrace, TraceEvent, TraceHandle, TRACE_SCHEMA};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::net::faults::FaultCounters;

/// Counter stripe count — same sizing rationale as `CommLedger`: a power
/// of two a little above typical core counts, indexed by the pool's
/// stable per-thread stripe id.
const METRIC_STRIPES: usize = 16;

/// One cache-line-aligned counter stripe.
#[derive(Default)]
#[repr(align(64))]
struct PaddedCell(AtomicU64);

fn stripe_index() -> usize {
    crate::exec::thread_stripe(METRIC_STRIPES)
}

// ---------------------------------------------------------------------------
// Counter
// ---------------------------------------------------------------------------

#[derive(Default)]
struct CounterCore {
    stripes: [PaddedCell; METRIC_STRIPES],
}

/// Monotonic `u64` counter. Handles are cheap to clone (an `Arc`); the
/// hot path is one relaxed `fetch_add` on a thread-private stripe.
#[derive(Clone, Default)]
pub struct Counter(Arc<CounterCore>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if n != 0 {
            self.0.stripes[stripe_index()].0.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Merged total across all stripes.
    pub fn get(&self) -> u64 {
        self.0.stripes.iter().map(|c| c.0.load(Ordering::Relaxed)).sum()
    }
}

impl std::fmt::Debug for Counter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Counter").field(&self.get()).finish()
    }
}

// ---------------------------------------------------------------------------
// Gauge
// ---------------------------------------------------------------------------

/// Last-value `f64` gauge (bit-stored in an `AtomicU64`). `set` is a
/// plain store; `add` is a CAS loop — gauges are written from serial
/// phases (clock spans, end-of-run scorecards), never from lanes.
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, dv: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + dv).to_bits();
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

impl std::fmt::Debug for Gauge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Gauge").field(&self.get()).finish()
    }
}

// ---------------------------------------------------------------------------
// Histogram
// ---------------------------------------------------------------------------

/// Power-of-two bucket count: bucket `b` holds samples in
/// `[2^(b-1), 2^b)` (bucket 0 holds zero), covering the full `u64` range.
const HIST_BUCKETS: usize = 65;

/// One stripe of histogram state. Buckets within a stripe share lines,
/// but stripes never share with each other — the same contention story
/// as the counters, just wider.
#[repr(align(64))]
struct HistStripe {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for HistStripe {
    fn default() -> Self {
        HistStripe {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

#[derive(Default)]
struct HistogramCore {
    stripes: [HistStripe; METRIC_STRIPES],
}

/// Log₂-bucketed `u64` histogram (latency ticks, retry counts, payload
/// sizes). Exact `count`/`sum`, bucketed distribution.
#[derive(Clone, Default)]
pub struct Histogram(Arc<HistogramCore>);

/// Merged histogram state at one point in time.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    /// `buckets[b]` counts samples in `[2^(b-1), 2^b)`; `buckets[0]` counts zeros.
    pub buckets: Vec<u64>,
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        let stripe = &self.0.stripes[stripe_index()];
        stripe.count.fetch_add(1, Ordering::Relaxed);
        stripe.sum.fetch_add(v, Ordering::Relaxed);
        let b = (64 - v.leading_zeros()) as usize;
        stripe.buckets[b].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let mut s = HistSnapshot { count: 0, sum: 0, buckets: vec![0; HIST_BUCKETS] };
        for stripe in &self.0.stripes {
            s.count += stripe.count.load(Ordering::Relaxed);
            s.sum += stripe.sum.load(Ordering::Relaxed);
            for (acc, b) in s.buckets.iter_mut().zip(&stripe.buckets) {
                *acc += b.load(Ordering::Relaxed);
            }
        }
        s
    }

    /// Arithmetic mean of observed samples (0 if empty).
    pub fn mean(&self) -> f64 {
        let s = self.snapshot();
        if s.count == 0 {
            0.0
        } else {
            s.sum as f64 / s.count as f64
        }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        f.debug_struct("Histogram").field("count", &s.count).field("sum", &s.sum).finish()
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// A registered metric handle, any kind.
#[derive(Clone, Debug)]
pub enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// Point-in-time value of one metric.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Histogram(HistSnapshot),
}

/// The typed metric registry. Names resolve to handles **once** at
/// registration; after that the map is never touched on a hot path.
/// Registering the same name twice is an error — handles are meant to be
/// created at construction and threaded by value, not re-looked-up.
#[derive(Default)]
pub struct MetricRegistry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl MetricRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a fresh counter under `name`. Errors if `name` exists.
    pub fn counter(&self, name: &str) -> Result<Counter> {
        let c = Counter::default();
        self.insert(name, Metric::Counter(c.clone()))?;
        Ok(c)
    }

    /// Register a fresh gauge under `name`. Errors if `name` exists.
    pub fn gauge(&self, name: &str) -> Result<Gauge> {
        let g = Gauge::default();
        self.insert(name, Metric::Gauge(g.clone()))?;
        Ok(g)
    }

    /// Register a fresh histogram under `name`. Errors if `name` exists.
    pub fn histogram(&self, name: &str) -> Result<Histogram> {
        let h = Histogram::default();
        self.insert(name, Metric::Histogram(h.clone()))?;
        Ok(h)
    }

    /// Get-or-register a counter — the cold-path fallback for callers
    /// that only know the name at call time (e.g. ad-hoc models outside
    /// the artifact registry). Errors if `name` is registered as a
    /// different kind.
    pub fn counter_or_existing(&self, name: &str) -> Result<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m.get(name) {
            Some(Metric::Counter(c)) => Ok(c.clone()),
            Some(_) => bail!("metric {name:?} already registered as a non-counter"),
            None => {
                let c = Counter::default();
                m.insert(name.to_string(), Metric::Counter(c.clone()));
                Ok(c)
            }
        }
    }

    fn insert(&self, name: &str, metric: Metric) -> Result<()> {
        let mut m = self.metrics.lock().unwrap();
        if m.contains_key(name) {
            bail!("metric {name:?} already registered");
        }
        m.insert(name.to_string(), metric);
        Ok(())
    }

    /// Look up an existing handle by name (registration-time use only).
    pub fn get(&self, name: &str) -> Option<Metric> {
        self.metrics.lock().unwrap().get(name).cloned()
    }

    /// Current value of a counter (0 if absent — absent and never-bumped
    /// are indistinguishable by design).
    pub fn counter_value(&self, name: &str) -> u64 {
        match self.get(name) {
            Some(Metric::Counter(c)) => c.get(),
            _ => 0,
        }
    }

    /// Current value of a gauge, if registered.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        match self.get(name) {
            Some(Metric::Gauge(g)) => Some(g.get()),
            _ => None,
        }
    }

    /// Merged point-in-time view of every registered metric, in name
    /// order (BTreeMap — deterministic iteration).
    pub fn snapshot(&self) -> BTreeMap<String, MetricValue> {
        self.metrics
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| {
                let val = match v {
                    Metric::Counter(c) => MetricValue::Counter(c.get()),
                    Metric::Gauge(g) => MetricValue::Gauge(g.get()),
                    Metric::Histogram(h) => MetricValue::Histogram(h.snapshot()),
                };
                (k.clone(), val)
            })
            .collect()
    }
}

impl std::fmt::Debug for MetricRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricRegistry").field("metrics", &self.snapshot()).finish()
    }
}

// ---------------------------------------------------------------------------
// Trainer metric set + scorecard views
// ---------------------------------------------------------------------------

/// Reliability scorecard: churn/reduce-scatter recovery counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReliabilityScorecard {
    /// Owner-drop fallbacks: RS groups that fell back to full-gather.
    pub rs_fallbacks: u64,
    /// RS retries that succeeded within the retry budget.
    pub rs_retries: u64,
    /// Crash rejoins served by a state pull from a live peer.
    pub rejoin_pulls: u64,
    /// Groups re-formed after a member churned out mid-matchmaking.
    pub churn_rescues: u64,
    /// Markov-churn peers revived by the Gilbert–Elliott good transition.
    pub markov_revivals: u64,
}

/// Fault scorecard: link-level loss/retry/crash counters plus the
/// straggler and bandwidth observations. Field names mirror
/// [`FaultCounters`] one-for-one so bench CSV columns stay stable.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultScorecard {
    pub msgs_lost: u64,
    pub retries: u64,
    pub timeouts: u64,
    pub quorum_degraded_rounds: u64,
    pub crashes: u64,
    pub ge_bad_transitions: u64,
    pub bursty_losses: u64,
    /// Simulated seconds of straggler tail exposed on the critical path.
    pub straggler_exposed_s: f64,
    /// Heterogeneous-bandwidth redraws applied over the run.
    pub bw_redraws: u64,
    /// p10/p50/p90 of drawn link bandwidths (present when links are on).
    pub bw_percentiles: Option<[f64; 3]>,
}

impl FaultScorecard {
    /// True when any fault *counter* fired (the straggler/bandwidth
    /// observations are not faults).
    pub fn any(&self) -> bool {
        self.counters().any()
    }

    /// The link-fault counters as the wire-level [`FaultCounters`] type.
    pub fn counters(&self) -> FaultCounters {
        FaultCounters {
            msgs_lost: self.msgs_lost,
            retries: self.retries,
            timeouts: self.timeouts,
            quorum_degraded_rounds: self.quorum_degraded_rounds,
            crashes: self.crashes,
            ge_bad_transitions: self.ge_bad_transitions,
            bursty_losses: self.bursty_losses,
        }
    }
}

/// Byzantine scorecard: attack pressure and defense quality.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ByzantineScorecard {
    pub attackers_active: u64,
    pub flagged_peers: u64,
    pub flag_precision: f64,
    pub flag_recall: f64,
    pub paroles_granted: u64,
    pub reban_count: u64,
}

/// Differential-privacy scorecard.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DpScorecard {
    /// Spent privacy budget (None when DP is off).
    pub epsilon: Option<f64>,
}

/// Every handle the trainer books through, resolved once at
/// construction. This is the single home for the counters that were
/// previously hand-threaded as flat `Trainer` fields; the `RunSummary`
/// scorecards are views over these handles.
#[derive(Clone, Debug)]
pub struct TrainerMetrics {
    // reliability
    pub rs_fallbacks: Counter,
    pub rs_retries: Counter,
    pub rejoin_pulls: Counter,
    pub churn_rescues: Counter,
    pub markov_revivals: Counter,
    // faults
    pub msgs_lost: Counter,
    pub retries: Counter,
    pub timeouts: Counter,
    pub quorum_degraded_rounds: Counter,
    pub crashes: Counter,
    pub ge_bad_transitions: Counter,
    pub bursty_losses: Counter,
    pub bw_redraws: Counter,
    pub straggler_exposed_s: Gauge,
    // byzantine
    pub attackers_active: Counter,
    pub flagged_peers: Counter,
    pub paroles_granted: Counter,
    pub reban_count: Counter,
    pub flag_precision: Gauge,
    pub flag_recall: Gauge,
}

impl TrainerMetrics {
    /// Register the full trainer metric set under the `fl.*` namespace.
    /// Errors if any name is taken (one trainer per registry).
    pub fn register(reg: &MetricRegistry) -> Result<Self> {
        Ok(TrainerMetrics {
            rs_fallbacks: reg.counter("fl.reliability.rs_fallbacks")?,
            rs_retries: reg.counter("fl.reliability.rs_retries")?,
            rejoin_pulls: reg.counter("fl.reliability.rejoin_pulls")?,
            churn_rescues: reg.counter("fl.reliability.churn_rescues")?,
            markov_revivals: reg.counter("fl.reliability.markov_revivals")?,
            msgs_lost: reg.counter("fl.faults.msgs_lost")?,
            retries: reg.counter("fl.faults.retries")?,
            timeouts: reg.counter("fl.faults.timeouts")?,
            quorum_degraded_rounds: reg.counter("fl.faults.quorum_degraded_rounds")?,
            crashes: reg.counter("fl.faults.crashes")?,
            ge_bad_transitions: reg.counter("fl.faults.ge_bad_transitions")?,
            bursty_losses: reg.counter("fl.faults.bursty_losses")?,
            bw_redraws: reg.counter("fl.faults.bw_redraws")?,
            straggler_exposed_s: reg.gauge("fl.faults.straggler_exposed_s")?,
            attackers_active: reg.counter("fl.byzantine.attackers_active")?,
            flagged_peers: reg.counter("fl.byzantine.flagged_peers")?,
            paroles_granted: reg.counter("fl.byzantine.paroles_granted")?,
            reban_count: reg.counter("fl.byzantine.reban_count")?,
            flag_precision: reg.gauge("fl.byzantine.flag_precision")?,
            flag_recall: reg.gauge("fl.byzantine.flag_recall")?,
        })
    }

    /// Fold one iteration's wire-level fault counters into the registry.
    pub fn add_faults(&self, fc: &FaultCounters) {
        self.msgs_lost.add(fc.msgs_lost);
        self.retries.add(fc.retries);
        self.timeouts.add(fc.timeouts);
        self.quorum_degraded_rounds.add(fc.quorum_degraded_rounds);
        self.crashes.add(fc.crashes);
        self.ge_bad_transitions.add(fc.ge_bad_transitions);
        self.bursty_losses.add(fc.bursty_losses);
    }

    pub fn reliability(&self) -> ReliabilityScorecard {
        ReliabilityScorecard {
            rs_fallbacks: self.rs_fallbacks.get(),
            rs_retries: self.rs_retries.get(),
            rejoin_pulls: self.rejoin_pulls.get(),
            churn_rescues: self.churn_rescues.get(),
            markov_revivals: self.markov_revivals.get(),
        }
    }

    /// Fault scorecard view; `bw_percentiles` is passed by the trainer
    /// because it only exists when a link table is configured.
    pub fn faults(&self, bw_percentiles: Option<[f64; 3]>) -> FaultScorecard {
        FaultScorecard {
            msgs_lost: self.msgs_lost.get(),
            retries: self.retries.get(),
            timeouts: self.timeouts.get(),
            quorum_degraded_rounds: self.quorum_degraded_rounds.get(),
            crashes: self.crashes.get(),
            ge_bad_transitions: self.ge_bad_transitions.get(),
            bursty_losses: self.bursty_losses.get(),
            straggler_exposed_s: self.straggler_exposed_s.get(),
            bw_redraws: self.bw_redraws.get(),
            bw_percentiles,
        }
    }

    pub fn byzantine(&self) -> ByzantineScorecard {
        ByzantineScorecard {
            attackers_active: self.attackers_active.get(),
            flagged_peers: self.flagged_peers.get(),
            flag_precision: self.flag_precision.get(),
            flag_recall: self.flag_recall.get(),
            paroles_granted: self.paroles_granted.get(),
            reban_count: self.reban_count.get(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts_across_threads_exactly() {
        let reg = MetricRegistry::new();
        let c = reg.counter("t.hits").unwrap();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
        assert_eq!(reg.counter_value("t.hits"), 4000);
    }

    #[test]
    fn reregistration_is_rejected() {
        let reg = MetricRegistry::new();
        reg.counter("x").unwrap();
        assert!(reg.counter("x").is_err());
        assert!(reg.gauge("x").is_err());
        assert!(reg.histogram("x").is_err());
    }

    #[test]
    fn counter_or_existing_returns_same_slot() {
        let reg = MetricRegistry::new();
        let a = reg.counter_or_existing("adhoc").unwrap();
        let b = reg.counter_or_existing("adhoc").unwrap();
        a.add(3);
        b.add(4);
        assert_eq!(reg.counter_value("adhoc"), 7);
        reg.gauge("g").unwrap();
        assert!(reg.counter_or_existing("g").is_err());
    }

    #[test]
    fn gauge_set_add_get() {
        let reg = MetricRegistry::new();
        let g = reg.gauge("t.g").unwrap();
        g.set(1.5);
        g.add(0.25);
        assert_eq!(g.get(), 1.75);
        assert_eq!(reg.gauge_value("t.g"), Some(1.75));
        assert_eq!(reg.gauge_value("missing"), None);
    }

    #[test]
    fn histogram_buckets_and_mean() {
        let h = Histogram::default();
        h.observe(0);
        h.observe(1);
        h.observe(2);
        h.observe(3);
        h.observe(1024);
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1030);
        assert_eq!(s.buckets[0], 1); // zero
        assert_eq!(s.buckets[1], 1); // 1
        assert_eq!(s.buckets[2], 2); // 2..4
        assert_eq!(s.buckets[11], 1); // 1024..2048
        assert_eq!(h.mean(), 206.0);
    }

    #[test]
    fn snapshot_is_name_ordered() {
        let reg = MetricRegistry::new();
        reg.counter("b").unwrap();
        reg.counter("a").unwrap();
        let names: Vec<_> = reg.snapshot().into_keys().collect();
        assert_eq!(names, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn fault_scorecard_round_trips_counters() {
        let fc = FaultCounters {
            msgs_lost: 1,
            retries: 2,
            timeouts: 3,
            quorum_degraded_rounds: 4,
            crashes: 5,
            ge_bad_transitions: 6,
            bursty_losses: 7,
        };
        let reg = MetricRegistry::new();
        let tm = TrainerMetrics::register(&reg).unwrap();
        tm.add_faults(&fc);
        let sc = tm.faults(None);
        assert_eq!(sc.counters(), fc);
        assert!(sc.any());
        assert!(!FaultScorecard::default().any());
    }
}
