//! Figure 9 extension — Byzantine resilience of robust group
//! aggregation, now as an arms race.
//!
//! Two arms share one harness:
//!
//! * **static** — per-iteration sign-flip over the attacker fraction
//!   {0, 0.1, 0.2, 0.3} across all six group-center estimators
//!   (aggregation::robust): the bit-exact legacy `mean` (no defence),
//!   coordinate-wise `trimmed_mean` and `median`, `norm_clip`, and the
//!   selection pair `krum` / `multi_krum`. Robust estimators also run
//!   reputation-gated matchmaking (coordinator::mar).
//! * **adaptive** — `adaptive_scale` attackers (attack::AttackPlan)
//!   that read their own outlier ratio from the previous round's
//!   reputation ledger and dial the corruption to sit just under the
//!   ban threshold, against `mean`, `trimmed_mean` and `multi_krum`
//!   with the forgiving reputation armed (`rep_decay`, `parole_rounds`)
//!   — bans expire into parole, flipped parolees are re-banned, and the
//!   `paroles_granted` / `reban_count` columns quantify the cycle.
//!
//! Emits `fig9_byzantine.csv` and `BENCH_byz.json`. The shape gates
//! encode the robustness claims: at 30% static sign-flip the
//! trimmed-mean + reputation run keeps its final loss within 2x the
//! attack-free run while the plain mean ends up measurably worse; at
//! 20% adaptive attackers Multi-Krum + parole stays within 2x clean
//! (with paroles actually granted and flag precision no worse than the
//! static baseline) while trimmed-mean-only degrades below it.
//! `MARFL_BENCH_FULL=1` lengthens the sweep; `MARFL_BENCH_NO_ASSERT=1`
//! records results without enforcing the gates.

#[path = "common/mod.rs"]
mod common;

use common::{assert_stable_columns, emit_csv, iters, mib, results_dir, runtime, timed};
use marfl::aggregation::robust::RobustEstimator;
use marfl::attack::{AttackConfig, AttackMode};
use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;
use marfl::telemetry::BenchReport;
use marfl::util::json::{arr, num, obj, s};

/// EWMA reputation ban threshold used by every defended cell.
const REP: f64 = 0.4;
/// Forgiveness knobs for the adaptive arm's defended cells: scores
/// drift back toward neutral and bans expire into parole.
const REP_DECAY: f64 = 0.05;
const PAROLE_ROUNDS: u64 = 2;

fn attack_plan(frac: f64, mode: AttackMode, est: RobustEstimator) -> AttackConfig {
    // plain mean is the undefended baseline; every robust estimator
    // also gets reputation-gated matchmaking. Attack-free rows run
    // without reputation so the mean cell stays on the bit-exact
    // legacy path and the zero-counter gate below is meaningful.
    let defended = est != RobustEstimator::Mean && frac > 0.0;
    let adaptive = mode == AttackMode::AdaptiveScale;
    AttackConfig {
        frac,
        mode,
        scale: 1.0,
        robust: est,
        trim: 0.25,
        rep_threshold: if defended { REP } else { 0.0 },
        rep_decay: if defended && adaptive { REP_DECAY } else { 0.0 },
        parole_rounds: if defended && adaptive { PAROLE_ROUNDS } else { 0 },
        ..AttackConfig::default()
    }
}

/// Per-cell results kept around for the shape gates.
struct Cell {
    loss: f64,
    precision: f64,
    paroles: u64,
}

fn main() {
    let peers = 16; // 4^2 MAR grid; 30% -> 5 ground-truth attackers
    let t = iters(10, 30);
    println!(
        "Byzantine arms race — attacker mode x fraction x estimator \
         (peers={peers}, T={t})\n"
    );
    let rt = runtime();
    let base = ExperimentConfig {
        model: "head".into(),
        peers,
        group_size: 4,
        mar_rounds: 2, // 16 = 4^2
        iterations: t,
        samples_per_peer: 32,
        test_samples: 1000,
        eval_every: t,
        seed: 20261,
        ..Default::default()
    };

    // (mode, estimators, fractions): the static arm sweeps every
    // estimator from the clean baseline up; the adaptive arm skips
    // frac=0 (identical to clean by the zero-draw contract) and pits
    // the threshold-probing attacker against the undefended mean, the
    // coordinate-wise trimmed mean, and Multi-Krum + parole.
    let arms: [(AttackMode, &[RobustEstimator], &[f64]); 2] = [
        (
            AttackMode::SignFlip,
            &[
                RobustEstimator::Mean,
                RobustEstimator::TrimmedMean,
                RobustEstimator::Median,
                RobustEstimator::NormClip,
                RobustEstimator::Krum,
                RobustEstimator::MultiKrum,
            ],
            &[0.0f64, 0.1, 0.2, 0.3],
        ),
        (
            AttackMode::AdaptiveScale,
            &[
                RobustEstimator::Mean,
                RobustEstimator::TrimmedMean,
                RobustEstimator::MultiKrum,
            ],
            &[0.1, 0.2, 0.3],
        ),
    ];

    let mut rows = vec![vec![
        "mode".into(),
        "estimator".into(),
        "frac".into(),
        "rep_threshold".into(),
        "rep_decay".into(),
        "parole_rounds".into(),
        "attackers_active".into(),
        "flagged_peers".into(),
        "flag_precision".into(),
        "flag_recall".into(),
        "paroles_granted".into(),
        "reban_count".into(),
        "data_mib".into(),
        "final_accuracy".into(),
        "final_loss".into(),
        "loss_ratio".into(),
    ]];
    let mut json_rows = Vec::new();
    // (mode, estimator, frac*10) -> gate-relevant results
    let mut cells = std::collections::BTreeMap::new();
    let mut clean_loss = f64::NAN;

    for &(mode, estimators, fracs) in &arms {
        for &est in estimators {
            for &frac in fracs {
                let atk = attack_plan(frac, mode, est);
                let label =
                    format!("{} {} frac={frac}", mode.name(), est.name());
                let cfg =
                    ExperimentConfig { attack: atk.clone(), ..base.clone() };
                let run = timed(&label, || {
                    Trainer::new(cfg, &rt).unwrap().run().unwrap()
                });
                if est == RobustEstimator::Mean && frac == 0.0 {
                    clean_loss = run.final_loss;
                }
                let ratio = run.final_loss / clean_loss;
                println!(
                    "    acc {:.3}  loss {:.3} ({ratio:.2}x clean)  \
                     attackers {}  flagged {} (P {:.2} R {:.2})  \
                     paroles {}  rebans {}",
                    run.final_accuracy,
                    run.final_loss,
                    run.byzantine.attackers_active,
                    run.byzantine.flagged_peers,
                    run.byzantine.flag_precision,
                    run.byzantine.flag_recall,
                    run.byzantine.paroles_granted,
                    run.byzantine.reban_count
                );
                rows.push(vec![
                    mode.name().into(),
                    est.name().into(),
                    frac.to_string(),
                    atk.rep_threshold.to_string(),
                    atk.rep_decay.to_string(),
                    atk.parole_rounds.to_string(),
                    run.byzantine.attackers_active.to_string(),
                    run.byzantine.flagged_peers.to_string(),
                    format!("{:.4}", run.byzantine.flag_precision),
                    format!("{:.4}", run.byzantine.flag_recall),
                    run.byzantine.paroles_granted.to_string(),
                    run.byzantine.reban_count.to_string(),
                    format!("{:.3}", mib(run.comm.data_bytes)),
                    format!("{:.4}", run.final_accuracy),
                    format!("{:.4}", run.final_loss),
                    format!("{ratio:.4}"),
                ]);
                json_rows.push(obj(vec![
                    ("mode", s(mode.name())),
                    ("estimator", s(est.name())),
                    ("frac", num(frac)),
                    ("rep_threshold", num(atk.rep_threshold)),
                    ("rep_decay", num(atk.rep_decay)),
                    ("parole_rounds", num(atk.parole_rounds as f64)),
                    ("attackers_active", num(run.byzantine.attackers_active as f64)),
                    ("flagged_peers", num(run.byzantine.flagged_peers as f64)),
                    ("flag_precision", num(run.byzantine.flag_precision)),
                    ("flag_recall", num(run.byzantine.flag_recall)),
                    ("paroles_granted", num(run.byzantine.paroles_granted as f64)),
                    ("reban_count", num(run.byzantine.reban_count as f64)),
                    ("data_bytes", num(run.comm.data_bytes as f64)),
                    ("final_accuracy", num(run.final_accuracy)),
                    ("final_loss", num(run.final_loss)),
                    ("loss_ratio", num(ratio)),
                ]));
                // attack-off rows must be indistinguishable from the
                // seed: no ground-truth attackers, nothing flagged. This
                // is the zero-overhead contract CI pins at fixed seeds.
                if frac == 0.0 {
                    assert_eq!(
                        run.byzantine.attackers_active, 0,
                        "attack-off row recorded attackers ({label})"
                    );
                    assert_eq!(
                        run.byzantine.flagged_peers, 0,
                        "attack-off row flagged peers ({label})"
                    );
                    assert_eq!(
                        run.byzantine.paroles_granted, 0,
                        "attack-off row granted paroles ({label})"
                    );
                } else {
                    assert!(
                        run.byzantine.attackers_active > 0,
                        "attacked row recorded no active attackers ({label})"
                    );
                }
                cells.insert(
                    (mode.name(), est.name(), (frac * 10.0).round() as u32),
                    Cell {
                        loss: run.final_loss,
                        precision: run.byzantine.flag_precision,
                        paroles: run.byzantine.paroles_granted,
                    },
                );
            }
        }
    }
    assert_stable_columns(
        "fig9_byzantine.csv",
        &rows,
        &[
            "mode",
            "estimator",
            "frac",
            "rep_threshold",
            "rep_decay",
            "parole_rounds",
            "attackers_active",
            "flagged_peers",
            "flag_precision",
            "flag_recall",
            "paroles_granted",
            "reban_count",
            "data_mib",
            "final_accuracy",
            "final_loss",
            "loss_ratio",
        ],
    );
    emit_csv("fig9_byzantine.csv", &rows);

    let path = BenchReport::new("byz")
        .field("peers", num(peers as f64))
        .field("iterations", num(t as f64))
        .field("modes", arr(vec![s("sign_flip"), s("adaptive_scale")]))
        .field("rep_threshold", num(REP))
        .field("rep_decay", num(REP_DECAY))
        .field("parole_rounds", num(PAROLE_ROUNDS as f64))
        .field("results", arr(json_rows))
        .write(&results_dir())
        .expect("write BENCH_byz.json");
    println!("  -> {}", path.display());

    // ---- paper-shape assertions ------------------------------------
    // Static arm: at 30% sign-flip the defended run (trimmed mean +
    // reputation) must stay within 2x the attack-free loss, and the
    // undefended plain mean must end up strictly worse than the
    // defended run — the distortion the robust path exists to remove.
    let mean_03 = cells[&("sign_flip", "mean", 3)].loss;
    let trimmed_03 = cells[&("sign_flip", "trimmed_mean", 3)].loss;
    println!(
        "\nstatic loss at frac=0.3: clean {clean_loss:.3} | trimmed+rep \
         {trimmed_03:.3} | plain mean {mean_03:.3}"
    );
    // Adaptive arm: at 20% threshold-probing attackers Multi-Krum +
    // parole must hold within 2x clean with paroles actually granted
    // and flag precision no worse than the static trimmed-mean
    // baseline, while the coordinate-wise trimmed mean — which the
    // dialed-down blend leaks through — lands strictly worse.
    let mk_02 = &cells[&("adaptive_scale", "multi_krum", 2)];
    let tm_02 = &cells[&("adaptive_scale", "trimmed_mean", 2)];
    let static_tm_02 = &cells[&("sign_flip", "trimmed_mean", 2)];
    println!(
        "adaptive loss at frac=0.2: multi_krum+parole {:.3} (P {:.2}, \
         paroles {}) | trimmed-only {:.3}",
        mk_02.loss, mk_02.precision, mk_02.paroles, tm_02.loss
    );
    if std::env::var("MARFL_BENCH_NO_ASSERT").is_err() {
        assert!(
            trimmed_03 <= 2.0 * clean_loss,
            "trimmed mean under 30% sign-flip must stay within 2x the \
             attack-free loss (got {trimmed_03:.4} vs clean {clean_loss:.4})"
        );
        assert!(
            mean_03 > trimmed_03,
            "plain mean under 30% sign-flip must be worse than the \
             defended trimmed mean (mean {mean_03:.4} vs trimmed \
             {trimmed_03:.4})"
        );
        assert!(
            mk_02.loss <= 2.0 * clean_loss,
            "multi-krum + parole under 20% adaptive attackers must stay \
             within 2x the attack-free loss (got {:.4} vs clean \
             {clean_loss:.4})",
            mk_02.loss
        );
        assert!(
            tm_02.loss > mk_02.loss,
            "trimmed-mean-only must degrade against adaptive attackers \
             relative to multi-krum + parole (trimmed {:.4} vs \
             multi-krum {:.4})",
            tm_02.loss,
            mk_02.loss
        );
        assert!(
            mk_02.paroles > 0,
            "the adaptive defended run must cycle bans through parole \
             (paroles_granted = 0)"
        );
        assert!(
            mk_02.precision >= static_tm_02.precision,
            "adaptive-arm flag precision ({:.4}) must not fall below \
             the static-attack baseline ({:.4})",
            mk_02.precision,
            static_tm_02.precision
        );
    }
}
