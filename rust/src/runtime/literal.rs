//! Literal marshalling helpers: Rust slices <-> XLA literals.

use anyhow::{Context, Result};
use xla::{ElementType, Literal};

/// Build an f32 literal of `dims` from a host slice (bytes are copied by
/// XLA; no lifetime coupling).
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let expected: usize = dims.iter().product();
    anyhow::ensure!(
        data.len() == expected,
        "lit_f32: {} values for dims {dims:?} (want {expected})",
        data.len()
    );
    // Safety: f32 slice reinterpreted as bytes; alignment of u8 is 1.
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)
        .context("create f32 literal")
}

/// Build an i32 literal of `dims` from a host slice.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let expected: usize = dims.iter().product();
    anyhow::ensure!(
        data.len() == expected,
        "lit_i32: {} values for dims {dims:?} (want {expected})",
        data.len()
    );
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)
        .context("create s32 literal")
}

/// Copy a literal back to a host `Vec<f32>`.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal to f32 vec")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_literal_round_trip() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0, 5.0, 6.5];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn i32_literal_round_trip() {
        let data = vec![1i32, -7, 300];
        let lit = lit_i32(&data, &[3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn dim_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1], &[2, 2]).is_err());
    }
}
