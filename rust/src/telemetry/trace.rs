//! Per-iteration round-event timeline.
//!
//! Every event is keyed to **simulated** seconds (`SimClock::now()`),
//! never wall clock, and is recorded either in a serial schedule phase
//! (plan drawing, matchmaking, reputation folds) or derived from values
//! that are themselves bit-identical between the serial and parallel
//! engines (clock spans, lane outcome counters). The trace is therefore
//! byte-for-byte identical under `MARFL_THREADS=1` and `MARFL_THREADS=4`
//! — that equality is pinned by `tests/telemetry.rs` and checked in CI.
//!
//! Wire format: JSON Lines. Line 1 is a header object carrying
//! [`TRACE_SCHEMA`]; every following line is one event object with an
//! `ev` discriminant plus `iter` / `t` (simulated seconds) keys. The
//! writer goes through `util::json`, whose object keys are BTreeMap-
//! sorted — serialization itself is deterministic.

use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::util::json::{num, obj, parse, s, Json};

/// Trace schema identifier, bumped on any wire-format change.
pub const TRACE_SCHEMA: &str = "marfl-trace/v1";

/// One discrete happening or span on the round timeline.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// An FL iteration begins with `participants` live peers.
    IterStart { participants: u64 },
    /// Parallel local-SGD span: `dt` simulated seconds on the critical
    /// path, of which `straggler_dt` is exposed straggler tail.
    LocalCompute { dt: f64, straggler_dt: f64 },
    /// DHT matchmaking for one group round: `control_s` of control-plane
    /// time, `hidden` when overlapped behind the previous exchange,
    /// producing `groups` groups.
    Matchmaking { round: u64, control_s: f64, hidden: bool, groups: u64 },
    /// One group round's exchange span, split into reduce-scatter and
    /// all-gather phase times (full-gather books everything into `rs_s`).
    Exchange { round: u64, groups: u64, rs_s: f64, ag_s: f64 },
    /// A group burned link-fault retries/timeouts this round.
    FaultRetries { round: u64, group: u64, retries: u64, timeouts: u64 },
    /// A group proceeded with a survivor quorum after losses.
    QuorumDegraded { round: u64, group: u64, lost: u64 },
    /// An RS group fell back to full-gather after its owner dropped.
    OwnerDropFallback { round: u64, group: u64 },
    /// An RS group succeeded within its retry budget.
    RsRetry { round: u64, group: u64 },
    /// A group lost quorum and aborted the round.
    GroupAbort { round: u64, group: u64, lost: u64 },
    /// A peer crashed mid-exchange.
    Crash { peer: u64 },
    /// A crashed peer rejoined by pulling state from a live peer.
    CrashRejoin { peer: u64 },
    /// Reputation ban crossed the threshold for `peer`.
    Ban { peer: u64 },
    /// A banned peer was paroled after its clean-decay window.
    Parole { peer: u64 },
    /// A paroled peer tripped the threshold again.
    Reban { peer: u64 },
    /// Group-KD distillation summary for the iteration.
    Mkd { rounds: u64, kd_steps: u64, teacher_transfers: u64, mean_loss: f64 },
    /// Periodic evaluation point.
    Eval { loss: f64, accuracy: f64 },
}

/// One timeline entry: which iteration, at what simulated time, what.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub iter: u64,
    /// Simulated seconds (`SimClock::now()` at record time).
    pub t: f64,
    pub kind: EventKind,
}

/// The recorded timeline. Shared as a [`TraceHandle`]; the mutex is only
/// ever locked from serial schedule phases, never from parallel lanes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundTrace {
    events: Vec<TraceEvent>,
}

/// Shared handle threaded through `Trainer` → `MarAggregator`.
pub type TraceHandle = Arc<Mutex<RoundTrace>>;

/// Fresh shared trace.
pub fn trace_handle() -> TraceHandle {
    Arc::new(Mutex::new(RoundTrace::default()))
}

impl RoundTrace {
    pub fn record(&mut self, iter: u64, t: f64, kind: EventKind) {
        self.events.push(TraceEvent { iter, t, kind });
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The document as JSON values: header line, then one per event.
    fn lines(&self) -> Vec<Json> {
        let mut lines = Vec::with_capacity(self.events.len() + 1);
        lines.push(obj(vec![
            ("schema", s(TRACE_SCHEMA)),
            ("events", num(self.events.len() as f64)),
        ]));
        lines.extend(self.events.iter().map(TraceEvent::to_json));
        lines
    }

    /// Serialize as JSONL: header line, then one line per event.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for line in self.lines() {
            out.push_str(&line.to_string());
            out.push('\n');
        }
        out
    }

    /// Write the JSONL document to `path` (creating parent dirs).
    pub fn write_jsonl(&self, path: &Path) -> Result<()> {
        crate::metrics::write_jsonl(path, &self.lines())
    }

    /// Parse and validate a JSONL trace document — the schema check used
    /// by `marfl trace-check` and the CI traced-run step. Rejects a
    /// missing/mismatched header, unknown event discriminants, and
    /// missing fields.
    pub fn parse_jsonl(text: &str) -> Result<RoundTrace> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().context("empty trace document")?;
        let header = parse(header).map_err(|e| anyhow::anyhow!("bad header: {e}"))?;
        match header.get("schema").and_then(|v| v.as_str()) {
            Some(TRACE_SCHEMA) => {}
            Some(other) => bail!("unsupported trace schema {other:?} (want {TRACE_SCHEMA})"),
            None => bail!("trace header missing \"schema\" key"),
        }
        let mut trace = RoundTrace::default();
        for (i, line) in lines.enumerate() {
            let v = parse(line).map_err(|e| anyhow::anyhow!("bad event on line {}: {e}", i + 2))?;
            trace.events.push(TraceEvent::from_json(&v).with_context(|| format!("line {}", i + 2))?);
        }
        if let Some(n) = header.get("events").and_then(|v| v.as_f64()) {
            if n as usize != trace.events.len() {
                bail!("header declares {} events, document has {}", n as usize, trace.events.len());
            }
        }
        Ok(trace)
    }
}

impl TraceEvent {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("iter", num(self.iter as f64)), ("t", num(self.t))];
        let ev = match &self.kind {
            EventKind::IterStart { participants } => {
                pairs.push(("participants", num(*participants as f64)));
                "iter_start"
            }
            EventKind::LocalCompute { dt, straggler_dt } => {
                pairs.push(("dt", num(*dt)));
                pairs.push(("straggler_dt", num(*straggler_dt)));
                "local_compute"
            }
            EventKind::Matchmaking { round, control_s, hidden, groups } => {
                pairs.push(("round", num(*round as f64)));
                pairs.push(("control_s", num(*control_s)));
                pairs.push(("hidden", Json::Bool(*hidden)));
                pairs.push(("groups", num(*groups as f64)));
                "matchmaking"
            }
            EventKind::Exchange { round, groups, rs_s, ag_s } => {
                pairs.push(("round", num(*round as f64)));
                pairs.push(("groups", num(*groups as f64)));
                pairs.push(("rs_s", num(*rs_s)));
                pairs.push(("ag_s", num(*ag_s)));
                "exchange"
            }
            EventKind::FaultRetries { round, group, retries, timeouts } => {
                pairs.push(("round", num(*round as f64)));
                pairs.push(("group", num(*group as f64)));
                pairs.push(("retries", num(*retries as f64)));
                pairs.push(("timeouts", num(*timeouts as f64)));
                "fault_retries"
            }
            EventKind::QuorumDegraded { round, group, lost } => {
                pairs.push(("round", num(*round as f64)));
                pairs.push(("group", num(*group as f64)));
                pairs.push(("lost", num(*lost as f64)));
                "quorum_degraded"
            }
            EventKind::OwnerDropFallback { round, group } => {
                pairs.push(("round", num(*round as f64)));
                pairs.push(("group", num(*group as f64)));
                "owner_drop_fallback"
            }
            EventKind::RsRetry { round, group } => {
                pairs.push(("round", num(*round as f64)));
                pairs.push(("group", num(*group as f64)));
                "rs_retry"
            }
            EventKind::GroupAbort { round, group, lost } => {
                pairs.push(("round", num(*round as f64)));
                pairs.push(("group", num(*group as f64)));
                pairs.push(("lost", num(*lost as f64)));
                "group_abort"
            }
            EventKind::Crash { peer } => {
                pairs.push(("peer", num(*peer as f64)));
                "crash"
            }
            EventKind::CrashRejoin { peer } => {
                pairs.push(("peer", num(*peer as f64)));
                "crash_rejoin"
            }
            EventKind::Ban { peer } => {
                pairs.push(("peer", num(*peer as f64)));
                "ban"
            }
            EventKind::Parole { peer } => {
                pairs.push(("peer", num(*peer as f64)));
                "parole"
            }
            EventKind::Reban { peer } => {
                pairs.push(("peer", num(*peer as f64)));
                "reban"
            }
            EventKind::Mkd { rounds, kd_steps, teacher_transfers, mean_loss } => {
                pairs.push(("rounds", num(*rounds as f64)));
                pairs.push(("kd_steps", num(*kd_steps as f64)));
                pairs.push(("teacher_transfers", num(*teacher_transfers as f64)));
                pairs.push(("mean_loss", num(*mean_loss)));
                "mkd"
            }
            EventKind::Eval { loss, accuracy } => {
                pairs.push(("loss", num(*loss)));
                pairs.push(("accuracy", num(*accuracy)));
                "eval"
            }
        };
        pairs.push(("ev", s(ev)));
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<TraceEvent> {
        fn f(v: &Json, key: &str) -> Result<f64> {
            v.get(key).and_then(|x| x.as_f64()).with_context(|| format!("missing numeric {key:?}"))
        }
        fn u(v: &Json, key: &str) -> Result<u64> {
            Ok(f(v, key)? as u64)
        }
        let ev = v.get("ev").and_then(|x| x.as_str()).context("missing \"ev\" discriminant")?;
        let kind = match ev {
            "iter_start" => EventKind::IterStart { participants: u(v, "participants")? },
            "local_compute" => EventKind::LocalCompute { dt: f(v, "dt")?, straggler_dt: f(v, "straggler_dt")? },
            "matchmaking" => EventKind::Matchmaking {
                round: u(v, "round")?,
                control_s: f(v, "control_s")?,
                hidden: matches!(v.get("hidden"), Some(Json::Bool(true))),
                groups: u(v, "groups")?,
            },
            "exchange" => EventKind::Exchange {
                round: u(v, "round")?,
                groups: u(v, "groups")?,
                rs_s: f(v, "rs_s")?,
                ag_s: f(v, "ag_s")?,
            },
            "fault_retries" => EventKind::FaultRetries {
                round: u(v, "round")?,
                group: u(v, "group")?,
                retries: u(v, "retries")?,
                timeouts: u(v, "timeouts")?,
            },
            "quorum_degraded" => EventKind::QuorumDegraded {
                round: u(v, "round")?,
                group: u(v, "group")?,
                lost: u(v, "lost")?,
            },
            "owner_drop_fallback" => {
                EventKind::OwnerDropFallback { round: u(v, "round")?, group: u(v, "group")? }
            }
            "rs_retry" => EventKind::RsRetry { round: u(v, "round")?, group: u(v, "group")? },
            "group_abort" => EventKind::GroupAbort {
                round: u(v, "round")?,
                group: u(v, "group")?,
                lost: u(v, "lost")?,
            },
            "crash" => EventKind::Crash { peer: u(v, "peer")? },
            "crash_rejoin" => EventKind::CrashRejoin { peer: u(v, "peer")? },
            "ban" => EventKind::Ban { peer: u(v, "peer")? },
            "parole" => EventKind::Parole { peer: u(v, "peer")? },
            "reban" => EventKind::Reban { peer: u(v, "peer")? },
            "mkd" => EventKind::Mkd {
                rounds: u(v, "rounds")?,
                kd_steps: u(v, "kd_steps")?,
                teacher_transfers: u(v, "teacher_transfers")?,
                mean_loss: f(v, "mean_loss")?,
            },
            "eval" => EventKind::Eval { loss: f(v, "loss")?, accuracy: f(v, "accuracy")? },
            other => bail!("unknown event kind {other:?}"),
        };
        Ok(TraceEvent { iter: u(v, "iter")?, t: f(v, "t")?, kind })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RoundTrace {
        let mut tr = RoundTrace::default();
        tr.record(0, 0.0, EventKind::IterStart { participants: 16 });
        tr.record(0, 1.25, EventKind::LocalCompute { dt: 1.25, straggler_dt: 0.5 });
        tr.record(0, 1.5, EventKind::Matchmaking { round: 0, control_s: 0.25, hidden: false, groups: 4 });
        tr.record(0, 2.0, EventKind::Exchange { round: 0, groups: 4, rs_s: 0.3, ag_s: 0.2 });
        tr.record(0, 2.0, EventKind::FaultRetries { round: 0, group: 1, retries: 2, timeouts: 1 });
        tr.record(0, 2.0, EventKind::QuorumDegraded { round: 0, group: 2, lost: 1 });
        tr.record(0, 2.0, EventKind::OwnerDropFallback { round: 0, group: 3 });
        tr.record(0, 2.0, EventKind::RsRetry { round: 0, group: 0 });
        tr.record(0, 2.0, EventKind::GroupAbort { round: 0, group: 1, lost: 3 });
        tr.record(1, 2.5, EventKind::Crash { peer: 7 });
        tr.record(1, 2.5, EventKind::CrashRejoin { peer: 7 });
        tr.record(1, 2.5, EventKind::Ban { peer: 3 });
        tr.record(1, 2.5, EventKind::Parole { peer: 3 });
        tr.record(1, 2.5, EventKind::Reban { peer: 3 });
        tr.record(1, 3.0, EventKind::Mkd { rounds: 2, kd_steps: 8, teacher_transfers: 4, mean_loss: 0.75 });
        tr.record(1, 3.0, EventKind::Eval { loss: 1.5, accuracy: 0.25 });
        tr
    }

    #[test]
    fn jsonl_round_trips_every_event_kind() {
        let tr = sample();
        let text = tr.to_jsonl();
        let back = RoundTrace::parse_jsonl(&text).unwrap();
        assert_eq!(back, tr);
        // serialization is deterministic: re-serialize byte-identically
        assert_eq!(back.to_jsonl(), text);
    }

    #[test]
    fn header_carries_schema() {
        let text = sample().to_jsonl();
        let first = text.lines().next().unwrap();
        assert!(first.contains(TRACE_SCHEMA));
    }

    #[test]
    fn rejects_bad_documents() {
        assert!(RoundTrace::parse_jsonl("").is_err());
        assert!(RoundTrace::parse_jsonl("{\"schema\":\"marfl-trace/v999\"}\n").is_err());
        assert!(RoundTrace::parse_jsonl("{\"no_schema\":1}\n").is_err());
        let bad_event = format!("{}\n{{\"ev\":\"warp_drive\",\"iter\":0,\"t\":0}}\n", obj(vec![("schema", s(TRACE_SCHEMA))]).to_string());
        assert!(RoundTrace::parse_jsonl(&bad_event).is_err());
        let missing_field = format!("{}\n{{\"ev\":\"crash\",\"iter\":0,\"t\":0}}\n", obj(vec![("schema", s(TRACE_SCHEMA))]).to_string());
        assert!(RoundTrace::parse_jsonl(&missing_field).is_err());
    }

    #[test]
    fn write_and_read_file() {
        let dir = std::env::temp_dir().join(format!("marfl_trace_test_{}", std::process::id()));
        let path = dir.join("round_trace.jsonl");
        let tr = sample();
        tr.write_jsonl(&path).unwrap();
        let back = RoundTrace::parse_jsonl(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back, tr);
    }
}
