//! Synthetic dataset generators (offline stand-ins for MNIST and 20NG).

use super::Dataset;
use crate::rng::Rng;

/// Image side for the MNIST-like task.
pub const IMG: usize = 16;
/// Embedding dimension for the 20NG-like task (frozen-encoder output).
pub const EMB: usize = 64;

/// MNIST-like: 10 classes of 16×16×1 images. Each class has a fixed
/// stroke/blob template (deterministic from the class id); samples add
/// ±2 px translation jitter and Gaussian pixel noise, giving a task a
/// small CNN learns to ~95%+ while remaining non-trivial — mirroring
/// MNIST's role in the paper.
pub fn mnist_like(n: usize, rng: &mut Rng) -> Dataset {
    let templates = class_templates();
    let elems = IMG * IMG;
    let mut x = Vec::with_capacity(n * elems);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(10);
        let dx = rng.below(5) as isize - 2;
        let dy = rng.below(5) as isize - 2;
        let t = &templates[c];
        for row in 0..IMG as isize {
            for col in 0..IMG as isize {
                let sr = row - dy;
                let sc = col - dx;
                let base = if (0..IMG as isize).contains(&sr)
                    && (0..IMG as isize).contains(&sc)
                {
                    t[(sr as usize) * IMG + sc as usize]
                } else {
                    0.0
                };
                let noisy = base + rng.normal_scaled(0.0, 0.25) as f32;
                x.push(noisy.clamp(-1.0, 2.0));
            }
        }
        y.push(c as i32);
    }
    Dataset { x, y, elems, classes: 10 }
}

/// Deterministic per-class stroke templates: each class is a union of
/// 3 line segments + 1 blob, positioned by a class-seeded PRNG. Distinct
/// enough to be separable, overlapping enough to need the conv layers.
fn class_templates() -> Vec<Vec<f32>> {
    (0..10)
        .map(|c| {
            let mut rng = Rng::new(0xDA7A_0000 + c as u64);
            let mut img = vec![0.0f32; IMG * IMG];
            for _ in 0..3 {
                draw_segment(&mut img, &mut rng);
            }
            draw_blob(&mut img, &mut rng);
            img
        })
        .collect()
}

fn draw_segment(img: &mut [f32], rng: &mut Rng) {
    let x0 = rng.below(IMG) as f64;
    let y0 = rng.below(IMG) as f64;
    let x1 = rng.below(IMG) as f64;
    let y1 = rng.below(IMG) as f64;
    let steps = 2 * IMG;
    for s in 0..=steps {
        let t = s as f64 / steps as f64;
        let x = x0 + (x1 - x0) * t;
        let y = y0 + (y1 - y0) * t;
        let (xi, yi) = (x.round() as usize, y.round() as usize);
        if xi < IMG && yi < IMG {
            img[yi * IMG + xi] = 1.0;
        }
    }
}

fn draw_blob(img: &mut [f32], rng: &mut Rng) {
    let cx = 3 + rng.below(IMG - 6);
    let cy = 3 + rng.below(IMG - 6);
    let r2 = (1 + rng.below(3)) as f64;
    for y in 0..IMG {
        for x in 0..IMG {
            let d2 = ((x as f64 - cx as f64).powi(2)
                + (y as f64 - cy as f64).powi(2))
                / (r2 * r2);
            if d2 < 1.0 {
                img[y * IMG + x] = (img[y * IMG + x] + (1.0 - d2) as f32).min(1.0);
            }
        }
    }
}

/// 20NG-like: 20-class embeddings in R^64 from anisotropic Gaussian
/// clusters. Cluster means are deterministic (seeded by class); per-class
/// anisotropic noise plus 15% "confuser" samples drawn halfway toward a
/// neighbouring class mean reproduce the harder, heterogeneity-sensitive
/// behaviour the paper reports for 20NG vs MNIST.
pub fn newsgroups_like(n: usize, rng: &mut Rng) -> Dataset {
    let means = cluster_means();
    let scales = cluster_scales();
    let mut x = Vec::with_capacity(n * EMB);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(20);
        let confuser = rng.chance(0.15);
        let other = (c + 1 + rng.below(19)) % 20;
        for d in 0..EMB {
            let mean = if confuser {
                0.5 * (means[c][d] + means[other][d])
            } else {
                means[c][d]
            };
            x.push((mean as f64 + rng.normal() * scales[c][d] as f64) as f32);
        }
        y.push(c as i32);
    }
    Dataset { x, y, elems: EMB, classes: 20 }
}

fn cluster_means() -> Vec<Vec<f32>> {
    (0..20)
        .map(|c| {
            let mut rng = Rng::new(0x20E6_0000 + c as u64);
            (0..EMB).map(|_| rng.normal_scaled(0.0, 1.1) as f32).collect()
        })
        .collect()
}

fn cluster_scales() -> Vec<Vec<f32>> {
    (0..20)
        .map(|c| {
            let mut rng = Rng::new(0x5CA1_0000 + c as u64);
            (0..EMB).map(|_| rng.range_f64(0.6, 1.4) as f32).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_like_shapes_and_labels() {
        let mut rng = Rng::new(5);
        let d = mnist_like(200, &mut rng);
        assert_eq!(d.elems, 256);
        assert_eq!(d.classes, 10);
        assert_eq!(d.x.len(), 200 * 256);
        assert!(d.y.iter().all(|&c| (0..10).contains(&c)));
        // all classes present in 200 draws with overwhelming probability
        let counts = d.class_counts();
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn mnist_like_classes_are_separable() {
        // nearest-template classification should beat chance by a lot
        let mut rng = Rng::new(6);
        let d = mnist_like(300, &mut rng);
        let templates = class_templates();
        let mut correct = 0;
        for i in 0..d.len() {
            let (x, y) = d.example(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = templates[a]
                        .iter()
                        .zip(x)
                        .map(|(t, v)| (t - v) * (t - v))
                        .sum();
                    let db: f32 = templates[b]
                        .iter()
                        .zip(x)
                        .map(|(t, v)| (t - v) * (t - v))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == y {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.len() as f64;
        assert!(acc > 0.5, "template-NN accuracy only {acc}");
    }

    #[test]
    fn newsgroups_like_shapes() {
        let mut rng = Rng::new(7);
        let d = newsgroups_like(400, &mut rng);
        assert_eq!(d.elems, 64);
        assert_eq!(d.classes, 20);
        assert!(d.y.iter().all(|&c| (0..20).contains(&c)));
    }

    #[test]
    fn newsgroups_like_clusters_separable_but_overlapping() {
        let mut rng = Rng::new(8);
        let d = newsgroups_like(1000, &mut rng);
        let means = cluster_means();
        let mut correct = 0;
        for i in 0..d.len() {
            let (x, y) = d.example(i);
            let best = (0..20)
                .min_by(|&a, &b| {
                    let da: f32 =
                        means[a].iter().zip(x).map(|(m, v)| (m - v) * (m - v)).sum();
                    let db: f32 =
                        means[b].iter().zip(x).map(|(m, v)| (m - v) * (m - v)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == y {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.len() as f64;
        // separable (way above 5% chance) but not saturated (confusers)
        assert!(acc > 0.5, "centroid accuracy only {acc}");
        assert!(acc < 0.99, "task too easy: {acc}");
    }

    #[test]
    fn generators_deterministic_given_seed() {
        let a = mnist_like(10, &mut Rng::new(99));
        let b = mnist_like(10, &mut Rng::new(99));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
