//! System-level property tests (in-repo harness, no PJRT needed):
//! coordinator routing/batching/state invariants under random
//! configurations — the "proptest on coordinator invariants" suite.

use std::sync::Arc;

use marfl::aggregation::{mean_of, AggCtx, Aggregate, PeerState};
use marfl::aggregation::{AllToAll, FedAvgServer, RingRdfl};
use marfl::coordinator::mixing::avg_distortion;
use marfl::coordinator::MarAggregator;
use marfl::metrics::CommLedger;
use marfl::net::{ChurnModel, Fabric};
use marfl::rng::Rng;
use marfl::sim::SimClock;
use marfl::testing::{check, Size};

struct Bundle {
    ledger: Arc<CommLedger>,
    fabric: Fabric,
    clock: SimClock,
    model: marfl::models::ModelMeta,
}

fn bundle(p: usize) -> Bundle {
    let ledger = Arc::new(CommLedger::new());
    Bundle {
        fabric: Fabric::new(ledger.clone(), 1e7, 0.001),
        ledger,
        clock: SimClock::new(),
        model: marfl::models::ModelMeta {
            name: "toy".into(),
            param_count: p,
            padded_len: p,
            input_shape: vec![4],
            classes: 3,
            batch: 8,
            eval_chunk: 8,
            init_file: String::new(),
            artifacts: Default::default(),
        },
    }
}

fn random_states(n: usize, p: usize, rng: &mut Rng) -> Vec<PeerState> {
    (0..n)
        .map(|_| PeerState {
            theta: (0..p).map(|_| rng.normal() as f32).collect(),
            momentum: (0..p).map(|_| rng.normal() as f32 * 0.1).collect(),
        })
        .collect()
}

/// Every aggregation strategy preserves the global mean over A_t (averaging
/// is mean-preserving regardless of topology), for random sizes/subsets.
#[test]
fn property_all_strategies_preserve_subset_mean() {
    check("mean_preservation", 24, 30, |rng, Size(sz)| {
        let n = (sz + 4).min(34);
        let p = 16;
        let k = 2 + rng.below(n - 2).min(n - 2);
        let agg_idx = rng.sample_indices(n, k.max(2));
        let strategies: Vec<Box<dyn Aggregate>> = vec![
            Box::new(FedAvgServer::default()),
            Box::new(RingRdfl),
            Box::new(AllToAll),
        ];
        for mut s in strategies {
            let mut states = random_states(n, p, &mut rng.fork(1));
            let (want, _) = mean_of(&states, &agg_idx);
            let mut b = bundle(p);
            let mut ctx = AggCtx {
                fabric: &b.fabric,
                clock: &mut b.clock,
                rng,
                runtime: None,
                model: &b.model,
                faults: &marfl::net::FaultConfig::OFF,
                links: None,
            };
            s.aggregate(&mut states, &agg_idx, &mut ctx).unwrap();
            let (got, _) = mean_of(&states, &agg_idx);
            for (g, w) in got.iter().zip(&want) {
                if (g - w).abs() > 1e-4 {
                    return Err(format!(
                        "{}: mean moved by {}",
                        s.name(),
                        (g - w).abs()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// MAR preserves the subset mean and strictly contracts distortion, for
/// random N, M, G (approximate mode included).
#[test]
fn property_mar_contracts_distortion_and_preserves_mean() {
    check("mar_contraction", 16, 40, |rng, Size(sz)| {
        let n = (sz + 6).min(46);
        let m = 2 + rng.below(3); // M in 2..=4
        let g = 2 + rng.below(3); // G in 2..=4
        let p = 8;
        let mut states = random_states(n, p, &mut rng.fork(2));
        let agg: Vec<usize> = (0..n).collect();
        let (want, _) = mean_of(&states, &agg);
        let before = avg_distortion(
            &states.iter().map(|s| s.theta.clone()).collect::<Vec<_>>(),
        );
        let ledger = Arc::new(CommLedger::new());
        let mut mar = MarAggregator::new(n, m, g, ledger.clone(), rng.next_u64());
        let mut b = bundle(p);
        let mut ctx = AggCtx {
            fabric: &b.fabric,
            clock: &mut b.clock,
            rng,
            runtime: None,
            model: &b.model,
            faults: &marfl::net::FaultConfig::OFF,
            links: None,
        };
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        let after = avg_distortion(
            &states.iter().map(|s| s.theta.clone()).collect::<Vec<_>>(),
        );
        let (got, _) = mean_of(&states, &agg);
        for (gv, wv) in got.iter().zip(&want) {
            if (gv - wv).abs() > 1e-4 {
                return Err(format!("mean moved by {}", (gv - wv).abs()));
            }
        }
        if before > 1e-9 && after > before * 0.9 {
            return Err(format!(
                "no contraction: {before:.4} -> {after:.4} (n={n} m={m} g={g})"
            ));
        }
        Ok(())
    });
}

/// MAR transfer count stays within the O(N·G·(M−1)) envelope for random
/// configurations — the routing invariant behind Figure 1.
#[test]
fn property_mar_transfer_count_bounded() {
    check("mar_transfer_bound", 16, 40, |rng, Size(sz)| {
        let n = (sz + 6).min(46);
        let m = 2 + rng.below(4);
        let g = 1 + rng.below(4);
        let p = 4;
        let mut states = random_states(n, p, &mut rng.fork(3));
        let agg: Vec<usize> = (0..n).collect();
        let ledger = Arc::new(CommLedger::new());
        let mut mar = MarAggregator::new(n, m, g, ledger, rng.next_u64());
        let b2 = bundle(p);
        let mut clock = SimClock::new();
        let mut ctx = AggCtx {
            fabric: &b2.fabric,
            clock: &mut clock,
            rng,
            runtime: None,
            model: &b2.model,
            faults: &marfl::net::FaultConfig::OFF,
            links: None,
        };
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        let msgs = b2.ledger.snapshot().data_msgs as usize;
        let bound = n * g * (m - 1);
        if msgs > bound {
            return Err(format!(
                "transfers {msgs} exceed N·G·(M−1) = {bound} (n={n} m={m} g={g})"
            ));
        }
        Ok(())
    });
}

/// Churn sampling invariants: participant sets are distinct, within
/// range, and aggregator sets are subsets of participants.
#[test]
fn property_churn_sets_well_formed() {
    check("churn_sets", 40, 60, |rng, Size(sz)| {
        let n = sz.max(3);
        let participation = 0.2 + rng.f64() * 0.8;
        let dropout = rng.f64() * 0.9;
        let churn = ChurnModel::new(participation, dropout);
        let u = churn.sample_participants(n, rng);
        if u.is_empty() || u.len() > n {
            return Err(format!("bad participant count {}", u.len()));
        }
        let mut sorted = u.clone();
        sorted.dedup();
        if sorted.len() != u.len() {
            return Err("duplicate participants".into());
        }
        let a = churn.sample_aggregators(&u, rng);
        if !a.iter().all(|x| u.contains(x)) {
            return Err("aggregator not a participant".into());
        }
        if u.len() >= 2 && a.len() < 2 {
            return Err("fewer than 2 aggregators despite 2+ participants".into());
        }
        Ok(())
    });
}

/// The ledger's data-byte count for MAR scales ~N·log(N) while AR-FL
/// scales ~N²: check the growth *ratio* between two sizes.
#[test]
fn property_scaling_shape() {
    let transfers = |n: usize, m: usize, g: usize, seed: u64| {
        let p = 4;
        let mut rng = Rng::new(seed);
        let mut states = random_states(n, p, &mut rng);
        let agg: Vec<usize> = (0..n).collect();
        let ledger = Arc::new(CommLedger::new());
        let mut mar = MarAggregator::new(n, m, g, ledger, seed);
        let b = bundle(p);
        let mut clock = SimClock::new();
        let mut ctx = AggCtx {
            fabric: &b.fabric,
            clock: &mut clock,
            rng: &mut rng,
            runtime: None,
            model: &b.model,
            faults: &marfl::net::FaultConfig::OFF,
            links: None,
        };
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        b.ledger.snapshot().data_msgs as f64
    };
    // 16 = 4^2 -> G=2 ; 64 = 4^3 -> G=3
    let small = transfers(16, 4, 2, 1);
    let large = transfers(64, 4, 3, 2);
    let mar_growth = large / small;
    // MAR: 16·2·3 = 96 -> 64·3·3 = 576: growth 6×. AR-FL would grow
    // 16·15=240 -> 64·63=4032: 16.8×. Assert MAR's growth is far below
    // quadratic growth.
    assert!(
        mar_growth < 8.0,
        "MAR growth {mar_growth} looks superlinear"
    );
    let quadratic_growth = (64.0 * 63.0) / (16.0 * 15.0);
    assert!(mar_growth < quadratic_growth / 2.0);
}
