//! Figures 4 & 10 — differentially private training.
//!
//! Paper claims: raising the noise multiplier σ reduces the privacy loss ε
//! but eventually degrades model utility, with the same pattern DP-FedAvg
//! shows — confirming DP is readily supported by the decentralized system.
//!
//! Default: 20NG-like (Fig. 4). MARFL_DATASET=cnn gives the MNIST-like
//! series (Fig. 10).

#[path = "common/mod.rs"]
mod common;

use common::{assert_stable_columns, emit_bench_report, emit_csv, iters, runtime, timed};
use marfl::config::{ExperimentConfig, Strategy};
use marfl::fl::Trainer;

fn main() {
    let dataset =
        std::env::var("MARFL_DATASET").unwrap_or_else(|_| "head".into());
    let peers = 64;
    let t = iters(24, 60);
    println!("Figure 4/10 — DP noise sweep on {dataset} (peers={peers}, T={t})\n");
    let rt = runtime();
    let base = ExperimentConfig {
        model: dataset.clone(),
        peers,
        group_size: 4,
        mar_rounds: 3,
        iterations: t,
        samples_per_peer: 64,
        test_samples: 1000,
        eval_every: 4,
        seed: 4242,
        ..Default::default()
    };

    // σ = 0 means DP off (reference); the rest sweep privatization strength
    let sigmas = [0.0, 0.1, 0.3, 0.6, 1.0];
    let mut rows = vec![vec![
        "strategy".into(),
        "noise_multiplier".into(),
        "epsilon".into(),
        "final_accuracy".into(),
    ]];
    let mut marfl_acc = Vec::new();
    for &sigma in &sigmas {
        for strategy in [Strategy::MarFl, Strategy::FedAvg] {
            let mut cfg = ExperimentConfig { strategy, ..base.clone() };
            if sigma > 0.0 {
                cfg.dp.enabled = true;
                cfg.dp.noise_multiplier = sigma;
            }
            let label = format!("{} σ={sigma}", strategy.name());
            let run =
                timed(&label, || Trainer::new(cfg, &rt).unwrap().run().unwrap());
            let eps = run
                .dp
                .epsilon
                .map(|e| format!("{e:.2}"))
                .unwrap_or_else(|| "inf".into());
            println!("    acc {:.3}  ε {eps}", run.final_accuracy);
            rows.push(vec![
                strategy.name().into(),
                sigma.to_string(),
                eps,
                format!("{:.4}", run.final_accuracy),
            ]);
            if strategy == Strategy::MarFl {
                marfl_acc.push((sigma, run.final_accuracy, run.dp.epsilon));
            }
        }
    }
    assert_stable_columns(
        "fig4_dp.csv",
        &rows,
        &[
            "strategy",
            "noise_multiplier",
            "epsilon",
            "final_accuracy",
        ],
    );
    emit_csv("fig4_dp.csv", &rows);
    emit_bench_report("dp", "dp_privacy_utility", &rows);

    // ---- paper-shape assertions ------------------------------------
    let no_dp = marfl_acc[0].1;
    let strongest = marfl_acc.last().unwrap().1;
    println!("\nno-DP acc {no_dp:.3} vs σ=1.0 acc {strongest:.3}");
    assert!(
        strongest < no_dp,
        "strong noise must eventually degrade utility"
    );
    // ε monotone decreasing in σ (same T)
    let eps: Vec<f64> = marfl_acc
        .iter()
        .filter_map(|(_, _, e)| *e)
        .collect();
    for w in eps.windows(2) {
        assert!(w[1] < w[0], "ε must fall as σ rises: {eps:?}");
    }
    println!("ε sweep (MAR-FL): {eps:?} — monotone, as in DP-FedAvg");
}
