//! TOML-subset parser (offline environment: no `toml` crate).
//!
//! Supports what experiment presets need: `[section]` headers, `key = value`
//! with string / integer / float / boolean / flat-array values, `#` comments.
//! Keys are exposed flattened as `section.key`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse a TOML-subset document into flattened `section.key -> Value`.
pub fn parse(input: &str) -> Result<BTreeMap<String, Value>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            section = name.trim().to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected key = value", lineno + 1);
        };
        let key = line[..eq].trim();
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.insert(full, value);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // naive: '#' inside strings unsupported (not needed by presets)
    match line.find('#') {
        Some(i) if !line[..i].contains('"') => &line[..i],
        Some(i) => {
            // check the '#' is not inside a quoted string
            let quotes = line[..i].matches('"').count();
            if quotes % 2 == 0 {
                &line[..i]
            } else {
                line
            }
        }
        None => line,
    }
}

/// Parse a single scalar or flat array.
pub fn parse_value(text: &str) -> Result<Value, String> {
    let t = text.trim();
    if t.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = t.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            return Err("unterminated array".into());
        };
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Some(s) = t.strip_prefix('"') {
        let Some(s) = s.strip_suffix('"') else {
            return Err("unterminated string".into());
        };
        return Ok(Value::Str(s.to_string()));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare word: treat as string (lets CLI overrides skip quotes)
    Ok(Value::Str(t.to_string()))
}

fn split_top_level(s: &str) -> Vec<&str> {
    // arrays are flat; just split on commas
    s.split(',').collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = r#"
            # preset for figure 1
            model = "cnn"
            peers = 125

            [mar]
            group_size = 5
            rounds = 3
            exact = true

            [dp]
            noise_multiplier = 0.5
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["model"], Value::Str("cnn".into()));
        assert_eq!(m["peers"], Value::Int(125));
        assert_eq!(m["mar.group_size"], Value::Int(5));
        assert_eq!(m["mar.exact"], Value::Bool(true));
        assert_eq!(m["dp.noise_multiplier"], Value::Float(0.5));
    }

    #[test]
    fn parses_arrays() {
        let m = parse("sizes = [16, 64, 125]").unwrap();
        assert_eq!(
            m["sizes"],
            Value::Arr(vec![Value::Int(16), Value::Int(64), Value::Int(125)])
        );
    }

    #[test]
    fn comments_stripped() {
        let m = parse("a = 1 # trailing\n# whole line\nb = 2").unwrap();
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn hash_inside_string_preserved() {
        let m = parse(r##"tag = "exp#7""##).unwrap();
        assert_eq!(m["tag"], Value::Str("exp#7".into()));
    }

    #[test]
    fn rejects_missing_equals() {
        assert!(parse("justakey").is_err());
    }

    #[test]
    fn bare_words_are_strings() {
        assert_eq!(parse_value("marfl").unwrap(), Value::Str("marfl".into()));
    }
}
