//! Fully decentralized differential privacy (paper Algorithm 4).
//!
//! Adapts DP-FedAvg with adaptive clipping (Andrew et al. 2021) to the
//! serverless setting: each peer clips and noises its own model delta
//! *locally* before MAR; aggregation then merely averages privatized
//! quantities, so the privacy loss accrues entirely from local
//! computation. Four quantities ride through MAR: the DP-safe model θ̂,
//! the momentum m, the clip indicator b, and the smoothed delta Δ̄ — the
//! engine packs (Δ̄ ‖ b) onto the momentum vector so any `Aggregate`
//! implementation averages them with byte-exact accounting, then unpacks
//! after aggregation and updates the adaptive clipping bound
//! C_{t+1} = C_t · exp(−η_C (b̃ − γ)).

pub mod accountant;

pub use accountant::RdpAccountant;

use crate::aggregation::PeerState;
use crate::config::DpConfig;
use crate::params::Theta;
use crate::rng::Rng;
use crate::util::l2_norm;

/// Per-experiment DP engine: adaptive clip bound + per-peer DP state.
pub struct DpEngine {
    pub cfg: DpConfig,
    /// current clipping bound C_t
    pub clip_bound: f64,
    /// θ̄_i^{t-1}: the last global model each peer obtained (peers that
    /// missed aggregations hold stale entries — the paper's Algorithm 4
    /// explicitly allows this). Shared copy-on-write handles on the state
    /// the peer already holds — zero-copy until either side writes.
    last_global: Vec<Option<Theta>>,
    /// Δ̄_i^{t-1}: the last smoothed delta each peer obtained
    smoothed_delta: Vec<Option<Vec<f32>>>,
    accountant: RdpAccountant,
}

impl DpEngine {
    pub fn new(cfg: DpConfig, n_peers: usize) -> Self {
        let clip_bound = cfg.clip_init;
        DpEngine {
            cfg,
            clip_bound,
            last_global: vec![None; n_peers],
            smoothed_delta: vec![None; n_peers],
            accountant: RdpAccountant::new(),
        }
    }

    /// Noise calibration (Algorithm 4 lines 1–3). Returns
    /// (σ_b, σ_Δ): indicator noise std and delta noise std.
    pub fn calibrate(&self, n_t: usize) -> (f64, f64) {
        let sigma_b = n_t as f64 / 20.0;
        let inv = self.cfg.noise_multiplier.powi(-2) - (2.0 * sigma_b).powi(-2);
        assert!(
            inv > 0.0,
            "noise multiplier {} too large for n_t={n_t} (needs σ_mult < n_t/10)",
            self.cfg.noise_multiplier
        );
        let z_delta = inv.powf(-0.5);
        (sigma_b, z_delta * self.clip_bound)
    }

    /// Pre-aggregation privatization (Algorithm 4 lines 4–9) for every
    /// aggregator. Replaces each θ with the DP-safe θ̂ and extends the
    /// momentum vector with (Δ̄_i ‖ b_i) so they are averaged by MAR.
    pub fn prepare(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        rng: &mut Rng,
    ) {
        let n_t = agg.len();
        if n_t == 0 {
            return;
        }
        let (_, sigma_delta) = self.calibrate(n_t);
        let per_coord_std = (sigma_delta * sigma_delta / n_t as f64).sqrt();
        for &i in agg {
            let p = states[i].theta.len();
            let reference: Theta = match &self.last_global[i] {
                Some(t) => t.clone(),
                None => Theta::zeros(p),
            };
            // Δ_i = θ_i^t − θ̄_i^{t-1}
            let delta: Vec<f32> = states[i]
                .theta
                .iter()
                .zip(&reference)
                .map(|(&t, &g)| t - g)
                .collect();
            let norm = l2_norm(&delta);
            let clipped_flag = if norm <= self.clip_bound { 1.0f32 } else { 0.0f32 };
            let scale = (self.clip_bound / norm.max(1e-12)).min(1.0) as f32;
            // Δ̃_i = clip(Δ_i) + N(0, σ_Δ²/n_t · I)
            let noisy: Vec<f32> = delta
                .iter()
                .map(|&d| d * scale + rng.normal_scaled(0.0, per_coord_std) as f32)
                .collect();
            // Δ̄_i^{t,0} = β Δ̄_i^{t-1} + Δ̃_i   (or Δ̃_i if ⊥)
            let smoothed: Vec<f32> = match &self.smoothed_delta[i] {
                Some(prev) => prev
                    .iter()
                    .zip(&noisy)
                    .map(|(&s, &d)| (self.cfg.beta as f32) * s + d)
                    .collect(),
                None => noisy,
            };
            // θ̂_i^{t,0} = θ̄_i^{t-1} + η_u Δ̄_i^{t,0} — built as fresh
            // storage: the peer's θ handle may be shared with groupmates
            // from the last broadcast, so replacing beats copy-on-write
            states[i].theta = reference
                .iter()
                .zip(&smoothed)
                .map(|(&g, &s)| g + (self.cfg.eta_u as f32) * s)
                .collect();
            // pack (Δ̄ ‖ b) onto the momentum payload for aggregation
            let mom = states[i].momentum.make_mut();
            mom.reserve(p + 1);
            mom.extend_from_slice(&smoothed);
            mom.push(clipped_flag);
        }
    }

    /// Post-aggregation unpack + adaptive bound update (lines 16–17).
    /// Returns the noised global clip fraction b̃.
    pub fn finalize(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        rng: &mut Rng,
    ) -> f64 {
        let n_t = agg.len();
        let (sigma_b, _) = self.calibrate(n_t.max(1));
        let mut b_bar = 0.0f64;
        for &i in agg {
            let p = states[i].theta.len();
            let mom_len = states[i].momentum.len();
            debug_assert_eq!(mom_len, 2 * p + 1, "momentum not in DP-packed form");
            let b = states[i].momentum[mom_len - 1] as f64;
            let smoothed = states[i].momentum[p..mom_len - 1].to_vec();
            // trim the packed payload into fresh storage (the extended
            // vector is shared group-wide after aggregation; truncating a
            // CoW copy would copy 2p+1 elements to keep p)
            let trimmed: Vec<f32> = states[i].momentum[..p].to_vec();
            states[i].momentum = trimmed.into();
            // the reference model is a shared handle on the peer's own
            // state — zero-copy until either side writes
            self.last_global[i] = Some(states[i].theta.clone());
            self.smoothed_delta[i] = Some(smoothed);
            b_bar += b;
        }
        b_bar /= n_t.max(1) as f64;
        // b̃ = b̄ + N(0, σ_b²)/n_t  (noise rescaled: we average, not sum)
        let b_tilde = b_bar + rng.normal_scaled(0.0, sigma_b) / n_t.max(1) as f64;
        // C_{t+1} = C_t · exp(−η_C (b̃ − γ))
        self.clip_bound *= (-self.cfg.eta_c * (b_tilde - self.cfg.gamma)).exp();
        self.accountant.step(self.cfg.noise_multiplier);
        b_tilde
    }

    /// Current (ε, δ)-DP guarantee after the iterations accounted so far.
    pub fn epsilon(&self) -> f64 {
        self.accountant.epsilon(self.cfg.delta)
    }

    pub fn iterations_accounted(&self) -> usize {
        self.accountant.steps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(noise: f64) -> DpEngine {
        DpEngine::new(
            DpConfig { enabled: true, noise_multiplier: noise, ..Default::default() },
            8,
        )
    }

    fn states(n: usize, p: usize, seed: u64) -> Vec<PeerState> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| PeerState {
                theta: (0..p).map(|_| rng.normal() as f32).collect(),
                momentum: Theta::zeros(p),
            })
            .collect()
    }

    #[test]
    fn calibration_matches_algorithm4() {
        let e = engine(0.3);
        let (sigma_b, sigma_delta) = e.calibrate(125);
        assert!((sigma_b - 6.25).abs() < 1e-12);
        let z = (0.3f64.powi(-2) - (12.5f64).powi(-2)).powf(-0.5);
        assert!((sigma_delta - z * e.clip_bound).abs() < 1e-12);
        // z ≈ σ_mult when σ_b large
        assert!((z - 0.3).abs() < 0.01);
    }

    #[test]
    #[should_panic(expected = "too large")]
    fn oversized_noise_multiplier_panics() {
        engine(10.0).calibrate(20);
    }

    #[test]
    fn prepare_packs_and_finalize_unpacks() {
        let mut e = engine(0.3);
        let mut s = states(4, 16, 1);
        let agg = vec![0, 1, 2, 3];
        let mut rng = Rng::new(2);
        e.prepare(&mut s, &agg, &mut rng);
        for &i in &agg {
            assert_eq!(s[i].momentum.len(), 2 * 16 + 1);
            let b = *s[i].momentum.last().unwrap();
            assert!(b == 0.0 || b == 1.0);
        }
        e.finalize(&mut s, &agg, &mut rng);
        for &i in &agg {
            assert_eq!(s[i].momentum.len(), 16);
            assert!(e.last_global[i].is_some());
            assert!(e.smoothed_delta[i].is_some());
        }
        assert_eq!(e.iterations_accounted(), 1);
    }

    #[test]
    fn large_update_is_clipped_small_passes() {
        let mut e = engine(0.1);
        e.clip_bound = 1.0;
        let mut s = states(2, 8, 3);
        // peer 0: huge delta (norm >> 1); peer 1: tiny delta
        for v in s[0].theta.make_mut() {
            *v = 100.0;
        }
        for v in s[1].theta.make_mut() {
            *v = 0.001;
        }
        let mut rng = Rng::new(4);
        e.prepare(&mut s, &[0, 1], &mut rng);
        let b0 = *s[0].momentum.last().unwrap();
        let b1 = *s[1].momentum.last().unwrap();
        assert_eq!(b0, 0.0, "huge delta must register as clipped");
        assert_eq!(b1, 1.0, "tiny delta must not clip");
        // clipped+noised model change is bounded: ‖θ̂ − θ̄‖ ≈ η_u(C + noise)
        let norm = l2_norm(&s[0].theta);
        assert!(norm < 5.0, "clipping failed: ‖θ̂‖ = {norm}");
    }

    #[test]
    fn clip_bound_adapts_toward_quantile() {
        // everyone unclipped (b̃ ≈ 1 > γ=0.5) -> bound must shrink
        let mut e = engine(0.1);
        let start = e.clip_bound;
        let mut s = states(8, 8, 5);
        for st in &mut s {
            for v in st.theta.make_mut() {
                *v *= 1e-3; // tiny deltas => all below the clip bound
            }
        }
        let agg: Vec<usize> = (0..8).collect();
        let mut rng = Rng::new(6);
        e.prepare(&mut s, &agg, &mut rng);
        e.finalize(&mut s, &agg, &mut rng);
        assert!(
            e.clip_bound < start,
            "bound should shrink when nothing clips: {} -> {}",
            start,
            e.clip_bound
        );
        // and the opposite direction: huge deltas => all clipped => grow
        let mut e2 = engine(0.1);
        let start2 = e2.clip_bound;
        let mut s2 = states(8, 8, 15);
        for st in &mut s2 {
            for v in st.theta.make_mut() {
                *v *= 100.0;
            }
        }
        let mut rng2 = Rng::new(16);
        e2.prepare(&mut s2, &agg, &mut rng2);
        e2.finalize(&mut s2, &agg, &mut rng2);
        assert!(
            e2.clip_bound > start2,
            "bound should grow when everything clips: {} -> {}",
            start2,
            e2.clip_bound
        );
    }

    #[test]
    fn noise_magnitude_matches_calibration() {
        // zero delta => θ̂ − θ̄ = η_u · (noise only); verify empirical std
        let mut e = engine(0.5);
        e.clip_bound = 1.0;
        let p = 4096;
        let n = 8;
        let mut s: Vec<PeerState> = (0..n)
            .map(|_| PeerState {
                theta: Theta::zeros(p),
                momentum: Theta::zeros(p),
            })
            .collect();
        let agg: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(7);
        let (_, sigma_delta) = e.calibrate(n);
        let want_std = (sigma_delta * sigma_delta / n as f64).sqrt();
        e.prepare(&mut s, &agg, &mut rng);
        // smoothed delta (== noisy delta here) sits in momentum[p..2p]
        let sample = &s[0].momentum[p..2 * p];
        let mean: f64 = sample.iter().map(|&v| v as f64).sum::<f64>() / p as f64;
        let var: f64 = sample
            .iter()
            .map(|&v| (v as f64 - mean).powi(2))
            .sum::<f64>()
            / p as f64;
        let std = var.sqrt();
        assert!(
            (std - want_std).abs() < 0.15 * want_std,
            "noise std {std:.4} vs calibrated {want_std:.4}"
        );
    }

    #[test]
    fn epsilon_grows_with_iterations() {
        let mut e = engine(0.5);
        let mut s = states(8, 8, 8);
        let agg: Vec<usize> = (0..8).collect();
        let mut rng = Rng::new(9);
        e.prepare(&mut s, &agg, &mut rng);
        e.finalize(&mut s, &agg, &mut rng);
        let eps1 = e.epsilon();
        e.prepare(&mut s, &agg, &mut rng);
        e.finalize(&mut s, &agg, &mut rng);
        let eps2 = e.epsilon();
        assert!(eps2 > eps1, "ε must grow: {eps1} -> {eps2}");
    }
}
