//! Scenario: privacy-preserving collaborative training (paper §2.2
//! "Privacy considerations" + Figure 4). Runs fully decentralized
//! DP-MAR-FL at three privatization strengths and reports the (ε, δ)
//! guarantee from the RDP accountant next to model utility.
//!
//! ```bash
//! cargo run --release --example private_training
//! ```

use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;
use marfl::models::default_artifact_dir;
use marfl::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&default_artifact_dir())?;
    let base = {
        let mut c = ExperimentConfig {
            model: "head".into(),
            peers: 64,
            group_size: 4,
            mar_rounds: 3,
            iterations: 20,
            samples_per_peer: 64,
            test_samples: 1000,
            eval_every: 4,
            seed: 909,
            ..Default::default()
        };
        c.dp.enabled = true;
        c
    };

    println!("fully decentralized DP (Algorithm 4) on 64 peers, T=20, δ=1e-5\n");
    println!("σ_mult   accuracy   ε(δ=1e-5)   final clip bound");
    for sigma in [0.1, 0.3, 0.6] {
        let mut cfg = base.clone();
        cfg.dp.noise_multiplier = sigma;
        let mut trainer = Trainer::new(cfg, &rt)?;
        let summary = trainer.run()?;
        println!(
            "{sigma:>6}   {:>8.3}   {:>9.2}   (adaptive, γ=0.5)",
            summary.final_accuracy,
            summary.dp.epsilon.unwrap(),
        );
    }
    println!(
        "\nno-DP reference: σ=0 disables clipping+noise entirely (privacy loss unbounded):"
    );
    let mut cfg = base.clone();
    cfg.dp.enabled = false;
    let summary = Trainer::new(cfg, &rt)?.run()?;
    println!("  none   {:>8.3}        inf", summary.final_accuracy);
    println!(
        "\nprivacy loss accrues entirely from local computation; MAR merely\naverages privatized models across groups (paper §2.2)."
    );
    Ok(())
}
