//! BAR — Butterfly All-Reduce (paper Appendix B.3).
//!
//! The hypercube recursive-halving reduce-scatter + recursive-doubling
//! all-gather: log₂(n) rounds, each peer exchanging a halving parameter
//! segment with its rank-XOR partner. Per-peer traffic is only
//! `2·(n−1)/n` state transfers — asymptotically optimal — **but** the
//! paper excludes BAR as a baseline because it "requires peers to be
//! totally reliable": every peer owns a disjoint chunk, so the butterfly
//! only runs over a power-of-two participant set and any missing peer
//! stalls whole chunks of the model.
//!
//! This implementation makes that limitation measurable: aggregation runs
//! over the largest 2^k subset of `A_t` (rank order); the remaining
//! `|A_t| − 2^k` peers are **left out entirely** (their state stays
//! stale), which is exactly the incomplete-aggregation behaviour Appendix
//! B.3 describes under heterogeneous participation.

use anyhow::Result;

use super::{mean_of, payload_bytes, AggCtx, AggReport, Aggregate, PeerState, Theta};
use crate::metrics::Plane;
use crate::net::FaultCounters;

#[derive(Debug, Default)]
pub struct Butterfly;

impl Butterfly {
    /// Largest power-of-two prefix of the aggregator set.
    pub fn butterfly_subset(agg: &[usize]) -> &[usize] {
        if agg.len() < 2 {
            return &agg[..0];
        }
        let k = usize::BITS - 1 - agg.len().leading_zeros();
        &agg[..1 << k]
    }
}

impl Aggregate for Butterfly {
    fn name(&self) -> &'static str {
        "bar"
    }

    fn aggregate(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport> {
        let fp = ctx.faults;
        let mut faults = FaultCounters::default();
        // fault plan: BAR "requires peers to be totally reliable" — a
        // crashed peer owns a disjoint chunk, so the butterfly re-forms
        // over the survivors (possibly halving the 2^k subset) before it
        // starts; draws are gated so the fault-free path is draw-free
        let live: Vec<usize> = if fp.crash_prob > 0.0 {
            agg.iter()
                .copied()
                .filter(|_| {
                    if ctx.rng.chance(fp.crash_prob) {
                        faults.crashes += 1;
                        false
                    } else {
                        true
                    }
                })
                .collect()
        } else {
            agg.to_vec()
        };
        let subset: Vec<usize> = Self::butterfly_subset(&live).to_vec();
        let n = subset.len();
        if n < 2 {
            return Ok(AggReport { faults, ..Default::default() });
        }
        let bytes = payload_bytes(states, &subset);
        let rounds = n.trailing_zeros() as usize; // log2(n)
        let link_on = fp.link_faults_enabled();
        // reduce-scatter: round r exchanges segments of bytes / 2^(r+1);
        // all-gather mirrors it. All pairs act in parallel per round.
        // Chunk ownership tolerates no loss: senders retry until delivery
        // (persistent links), so faults cost bytes and time, never chunks.
        for r in 0..rounds {
            let seg = bytes >> (r + 1);
            let mut lane_times = Vec::with_capacity(n);
            for i in 0..n {
                if link_on {
                    // round r pairs i with i ^ 2^r — the directed link the
                    // Gilbert–Elliott chain (when active) is keyed on
                    let lf = fp.draw_directed(
                        subset[i],
                        subset[i ^ (1 << r)],
                        1,
                        true,
                        ctx.links.as_deref_mut(),
                        ctx.rng,
                    );
                    faults.absorb(&lf);
                    lane_times.push(ctx.fabric.send_faulty(
                        seg.max(1),
                        Plane::Data,
                        &lf,
                    ));
                } else {
                    lane_times.push(ctx.fabric.send(seg.max(1), Plane::Data));
                }
            }
            ctx.clock.parallel(lane_times);
        }
        for r in (0..rounds).rev() {
            let seg = bytes >> (r + 1);
            let mut lane_times = Vec::with_capacity(n);
            for i in 0..n {
                if link_on {
                    let lf = fp.draw_directed(
                        subset[i],
                        subset[i ^ (1 << r)],
                        1,
                        true,
                        ctx.links.as_deref_mut(),
                        ctx.rng,
                    );
                    faults.absorb(&lf);
                    lane_times.push(ctx.fabric.send_faulty(
                        seg.max(1),
                        Plane::Data,
                        &lf,
                    ));
                } else {
                    lane_times.push(ctx.fabric.send(seg.max(1), Plane::Data));
                }
            }
            ctx.clock.parallel(lane_times);
        }
        // the butterfly computes the exact mean over the 2^k subset
        let (theta, mom) = mean_of(states, &subset);
        let (theta, mom) = (Theta::new(theta), Theta::new(mom));
        for &i in &subset {
            states[i].theta = theta.clone();
            states[i].momentum = mom.clone();
        }
        Ok(AggReport {
            rounds: 2 * rounds,
            groups: 1,
            faults,
            ..Default::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::test_support::*;

    #[test]
    fn power_of_two_set_gets_exact_average() {
        let mut states = random_states(8, 32, 40);
        let agg: Vec<usize> = (0..8).collect();
        let (want, _) = mean_of(&states, &agg);
        let mut tc = TestCtx::new(32);
        let mut ctx = tc.ctx();
        Butterfly.aggregate(&mut states, &agg, &mut ctx).unwrap();
        for s in &states {
            crate::testing::assert_allclose(&s.theta, &want, 1e-6, 1e-7);
        }
    }

    #[test]
    fn traffic_is_two_n_minus_one_over_n_states() {
        let n = 16;
        let p = 1024;
        let mut states = random_states(n, p, 41);
        let agg: Vec<usize> = (0..n).collect();
        let mut tc = TestCtx::new(p);
        let mut ctx = tc.ctx();
        Butterfly.aggregate(&mut states, &agg, &mut ctx).unwrap();
        let got = tc.ledger.snapshot().data_bytes;
        // per peer: 2 * sum_{r=1..log2 n} bytes/2^r = 2*bytes*(n-1)/n
        let state = 2 * p as u64 * 4;
        let want = n as u64 * 2 * state * (n as u64 - 1) / n as u64;
        assert_eq!(got, want, "got {got} want {want}");
    }

    #[test]
    fn stragglers_beyond_power_of_two_left_stale() {
        // 11 aggregators -> butterfly over 8; peers 8..10 untouched: the
        // incomplete-aggregation behaviour of Appendix B.3
        let mut states = random_states(11, 16, 42);
        let before9 = states[9].theta.clone();
        let agg: Vec<usize> = (0..11).collect();
        let (want_subset, _) = mean_of(&states, &agg[..8]);
        let mut tc = TestCtx::new(16);
        let mut ctx = tc.ctx();
        Butterfly.aggregate(&mut states, &agg, &mut ctx).unwrap();
        crate::testing::assert_allclose(&states[0].theta, &want_subset, 1e-6, 1e-7);
        assert_eq!(states[9].theta, before9, "straggler must be left out");
    }

    #[test]
    fn bar_beats_even_marfl_on_bytes_but_excludes_peers() {
        // why the paper still prefers MAR: BAR's efficiency only covers
        // the 2^k subset; with 125 aggregators, 61 peers get nothing
        let agg: Vec<usize> = (0..125).collect();
        let subset = Butterfly::butterfly_subset(&agg);
        assert_eq!(subset.len(), 64);
        assert_eq!(125 - subset.len(), 61);
    }

    #[test]
    fn single_pair_works() {
        let mut states = random_states(2, 8, 43);
        let (want, _) = mean_of(&states, &[0, 1]);
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        Butterfly.aggregate(&mut states, &[0, 1], &mut ctx).unwrap();
        crate::testing::assert_allclose(&states[0].theta, &want, 1e-6, 1e-7);
    }
}
