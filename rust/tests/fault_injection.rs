//! Fault-injection fabric verification: an inert plan must be
//! bit-identical to `FaultConfig::OFF` (zero extra draws), an active
//! plan must stay bit-identical across the serial and parallel engines,
//! the fabric's retry booking must match its closed form, and a
//! quorum-degraded group must average exactly its survivors while the
//! lost members stay bitwise stale.

use std::sync::Arc;

use marfl::aggregation::{
    mean_of, AggCtx, AggReport, Aggregate, GroupExchange, PeerState,
};
use marfl::config::ExperimentConfig;
use marfl::coordinator::{AggOptions, MarAggregator};
use marfl::fl::Trainer;
use marfl::metrics::{CommLedger, CommSnapshot, Plane};
use marfl::net::{BwDist, Fabric, FaultConfig, LinkFault, RETRY_CTRL_BYTES};
use marfl::rng::Rng;
use marfl::runtime::Runtime;
use marfl::sim::SimClock;

fn toy_model(p: usize) -> marfl::models::ModelMeta {
    marfl::models::ModelMeta {
        name: "toy".into(),
        param_count: p,
        padded_len: p,
        input_shape: vec![4],
        classes: 3,
        batch: 8,
        eval_chunk: 8,
        init_file: String::new(),
        artifacts: Default::default(),
    }
}

fn random_states(n: usize, p: usize, seed: u64) -> Vec<PeerState> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| PeerState {
            theta: (0..p).map(|_| rng.normal() as f32).collect(),
            momentum: (0..p).map(|_| rng.normal() as f32 * 0.1).collect(),
        })
        .collect()
}

/// One MAR aggregate call under `faults`; returns (states, ledger
/// snapshot, simulated clock, report).
#[allow(clippy::too_many_arguments)]
fn run_mar_faulty(
    n: usize,
    m: usize,
    g: usize,
    p: usize,
    exchange: GroupExchange,
    faults: &FaultConfig,
    parallel: bool,
    rng_seed: u64,
) -> (Vec<PeerState>, CommSnapshot, f64, AggReport) {
    let mut states = random_states(n, p, 0xFA17 ^ n as u64);
    let agg: Vec<usize> = (0..n).collect();
    let ledger = Arc::new(CommLedger::new());
    let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
    let mut clock = SimClock::new();
    let mut rng = Rng::new(rng_seed);
    let model = toy_model(p);
    let mut mar = MarAggregator::with_options(
        n,
        m,
        g,
        ledger.clone(),
        7,
        AggOptions { exchange, parallel, ..AggOptions::default() },
    );
    ledger.reset(); // drop DHT join traffic
    let mut ctx = AggCtx {
        fabric: &fabric,
        clock: &mut clock,
        rng: &mut rng,
        runtime: None,
        model: &model,
        faults,
        links: None,
    };
    let report = mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
    (states, ledger.snapshot(), clock.now(), report)
}

/// (a) Faults off ⇒ bit-identical to the inert `OFF` plan: a config
/// whose probabilities are all zero (whatever its other knobs say) must
/// consume zero extra draws and leave states, ledger, clock and report
/// untouched relative to `FaultConfig::OFF` — on both engines and both
/// wire protocols.
#[test]
fn inert_plan_is_bit_identical_to_off() {
    // zero probabilities, deliberately weird non-probability knobs: none
    // of them may be observable while the plan is inert
    let inert = FaultConfig {
        loss: 0.0,
        degrade_prob: 0.0,
        straggler_prob: 0.0,
        crash_prob: 0.0,
        degrade_bw: 0.01,
        degrade_lat: 100.0,
        straggler_mult: 50.0,
        max_retries: 9,
        timeout_s: 7.0,
        backoff_s: 3.0,
        quorum_min: 5,
        // Gilbert–Elliott knobs: ge_p = 0 keeps every chain inert, so the
        // weird state-dependent multipliers must never be observable
        ge_p: 0.0,
        ge_r: 0.9,
        ge_loss: 1.0,
        ge_bw: 0.01,
        ge_lat: 100.0,
        // bandwidth heterogeneity off: sigma/bounds must be dead knobs
        bw_dist: BwDist::Off,
        bw_sigma: 9.0,
        bw_min: 0.5,
        bw_max: 0.5,
    };
    assert!(!inert.enabled());
    for &exchange in &[GroupExchange::FullGather, GroupExchange::ReduceScatter]
    {
        for &parallel in &[false, true] {
            let (off_states, off_snap, off_clock, off_rep) = run_mar_faulty(
                27,
                3,
                3,
                129,
                exchange,
                &FaultConfig::OFF,
                parallel,
                77,
            );
            let (in_states, in_snap, in_clock, in_rep) = run_mar_faulty(
                27, 3, 3, 129, exchange, &inert, parallel, 77,
            );
            for (a, b) in off_states.iter().zip(&in_states) {
                assert_eq!(a.theta, b.theta, "inert plan perturbed states");
                assert_eq!(a.momentum, b.momentum);
            }
            assert_eq!(off_snap, in_snap, "inert plan perturbed the ledger");
            assert_eq!(off_clock.to_bits(), in_clock.to_bits());
            assert_eq!(off_rep, in_rep);
            assert!(!off_rep.faults.any(), "OFF plan must report no faults");
        }
    }
}

/// (b) An active plan stays bit-identical across engines: every fault is
/// drawn in the serial schedule phase, so the group-parallel engine
/// reproduces the serial reference exactly — states, ledger, clock and
/// fault counters — and the counters are actually nonzero.
#[test]
fn active_plan_parallel_matches_serial() {
    let plan = FaultConfig {
        loss: 0.15,
        degrade_prob: 0.25,
        crash_prob: 0.03,
        ..FaultConfig::default()
    };
    for &exchange in &[GroupExchange::FullGather, GroupExchange::ReduceScatter]
    {
        let (s_states, s_snap, s_clock, s_rep) =
            run_mar_faulty(27, 3, 3, 129, exchange, &plan, false, 77);
        let (p_states, p_snap, p_clock, p_rep) =
            run_mar_faulty(27, 3, 3, 129, exchange, &plan, true, 77);
        for (i, (a, b)) in s_states.iter().zip(&p_states).enumerate() {
            assert_eq!(a.theta, b.theta, "peer {i} theta diverged");
            assert_eq!(a.momentum, b.momentum, "peer {i} momentum diverged");
        }
        assert_eq!(s_snap, p_snap, "ledger diverged under faults");
        assert_eq!(s_clock.to_bits(), p_clock.to_bits(), "clock diverged");
        assert_eq!(s_rep, p_rep, "fault counters diverged");
        assert!(
            s_rep.faults.msgs_lost > 0,
            "loss=0.15 over 27 peers must lose messages"
        );
        assert!(s_rep.faults.retries > 0, "losses must trigger retries");
    }
}

/// (c) Closed-form retry accounting: a lossy link books the payload once
/// per attempt on its own plane, one `RETRY_CTRL_BYTES` probe per
/// retry/timeout on the control plane, and a duration of
/// `attempts·latency·lat_mult + attempts·bytes/(bw·bw_mult) + penalty`.
#[test]
fn fabric_retry_booking_matches_closed_form() {
    let ledger = Arc::new(CommLedger::new());
    let fabric = Fabric::new(ledger.clone(), 1000.0, 0.01);
    let lf = LinkFault {
        bw_mult: 0.5,
        lat_mult: 2.0,
        retries: 3,
        timeouts: 1,
        penalty_s: 0.7,
    };

    // single message: 1 + 3 retries = 4 attempts, 4 probes
    let t = fabric.send_faulty(500, Plane::Data, &lf);
    let snap = ledger.snapshot();
    assert_eq!(snap.data_msgs, 4);
    assert_eq!(snap.data_bytes, 4 * 500);
    assert_eq!(snap.control_msgs, 4);
    assert_eq!(snap.control_bytes, 4 * RETRY_CTRL_BYTES);
    let want = 4.0 * 0.01 * 2.0 + (4.0 * 500.0) / (1000.0 * 0.5) + 0.7;
    assert!((t - want).abs() < 1e-12, "{t} vs {want}");

    // k-message sequence: k + retries attempts over the same link
    ledger.reset();
    let t = fabric.sequential_faulty(5, 500, Plane::Data, &lf);
    let snap = ledger.snapshot();
    assert_eq!(snap.data_msgs, 5 + 3);
    assert_eq!(snap.data_bytes, (5 + 3) * 500);
    assert_eq!(snap.control_msgs, 4);
    assert_eq!(snap.control_bytes, 4 * RETRY_CTRL_BYTES);
    let want = 8.0 * 0.01 * 2.0 + (8.0 * 500.0) / (1000.0 * 0.5) + 0.7;
    assert!((t - want).abs() < 1e-12, "{t} vs {want}");

    // a clean link delegates to the legacy path bit for bit
    ledger.reset();
    let faulty = fabric.send_faulty(500, Plane::Data, &LinkFault::CLEAN);
    let clean_snap = ledger.snapshot();
    ledger.reset();
    let legacy = fabric.send(500, Plane::Data);
    assert_eq!(faulty.to_bits(), legacy.to_bits());
    assert_eq!(clean_snap, ledger.snapshot());
    ledger.reset();
    let faulty = fabric.sequential_faulty(7, 500, Plane::Data, &LinkFault::CLEAN);
    let clean_snap = ledger.snapshot();
    ledger.reset();
    let legacy = fabric.sequential(7, 500, Plane::Data);
    assert_eq!(faulty.to_bits(), legacy.to_bits());
    assert_eq!(clean_snap, ledger.snapshot());
}

/// (d) Quorum-degraded groups: when losses thin a full-gather group but
/// leave at least `quorum_min` survivors, the survivors average exactly
/// their renormalized mean (hand-computed via `mean_of`) and the lost
/// members stay bitwise stale.
#[test]
fn quorum_degraded_group_averages_survivors_exactly() {
    // single group of 4 (4 = 4^1), one MAR round, lossy links: scan a
    // few deterministic seeds until one yields a degraded (not aborted,
    // not clean) round, then pin its exact outcome
    let n = 4;
    let p = 65;
    let plan = FaultConfig { loss: 0.35, ..FaultConfig::default() };
    let before = random_states(n, p, 0xFA17 ^ n as u64);
    let mut found = false;
    for seed in 0..200u64 {
        let (states, _, _, rep) = run_mar_faulty(
            n,
            4,
            1,
            p,
            GroupExchange::FullGather,
            &plan,
            true,
            seed,
        );
        if rep.faults.quorum_degraded_rounds == 0 {
            continue;
        }
        let stale: Vec<usize> =
            (0..n).filter(|&i| states[i].theta == before[i].theta).collect();
        let survivors: Vec<usize> =
            (0..n).filter(|i| !stale.contains(i)).collect();
        assert!(!stale.is_empty(), "a degraded round must lose someone");
        assert!(
            survivors.len() >= plan.quorum_min,
            "degraded rounds require a quorum of survivors"
        );
        let (want_t, want_m) = mean_of(&before, &survivors);
        for &i in &survivors {
            assert_eq!(
                states[i].theta, want_t,
                "survivor {i} must hold the survivor mean exactly"
            );
            assert_eq!(states[i].momentum, want_m);
        }
        for &i in &stale {
            assert_eq!(
                states[i].momentum, before[i].momentum,
                "lost peer {i} must stay bitwise stale"
            );
        }
        assert!(rep.faults.timeouts > 0, "degradation implies timeouts");
        found = true;
        break;
    }
    assert!(found, "no seed in 0..200 produced a quorum-degraded round");
}

/// End-to-end: a default-config Trainer run reports all-zero fault
/// counters (the plan is off by default), and an active plan surfaces
/// nonzero counters through `RunSummary` while both engines agree.
#[test]
fn trainer_surfaces_fault_counters_deterministically() {
    let rt = Runtime::new(&marfl::models::default_artifact_dir()).unwrap();
    let base = ExperimentConfig {
        model: "head".into(),
        peers: 9,
        group_size: 3,
        iterations: 3,
        samples_per_peer: 32,
        test_samples: 250,
        eval_every: 3,
        local_batches: 2,
        seed: 4321,
        ..Default::default()
    };
    let run = |cfg: ExperimentConfig| {
        let mut t = Trainer::new(cfg, &rt).unwrap();
        t.run().unwrap()
    };
    let clean = run(base.clone());
    assert!(!clean.faults.any(), "default plan must report zero faults");
    assert_eq!(clean.faults.straggler_exposed_s, 0.0);
    assert_eq!(clean.reliability.rejoin_pulls, 0);

    let mut faulty_cfg = base.clone();
    faulty_cfg.faults = FaultConfig {
        loss: 0.2,
        straggler_prob: 0.3,
        crash_prob: 0.05,
        ..FaultConfig::default()
    };
    let a = run(faulty_cfg.clone());
    let b = run(faulty_cfg);
    assert!(a.faults.msgs_lost > 0, "loss=0.2 must lose messages");
    assert!(a.faults.straggler_exposed_s > 0.0, "stragglers must cost time");
    assert_eq!(a.faults, b.faults, "fault counters must be reproducible");
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits());
    assert_eq!(a.comm, b.comm);
}
