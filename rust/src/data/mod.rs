//! Data substrate: synthetic datasets + non-iid partitioning.
//!
//! The environment is offline, so the paper's MNIST and 20 Newsgroups are
//! substituted with synthetic equivalents that exercise the same code
//! paths and difficulty axes (DESIGN.md §Substitutions):
//!
//! * [`synth::mnist_like`]  — 10-class 16×16×1 images built from per-class
//!   stroke/blob templates with jitter and noise (stands in for MNIST).
//! * [`synth::newsgroups_like`] — 20-class 64-d embeddings from overlapping
//!   anisotropic Gaussian clusters (stands in for frozen-DistilBERT CLS
//!   embeddings of 20NG; the paper trains only the head on top of these).
//!
//! [`lda`] implements the Latent-Dirichlet-Allocation partitioner the paper
//! uses (α = 1.0) to create heterogeneous per-peer shards.

pub mod lda;
pub mod synth;

use crate::rng::Rng;

/// A flat dataset: `x` row-major `[n, elems]`, integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    /// per-example feature element count (e.g. 16*16*1 = 256 or 64)
    pub elems: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn example(&self, i: usize) -> (&[f32], i32) {
        (&self.x[i * self.elems..(i + 1) * self.elems], self.y[i])
    }

    /// Gather examples by index into a contiguous batch (x, y).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        self.gather_into(idx, &mut x, &mut y);
        (x, y)
    }

    /// [`Self::gather`] into caller-owned buffers: clears and refills
    /// `x`/`y` without shrinking their capacity, so a per-worker scratch
    /// buffer (see [`crate::exec::with_scratch`]) amortizes the batch
    /// allocation to zero after the first gather on each worker.
    pub fn gather_into(&self, idx: &[usize], x: &mut Vec<f32>, y: &mut Vec<i32>) {
        x.clear();
        y.clear();
        x.reserve(idx.len() * self.elems);
        y.reserve(idx.len());
        for &i in idx {
            x.extend_from_slice(&self.x[i * self.elems..(i + 1) * self.elems]);
            y.push(self.y[i]);
        }
    }

    /// Gather into a [`BatchBuf`] (convenience for scratch-buffer call
    /// sites).
    pub fn gather_into_buf(&self, idx: &[usize], buf: &mut BatchBuf) {
        self.gather_into(idx, &mut buf.x, &mut buf.y);
    }

    /// Class histogram (used by heterogeneity tests/benches).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes];
        for &c in &self.y {
            counts[c as usize] += 1;
        }
        counts
    }
}

/// Reusable mini-batch buffers for [`Dataset::gather_into`]. `Default` so
/// it can live in the per-worker scratch arena
/// ([`crate::exec::with_scratch`]): each pool thread gathers every batch
/// it processes into the same pair of vectors.
#[derive(Clone, Debug, Default)]
pub struct BatchBuf {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
}

/// A peer's local shard: indices into a shared dataset plus a cursor so
/// sequential mini-batches wrap deterministically (the paper's KD epoch
/// accounting assumes no shuffling between batches).
#[derive(Clone, Debug)]
pub struct Shard {
    pub indices: Vec<usize>,
    cursor: usize,
}

impl Shard {
    pub fn new(indices: Vec<usize>) -> Self {
        Shard { indices, cursor: 0 }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Next mini-batch of `b` dataset indices, wrapping around.
    pub fn next_batch(&mut self, b: usize) -> Vec<usize> {
        assert!(!self.indices.is_empty(), "empty shard");
        let mut out = Vec::with_capacity(b);
        for _ in 0..b {
            out.push(self.indices[self.cursor]);
            self.cursor = (self.cursor + 1) % self.indices.len();
        }
        out
    }

    /// Fraction of the local data seen so far (for KD epoch accounting).
    pub fn epochs_seen(&self, batches_taken: usize, batch_size: usize) -> f64 {
        (batches_taken * batch_size) as f64 / self.len().max(1) as f64
    }
}

/// Train/test bundle for one task, pre-partitioned across peers.
pub struct FlData {
    pub train: Dataset,
    pub test: Dataset,
    pub shards: Vec<Shard>,
}

/// Build the full data environment for a config-described experiment.
pub fn build(
    model: &str,
    peers: usize,
    samples_per_peer: usize,
    test_samples: usize,
    iid: bool,
    lda_alpha: f64,
    rng: &mut Rng,
) -> FlData {
    let train_n = peers * samples_per_peer;
    let (train, test) = match model {
        "cnn" => (
            synth::mnist_like(train_n, rng),
            synth::mnist_like(test_samples, rng),
        ),
        "head" => (
            synth::newsgroups_like(train_n, rng),
            synth::newsgroups_like(test_samples, rng),
        ),
        other => panic!("unknown model {other:?}"),
    };
    let shards = if iid {
        lda::partition_iid(&train, peers, rng)
    } else {
        lda::partition_lda(&train, peers, lda_alpha, rng)
    };
    FlData { train, test, shards: shards.into_iter().map(Shard::new).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_builds_contiguous_batch() {
        let mut rng = Rng::new(1);
        let d = synth::newsgroups_like(50, &mut rng);
        let (x, y) = d.gather(&[0, 5, 7]);
        assert_eq!(x.len(), 3 * d.elems);
        assert_eq!(y.len(), 3);
        assert_eq!(&x[..d.elems], d.example(0).0);
        assert_eq!(y[1], d.example(5).1);
    }

    #[test]
    fn shard_batches_wrap_deterministically() {
        let mut s = Shard::new(vec![10, 11, 12]);
        assert_eq!(s.next_batch(2), vec![10, 11]);
        assert_eq!(s.next_batch(2), vec![12, 10]);
        assert_eq!(s.next_batch(2), vec![11, 12]);
    }

    #[test]
    fn build_creates_one_shard_per_peer() {
        let mut rng = Rng::new(2);
        let fl = build("head", 8, 16, 100, false, 1.0, &mut rng);
        assert_eq!(fl.shards.len(), 8);
        let total: usize = fl.shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, fl.train.len());
        assert_eq!(fl.test.len(), 100);
    }

    #[test]
    fn epochs_seen_accounting() {
        let s = Shard::new((0..64).collect());
        assert!((s.epochs_seen(2, 64) - 2.0).abs() < 1e-12);
        assert!((s.epochs_seen(1, 32) - 0.5).abs() < 1e-12);
    }
}
