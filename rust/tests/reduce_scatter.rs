//! Chunk-owned reduce-scatter verification: the assembled chunk-owned
//! result must be bit-identical to full-gather averaging, the ledger's
//! phase counters must match the closed form, and a dropped chunk owner
//! must degrade gracefully (full-gather fallback among the survivors,
//! stale victim) — deterministically, on both engines.

use std::sync::Arc;

use marfl::aggregation::{mean_of, AggCtx, AggReport, Aggregate, GroupExchange, PeerState};
use marfl::coordinator::{AggOptions, MarAggregator};
use marfl::metrics::{CommLedger, CommSnapshot};
use marfl::models::ModelMeta;
use marfl::net::Fabric;
use marfl::rng::Rng;
use marfl::sim::SimClock;

fn toy_model(p: usize) -> ModelMeta {
    ModelMeta {
        name: "toy".into(),
        param_count: p,
        padded_len: p,
        input_shape: vec![4],
        classes: 3,
        batch: 8,
        eval_chunk: 8,
        init_file: String::new(),
        artifacts: Default::default(),
    }
}

fn random_states(n: usize, p: usize, seed: u64) -> Vec<PeerState> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| PeerState {
            theta: (0..p).map(|_| rng.normal() as f32).collect(),
            momentum: (0..p).map(|_| rng.normal() as f32 * 0.1).collect(),
        })
        .collect()
}

/// One MAR aggregate call with fixed seeds; returns (states, ledger
/// delta, simulated clock, report).
#[allow(clippy::too_many_arguments)]
fn run_mar_budget(
    n: usize,
    m: usize,
    g: usize,
    p: usize,
    exchange: GroupExchange,
    rs_drop: f64,
    rs_retry_budget: usize,
    parallel: bool,
) -> (Vec<PeerState>, CommSnapshot, f64, AggReport) {
    let mut states = random_states(n, p, 0xC0FFEE ^ n as u64);
    let agg: Vec<usize> = (0..n).collect();
    let ledger = Arc::new(CommLedger::new());
    let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
    let mut clock = SimClock::new();
    let mut rng = Rng::new(77);
    let model = toy_model(p);
    let mut mar = MarAggregator::with_options(
        n,
        m,
        g,
        ledger.clone(),
        7,
        AggOptions {
            exchange,
            rs_drop,
            rs_retry_budget,
            parallel,
            ..AggOptions::default()
        },
    );
    ledger.reset(); // drop DHT join traffic
    let mut ctx = AggCtx {
        fabric: &fabric,
        clock: &mut clock,
        rng: &mut rng,
        runtime: None,
        model: &model,
        faults: &marfl::net::FaultConfig::OFF,
        links: None,
    };
    let report = mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
    (states, ledger.snapshot(), clock.now(), report)
}

/// [`run_mar_budget`] with the default (seed) retry budget of 0.
fn run_mar(
    n: usize,
    m: usize,
    g: usize,
    p: usize,
    exchange: GroupExchange,
    rs_drop: f64,
    parallel: bool,
) -> (Vec<PeerState>, CommSnapshot, f64) {
    let (states, snap, clock, _) =
        run_mar_budget(n, m, g, p, exchange, rs_drop, 0, parallel);
    (states, snap, clock)
}

/// The tentpole equivalence: chunk-owned reduce-scatter assembles the
/// exact full-gather average, bit for bit — on perfect grids and in
/// approximate mode — while moving 2/(M) of the bytes per phase pair.
#[test]
fn chunk_owned_result_bit_identical_to_full_gather() {
    for &(n, m, g) in &[(27usize, 3usize, 3usize), (8, 2, 3), (20, 3, 2)] {
        let (fg_states, fg_snap, _) =
            run_mar(n, m, g, 257, GroupExchange::FullGather, 0.0, true);
        let (rs_states, rs_snap, _) =
            run_mar(n, m, g, 257, GroupExchange::ReduceScatter, 0.0, true);
        for (i, (a, b)) in fg_states.iter().zip(&rs_states).enumerate() {
            assert_eq!(a.theta, b.theta, "peer {i} theta diverged (n={n})");
            assert_eq!(a.momentum, b.momentum, "peer {i} momentum diverged");
        }
        assert!(rs_snap.rs_bytes > 0, "no reduce-scatter traffic booked");
        assert_eq!(rs_snap.rs_bytes, rs_snap.ag_bytes);
        assert_eq!(rs_snap.data_bytes, rs_snap.rs_bytes + rs_snap.ag_bytes);
        assert_eq!(fg_snap.rs_bytes, 0, "full gather must book no phases");
        // 2(k−1)/k vs (k−1) state transfers per member: equal at M=2,
        // strictly cheaper for every larger group
        assert!(
            rs_snap.data_bytes <= fg_snap.data_bytes,
            "chunked exchange must not cost extra bytes (n={n})"
        );
        if m >= 3 {
            assert!(
                rs_snap.data_bytes < fg_snap.data_bytes,
                "chunked exchange must cut data bytes (n={n}, m={m})"
            );
        }
    }
}

/// A dropped chunk owner stalls its group's stripes; the survivors fall
/// back to a full gather among themselves and the victim goes stale —
/// the exchange still completes and the ledger shows plain (non-phase)
/// data traffic for the recovery.
#[test]
fn dropped_chunk_owner_degrades_gracefully() {
    // single group (3 = 3^1), drop probability 1: the fallback is certain
    let n = 3;
    let p = 129;
    let before = random_states(n, p, 0xC0FFEE ^ n as u64);
    let (states, snap, _) =
        run_mar(n, 3, 1, p, GroupExchange::ReduceScatter, 1.0, true);
    // exactly one peer (the victim) is bitwise stale
    let stale: Vec<usize> = (0..n)
        .filter(|&i| states[i].theta == before[i].theta)
        .collect();
    assert_eq!(stale.len(), 1, "exactly one dropped owner expected");
    let victim = stale[0];
    let survivors: Vec<usize> = (0..n).filter(|&i| i != victim).collect();
    let (want_t, want_m) = mean_of(&before, &survivors);
    for &i in &survivors {
        assert_eq!(states[i].theta, want_t, "survivor must hold the mean");
        assert_eq!(states[i].momentum, want_m);
    }
    // the aborted chunk exchange books nothing; the recovery books a
    // survivors-only full gather: 2 members × 1 transfer each
    assert_eq!(snap.rs_bytes, 0);
    assert_eq!(snap.ag_bytes, 0);
    let bytes = 2 * p as u64 * 4;
    assert_eq!(snap.data_msgs, 2);
    assert_eq!(snap.data_bytes, 2 * bytes);
}

/// Owner drops are schedule state drawn before the fan-out, so the
/// group-parallel engine stays bit-identical to the serial reference —
/// states, ledger totals and simulated clock — even mid-churn.
#[test]
fn rs_with_drops_parallel_matches_serial() {
    for &rs_drop in &[0.0, 0.5, 1.0] {
        let (s_states, s_snap, s_clock) =
            run_mar(27, 3, 3, 129, GroupExchange::ReduceScatter, rs_drop, false);
        let (p_states, p_snap, p_clock) =
            run_mar(27, 3, 3, 129, GroupExchange::ReduceScatter, rs_drop, true);
        for (a, b) in s_states.iter().zip(&p_states) {
            assert_eq!(a.theta, b.theta, "states diverged (rs_drop={rs_drop})");
            assert_eq!(a.momentum, b.momentum);
        }
        assert_eq!(s_snap, p_snap, "ledger diverged (rs_drop={rs_drop})");
        assert_eq!(s_clock.to_bits(), p_clock.to_bits(), "clock diverged");
    }
}

/// `mar.rs_retry_budget`: the same drop schedule is drawn either way
/// (victims, staleness and matchmaking are identical), but budgeted
/// groups *defer* — no survivors-only recovery gather, no averaging —
/// so every drop lands as either a fallback (budget 0) or a retry /
/// terminal-round fallback (budget on), and the budgeted run books
/// strictly fewer recovery bytes.
#[test]
fn retry_budget_defers_instead_of_falling_back() {
    let (seed_states, seed_snap, _, seed_rep) =
        run_mar_budget(27, 3, 3, 129, GroupExchange::ReduceScatter, 1.0, 0, true);
    let (ret_states, ret_snap, _, ret_rep) = run_mar_budget(
        27,
        3,
        3,
        129,
        GroupExchange::ReduceScatter,
        1.0,
        usize::MAX,
        true,
    );
    assert_eq!(seed_rep.reliability.rs_retries, 0, "budget 0 must never retry");
    assert!(seed_rep.reliability.rs_fallbacks > 0);
    assert!(ret_rep.reliability.rs_retries > 0, "an uncapped budget must retry");
    assert!(
        ret_rep.reliability.rs_fallbacks > 0,
        "final-round drops cannot retry (no round to re-form in)"
    );
    // identical drop schedule: every drop is accounted exactly once
    assert_eq!(
        seed_rep.reliability.rs_fallbacks,
        ret_rep.reliability.rs_fallbacks + ret_rep.reliability.rs_retries,
        "retries must re-label fallbacks, not change the drop schedule"
    );
    // deferring skips the survivors-only recovery gathers
    assert!(
        ret_snap.data_bytes < seed_snap.data_bytes,
        "retry runs must book fewer recovery bytes ({} vs {})",
        ret_snap.data_bytes,
        seed_snap.data_bytes
    );
    // and some retried groups' members keep their pre-round state
    // (they averaged nothing), so the state sets differ
    let diverged = seed_states
        .iter()
        .zip(&ret_states)
        .any(|(a, b)| a.theta != b.theta);
    assert!(diverged, "deferred groups must skip averaging");
}

/// A finite budget is consumed in draw order and then drops fall back
/// again; the drop schedule itself never changes.
#[test]
fn retry_budget_is_consumed_in_schedule_order() {
    let (_, _, _, unbounded) = run_mar_budget(
        27,
        3,
        3,
        129,
        GroupExchange::ReduceScatter,
        1.0,
        usize::MAX,
        true,
    );
    let budget = 2usize;
    let (_, _, _, capped) = run_mar_budget(
        27,
        3,
        3,
        129,
        GroupExchange::ReduceScatter,
        1.0,
        budget,
        true,
    );
    assert_eq!(capped.reliability.rs_retries, budget, "exactly the budget may be spent");
    assert_eq!(
        capped.reliability.rs_retries + capped.reliability.rs_fallbacks,
        unbounded.reliability.rs_retries + unbounded.reliability.rs_fallbacks,
        "total drops are schedule state, independent of the budget"
    );
}

/// Budgeted runs stay bit-identical across engines, like every other
/// schedule-state knob.
#[test]
fn retry_budget_parallel_matches_serial() {
    for &budget in &[1usize, 4] {
        let (s_states, s_snap, s_clock, s_rep) = run_mar_budget(
            27,
            3,
            3,
            129,
            GroupExchange::ReduceScatter,
            0.5,
            budget,
            false,
        );
        let (p_states, p_snap, p_clock, p_rep) = run_mar_budget(
            27,
            3,
            3,
            129,
            GroupExchange::ReduceScatter,
            0.5,
            budget,
            true,
        );
        for (a, b) in s_states.iter().zip(&p_states) {
            assert_eq!(a.theta, b.theta, "states diverged (budget={budget})");
            assert_eq!(a.momentum, b.momentum);
        }
        assert_eq!(s_snap, p_snap, "ledger diverged (budget={budget})");
        assert_eq!(s_clock.to_bits(), p_clock.to_bits(), "clock diverged");
        assert_eq!(s_rep, p_rep, "report diverged (budget={budget})");
    }
}

/// Off-grid (approximate) rounds form ragged groups; phase booking stays
/// exact for every group size the scheduler produces.
#[test]
fn phase_bytes_stay_exact_off_grid() {
    let (_, snap, _) = run_mar(20, 3, 2, 257, GroupExchange::ReduceScatter, 0.0, true);
    assert!(snap.rs_bytes > 0);
    assert_eq!(snap.rs_bytes, snap.ag_bytes);
    assert_eq!(snap.data_bytes, snap.rs_bytes + snap.ag_bytes);
    assert_eq!(snap.rs_msgs, snap.ag_msgs);
}

/// Churn under reduce-scatter still shrinks distortion toward the global
/// mean: dropped owners go stale, but every surviving group averages.
#[test]
fn rs_churn_still_reduces_distortion() {
    let n = 27;
    let p = 65;
    let before = random_states(n, p, 0xC0FFEE ^ n as u64);
    let agg: Vec<usize> = (0..n).collect();
    let (want_t, _) = mean_of(&before, &agg);
    let dist = |states: &[PeerState]| -> f64 {
        states
            .iter()
            .map(|s| {
                s.theta
                    .iter()
                    .zip(&want_t)
                    .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                    .sum::<f64>()
            })
            .sum()
    };
    let (after, _, _) =
        run_mar(n, 3, 3, p, GroupExchange::ReduceScatter, 0.3, true);
    assert!(
        dist(&after) < dist(&before) * 0.6,
        "churned reduce-scatter must still mix states"
    );
}
