//! SAPS-style exchange (Tang et al. 2020) — Table 1 related work.
//!
//! Sparsification and Adaptive Peer Selection: per iteration each peer
//! exchanges a **top-k sparsified** model with a single selected
//! high-throughput partner. Cheap on the wire (O(N · k) with k ≪ P), but —
//! the paper's critique — information spreads only *locally*, there is no
//! synchronized global aggregation, and sparsification discards mass, so
//! convergence is slow and churn-sensitive.
//!
//! Partner selection models SAPS' bandwidth-adaptive matching: peers are
//! paired greedily by descending link capacity (here: a static per-peer
//! capacity drawn once, standing in for measured throughput).

use anyhow::Result;

use super::{AggCtx, AggReport, Aggregate, PeerState};
use crate::metrics::Plane;
use crate::net::{FaultCounters, LinkFault};
use crate::rng::Rng;

/// Keep the `ratio` largest-magnitude entries of `v` (others zeroed).
/// Returns (sparse vector, kept count).
pub fn top_k_sparsify(v: &[f32], ratio: f64) -> (Vec<f32>, usize) {
    assert!((0.0..=1.0).contains(&ratio));
    let keep = ((v.len() as f64 * ratio).ceil() as usize).min(v.len());
    if keep == v.len() {
        return (v.to_vec(), keep);
    }
    let mut idx: Vec<usize> = (0..v.len()).collect();
    idx.select_nth_unstable_by(keep.saturating_sub(1), |&a, &b| {
        v[b].abs().partial_cmp(&v[a].abs()).unwrap()
    });
    let mut out = vec![0.0f32; v.len()];
    for &i in &idx[..keep] {
        out[i] = v[i];
    }
    (out, keep)
}

#[derive(Debug)]
pub struct Saps {
    /// sparsification ratio (fraction of parameters exchanged)
    pub ratio: f64,
    /// static per-peer link capacities (populated lazily)
    capacities: Vec<f64>,
}

impl Default for Saps {
    fn default() -> Self {
        Saps { ratio: 0.05, capacities: Vec::new() }
    }
}

impl Saps {
    /// Greedy capacity-descending pairing (SAPS' adaptive peer selection).
    fn pair(&mut self, agg: &[usize], rng: &mut Rng) -> Vec<(usize, usize)> {
        let max_peer = agg.iter().copied().max().unwrap_or(0);
        while self.capacities.len() <= max_peer {
            self.capacities.push(rng.range_f64(0.2, 1.0));
        }
        let mut order: Vec<usize> = agg.to_vec();
        order.sort_by(|&a, &b| {
            self.capacities[b].partial_cmp(&self.capacities[a]).unwrap()
        });
        order.chunks(2).filter(|c| c.len() == 2).map(|c| (c[0], c[1])).collect()
    }
}

impl Aggregate for Saps {
    fn name(&self) -> &'static str {
        "saps"
    }

    fn aggregate(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport> {
        let fp = ctx.faults;
        let mut faults = FaultCounters::default();
        // fault plan: crashed peers are never paired (draws gated — the
        // fault-free path consumes no extra randomness)
        let live: Vec<usize> = if fp.crash_prob > 0.0 {
            agg.iter()
                .copied()
                .filter(|_| {
                    if ctx.rng.chance(fp.crash_prob) {
                        faults.crashes += 1;
                        false
                    } else {
                        true
                    }
                })
                .collect()
        } else {
            agg.to_vec()
        };
        let agg = &live[..];
        if agg.len() < 2 {
            return Ok(AggReport { faults, ..Default::default() });
        }
        let pairs = self.pair(agg, ctx.rng);
        let p = states[agg[0]].theta.len();
        // sparse payload: kept values + their indices (4 B value + 4 B idx)
        let kept = ((p as f64 * self.ratio).ceil() as usize).min(p);
        let bytes = (kept * 8) as u64 * 2; // theta + momentum planes
        // pairs are disjoint, so every sparsify+merge lane runs
        // concurrently on the exec pool
        let groups: Vec<Vec<usize>> =
            pairs.iter().map(|&(a, b)| vec![a, b]).collect();
        // per-direction link draws (serial, pair order): a direction
        // whose sparse packet times out is booked but never merged
        let pair_links: Vec<(LinkFault, LinkFault)> =
            if fp.link_faults_enabled() {
                pairs
                    .iter()
                    .map(|&(a, b)| {
                        // each direction of the pair keys its own
                        // Gilbert–Elliott chain
                        let ab = fp.draw_directed(
                            a,
                            b,
                            1,
                            false,
                            ctx.links.as_deref_mut(),
                            ctx.rng,
                        );
                        faults.absorb(&ab);
                        let ba = fp.draw_directed(
                            b,
                            a,
                            1,
                            false,
                            ctx.links.as_deref_mut(),
                            ctx.rng,
                        );
                        faults.absorb(&ba);
                        (ab, ba)
                    })
                    .collect()
            } else {
                Vec::new()
            };
        let ratio = self.ratio;
        let fabric = ctx.fabric;
        let lane_times =
            crate::exec::par_disjoint_map(states, &groups, |gi, views| {
                // bidirectional sparsified exchange
                let (got_ab, got_ba, t) = match pair_links.get(gi) {
                    Some(&(ab, ba)) => (
                        !ab.lost(),
                        !ba.lost(),
                        fabric.send_faulty(bytes, Plane::Data, &ab)
                            + fabric.send_faulty(bytes, Plane::Data, &ba),
                    ),
                    None => (
                        true,
                        true,
                        fabric.send(bytes, Plane::Data)
                            + fabric.send(bytes, Plane::Data),
                    ),
                };
                let (va, vb) = views.split_at_mut(1);
                let a = &mut *va[0];
                let b = &mut *vb[0];
                let (sa_t, _) = top_k_sparsify(&a.theta, ratio);
                let (sb_t, _) = top_k_sparsify(&b.theta, ratio);
                let (sa_m, _) = top_k_sparsify(&a.momentum, ratio);
                let (sb_m, _) = top_k_sparsify(&b.momentum, ratio);
                // merge: average own dense state with partner's sparse one
                // at the transmitted coordinates (SAPS-style partial
                // merge). make_mut detaches any shared storage first.
                if got_ba {
                    merge_sparse(a.theta.make_mut(), &sb_t);
                }
                if got_ab {
                    merge_sparse(b.theta.make_mut(), &sa_t);
                }
                if got_ba {
                    merge_sparse(a.momentum.make_mut(), &sb_m);
                }
                if got_ab {
                    merge_sparse(b.momentum.make_mut(), &sa_m);
                }
                t
            })?;
        ctx.clock.parallel(lane_times);
        Ok(AggReport {
            rounds: 1,
            groups: pairs.len(),
            faults,
            ..Default::default()
        })
    }
}

/// Average `dst` with the non-zero coordinates of `sparse`.
fn merge_sparse(dst: &mut [f32], sparse: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(sparse) {
        if s != 0.0 {
            *d = 0.5 * (*d + s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::test_support::*;
    use crate::coordinator::mixing::avg_distortion;

    #[test]
    fn top_k_keeps_largest_magnitudes() {
        let v = vec![0.1f32, -5.0, 0.2, 3.0, -0.05];
        let (s, kept) = top_k_sparsify(&v, 0.4);
        assert_eq!(kept, 2);
        assert_eq!(s, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn top_k_full_ratio_is_identity() {
        let v = vec![1.0f32, 2.0, 3.0];
        let (s, kept) = top_k_sparsify(&v, 1.0);
        assert_eq!(kept, 3);
        assert_eq!(s, v);
    }

    #[test]
    fn traffic_far_below_dense_exchange() {
        let n = 16;
        let p = 1024;
        let mut states = random_states(n, p, 60);
        let agg: Vec<usize> = (0..n).collect();
        let mut tc = TestCtx::new(p);
        let mut ctx = tc.ctx();
        Saps::default().aggregate(&mut states, &agg, &mut ctx).unwrap();
        let sparse_bytes = tc.ledger.snapshot().data_bytes;
        // dense pairwise exchange would be n * 2p * 4 * 2 planes... just
        // check we are at least 5x below one dense all-state pass
        let dense = (n as u64) * (2 * p as u64 * 4);
        assert!(
            sparse_bytes * 5 < dense,
            "sparse {sparse_bytes} not far below dense {dense}"
        );
    }

    #[test]
    fn pairwise_exchange_mixes_far_slower_than_mar() {
        let n = 27;
        let p = 64;
        let agg: Vec<usize> = (0..n).collect();
        let mut s_states = random_states(n, p, 61);
        let mut tc = TestCtx::new(p);
        let mut saps = Saps::default();
        let mut ctx = tc.ctx();
        saps.aggregate(&mut s_states, &agg, &mut ctx).unwrap();
        let after_saps = avg_distortion(
            &s_states.iter().map(|s| s.theta.clone()).collect::<Vec<_>>(),
        );
        let mut m_states = random_states(n, p, 61);
        let mut tc2 = TestCtx::new(p);
        let mut mar = crate::coordinator::MarAggregator::new(
            n,
            3,
            3,
            tc2.ledger.clone(),
            62,
        );
        let mut ctx2 = tc2.ctx();
        mar.aggregate(&mut m_states, &agg, &mut ctx2).unwrap();
        let after_mar = avg_distortion(
            &m_states.iter().map(|s| s.theta.clone()).collect::<Vec<_>>(),
        );
        assert!(
            after_mar < after_saps * 1e-3,
            "no global aggregation: SAPS {after_saps:.3e} vs MAR {after_mar:.3e}"
        );
    }

    #[test]
    fn capacity_pairing_is_deterministic_per_engine() {
        let mut saps = Saps::default();
        let agg: Vec<usize> = (0..10).collect();
        let mut rng = crate::rng::Rng::new(63);
        let a = saps.pair(&agg, &mut rng);
        let b = saps.pair(&agg, &mut rng);
        assert_eq!(a, b, "capacities are static once drawn");
        assert_eq!(a.len(), 5);
    }
}
