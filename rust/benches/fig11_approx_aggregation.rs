//! Figure 11 — approximate aggregation: trading exactness for bytes.
//!
//! Paper claim: on 125 peers, relaxing the exact configuration (M=5, G=3,
//! 5³=125) to M=3, G=4 yields only approximate per-iteration averages but
//! cuts communication by up to 33% with no substantial loss in model
//! utility — approximations converge to near-exact global averages over
//! iterations (Eq. 1).

#[path = "common/mod.rs"]
mod common;

use common::{assert_stable_columns, emit_bench_report, emit_csv, iters, mib, runtime, timed};
use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;

fn main() {
    let rt = runtime();
    let t = iters(20, 50);
    let peers = 125;
    println!("Figure 11 — approximate aggregation (peers={peers}, T={t})\n");
    let base = ExperimentConfig {
        model: "head".into(),
        peers,
        iterations: t,
        samples_per_peer: 64,
        test_samples: 1000,
        eval_every: 4,
        seed: 1111,
        ..Default::default()
    };

    // (label, M, G, reduce_scatter): exact 5^3 grid, the paper's
    // approximate relaxation, and the chunk-owned wire protocol on the
    // exact grid (both phases reported from the ledger sub-counters)
    let variants = [
        ("exact M=5 G=3", 5usize, 3usize, false),
        ("approx M=3 G=4", 3, 4, false),
        ("exact M=5 G=3 + reduce-scatter", 5, 3, true),
    ];
    let mut rows = vec![vec![
        "variant".into(),
        "group_size".into(),
        "mar_rounds".into(),
        "data_bytes".into(),
        "rs_bytes".into(),
        "ag_bytes".into(),
        "final_accuracy".into(),
    ]];
    let mut out = Vec::new();
    for (label, m, g, reduce_scatter) in variants {
        let cfg = ExperimentConfig {
            group_size: m,
            mar_rounds: g,
            reduce_scatter,
            ..base.clone()
        };
        let run = timed(label, || Trainer::new(cfg, &rt).unwrap().run().unwrap());
        println!(
            "    data {:.0} MiB (RS {:.0} + AG {:.0})  acc {:.3}",
            mib(run.comm.data_bytes),
            mib(run.comm.rs_bytes),
            mib(run.comm.ag_bytes),
            run.final_accuracy
        );
        rows.push(vec![
            label.into(),
            m.to_string(),
            g.to_string(),
            run.comm.data_bytes.to_string(),
            run.comm.rs_bytes.to_string(),
            run.comm.ag_bytes.to_string(),
            format!("{:.4}", run.final_accuracy),
        ]);
        out.push((label, run));
    }
    assert_stable_columns(
        "fig11_approx_aggregation.csv",
        &rows,
        &[
            "variant",
            "group_size",
            "mar_rounds",
            "data_bytes",
            "rs_bytes",
            "ag_bytes",
            "final_accuracy",
        ],
    );
    emit_csv("fig11_approx_aggregation.csv", &rows);
    emit_bench_report("approx_agg", "approx_aggregation", &rows);

    let exact = &out[0].1;
    let approx = &out[1].1;
    let saving = 1.0 - approx.comm.data_bytes as f64 / exact.comm.data_bytes as f64;
    println!(
        "\ncommunication saving: {:.0}% (paper: up to 33%)",
        saving * 100.0
    );
    println!(
        "accuracy: exact {:.3} vs approx {:.3}",
        exact.final_accuracy, approx.final_accuracy
    );
    assert!(
        saving > 0.15,
        "approximate mode must reduce communication meaningfully"
    );
    assert!(
        approx.final_accuracy > exact.final_accuracy - 0.08,
        "approximate aggregation must preserve model utility"
    );

    // chunk ownership: same exact grid, 2(M−1)/M instead of (M−1) state
    // transfers per member, and bit-identical averaging
    let rs = &out[2].1;
    println!(
        "reduce-scatter on the exact grid: {:.0} MiB vs {:.0} MiB full-gather \
         ({:.2}x), acc {:.3}",
        mib(rs.comm.data_bytes),
        mib(exact.comm.data_bytes),
        exact.comm.data_bytes as f64 / rs.comm.data_bytes as f64,
        rs.final_accuracy
    );
    assert!(
        rs.comm.data_bytes < exact.comm.data_bytes,
        "chunk ownership must cut data bytes on the same schedule"
    );
    assert_eq!(
        rs.comm.data_bytes,
        rs.comm.rs_bytes + rs.comm.ag_bytes,
        "RS-mode data traffic must be exactly the two phases"
    );
    assert!(
        (rs.final_accuracy - exact.final_accuracy).abs() < 1e-12,
        "chunk-owned averaging is bit-identical; accuracy must match"
    );
}
