//! Model-compute runtime. This is the ONLY place model compute happens at
//! run time — Python is never on the request path.
//!
//! Two interchangeable backends sit behind one facade:
//!
//! * **native** (default build) — a pure-Rust reference implementation of
//!   the model zoo (`native.rs`): the same forward/backward/damped-momentum
//!   semantics `python/compile/model.py` lowers, over the same
//!   flat-parameter ABI. Needs no artifacts and no XLA closure, so
//!   `cargo build && cargo test` work on any machine.
//! * **pjrt** (`--features pjrt`) — loads the AOT HLO-text artifacts and
//!   executes them through a PJRT CPU client (`pjrt.rs`), following
//!   /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//!   `XlaComputation::from_proto` → `client.compile` (cached per entry
//!   point) → `execute`. Selected automatically when the feature is on and
//!   `meta.json` exists; `MARFL_BACKEND=native` forces the fallback.
//!
//! The facade is `Sync`: the peer-parallel trainer (`fl`) drives
//! `train_step_into` from many `exec` pool workers at once. Native
//! compute is thread-safe (its scratch arenas are per-worker
//! thread-locals); the PJRT executable cache is behind locks and XLA's
//! client/executables support concurrent execution.
//!
//! The hot path is the **in-place step API** (`train_step_into` /
//! `kd_step_into`): the fused damped-momentum update is written straight
//! into the caller's `Theta::make_mut` buffers and nothing is allocated
//! per step. The original `StepOut`-returning signatures remain as thin
//! compat shims over it (both backends), bit-identical by construction.

#[cfg(feature = "pjrt")]
pub mod literal;
pub mod native;
#[cfg(feature = "pjrt")]
mod pjrt;

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

use crate::models::{ArtifactMeta, ModelMeta};
use crate::telemetry::{Counter, Metric, MetricRegistry, MetricValue};

/// Backend dispatch + per-entry-point execution accounting.
pub struct Runtime {
    pub meta: ArtifactMeta,
    backend: Backend,
    /// per-model execution counters, resolved to registry handles once
    /// at construction so the step hot path books without formatting a
    /// key or touching the name map
    keys: HashMap<String, EntryCounters>,
    /// the runtime's own metric registry: every `{model}_{entry}`
    /// counter lives here (the old striped `calls` maps and per-call key
    /// matching are gone)
    registry: MetricRegistry,
}

/// Pre-registered `{model}_{entry}` counter handles (one set per
/// registry model). Same key names as the seed's per-call `format!`
/// produced, so `call_counts()` output is unchanged.
struct EntryCounters {
    train_step: Counter,
    kd_step: Counter,
    logits: Counter,
    eval: Counter,
    /// `group_mean_{model}_{k}` per supported group size k
    group_mean: Vec<(usize, Counter)>,
}

impl EntryCounters {
    fn register(
        reg: &MetricRegistry,
        model: &str,
        group_sizes: &[usize],
    ) -> Result<Self> {
        Ok(EntryCounters {
            train_step: reg.counter(&format!("{model}_train_step"))?,
            kd_step: reg.counter(&format!("{model}_kd_step"))?,
            logits: reg.counter(&format!("{model}_logits"))?,
            eval: reg.counter(&format!("{model}_eval"))?,
            group_mean: group_sizes
                .iter()
                .map(|&k| {
                    Ok((k, reg.counter(&format!("group_mean_{model}_{k}"))?))
                })
                .collect::<Result<_>>()?,
        })
    }
}

enum Backend {
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

/// Result of one local training / KD step on the compat path
/// ([`Runtime::train_step`] / [`Runtime::kd_step`]): freshly owned
/// `Vec`s a caller can move straight into copy-on-write `params::Theta`
/// handles. The hot path is the in-place API
/// ([`Runtime::train_step_into`] / [`Runtime::kd_step_into`]), which
/// writes the fused update through `Theta::make_mut` buffers and
/// allocates nothing — copy-on-write is what keeps those writes from
/// ever landing in storage shared with snapshots or groupmates.
#[derive(Clone, Debug)]
pub struct StepOut {
    pub theta: Vec<f32>,
    pub momentum: Vec<f32>,
    pub loss: f32,
}

impl Runtime {
    /// Open a runtime over an artifact directory. When no artifacts have
    /// been lowered there, the builtin model registry + native backend
    /// are used so the full system runs artifact-free. A *present but
    /// unreadable* `meta.json` is still a hard error — silently swapping
    /// in the builtin registry under real artifacts would execute lowered
    /// HLO against mismatched metadata.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let meta = if artifact_dir.join("meta.json").exists() {
            ArtifactMeta::load(artifact_dir)?
        } else {
            log::info!(
                "no artifacts at {artifact_dir:?}; \
                 using builtin model registry + native backend"
            );
            ArtifactMeta::builtin(artifact_dir)
        };
        let backend = Self::pick_backend(&meta)?;
        let registry = MetricRegistry::new();
        let keys = meta
            .models
            .keys()
            .map(|name| {
                Ok((
                    name.clone(),
                    EntryCounters::register(&registry, name, &meta.group_sizes)?,
                ))
            })
            .collect::<Result<_>>()?;
        Ok(Runtime { meta, backend, keys, registry })
    }

    #[cfg(feature = "pjrt")]
    fn pick_backend(meta: &ArtifactMeta) -> Result<Backend> {
        let forced_native = std::env::var_os("MARFL_BACKEND")
            .is_some_and(|v| v.to_str() == Some("native"));
        if !forced_native && meta.dir.join("meta.json").exists() {
            return Ok(Backend::Pjrt(pjrt::PjrtBackend::new(&meta.dir)?));
        }
        Ok(Backend::Native)
    }

    #[cfg(not(feature = "pjrt"))]
    fn pick_backend(_meta: &ArtifactMeta) -> Result<Backend> {
        Ok(Backend::Native)
    }

    /// Which backend executes compute ("native" or "pjrt").
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Native => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Load the shared initial parameters for `model` (paper: every peer
    /// starts from the same randomly initialized θ⁰). With real artifacts
    /// (`meta.json` present) the lowered `{m}_init.bin` is REQUIRED — a
    /// missing file is a hard error, not a silent swap to different
    /// initial weights. Only the builtin artifact-free registry uses the
    /// native backend's deterministic He initialization.
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let m = self.meta.model(model)?;
        if self.meta.dir.join("meta.json").exists() {
            let path = self.meta.artifact_path(&m.init_file);
            let theta = crate::util::read_f32_le(&path)?;
            anyhow::ensure!(
                theta.len() == m.padded_len,
                "{path:?}: expected {} f32, got {}",
                m.padded_len,
                theta.len()
            );
            Ok(theta)
        } else {
            native::init_params(m)
        }
    }

    /// Pre-compile a set of entry points (avoids first-use jitter in
    /// benches). No-op on the native backend.
    pub fn warmup(&self, entries: &[String]) -> Result<()> {
        match &self.backend {
            Backend::Native => Ok(()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.warmup(entries),
        }
    }

    /// The runtime's metric registry (every `{model}_{entry}` counter).
    pub fn metric_registry(&self) -> &MetricRegistry {
        &self.registry
    }

    /// Per-entry execution counts (perf diagnostics). Read back from the
    /// registry; entries that never executed are omitted, matching the
    /// lazily-populated maps this view replaced.
    pub fn call_counts(&self) -> HashMap<String, u64> {
        self.registry
            .snapshot()
            .into_iter()
            .filter_map(|(name, v)| match v {
                MetricValue::Counter(n) if n > 0 => Some((name, n)),
                _ => None,
            })
            .collect()
    }

    /// Count a per-model entry point through the pre-registered handles;
    /// ad-hoc metas outside the artifact registry fall back to the
    /// registry's get-or-register cold path.
    fn count_model(
        &self,
        m: &ModelMeta,
        pick: fn(&EntryCounters) -> &Counter,
        suffix: &str,
    ) {
        match self.keys.get(m.name.as_str()) {
            Some(keys) => pick(keys).inc(),
            None => {
                if let Ok(c) = self
                    .registry
                    .counter_or_existing(&format!("{}_{suffix}", m.name))
                {
                    c.inc();
                }
            }
        }
    }

    // -----------------------------------------------------------------
    // Typed entry points (flat-parameter ABI)
    // -----------------------------------------------------------------

    /// One local momentum-SGD step over a batch, applied **in place**:
    /// the fused damped-momentum update lands directly in `theta` /
    /// `momentum` — the buffers a caller obtains from
    /// `params::Theta::make_mut` — so the native step allocates nothing.
    /// Returns the batch loss.
    pub fn train_step_into(
        &self,
        m: &ModelMeta,
        theta: &mut [f32],
        momentum: &mut [f32],
        x: &[f32],
        y: &[i32],
        eta: f32,
        mu: f32,
    ) -> Result<f32> {
        debug_assert_eq!(theta.len(), m.padded_len);
        debug_assert_eq!(x.len(), m.batch * m.input_elems());
        debug_assert_eq!(y.len(), m.batch);
        self.count_model(m, |k| &k.train_step, "train_step");
        match &self.backend {
            Backend::Native => {
                native::train_step_into(m, theta, momentum, x, y, eta, mu)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.train_step_into(m, theta, momentum, x, y, eta, mu),
        }
    }

    /// One local momentum-SGD step over a batch — compat shim over
    /// [`Self::train_step_into`] returning freshly owned buffers.
    pub fn train_step(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        eta: f32,
        mu: f32,
    ) -> Result<StepOut> {
        let mut theta2 = theta.to_vec();
        let mut momentum2 = momentum.to_vec();
        let loss = self.train_step_into(m, &mut theta2, &mut momentum2, x, y, eta, mu)?;
        Ok(StepOut { theta: theta2, momentum: momentum2, loss })
    }

    /// One Moshpit-KD student step (Algorithm 2), applied **in place**
    /// like [`Self::train_step_into`]. Returns the distillation loss.
    #[allow(clippy::too_many_arguments)]
    pub fn kd_step_into(
        &self,
        m: &ModelMeta,
        theta: &mut [f32],
        momentum: &mut [f32],
        x: &[f32],
        y: &[i32],
        zbar: &[f32],
        lambda: f32,
        eta: f32,
        mu: f32,
    ) -> Result<f32> {
        debug_assert_eq!(zbar.len(), m.batch * m.classes);
        self.count_model(m, |k| &k.kd_step, "kd_step");
        match &self.backend {
            Backend::Native => {
                // τ is baked into the lowered artifact; the native path
                // takes it from the registry
                let tau = self.meta.kd_tau as f32;
                native::kd_step_into(m, theta, momentum, x, y, zbar, lambda, tau, eta, mu)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => {
                b.kd_step_into(m, theta, momentum, x, y, zbar, lambda, eta, mu)
            }
        }
    }

    /// One Moshpit-KD student step — compat shim over
    /// [`Self::kd_step_into`] returning freshly owned buffers.
    #[allow(clippy::too_many_arguments)]
    pub fn kd_step(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        zbar: &[f32],
        lambda: f32,
        eta: f32,
        mu: f32,
    ) -> Result<StepOut> {
        let mut theta2 = theta.to_vec();
        let mut momentum2 = momentum.to_vec();
        let loss = self
            .kd_step_into(m, &mut theta2, &mut momentum2, x, y, zbar, lambda, eta, mu)?;
        Ok(StepOut { theta: theta2, momentum: momentum2, loss })
    }

    /// Teacher forward pass: logits for one training batch, written into
    /// `out` (cleared first). On the native backend the forward caches
    /// live in the per-worker workspace, so the call is allocation-free
    /// once `out` has capacity.
    pub fn logits_into(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        x: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.count_model(m, |k| &k.logits, "logits");
        match &self.backend {
            Backend::Native => native::logits_into(m, theta, x, out),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => {
                let z = b.logits(m, theta, x)?;
                out.clear();
                out.extend_from_slice(&z);
                Ok(())
            }
        }
    }

    /// Teacher forward pass: logits for one training batch.
    pub fn logits(&self, m: &ModelMeta, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.logits_into(m, theta, x, &mut out)?;
        Ok(out)
    }

    /// Evaluate over a full test set (x row-major, len multiple of the
    /// eval chunk). Returns (mean loss, accuracy).
    pub fn evaluate(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(f64, f64)> {
        let n = y.len();
        let elems = m.input_elems();
        anyhow::ensure!(
            n % m.eval_chunk == 0,
            "test set size {n} not a multiple of eval chunk {}",
            m.eval_chunk
        );
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for c in 0..n / m.eval_chunk {
            let xs = &x[c * m.eval_chunk * elems..(c + 1) * m.eval_chunk * elems];
            let ys = &y[c * m.eval_chunk..(c + 1) * m.eval_chunk];
            self.count_model(m, |k| &k.eval, "eval");
            let (ls, cr) = match &self.backend {
                Backend::Native => native::eval_chunk(m, theta, xs, ys)?,
                #[cfg(feature = "pjrt")]
                Backend::Pjrt(b) => b.eval_chunk(m, theta, xs, ys)?,
            };
            loss_sum += ls;
            correct += cr;
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }

    /// Average `k` stacked flat vectors through the group-mean kernel.
    /// `stack` is row-major `[k, padded_len]`.
    pub fn group_mean(&self, m: &ModelMeta, stack: &[f32], k: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            self.meta.group_sizes.contains(&k),
            "no group_mean artifact for k={k} (have {:?})",
            self.meta.group_sizes
        );
        debug_assert_eq!(stack.len(), k * m.padded_len);
        match self
            .keys
            .get(m.name.as_str())
            .and_then(|ks| ks.group_mean.iter().find(|(gk, _)| *gk == k))
        {
            Some((_, c)) => c.inc(),
            None => {
                if let Ok(c) = self
                    .registry
                    .counter_or_existing(&format!("group_mean_{}_{k}", m.name))
                {
                    c.inc();
                }
            }
        }
        match &self.backend {
            Backend::Native => native::group_mean(m, stack, k),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.group_mean(m, stack, k),
        }
    }
}

// Runtime's own Send/Sync derive automatically from its fields; on pjrt
// builds that hinges on the scoped `unsafe impl Send/Sync for
// PjrtBackend` in pjrt.rs (where the serialization invariant lives), so
// the compiler keeps checking every other Runtime field.

#[cfg(test)]
mod tests {
    // Full runtime execution tests live in rust/tests/runtime_integration.rs
    // (they run against whichever backend the build selects). Unit tests
    // here cover facade-only logic.
    use super::*;

    #[test]
    fn step_out_is_cloneable_value_type() {
        let s = StepOut { theta: vec![1.0], momentum: vec![0.0], loss: 0.5 };
        let t = s.clone();
        assert_eq!(t.loss, 0.5);
    }

    #[test]
    fn artifact_free_runtime_uses_native_backend() {
        let rt = Runtime::new(Path::new("/nonexistent_marfl_artifacts")).unwrap();
        assert_eq!(rt.backend_name(), "native");
        assert!(rt.meta.models.contains_key("cnn"));
        assert!(rt.meta.models.contains_key("head"));
    }

    #[test]
    fn runtime_is_sync() {
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<Runtime>();
    }

    #[test]
    fn entry_counters_are_preregistered_for_every_registry_model() {
        let rt = Runtime::new(Path::new("/nonexistent_marfl_artifacts")).unwrap();
        for name in rt.meta.models.keys() {
            // registered under the seed's key names…
            for entry in ["train_step", "kd_step", "logits", "eval"] {
                assert!(
                    rt.registry.get(&format!("{name}_{entry}")).is_some(),
                    "{name}_{entry} not pre-registered"
                );
            }
            for k in &rt.meta.group_sizes {
                assert!(rt.registry.get(&format!("group_mean_{name}_{k}")).is_some());
            }
            assert_eq!(rt.keys[name].group_mean.len(), rt.meta.group_sizes.len());
        }
        // …but absent from call_counts until executed (the seed's maps
        // were lazily populated)
        assert!(!rt.call_counts().contains_key("cnn_train_step"));
        // counting through the handles lands on the same names the
        // seed's per-call format! produced
        let m = rt.meta.model("cnn").unwrap().clone();
        rt.count_model(&m, |k| &k.train_step, "train_step");
        rt.count_model(&m, |k| &k.train_step, "train_step");
        assert_eq!(rt.call_counts()["cnn_train_step"], 2);
        // ad-hoc metas outside the artifact registry take the
        // get-or-register cold path under the same naming scheme
        let mut toy = m.clone();
        toy.name = "toy".into();
        rt.count_model(&toy, |k| &k.train_step, "train_step");
        assert_eq!(rt.call_counts()["toy_train_step"], 1);
    }
}
