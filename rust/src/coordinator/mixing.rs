//! Mixing dynamics of MAR (paper §2.3, Eq. 1; Ryabinin et al. 2021).
//!
//! For random partitioning of N peers into r groups that average locally,
//! the expected average squared distance to the global mean contracts by
//!
//! ```text
//! factor(N, r) = (r - 1)/N + r/N²
//! ```
//!
//! per averaging iteration — independent of the communication graph's
//! spectral properties. The deterministic key schedule MAR actually uses
//! mixes at least this fast (exactly 0 after d rounds on a perfect grid);
//! the property tests validate both statements against simulation.

/// One-iteration contraction factor of Eq. 1.
pub fn distortion_factor(n: usize, r: usize) -> f64 {
    assert!(n >= 1 && r >= 1);
    let (n, r) = (n as f64, r as f64);
    (r - 1.0) / n + r / (n * n)
}

/// Expected distortion after `t` iterations from initial distortion `d0`.
pub fn expected_distortion(d0: f64, n: usize, r: usize, t: usize) -> f64 {
    d0 * distortion_factor(n, r).powi(t as i32)
}

/// Measured average squared distance to the global mean:
/// (1/N) Σ_i ‖θ_i − θ̄‖². Generic over the vector handle so both
/// `Vec<f32>` rows and zero-copy [`crate::params::Theta`] handles work.
pub fn avg_distortion<V: AsRef<[f32]>>(values: &[V]) -> f64 {
    let n = values.len();
    assert!(n > 0);
    let p = values[0].as_ref().len();
    let mut mean = vec![0.0f64; p];
    for v in values {
        for (a, &x) in mean.iter_mut().zip(v.as_ref()) {
            *a += x as f64;
        }
    }
    for a in &mut mean {
        *a /= n as f64;
    }
    values
        .iter()
        .map(|v| {
            v.as_ref()
                .iter()
                .zip(&mean)
                .map(|(&x, &m)| (x as f64 - m) * (x as f64 - m))
                .sum::<f64>()
        })
        .sum::<f64>()
        / n as f64
}

/// One random-grouping averaging iteration (the Eq. 1 model): partition
/// `values` uniformly into `r` groups, replace members by the group mean.
pub fn random_grouping_round(
    values: &mut [Vec<f32>],
    r: usize,
    rng: &mut crate::rng::Rng,
) {
    let n = values.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    // deal peers into r groups round-robin over a random order — a
    // uniform random partition into r cells (sizes as equal as possible)
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); r];
    for (i, peer) in order.into_iter().enumerate() {
        groups[i % r].push(peer);
    }
    let p = values[0].len();
    for group in groups {
        if group.len() < 2 {
            continue;
        }
        let mut mean = vec![0.0f64; p];
        for &i in &group {
            for (a, &x) in mean.iter_mut().zip(&values[i]) {
                *a += x as f64;
            }
        }
        for a in &mut mean {
            *a /= group.len() as f64;
        }
        for &i in &group {
            for (dst, &m) in values[i].iter_mut().zip(&mean) {
                *dst = m as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::check;

    #[test]
    fn factor_matches_paper_examples() {
        // N = 125, r = 25 groups (size 5): (24/125) + (25/15625)
        let f = distortion_factor(125, 25);
        assert!((f - (24.0 / 125.0 + 25.0 / 15625.0)).abs() < 1e-12);
        // r = 1 (one global group): factor = 1/N² -> near-exact in one shot
        assert!(distortion_factor(100, 1) < 1e-3);
    }

    #[test]
    fn expected_distortion_decays_geometrically() {
        let d0 = 4.0;
        let one = expected_distortion(d0, 50, 10, 1);
        let two = expected_distortion(d0, 50, 10, 2);
        assert!((two / one - distortion_factor(50, 10)).abs() < 1e-12);
    }

    #[test]
    fn avg_distortion_zero_iff_consensus() {
        let consensus = vec![vec![1.0f32, 2.0]; 5];
        assert!(avg_distortion(&consensus) < 1e-15);
        let spread = vec![vec![0.0f32], vec![2.0f32]];
        assert!((avg_distortion(&spread) - 1.0).abs() < 1e-12);
    }

    /// Monte-Carlo validation of Eq. 1: measured contraction of random
    /// grouping matches the analytic factor within statistical tolerance.
    #[test]
    fn eq1_contraction_measured() {
        let n = 60;
        let r = 12; // groups of 5
        let trials = 400;
        let mut rng = Rng::new(0xE91);
        let mut measured_sum = 0.0;
        for _ in 0..trials {
            let mut values: Vec<Vec<f32>> = (0..n)
                .map(|_| vec![rng.normal() as f32])
                .collect();
            let before = avg_distortion(&values);
            random_grouping_round(&mut values, r, &mut rng);
            measured_sum += avg_distortion(&values) / before;
        }
        let measured = measured_sum / trials as f64;
        let analytic = distortion_factor(n, r);
        // Eq. 1 is derived for an idealized partition model; round-robin
        // dealing (equal-size groups) mixes slightly *faster*, so accept
        // [0.5x, 1.1x] of the analytic factor
        assert!(
            measured < analytic * 1.1 && measured > analytic * 0.5,
            "measured {measured:.4} vs analytic {analytic:.4}"
        );
    }

    /// Property: repeated random-grouping rounds drive distortion to ~0
    /// at at least the Eq. 1 rate, for random sizes.
    #[test]
    fn property_mixing_bound() {
        check("mixing_bound", 12, 40, |rng, size| {
            let n = (size.0 + 10).min(50);
            let r = (n / 4).max(2);
            let mut values: Vec<Vec<f32>> =
                (0..n).map(|_| vec![rng.normal() as f32 * 2.0]).collect();
            let d0 = avg_distortion(&values);
            let t = 6;
            for _ in 0..t {
                random_grouping_round(&mut values, r, rng);
            }
            let measured = avg_distortion(&values);
            // generous slack (single sample path): 50x the expectation
            // still separates geometric decay from stagnation
            let bound = expected_distortion(d0, n, r, t) * 50.0 + 1e-12;
            if measured > bound {
                return Err(format!(
                    "distortion {measured:.3e} exceeds 50x Eq.1 bound {bound:.3e} (n={n}, r={r})"
                ));
            }
            Ok(())
        });
    }
}
