//! Simulated Kademlia DHT — the MAR-FL control plane.
//!
//! The paper coordinates group formation through a Hivemind Kademlia DHT:
//! barriers and group-key announcements travel the DHT, model weights never
//! do. This module reproduces that substrate in-process with byte-accurate
//! message accounting so the control-plane O(N log N) claim is measurable:
//! each iterative lookup costs O(log N) query round-trips, and a round's
//! matchmaking issues O(N) get/store operations.
//!
//! Realism choices: α-parallel iterative lookup (α = 3), k = 8 buckets with
//! LRU eviction, store-to-k-closest replication, per-message byte sizes
//! modelled on Kademlia RPC framing. Liveness pings and UDP loss are out of
//! scope (the paper's churn acts at the aggregation layer, which injects
//! dropouts explicitly — see `net::churn`).

pub mod id;
pub mod routing;

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

pub use id::Key;
pub use routing::RoutingTable;

use crate::metrics::{CommLedger, Plane};

/// α: lookup parallelism.
const ALPHA: usize = 3;
/// Replication factor for STOREs (= bucket k).
const REPLICATE: usize = routing::K;

/// Approximate wire sizes (bytes) per RPC, modelled on Kademlia framing:
/// header + 160-bit ids.
const FIND_NODE_REQ: u64 = 72;
const FIND_NODE_RESP_PER_CONTACT: u64 = 26;
const FIND_NODE_RESP_BASE: u64 = 48;
const STORE_BASE: u64 = 92;
const GET_REQ: u64 = 72;
const GET_RESP_BASE: u64 = 48;

/// One node's storage: content key -> list of small byte payloads.
#[derive(Clone, Debug, Default)]
struct NodeStore {
    items: BTreeMap<Key, Vec<Vec<u8>>>,
}

struct NodeState {
    routing: RoutingTable,
    store: NodeStore,
}

/// Outcome of an iterative lookup.
#[derive(Clone, Debug)]
pub struct LookupResult {
    pub closest: Vec<Key>,
    /// query round-trips issued (the paper's "hops")
    pub hops: usize,
}

/// The in-process Kademlia network. Node storage is a HashMap — node
/// lookups by 160-bit key happen on every routing refresh, and hashing
/// beats the BTreeMap's memcmp walk (EXPERIMENTS.md §Perf).
pub struct SimDht {
    nodes: HashMap<Key, NodeState>,
    ledger: Arc<CommLedger>,
    /// cumulative lookup query rounds (the coordinator converts hop deltas
    /// into simulated control-plane latency)
    hops_total: u64,
}

impl SimDht {
    pub fn new(ledger: Arc<CommLedger>) -> Self {
        SimDht { nodes: HashMap::new(), ledger, hops_total: 0 }
    }

    /// Cumulative lookup hops across all operations so far.
    pub fn hops_total(&self) -> u64 {
        self.hops_total
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn node_ids(&self) -> Vec<Key> {
        self.nodes.keys().copied().collect()
    }

    /// Join `id` to the network, bootstrapping its routing table via a
    /// self-lookup through any existing node (Kademlia join protocol).
    pub fn join(&mut self, id: Key) {
        let bootstrap = self.nodes.keys().next().copied();
        self.nodes.insert(id, NodeState {
            routing: RoutingTable::new(id),
            store: NodeStore::default(),
        });
        if let Some(seed) = bootstrap {
            self.nodes.get_mut(&id).unwrap().routing.insert(seed);
            self.nodes.get_mut(&seed).unwrap().routing.insert(id);
            // self-lookup populates buckets along the path
            self.lookup(id, id);
        }
    }

    /// Iterative FIND_NODE from `from` toward `target`. Returns the k
    /// closest nodes found and the number of query rounds. Books every
    /// request/response on the control plane.
    pub fn lookup(&mut self, from: Key, target: Key) -> LookupResult {
        let mut shortlist: Vec<Key> = self
            .nodes
            .get(&from)
            .expect("lookup from unknown node")
            .routing
            .closest(&target, REPLICATE);
        let mut queried: Vec<Key> = Vec::new();
        let mut hops = 0;
        loop {
            // α closest unqueried candidates
            let mut candidates: Vec<Key> = shortlist
                .iter()
                .filter(|c| !queried.contains(c) && **c != from)
                .copied()
                .collect();
            candidates.sort_by_key(|c| c.distance(&target));
            candidates.truncate(ALPHA);
            if candidates.is_empty() {
                break;
            }
            hops += 1;
            // query phase: immutable reads + ledger booking
            let mut gathered: Vec<(Key, Vec<Key>)> =
                Vec::with_capacity(candidates.len());
            for c in candidates {
                queried.push(c);
                // request
                self.ledger.record(Plane::Control, FIND_NODE_REQ);
                let contacts = match self.nodes.get(&c) {
                    Some(node) => node.routing.closest(&target, REPLICATE),
                    None => Vec::new(),
                };
                // response
                self.ledger.record(
                    Plane::Control,
                    FIND_NODE_RESP_BASE
                        + FIND_NODE_RESP_PER_CONTACT * contacts.len() as u64,
                );
                gathered.push((c, contacts));
            }
            // refresh phase: bilateral routing updates (every Kademlia
            // message is a liveness signal). Batched so `from`'s node is
            // located once per hop instead of once per contact — see
            // EXPERIMENTS.md §Perf.
            for (c, _) in &gathered {
                if let Some(n) = self.nodes.get_mut(c) {
                    n.routing.insert(from);
                }
            }
            if let Some(n) = self.nodes.get_mut(&from) {
                for (c, contacts) in &gathered {
                    n.routing.insert(*c);
                    for contact in contacts {
                        if *contact != from {
                            n.routing.insert(*contact);
                        }
                    }
                }
            }
            for (_, contacts) in gathered {
                for contact in contacts {
                    if !shortlist.contains(&contact) && contact != from {
                        shortlist.push(contact);
                    }
                }
            }
            shortlist.sort_by_key(|c| c.distance(&target));
            shortlist.truncate(REPLICATE);
            // converged when all of the k closest have been queried
            if shortlist.iter().all(|c| queried.contains(c) || *c == from) {
                break;
            }
        }
        self.hops_total += hops as u64;
        LookupResult { closest: shortlist, hops }
    }

    /// STORE `payload` under `key`, replicated to the k closest nodes.
    pub fn store(&mut self, from: Key, key: Key, payload: Vec<u8>) -> usize {
        let res = self.lookup(from, key);
        let targets = if res.closest.is_empty() { vec![from] } else { res.closest.clone() };
        let n = targets.len();
        for t in targets {
            self.ledger
                .record(Plane::Control, STORE_BASE + payload.len() as u64);
            if let Some(node) = self.nodes.get_mut(&t) {
                node.store.items.entry(key).or_default().push(payload.clone());
            }
        }
        n
    }

    /// GET all payloads stored under `key` (union over the k closest).
    pub fn get(&mut self, from: Key, key: Key) -> Vec<Vec<u8>> {
        let res = self.lookup(from, key);
        let mut out: Vec<Vec<u8>> = Vec::new();
        for t in &res.closest {
            self.ledger.record(Plane::Control, GET_REQ);
            let values: Vec<Vec<u8>> = self
                .nodes
                .get(t)
                .map(|n| n.store.items.get(&key).cloned().unwrap_or_default())
                .unwrap_or_default();
            let resp_bytes: u64 =
                values.iter().map(|v| v.len() as u64).sum::<u64>() + GET_RESP_BASE;
            self.ledger.record(Plane::Control, resp_bytes);
            for v in values {
                if !out.contains(&v) {
                    out.push(v);
                }
            }
        }
        out
    }

    /// Remove every stored value under `key` network-wide (the paper's
    /// dispatcher "periodically clears stale entries from the shared
    /// dictionary"; here keys are iteration-scoped and cleared after use).
    pub fn clear(&mut self, key: Key) {
        for node in self.nodes.values_mut() {
            node.store.items.remove(&key);
        }
    }

    /// Drop a node from the network (churn).
    pub fn leave(&mut self, id: Key) {
        self.nodes.remove(&id);
    }
}

// ---------------------------------------------------------------------
// Announcement helpers (group-formation metadata)
// ---------------------------------------------------------------------

/// Encode a peer announcement (peer index as 8-byte LE).
pub fn encode_peer(peer: usize) -> Vec<u8> {
    (peer as u64).to_le_bytes().to_vec()
}

pub fn decode_peer(bytes: &[u8]) -> Option<usize> {
    bytes.try_into().ok().map(|b: [u8; 8]| u64::from_le_bytes(b) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn network(n: usize, seed: u64) -> (SimDht, Vec<Key>) {
        let ledger = Arc::new(CommLedger::new());
        let mut dht = SimDht::new(ledger);
        let mut rng = Rng::new(seed);
        let ids: Vec<Key> = (0..n).map(|_| Key::random(&mut rng)).collect();
        for id in &ids {
            dht.join(*id);
        }
        (dht, ids)
    }

    #[test]
    fn store_then_get_round_trips() {
        let (mut dht, ids) = network(30, 1);
        let key = Key::hash_of("group:0:1");
        dht.store(ids[3], key, encode_peer(3));
        dht.store(ids[7], key, encode_peer(7));
        let got = dht.get(ids[12], key);
        let mut peers: Vec<usize> =
            got.iter().filter_map(|v| decode_peer(v)).collect();
        peers.sort_unstable();
        assert_eq!(peers, vec![3, 7]);
    }

    #[test]
    fn lookup_hops_scale_logarithmically() {
        // hops for N=256 should stay near log2(256)/log2(k)-ish, certainly
        // far below linear probing
        let (mut dht, ids) = network(256, 2);
        let mut rng = Rng::new(3);
        let mut total_hops = 0;
        let trials = 40;
        for _ in 0..trials {
            let from = ids[rng.below(ids.len())];
            let target = Key::random(&mut rng);
            total_hops += dht.lookup(from, target).hops;
        }
        let avg = total_hops as f64 / trials as f64;
        assert!(avg <= 8.0, "average hops {avg} too high for 256 nodes");
        assert!(avg >= 1.0);
    }

    #[test]
    fn lookup_finds_globally_closest_nodes() {
        let (mut dht, ids) = network(64, 4);
        let target = Key::hash_of("needle");
        let res = dht.lookup(ids[0], target);
        // ground truth: sort all ids by distance
        let mut truth = ids.clone();
        truth.sort_by_key(|p| p.distance(&target));
        // the true closest node must be discovered
        assert!(
            res.closest.contains(&truth[0]) || truth[0] == ids[0],
            "lookup missed the globally closest node"
        );
    }

    #[test]
    fn control_bytes_booked() {
        let ledger = Arc::new(CommLedger::new());
        let mut dht = SimDht::new(ledger.clone());
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            dht.join(Key::random(&mut rng));
        }
        let before = ledger.snapshot();
        let ids = dht.node_ids();
        dht.store(ids[0], Key::hash_of("x"), encode_peer(0));
        let after = ledger.snapshot();
        assert!(after.control_bytes > before.control_bytes);
        assert_eq!(after.data_bytes, before.data_bytes);
    }

    #[test]
    fn clear_removes_all_replicas() {
        let (mut dht, ids) = network(25, 6);
        let key = Key::hash_of("ephemeral");
        dht.store(ids[1], key, encode_peer(1));
        assert!(!dht.get(ids[2], key).is_empty());
        dht.clear(key);
        assert!(dht.get(ids[2], key).is_empty());
    }

    #[test]
    fn leave_then_lookup_still_works() {
        let (mut dht, ids) = network(40, 7);
        for id in &ids[..10] {
            dht.leave(*id);
        }
        // lookups from surviving nodes must not panic and still converge
        let res = dht.lookup(ids[20], Key::hash_of("after-churn"));
        assert!(!res.closest.is_empty());
    }

    #[test]
    fn peer_encoding_round_trip() {
        for p in [0usize, 1, 124, 1 << 40] {
            assert_eq!(decode_peer(&encode_peer(p)), Some(p));
        }
        assert_eq!(decode_peer(&[1, 2, 3]), None);
    }
}
