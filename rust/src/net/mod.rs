//! Simulated P2P transport fabric + churn injection.
//!
//! The paper evaluates under bandwidth-limited wireless links with peer
//! churn. The fabric books every payload on the [`CommLedger`] and converts
//! bytes into simulated transfer time (latency + bytes/bandwidth); the
//! churn model reproduces the paper's two disturbance axes:
//!
//! * **participation rate** — how many peers take part in an entire FL
//!   iteration (local update + aggregation), set `U_t`;
//! * **dropout likelihood** — a participating peer completes its local
//!   update but vanishes before/during aggregation, thinning `A_t`.

pub mod churn;
pub mod faults;
pub mod trace;

pub use churn::ChurnModel;
pub use faults::{
    BwDist, FaultConfig, FaultCounters, LinkFault, LinkState, RETRY_CTRL_BYTES,
};
pub use trace::MarkovChurn;

use std::sync::Arc;

use crate::metrics::{CommLedger, ExchangePhase, Plane};

/// Uniform-link transport model.
#[derive(Clone)]
pub struct Fabric {
    ledger: Arc<CommLedger>,
    /// bytes per second per link
    pub bandwidth: f64,
    /// seconds per message
    pub latency: f64,
}

impl Fabric {
    pub fn new(ledger: Arc<CommLedger>, bandwidth: f64, latency: f64) -> Self {
        assert!(bandwidth > 0.0);
        Fabric { ledger, bandwidth, latency }
    }

    /// Simulated duration of one message — the single source of the link
    /// cost model (`send` and `sequential` must agree exactly).
    fn duration(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Book one point-to-point message; returns its simulated duration.
    pub fn send(&self, bytes: u64, plane: Plane) -> f64 {
        self.ledger.record(plane, bytes);
        self.duration(bytes)
    }

    /// Duration of `k` messages of `bytes` sent sequentially over one
    /// link. Booked in one batched ledger update (2 atomic adds instead
    /// of 2·k); the duration is still the *summed* per-message time, so
    /// both ledger totals and simulated clocks are bit-identical to `k`
    /// separate `send`s — the parallel-engine determinism tests rely on
    /// this.
    pub fn sequential(&self, k: usize, bytes: u64, plane: Plane) -> f64 {
        if k == 0 {
            return 0.0;
        }
        self.ledger.record_many(plane, k as u64, k as u64 * bytes);
        (0..k).map(|_| self.duration(bytes)).sum()
    }

    /// Duration of `k` messages totalling `total_bytes` sent sequentially
    /// over one link, booked as one wire phase of a chunk-owned group
    /// exchange (data-plane counters plus the reduce-scatter/all-gather
    /// sub-counters). The per-message cost model is linear in bytes, so
    /// the batched duration `k·latency + total/bandwidth` equals the
    /// summed per-message durations exactly.
    pub fn sequential_phased(
        &self,
        k: usize,
        total_bytes: u64,
        phase: ExchangePhase,
    ) -> f64 {
        if k == 0 {
            return 0.0;
        }
        self.ledger.record_phase(phase, k as u64, total_bytes);
        k as f64 * self.latency + total_bytes as f64 / self.bandwidth
    }

    /// [`Self::send`] under a pre-drawn [`LinkFault`]: every lost
    /// transmission is retried, so the payload is booked once per
    /// attempt (`1 + retries` attempts) plus one control-plane probe per
    /// retry/timeout, and the duration carries the degradation
    /// multipliers and the timeout/backoff penalty. A clean link
    /// delegates to [`Self::send`] — bit-identical to the fault-free
    /// build (pinned by `tests/fault_injection.rs`).
    pub fn send_faulty(&self, bytes: u64, plane: Plane, f: &LinkFault) -> f64 {
        if f.is_clean() {
            return self.send(bytes, plane);
        }
        let attempts = 1 + f.retries;
        self.ledger.record_many(plane, attempts, attempts * bytes);
        let probes = f.retries + f.timeouts;
        if probes > 0 {
            self.ledger.record_many(
                Plane::Control,
                probes,
                probes * faults::RETRY_CTRL_BYTES,
            );
        }
        attempts as f64 * self.latency * f.lat_mult
            + (attempts * bytes) as f64 / (self.bandwidth * f.bw_mult)
            + f.penalty_s
    }

    /// [`Self::sequential`] under a pre-drawn [`LinkFault`]: `k`
    /// first-attempt messages plus the link's retries, each booked on
    /// `plane`, probes on the control plane, degradation and penalty on
    /// the duration. Clean links delegate to [`Self::sequential`]
    /// (whose duration is a *sum* of per-message times — delegation is
    /// what keeps the faults-off path bit-identical).
    pub fn sequential_faulty(
        &self,
        k: usize,
        bytes: u64,
        plane: Plane,
        f: &LinkFault,
    ) -> f64 {
        if f.is_clean() {
            return self.sequential(k, bytes, plane);
        }
        if k == 0 && f.retries == 0 && f.timeouts == 0 {
            return 0.0;
        }
        let attempts = k as u64 + f.retries;
        self.ledger.record_many(plane, attempts, attempts * bytes);
        let probes = f.retries + f.timeouts;
        if probes > 0 {
            self.ledger.record_many(
                Plane::Control,
                probes,
                probes * faults::RETRY_CTRL_BYTES,
            );
        }
        attempts as f64 * self.latency * f.lat_mult
            + (attempts * bytes) as f64 / (self.bandwidth * f.bw_mult)
            + f.penalty_s
    }

    pub fn ledger(&self) -> &Arc<CommLedger> {
        &self.ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_books_bytes_and_returns_time() {
        let ledger = Arc::new(CommLedger::new());
        let f = Fabric::new(ledger.clone(), 1000.0, 0.01);
        let t = f.send(500, Plane::Data);
        assert!((t - 0.51).abs() < 1e-12);
        assert_eq!(ledger.snapshot().data_bytes, 500);
    }

    #[test]
    fn sequential_accumulates() {
        let ledger = Arc::new(CommLedger::new());
        let f = Fabric::new(ledger.clone(), 1000.0, 0.0);
        let t = f.sequential(4, 250, Plane::Data);
        assert!((t - 1.0).abs() < 1e-12);
        assert_eq!(ledger.snapshot().data_msgs, 4);
    }

    #[test]
    fn sequential_phased_books_phase_and_data() {
        let ledger = Arc::new(CommLedger::new());
        let f = Fabric::new(ledger.clone(), 1000.0, 0.01);
        let t = f.sequential_phased(4, 2000, ExchangePhase::ReduceScatter);
        assert!((t - (0.04 + 2.0)).abs() < 1e-12);
        let s = ledger.snapshot();
        assert_eq!(s.rs_msgs, 4);
        assert_eq!(s.rs_bytes, 2000);
        assert_eq!(s.data_msgs, 4);
        assert_eq!(s.data_bytes, 2000);
        assert_eq!(s.ag_bytes, 0);
        // zero messages book nothing
        assert_eq!(f.sequential_phased(0, 999, ExchangePhase::AllGather), 0.0);
        assert_eq!(ledger.snapshot().ag_bytes, 0);
    }
}
