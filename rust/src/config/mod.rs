//! Experiment configuration system.
//!
//! Defaults reproduce the paper's setup (§3.1): 125 peers, LDA(α=1.0)
//! non-iid splits, full participation, momentum-SGD η=0.1 μ=0.9, eval
//! every 5th iteration, exact MAR (M=5, G=3 for 125 peers). Presets for
//! each figure live in `configs/` and are parsed by [`toml_lite`];
//! `key=value` CLI overrides are applied on top.

pub mod toml_lite;

use std::path::Path;

use anyhow::{bail, Context, Result};

use toml_lite::{parse_value, Value};

use crate::aggregation::robust::RobustEstimator;
use crate::attack::{AttackConfig, AttackMode};
use crate::net::{BwDist, FaultConfig};

/// Aggregation technique (paper baselines + contribution).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Moshpit All-Reduce FL (the paper's system).
    MarFl,
    /// Ring Decentralized FL (Galaxy FL; full-model ring circulation).
    Rdfl,
    /// Naive all-to-all All-Reduce FL.
    ArFl,
    /// Client-server FedAvg reference.
    FedAvg,
    /// Butterfly All-Reduce (Appendix B.3: efficient but requires totally
    /// reliable peers; only the largest 2^k subset aggregates).
    Bar,
    /// BrainTorrent-style gossip (Roy et al. 2019, Table 1): epidemic
    /// pull-merge, no synchronized global aggregation.
    Gossip,
    /// SAPS-style sparsified pairwise exchange (Tang et al. 2020,
    /// Table 1): cheap but spreads information only locally.
    Saps,
}

impl Strategy {
    pub fn parse(s: &str) -> Result<Strategy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "marfl" | "mar-fl" | "mar" => Strategy::MarFl,
            "rdfl" | "ring" => Strategy::Rdfl,
            "arfl" | "ar-fl" | "alltoall" | "all-to-all" => Strategy::ArFl,
            "fedavg" | "fed-avg" | "cs" => Strategy::FedAvg,
            "bar" | "butterfly" => Strategy::Bar,
            "gossip" | "braintorrent" => Strategy::Gossip,
            "saps" => Strategy::Saps,
            other => bail!("unknown strategy {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::MarFl => "marfl",
            Strategy::Rdfl => "rdfl",
            Strategy::ArFl => "arfl",
            Strategy::FedAvg => "fedavg",
            Strategy::Bar => "bar",
            Strategy::Gossip => "gossip",
            Strategy::Saps => "saps",
        }
    }
}

/// Knowledge-distillation (Moshpit-KD) settings — paper §2.2 and A.1.
#[derive(Clone, Debug)]
pub struct KdConfig {
    pub enabled: bool,
    /// number of FL iterations K that use MKD
    pub k_iterations: usize,
    /// teacher selection ratio ρ_ℓ (paper: 0.4)
    pub rho_ell: f64,
    /// distillation epochs E per MKD round (paper: 1)
    pub epochs: usize,
}

impl Default for KdConfig {
    fn default() -> Self {
        KdConfig { enabled: false, k_iterations: 8, rho_ell: 0.4, epochs: 1 }
    }
}

/// Differential-privacy settings — paper Algorithm 4 / Andrew et al. 2021.
#[derive(Clone, Debug)]
pub struct DpConfig {
    pub enabled: bool,
    /// noise multiplier σ_mult
    pub noise_multiplier: f64,
    /// initial clipping bound C_0
    pub clip_init: f64,
    /// target clipping quantile γ (paper: 0.5)
    pub gamma: f64,
    /// clipping-bound learning rate η_C (paper: 0.2)
    pub eta_c: f64,
    /// server-style update stepsize η_u (paper: 0.1)
    pub eta_u: f64,
    /// delta smoothing factor β (paper: 0.9)
    pub beta: f64,
    /// δ for (ε, δ)-DP reporting
    pub delta: f64,
}

impl Default for DpConfig {
    fn default() -> Self {
        DpConfig {
            enabled: false,
            noise_multiplier: 0.3,
            clip_init: 0.5,
            gamma: 0.5,
            eta_c: 0.2,
            eta_u: 0.1,
            beta: 0.9,
            delta: 1e-5,
        }
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// model / task: "cnn" (MNIST-like) or "head" (20NG-like)
    pub model: String,
    pub strategy: Strategy,
    /// total number of peers N (paper: 16 / 64 / 125)
    pub peers: usize,
    /// FL iterations T
    pub iterations: usize,
    /// MAR group size M (paper: 5 exact, 3 approximate)
    pub group_size: usize,
    /// MAR rounds G per iteration; 0 = auto ⌈log_M N⌉
    pub mar_rounds: usize,
    /// use Moshpit-SGD's chunked reduce-scatter within groups (ablation)
    pub reduce_scatter: bool,
    /// probability a reduce-scatter group loses a chunk owner
    /// mid-exchange (the group falls back to a full gather among the
    /// survivors; ignored under full-gather)
    pub rs_drop: f64,
    /// per-iteration budget of owner-drop retries: while budget remains
    /// (and a later MAR round exists), a dropped-owner group defers to
    /// the next round's matchmaking instead of falling back to the
    /// survivors-only full gather. 0 = always fall back (seed behavior)
    pub rs_retry_budget: usize,
    /// momentum-SGD stepsize η (paper: 0.1)
    pub eta: f32,
    /// momentum μ (paper: 0.9)
    pub mu: f32,
    /// local mini-batches per iteration (paper trains one batch per round)
    pub local_batches: usize,
    /// fraction of peers participating in an entire FL iteration
    pub participation: f64,
    /// probability a participating peer drops during aggregation
    pub dropout: f64,
    /// participation model: "bernoulli" (paper §3.1 default) or "markov"
    /// (bursty Gilbert–Elliott wireless availability, net::trace)
    pub churn_model: String,
    /// markov churn: P(Up -> Down) per iteration
    pub markov_p_down: f64,
    /// markov churn: P(Down -> Up) per iteration
    pub markov_p_up: f64,
    /// evaluate every k-th iteration (paper: 5)
    pub eval_every: usize,
    /// LDA concentration α; ignored when `iid`
    pub lda_alpha: f64,
    pub iid: bool,
    /// samples per peer (train shard target size)
    pub samples_per_peer: usize,
    /// shared test-set size
    pub test_samples: usize,
    pub seed: u64,
    pub kd: KdConfig,
    pub dp: DpConfig,
    /// link bandwidth for the simulated-time model (bytes/s)
    pub link_bandwidth: f64,
    /// link latency (s)
    pub link_latency: f64,
    /// fault-injection plan (net::faults) — all knobs default off
    pub faults: FaultConfig,
    /// Byzantine adversary + robust-aggregation plan (attack) — all
    /// knobs default off (`frac = 0`, estimator `mean`)
    pub attack: AttackConfig,
    /// stop once this test accuracy is reached (0 disables)
    pub target_accuracy: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "cnn".into(),
            strategy: Strategy::MarFl,
            peers: 125,
            iterations: 50,
            group_size: 5,
            mar_rounds: 0,
            reduce_scatter: false,
            rs_drop: 0.0,
            rs_retry_budget: 0,
            eta: 0.1,
            mu: 0.9,
            local_batches: 1,
            participation: 1.0,
            dropout: 0.0,
            churn_model: "bernoulli".into(),
            markov_p_down: 0.1,
            markov_p_up: 0.4,
            eval_every: 5,
            lda_alpha: 1.0,
            iid: false,
            samples_per_peer: 64,
            test_samples: 2000,
            seed: 42,
            kd: KdConfig::default(),
            dp: DpConfig::default(),
            // 100 Mbit/s wireless-ish link, 20 ms latency
            link_bandwidth: 12.5e6,
            link_latency: 0.02,
            faults: FaultConfig::default(),
            attack: AttackConfig::default(),
            target_accuracy: 0.0,
        }
    }
}

impl ExperimentConfig {
    /// Effective MAR rounds: explicit value or ⌈log_M N⌉ (smallest G with
    /// M^G >= N — integer arithmetic, no float-log edge cases).
    pub fn effective_mar_rounds(&self) -> usize {
        if self.mar_rounds > 0 {
            return self.mar_rounds;
        }
        let m = self.group_size.max(2);
        let mut g = 1usize;
        let mut cap = m;
        while cap < self.peers {
            cap = cap.saturating_mul(m);
            g += 1;
        }
        g
    }

    /// Load a preset file and apply `key=value` overrides.
    pub fn load(path: &Path, overrides: &[String]) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path:?}"))?;
        let mut cfg = ExperimentConfig::default();
        for (k, v) in toml_lite::parse(&text)? {
            cfg.set(&k, &v).with_context(|| format!("config key {k:?}"))?;
        }
        cfg.apply_overrides(overrides)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply `key=value` strings (CLI `--set`).
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<()> {
        for o in overrides {
            let Some(eq) = o.find('=') else {
                bail!("override {o:?} is not key=value");
            };
            let key = o[..eq].trim();
            let value = parse_value(o[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("override {o:?}: {e}"))?;
            self.set(key, &value).with_context(|| format!("override {o:?}"))?;
        }
        Ok(())
    }

    fn set(&mut self, key: &str, v: &Value) -> Result<()> {
        fn usize_of(v: &Value) -> Result<usize> {
            v.as_usize().ok_or_else(|| anyhow::anyhow!("expected integer"))
        }
        fn f64_of(v: &Value) -> Result<f64> {
            v.as_f64().ok_or_else(|| anyhow::anyhow!("expected number"))
        }
        fn bool_of(v: &Value) -> Result<bool> {
            v.as_bool().ok_or_else(|| anyhow::anyhow!("expected bool"))
        }
        match key {
            "model" => {
                self.model = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("expected string"))?
                    .to_string()
            }
            "strategy" => {
                self.strategy = Strategy::parse(
                    v.as_str().ok_or_else(|| anyhow::anyhow!("expected string"))?,
                )?
            }
            "peers" => self.peers = usize_of(v)?,
            "iterations" => self.iterations = usize_of(v)?,
            "eta" => self.eta = f64_of(v)? as f32,
            "mu" => self.mu = f64_of(v)? as f32,
            "local_batches" => self.local_batches = usize_of(v)?,
            "participation" => self.participation = f64_of(v)?,
            "dropout" => self.dropout = f64_of(v)?,
            "churn.model" | "churn_model" => {
                self.churn_model = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("expected string"))?
                    .to_string()
            }
            "churn.p_down" | "markov_p_down" => self.markov_p_down = f64_of(v)?,
            "churn.p_up" | "markov_p_up" => self.markov_p_up = f64_of(v)?,
            "eval_every" => self.eval_every = usize_of(v)?,
            "lda_alpha" => self.lda_alpha = f64_of(v)?,
            "iid" => self.iid = bool_of(v)?,
            "samples_per_peer" => self.samples_per_peer = usize_of(v)?,
            "test_samples" => self.test_samples = usize_of(v)?,
            "seed" => self.seed = usize_of(v)? as u64,
            "target_accuracy" => self.target_accuracy = f64_of(v)?,
            "link_bandwidth" => self.link_bandwidth = f64_of(v)?,
            "link_latency" => self.link_latency = f64_of(v)?,
            "mar.group_size" | "group_size" => self.group_size = usize_of(v)?,
            "mar.rounds" | "mar_rounds" => self.mar_rounds = usize_of(v)?,
            "mar.reduce_scatter" | "reduce_scatter" => {
                self.reduce_scatter = bool_of(v)?
            }
            "mar.rs_drop" | "rs_drop" => self.rs_drop = f64_of(v)?,
            "mar.rs_retry_budget" | "rs_retry_budget" => {
                self.rs_retry_budget = usize_of(v)?
            }
            "kd.enabled" => self.kd.enabled = bool_of(v)?,
            "kd.k_iterations" => self.kd.k_iterations = usize_of(v)?,
            "kd.rho_ell" => self.kd.rho_ell = f64_of(v)?,
            "kd.epochs" => self.kd.epochs = usize_of(v)?,
            "dp.enabled" => self.dp.enabled = bool_of(v)?,
            "dp.noise_multiplier" => self.dp.noise_multiplier = f64_of(v)?,
            "dp.clip_init" => self.dp.clip_init = f64_of(v)?,
            "dp.gamma" => self.dp.gamma = f64_of(v)?,
            "dp.eta_c" => self.dp.eta_c = f64_of(v)?,
            "dp.eta_u" => self.dp.eta_u = f64_of(v)?,
            "dp.beta" => self.dp.beta = f64_of(v)?,
            "dp.delta" => self.dp.delta = f64_of(v)?,
            "faults.loss" => self.faults.loss = f64_of(v)?,
            "faults.degrade_prob" => self.faults.degrade_prob = f64_of(v)?,
            "faults.degrade_bw" => self.faults.degrade_bw = f64_of(v)?,
            "faults.degrade_lat" => self.faults.degrade_lat = f64_of(v)?,
            "faults.straggler_prob" => self.faults.straggler_prob = f64_of(v)?,
            "faults.straggler_mult" => self.faults.straggler_mult = f64_of(v)?,
            "faults.crash_prob" => self.faults.crash_prob = f64_of(v)?,
            "faults.max_retries" => {
                self.faults.max_retries = usize_of(v)? as u32
            }
            "faults.timeout_s" => self.faults.timeout_s = f64_of(v)?,
            "faults.backoff_s" => self.faults.backoff_s = f64_of(v)?,
            "faults.quorum_min" => self.faults.quorum_min = usize_of(v)?,
            "faults.ge_p" => self.faults.ge_p = f64_of(v)?,
            "faults.ge_r" => self.faults.ge_r = f64_of(v)?,
            "faults.ge_loss" => self.faults.ge_loss = f64_of(v)?,
            "faults.ge_bw" => self.faults.ge_bw = f64_of(v)?,
            "faults.ge_lat" => self.faults.ge_lat = f64_of(v)?,
            "faults.bw_dist" => {
                let name = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("expected string"))?;
                self.faults.bw_dist = BwDist::parse(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "faults.bw_dist must be off, lognormal or uniform, \
                         got {name:?}"
                    )
                })?
            }
            "faults.bw_sigma" => self.faults.bw_sigma = f64_of(v)?,
            "faults.bw_min" => self.faults.bw_min = f64_of(v)?,
            "faults.bw_max" => self.faults.bw_max = f64_of(v)?,
            "faults.bw_redraw_rounds" => {
                self.faults.bw_redraw_rounds = usize_of(v)?
            }
            "attack.frac" => self.attack.frac = f64_of(v)?,
            "attack.mode" => {
                self.attack.mode = AttackMode::parse(
                    v.as_str().ok_or_else(|| anyhow::anyhow!("expected string"))?,
                )?
            }
            "attack.scale" => self.attack.scale = f64_of(v)?,
            "attack.collude" => self.attack.collude = bool_of(v)?,
            "attack.robust" => {
                self.attack.robust = RobustEstimator::parse(
                    v.as_str().ok_or_else(|| anyhow::anyhow!("expected string"))?,
                )?
            }
            "attack.trim" => self.attack.trim = f64_of(v)?,
            "attack.rep_threshold" => self.attack.rep_threshold = f64_of(v)?,
            "attack.rep_decay" => self.attack.rep_decay = f64_of(v)?,
            "attack.parole_rounds" => {
                self.attack.parole_rounds = usize_of(v)? as u64
            }
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.model != "cnn" && self.model != "head" {
            bail!("model must be cnn or head, got {:?}", self.model);
        }
        if self.peers < 2 {
            bail!("need at least 2 peers");
        }
        if self.group_size < 2 {
            bail!("MAR group size must be >= 2");
        }
        if !(0.0..=1.0).contains(&self.participation) || self.participation <= 0.0 {
            bail!("participation must be in (0, 1]");
        }
        if !(0.0..=1.0).contains(&self.dropout) {
            bail!("dropout must be in [0, 1]");
        }
        if !(0.0..=1.0).contains(&self.rs_drop) {
            bail!("mar.rs_drop must be in [0, 1]");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be >= 1");
        }
        if self.churn_model != "bernoulli" && self.churn_model != "markov" {
            bail!("churn.model must be bernoulli or markov");
        }
        if self.churn_model == "markov" && self.markov_p_up <= 0.0 {
            bail!("markov churn needs p_up > 0");
        }
        let f = &self.faults;
        for (name, p) in [
            ("faults.loss", f.loss),
            ("faults.degrade_prob", f.degrade_prob),
            ("faults.straggler_prob", f.straggler_prob),
            ("faults.crash_prob", f.crash_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                bail!("{name} must be in [0, 1]");
            }
        }
        if !(f.degrade_bw > 0.0 && f.degrade_bw <= 1.0) {
            bail!("faults.degrade_bw must be in (0, 1]");
        }
        if f.degrade_lat < 1.0 {
            bail!("faults.degrade_lat must be >= 1");
        }
        if f.straggler_mult < 1.0 {
            bail!("faults.straggler_mult must be >= 1");
        }
        if f.quorum_min < 2 {
            bail!("faults.quorum_min must be >= 2");
        }
        if f.timeout_s < 0.0 || f.backoff_s < 0.0 {
            bail!("faults.timeout_s / backoff_s must be >= 0");
        }
        for (name, p) in [
            ("faults.ge_p", f.ge_p),
            ("faults.ge_r", f.ge_r),
            ("faults.ge_loss", f.ge_loss),
        ] {
            if !(0.0..=1.0).contains(&p) {
                bail!("{name} must be in [0, 1]");
            }
        }
        if f.ge_p > 0.0 && f.ge_r <= 0.0 {
            bail!("faults.ge_r must be > 0 when ge_p > 0 (bad links must be able to recover)");
        }
        if !(f.ge_bw > 0.0 && f.ge_bw <= 1.0) {
            bail!("faults.ge_bw must be in (0, 1]");
        }
        if f.ge_lat < 1.0 {
            bail!("faults.ge_lat must be >= 1");
        }
        if f.bw_sigma < 0.0 {
            bail!("faults.bw_sigma must be >= 0");
        }
        if !(f.bw_min > 0.0 && f.bw_min <= f.bw_max) {
            bail!("faults.bw_min/bw_max must satisfy 0 < bw_min <= bw_max");
        }
        self.attack.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.peers, 125);
        assert_eq!(c.group_size, 5);
        assert_eq!(c.eta, 0.1);
        assert_eq!(c.mu, 0.9);
        assert_eq!(c.eval_every, 5);
        assert_eq!(c.lda_alpha, 1.0);
        assert_eq!(c.kd.rho_ell, 0.4);
        assert_eq!(c.dp.gamma, 0.5);
        assert_eq!(c.dp.eta_c, 0.2);
        assert_eq!(c.dp.beta, 0.9);
    }

    #[test]
    fn effective_rounds_perfect_grid() {
        // 125 = 5^3 -> 3 rounds
        let c = ExperimentConfig { peers: 125, group_size: 5, ..Default::default() };
        assert_eq!(c.effective_mar_rounds(), 3);
        // 16 = 4^2
        let c = ExperimentConfig { peers: 16, group_size: 4, ..Default::default() };
        assert_eq!(c.effective_mar_rounds(), 2);
        // 64 = 4^3
        let c = ExperimentConfig { peers: 64, group_size: 4, ..Default::default() };
        assert_eq!(c.effective_mar_rounds(), 3);
    }

    #[test]
    fn effective_rounds_imperfect_grid_rounds_up() {
        // 125 peers with group size 3: 3^4 = 81 < 125 <= 3^5 -> 5 rounds
        let c = ExperimentConfig {
            peers: 125,
            group_size: 3,
            ..Default::default()
        };
        assert_eq!(c.effective_mar_rounds(), 5);
        // explicit value wins (paper's approximate mode uses 4)
        let c = ExperimentConfig { mar_rounds: 4, ..c };
        assert_eq!(c.effective_mar_rounds(), 4);
    }

    #[test]
    fn overrides_apply() {
        let mut c = ExperimentConfig::default();
        c.apply_overrides(&[
            "strategy=rdfl".into(),
            "peers=16".into(),
            "dp.enabled=true".into(),
            "kd.rho_ell=0.5".into(),
        ])
        .unwrap();
        assert_eq!(c.strategy, Strategy::Rdfl);
        assert_eq!(c.peers, 16);
        assert!(c.dp.enabled);
        assert_eq!(c.kd.rho_ell, 0.5);
    }

    #[test]
    fn reduce_scatter_knobs_apply_and_validate() {
        let mut c = ExperimentConfig::default();
        c.apply_overrides(&[
            "mar.reduce_scatter=true".into(),
            "mar.rs_drop=0.25".into(),
            "mar.rs_retry_budget=3".into(),
        ])
        .unwrap();
        assert!(c.reduce_scatter);
        assert_eq!(c.rs_drop, 0.25);
        assert_eq!(c.rs_retry_budget, 3);
        assert!(c.validate().is_ok());
        c.rs_drop = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn fault_knobs_apply_and_validate() {
        let mut c = ExperimentConfig::default();
        assert!(!c.faults.enabled());
        c.apply_overrides(&[
            "faults.loss=0.05".into(),
            "faults.degrade_prob=0.1".into(),
            "faults.straggler_prob=0.2".into(),
            "faults.straggler_mult=6.0".into(),
            "faults.crash_prob=0.02".into(),
            "faults.max_retries=5".into(),
            "faults.quorum_min=3".into(),
        ])
        .unwrap();
        assert!(c.faults.enabled());
        assert_eq!(c.faults.loss, 0.05);
        assert_eq!(c.faults.max_retries, 5);
        assert_eq!(c.faults.quorum_min, 3);
        assert!(c.validate().is_ok());
        c.faults.loss = 1.5;
        assert!(c.validate().is_err());
        c.faults.loss = 0.05;
        c.faults.quorum_min = 1;
        assert!(c.validate().is_err());
        c.faults.quorum_min = 2;
        c.faults.degrade_bw = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn ge_knobs_apply_and_validate() {
        let mut c = ExperimentConfig::default();
        assert!(!c.faults.time_correlated());
        c.apply_overrides(&[
            "faults.ge_p=0.1".into(),
            "faults.ge_r=0.4".into(),
            "faults.ge_loss=0.6".into(),
            "faults.ge_bw=0.2".into(),
            "faults.ge_lat=8.0".into(),
            "faults.bw_dist=lognormal".into(),
            "faults.bw_sigma=0.7".into(),
            "faults.bw_min=0.2".into(),
            "faults.bw_max=0.9".into(),
        ])
        .unwrap();
        assert!(c.faults.ge_enabled());
        assert!(c.faults.hetero_bw());
        assert_eq!(c.faults.ge_p, 0.1);
        assert_eq!(c.faults.bw_dist, BwDist::LogNormal);
        assert!(c.validate().is_ok());
        // an absorbing bad state can never deliver: rejected
        c.faults.ge_r = 0.0;
        assert!(c.validate().is_err());
        c.faults.ge_r = 0.4;
        c.faults.ge_loss = 1.5;
        assert!(c.validate().is_err());
        c.faults.ge_loss = 0.6;
        c.faults.bw_min = 0.0;
        assert!(c.validate().is_err());
        c.faults.bw_min = 0.95;
        assert!(c.validate().is_err(), "bw_min > bw_max must fail");
        // unknown distribution name is rejected at set() time
        let mut c2 = ExperimentConfig::default();
        assert!(c2.apply_overrides(&["faults.bw_dist=pareto".into()]).is_err());
    }

    #[test]
    fn byzantine_knobs_apply_and_validate() {
        let mut c = ExperimentConfig::default();
        assert!(!c.attack.enabled());
        assert!(c.attack.policy().is_mean());
        c.apply_overrides(&[
            "attack.frac=0.2".into(),
            "attack.mode=gauss_noise".into(),
            "attack.scale=2.0".into(),
            "attack.collude=true".into(),
            "attack.robust=trimmed_mean".into(),
            "attack.trim=0.3".into(),
            "attack.rep_threshold=0.4".into(),
            "attack.rep_decay=0.05".into(),
            "attack.parole_rounds=3".into(),
            "faults.bw_redraw_rounds=5".into(),
        ])
        .unwrap();
        assert!(c.attack.enabled());
        assert!(c.attack.rep_enabled());
        assert_eq!(c.attack.mode, AttackMode::GaussNoise);
        assert_eq!(c.attack.robust, RobustEstimator::TrimmedMean);
        assert!(c.attack.collude);
        assert_eq!(c.attack.rep_decay, 0.05);
        assert_eq!(c.attack.parole_rounds, 3);
        assert_eq!(c.faults.bw_redraw_rounds, 5);
        assert!(c.validate().is_ok());
        // half-or-more Byzantine peers break every estimator: rejected
        c.attack.frac = 0.5;
        assert!(c.validate().is_err());
        c.attack.frac = 0.2;
        c.attack.trim = 0.5;
        assert!(c.validate().is_err());
        c.attack.trim = 0.3;
        c.attack.rep_threshold = 1.0;
        assert!(c.validate().is_err());
        c.attack.rep_threshold = 0.4;
        c.attack.scale = -1.0;
        assert!(c.validate().is_err());
        c.attack.scale = 2.0;
        // EWMA decay is a [0,1) rate: 1.0 would erase history instantly
        c.attack.rep_decay = 1.0;
        assert!(c.validate().is_err());
        c.attack.rep_decay = -0.1;
        assert!(c.validate().is_err());
        c.attack.rep_decay = 0.0;
        assert!(c.validate().is_ok());
        // unknown mode / estimator names are rejected at set() time
        let mut c2 = ExperimentConfig::default();
        assert!(c2.apply_overrides(&["attack.mode=backdoor".into()]).is_err());
        assert!(c2.apply_overrides(&["attack.robust=bulyan".into()]).is_err());
        // the adaptive modes and selection estimators parse
        c2.apply_overrides(&[
            "attack.mode=adaptive_scale".into(),
            "attack.robust=krum".into(),
        ])
        .unwrap();
        assert_eq!(c2.attack.mode, AttackMode::AdaptiveScale);
        assert_eq!(c2.attack.robust, RobustEstimator::Krum);
        c2.apply_overrides(&[
            "attack.mode=alie".into(),
            "attack.robust=multi_krum".into(),
        ])
        .unwrap();
        assert_eq!(c2.attack.mode, AttackMode::Alie);
        assert_eq!(c2.attack.robust, RobustEstimator::MultiKrum);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = ExperimentConfig::default();
        assert!(c.apply_overrides(&["bogus=1".into()]).is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = ExperimentConfig::default();
        c.participation = 0.0;
        assert!(c.validate().is_err());
        c.participation = 0.5;
        c.model = "resnet".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn repo_presets_load() {
        // every shipped preset must parse and validate; overrides stack
        for preset in [
            "configs/paper_default.toml",
            "configs/fig11_approx.toml",
            "configs/dp_20ng.toml",
            "configs/mkd_20ng.toml",
            "configs/churn_markov.toml",
            "configs/faults_bursty.toml",
            "configs/byzantine.toml",
            "configs/byzantine_adaptive.toml",
        ] {
            let cfg = ExperimentConfig::load(
                Path::new(preset),
                &["seed=1".into()],
            )
            .unwrap_or_else(|e| panic!("{preset}: {e:#}"));
            assert_eq!(cfg.seed, 1, "{preset}: override not applied");
        }
        let dp = ExperimentConfig::load(
            Path::new("configs/dp_20ng.toml"),
            &[],
        )
        .unwrap();
        assert!(dp.dp.enabled);
        assert_eq!(dp.dp.gamma, 0.5);
        let kd = ExperimentConfig::load(
            Path::new("configs/mkd_20ng.toml"),
            &[],
        )
        .unwrap();
        assert!(kd.kd.enabled);
        assert_eq!(kd.kd.k_iterations, 6);
        let churn = ExperimentConfig::load(
            Path::new("configs/churn_markov.toml"),
            &[],
        )
        .unwrap();
        assert_eq!(churn.churn_model, "markov");
        assert!(churn.faults.enabled());
        let byz = ExperimentConfig::load(
            Path::new("configs/byzantine.toml"),
            &[],
        )
        .unwrap();
        assert!(byz.attack.enabled());
        assert!(byz.attack.rep_enabled());
        assert_eq!(byz.attack.robust, RobustEstimator::TrimmedMean);
        let adaptive = ExperimentConfig::load(
            Path::new("configs/byzantine_adaptive.toml"),
            &[],
        )
        .unwrap();
        assert!(adaptive.attack.enabled());
        assert!(adaptive.attack.rep_enabled());
        assert_eq!(adaptive.attack.mode, AttackMode::AdaptiveScale);
        assert_eq!(adaptive.attack.robust, RobustEstimator::MultiKrum);
        assert!(adaptive.attack.rep_decay > 0.0);
        assert!(adaptive.attack.parole_rounds > 0);
    }

    #[test]
    fn strategy_parsing() {
        assert_eq!(Strategy::parse("MAR-FL").unwrap(), Strategy::MarFl);
        assert_eq!(Strategy::parse("ring").unwrap(), Strategy::Rdfl);
        assert_eq!(Strategy::parse("fedavg").unwrap(), Strategy::FedAvg);
        assert_eq!(Strategy::parse("braintorrent").unwrap(), Strategy::Gossip);
        assert_eq!(Strategy::parse("saps").unwrap(), Strategy::Saps);
        assert_eq!(Strategy::parse("butterfly").unwrap(), Strategy::Bar);
        assert!(Strategy::parse("telepathy").is_err());
    }
}
