//! Simulated wall clock.
//!
//! The simulation executes serially on one core, but the system it models
//! is parallel: within one round every peer (or group) communicates
//! concurrently. The clock therefore advances by the *maximum* over
//! parallel lanes, and by the sum across sequential phases — giving the
//! simulated round/iteration times reported in EXPERIMENTS.md.

/// Accumulating simulated clock.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    time_s: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock::default()
    }

    pub fn now(&self) -> f64 {
        self.time_s
    }

    /// A sequential phase of duration `dt`.
    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0, "negative phase duration {dt}");
        self.time_s += dt;
    }

    /// A parallel phase: lanes run concurrently, the phase lasts as long
    /// as the slowest lane.
    pub fn parallel(&mut self, lane_times: impl IntoIterator<Item = f64>) {
        let max = lane_times.into_iter().fold(0.0f64, f64::max);
        self.time_s += max;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_sum_sequentially() {
        let mut c = SimClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_takes_max() {
        let mut c = SimClock::new();
        c.parallel([0.2, 0.9, 0.4]);
        assert!((c.now() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn empty_parallel_is_free() {
        let mut c = SimClock::new();
        c.parallel([]);
        assert_eq!(c.now(), 0.0);
    }
}
