//! 160-bit Kademlia keyspace: node ids, content keys, XOR metric.

use crate::rng::Rng;

pub const KEY_BYTES: usize = 20;
pub const KEY_BITS: usize = KEY_BYTES * 8;

/// A 160-bit identifier (node id or content key).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Key(pub [u8; KEY_BYTES]);

impl std::fmt::Debug for Key {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for b in &self.0[..4] {
            write!(f, "{b:02x}")?;
        }
        write!(f, "…")
    }
}

impl Key {
    pub fn random(rng: &mut Rng) -> Key {
        let mut k = [0u8; KEY_BYTES];
        for chunk in k.chunks_mut(8) {
            let v = rng.next_u64().to_le_bytes();
            let n = chunk.len();
            chunk.copy_from_slice(&v[..n]);
        }
        Key(k)
    }

    /// Deterministic key for a string (content addressing for group keys,
    /// barriers, announcements). FNV-1a folded to 160 bits.
    pub fn hash_of(s: &str) -> Key {
        let mut k = [0u8; KEY_BYTES];
        let mut h = 0xcbf29ce484222325u64;
        for (i, b) in s.bytes().chain(0u8..5).enumerate() {
            h ^= b as u64 ^ (i as u64) << 1;
            h = h.wrapping_mul(0x100000001b3);
            k[i % KEY_BYTES] ^= (h >> 24) as u8;
        }
        // extra mixing round so short strings fill all bytes
        for i in 0..KEY_BYTES {
            h ^= k[i] as u64;
            h = h.wrapping_mul(0x100000001b3);
            k[i] ^= (h >> 32) as u8;
        }
        Key(k)
    }

    /// XOR distance to another key.
    pub fn distance(&self, other: &Key) -> Distance {
        let mut d = [0u8; KEY_BYTES];
        for i in 0..KEY_BYTES {
            d[i] = self.0[i] ^ other.0[i];
        }
        Distance(d)
    }

    /// Index of the k-bucket `other` falls into from `self`'s perspective:
    /// the bit position of the highest differing bit (0..160), or None for
    /// self.
    pub fn bucket_index(&self, other: &Key) -> Option<usize> {
        let d = self.distance(other);
        d.leading_zeros().map(|lz| KEY_BITS - 1 - lz)
    }
}

/// XOR distance, ordered big-endian (larger = farther).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Distance(pub [u8; KEY_BYTES]);

impl Distance {
    /// Number of leading zero bits; None when distance is zero (same key).
    pub fn leading_zeros(&self) -> Option<usize> {
        let mut lz = 0;
        for b in &self.0 {
            if *b == 0 {
                lz += 8;
            } else {
                return Some(lz + b.leading_zeros() as usize);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_is_metric_like() {
        let mut rng = Rng::new(1);
        let a = Key::random(&mut rng);
        let b = Key::random(&mut rng);
        assert_eq!(a.distance(&b), b.distance(&a));
        assert_eq!(a.distance(&a).leading_zeros(), None);
        assert!(a.distance(&b) > a.distance(&a));
    }

    #[test]
    fn hash_of_is_deterministic_and_spread() {
        let a = Key::hash_of("group:1:0:0");
        let b = Key::hash_of("group:1:0:0");
        let c = Key::hash_of("group:1:0:1");
        assert_eq!(a, b);
        assert_ne!(a, c);
        // all bytes populated for a short input
        assert!(a.0.iter().filter(|&&b| b != 0).count() > 10);
    }

    #[test]
    fn bucket_index_range() {
        let mut rng = Rng::new(2);
        let me = Key::random(&mut rng);
        for _ in 0..100 {
            let other = Key::random(&mut rng);
            let idx = me.bucket_index(&other).unwrap();
            assert!(idx < KEY_BITS);
        }
        assert_eq!(me.bucket_index(&me), None);
    }

    #[test]
    fn ordering_matches_bigendian_magnitude() {
        let zero = Key([0; KEY_BYTES]);
        let mut one = [0u8; KEY_BYTES];
        one[KEY_BYTES - 1] = 1;
        let mut big = [0u8; KEY_BYTES];
        big[0] = 1;
        assert!(zero.distance(&Key(one)) < zero.distance(&Key(big)));
    }
}
