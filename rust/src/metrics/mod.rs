//! Metrics substrate: communication ledger, training curves, CSV/JSON
//! emission. Every byte that crosses the simulated network is booked here,
//! split into control plane (DHT coordination) and data plane (model
//! exchange) — the paper's headline numbers are exactly these counters.

pub mod curves;
pub mod ledger;
pub mod writer;

pub use curves::{CurvePoint, TrainCurve};
pub use ledger::{CommLedger, CommSnapshot, ExchangePhase, Plane};
pub use writer::{write_csv, write_json, write_jsonl};
