//! Aggregation strategies: the paper's baselines and the shared machinery
//! MAR builds on.
//!
//! All strategies implement [`Aggregate`] over flat peer states
//! (θ ‖ momentum — the paper's Moshpit-AR averages both). Communication is
//! booked byte-exactly on the fabric; one "state transfer" is
//! `2 · P_pad · 4` bytes for every technique, so cross-technique ratios
//! (the paper's headline results) are unit-independent.
//!
//! Per-iteration data-plane cost (N peers, group size M, G MAR rounds):
//!
//! | technique | state transfers | asymptotic |
//! |---|---|---|
//! | FedAvg   | 2N              | O(N)       |
//! | AR-FL    | N(N−1)          | O(N²)      |
//! | RDFL     | N(N−1)          | O(N²)      |
//! | MAR-FL   | ≤ N·G·(M−1)     | O(N log N) |

pub mod alltoall;
pub mod butterfly;
pub mod fedavg;
pub mod gossip;
pub mod ring;
pub mod robust;
pub mod saps;

pub use alltoall::AllToAll;
pub use butterfly::Butterfly;
pub use fedavg::FedAvgServer;
pub use gossip::Gossip;
pub use ring::RingRdfl;
pub use saps::Saps;

use anyhow::Result;

use crate::metrics::{ExchangePhase, Plane};
use crate::models::ModelMeta;
use crate::net::{Fabric, FaultConfig, FaultCounters, LinkFault, LinkState};
pub use crate::params::Theta;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::sim::SimClock;

/// One peer's aggregatable state: flat parameters + momentum (both length
/// `P_pad`), held as copy-on-write [`Theta`] handles so snapshots, group
/// means and DP references share storage instead of cloning.
#[derive(Clone, Debug)]
pub struct PeerState {
    pub theta: Theta,
    pub momentum: Theta,
}

impl PeerState {
    pub fn new(theta: Vec<f32>) -> Self {
        let momentum = Theta::zeros(theta.len());
        PeerState { theta: theta.into(), momentum }
    }
}

/// Wire size of one full state transfer (θ + momentum) for a plain
/// (non-DP) iteration — static per-model accounting used by the analytic
/// benches.
pub fn state_bytes(model: &ModelMeta) -> u64 {
    model.model_bytes() * 2
}

/// Actual wire size of the states being aggregated right now. During DP
/// iterations the momentum vector carries the smoothed delta and the clip
/// indicator (Algorithm 4 averages four quantities through MAR), so the
/// payload is larger than the static `state_bytes`.
pub fn payload_bytes(states: &[PeerState], members: &[usize]) -> u64 {
    let s = &states[members[0]];
    ((s.theta.len() + s.momentum.len()) * 4) as u64
}

/// Shared context threaded through an aggregation call.
pub struct AggCtx<'a> {
    pub fabric: &'a Fabric,
    pub clock: &'a mut SimClock,
    pub rng: &'a mut Rng,
    /// When present, within-group averaging runs through the Pallas
    /// `group_mean` artifact; otherwise the native f64 path is used.
    pub runtime: Option<&'a Runtime>,
    pub model: &'a ModelMeta,
    /// Fault-injection plan (net::faults). `&FaultConfig::OFF` disables
    /// injection — the default everywhere faults are not under test.
    pub faults: &'a FaultConfig,
    /// Time-correlated link state (Gilbert–Elliott chains + per-peer
    /// bandwidths), present only when `faults.time_correlated()`. `None`
    /// keeps every draw on the bit-exact i.i.d. path.
    pub links: Option<&'a mut LinkState>,
}

/// What an aggregation did (for ledger-independent assertions).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AggReport {
    /// communication rounds executed
    pub rounds: usize,
    /// groups formed across all rounds (MAR) or 1 (global techniques)
    pub groups: usize,
    /// reduce-scatter groups that lost a chunk owner mid-exchange and
    /// fell back to a survivors-only full gather (0 under full-gather) —
    /// the per-iteration reliability signal `fig3_churn` plots against
    /// `mar.rs_drop`
    pub rs_fallbacks: usize,
    /// reduce-scatter groups that lost a chunk owner and *deferred*
    /// instead — survivors skipped averaging and re-formed via the next
    /// round's matchmaking, spending one unit of `mar.rs_retry_budget`
    /// (0 with the default budget of 0, where every drop falls back)
    pub rs_retries: usize,
    /// peers newly banned by the reputation ledger during this
    /// aggregation (0 whenever reputation gating is off)
    pub flagged_peers: u64,
    /// fault-injection outcomes for this aggregation (all zero when the
    /// plan is off)
    pub faults: FaultCounters,
}

/// An aggregation technique. `agg` lists the indices of peers in `A_t`
/// (participants that survived dropout); only their states may be read or
/// written.
pub trait Aggregate {
    fn name(&self) -> &'static str;

    fn aggregate(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport>;
}

// ---------------------------------------------------------------------
// Shared vector math — the averaging hot path
// ---------------------------------------------------------------------
//
// All strategies reduce to element-wise means over selected peer vectors.
// The kernel below strip-mines the output into cache-resident chunks and
// accumulates each chunk in a reusable per-thread f64 scratch buffer, so
// the inner loop is a plain `f64 += f32 as f64` stream the compiler
// auto-vectorizes. Because every output element still sums its inputs in
// member order, the result is bit-identical to the naive full-vector
// accumulation regardless of strip width or thread count — the property
// the parallel round engine's determinism tests pin down. Group averaging
// lands the mean in ONE freshly allocated canonical vector per group and
// broadcasts it to every member as a shared `Theta` handle: k refcount
// bumps instead of k buffer copies (the zero-copy broadcast the
// snapshot-aliasing tests pin down).

/// Output strip width (f32 elements). The f64 scratch for one strip is
/// 32 KiB — resident in L1/L2 while every member's strip streams through.
const MEAN_STRIPE: usize = 4096;

thread_local! {
    /// Per-thread f64 accumulator, reused across calls (allocation-free
    /// steady state).
    static MEAN_ACC: std::cell::RefCell<Vec<f64>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Mean one output strip: `out` is the strip at offset `off` of the full
/// result; `row(k)` yields the k-th full input vector.
fn stripe_mean_into<'a>(
    rows: usize,
    row: impl Fn(usize) -> &'a [f32],
    off: usize,
    out: &mut [f32],
    inv: f64,
) {
    MEAN_ACC.with(|acc| {
        let mut acc = acc.borrow_mut();
        acc.clear();
        acc.resize(out.len(), 0.0);
        for r in 0..rows {
            let src = &row(r)[off..off + out.len()];
            for (a, &v) in acc.iter_mut().zip(src) {
                *a += v as f64;
            }
        }
        for (dst, &a) in out.iter_mut().zip(acc.iter()) {
            *dst = (a * inv) as f32;
        }
    });
}

/// Write the element-wise mean of `rows` vectors into `out` (all length
/// `out.len()`), f64 strip accumulation. With `parallel`, large outputs
/// are split across the `exec` pool (bit-identical: strips are
/// independent and each element keeps its member-order sum).
pub fn mean_indexed_into<'a, F>(rows: usize, row: F, out: &mut [f32], parallel: bool)
where
    F: Fn(usize) -> &'a [f32] + Sync,
{
    assert!(rows > 0, "mean of zero rows");
    let inv = 1.0 / rows as f64;
    if parallel && out.len() >= 2 * MEAN_STRIPE && crate::exec::threads() > 1 {
        use rayon::prelude::*;
        crate::exec::pool().install(|| {
            out.par_chunks_mut(MEAN_STRIPE).enumerate().for_each(|(ci, chunk)| {
                stripe_mean_into(rows, &row, ci * MEAN_STRIPE, chunk, inv);
            });
        });
    } else {
        for (ci, chunk) in out.chunks_mut(MEAN_STRIPE).enumerate() {
            stripe_mean_into(rows, &row, ci * MEAN_STRIPE, chunk, inv);
        }
    }
}

/// Native mean of the selected peers' (θ, m), f64 accumulation. The
/// momentum vector may be longer than θ (DP packs extra averaged
/// quantities onto it); each vector is averaged at its own length.
pub fn mean_of(states: &[PeerState], members: &[usize]) -> (Vec<f32>, Vec<f32>) {
    assert!(!members.is_empty());
    let p = states[members[0]].theta.len();
    let q = states[members[0]].momentum.len();
    for &i in members {
        assert_eq!(states[i].theta.len(), p, "ragged theta lengths");
        assert_eq!(states[i].momentum.len(), q, "ragged momentum lengths");
    }
    let mut theta = vec![0.0f32; p];
    let mut mom = vec![0.0f32; q];
    mean_indexed_into(
        members.len(),
        |k| states[members[k]].theta.as_slice(),
        &mut theta,
        true,
    );
    mean_indexed_into(
        members.len(),
        |k| states[members[k]].momentum.as_slice(),
        &mut mom,
        true,
    );
    (theta, mom)
}

/// [`mean_of`] under a robust-center policy: `Mean` delegates to the
/// bit-exact strip-mined mean; the other estimators run their own
/// kernels (same striping, same f64 ordering guarantees). Used by the
/// server-collected FedAvg baseline.
pub fn robust_mean_of(
    states: &[PeerState],
    members: &[usize],
    policy: robust::RobustPolicy,
) -> (Vec<f32>, Vec<f32>) {
    if policy.is_mean() {
        return mean_of(states, members);
    }
    assert!(!members.is_empty());
    let n = members.len();
    let p = states[members[0]].theta.len();
    let q = states[members[0]].momentum.len();
    for &i in members {
        assert_eq!(states[i].theta.len(), p, "ragged theta lengths");
        assert_eq!(states[i].momentum.len(), q, "ragged momentum lengths");
    }
    let mut theta = vec![0.0f32; p];
    let mut mom = vec![0.0f32; q];
    match policy.est {
        robust::RobustEstimator::Mean => unreachable!("delegated above"),
        robust::RobustEstimator::NormClip => {
            let w =
                robust::clip_weights(n, |k| states[members[k]].theta.as_slice());
            robust::weighted_mean_indexed_into(
                n,
                |k| states[members[k]].theta.as_slice(),
                &w,
                &mut theta,
                true,
            );
            robust::weighted_mean_indexed_into(
                n,
                |k| states[members[k]].momentum.as_slice(),
                &w,
                &mut mom,
                true,
            );
        }
        robust::RobustEstimator::TrimmedMean | robust::RobustEstimator::Median => {
            let drop = policy.drop_count(n);
            robust::trimmed_indexed_into(
                n,
                |k| states[members[k]].theta.as_slice(),
                &mut theta,
                drop,
                true,
            );
            robust::trimmed_indexed_into(
                n,
                |k| states[members[k]].momentum.as_slice(),
                &mut mom,
                drop,
                true,
            );
        }
        robust::RobustEstimator::Krum | robust::RobustEstimator::MultiKrum => {
            if n < 3 {
                return mean_of(states, members);
            }
            let sel = robust::krum_select(
                n,
                |k| states[members[k]].theta.as_slice(),
                policy.krum_f(n),
                policy.est == robust::RobustEstimator::MultiKrum,
            );
            mean_indexed_into(
                sel.len(),
                |k| states[members[sel[k]]].theta.as_slice(),
                &mut theta,
                true,
            );
            mean_indexed_into(
                sel.len(),
                |k| states[members[sel[k]]].momentum.as_slice(),
                &mut mom,
                true,
            );
        }
    }
    (theta, mom)
}

/// How a group's member states are accessed during in-place averaging —
/// one body ([`average_rows`]) serves both the slice+indices shape
/// (serial engine) and the exclusive-views shape handed out by
/// `exec::par_disjoint_map` (parallel lanes). `Sync` because the mean
/// kernel's row accessor closure must be shareable.
trait GroupRows: Sync {
    fn rows(&self) -> usize;
    fn theta(&self, k: usize) -> &[f32];
    fn momentum(&self, k: usize) -> &[f32];
    /// Broadcast the canonical mean to every member — shared handles,
    /// zero buffer copies.
    fn write_all(&mut self, theta: Theta, mom: Theta);
}

struct SliceRows<'a> {
    states: &'a mut [PeerState],
    members: &'a [usize],
}

impl GroupRows for SliceRows<'_> {
    fn rows(&self) -> usize {
        self.members.len()
    }
    fn theta(&self, k: usize) -> &[f32] {
        self.states[self.members[k]].theta.as_slice()
    }
    fn momentum(&self, k: usize) -> &[f32] {
        self.states[self.members[k]].momentum.as_slice()
    }
    fn write_all(&mut self, theta: Theta, mom: Theta) {
        for &i in self.members {
            self.states[i].theta = theta.clone();
            self.states[i].momentum = mom.clone();
        }
    }
}

struct ViewRows<'a, 'b> {
    views: &'a mut [&'b mut PeerState],
}

impl GroupRows for ViewRows<'_, '_> {
    fn rows(&self) -> usize {
        self.views.len()
    }
    fn theta(&self, k: usize) -> &[f32] {
        self.views[k].theta.as_slice()
    }
    fn momentum(&self, k: usize) -> &[f32] {
        self.views[k].momentum.as_slice()
    }
    fn write_all(&mut self, theta: Theta, mom: Theta) {
        for v in self.views.iter_mut() {
            v.theta = theta.clone();
            v.momentum = mom.clone();
        }
    }
}

/// In-place group average: the mean lands in one freshly allocated
/// canonical vector and every member receives a shared handle on it —
/// one O(|θ|) allocation per group instead of k buffer copies. Serial
/// striping (used inside group-parallel lanes, where the outer fan-out
/// owns the cores).
fn average_rows<R: GroupRows>(rows: &mut R) {
    robust_average_rows(rows, robust::RobustPolicy::MEAN, false);
}

/// Generalized [`average_rows`]: the group center is computed by
/// `policy` (the `Mean` arm runs the exact legacy `mean_indexed_into`
/// calls — bit-identical), and when `want_scores` each member's L2
/// distance to the center is measured BEFORE the zero-copy broadcast
/// rewrites the members (afterwards every member aliases the center).
fn robust_average_rows<R: GroupRows>(
    rows: &mut R,
    policy: robust::RobustPolicy,
    want_scores: bool,
) -> Option<robust::GroupScores> {
    let n = rows.rows();
    if n < 2 {
        return want_scores.then(|| robust::GroupScores {
            dists: vec![0.0; n],
            center_norm: 0.0,
        });
    }
    let p = rows.theta(0).len();
    let q = rows.momentum(0).len();
    for k in 0..n {
        assert_eq!(rows.theta(k).len(), p, "ragged theta lengths");
        assert_eq!(rows.momentum(k).len(), q, "ragged momentum lengths");
    }
    let mut tbuf = vec![0.0f32; p];
    let mut mbuf = vec![0.0f32; q];
    let scores;
    {
        let shared = &*rows;
        match policy.est {
            robust::RobustEstimator::Mean => {
                mean_indexed_into(n, |k| shared.theta(k), tbuf.as_mut_slice(), false);
                mean_indexed_into(
                    n,
                    |k| shared.momentum(k),
                    mbuf.as_mut_slice(),
                    false,
                );
            }
            robust::RobustEstimator::NormClip => {
                // clip by θ norms; momentum rides with its θ's weight so
                // an amplified state is damped coherently
                let w = robust::clip_weights(n, |k| shared.theta(k));
                robust::weighted_mean_indexed_into(
                    n,
                    |k| shared.theta(k),
                    &w,
                    tbuf.as_mut_slice(),
                    false,
                );
                robust::weighted_mean_indexed_into(
                    n,
                    |k| shared.momentum(k),
                    &w,
                    mbuf.as_mut_slice(),
                    false,
                );
            }
            robust::RobustEstimator::TrimmedMean | robust::RobustEstimator::Median => {
                let drop = policy.drop_count(n);
                robust::trimmed_indexed_into(
                    n,
                    |k| shared.theta(k),
                    tbuf.as_mut_slice(),
                    drop,
                    false,
                );
                robust::trimmed_indexed_into(
                    n,
                    |k| shared.momentum(k),
                    mbuf.as_mut_slice(),
                    drop,
                    false,
                );
            }
            robust::RobustEstimator::Krum | robust::RobustEstimator::MultiKrum => {
                // selection over full θ vectors; the momentum center
                // averages the same selected members so both halves of
                // the state move coherently. k < 3 degenerates to mean.
                let sel = krum_members(n, |k| shared.theta(k), policy);
                mean_indexed_into(
                    sel.len(),
                    |k| shared.theta(sel[k]),
                    tbuf.as_mut_slice(),
                    false,
                );
                mean_indexed_into(
                    sel.len(),
                    |k| shared.momentum(sel[k]),
                    mbuf.as_mut_slice(),
                    false,
                );
            }
        }
        scores = want_scores.then(|| robust::GroupScores {
            dists: (0..n).map(|k| robust::l2_distance(shared.theta(k), &tbuf)).collect(),
            center_norm: robust::l2_norm(&tbuf),
        });
    }
    rows.write_all(Theta::new(tbuf), Theta::new(mbuf));
    scores
}

/// The member subset a Krum-family policy averages: the Krum winner (or
/// the Multi-Krum survivor set) for k ≥ 3, every member below that —
/// selection needs `k − f − 2 ≥ 1` neighbours, so tiny groups degrade
/// to the plain mean. Shared by the full-gather and chunk-owned paths
/// so both assemble the identical center.
fn krum_members<'a, F>(n: usize, theta: F, policy: robust::RobustPolicy) -> Vec<usize>
where
    F: Fn(usize) -> &'a [f32] + Sync,
{
    if n < 3 {
        return (0..n).collect();
    }
    robust::krum_select(
        n,
        theta,
        policy.krum_f(n),
        policy.est == robust::RobustEstimator::MultiKrum,
    )
}

/// [`average_rows`] over `states[members]` (serial reference engine).
pub fn average_group_native(states: &mut [PeerState], members: &[usize]) {
    average_rows(&mut SliceRows { states, members });
}

/// [`average_rows`] over the exclusive member views handed out by
/// `exec::par_disjoint_map` — the group-parallel averaging lane body.
pub fn average_views(views: &mut [&mut PeerState]) {
    average_rows(&mut ViewRows { views });
}

// ---------------------------------------------------------------------
// Chunk ownership (Moshpit-SGD's reduce-scatter wire protocol)
// ---------------------------------------------------------------------
//
// Under `GroupExchange::ReduceScatter`, member `k` of a size-`n` group
// owns the k-th balanced contiguous stripe of every exchanged vector
// (`exec::stripe_range`; the rank doubles as the chunk index
// `GroupKey::set_chunk` records). During reduce-scatter the owner
// receives the other members' copies of its stripe and averages ONLY
// that stripe — 1/n of the full-gather averaging FLOPs and scratch
// traffic per member — and during all-gather it broadcasts the averaged
// stripe back. Stripes partition the vector and every element still
// accumulates its inputs in member order, so the assembled result is
// bit-identical to full-gather averaging (the equivalence the
// reduce-scatter tests pin down).

/// In-place chunk-owned group average: owner `k` computes only its
/// balanced stripe of the mean (the reduce-scatter compute model), the
/// stripes assemble in one canonical vector, and the all-gather
/// broadcast hands every member a shared handle on it. Bit-identical to
/// [`average_rows`]. With `stripe_parallel`, owner stripes fan out
/// across the `exec` pool; the canonical buffers are locals (never a
/// thread-local borrow held across the fan-out), so a work-stealing
/// re-entry on this thread cannot alias them.
fn average_rows_chunked<R: GroupRows>(rows: &mut R, stripe_parallel: bool) {
    robust_average_rows_chunked(rows, stripe_parallel, robust::RobustPolicy::MEAN, false);
}

/// Generalized [`average_rows_chunked`]: each chunk owner applies
/// `policy` to its owned stripe. Coordinate-wise estimators (trimmed
/// mean, median) are stripe-local, and norm-clip weights come from
/// FULL-vector norms, so every estimator assembles exactly the vector
/// its full-gather counterpart computes — the `Mean` arm runs the
/// legacy stripe bodies bit-exactly.
fn robust_average_rows_chunked<R: GroupRows>(
    rows: &mut R,
    stripe_parallel: bool,
    policy: robust::RobustPolicy,
    want_scores: bool,
) -> Option<robust::GroupScores> {
    let n = rows.rows();
    if n < 2 {
        return want_scores.then(|| robust::GroupScores {
            dists: vec![0.0; n],
            center_norm: 0.0,
        });
    }
    let p = rows.theta(0).len();
    let q = rows.momentum(0).len();
    for k in 0..n {
        assert_eq!(rows.theta(k).len(), p, "ragged theta lengths");
        assert_eq!(rows.momentum(k).len(), q, "ragged momentum lengths");
    }
    let mut tbuf = vec![0.0f32; p];
    let mut mbuf = vec![0.0f32; q];
    let scores;
    {
        let shared = &*rows;
        let par = stripe_parallel && crate::exec::threads() > 1;
        let drop = policy.drop_count(n);
        let clip = (policy.est == robust::RobustEstimator::NormClip)
            .then(|| robust::clip_weights(n, |k| shared.theta(k)));
        // Krum selection reads FULL θ vectors (like clip weights), so it
        // is precomputed once here; every owner stripe then averages the
        // same selected rows — assembling exactly the full-gather center
        let sel =
            policy.is_selection().then(|| krum_members(n, |k| shared.theta(k), policy));
        crate::exec::map_ranges_mut(
            tbuf.as_mut_slice(),
            &crate::exec::stripe_ranges(p, n),
            par,
            |owner, stripe| {
                robust_owner_stripe(
                    n,
                    |k| shared.theta(k),
                    p,
                    owner,
                    stripe,
                    policy,
                    drop,
                    clip.as_deref(),
                    sel.as_deref(),
                );
            },
        )
        .expect("owner stripes are disjoint by construction");
        crate::exec::map_ranges_mut(
            mbuf.as_mut_slice(),
            &crate::exec::stripe_ranges(q, n),
            par,
            |owner, stripe| {
                robust_owner_stripe(
                    n,
                    |k| shared.momentum(k),
                    q,
                    owner,
                    stripe,
                    policy,
                    drop,
                    clip.as_deref(),
                    sel.as_deref(),
                );
            },
        )
        .expect("owner stripes are disjoint by construction");
        scores = want_scores.then(|| robust::GroupScores {
            dists: (0..n).map(|k| robust::l2_distance(shared.theta(k), &tbuf)).collect(),
            center_norm: robust::l2_norm(&tbuf),
        });
    }
    rows.write_all(Theta::new(tbuf), Theta::new(mbuf));
    scores
}

/// One chunk owner's estimate of its stripe under `policy` — the
/// shared body of [`robust_average_rows_chunked`]. `drop`, `clip` and
/// `sel` are precomputed by the caller (clip weights and the Krum
/// selection both come from FULL vectors).
#[allow(clippy::too_many_arguments)]
fn robust_owner_stripe<'a, F>(
    n: usize,
    vecs: F,
    len: usize,
    owner: usize,
    stripe: &mut [f32],
    policy: robust::RobustPolicy,
    drop: usize,
    clip: Option<&[f64]>,
    sel: Option<&[usize]>,
) where
    F: Fn(usize) -> &'a [f32] + Sync,
{
    let r = crate::exec::stripe_range(len, n, owner);
    match policy.est {
        robust::RobustEstimator::Mean => {
            mean_indexed_into(n, |k| &vecs(k)[r.start..r.end], stripe, false)
        }
        robust::RobustEstimator::NormClip => robust::weighted_mean_indexed_into(
            n,
            |k| &vecs(k)[r.start..r.end],
            clip.expect("clip weights precomputed"),
            stripe,
            false,
        ),
        robust::RobustEstimator::TrimmedMean | robust::RobustEstimator::Median => {
            robust::trimmed_indexed_into(
                n,
                |k| &vecs(k)[r.start..r.end],
                stripe,
                drop,
                false,
            )
        }
        robust::RobustEstimator::Krum | robust::RobustEstimator::MultiKrum => {
            let sel = sel.expect("krum selection precomputed");
            mean_indexed_into(
                sel.len(),
                |k| &vecs(sel[k])[r.start..r.end],
                stripe,
                false,
            )
        }
    }
}

/// [`average_rows_chunked`] over `states[members]` — the serial-engine
/// reduce-scatter averaging path (stripes run in owner order).
pub fn average_group_chunked(states: &mut [PeerState], members: &[usize]) {
    average_rows_chunked(&mut SliceRows { states, members }, false);
}

/// [`average_rows_chunked`] over exclusive member views — the
/// reduce-scatter group-parallel lane body. `stripe_parallel` lets a
/// round whose group fan-out underfills the engine pool recover
/// utilization by striping owners across it; results are bit-identical
/// either way.
pub fn average_views_chunked(views: &mut [&mut PeerState], stripe_parallel: bool) {
    average_rows_chunked(&mut ViewRows { views }, stripe_parallel);
}

// ---------------------------------------------------------------------
// Robust entry points (Byzantine-tolerant centers + outlier scores)
// ---------------------------------------------------------------------
//
// Policy-threaded versions of the averaging wrappers above. A `Mean`
// policy runs the exact same code paths bit-for-bit; `want_scores`
// additionally returns each member's distance to the center (measured
// before the broadcast) for the reputation ledger. See
// [`robust`] for the estimators and `attack` for the adversary model.

/// Robust [`average_group_native`]; returns outlier scores on request.
pub fn robust_average_group_native(
    states: &mut [PeerState],
    members: &[usize],
    policy: robust::RobustPolicy,
    want_scores: bool,
) -> Option<robust::GroupScores> {
    robust_average_rows(&mut SliceRows { states, members }, policy, want_scores)
}

/// Robust [`average_views`] (group-parallel lane body).
pub fn robust_average_views(
    views: &mut [&mut PeerState],
    policy: robust::RobustPolicy,
    want_scores: bool,
) -> Option<robust::GroupScores> {
    robust_average_rows(&mut ViewRows { views }, policy, want_scores)
}

/// Robust [`average_group_chunked`] (chunk-owned reduce-scatter path:
/// the estimator applies per owned stripe, assembling the identical
/// vector).
pub fn robust_average_group_chunked(
    states: &mut [PeerState],
    members: &[usize],
    policy: robust::RobustPolicy,
    want_scores: bool,
) -> Option<robust::GroupScores> {
    robust_average_rows_chunked(
        &mut SliceRows { states, members },
        false,
        policy,
        want_scores,
    )
}

/// Robust [`average_views_chunked`].
pub fn robust_average_views_chunked(
    views: &mut [&mut PeerState],
    stripe_parallel: bool,
    policy: robust::RobustPolicy,
    want_scores: bool,
) -> Option<robust::GroupScores> {
    robust_average_rows_chunked(
        &mut ViewRows { views },
        stripe_parallel,
        policy,
        want_scores,
    )
}

/// The compute one chunk owner performs during reduce-scatter: the mean
/// of the selected peers' (θ, momentum) restricted to `owner`'s stripes
/// (`owner` is the member's rank in the group — its chunk index). The
/// micro bench compares this against full-vector averaging to pin the
/// ~M× per-member kernel saving chunk ownership buys.
pub fn owner_stripe_mean(
    states: &[PeerState],
    members: &[usize],
    owner: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert!(!members.is_empty(), "owner stripe of an empty group");
    assert!(owner < members.len(), "owner {owner} outside the group");
    let p = states[members[0]].theta.len();
    let q = states[members[0]].momentum.len();
    for &i in members {
        assert_eq!(states[i].theta.len(), p, "ragged theta lengths");
        assert_eq!(states[i].momentum.len(), q, "ragged momentum lengths");
    }
    let rt = crate::exec::stripe_range(p, members.len(), owner);
    let rq = crate::exec::stripe_range(q, members.len(), owner);
    let mut theta = vec![0.0f32; rt.len()];
    let mut mom = vec![0.0f32; rq.len()];
    mean_indexed_into(
        members.len(),
        |k| &states[members[k]].theta[rt.start..rt.end],
        &mut theta,
        false,
    );
    mean_indexed_into(
        members.len(),
        |k| &states[members[k]].momentum[rq.start..rq.end],
        &mut mom,
        false,
    );
    (theta, mom)
}

/// Use the Pallas `group_mean` artifact for within-group averaging?
/// Benchmarked ablation (`micro_hotpath`): at this model scale the PJRT
/// call overhead (~0.7 ms literal marshalling + dispatch) outweighs the
/// kernel win, so the native f64 path is the default; set
/// `MARFL_PJRT_GROUP_MEAN=1` to flip (and on a real TPU backend the
/// artifact path is the one that scales). See EXPERIMENTS.md §Perf.
pub(crate) fn pjrt_group_mean_enabled() -> bool {
    static FLAG: once_cell::sync::Lazy<bool> = once_cell::sync::Lazy::new(|| {
        std::env::var_os("MARFL_PJRT_GROUP_MEAN").is_some()
    });
    *FLAG
}

/// Policy-threaded [`average_group`]: a plain-`Mean` policy with no
/// score request dispatches through [`average_group`] (keeping the
/// PJRT artifact path reachable, bit-exactly); robust estimators and
/// score collection always run the native path — the Pallas artifact
/// only computes means.
pub fn robust_average_group(
    states: &mut [PeerState],
    members: &[usize],
    ctx: &mut AggCtx<'_>,
    policy: robust::RobustPolicy,
    want_scores: bool,
) -> Result<Option<robust::GroupScores>> {
    if policy.is_mean() && !want_scores {
        average_group(states, members, ctx)?;
        return Ok(None);
    }
    Ok(robust_average_group_native(states, members, policy, want_scores))
}

/// Average the states of `members` and write the result back to each of
/// them. Default: native f64 accumulation; the Pallas group-mean artifact
/// is used when `MARFL_PJRT_GROUP_MEAN=1` and the shapes/group size match
/// (see `pjrt_group_mean_enabled`).
pub fn average_group(
    states: &mut [PeerState],
    members: &[usize],
    ctx: &mut AggCtx<'_>,
) -> Result<()> {
    if members.len() < 2 {
        return Ok(());
    }
    let plain_shape = states[members[0]].theta.len() == ctx.model.padded_len
        && states[members[0]].momentum.len() == ctx.model.padded_len;
    match ctx.runtime {
        Some(rt)
            if pjrt_group_mean_enabled()
                && plain_shape
                && rt.meta.group_sizes.contains(&members.len()) =>
        {
            let p = ctx.model.padded_len;
            let mut stack = Vec::with_capacity(members.len() * p);
            for &i in members {
                stack.extend_from_slice(&states[i].theta);
            }
            let theta = Theta::new(rt.group_mean(ctx.model, &stack, members.len())?);
            stack.clear();
            for &i in members {
                stack.extend_from_slice(&states[i].momentum);
            }
            let mom = Theta::new(rt.group_mean(ctx.model, &stack, members.len())?);
            for &i in members {
                states[i].theta = theta.clone();
                states[i].momentum = mom.clone();
            }
        }
        _ => average_group_native(states, members),
    }
    Ok(())
}

/// How a Moshpit group moves its states.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupExchange {
    /// Every member sends its full state to every other member:
    /// k(k−1) transfers of `bytes` per group. Matches the accounting the
    /// paper's headline ratios imply (≈10× vs RDFL at N=125).
    FullGather,
    /// Moshpit-SGD's chunked protocol: each member owns a disjoint 1/k
    /// stripe of the vector; reduce-scatter + all-gather moves exactly
    /// 2·(k−1)/k·bytes per member — a further (k/2)× wire reduction —
    /// and each member averages only its owned stripe (a ~k× compute
    /// reduction). Exposed as the `mar.reduce_scatter` ablation.
    ReduceScatter,
}

/// Simulated duration of one group exchange, split by wire phase.
/// Full-gather is a pure gather: its whole duration books as
/// `all_gather_s`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExchangeTiming {
    pub reduce_scatter_s: f64,
    pub all_gather_s: f64,
}

impl ExchangeTiming {
    pub fn total(&self) -> f64 {
        self.reduce_scatter_s + self.all_gather_s
    }
}

/// Book one chunk-owned reduce-scatter + all-gather exchange for a group
/// of `group_len` members moving `bytes` of state each. Owner `i`'s wire
/// chunk is the balanced byte split of the payload, so the totals are
/// exact: each phase moves `(k−1)·bytes` across the group — the
/// `2(k−1)/k` state transfers per member that `coordinator/mar.rs`
/// asserts in closed form. Both phases book on the ledger's per-phase
/// sub-counters; the returned timing keeps them separate because the
/// all-gather cannot start before the group's reduction completes.
pub fn book_reduce_scatter_fabric(
    group_len: usize,
    bytes: u64,
    fabric: &Fabric,
) -> ExchangeTiming {
    if group_len < 2 {
        return ExchangeTiming::default();
    }
    let k = group_len as u64;
    let chunk = |i: u64| bytes / k + u64::from(i < bytes % k);
    // reduce-scatter: member j streams every other owner's chunk to its
    // owner — (k−1) messages totalling bytes − chunk(j); members send in
    // parallel, so the phase lasts as long as the slowest member
    let mut rs = 0.0f64;
    for j in 0..k {
        rs = fabric
            .sequential_phased(
                group_len - 1,
                bytes - chunk(j),
                ExchangePhase::ReduceScatter,
            )
            .max(rs);
    }
    // all-gather: owner i broadcasts its averaged chunk to the others
    let mut ag = 0.0f64;
    for i in 0..k {
        ag = fabric
            .sequential_phased(
                group_len - 1,
                (k - 1) * chunk(i),
                ExchangePhase::AllGather,
            )
            .max(ag);
    }
    ExchangeTiming { reduce_scatter_s: rs, all_gather_s: ag }
}

/// [`book_reduce_scatter_fabric`] under per-member pre-drawn links.
/// Degradation multiplies each member's phase durations; retry
/// surcharges (extra chunk retransmissions, their control-plane probes,
/// and the timeout/backoff penalty) book on the reduce-scatter phase, a
/// retried chunk costing the balanced `bytes/k` floor — keeping the
/// coordinator's closed-form phase-byte assertion exact. Links with
/// timeouts must not reach this booker: a member whose message died for
/// good leaves the group through the quorum path instead. All-clean
/// links delegate to [`book_reduce_scatter_fabric`] bit-exactly.
pub fn book_reduce_scatter_faulty(
    links: &[LinkFault],
    bytes: u64,
    fabric: &Fabric,
) -> ExchangeTiming {
    if links.iter().all(LinkFault::is_clean) {
        return book_reduce_scatter_fabric(links.len(), bytes, fabric);
    }
    let group_len = links.len();
    if group_len < 2 {
        return ExchangeTiming::default();
    }
    let k = group_len as u64;
    let chunk = |i: u64| bytes / k + u64::from(i < bytes % k);
    let retry_chunk = bytes / k;
    let ledger = fabric.ledger();
    let mut rs = 0.0f64;
    for (j, f) in links.iter().enumerate() {
        debug_assert!(!f.lost(), "timed-out member reached the RS booker");
        let payload = bytes - chunk(j as u64);
        ledger.record_phase(
            ExchangePhase::ReduceScatter,
            (group_len - 1) as u64,
            payload,
        );
        let mut t = (group_len - 1) as f64 * fabric.latency * f.lat_mult
            + payload as f64 / (fabric.bandwidth * f.bw_mult);
        if f.retries > 0 {
            ledger.record_phase(
                ExchangePhase::ReduceScatter,
                f.retries,
                f.retries * retry_chunk,
            );
            ledger.record_many(
                Plane::Control,
                f.retries,
                f.retries * crate::net::RETRY_CTRL_BYTES,
            );
            t += f.retries as f64 * fabric.latency * f.lat_mult
                + (f.retries * retry_chunk) as f64
                    / (fabric.bandwidth * f.bw_mult);
        }
        t += f.penalty_s;
        rs = rs.max(t);
    }
    let mut ag = 0.0f64;
    for (i, f) in links.iter().enumerate() {
        let payload = (k - 1) * chunk(i as u64);
        ledger.record_phase(
            ExchangePhase::AllGather,
            (group_len - 1) as u64,
            payload,
        );
        let t = (group_len - 1) as f64 * fabric.latency * f.lat_mult
            + payload as f64 / (fabric.bandwidth * f.bw_mult);
        ag = ag.max(t);
    }
    ExchangeTiming { reduce_scatter_s: rs, all_gather_s: ag }
}

/// Full-gather group exchange under per-member pre-drawn links: each
/// member's lane books through [`Fabric::sequential_faulty`] (clean
/// links delegate to the exact legacy path); the exchange lasts as long
/// as the slowest member.
pub fn book_full_gather_faulty(
    links: &[LinkFault],
    bytes: u64,
    fabric: &Fabric,
) -> f64 {
    if links.len() < 2 {
        return 0.0;
    }
    let mut per_member = 0.0f64;
    for f in links {
        per_member = fabric
            .sequential_faulty(links.len() - 1, bytes, Plane::Data, f)
            .max(per_member);
    }
    per_member
}

/// Book one group's exchange on the fabric; returns the group's simulated
/// duration (each member's sends are sequential; members operate in
/// parallel). Takes `&Fabric` directly so group-parallel lanes can book
/// concurrently — the ledger is contention-free and booking commutes.
pub fn book_group_exchange_fabric(
    group_len: usize,
    bytes: u64,
    mode: GroupExchange,
    fabric: &Fabric,
) -> f64 {
    if group_len < 2 {
        return 0.0;
    }
    match mode {
        GroupExchange::FullGather => {
            let mut per_member = 0.0f64;
            for _ in 0..group_len {
                per_member = fabric
                    .sequential(group_len - 1, bytes, Plane::Data)
                    .max(per_member);
            }
            per_member
        }
        GroupExchange::ReduceScatter => {
            book_reduce_scatter_fabric(group_len, bytes, fabric).total()
        }
    }
}

/// Ctx-threaded wrapper around [`book_group_exchange_fabric`].
pub fn book_group_exchange_mode(
    group_len: usize,
    bytes: u64,
    mode: GroupExchange,
    ctx: &mut AggCtx<'_>,
) -> f64 {
    book_group_exchange_fabric(group_len, bytes, mode, ctx.fabric)
}

/// Back-compat: full-gather exchange.
pub fn book_group_exchange(group_len: usize, bytes: u64, ctx: &mut AggCtx<'_>) -> f64 {
    book_group_exchange_mode(group_len, bytes, GroupExchange::FullGather, ctx)
}

/// Build an `Aggregate` for a strategy (MAR is constructed separately in
/// `coordinator`, since it owns the DHT). Plain-`Mean` policy — the
/// bit-exact legacy construction.
pub fn baseline_for(
    strategy: crate::config::Strategy,
) -> Option<Box<dyn Aggregate>> {
    baseline_for_robust(strategy, robust::RobustPolicy::MEAN)
}

/// [`baseline_for`] with a robust-center policy. The server-mediated
/// and gossip baselines honor it (FedAvg trims over ALL received
/// updates; gossip clips its pairwise pulls); the fixed-schedule
/// all-reduce topologies (ring, butterfly, all-to-all, SAPS) keep their
/// exact pairwise/global means — their wire protocols average
/// incrementally, where coordinate-wise trimming has no analogue.
pub fn baseline_for_robust(
    strategy: crate::config::Strategy,
    policy: robust::RobustPolicy,
) -> Option<Box<dyn Aggregate>> {
    use crate::config::Strategy::*;
    match strategy {
        FedAvg => Some(Box::new(FedAvgServer::default().with_robust(policy))),
        Rdfl => Some(Box::new(RingRdfl::default())),
        ArFl => Some(Box::new(AllToAll::default())),
        Bar => Some(Box::new(Butterfly::default())),
        Gossip => Some(Box::new(gossip::Gossip::default().with_robust(policy))),
        Saps => Some(Box::new(saps::Saps::default())),
        MarFl => None,
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::metrics::CommLedger;
    use std::sync::Arc;

    /// A self-owning AggCtx bundle for aggregation unit tests.
    pub struct TestCtx {
        pub ledger: Arc<CommLedger>,
        pub fabric: Fabric,
        pub clock: SimClock,
        pub rng: Rng,
        pub model: ModelMeta,
    }

    impl TestCtx {
        pub fn new(padded_len: usize) -> Self {
            let ledger = Arc::new(CommLedger::new());
            let fabric = Fabric::new(ledger.clone(), 1e6, 0.001);
            TestCtx {
                ledger,
                fabric,
                clock: SimClock::new(),
                rng: Rng::new(0xA11CE),
                model: ModelMeta {
                    name: "toy".into(),
                    param_count: padded_len,
                    padded_len,
                    input_shape: vec![4],
                    classes: 3,
                    batch: 8,
                    eval_chunk: 8,
                    init_file: String::new(),
                    artifacts: Default::default(),
                },
            }
        }

        pub fn ctx(&mut self) -> AggCtx<'_> {
            AggCtx {
                fabric: &self.fabric,
                clock: &mut self.clock,
                rng: &mut self.rng,
                runtime: None,
                model: &self.model,
                faults: &FaultConfig::OFF,
                links: None,
            }
        }
    }

    /// Random peer states for math tests.
    pub fn random_states(n: usize, p: usize, seed: u64) -> Vec<PeerState> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| PeerState {
                theta: (0..p).map(|_| rng.normal() as f32).collect(),
                momentum: (0..p).map(|_| rng.normal() as f32 * 0.1).collect(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;

    #[test]
    fn mean_of_matches_hand_computation() {
        let states = vec![
            PeerState {
                theta: vec![1.0, 2.0].into(),
                momentum: vec![0.0, 4.0].into(),
            },
            PeerState {
                theta: vec![3.0, 6.0].into(),
                momentum: vec![2.0, 0.0].into(),
            },
        ];
        let (t, m) = mean_of(&states, &[0, 1]);
        assert_eq!(t, vec![2.0, 4.0]);
        assert_eq!(m, vec![1.0, 2.0]);
    }

    #[test]
    fn average_group_writes_back_to_all_members() {
        let mut states = random_states(5, 16, 1);
        let mut tc = TestCtx::new(16);
        let mut ctx = tc.ctx();
        let (want_t, want_m) = mean_of(&states, &[1, 3, 4]);
        average_group(&mut states, &[1, 3, 4], &mut ctx).unwrap();
        for &i in &[1, 3, 4] {
            crate::testing::assert_allclose(&states[i].theta, &want_t, 1e-6, 1e-7);
            crate::testing::assert_allclose(&states[i].momentum, &want_m, 1e-6, 1e-7);
        }
        // non-members untouched
        let fresh = random_states(5, 16, 1);
        assert_eq!(states[0].theta, fresh[0].theta);
        assert_eq!(states[2].theta, fresh[2].theta);
    }

    #[test]
    fn striped_mean_bit_identical_to_naive_accumulation() {
        // reference: the pre-refactor full-vector f64 accumulation
        fn naive_mean(states: &[PeerState], members: &[usize]) -> Vec<f32> {
            let p = states[members[0]].theta.len();
            let mut acc = vec![0.0f64; p];
            for &i in members {
                for (a, &v) in acc.iter_mut().zip(&states[i].theta) {
                    *a += v as f64;
                }
            }
            let inv = 1.0 / members.len() as f64;
            acc.iter().map(|&v| (v * inv) as f32).collect()
        }
        // length crosses several strips and a ragged tail
        let p = 3 * 4096 + 37;
        let states = random_states(7, p, 91);
        let members = vec![0, 2, 3, 6];
        let want = naive_mean(&states, &members);
        let (got, _) = mean_of(&states, &members);
        assert_eq!(got, want, "striped mean must be bit-identical");
    }

    #[test]
    fn average_group_native_matches_mean_of_bitwise() {
        let mut states = random_states(6, 4096 + 11, 92);
        let members = vec![1, 2, 5];
        let (want_t, want_m) = mean_of(&states, &members);
        average_group_native(&mut states, &members);
        for &i in &members {
            assert_eq!(states[i].theta, want_t);
            assert_eq!(states[i].momentum, want_m);
        }
    }

    #[test]
    fn group_average_broadcast_is_zero_copy() {
        // after a group averages, every member holds a shared handle on
        // ONE canonical mean allocation — k refcount bumps, zero buffer
        // copies — and non-members share nothing with it
        let mut states = random_states(5, 64, 99);
        let members = vec![0, 2, 4];
        average_group_native(&mut states, &members);
        assert!(states[0].theta.shares_storage(&states[2].theta));
        assert!(states[0].theta.shares_storage(&states[4].theta));
        assert!(states[0].momentum.shares_storage(&states[2].momentum));
        assert!(!states[0].theta.shares_storage(&states[1].theta));
        // same contract on the chunk-owned path
        let mut states = random_states(5, 64, 99);
        average_group_chunked(&mut states, &members);
        assert!(states[0].theta.shares_storage(&states[4].theta));
        // mutating one member afterwards detaches it without perturbing
        // the groupmates (copy-on-write); compare against an independent
        // Vec copy so the assertion reads real payload, not an alias
        let before = states[2].theta.to_vec();
        states[0].theta.make_mut()[0] += 1.0;
        assert!(!states[0].theta.shares_storage(&states[2].theta));
        assert_eq!(states[2].theta, before);
    }

    #[test]
    fn average_views_matches_average_group_native_bitwise() {
        let mut a = random_states(5, 513, 93);
        let mut b = a.clone();
        let members = vec![0, 3, 4];
        average_group_native(&mut a, &members);
        let groups = vec![members.clone()];
        crate::exec::par_disjoint_map(&mut b, &groups, |_, views| {
            average_views(views);
        })
        .unwrap();
        for i in 0..a.len() {
            assert_eq!(a[i].theta, b[i].theta);
            assert_eq!(a[i].momentum, b[i].momentum);
        }
    }

    #[test]
    fn chunk_owned_average_bit_identical_to_full() {
        // stripe boundaries cross several MEAN_STRIPE chunks and a ragged
        // tail; every group size must assemble the exact full mean
        let p = 2 * 4096 + 103;
        for &n in &[2usize, 3, 5, 8] {
            let mut a = random_states(n, p, 95);
            let mut b = a.clone();
            let members: Vec<usize> = (0..n).collect();
            average_group_native(&mut a, &members);
            average_group_chunked(&mut b, &members);
            for i in 0..n {
                assert_eq!(a[i].theta, b[i].theta, "theta diverged (M={n})");
                assert_eq!(a[i].momentum, b[i].momentum, "momentum diverged");
            }
        }
    }

    #[test]
    fn chunk_owned_average_handles_extended_momentum() {
        // DP iterations extend momentum beyond theta; stripes partition
        // each vector at its own length
        let mut a = random_states(3, 300, 98);
        for s in &mut a {
            s.momentum.make_mut().extend_from_slice(&[1.0, 2.0, 3.0]);
        }
        let mut b = a.clone();
        let members = vec![0, 1, 2];
        average_group_native(&mut a, &members);
        average_group_chunked(&mut b, &members);
        for i in 0..3 {
            assert_eq!(a[i].theta, b[i].theta);
            assert_eq!(a[i].momentum, b[i].momentum);
        }
    }

    #[test]
    fn chunk_owned_views_with_stripe_parallel_bit_identical() {
        let mut a = random_states(6, 3 * 4096 + 1, 96);
        let mut b = a.clone();
        let members = vec![0, 2, 3, 5];
        average_group_chunked(&mut a, &members);
        let groups = vec![members.clone()];
        crate::exec::par_disjoint_map(&mut b, &groups, |_, views| {
            average_views_chunked(views, true);
        })
        .unwrap();
        for i in 0..6 {
            assert_eq!(a[i].theta, b[i].theta);
            assert_eq!(a[i].momentum, b[i].momentum);
        }
    }

    #[test]
    fn owner_stripes_assemble_into_the_full_mean() {
        let p = 4096 + 77;
        let states = random_states(7, p, 97);
        let members = vec![0, 1, 3, 4, 6];
        let (want_t, want_m) = mean_of(&states, &members);
        let mut got_t = Vec::new();
        let mut got_m = Vec::new();
        for owner in 0..members.len() {
            let (t, m) = owner_stripe_mean(&states, &members, owner);
            got_t.extend_from_slice(&t);
            got_m.extend_from_slice(&m);
        }
        assert_eq!(got_t, want_t);
        assert_eq!(got_m, want_m);
    }

    #[test]
    fn reduce_scatter_booking_is_exact_per_phase() {
        let tc = TestCtx::new(32);
        let bytes = 1003u64; // deliberately not divisible by k
        let k = 4usize;
        let tm = book_reduce_scatter_fabric(k, bytes, &tc.fabric);
        assert!(tm.reduce_scatter_s > 0.0 && tm.all_gather_s > 0.0);
        assert!(tm.total() > tm.all_gather_s);
        let s = tc.ledger.snapshot();
        // each phase moves exactly (k−1)·bytes in k(k−1) chunk messages
        assert_eq!(s.rs_bytes, (k as u64 - 1) * bytes);
        assert_eq!(s.ag_bytes, (k as u64 - 1) * bytes);
        assert_eq!(s.rs_msgs, (k * (k - 1)) as u64);
        assert_eq!(s.ag_msgs, (k * (k - 1)) as u64);
        assert_eq!(s.data_bytes, 2 * (k as u64 - 1) * bytes);
        // singleton groups book nothing
        let tc2 = TestCtx::new(32);
        assert_eq!(
            book_reduce_scatter_fabric(1, bytes, &tc2.fabric),
            ExchangeTiming::default()
        );
        assert_eq!(tc2.ledger.snapshot().data_bytes, 0);
    }

    #[test]
    fn mean_handles_extended_momentum_lengths() {
        // DP iterations extend momentum beyond theta; each vector averages
        // at its own length
        let mut states = random_states(3, 16, 94);
        for s in &mut states {
            s.momentum.make_mut().extend_from_slice(&[1.0, 2.0, 3.0]);
        }
        let (t, m) = mean_of(&states, &[0, 1, 2]);
        assert_eq!(t.len(), 16);
        assert_eq!(m.len(), 19);
        assert_eq!(&m[16..], &[1.0, 2.0, 3.0]);
        average_group_native(&mut states, &[0, 1, 2]);
        assert_eq!(states[0].momentum.len(), 19);
    }

    #[test]
    fn singleton_group_is_noop() {
        let mut states = random_states(2, 8, 2);
        let orig = states[0].theta.clone();
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        average_group(&mut states, &[0], &mut ctx).unwrap();
        assert_eq!(states[0].theta, orig);
    }

    #[test]
    fn group_exchange_books_k_times_k_minus_one_transfers() {
        let mut tc = TestCtx::new(32);
        let bytes = state_bytes(&tc.model);
        let mut ctx = tc.ctx();
        let dur = book_group_exchange(5, bytes, &mut ctx);
        assert!(dur > 0.0);
        let snap = tc.ledger.snapshot();
        assert_eq!(snap.data_msgs, 5 * 4);
        assert_eq!(snap.data_bytes, 5 * 4 * 2 * 32 * 4);
    }

    #[test]
    fn payload_bytes_tracks_extended_momentum() {
        let mut states = random_states(2, 16, 14);
        assert_eq!(payload_bytes(&states, &[0, 1]), 2 * 16 * 4);
        // DP iteration: momentum carries Δ̄ and the clip indicator
        states[0].momentum.make_mut().extend_from_slice(&[0.0; 17]);
        assert_eq!(payload_bytes(&states, &[0]), (16 + 33) * 4);
    }

    #[test]
    fn state_bytes_counts_theta_and_momentum() {
        let tc = TestCtx::new(100);
        assert_eq!(state_bytes(&tc.model), 800);
    }
}
