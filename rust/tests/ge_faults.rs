//! Gilbert–Elliott link-chain verification: an inert GE config must be
//! bit-identical to the seed behaviour (zero extra draws, even with a
//! `LinkState` wired into the context), an active bursty plan must stay
//! bit-identical across the serial and parallel engines (all chain
//! advances happen in the serial schedule phase), the chain must reach
//! its stationary bad fraction `p / (p + r)`, and the Trainer must
//! surface burst counters and bandwidth percentiles through
//! `RunSummary` deterministically.

use std::sync::Arc;

use marfl::aggregation::{AggCtx, AggReport, GroupExchange, PeerState};
use marfl::config::ExperimentConfig;
use marfl::coordinator::{AggOptions, MarAggregator};
use marfl::fl::Trainer;
use marfl::metrics::{CommLedger, CommSnapshot};
use marfl::net::{BwDist, Fabric, FaultConfig, LinkState};
use marfl::rng::Rng;
use marfl::runtime::Runtime;
use marfl::sim::SimClock;

fn toy_model(p: usize) -> marfl::models::ModelMeta {
    marfl::models::ModelMeta {
        name: "toy".into(),
        param_count: p,
        padded_len: p,
        input_shape: vec![4],
        classes: 3,
        batch: 8,
        eval_chunk: 8,
        init_file: String::new(),
        artifacts: Default::default(),
    }
}

fn random_states(n: usize, p: usize, seed: u64) -> Vec<PeerState> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| PeerState {
            theta: (0..p).map(|_| rng.normal() as f32).collect(),
            momentum: (0..p).map(|_| rng.normal() as f32 * 0.1).collect(),
        })
        .collect()
}

/// A bursty plan: π = p/(p+r) = 0.25 of links bad at any time, mean
/// burst length 1/r ≈ 3.3 schedule ticks.
fn bursty_plan() -> FaultConfig {
    FaultConfig {
        loss: 0.05,
        ge_p: 0.1,
        ge_r: 0.3,
        ge_loss: 0.5,
        ge_bw: 0.25,
        ge_lat: 4.0,
        bw_dist: BwDist::LogNormal,
        bw_sigma: 0.5,
        bw_min: 0.2,
        bw_max: 1.0,
        ..FaultConfig::default()
    }
}

/// One MAR aggregate call under `faults` with an optional link chain;
/// returns (states, ledger snapshot, clock, report, link state).
#[allow(clippy::too_many_arguments)]
fn run_mar_linked(
    n: usize,
    m: usize,
    g: usize,
    p: usize,
    exchange: GroupExchange,
    faults: &FaultConfig,
    links: Option<LinkState>,
    parallel: bool,
    rng_seed: u64,
) -> (Vec<PeerState>, CommSnapshot, f64, AggReport, Option<LinkState>) {
    let mut states = random_states(n, p, 0x6E17 ^ n as u64);
    let agg: Vec<usize> = (0..n).collect();
    let ledger = Arc::new(CommLedger::new());
    let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
    let mut clock = SimClock::new();
    let mut rng = Rng::new(rng_seed);
    let model = toy_model(p);
    let mut mar = MarAggregator::with_options(
        n,
        m,
        g,
        ledger.clone(),
        7,
        AggOptions { exchange, parallel, ..AggOptions::default() },
    );
    ledger.reset(); // drop DHT join traffic
    let mut links = links;
    let mut ctx = AggCtx {
        fabric: &fabric,
        clock: &mut clock,
        rng: &mut rng,
        runtime: None,
        model: &model,
        faults,
        links: links.as_mut(),
    };
    let report = mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
    (states, ledger.snapshot(), clock.now(), report, links)
}

/// (a) Inert GE config ⇒ bit-identical to the plain plan, even when a
/// `LinkState` is wired into the context: with `ge_p = 0` and
/// `bw_dist = "off"` the chain code path must never run, never draw,
/// and never perturb states, ledger, clock or report.
#[test]
fn inert_ge_config_is_bit_identical_to_seed() {
    let inert = FaultConfig {
        loss: 0.15, // ordinary i.i.d. losses stay on
        ge_p: 0.0,  // ...but every chain is frozen good
        ge_r: 0.9,
        ge_loss: 1.0,
        ge_bw: 0.01,
        ge_lat: 100.0,
        bw_dist: BwDist::Off,
        bw_sigma: 9.0,
        bw_min: 0.5,
        bw_max: 0.5,
        ..FaultConfig::default()
    };
    assert!(!inert.time_correlated());
    let base = FaultConfig { loss: 0.15, ..FaultConfig::default() };
    for &exchange in &[GroupExchange::FullGather, GroupExchange::ReduceScatter]
    {
        for &parallel in &[false, true] {
            let (b_states, b_snap, b_clock, b_rep, _) = run_mar_linked(
                27, 3, 3, 129, exchange, &base, None, parallel, 77,
            );
            // wire a (necessarily empty) LinkState in anyway: the
            // delegation guard, not the caller, must keep it inert
            let ls = LinkState::new(&inert, 27, &mut Rng::new(5));
            let (i_states, i_snap, i_clock, i_rep, i_ls) = run_mar_linked(
                27,
                3,
                3,
                129,
                exchange,
                &inert,
                Some(ls.clone()),
                parallel,
                77,
            );
            for (a, b) in b_states.iter().zip(&i_states) {
                assert_eq!(a.theta, b.theta, "inert GE perturbed states");
                assert_eq!(a.momentum, b.momentum);
            }
            assert_eq!(b_snap, i_snap, "inert GE perturbed the ledger");
            assert_eq!(b_clock.to_bits(), i_clock.to_bits());
            assert_eq!(b_rep, i_rep);
            assert_eq!(
                i_ls.unwrap(),
                ls,
                "inert GE must never touch the link state"
            );
        }
    }
}

/// (b) An active bursty plan stays bit-identical across engines: chain
/// advances and bandwidth draws all happen in the serial schedule
/// phase, so serial and group-parallel runs agree on states, ledger,
/// clock, counters — and on the final chain state itself.
#[test]
fn bursty_plan_parallel_matches_serial() {
    let plan = bursty_plan();
    for &exchange in &[GroupExchange::FullGather, GroupExchange::ReduceScatter]
    {
        let mk = || LinkState::new(&plan, 27, &mut Rng::new(5));
        let (s_states, s_snap, s_clock, s_rep, s_ls) = run_mar_linked(
            27, 3, 3, 129, exchange, &plan, Some(mk()), false, 77,
        );
        let (p_states, p_snap, p_clock, p_rep, p_ls) = run_mar_linked(
            27, 3, 3, 129, exchange, &plan, Some(mk()), true, 77,
        );
        for (i, (a, b)) in s_states.iter().zip(&p_states).enumerate() {
            assert_eq!(a.theta, b.theta, "peer {i} theta diverged");
            assert_eq!(a.momentum, b.momentum, "peer {i} momentum diverged");
        }
        assert_eq!(s_snap, p_snap, "ledger diverged under bursty faults");
        assert_eq!(s_clock.to_bits(), p_clock.to_bits(), "clock diverged");
        assert_eq!(s_rep, p_rep, "fault counters diverged");
        let (s_ls, p_ls) = (s_ls.unwrap(), p_ls.unwrap());
        assert_eq!(s_ls, p_ls, "link chains diverged across engines");
        assert!(
            s_ls.ge_bad_transitions > 0,
            "π = 0.25 over 27² chains must produce burst onsets"
        );
    }
}

/// (c) Chain stationarity: advancing one chain many times from the
/// stationary initial distribution keeps the empirical bad fraction at
/// `p / (p + r)` within sampling noise.
#[test]
fn chain_reaches_stationary_bad_fraction() {
    let cfg = FaultConfig { ge_p: 0.12, ge_r: 0.28, ..FaultConfig::default() };
    let mut ls = LinkState::new(&cfg, 2, &mut Rng::new(11));
    let mut rng = Rng::new(12);
    let steps = 40_000usize;
    let mut bad = 0usize;
    for _ in 0..steps {
        if ls.advance(&cfg, 0, 1, &mut rng) {
            bad += 1;
        }
    }
    let want = cfg.ge_p / (cfg.ge_p + cfg.ge_r);
    let got = bad as f64 / steps as f64;
    assert!(
        (got - want).abs() < 0.02,
        "empirical bad fraction {got:.4} vs stationary {want:.4}"
    );
    // every recorded onset is a good→bad flip, so onsets can cover at
    // most half the steps
    assert!(ls.ge_bad_transitions > 0);
    assert!((ls.ge_bad_transitions as usize) < steps / 2 + 1);
}

/// (d) KD logit-exchange lanes under an active link chain: the
/// per-directed-link GE observations (PR 8's `draw_member` swap in
/// `kd::run_mkd`) all happen in the serial schedule phase, so the
/// student-parallel engine stays bit-identical to the serial reference
/// — states, ledger, clock, report counters, and the chain itself.
#[test]
fn kd_logit_lanes_bursty_parallel_matches_serial() {
    use marfl::kd::{KdEngine, KdReport};

    let plan = bursty_plan();
    let run = |parallel: bool| -> (
        Vec<PeerState>,
        CommSnapshot,
        f64,
        KdReport,
        LinkState,
    ) {
        let peers = 12;
        let rt = Runtime::new(&marfl::models::default_artifact_dir()).unwrap();
        let model = rt.meta.model("head").unwrap().clone();
        let mut rng = Rng::new(0x5EED);
        let mut fl =
            marfl::data::build("head", peers, 32, 250, true, 1.0, &mut rng.fork(1));
        let theta0 = rt.init_params("head").unwrap();
        let mut states = vec![PeerState::new(theta0); peers];
        let agg: Vec<usize> = (0..peers).collect();
        let ledger = Arc::new(CommLedger::new());
        let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
        let mut mar = MarAggregator::new(peers, 4, 2, ledger.clone(), 7);
        ledger.reset(); // drop DHT join traffic
        let kd = KdEngine::new(
            marfl::config::KdConfig {
                enabled: true,
                k_iterations: 6,
                rho_ell: 0.4,
                epochs: 2,
            },
            rt.meta.kd_tau,
            0.1,
            0.9,
        )
        .with_parallel(parallel);
        let mut clock = SimClock::new();
        let mut kd_rng = rng.fork(2);
        let mut links = Some(LinkState::new(&plan, peers, &mut Rng::new(5)));
        let mut ctx = AggCtx {
            fabric: &fabric,
            clock: &mut clock,
            rng: &mut kd_rng,
            runtime: Some(&rt),
            model: &model,
            faults: &plan,
            links: links.as_mut(),
        };
        let report = kd
            .run_mkd(
                1,
                &rt,
                &model,
                &fl.train,
                &mut fl.shards,
                &mut states,
                &agg,
                &mut mar,
                &mut ctx,
            )
            .unwrap();
        (states, ledger.snapshot(), clock.now(), report, links.unwrap())
    };

    let (s_states, s_snap, s_clock, s_rep, s_ls) = run(false);
    let (p_states, p_snap, p_clock, p_rep, p_ls) = run(true);
    for (i, (a, b)) in s_states.iter().zip(&p_states).enumerate() {
        assert_eq!(a.theta, b.theta, "peer {i} theta diverged");
        assert_eq!(a.momentum, b.momentum, "peer {i} momentum diverged");
    }
    assert_eq!(s_snap, p_snap, "ledger diverged on bursty KD lanes");
    assert_eq!(s_clock.to_bits(), p_clock.to_bits(), "clock diverged");
    assert_eq!(s_rep.kd_steps, p_rep.kd_steps);
    assert_eq!(s_rep.teacher_transfers, p_rep.teacher_transfers);
    assert_eq!(s_rep.mean_loss.to_bits(), p_rep.mean_loss.to_bits());
    assert_eq!(s_rep.faults, p_rep.faults, "KD fault counters diverged");
    assert_eq!(s_ls, p_ls, "link chains diverged across KD engines");
    // the chain actually fired on the logit lanes
    assert!(s_rep.faults.msgs_lost + s_rep.faults.bursty_losses > 0);
    assert!(s_rep.kd_steps > 0, "the pass must still do KD work");
}

/// End-to-end: a bursty Trainer run surfaces burst counters and
/// bandwidth percentiles through `RunSummary`, reproducibly; the same
/// config with the chain knobs zeroed reports neither.
#[test]
fn trainer_surfaces_burst_stats_deterministically() {
    let rt = Runtime::new(&marfl::models::default_artifact_dir()).unwrap();
    let base = ExperimentConfig {
        model: "head".into(),
        peers: 9,
        group_size: 3,
        iterations: 4,
        samples_per_peer: 32,
        test_samples: 250,
        eval_every: 4,
        local_batches: 2,
        seed: 991,
        ..Default::default()
    };
    let run = |cfg: ExperimentConfig| {
        let mut t = Trainer::new(cfg, &rt).unwrap();
        t.run().unwrap()
    };

    let clean = run(base.clone());
    assert_eq!(clean.faults.ge_bad_transitions, 0);
    assert_eq!(clean.faults.bursty_losses, 0);
    assert!(clean.faults.bw_percentiles.is_none(), "no bw draw when dist is off");

    let mut bursty_cfg = base.clone();
    bursty_cfg.faults = bursty_plan();
    let a = run(bursty_cfg.clone());
    let b = run(bursty_cfg.clone());
    assert!(
        a.faults.ge_bad_transitions > 0,
        "bursty run must observe burst onsets"
    );
    assert!(a.faults.msgs_lost > 0, "bursty run must lose messages");
    let [p10, p50, p90] =
        a.faults.bw_percentiles.expect("lognormal bw draw must report percentiles");
    assert!(p10 <= p50 && p50 <= p90, "percentiles must be ordered");
    assert!(
        p10 >= bursty_cfg.faults.bw_min - 1e-12
            && p90 <= bursty_cfg.faults.bw_max + 1e-12,
        "percentiles must respect the clamp: [{p10}, {p50}, {p90}]"
    );
    assert_eq!(a.faults, b.faults, "burst counters must be reproducible");
    assert_eq!(a.faults.bw_percentiles, b.faults.bw_percentiles);
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits());
    assert_eq!(a.comm, b.comm);

    // the bursty plan must actually cost something relative to the
    // matched i.i.d.-only plan (same loss, chains frozen)
    let mut iid_cfg = base;
    iid_cfg.faults =
        FaultConfig { loss: bursty_plan().loss, ..FaultConfig::default() };
    let iid = run(iid_cfg);
    assert!(
        a.sim_time_s > iid.sim_time_s,
        "bad-state slowdowns must show up in simulated time: \
         bursty {} vs iid {}",
        a.sim_time_s,
        iid.sim_time_s
    );
}
