//! Participation sampling and dropout injection (paper §3.1).

use crate::rng::Rng;

/// The paper's two disturbance knobs.
#[derive(Clone, Copy, Debug)]
pub struct ChurnModel {
    /// fraction of peers participating in an entire FL iteration
    pub participation: f64,
    /// probability a participant drops before aggregation (has done its
    /// local update, does not join `A_t`)
    pub dropout: f64,
}

impl ChurnModel {
    pub fn new(participation: f64, dropout: f64) -> Self {
        assert!(participation > 0.0 && participation <= 1.0);
        assert!((0.0..=1.0).contains(&dropout));
        ChurnModel { participation, dropout }
    }

    pub fn full() -> Self {
        ChurnModel { participation: 1.0, dropout: 0.0 }
    }

    /// Sample the participant set `U_t ⊆ [N]` for one FL iteration.
    /// Guarantees at least one participant.
    pub fn sample_participants(&self, n: usize, rng: &mut Rng) -> Vec<usize> {
        let k = ((n as f64 * self.participation).round() as usize).clamp(1, n);
        let mut idx = rng.sample_indices(n, k);
        idx.sort_unstable();
        idx
    }

    /// Thin `U_t` into the aggregation set `A_t`: each participant
    /// independently drops with probability `dropout`. Guarantees at least
    /// two aggregators when at least two participants exist (a single peer
    /// cannot form a group; the paper's dispatcher skips aggregation then).
    pub fn sample_aggregators(
        &self,
        participants: &[usize],
        rng: &mut Rng,
    ) -> Vec<usize> {
        self.sample_aggregators_counted(participants, rng).0
    }

    /// [`Self::sample_aggregators`] plus a flag reporting whether the
    /// keep-alive fallback fired (the dropout draws left `< 2` survivors
    /// and `A_t` was rebuilt from dropped participants) — a silent
    /// "resurrection" path `RunSummary` now surfaces as a metric.
    pub fn sample_aggregators_counted(
        &self,
        participants: &[usize],
        rng: &mut Rng,
    ) -> (Vec<usize>, bool) {
        let mut agg: Vec<usize> = participants
            .iter()
            .copied()
            .filter(|_| !rng.chance(self.dropout))
            .collect();
        let mut rescued = false;
        if agg.len() < 2 && participants.len() >= 2 {
            // keep the system alive under pathological dropout draws
            rescued = true;
            agg = participants.to_vec();
            while agg.len() > 2 {
                let i = rng.below(agg.len());
                agg.remove(i);
            }
        }
        (agg, rescued)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_returns_everyone() {
        let mut rng = Rng::new(1);
        let c = ChurnModel::full();
        assert_eq!(c.sample_participants(10, &mut rng), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn participation_rate_respected() {
        let mut rng = Rng::new(2);
        let c = ChurnModel::new(0.5, 0.0);
        let p = c.sample_participants(100, &mut rng);
        assert_eq!(p.len(), 50);
        // distinct & in range
        let mut q = p.clone();
        q.dedup();
        assert_eq!(q.len(), 50);
        assert!(p.iter().all(|&i| i < 100));
    }

    #[test]
    fn dropout_thins_aggregators_statistically() {
        let mut rng = Rng::new(3);
        let c = ChurnModel::new(1.0, 0.2);
        let participants: Vec<usize> = (0..1000).collect();
        let agg = c.sample_aggregators(&participants, &mut rng);
        let frac = agg.len() as f64 / 1000.0;
        assert!((frac - 0.8).abs() < 0.05, "survivor fraction {frac}");
    }

    #[test]
    fn never_fewer_than_two_aggregators() {
        let mut rng = Rng::new(4);
        let c = ChurnModel::new(1.0, 0.99);
        for _ in 0..50 {
            let agg = c.sample_aggregators(&[3, 9, 12], &mut rng);
            assert!(agg.len() >= 2, "{agg:?}");
        }
    }

    #[test]
    fn counted_variant_reports_rescues() {
        let mut rng = Rng::new(4);
        let c = ChurnModel::new(1.0, 1.0);
        // certain dropout: every draw kills everyone, so every call rescues
        let (agg, rescued) = c.sample_aggregators_counted(&[3, 9, 12], &mut rng);
        assert!(rescued);
        assert_eq!(agg.len(), 2);
        let c = ChurnModel::new(1.0, 0.0);
        let (agg, rescued) = c.sample_aggregators_counted(&[3, 9, 12], &mut rng);
        assert!(!rescued);
        assert_eq!(agg, vec![3, 9, 12]);
    }

    #[test]
    fn zero_dropout_keeps_all() {
        let mut rng = Rng::new(5);
        let c = ChurnModel::new(1.0, 0.0);
        let p: Vec<usize> = (0..20).collect();
        assert_eq!(c.sample_aggregators(&p, &mut rng), p);
    }
}
