//! Communication ledger: the paper's primary measurement instrument.
//!
//! Counters are atomic so the ledger can be shared (`Arc`) between the
//! coordinator, the DHT and the fabric without locks on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Which plane a message belongs to. The paper's claim is that control
/// traffic (DHT barriers/announcements, O(N log N) small messages) is
/// negligible next to data traffic (model exchange).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// DHT lookups, stores, barrier metadata.
    Control,
    /// Model / momentum / logits payloads.
    Data,
}

/// Lock-free byte/message accounting.
#[derive(Debug, Default)]
pub struct CommLedger {
    data_bytes: AtomicU64,
    data_msgs: AtomicU64,
    control_bytes: AtomicU64,
    control_msgs: AtomicU64,
}

/// A point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommSnapshot {
    pub data_bytes: u64,
    pub data_msgs: u64,
    pub control_bytes: u64,
    pub control_msgs: u64,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Book one message of `bytes` on `plane`.
    pub fn record(&self, plane: Plane, bytes: u64) {
        match plane {
            Plane::Data => {
                self.data_bytes.fetch_add(bytes, Ordering::Relaxed);
                self.data_msgs.fetch_add(1, Ordering::Relaxed);
            }
            Plane::Control => {
                self.control_bytes.fetch_add(bytes, Ordering::Relaxed);
                self.control_msgs.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn snapshot(&self) -> CommSnapshot {
        CommSnapshot {
            data_bytes: self.data_bytes.load(Ordering::Relaxed),
            data_msgs: self.data_msgs.load(Ordering::Relaxed),
            control_bytes: self.control_bytes.load(Ordering::Relaxed),
            control_msgs: self.control_msgs.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.data_bytes.store(0, Ordering::Relaxed);
        self.data_msgs.store(0, Ordering::Relaxed);
        self.control_bytes.store(0, Ordering::Relaxed);
        self.control_msgs.store(0, Ordering::Relaxed);
    }
}

impl CommSnapshot {
    pub fn total_bytes(&self) -> u64 {
        self.data_bytes + self.control_bytes
    }

    /// Delta between two snapshots (e.g. one FL iteration).
    pub fn since(&self, earlier: &CommSnapshot) -> CommSnapshot {
        CommSnapshot {
            data_bytes: self.data_bytes - earlier.data_bytes,
            data_msgs: self.data_msgs - earlier.data_msgs,
            control_bytes: self.control_bytes - earlier.control_bytes,
            control_msgs: self.control_msgs - earlier.control_msgs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn records_per_plane() {
        let l = CommLedger::new();
        l.record(Plane::Data, 100);
        l.record(Plane::Data, 50);
        l.record(Plane::Control, 8);
        let s = l.snapshot();
        assert_eq!(s.data_bytes, 150);
        assert_eq!(s.data_msgs, 2);
        assert_eq!(s.control_bytes, 8);
        assert_eq!(s.control_msgs, 1);
        assert_eq!(s.total_bytes(), 158);
    }

    #[test]
    fn since_computes_deltas() {
        let l = CommLedger::new();
        l.record(Plane::Data, 10);
        let a = l.snapshot();
        l.record(Plane::Data, 32);
        l.record(Plane::Control, 4);
        let d = l.snapshot().since(&a);
        assert_eq!(d.data_bytes, 32);
        assert_eq!(d.data_msgs, 1);
        assert_eq!(d.control_bytes, 4);
    }

    #[test]
    fn concurrent_recording_is_consistent() {
        let l = Arc::new(CommLedger::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        l.record(Plane::Data, 3);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = l.snapshot();
        assert_eq!(s.data_bytes, 12_000);
        assert_eq!(s.data_msgs, 4_000);
    }

    #[test]
    fn reset_zeroes() {
        let l = CommLedger::new();
        l.record(Plane::Control, 9);
        l.reset();
        assert_eq!(l.snapshot(), CommSnapshot::default());
    }
}
