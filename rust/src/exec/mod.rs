//! Parallel execution engine for the round loop.
//!
//! The simulation models a *parallel* P2P deployment — within one MAR
//! round every group averages concurrently, and every participant runs its
//! local SGD step concurrently — but the seed reproduction executed all of
//! it serially on one core. This module gives the simulator the same
//! parallelism it models:
//!
//! * a process-wide [`rayon`] thread pool sized by the `MARFL_THREADS`
//!   environment knob (default: all available cores), shared by every
//!   parallel phase so nested fan-out cannot oversubscribe the host;
//! * [`par_disjoint_map`] — safe concurrent mutation of *disjoint* index
//!   groups over one `&mut [T]` (the shape of a MAR round: groups are
//!   disjoint subsets of `states`). Overlapping or out-of-bounds groups
//!   are rejected before any thread is spawned;
//! * [`par_map_at`] — the singleton special case (one element per lane),
//!   used for peer-parallel local training.
//!
//! Determinism: callers draw all randomness and schedule-order-sensitive
//! state (batch cursors, DHT matchmaking, group membership) *serially*
//! before fanning out, so lane bodies are pure functions of disjoint data
//! and results are bit-identical to serial execution regardless of thread
//! count or interleaving. `tests/parallel_engine.rs` asserts this.

use anyhow::{ensure, Result};
use once_cell::sync::Lazy;
use rayon::prelude::*;

/// Worker count for the engine pool: `MARFL_THREADS` if set (>= 1),
/// otherwise the machine's available parallelism.
pub fn threads() -> usize {
    static N: Lazy<usize> = Lazy::new(|| {
        if let Some(v) = std::env::var_os("MARFL_THREADS") {
            if let Some(n) = v.to_str().and_then(|s| s.trim().parse::<usize>().ok())
            {
                if n >= 1 {
                    return n;
                }
            }
            log::warn!("ignoring invalid MARFL_THREADS={v:?}");
        }
        std::thread::available_parallelism().map_or(1, |n| n.get())
    });
    *N
}

/// The process-wide engine pool (built lazily on first parallel phase).
pub fn pool() -> &'static rayon::ThreadPool {
    static POOL: Lazy<rayon::ThreadPool> = Lazy::new(|| {
        rayon::ThreadPoolBuilder::new()
            .num_threads(threads())
            .thread_name(|i| format!("marfl-exec-{i}"))
            .build()
            .expect("build exec thread pool")
    });
    &POOL
}

/// Stable small per-thread index in `[0, buckets)` — the striping
/// primitive behind the contention-free counters (`CommLedger` shards,
/// the runtime's call accounting). Threads are assigned round-robin at
/// first use, so up to `buckets` workers touch distinct stripes.
pub fn thread_stripe(buckets: usize) -> usize {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static IDX: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
    }
    IDX.with(|c| {
        let mut v = c.get();
        if v == usize::MAX {
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            c.set(v);
        }
        v % buckets
    })
}

/// Run `f` with the calling worker's persistent instance of the scratch
/// type `T` — the per-worker arena primitive behind the allocation-free
/// hot paths (the native backend's `StepWorkspace`). Each thread owns one
/// `T` per type, created on first use with `Default` and reused for the
/// life of the thread, so steady-state calls allocate nothing. The entry
/// is *taken out* of the thread-local store for the duration of `f`:
/// re-entrant use of the same scratch type sees a fresh (temporary)
/// instance instead of a panicking `RefCell` borrow.
pub fn with_scratch<T, R, F>(f: F) -> R
where
    T: Default + 'static,
    F: FnOnce(&mut T) -> R,
{
    use std::any::{Any, TypeId};
    use std::cell::RefCell;
    use std::collections::HashMap;
    thread_local! {
        static SCRATCH: RefCell<HashMap<TypeId, Box<dyn Any>>> =
            RefCell::new(HashMap::new());
    }
    SCRATCH.with(|store| {
        let mut boxed: Box<dyn Any> = store
            .borrow_mut()
            .remove(&TypeId::of::<T>())
            .unwrap_or_else(|| Box::<T>::default());
        let out = f(boxed.downcast_mut::<T>().expect("scratch type"));
        store.borrow_mut().insert(TypeId::of::<T>(), boxed);
        out
    })
}

/// Raw-pointer wrapper so disjoint `&mut` views can cross thread
/// boundaries. Safety rests on the disjointness validation below.
struct SendPtr<T>(*mut T);

impl<T> SendPtr<T> {
    fn get(&self) -> *mut T {
        self.0
    }
}

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Validate that `groups` index into a slice of length `len` without any
/// index appearing twice (within a group or across groups). This is the
/// precondition that makes concurrent `&mut` views sound; callers get a
/// hard error — not UB — on overlap.
pub fn validate_disjoint(len: usize, groups: &[Vec<usize>]) -> Result<()> {
    let mut seen = vec![false; len];
    for (gi, group) in groups.iter().enumerate() {
        for &i in group {
            ensure!(
                i < len,
                "group {gi}: index {i} out of bounds (slice len {len})"
            );
            ensure!(
                !std::mem::replace(&mut seen[i], true),
                "group {gi}: index {i} appears in more than one group slot"
            );
        }
    }
    Ok(())
}

/// Run `f` once per group, concurrently, each invocation receiving
/// exclusive `&mut` views of that group's elements of `data` (in the
/// group's own index order). Results are returned in group order, so the
/// output is independent of scheduling. Rejects overlapping groups.
pub fn par_disjoint_map<T, R, F>(
    data: &mut [T],
    groups: &[Vec<usize>],
    f: F,
) -> Result<Vec<R>>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut [&mut T]) -> R + Sync,
{
    validate_disjoint(data.len(), groups)?;
    let base = SendPtr(data.as_mut_ptr());
    let out = pool().install(|| {
        groups
            .par_iter()
            .enumerate()
            .map(|(gi, group)| {
                // SAFETY: validate_disjoint guarantees every index is in
                // bounds and owned by exactly one group, so these &mut
                // views never alias across (or within) lanes.
                let mut views: Vec<&mut T> = group
                    .iter()
                    .map(|&i| unsafe { &mut *base.get().add(i) })
                    .collect();
                f(gi, &mut views)
            })
            .collect()
    });
    Ok(out)
}

/// The `idx`-th of `parts` balanced contiguous stripes of `[0, len)` —
/// the partition behind reduce-scatter chunk ownership (member `idx` of a
/// size-`parts` group owns exactly this stripe of every exchanged
/// vector). The first `len % parts` stripes take one extra element, so
/// stripes are disjoint, ordered, cover `[0, len)` and differ in length
/// by at most one.
pub fn stripe_range(len: usize, parts: usize, idx: usize) -> std::ops::Range<usize> {
    assert!(parts > 0, "stripe_range: zero parts");
    assert!(idx < parts, "stripe_range: stripe {idx} out of {parts}");
    let base = len / parts;
    let rem = len % parts;
    let start = idx * base + idx.min(rem);
    start..start + base + usize::from(idx < rem)
}

/// All `parts` stripes of [`stripe_range`], in order.
pub fn stripe_ranges(len: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    (0..parts).map(|i| stripe_range(len, parts, i)).collect()
}

/// Run `f` once per range over disjoint subslices of `data` (`f(i, &mut
/// data[ranges[i]])`). Ranges must be sorted, non-overlapping and in
/// bounds — validated before any work starts. With `parallel`, ranges fan
/// out across the engine pool; subslices are data-disjoint and every
/// element's computation is independent of lane scheduling, so results
/// match the serial order exactly. Callers inside group-parallel lanes
/// pass `parallel = false` (the outer fan-out owns the pool) unless the
/// lane count underfills it.
pub fn map_ranges_mut<T, F>(
    data: &mut [T],
    ranges: &[std::ops::Range<usize>],
    parallel: bool,
    f: F,
) -> Result<()>
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let mut prev_end = 0usize;
    for (i, r) in ranges.iter().enumerate() {
        ensure!(r.start <= r.end, "range {i} ({r:?}) is inverted");
        ensure!(
            r.end <= data.len(),
            "range {i} ({r:?}) escapes the slice (len {})",
            data.len()
        );
        ensure!(
            r.start >= prev_end,
            "range {i} ({r:?}) overlaps its predecessor or is out of order"
        );
        prev_end = r.end;
    }
    if parallel && threads() > 1 {
        let base = SendPtr(data.as_mut_ptr());
        pool().install(|| {
            ranges.par_iter().enumerate().for_each(|(i, r)| {
                // SAFETY: ranges validated sorted + disjoint + in bounds
                // above, so these subslices never alias.
                let sub = unsafe {
                    std::slice::from_raw_parts_mut(base.get().add(r.start), r.len())
                };
                f(i, sub);
            });
        });
    } else {
        for (i, r) in ranges.iter().enumerate() {
            f(i, &mut data[r.clone()]);
        }
    }
    Ok(())
}

/// Run `f` once per index, concurrently, each invocation receiving the
/// lane position and an exclusive `&mut` view of `data[indices[pos]]`.
/// Rejects duplicate or out-of-bounds indices. Results are in lane order.
pub fn par_map_at<T, R, F>(data: &mut [T], indices: &[usize], f: F) -> Result<Vec<R>>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let mut seen = vec![false; data.len()];
    for &i in indices {
        ensure!(i < data.len(), "index {i} out of bounds (len {})", data.len());
        ensure!(
            !std::mem::replace(&mut seen[i], true),
            "index {i} appears more than once"
        );
    }
    let base = SendPtr(data.as_mut_ptr());
    let out = pool().install(|| {
        indices
            .par_iter()
            .enumerate()
            .map(|(pos, &i)| {
                // SAFETY: indices validated distinct and in bounds above.
                let elem = unsafe { &mut *base.get().add(i) };
                f(pos, elem)
            })
            .collect()
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_map_mutates_and_preserves_order() {
        let mut data: Vec<u64> = (0..10).collect();
        let groups = vec![vec![0, 1], vec![4], vec![9, 3]];
        let sums = par_disjoint_map(&mut data, &groups, |gi, views| {
            let mut s = 0u64;
            for v in views.iter_mut() {
                **v += 100;
                s += **v;
            }
            (gi, s)
        })
        .unwrap();
        assert_eq!(sums, vec![(0, 201), (1, 104), (2, 212)]);
        assert_eq!(data, vec![100, 101, 2, 103, 104, 5, 6, 7, 8, 109]);
    }

    #[test]
    fn overlapping_groups_rejected() {
        let mut data = vec![0u8; 4];
        let overlapping = vec![vec![0, 1], vec![1, 2]];
        let err = par_disjoint_map(&mut data, &overlapping, |_, _| ()).unwrap_err();
        assert!(format!("{err:#}").contains("more than one group"));
        // nothing executed
        assert_eq!(data, vec![0; 4]);
    }

    #[test]
    fn duplicate_within_one_group_rejected() {
        let mut data = vec![0u8; 4];
        assert!(par_disjoint_map(&mut data, &[vec![2, 2]], |_, _| ()).is_err());
    }

    #[test]
    fn out_of_bounds_rejected() {
        let mut data = vec![0u8; 4];
        assert!(par_disjoint_map(&mut data, &[vec![4]], |_, _| ()).is_err());
        assert!(par_map_at(&mut data, &[4], |_, _| ()).is_err());
    }

    #[test]
    fn map_at_runs_each_lane_once() {
        let mut data: Vec<u64> = vec![10, 20, 30, 40];
        let got = par_map_at(&mut data, &[3, 0], |pos, v| {
            *v += 1;
            (pos, *v)
        })
        .unwrap();
        assert_eq!(got, vec![(0, 41), (1, 11)]);
        assert_eq!(data, vec![11, 20, 30, 41]);
    }

    #[test]
    fn map_at_rejects_duplicates() {
        let mut data = vec![0u8; 3];
        assert!(par_map_at(&mut data, &[1, 1], |_, _| ()).is_err());
    }

    #[test]
    fn empty_groups_are_fine() {
        let mut data = vec![0u8; 2];
        let out: Vec<()> = par_disjoint_map(&mut data, &[], |_, _| ()).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn stripe_ranges_partition_exactly() {
        assert_eq!(stripe_ranges(10, 3), vec![0..4, 4..7, 7..10]);
        let rs = stripe_ranges(4096, 4);
        assert!(rs.iter().all(|r| r.len() == 1024));
        // more parts than elements: trailing stripes are empty
        let rs = stripe_ranges(3, 5);
        assert_eq!(rs.iter().map(|r| r.len()).sum::<usize>(), 3);
        assert_eq!(rs.iter().filter(|r| r.is_empty()).count(), 2);
        // stripes always cover [0, len) in order
        for (len, parts) in [(0usize, 1usize), (1, 1), (129, 7), (4096, 5)] {
            let rs = stripe_ranges(len, parts);
            assert_eq!(rs.len(), parts);
            assert_eq!(rs.first().unwrap().start, 0);
            assert_eq!(rs.last().unwrap().end, len);
            for w in rs.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            for (i, r) in rs.iter().enumerate() {
                assert_eq!(*r, stripe_range(len, parts, i));
            }
        }
    }

    #[test]
    fn map_ranges_parallel_matches_serial() {
        let xform = |i: usize, s: &mut [u64]| {
            for v in s.iter_mut() {
                *v = *v * 3 + i as u64;
            }
        };
        let mut a: Vec<u64> = (0..1000).collect();
        let mut b = a.clone();
        let ranges = stripe_ranges(1000, 7);
        map_ranges_mut(&mut a, &ranges, false, xform).unwrap();
        map_ranges_mut(&mut b, &ranges, true, xform).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn map_ranges_rejects_bad_ranges() {
        let mut d = vec![0u8; 8];
        assert!(map_ranges_mut(&mut d, &[0..4, 3..6], false, |_, _| ()).is_err());
        assert!(map_ranges_mut(&mut d, &[0..4, 5..9], false, |_, _| ()).is_err());
        assert!(map_ranges_mut(&mut d, &[4..2], false, |_, _| ()).is_err());
        assert!(map_ranges_mut(&mut d, &[2..4, 0..2], false, |_, _| ()).is_err());
        assert!(map_ranges_mut(&mut d, &[0..2, 2..4], false, |_, _| ()).is_ok());
    }

    #[test]
    fn scratch_persists_per_thread_and_is_reentrant() {
        #[derive(Default)]
        struct Buf(Vec<u64>);
        // first use: default-constructed; grows and persists
        with_scratch(|b: &mut Buf| {
            assert!(b.0.is_empty());
            b.0.extend_from_slice(&[1, 2, 3]);
        });
        let ptr = with_scratch(|b: &mut Buf| {
            assert_eq!(b.0, vec![1, 2, 3], "scratch must persist across calls");
            b.0.as_ptr() as usize
        });
        // steady state: same backing allocation, no reallocation
        with_scratch(|b: &mut Buf| {
            assert_eq!(b.0.as_ptr() as usize, ptr);
            // re-entrant use sees a fresh temporary, not a borrow panic
            with_scratch(|inner: &mut Buf| assert!(inner.0.is_empty()));
        });
        // other threads get their own instance
        std::thread::spawn(|| {
            with_scratch(|b: &mut Buf| assert!(b.0.is_empty()));
        })
        .join()
        .unwrap();
    }

    #[test]
    fn threads_is_at_least_one() {
        assert!(threads() >= 1);
        // pool builds and runs
        let n: usize = pool().install(|| (0..100).into_par_iter().sum());
        assert_eq!(n, 4950);
    }
}
