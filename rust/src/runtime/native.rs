//! Pure-Rust reference backend: the same model semantics
//! `python/compile/model.py` lowers to HLO, implemented directly over the
//! flat-parameter ABI so the whole system (trainer, KD, DP, benches) runs
//! on machines without the XLA closure or lowered artifacts.
//!
//! Parameter layout matches JAX's `ravel_pytree` over the init dicts
//! (alphabetical key order, row-major leaves):
//!
//! * `head` — MLP 64 → 128(ReLU) → 20:
//!   `fc1_b[128] ‖ fc1_w[64,128] ‖ fc2_b[20] ‖ fc2_w[128,20]` (P = 10900)
//! * `cnn` — conv3×3(1→8, SAME) + ReLU + maxpool2, conv3×3(8→16, SAME) +
//!   ReLU + maxpool2, fc 256 → 64(ReLU) → 10, NHWC:
//!   `conv1_b[8] ‖ conv1_w[3,3,1,8] ‖ conv2_b[16] ‖ conv2_w[3,3,8,16] ‖`
//!   `fc1_b[64] ‖ fc1_w[256,64] ‖ fc2_b[10] ‖ fc2_w[64,10]` (P = 18346)
//!
//! Losses: mean softmax cross-entropy; KD adds Hinton-rescaled
//! `λ·τ²·KL(softmax(z̄/τ) ‖ softmax(s/τ))`. Updates: the damped momentum
//! rule `m' = μ·m + (1−μ)·g`, `θ' = θ − η·m'` over the padded flat vector
//! (padding gradients are zero, so the tail invariant survives).
//!
//! Since the allocation-free kernel rework the hot path is **in place and
//! workspace-backed**: [`train_step_into`] / [`kd_step_into`] apply the
//! fused damped-momentum update directly into caller-owned θ/momentum
//! buffers (the slices `params::Theta::make_mut` hands out), and every
//! forward cache, logit gradient, flat gradient and softmax scratch lives
//! in a per-worker [`StepWorkspace`] arena (`exec::with_scratch`) that is
//! sized once and reused — the steady state allocates nothing. The dense
//! and conv kernels are register-blocked (BLK-wide output tiles held in
//! registers across the reduction) while keeping every output element's
//! accumulation order identical to the seed scalar kernels, so results
//! are **bit-identical** to the original path — preserved verbatim as
//! [`reference`] and pinned by `tests/kernel_equivalence.rs`.
//!
//! Everything here is stateless and `Sync` (the workspace is per-thread);
//! the peer-parallel trainer calls these functions from many `exec`
//! workers at once.

use anyhow::{bail, ensure, Result};

use super::StepOut;
use crate::models::ModelMeta;
use crate::rng::Rng;

// ---------------------------------------------------------------------
// Flat layouts (offsets into theta / the gradient vector)
// ---------------------------------------------------------------------

// head task (20NG-like embeddings)
const H_IN: usize = 64;
const H_HID: usize = 128;
const H_CLS: usize = 20;
const H_FC1_B: usize = 0;
const H_FC1_W: usize = H_FC1_B + H_HID;
const H_FC2_B: usize = H_FC1_W + H_IN * H_HID;
const H_FC2_W: usize = H_FC2_B + H_CLS;
/// head true parameter count (10 900)
pub const HEAD_PARAMS: usize = H_FC2_W + H_HID * H_CLS;

// cnn task (MNIST-like 16×16×1 images)
const IMG: usize = 16;
const C1: usize = 8;
const C2: usize = 16;
const FC_IN: usize = 4 * 4 * C2; // 256, post two maxpools
const FC_HID: usize = 64;
const C_CLS: usize = 10;
const C_C1B: usize = 0;
const C_C1W: usize = C_C1B + C1;
const C_C2B: usize = C_C1W + 3 * 3 * C1;
const C_C2W: usize = C_C2B + C2;
const C_F1B: usize = C_C2W + 3 * 3 * C1 * C2;
const C_F1W: usize = C_F1B + FC_HID;
const C_F2B: usize = C_F1W + FC_IN * FC_HID;
const C_F2W: usize = C_F2B + C_CLS;
/// cnn true parameter count (18 346)
pub const CNN_PARAMS: usize = C_F2W + FC_HID * C_CLS;

fn sl(v: &[f32], off: usize, len: usize) -> &[f32] {
    &v[off..off + len]
}

fn sl_mut(v: &mut [f32], off: usize, len: usize) -> &mut [f32] {
    &mut v[off..off + len]
}

fn check_meta(m: &ModelMeta) -> Result<()> {
    let (params, elems, classes) = match m.name.as_str() {
        "head" => (HEAD_PARAMS, H_IN, H_CLS),
        "cnn" => (CNN_PARAMS, IMG * IMG, C_CLS),
        other => bail!("native backend has no model {other:?}"),
    };
    ensure!(
        m.param_count == params,
        "model {:?}: meta says {} params, native layout has {params}",
        m.name,
        m.param_count
    );
    ensure!(m.padded_len >= params, "padded_len below parameter count");
    ensure!(m.input_elems() == elems, "unexpected input shape");
    ensure!(m.classes == classes, "unexpected class count");
    Ok(())
}

fn batch_of(m: &ModelMeta, x: &[f32], y: &[i32]) -> Result<usize> {
    let elems = m.input_elems();
    ensure!(!y.is_empty() && x.len() == y.len() * elems, "x/y shape mismatch");
    for &yi in y {
        ensure!((0..m.classes as i32).contains(&yi), "label {yi} out of range");
    }
    Ok(y.len())
}

// ---------------------------------------------------------------------
// Dense / conv primitives — register-blocked (f32, matching the lowered
// kernels bit for bit)
// ---------------------------------------------------------------------
//
// Every kernel below tiles its output dimension BLK-wide so the
// accumulator tile lives in registers across the whole reduction instead
// of a load/store of the output per reduction step. The reduction order
// *per output element* is exactly the scalar seed kernel's (preserved
// verbatim in [`reference`]), so f32 rounding is identical and results
// are bit-identical — the property `tests/kernel_equivalence.rs` pins.

/// Register-block width (8 f32 = one 256-bit SIMD vector).
const BLK: usize = 8;

/// `out[b, o] = bias[o] + Σ_i x[b, i] · w[i, o]`. The o dimension is tiled
/// BLK-wide; each tile accumulates the full i reduction in registers
/// (per-element i order unchanged from the scalar kernel).
fn affine(x: &[f32], w: &[f32], bias: &[f32], b: usize, din: usize, dout: usize, out: &mut [f32]) {
    for bi in 0..b {
        let xrow = &x[bi * din..(bi + 1) * din];
        let orow = &mut out[bi * dout..(bi + 1) * dout];
        let mut o = 0usize;
        while o + BLK <= dout {
            let mut acc = [0.0f32; BLK];
            acc.copy_from_slice(&bias[o..o + BLK]);
            for (i, &xv) in xrow.iter().enumerate() {
                let wrow = &w[i * dout + o..i * dout + o + BLK];
                for (a, &wv) in acc.iter_mut().zip(wrow) {
                    *a += xv * wv;
                }
            }
            orow[o..o + BLK].copy_from_slice(&acc);
            o += BLK;
        }
        for oj in o..dout {
            let mut a = bias[oj];
            for (i, &xv) in xrow.iter().enumerate() {
                a += xv * w[i * dout + oj];
            }
            orow[oj] = a;
        }
    }
}

/// Backward of [`affine`]: dW/db stream the batch through BLK-wide
/// register tiles (one gradient-buffer load/store per tile instead of
/// one per example); dx keeps its dot-product form with a 4-wide din
/// tile sharing each upstream-gradient load. Per-element accumulation
/// order (bi ascending for dW/db, o ascending for dx) matches the
/// scalar seed kernel exactly.
#[allow(clippy::too_many_arguments)]
fn affine_backward(
    x: &[f32],
    w: &[f32],
    dout_grad: &[f32],
    b: usize,
    din: usize,
    dout: usize,
    dw: &mut [f32],
    db: &mut [f32],
    dx: Option<&mut [f32]>,
) {
    // db[o] += Σ_bi g[bi, o]
    let mut o = 0usize;
    while o + BLK <= dout {
        let mut acc = [0.0f32; BLK];
        acc.copy_from_slice(&db[o..o + BLK]);
        for bi in 0..b {
            let grow = &dout_grad[bi * dout + o..bi * dout + o + BLK];
            for (a, &g) in acc.iter_mut().zip(grow) {
                *a += g;
            }
        }
        db[o..o + BLK].copy_from_slice(&acc);
        o += BLK;
    }
    for oj in o..dout {
        let mut a = db[oj];
        for bi in 0..b {
            a += dout_grad[bi * dout + oj];
        }
        db[oj] = a;
    }
    // dW[i, o] += Σ_bi x[bi, i] · g[bi, o]
    for i in 0..din {
        let dwrow = &mut dw[i * dout..(i + 1) * dout];
        let mut o = 0usize;
        while o + BLK <= dout {
            let mut acc = [0.0f32; BLK];
            acc.copy_from_slice(&dwrow[o..o + BLK]);
            for bi in 0..b {
                let xv = x[bi * din + i];
                let grow = &dout_grad[bi * dout + o..bi * dout + o + BLK];
                for (a, &g) in acc.iter_mut().zip(grow) {
                    *a += xv * g;
                }
            }
            dwrow[o..o + BLK].copy_from_slice(&acc);
            o += BLK;
        }
        for oj in o..dout {
            let mut a = dwrow[oj];
            for bi in 0..b {
                a += x[bi * din + i] * dout_grad[bi * dout + oj];
            }
            dwrow[oj] = a;
        }
    }
    // dx[bi, i] = Σ_o w[i, o] · g[bi, o]
    if let Some(dx) = dx {
        for bi in 0..b {
            let grow = &dout_grad[bi * dout..(bi + 1) * dout];
            let dxrow = &mut dx[bi * din..(bi + 1) * din];
            let mut i = 0usize;
            while i + 4 <= din {
                let mut s = [0.0f32; 4];
                for (oj, &g) in grow.iter().enumerate() {
                    for (j, sj) in s.iter_mut().enumerate() {
                        *sj += w[(i + j) * dout + oj] * g;
                    }
                }
                dxrow[i..i + 4].copy_from_slice(&s);
                i += 4;
            }
            for ij in i..din {
                let wrow = &w[ij * dout..(ij + 1) * dout];
                let mut s = 0.0f32;
                for (&wv, &g) in wrow.iter().zip(grow) {
                    s += wv * g;
                }
                dxrow[ij] = s;
            }
        }
    }
}

fn relu_inplace(v: &mut [f32]) {
    for x in v {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

/// Zero grads where the (post-ReLU) activation is zero.
fn relu_mask(grad: &mut [f32], act: &[f32]) {
    for (g, &a) in grad.iter_mut().zip(act) {
        if a <= 0.0 {
            *g = 0.0;
        }
    }
}

/// 3×3 SAME conv, NHWC, stride 1. `w` is `[3,3,cin,cout]` row-major. The
/// cout dimension is tiled BLK-wide; the tile accumulates the whole
/// ky/kx/cin reduction (same boundary skips, same per-element order as
/// the scalar kernel) in registers.
#[allow(clippy::too_many_arguments)]
fn conv3x3_same(
    inp: &[f32],
    b: usize,
    hw: usize,
    cin: usize,
    w: &[f32],
    bias: &[f32],
    cout: usize,
    out: &mut [f32],
) {
    for bi in 0..b {
        let ibase = bi * hw * hw * cin;
        let obase = bi * hw * hw * cout;
        for y in 0..hw {
            for x in 0..hw {
                let ooff = obase + (y * hw + x) * cout;
                let mut co = 0usize;
                while co + BLK <= cout {
                    let mut acc = [0.0f32; BLK];
                    acc.copy_from_slice(&bias[co..co + BLK]);
                    for ky in 0..3usize {
                        let sy = y as isize + ky as isize - 1;
                        if sy < 0 || sy >= hw as isize {
                            continue;
                        }
                        for kx in 0..3usize {
                            let sx = x as isize + kx as isize - 1;
                            if sx < 0 || sx >= hw as isize {
                                continue;
                            }
                            let ioff =
                                ibase + (sy as usize * hw + sx as usize) * cin;
                            for i in 0..cin {
                                let iv = inp[ioff + i];
                                let woff = ((ky * 3 + kx) * cin + i) * cout + co;
                                let wrow = &w[woff..woff + BLK];
                                for (a, &wv) in acc.iter_mut().zip(wrow) {
                                    *a += iv * wv;
                                }
                            }
                        }
                    }
                    out[ooff + co..ooff + co + BLK].copy_from_slice(&acc);
                    co += BLK;
                }
                while co < cout {
                    let mut a = bias[co];
                    for ky in 0..3usize {
                        let sy = y as isize + ky as isize - 1;
                        if sy < 0 || sy >= hw as isize {
                            continue;
                        }
                        for kx in 0..3usize {
                            let sx = x as isize + kx as isize - 1;
                            if sx < 0 || sx >= hw as isize {
                                continue;
                            }
                            let ioff =
                                ibase + (sy as usize * hw + sx as usize) * cin;
                            for i in 0..cin {
                                a += inp[ioff + i]
                                    * w[((ky * 3 + kx) * cin + i) * cout + co];
                            }
                        }
                    }
                    out[ooff + co] = a;
                    co += 1;
                }
            }
        }
    }
}

/// Backward of [`conv3x3_same`], split into register-tiled passes: db
/// and dW stream every (bi, y, x) position through a BLK-wide register
/// tile (per-element order stays (bi, y, x) ascending under the forward
/// kernel's boundary skips); dInp keeps the scalar traversal with a
/// 4-wide cin tile sharing each gradient-row load. Bit-identical to the
/// scalar seed kernel.
#[allow(clippy::too_many_arguments)]
fn conv3x3_same_backward(
    inp: &[f32],
    b: usize,
    hw: usize,
    cin: usize,
    w: &[f32],
    cout: usize,
    dout: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dinp: Option<&mut [f32]>,
) {
    // db[c] += Σ_{bi,y,x} g[bi, y, x, c] — all cells, no boundary skips
    let cells = b * hw * hw;
    let mut co = 0usize;
    while co + BLK <= cout {
        let mut acc = [0.0f32; BLK];
        acc.copy_from_slice(&db[co..co + BLK]);
        for cell in 0..cells {
            let grow = &dout[cell * cout + co..cell * cout + co + BLK];
            for (a, &g) in acc.iter_mut().zip(grow) {
                *a += g;
            }
        }
        db[co..co + BLK].copy_from_slice(&acc);
        co += BLK;
    }
    while co < cout {
        let mut a = db[co];
        for cell in 0..cells {
            a += dout[cell * cout + co];
        }
        db[co] = a;
        co += 1;
    }
    // dW[ky, kx, i, c] += Σ over valid (bi, y, x) of inp · g
    for ky in 0..3usize {
        for kx in 0..3usize {
            for i in 0..cin {
                let wbase = ((ky * 3 + kx) * cin + i) * cout;
                let mut co = 0usize;
                while co + BLK <= cout {
                    let mut acc = [0.0f32; BLK];
                    acc.copy_from_slice(&dw[wbase + co..wbase + co + BLK]);
                    for bi in 0..b {
                        let ibase = bi * hw * hw * cin;
                        let obase = bi * hw * hw * cout;
                        for y in 0..hw {
                            let sy = y as isize + ky as isize - 1;
                            if sy < 0 || sy >= hw as isize {
                                continue;
                            }
                            for x in 0..hw {
                                let sx = x as isize + kx as isize - 1;
                                if sx < 0 || sx >= hw as isize {
                                    continue;
                                }
                                let iv = inp[ibase
                                    + (sy as usize * hw + sx as usize) * cin
                                    + i];
                                let goff = obase + (y * hw + x) * cout + co;
                                let grow = &dout[goff..goff + BLK];
                                for (a, &g) in acc.iter_mut().zip(grow) {
                                    *a += iv * g;
                                }
                            }
                        }
                    }
                    dw[wbase + co..wbase + co + BLK].copy_from_slice(&acc);
                    co += BLK;
                }
                while co < cout {
                    let mut a = dw[wbase + co];
                    for bi in 0..b {
                        let ibase = bi * hw * hw * cin;
                        let obase = bi * hw * hw * cout;
                        for y in 0..hw {
                            let sy = y as isize + ky as isize - 1;
                            if sy < 0 || sy >= hw as isize {
                                continue;
                            }
                            for x in 0..hw {
                                let sx = x as isize + kx as isize - 1;
                                if sx < 0 || sx >= hw as isize {
                                    continue;
                                }
                                a += inp[ibase
                                    + (sy as usize * hw + sx as usize) * cin
                                    + i]
                                    * dout[obase + (y * hw + x) * cout + co];
                            }
                        }
                    }
                    dw[wbase + co] = a;
                    co += 1;
                }
            }
        }
    }
    // dInp: scalar (y, x, ky, kx) traversal, cin tiled 4-wide
    if let Some(dinp) = dinp {
        for bi in 0..b {
            let ibase = bi * hw * hw * cin;
            let obase = bi * hw * hw * cout;
            for y in 0..hw {
                for x in 0..hw {
                    let goff = obase + (y * hw + x) * cout;
                    let grow = &dout[goff..goff + cout];
                    for ky in 0..3usize {
                        let sy = y as isize + ky as isize - 1;
                        if sy < 0 || sy >= hw as isize {
                            continue;
                        }
                        for kx in 0..3usize {
                            let sx = x as isize + kx as isize - 1;
                            if sx < 0 || sx >= hw as isize {
                                continue;
                            }
                            let ioff =
                                ibase + (sy as usize * hw + sx as usize) * cin;
                            let kbase = (ky * 3 + kx) * cin;
                            let mut i = 0usize;
                            while i + 4 <= cin {
                                let mut s = [0.0f32; 4];
                                for (oj, &g) in grow.iter().enumerate() {
                                    for (j, sj) in s.iter_mut().enumerate() {
                                        *sj += w[(kbase + i + j) * cout + oj] * g;
                                    }
                                }
                                for (j, &sj) in s.iter().enumerate() {
                                    dinp[ioff + i + j] += sj;
                                }
                                i += 4;
                            }
                            while i < cin {
                                let wrow =
                                    &w[(kbase + i) * cout..(kbase + i + 1) * cout];
                                let mut s = 0.0f32;
                                for (&wv, &g) in wrow.iter().zip(grow) {
                                    s += wv * g;
                                }
                                dinp[ioff + i] += s;
                                i += 1;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// 2×2 stride-2 max pool, NHWC; records the argmax flat index per cell.
fn maxpool2(inp: &[f32], b: usize, hw: usize, c: usize, out: &mut [f32], arg: &mut [u32]) {
    let oh = hw / 2;
    for bi in 0..b {
        for y in 0..oh {
            for x in 0..oh {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bidx = 0u32;
                    for dy in 0..2usize {
                        for dx in 0..2usize {
                            let idx = ((bi * hw + (2 * y + dy)) * hw + (2 * x + dx)) * c
                                + ch;
                            let v = inp[idx];
                            if v > best {
                                best = v;
                                bidx = idx as u32;
                            }
                        }
                    }
                    let oidx = ((bi * oh + y) * oh + x) * c + ch;
                    out[oidx] = best;
                    arg[oidx] = bidx;
                }
            }
        }
    }
}

fn maxpool2_backward(dout: &[f32], arg: &[u32], dinp: &mut [f32]) {
    for (&g, &i) in dout.iter().zip(arg.iter()) {
        dinp[i as usize] += g;
    }
}

// ---------------------------------------------------------------------
// Losses (workspace-buffer variants)
// ---------------------------------------------------------------------

/// Mean softmax cross-entropy; writes the logit gradient `(p − onehot)/B`
/// into `dz` (fully overwritten) and returns the loss.
fn ce_loss_grad_into(z: &[f32], y: &[i32], rows: usize, classes: usize, dz: &mut [f32]) -> f32 {
    debug_assert_eq!(dz.len(), rows * classes);
    let invb = 1.0 / rows as f32;
    let mut loss = 0.0f64;
    for r in 0..rows {
        let zr = &z[r * classes..(r + 1) * classes];
        let dr = &mut dz[r * classes..(r + 1) * classes];
        let max = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for (&zv, d) in zr.iter().zip(dr.iter_mut()) {
            let e = (zv - max).exp();
            *d = e;
            denom += e;
        }
        let yi = y[r] as usize;
        loss += (denom.ln() + max - zr[yi]) as f64;
        for d in dr.iter_mut() {
            *d = *d / denom * invb;
        }
        dr[yi] -= invb;
    }
    (loss / rows as f64) as f32
}

/// Softened softmax probabilities of one logit row at temperature τ.
fn softmax_tau(zr: &[f32], tau: f32, out: &mut [f32]) {
    let max = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max) / tau;
    let mut denom = 0.0f32;
    for (&zv, o) in zr.iter().zip(out.iter_mut()) {
        let e = (zv / tau - max).exp();
        *o = e;
        denom += e;
    }
    for o in out.iter_mut() {
        *o /= denom;
    }
}

/// KD loss `L = (1−λ)·CE + λ·τ²·KL(p_t ‖ p_s)` (Hinton rescaling); writes
/// the logit gradient `(1−λ)·dCE + (λ·τ/B)·(p_s − p_t)` into `dz` and
/// uses the caller's `ps`/`pt` softmax scratch (length `classes` each).
/// With λ = 0 this is exactly [`ce_loss_grad_into`].
#[allow(clippy::too_many_arguments)]
fn kd_loss_grad_into(
    z: &[f32],
    y: &[i32],
    zbar: &[f32],
    lam: f32,
    tau: f32,
    rows: usize,
    classes: usize,
    dz: &mut [f32],
    ps: &mut [f32],
    pt: &mut [f32],
) -> f32 {
    let ce = ce_loss_grad_into(z, y, rows, classes, dz);
    for d in dz.iter_mut() {
        *d *= 1.0 - lam;
    }
    let mut kl_mean = 0.0f64;
    let scale = lam * tau / rows as f32;
    for r in 0..rows {
        let zr = &z[r * classes..(r + 1) * classes];
        let tr = &zbar[r * classes..(r + 1) * classes];
        softmax_tau(zr, tau, ps);
        softmax_tau(tr, tau, pt);
        let mut kl = 0.0f64;
        for c in 0..classes {
            if pt[c] > 0.0 {
                kl += pt[c] as f64 * ((pt[c] as f64).ln() - (ps[c].max(1e-30) as f64).ln());
            }
        }
        kl_mean += kl;
        let dr = &mut dz[r * classes..(r + 1) * classes];
        for c in 0..classes {
            dr[c] += scale * (ps[c] - pt[c]);
        }
    }
    kl_mean /= rows as f64;
    (1.0 - lam) * ce + lam * tau * tau * (kl_mean as f32)
}

// ---------------------------------------------------------------------
// Per-worker scratch arena
// ---------------------------------------------------------------------

/// Every buffer one step / forward pass needs, owned per worker thread
/// (`exec::with_scratch`) and reused across calls: the seed path
/// heap-allocated each of these afresh per `train_step`/`kd_step`/
/// `logits`/`eval_chunk` call. Buffers are grown once per (model, batch)
/// shape; accumulation targets are re-zeroed (a memset, not an
/// allocation) before each use, buffers the kernels fully overwrite are
/// only resized.
#[derive(Default)]
pub struct StepWorkspace {
    /// padded flat gradient (zeroed per step; backward accumulates)
    g: Vec<f32>,
    /// loss gradient wrt logits [b, classes]
    dz: Vec<f32>,
    /// post-ReLU hidden activations (head fc1 / cnn fc1)
    h: Vec<f32>,
    /// logits [b, classes]
    z: Vec<f32>,
    /// cnn: post-ReLU conv1 activations [b,16,16,8]
    a1: Vec<f32>,
    /// cnn: pooled [b,8,8,8]
    p1: Vec<f32>,
    arg1: Vec<u32>,
    /// cnn: post-ReLU conv2 activations [b,8,8,16]
    a2: Vec<f32>,
    /// cnn: pooled = flat fc input [b,4,4,16] == [b,256]
    p2: Vec<f32>,
    arg2: Vec<u32>,
    /// hidden-layer gradient scratch
    dh: Vec<f32>,
    /// cnn backward scratch (dp* accumulate, hence zeroed per step)
    dp2: Vec<f32>,
    da2: Vec<f32>,
    dp1: Vec<f32>,
    da1: Vec<f32>,
    /// softmax scratch rows for the KD loss
    ps: Vec<f32>,
    pt: Vec<f32>,
}

/// Size `buf` for `n` elements the kernel fully overwrites (no zeroing;
/// allocation-free once capacity is established).
fn sized(buf: &mut Vec<f32>, n: usize) {
    if buf.len() != n {
        buf.resize(n, 0.0);
    }
}

fn sized_u32(buf: &mut Vec<u32>, n: usize) {
    if buf.len() != n {
        buf.resize(n, 0);
    }
}

/// Size `buf` to `n` zeros — for accumulation targets. A memset in the
/// steady state, never an allocation once capacity is established.
fn zeroed(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

// ---------------------------------------------------------------------
// Workspace-backed forward / backward passes
// ---------------------------------------------------------------------

fn head_forward_ws(ws: &mut StepWorkspace, theta: &[f32], x: &[f32], b: usize) {
    let fc1_b = sl(theta, H_FC1_B, H_HID);
    let fc1_w = sl(theta, H_FC1_W, H_IN * H_HID);
    let fc2_b = sl(theta, H_FC2_B, H_CLS);
    let fc2_w = sl(theta, H_FC2_W, H_HID * H_CLS);
    sized(&mut ws.h, b * H_HID);
    sized(&mut ws.z, b * H_CLS);
    affine(x, fc1_w, fc1_b, b, H_IN, H_HID, &mut ws.h);
    relu_inplace(&mut ws.h);
    affine(&ws.h, fc2_w, fc2_b, b, H_HID, H_CLS, &mut ws.z);
}

fn head_backward_ws(
    ws: &mut StepWorkspace,
    m: &ModelMeta,
    theta: &[f32],
    x: &[f32],
    b: usize,
) {
    zeroed(&mut ws.g, m.padded_len);
    sized(&mut ws.dh, b * H_HID);
    let fc2_w = sl(theta, H_FC2_W, H_HID * H_CLS);
    let StepWorkspace { g, dz, h, dh, .. } = ws;
    // decompose the flat gradient into its non-overlapping layer slices
    let (gfc1b, rest) = g.split_at_mut(H_HID);
    let (gfc1w, rest) = rest.split_at_mut(H_IN * H_HID);
    let (gfc2b, rest) = rest.split_at_mut(H_CLS);
    let (gfc2w, _pad) = rest.split_at_mut(H_HID * H_CLS);

    affine_backward(h, fc2_w, dz, b, H_HID, H_CLS, gfc2w, gfc2b, Some(&mut dh[..]));
    relu_mask(dh, h);
    affine_backward(x, &[], dh, b, H_IN, H_HID, gfc1w, gfc1b, None);
}

fn cnn_forward_ws(ws: &mut StepWorkspace, theta: &[f32], x: &[f32], b: usize) {
    let c1b = sl(theta, C_C1B, C1);
    let c1w = sl(theta, C_C1W, 3 * 3 * C1);
    let c2b = sl(theta, C_C2B, C2);
    let c2w = sl(theta, C_C2W, 3 * 3 * C1 * C2);
    let f1b = sl(theta, C_F1B, FC_HID);
    let f1w = sl(theta, C_F1W, FC_IN * FC_HID);
    let f2b = sl(theta, C_F2B, C_CLS);
    let f2w = sl(theta, C_F2W, FC_HID * C_CLS);

    sized(&mut ws.a1, b * IMG * IMG * C1);
    sized(&mut ws.p1, b * 8 * 8 * C1);
    sized_u32(&mut ws.arg1, b * 8 * 8 * C1);
    sized(&mut ws.a2, b * 8 * 8 * C2);
    sized(&mut ws.p2, b * 4 * 4 * C2);
    sized_u32(&mut ws.arg2, b * 4 * 4 * C2);
    sized(&mut ws.h, b * FC_HID);
    sized(&mut ws.z, b * C_CLS);

    conv3x3_same(x, b, IMG, 1, c1w, c1b, C1, &mut ws.a1);
    relu_inplace(&mut ws.a1);
    maxpool2(&ws.a1, b, IMG, C1, &mut ws.p1, &mut ws.arg1);

    conv3x3_same(&ws.p1, b, 8, C1, c2w, c2b, C2, &mut ws.a2);
    relu_inplace(&mut ws.a2);
    maxpool2(&ws.a2, b, 8, C2, &mut ws.p2, &mut ws.arg2);

    affine(&ws.p2, f1w, f1b, b, FC_IN, FC_HID, &mut ws.h);
    relu_inplace(&mut ws.h);
    affine(&ws.h, f2w, f2b, b, FC_HID, C_CLS, &mut ws.z);
}

fn cnn_backward_ws(
    ws: &mut StepWorkspace,
    m: &ModelMeta,
    theta: &[f32],
    x: &[f32],
    b: usize,
) {
    zeroed(&mut ws.g, m.padded_len);
    sized(&mut ws.dh, b * FC_HID);
    sized(&mut ws.dp2, b * FC_IN);
    // maxpool/conv backward accumulate into these
    zeroed(&mut ws.da2, b * 8 * 8 * C2);
    zeroed(&mut ws.dp1, b * 8 * 8 * C1);
    zeroed(&mut ws.da1, b * IMG * IMG * C1);
    let c2w = sl(theta, C_C2W, 3 * 3 * C1 * C2);
    let f1w = sl(theta, C_F1W, FC_IN * FC_HID);
    let f2w = sl(theta, C_F2W, FC_HID * C_CLS);
    let StepWorkspace { g, dz, h, a1, p1, arg1, a2, p2, arg2, dh, dp2, da2, dp1, da1, .. } =
        ws;
    // decompose the flat gradient into its non-overlapping layer slices
    let (gc1b, rest) = g.split_at_mut(C1);
    let (gc1w, rest) = rest.split_at_mut(3 * 3 * C1);
    let (gc2b, rest) = rest.split_at_mut(C2);
    let (gc2w, rest) = rest.split_at_mut(3 * 3 * C1 * C2);
    let (gf1b, rest) = rest.split_at_mut(FC_HID);
    let (gf1w, rest) = rest.split_at_mut(FC_IN * FC_HID);
    let (gf2b, rest) = rest.split_at_mut(C_CLS);
    let (gf2w, _pad) = rest.split_at_mut(FC_HID * C_CLS);

    // fc head
    affine_backward(h, f2w, dz, b, FC_HID, C_CLS, gf2w, gf2b, Some(&mut dh[..]));
    relu_mask(dh, h);
    affine_backward(p2, f1w, dh, b, FC_IN, FC_HID, gf1w, gf1b, Some(&mut dp2[..]));

    // conv block 2
    maxpool2_backward(dp2, arg2, da2);
    relu_mask(da2, a2);
    conv3x3_same_backward(p1, b, 8, C1, c2w, C2, da2, gc2w, gc2b, Some(&mut dp1[..]));

    // conv block 1
    maxpool2_backward(dp1, arg1, da1);
    relu_mask(da1, a1);
    conv3x3_same_backward(x, b, IMG, 1, &[], C1, da1, gc1w, gc1b, None);
}

/// Forward pass into the workspace (`ws.z` holds the logits afterwards).
fn forward_ws(
    ws: &mut StepWorkspace,
    m: &ModelMeta,
    theta: &[f32],
    x: &[f32],
    b: usize,
) -> Result<()> {
    ensure!(theta.len() == m.padded_len, "theta length mismatch");
    match m.name.as_str() {
        "head" => head_forward_ws(ws, theta, x, b),
        "cnn" => cnn_forward_ws(ws, theta, x, b),
        other => bail!("native backend has no model {other:?}"),
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Entry points (called by the Runtime facade)
// ---------------------------------------------------------------------

/// Forward + loss-grad + backward + fused damped momentum applied in
/// place, generically over the loss's logit gradient. The `loss_grad`
/// closure reads `ws.z` and fills `ws.dz`.
#[allow(clippy::too_many_arguments)]
fn step_into_with<F>(
    m: &ModelMeta,
    theta: &mut [f32],
    momentum: &mut [f32],
    x: &[f32],
    b: usize,
    eta: f32,
    mu: f32,
    loss_grad: F,
) -> Result<f32>
where
    F: FnOnce(&mut StepWorkspace, usize) -> f32,
{
    ensure!(theta.len() == m.padded_len, "theta length mismatch");
    ensure!(momentum.len() == m.padded_len, "momentum length mismatch");
    crate::exec::with_scratch(|ws: &mut StepWorkspace| -> Result<f32> {
        let loss = match m.name.as_str() {
            "head" => {
                head_forward_ws(ws, theta, x, b);
                let loss = loss_grad(ws, b);
                head_backward_ws(ws, m, theta, x, b);
                loss
            }
            "cnn" => {
                cnn_forward_ws(ws, theta, x, b);
                let loss = loss_grad(ws, b);
                cnn_backward_ws(ws, m, theta, x, b);
                loss
            }
            other => bail!("native backend has no model {other:?}"),
        };
        // fused damped-momentum update, in place over the padded flat
        // vectors: m' = μ·m + (1−μ)·g, θ' = θ − η·m'. Same expressions,
        // same order as the seed rule — bit-identical; padding gradients
        // are zero, so the tail invariant survives.
        for ((t, mv), &gv) in theta.iter_mut().zip(momentum.iter_mut()).zip(ws.g.iter()) {
            let mn = mu * *mv + (1.0 - mu) * gv;
            *mv = mn;
            *t -= eta * mn;
        }
        Ok(loss)
    })
}

/// One local momentum-SGD step over a batch, applied **in place**:
/// `theta`/`momentum` are the buffers `params::Theta::make_mut` hands
/// out, and the step allocates nothing in the steady state. Returns the
/// batch loss. Bit-identical to the seed [`reference::train_step`] path
/// (pinned by `tests/kernel_equivalence.rs`).
pub fn train_step_into(
    m: &ModelMeta,
    theta: &mut [f32],
    momentum: &mut [f32],
    x: &[f32],
    y: &[i32],
    eta: f32,
    mu: f32,
) -> Result<f32> {
    check_meta(m)?;
    let b = batch_of(m, x, y)?;
    let classes = m.classes;
    step_into_with(m, theta, momentum, x, b, eta, mu, |ws, b| {
        sized(&mut ws.dz, b * classes);
        ce_loss_grad_into(&ws.z, y, b, classes, &mut ws.dz)
    })
}

/// One Moshpit-KD student step (Algorithm 2), applied **in place** like
/// [`train_step_into`]. τ is the lowering-time KD temperature
/// (`meta.kd_tau`).
#[allow(clippy::too_many_arguments)]
pub fn kd_step_into(
    m: &ModelMeta,
    theta: &mut [f32],
    momentum: &mut [f32],
    x: &[f32],
    y: &[i32],
    zbar: &[f32],
    lambda: f32,
    tau: f32,
    eta: f32,
    mu: f32,
) -> Result<f32> {
    check_meta(m)?;
    let b = batch_of(m, x, y)?;
    ensure!(zbar.len() == b * m.classes, "zbar shape mismatch");
    ensure!(tau > 0.0, "KD temperature must be positive");
    let classes = m.classes;
    step_into_with(m, theta, momentum, x, b, eta, mu, |ws, b| {
        sized(&mut ws.dz, b * classes);
        sized(&mut ws.ps, classes);
        sized(&mut ws.pt, classes);
        let StepWorkspace { z, dz, ps, pt, .. } = ws;
        kd_loss_grad_into(z, y, zbar, lambda, tau, b, classes, dz, ps, pt)
    })
}

/// One local momentum-SGD step over a batch — compat shim over
/// [`train_step_into`] for callers that want freshly owned buffers.
pub fn train_step(
    m: &ModelMeta,
    theta: &[f32],
    momentum: &[f32],
    x: &[f32],
    y: &[i32],
    eta: f32,
    mu: f32,
) -> Result<StepOut> {
    let mut theta2 = theta.to_vec();
    let mut momentum2 = momentum.to_vec();
    let loss = train_step_into(m, &mut theta2, &mut momentum2, x, y, eta, mu)?;
    Ok(StepOut { theta: theta2, momentum: momentum2, loss })
}

/// One Moshpit-KD student step — compat shim over [`kd_step_into`].
#[allow(clippy::too_many_arguments)]
pub fn kd_step(
    m: &ModelMeta,
    theta: &[f32],
    momentum: &[f32],
    x: &[f32],
    y: &[i32],
    zbar: &[f32],
    lambda: f32,
    tau: f32,
    eta: f32,
    mu: f32,
) -> Result<StepOut> {
    let mut theta2 = theta.to_vec();
    let mut momentum2 = momentum.to_vec();
    let loss =
        kd_step_into(m, &mut theta2, &mut momentum2, x, y, zbar, lambda, tau, eta, mu)?;
    Ok(StepOut { theta: theta2, momentum: momentum2, loss })
}

/// Forward pass: logits for a batch, written into `out` (cleared first).
/// The forward caches live in the per-worker workspace, so KD teacher
/// rating stops allocating activation buffers per call.
pub fn logits_into(m: &ModelMeta, theta: &[f32], x: &[f32], out: &mut Vec<f32>) -> Result<()> {
    check_meta(m)?;
    let elems = m.input_elems();
    ensure!(!x.is_empty() && x.len() % elems == 0, "x shape mismatch");
    let b = x.len() / elems;
    crate::exec::with_scratch(|ws: &mut StepWorkspace| -> Result<()> {
        forward_ws(ws, m, theta, x, b)?;
        out.clear();
        out.extend_from_slice(&ws.z);
        Ok(())
    })
}

/// Forward pass: logits for a batch (allocating convenience wrapper).
pub fn logits(m: &ModelMeta, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    logits_into(m, theta, x, &mut out)?;
    Ok(out)
}

/// One eval chunk: (summed NLL, correct count). Workspace-backed — the
/// whole evaluation allocates nothing in the steady state.
pub fn eval_chunk(m: &ModelMeta, theta: &[f32], x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
    check_meta(m)?;
    let rows = batch_of(m, x, y)?;
    crate::exec::with_scratch(|ws: &mut StepWorkspace| -> Result<(f64, f64)> {
        forward_ws(ws, m, theta, x, rows)?;
        let c = m.classes;
        let z: &[f32] = &ws.z;
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for r in 0..rows {
            let zr = &z[r * c..(r + 1) * c];
            let max = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let denom: f32 = zr.iter().map(|&v| (v - max).exp()).sum();
            loss_sum += (denom.ln() + max - zr[y[r] as usize]) as f64;
            let mut best = 0usize;
            for (j, &v) in zr.iter().enumerate() {
                if v > zr[best] {
                    best = j;
                }
            }
            if best == y[r] as usize {
                correct += 1.0;
            }
        }
        Ok((loss_sum, correct))
    })
}

/// Mean of `k` stacked flat vectors (`stack` row-major `[k, padded_len]`),
/// through the same allocation-free f64 strip kernel the aggregators use.
pub fn group_mean(m: &ModelMeta, stack: &[f32], k: usize) -> Result<Vec<f32>> {
    let p = m.padded_len;
    ensure!(k > 0 && stack.len() == k * p, "stack shape mismatch");
    let mut out = vec![0.0f32; p];
    crate::aggregation::mean_indexed_into(k, |r| &stack[r * p..(r + 1) * p], &mut out, true);
    Ok(out)
}

/// Deterministic He initialization over the flat layout (weights
/// `N(0, 2/fan_in)`, biases zero, zero tail padding) — the artifact-free
/// stand-in for `{m}_init.bin`. Every call returns the same θ⁰, so all
/// peers share it (paper §2.2).
pub fn init_params(m: &ModelMeta) -> Result<Vec<f32>> {
    check_meta(m)?;
    let mut theta = vec![0.0f32; m.padded_len];
    fn he_fill(slice: &mut [f32], fan_in: usize, rng: &mut Rng) {
        let std = (2.0 / fan_in as f64).sqrt();
        for v in slice {
            *v = (rng.normal() * std) as f32;
        }
    }
    match m.name.as_str() {
        "head" => {
            let mut rng = Rng::new(0x4EAD_5EED);
            he_fill(sl_mut(&mut theta, H_FC1_W, H_IN * H_HID), H_IN, &mut rng);
            he_fill(sl_mut(&mut theta, H_FC2_W, H_HID * H_CLS), H_HID, &mut rng);
        }
        "cnn" => {
            let mut rng = Rng::new(0xC4_45EED);
            he_fill(sl_mut(&mut theta, C_C1W, 3 * 3 * C1), 9, &mut rng);
            he_fill(sl_mut(&mut theta, C_C2W, 3 * 3 * C1 * C2), 9 * C1, &mut rng);
            he_fill(sl_mut(&mut theta, C_F1W, FC_IN * FC_HID), FC_IN, &mut rng);
            he_fill(sl_mut(&mut theta, C_F2W, FC_HID * C_CLS), FC_HID, &mut rng);
        }
        other => bail!("native backend has no model {other:?}"),
    }
    Ok(theta)
}

// ---------------------------------------------------------------------
// Seed reference path
// ---------------------------------------------------------------------

/// The seed's allocating, scalar-kernel backend, preserved verbatim: the
/// bit-identity anchor for the workspace/in-place path
/// (`tests/kernel_equivalence.rs` asserts exact equality of states,
/// momentum and losses) and the baseline of the `micro_hotpath`
/// train-step ablation (`BENCH_kernels.json`). Element-wise helpers that
/// the rework did not touch (ReLU, maxpool, τ-softmax) are shared with
/// the parent module.
pub mod reference {
    use anyhow::{bail, ensure, Result};

    use super::{
        batch_of, check_meta, maxpool2, maxpool2_backward, relu_inplace, relu_mask,
        sl, softmax_tau, C1, C2, C_C1W, C_C2W, C_CLS, C_F1W, C_F2W, FC_HID, FC_IN,
        H_CLS, H_FC1_W, H_FC2_W, H_HID, H_IN, IMG,
    };
    use crate::models::ModelMeta;
    use crate::runtime::StepOut;

    /// `out[b, o] = bias[o] + Σ_i x[b, i] · w[i, o]` (seed scalar kernel)
    fn affine(
        x: &[f32],
        w: &[f32],
        bias: &[f32],
        b: usize,
        din: usize,
        dout: usize,
        out: &mut [f32],
    ) {
        for bi in 0..b {
            let xrow = &x[bi * din..(bi + 1) * din];
            let orow = &mut out[bi * dout..(bi + 1) * dout];
            orow.copy_from_slice(bias);
            for (i, &xv) in xrow.iter().enumerate() {
                let wrow = &w[i * dout..(i + 1) * dout];
                for (ov, &wv) in orow.iter_mut().zip(wrow) {
                    *ov += xv * wv;
                }
            }
        }
    }

    /// Accumulate dW/db (and optionally dx) for an affine layer given
    /// dout (seed scalar kernel).
    #[allow(clippy::too_many_arguments)]
    fn affine_backward(
        x: &[f32],
        w: &[f32],
        dout_grad: &[f32],
        b: usize,
        din: usize,
        dout: usize,
        dw: &mut [f32],
        db: &mut [f32],
        mut dx: Option<&mut [f32]>,
    ) {
        for bi in 0..b {
            let xrow = &x[bi * din..(bi + 1) * din];
            let grow = &dout_grad[bi * dout..(bi + 1) * dout];
            for (dbv, &g) in db.iter_mut().zip(grow) {
                *dbv += g;
            }
            for (i, &xv) in xrow.iter().enumerate() {
                let dwrow = &mut dw[i * dout..(i + 1) * dout];
                for (dwv, &g) in dwrow.iter_mut().zip(grow) {
                    *dwv += xv * g;
                }
            }
            if let Some(dx) = dx.as_deref_mut() {
                let dxrow = &mut dx[bi * din..(bi + 1) * din];
                for (i, dxv) in dxrow.iter_mut().enumerate() {
                    let wrow = &w[i * dout..(i + 1) * dout];
                    let mut s = 0.0f32;
                    for (&wv, &g) in wrow.iter().zip(grow) {
                        s += wv * g;
                    }
                    *dxv = s;
                }
            }
        }
    }

    /// 3×3 SAME conv, NHWC, stride 1 (seed scalar kernel).
    #[allow(clippy::too_many_arguments)]
    fn conv3x3_same(
        inp: &[f32],
        b: usize,
        hw: usize,
        cin: usize,
        w: &[f32],
        bias: &[f32],
        cout: usize,
        out: &mut [f32],
    ) {
        for bi in 0..b {
            let ibase = bi * hw * hw * cin;
            let obase = bi * hw * hw * cout;
            for y in 0..hw {
                for x in 0..hw {
                    let ooff = obase + (y * hw + x) * cout;
                    let orow = &mut out[ooff..ooff + cout];
                    orow.copy_from_slice(bias);
                    for ky in 0..3usize {
                        let sy = y as isize + ky as isize - 1;
                        if sy < 0 || sy >= hw as isize {
                            continue;
                        }
                        for kx in 0..3usize {
                            let sx = x as isize + kx as isize - 1;
                            if sx < 0 || sx >= hw as isize {
                                continue;
                            }
                            let ioff =
                                ibase + (sy as usize * hw + sx as usize) * cin;
                            for i in 0..cin {
                                let iv = inp[ioff + i];
                                let woff = ((ky * 3 + kx) * cin + i) * cout;
                                let wrow = &w[woff..woff + cout];
                                for (ov, &wv) in orow.iter_mut().zip(wrow) {
                                    *ov += iv * wv;
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    /// Backward of the seed conv kernel.
    #[allow(clippy::too_many_arguments)]
    fn conv3x3_same_backward(
        inp: &[f32],
        b: usize,
        hw: usize,
        cin: usize,
        w: &[f32],
        cout: usize,
        dout: &[f32],
        dw: &mut [f32],
        db: &mut [f32],
        mut dinp: Option<&mut [f32]>,
    ) {
        for bi in 0..b {
            let ibase = bi * hw * hw * cin;
            let obase = bi * hw * hw * cout;
            for y in 0..hw {
                for x in 0..hw {
                    let goff = obase + (y * hw + x) * cout;
                    let grow = &dout[goff..goff + cout];
                    for (dbv, &g) in db.iter_mut().zip(grow) {
                        *dbv += g;
                    }
                    for ky in 0..3usize {
                        let sy = y as isize + ky as isize - 1;
                        if sy < 0 || sy >= hw as isize {
                            continue;
                        }
                        for kx in 0..3usize {
                            let sx = x as isize + kx as isize - 1;
                            if sx < 0 || sx >= hw as isize {
                                continue;
                            }
                            let ioff =
                                ibase + (sy as usize * hw + sx as usize) * cin;
                            for i in 0..cin {
                                let iv = inp[ioff + i];
                                let woff = ((ky * 3 + kx) * cin + i) * cout;
                                let dwrow = &mut dw[woff..woff + cout];
                                for (dwv, &g) in dwrow.iter_mut().zip(grow) {
                                    *dwv += iv * g;
                                }
                            }
                            if let Some(dinp) = dinp.as_deref_mut() {
                                for i in 0..cin {
                                    let woff = ((ky * 3 + kx) * cin + i) * cout;
                                    let wrow = &w[woff..woff + cout];
                                    let mut s = 0.0f32;
                                    for (&wv, &g) in wrow.iter().zip(grow) {
                                        s += wv * g;
                                    }
                                    dinp[ioff + i] += s;
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    struct HeadCache {
        h: Vec<f32>,
        z: Vec<f32>,
    }

    fn head_forward(theta: &[f32], x: &[f32], b: usize) -> HeadCache {
        let fc1_b = sl(theta, 0, H_HID);
        let fc1_w = sl(theta, H_FC1_W, H_IN * H_HID);
        let fc2_b = sl(theta, H_FC1_W + H_IN * H_HID, H_CLS);
        let fc2_w = sl(theta, H_FC2_W, H_HID * H_CLS);
        let mut h = vec![0.0f32; b * H_HID];
        affine(x, fc1_w, fc1_b, b, H_IN, H_HID, &mut h);
        relu_inplace(&mut h);
        let mut z = vec![0.0f32; b * H_CLS];
        affine(&h, fc2_w, fc2_b, b, H_HID, H_CLS, &mut z);
        HeadCache { h, z }
    }

    fn head_backward(
        theta: &[f32],
        x: &[f32],
        cache: &HeadCache,
        dz: &[f32],
        b: usize,
        g: &mut [f32],
    ) {
        let fc2_w = sl(theta, H_FC2_W, H_HID * H_CLS);
        let (gfc1b, rest) = g.split_at_mut(H_HID);
        let (gfc1w, rest) = rest.split_at_mut(H_IN * H_HID);
        let (gfc2b, rest) = rest.split_at_mut(H_CLS);
        let (gfc2w, _pad) = rest.split_at_mut(H_HID * H_CLS);

        let mut dh = vec![0.0f32; b * H_HID];
        affine_backward(&cache.h, fc2_w, dz, b, H_HID, H_CLS, gfc2w, gfc2b, Some(&mut dh));
        relu_mask(&mut dh, &cache.h);
        affine_backward(x, &[], &dh, b, H_IN, H_HID, gfc1w, gfc1b, None);
    }

    struct CnnCache {
        a1: Vec<f32>,
        p1: Vec<f32>,
        arg1: Vec<u32>,
        a2: Vec<f32>,
        p2: Vec<f32>,
        arg2: Vec<u32>,
        h: Vec<f32>,
        z: Vec<f32>,
    }

    fn cnn_forward(theta: &[f32], x: &[f32], b: usize) -> CnnCache {
        let c1b = sl(theta, 0, C1);
        let c1w = sl(theta, C_C1W, 3 * 3 * C1);
        let c2b = sl(theta, C_C1W + 3 * 3 * C1, C2);
        let c2w = sl(theta, C_C2W, 3 * 3 * C1 * C2);
        let f1b = sl(theta, C_C2W + 3 * 3 * C1 * C2, FC_HID);
        let f1w = sl(theta, C_F1W, FC_IN * FC_HID);
        let f2b = sl(theta, C_F1W + FC_IN * FC_HID, C_CLS);
        let f2w = sl(theta, C_F2W, FC_HID * C_CLS);

        let mut a1 = vec![0.0f32; b * IMG * IMG * C1];
        conv3x3_same(x, b, IMG, 1, c1w, c1b, C1, &mut a1);
        relu_inplace(&mut a1);
        let mut p1 = vec![0.0f32; b * 8 * 8 * C1];
        let mut arg1 = vec![0u32; b * 8 * 8 * C1];
        maxpool2(&a1, b, IMG, C1, &mut p1, &mut arg1);

        let mut a2 = vec![0.0f32; b * 8 * 8 * C2];
        conv3x3_same(&p1, b, 8, C1, c2w, c2b, C2, &mut a2);
        relu_inplace(&mut a2);
        let mut p2 = vec![0.0f32; b * 4 * 4 * C2];
        let mut arg2 = vec![0u32; b * 4 * 4 * C2];
        maxpool2(&a2, b, 8, C2, &mut p2, &mut arg2);

        let mut h = vec![0.0f32; b * FC_HID];
        affine(&p2, f1w, f1b, b, FC_IN, FC_HID, &mut h);
        relu_inplace(&mut h);
        let mut z = vec![0.0f32; b * C_CLS];
        affine(&h, f2w, f2b, b, FC_HID, C_CLS, &mut z);
        CnnCache { a1, p1, arg1, a2, p2, arg2, h, z }
    }

    fn cnn_backward(
        theta: &[f32],
        x: &[f32],
        cache: &CnnCache,
        dz: &[f32],
        b: usize,
        g: &mut [f32],
    ) {
        let c2w = sl(theta, C_C2W, 3 * 3 * C1 * C2);
        let f1w = sl(theta, C_F1W, FC_IN * FC_HID);
        let f2w = sl(theta, C_F2W, FC_HID * C_CLS);
        let (gc1b, rest) = g.split_at_mut(C1);
        let (gc1w, rest) = rest.split_at_mut(3 * 3 * C1);
        let (gc2b, rest) = rest.split_at_mut(C2);
        let (gc2w, rest) = rest.split_at_mut(3 * 3 * C1 * C2);
        let (gf1b, rest) = rest.split_at_mut(FC_HID);
        let (gf1w, rest) = rest.split_at_mut(FC_IN * FC_HID);
        let (gf2b, rest) = rest.split_at_mut(C_CLS);
        let (gf2w, _pad) = rest.split_at_mut(FC_HID * C_CLS);

        let mut dh = vec![0.0f32; b * FC_HID];
        let mut dp2 = vec![0.0f32; b * FC_IN];
        let mut da2 = vec![0.0f32; b * 8 * 8 * C2];
        let mut dp1 = vec![0.0f32; b * 8 * 8 * C1];
        let mut da1 = vec![0.0f32; b * IMG * IMG * C1];

        affine_backward(&cache.h, f2w, dz, b, FC_HID, C_CLS, gf2w, gf2b, Some(&mut dh));
        relu_mask(&mut dh, &cache.h);
        affine_backward(&cache.p2, f1w, &dh, b, FC_IN, FC_HID, gf1w, gf1b, Some(&mut dp2));

        maxpool2_backward(&dp2, &cache.arg2, &mut da2);
        relu_mask(&mut da2, &cache.a2);
        conv3x3_same_backward(
            &cache.p1,
            b,
            8,
            C1,
            c2w,
            C2,
            &da2,
            gc2w,
            gc2b,
            Some(&mut dp1),
        );

        maxpool2_backward(&dp1, &cache.arg1, &mut da1);
        relu_mask(&mut da1, &cache.a1);
        conv3x3_same_backward(x, b, IMG, 1, &[], C1, &da1, gc1w, gc1b, None);
    }

    /// Mean softmax cross-entropy and its logit gradient (seed, fresh
    /// `dz` allocation per call).
    fn ce_loss_grad(z: &[f32], y: &[i32], rows: usize, classes: usize) -> (f32, Vec<f32>) {
        let mut dz = vec![0.0f32; rows * classes];
        let invb = 1.0 / rows as f32;
        let mut loss = 0.0f64;
        for r in 0..rows {
            let zr = &z[r * classes..(r + 1) * classes];
            let dr = &mut dz[r * classes..(r + 1) * classes];
            let max = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for (&zv, d) in zr.iter().zip(dr.iter_mut()) {
                let e = (zv - max).exp();
                *d = e;
                denom += e;
            }
            let yi = y[r] as usize;
            loss += (denom.ln() + max - zr[yi]) as f64;
            for d in dr.iter_mut() {
                *d = *d / denom * invb;
            }
            dr[yi] -= invb;
        }
        ((loss / rows as f64) as f32, dz)
    }

    /// Seed KD loss (fresh allocations per call).
    #[allow(clippy::too_many_arguments)]
    fn kd_loss_grad(
        z: &[f32],
        y: &[i32],
        zbar: &[f32],
        lam: f32,
        tau: f32,
        rows: usize,
        classes: usize,
    ) -> (f32, Vec<f32>) {
        let (ce, mut dz) = ce_loss_grad(z, y, rows, classes);
        for d in dz.iter_mut() {
            *d *= 1.0 - lam;
        }
        let mut ps = vec![0.0f32; classes];
        let mut pt = vec![0.0f32; classes];
        let mut kl_mean = 0.0f64;
        let scale = lam * tau / rows as f32;
        for r in 0..rows {
            let zr = &z[r * classes..(r + 1) * classes];
            let tr = &zbar[r * classes..(r + 1) * classes];
            softmax_tau(zr, tau, &mut ps);
            softmax_tau(tr, tau, &mut pt);
            let mut kl = 0.0f64;
            for c in 0..classes {
                if pt[c] > 0.0 {
                    kl += pt[c] as f64
                        * ((pt[c] as f64).ln() - (ps[c].max(1e-30) as f64).ln());
                }
            }
            kl_mean += kl;
            let dr = &mut dz[r * classes..(r + 1) * classes];
            for c in 0..classes {
                dr[c] += scale * (ps[c] - pt[c]);
            }
        }
        kl_mean /= rows as f64;
        let loss = (1.0 - lam) * ce + lam * tau * tau * (kl_mean as f32);
        (loss, dz)
    }

    /// Seed step driver: fresh forward cache, fresh gradient, fresh
    /// θ'/m' output vectors.
    #[allow(clippy::too_many_arguments)]
    fn step_with<F>(
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        b: usize,
        eta: f32,
        mu: f32,
        loss_grad: F,
    ) -> Result<StepOut>
    where
        F: FnOnce(&[f32]) -> (f32, Vec<f32>),
    {
        ensure!(theta.len() == m.padded_len, "theta length mismatch");
        ensure!(momentum.len() == m.padded_len, "momentum length mismatch");
        let mut g = vec![0.0f32; m.padded_len];
        let loss = match m.name.as_str() {
            "head" => {
                let cache = head_forward(theta, x, b);
                let (loss, dz) = loss_grad(&cache.z);
                head_backward(theta, x, &cache, &dz, b, &mut g);
                loss
            }
            "cnn" => {
                let cache = cnn_forward(theta, x, b);
                let (loss, dz) = loss_grad(&cache.z);
                cnn_backward(theta, x, &cache, &dz, b, &mut g);
                loss
            }
            other => bail!("native backend has no model {other:?}"),
        };
        let mut theta2 = Vec::with_capacity(theta.len());
        let mut mom2 = Vec::with_capacity(momentum.len());
        for ((&t, &mv), &gv) in theta.iter().zip(momentum).zip(&g) {
            let mn = mu * mv + (1.0 - mu) * gv;
            mom2.push(mn);
            theta2.push(t - eta * mn);
        }
        Ok(StepOut { theta: theta2, momentum: mom2, loss })
    }

    /// Seed train step (allocating, scalar kernels).
    pub fn train_step(
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        eta: f32,
        mu: f32,
    ) -> Result<StepOut> {
        check_meta(m)?;
        let b = batch_of(m, x, y)?;
        step_with(m, theta, momentum, x, b, eta, mu, |z| {
            ce_loss_grad(z, y, b, m.classes)
        })
    }

    /// Seed KD step (allocating, scalar kernels).
    #[allow(clippy::too_many_arguments)]
    pub fn kd_step(
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        zbar: &[f32],
        lambda: f32,
        tau: f32,
        eta: f32,
        mu: f32,
    ) -> Result<StepOut> {
        check_meta(m)?;
        let b = batch_of(m, x, y)?;
        ensure!(zbar.len() == b * m.classes, "zbar shape mismatch");
        ensure!(tau > 0.0, "KD temperature must be positive");
        step_with(m, theta, momentum, x, b, eta, mu, |z| {
            kd_loss_grad(z, y, zbar, lambda, tau, b, m.classes)
        })
    }

    /// Seed forward pass (fresh cache + logits allocation per call).
    pub fn logits(m: &ModelMeta, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        check_meta(m)?;
        let elems = m.input_elems();
        ensure!(!x.is_empty() && x.len() % elems == 0, "x shape mismatch");
        let b = x.len() / elems;
        ensure!(theta.len() == m.padded_len, "theta length mismatch");
        Ok(match m.name.as_str() {
            "head" => head_forward(theta, x, b).z,
            "cnn" => cnn_forward(theta, x, b).z,
            other => bail!("native backend has no model {other:?}"),
        })
    }

    /// Seed eval chunk: (summed NLL, correct count).
    pub fn eval_chunk(
        m: &ModelMeta,
        theta: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(f64, f64)> {
        check_meta(m)?;
        let rows = batch_of(m, x, y)?;
        let z = logits(m, theta, x)?;
        let c = m.classes;
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for r in 0..rows {
            let zr = &z[r * c..(r + 1) * c];
            let max = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let denom: f32 = zr.iter().map(|&v| (v - max).exp()).sum();
            loss_sum += (denom.ln() + max - zr[y[r] as usize]) as f64;
            let mut best = 0usize;
            for (j, &v) in zr.iter().enumerate() {
                if v > zr[best] {
                    best = j;
                }
            }
            if best == y[r] as usize {
                correct += 1.0;
            }
        }
        Ok((loss_sum, correct))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ArtifactMeta;

    fn meta() -> ArtifactMeta {
        ArtifactMeta::builtin(std::path::Path::new("/nonexistent"))
    }

    fn head_meta() -> ModelMeta {
        meta().model("head").unwrap().clone()
    }

    fn cnn_meta() -> ModelMeta {
        meta().model("cnn").unwrap().clone()
    }

    #[test]
    fn layout_counts_match_registry() {
        assert_eq!(HEAD_PARAMS, 10_900);
        assert_eq!(CNN_PARAMS, 18_346);
        assert_eq!(head_meta().param_count, HEAD_PARAMS);
        assert_eq!(cnn_meta().param_count, CNN_PARAMS);
    }

    #[test]
    fn init_is_deterministic_with_zero_bias_and_tail() {
        for m in [head_meta(), cnn_meta()] {
            let a = init_params(&m).unwrap();
            let b = init_params(&m).unwrap();
            assert_eq!(a, b);
            assert_eq!(a.len(), m.padded_len);
            assert!(a[m.param_count..].iter().all(|&v| v == 0.0));
            assert!(a.iter().any(|&v| v != 0.0));
        }
        // head biases (layout prefix) are zero
        let h = init_params(&head_meta()).unwrap();
        assert!(h[..H_HID].iter().all(|&v| v == 0.0));
    }

    /// Central finite differences against the analytic gradient — the
    /// correctness anchor for the whole backward implementation, run
    /// against the register-blocked kernels (the shim path).
    fn fd_check(m: &ModelMeta, probes: &[usize]) {
        let mut rng = Rng::new(0xFD);
        let theta = init_params(m).unwrap();
        let b = 4;
        let x: Vec<f32> =
            (0..b * m.input_elems()).map(|_| rng.normal() as f32 * 0.5).collect();
        let y: Vec<i32> = (0..b).map(|i| (i % m.classes) as i32).collect();

        // analytic gradient via a (η=1, μ=0) step: θ' = θ − g
        let mom = vec![0.0f32; theta.len()];
        let out = train_step(m, &theta, &mom, &x, &y, 1.0, 0.0).unwrap();
        let grad: Vec<f32> =
            theta.iter().zip(&out.theta).map(|(&t, &t2)| t - t2).collect();

        let loss_at = |th: &[f32]| -> f64 {
            let o = train_step(m, th, &mom, &x, &y, 0.0, 0.0).unwrap();
            o.loss as f64
        };
        let eps = 2e-2f64;
        for &j in probes {
            let mut tp = theta.clone();
            tp[j] += eps as f32;
            let lp = loss_at(&tp);
            tp[j] = theta[j] - eps as f32;
            let lm = loss_at(&tp);
            let fd = (lp - lm) / (2.0 * eps);
            let an = grad[j] as f64;
            assert!(
                (fd - an).abs() <= 2e-3 + 0.08 * an.abs().max(fd.abs()),
                "param {j}: fd {fd:.6} vs analytic {an:.6}"
            );
        }
    }

    #[test]
    fn head_gradients_match_finite_differences() {
        // probe biases and weights in both layers
        fd_check(
            &head_meta(),
            &[0, 5, H_FC1_W + 3, H_FC1_W + 1000, H_FC2_B + 2, H_FC2_W + 7, H_FC2_W + 999],
        );
    }

    #[test]
    fn cnn_gradients_match_finite_differences() {
        fd_check(
            &cnn_meta(),
            &[
                C_C1B + 1,
                C_C1W + 10,
                C_C2B + 3,
                C_C2W + 100,
                C_F1B + 5,
                C_F1W + 5000,
                C_F2B + 4,
                C_F2W + 123,
            ],
        );
    }

    #[test]
    fn kd_step_lambda_zero_equals_train_step() {
        let m = head_meta();
        let mut rng = Rng::new(3);
        let theta = init_params(&m).unwrap();
        let mom = vec![0.0f32; theta.len()];
        let b = m.batch;
        let x: Vec<f32> =
            (0..b * m.input_elems()).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..b).map(|i| (i % m.classes) as i32).collect();
        let zbar = vec![0.0f32; b * m.classes];
        let a = train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
        let k = kd_step(&m, &theta, &mom, &x, &y, &zbar, 0.0, 3.0, 0.1, 0.9).unwrap();
        assert_eq!(a.theta, k.theta, "λ=0 KD must equal plain CE training");
        assert!((a.loss - k.loss).abs() < 1e-7);
    }

    #[test]
    fn momentum_rule_matches_hand_computation() {
        // single logit parameter view: check m' = μm + (1−μ)g, θ' = θ−ηm'
        let m = head_meta();
        let theta = init_params(&m).unwrap();
        let mom = vec![0.25f32; theta.len()];
        let mut rng = Rng::new(4);
        let b = 2;
        let x: Vec<f32> =
            (0..b * m.input_elems()).map(|_| rng.normal() as f32).collect();
        let y = vec![0i32, 1];
        // g via η=1, μ=0 from zero momentum
        let zero = vec![0.0f32; theta.len()];
        let gstep = train_step(&m, &theta, &zero, &x, &y, 1.0, 0.0).unwrap();
        let g: Vec<f32> =
            theta.iter().zip(&gstep.theta).map(|(&t, &t2)| t - t2).collect();
        let out = train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
        for j in [0usize, H_FC1_W + 17, H_FC2_W + 40] {
            let want_m = 0.9 * mom[j] + 0.1 * g[j];
            assert!((out.momentum[j] - want_m).abs() < 1e-5);
            let want_t = theta[j] - 0.1 * out.momentum[j];
            assert!((out.theta[j] - want_t).abs() < 1e-6);
        }
    }

    #[test]
    fn training_reduces_loss_on_fixed_batch() {
        let m = head_meta();
        let mut rng = Rng::new(5);
        let data = crate::data::synth::newsgroups_like(m.batch, &mut rng);
        let idx: Vec<usize> = (0..m.batch).collect();
        let (x, y) = data.gather(&idx);
        let mut theta = init_params(&m).unwrap();
        let mut mom = vec![0.0f32; theta.len()];
        let mut first = 0.0f32;
        let mut last = 0.0f32;
        for s in 0..25 {
            let out = train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
            theta = out.theta;
            mom = out.momentum;
            if s == 0 {
                first = out.loss;
            }
            last = out.loss;
        }
        assert!(last < first * 0.6, "loss {first} -> {last}");
    }

    #[test]
    fn in_place_step_equals_shim_and_reference() {
        // one multi-step schedule, three paths: seed reference, compat
        // shim, and the in-place workspace path — bitwise identical
        // states, momentum and losses (the full suite lives in
        // tests/kernel_equivalence.rs; this is the unit-level smoke pin)
        for m in [head_meta(), cnn_meta()] {
            let mut rng = Rng::new(11);
            let b = 4usize;
            let x: Vec<f32> =
                (0..b * m.input_elems()).map(|_| rng.normal() as f32).collect();
            let y: Vec<i32> = (0..b).map(|i| (i % m.classes) as i32).collect();
            let mut t_ref = init_params(&m).unwrap();
            let mut m_ref = vec![0.0f32; t_ref.len()];
            let mut t_inp = t_ref.clone();
            let mut m_inp = m_ref.clone();
            for _ in 0..3 {
                let out =
                    reference::train_step(&m, &t_ref, &m_ref, &x, &y, 0.1, 0.9).unwrap();
                let loss =
                    train_step_into(&m, &mut t_inp, &mut m_inp, &x, &y, 0.1, 0.9)
                        .unwrap();
                t_ref = out.theta;
                m_ref = out.momentum;
                assert_eq!(out.loss.to_bits(), loss.to_bits(), "loss diverged");
                assert_eq!(t_ref, t_inp, "theta diverged ({})", m.name);
                assert_eq!(m_ref, m_inp, "momentum diverged ({})", m.name);
            }
        }
    }

    #[test]
    fn logits_and_eval_match_reference_bitwise() {
        for m in [head_meta(), cnn_meta()] {
            let mut rng = Rng::new(12);
            let rows = 8usize;
            let x: Vec<f32> =
                (0..rows * m.input_elems()).map(|_| rng.normal() as f32).collect();
            let y: Vec<i32> = (0..rows).map(|i| (i % m.classes) as i32).collect();
            let theta = init_params(&m).unwrap();
            let z_ref = reference::logits(&m, &theta, &x).unwrap();
            let z_ws = logits(&m, &theta, &x).unwrap();
            assert_eq!(z_ref, z_ws, "logits diverged ({})", m.name);
            let e_ref = reference::eval_chunk(&m, &theta, &x, &y).unwrap();
            let e_ws = eval_chunk(&m, &theta, &x, &y).unwrap();
            assert_eq!(e_ref.0.to_bits(), e_ws.0.to_bits());
            assert_eq!(e_ref.1.to_bits(), e_ws.1.to_bits());
        }
    }

    #[test]
    fn eval_chunk_counts_and_losses_are_sane() {
        let m = head_meta();
        let mut rng = Rng::new(6);
        let data = crate::data::synth::newsgroups_like(40, &mut rng);
        let theta = init_params(&m).unwrap();
        let (loss_sum, correct) =
            eval_chunk(&m, &theta, &data.x, &data.y).unwrap();
        assert!(loss_sum > 0.0 && loss_sum.is_finite());
        assert!((0.0..=40.0).contains(&correct));
    }

    #[test]
    fn group_mean_is_exact_mean() {
        let m = head_meta();
        let p = m.padded_len;
        let mut rng = Rng::new(7);
        let stack: Vec<f32> = (0..3 * p).map(|_| rng.normal() as f32).collect();
        let got = group_mean(&m, &stack, 3).unwrap();
        // same operation order as the strip kernel: f64 sum, then * (1/k)
        let inv = 1.0f64 / 3.0;
        for j in (0..p).step_by(997) {
            let want = ((stack[j] as f64 + stack[p + j] as f64 + stack[2 * p + j] as f64)
                * inv) as f32;
            assert_eq!(got[j], want);
        }
    }
}
