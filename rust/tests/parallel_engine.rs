//! Parallel round engine verification: the parallel execution paths must
//! be *bit-identical* to the serial reference — same states, same ledger
//! totals, same simulated clock — and the disjoint-partition utility must
//! reject unsound inputs.

use std::sync::Arc;

use marfl::aggregation::{AggCtx, Aggregate, PeerState};
use marfl::config::ExperimentConfig;
use marfl::coordinator::{AggOptions, MarAggregator};
use marfl::exec;
use marfl::fl::Trainer;
use marfl::metrics::{CommLedger, Plane};
use marfl::models::ModelMeta;
use marfl::net::Fabric;
use marfl::rng::Rng;
use marfl::runtime::Runtime;
use marfl::sim::SimClock;

fn toy_model(p: usize) -> ModelMeta {
    ModelMeta {
        name: "toy".into(),
        param_count: p,
        padded_len: p,
        input_shape: vec![4],
        classes: 3,
        batch: 8,
        eval_chunk: 8,
        init_file: String::new(),
        artifacts: Default::default(),
    }
}

fn random_states(n: usize, p: usize, seed: u64) -> Vec<PeerState> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| PeerState {
            theta: (0..p).map(|_| rng.normal() as f32).collect(),
            momentum: (0..p).map(|_| rng.normal() as f32 * 0.1).collect(),
        })
        .collect()
}

/// Run one MAR aggregate call and return (states, ledger snapshot, clock).
fn run_mar(
    n: usize,
    m: usize,
    g: usize,
    p: usize,
    parallel: bool,
) -> (Vec<PeerState>, marfl::metrics::CommSnapshot, f64) {
    let mut states = random_states(n, p, 0xBEEF ^ n as u64);
    let agg: Vec<usize> = (0..n).collect();
    let ledger = Arc::new(CommLedger::new());
    let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
    let mut clock = SimClock::new();
    let mut rng = Rng::new(77);
    let model = toy_model(p);
    let mut mar = MarAggregator::with_options(
        n,
        m,
        g,
        ledger.clone(),
        7,
        AggOptions { parallel, ..AggOptions::default() },
    );
    let mut ctx = AggCtx {
        fabric: &fabric,
        clock: &mut clock,
        rng: &mut rng,
        runtime: None,
        model: &model,
        faults: &marfl::net::FaultConfig::OFF,
        links: None,
    };
    mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
    (states, ledger.snapshot(), clock.now())
}

/// The headline determinism guarantee: group-parallel aggregation yields
/// the exact same peer states, byte/message counts and simulated time as
/// the serial reference — on perfect grids and in approximate mode.
#[test]
fn parallel_and_serial_mar_bit_identical() {
    for &(n, m, g) in &[(27usize, 3usize, 3usize), (125, 5, 3), (20, 3, 2)] {
        let (s_states, s_ledger, s_clock) = run_mar(n, m, g, 257, false);
        let (p_states, p_ledger, p_clock) = run_mar(n, m, g, 257, true);
        for (i, (a, b)) in s_states.iter().zip(&p_states).enumerate() {
            assert_eq!(a.theta, b.theta, "peer {i} theta diverged (n={n})");
            assert_eq!(a.momentum, b.momentum, "peer {i} momentum diverged");
        }
        assert_eq!(s_ledger, p_ledger, "ledger totals diverged (n={n})");
        assert_eq!(
            s_clock.to_bits(),
            p_clock.to_bits(),
            "simulated clock diverged (n={n})"
        );
    }
}

/// Same guarantee under the reduce-scatter wire protocol.
#[test]
fn parallel_reduce_scatter_matches_serial() {
    let build = |parallel: bool| {
        let n = 27;
        let mut states = random_states(n, 129, 4);
        let agg: Vec<usize> = (0..n).collect();
        let ledger = Arc::new(CommLedger::new());
        let fabric = Fabric::new(ledger.clone(), 1e6, 0.001);
        let mut clock = SimClock::new();
        let mut rng = Rng::new(5);
        let model = toy_model(129);
        let mut mar = MarAggregator::with_options(
            n,
            3,
            3,
            ledger.clone(),
            7,
            AggOptions {
                exchange: marfl::aggregation::GroupExchange::ReduceScatter,
                parallel,
                ..AggOptions::default()
            },
        );
        let mut ctx = AggCtx {
            fabric: &fabric,
            clock: &mut clock,
            rng: &mut rng,
            runtime: None,
            model: &model,
            faults: &marfl::net::FaultConfig::OFF,
            links: None,
        };
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        (states, ledger.snapshot())
    };
    let (s_states, s_ledger) = build(false);
    let (p_states, p_ledger) = build(true);
    assert_eq!(s_ledger, p_ledger);
    for (a, b) in s_states.iter().zip(&p_states) {
        assert_eq!(a.theta, b.theta);
    }
}

/// Ledger booking from many engine workers loses nothing: concurrent
/// sends sum to exactly the serial totals.
#[test]
fn concurrent_fabric_booking_is_exact() {
    let ledger = Arc::new(CommLedger::new());
    let fabric = Fabric::new(ledger.clone(), 1e6, 0.0);
    let mut lanes = vec![0u8; 64];
    let idx: Vec<usize> = (0..64).collect();
    exec::par_map_at(&mut lanes, &idx, |pos, _| {
        fabric.send(pos as u64 + 1, Plane::Data);
        fabric.sequential(3, 10, Plane::Control);
    })
    .unwrap();
    let snap = ledger.snapshot();
    assert_eq!(snap.data_msgs, 64);
    assert_eq!(snap.data_bytes, (1..=64).sum::<u64>());
    assert_eq!(snap.control_msgs, 64 * 3);
    assert_eq!(snap.control_bytes, 64 * 3 * 10);
}

/// The disjoint-partition utility is the engine's soundness gate: groups
/// that overlap (or escape the slice) must be rejected up front.
#[test]
fn disjoint_partition_rejects_bad_groups() {
    let mut states = random_states(6, 8, 9);
    let overlap = vec![vec![0, 1], vec![2, 1]];
    assert!(exec::par_disjoint_map(&mut states, &overlap, |_, _| ()).is_err());
    let oob = vec![vec![0], vec![6]];
    assert!(exec::par_disjoint_map(&mut states, &oob, |_, _| ()).is_err());
    assert!(exec::validate_disjoint(6, &overlap).is_err());
    assert!(exec::validate_disjoint(6, &[vec![0, 5], vec![3]]).is_ok());
}

/// Peer-parallel local training is reproducible end to end: two identical
/// trainer runs (thread scheduling varies) end in bit-identical states.
#[test]
fn peer_parallel_training_bit_reproducible() {
    let rt = Runtime::new(&marfl::models::default_artifact_dir()).unwrap();
    let run = || {
        let cfg = ExperimentConfig {
            model: "head".into(),
            peers: 9,
            group_size: 3,
            iterations: 3,
            samples_per_peer: 32,
            test_samples: 250,
            eval_every: 3,
            local_batches: 2,
            seed: 1234,
            ..Default::default()
        };
        let mut t = Trainer::new(cfg, &rt).unwrap();
        let summary = t.run().unwrap();
        let states: Vec<PeerState> = t.states().to_vec();
        (states, summary.comm, summary.sim_time_s)
    };
    let (a_states, a_comm, a_time) = run();
    let (b_states, b_comm, b_time) = run();
    assert_eq!(a_comm, b_comm);
    assert_eq!(a_time.to_bits(), b_time.to_bits());
    for (a, b) in a_states.iter().zip(&b_states) {
        assert_eq!(a.theta, b.theta);
        assert_eq!(a.momentum, b.momentum);
    }
}

/// The baselines that now fan out (SAPS pairs, gossip pulls) remain
/// deterministic for a fixed seed.
#[test]
fn parallel_baselines_reproducible() {
    use marfl::aggregation::{Gossip, Saps};
    fn mk_saps() -> Box<dyn Aggregate> {
        Box::new(Saps::default())
    }
    fn mk_gossip() -> Box<dyn Aggregate> {
        Box::new(Gossip::default())
    }
    let makers: [fn() -> Box<dyn Aggregate>; 2] = [mk_saps, mk_gossip];
    for mk in makers {
        let run = |mut agg_impl: Box<dyn Aggregate>| {
            let n = 24;
            let mut states = random_states(n, 65, 11);
            let agg: Vec<usize> = (0..n).collect();
            let ledger = Arc::new(CommLedger::new());
            let fabric = Fabric::new(ledger.clone(), 1e6, 0.001);
            let mut clock = SimClock::new();
            let mut rng = Rng::new(13);
            let model = toy_model(65);
            let mut ctx = AggCtx {
                fabric: &fabric,
                clock: &mut clock,
                rng: &mut rng,
                runtime: None,
                model: &model,
                faults: &marfl::net::FaultConfig::OFF,
                links: None,
            };
            agg_impl.aggregate(&mut states, &agg, &mut ctx).unwrap();
            (states, ledger.snapshot())
        };
        let (a_states, a_ledger) = run(mk());
        let (b_states, b_ledger) = run(mk());
        assert_eq!(a_ledger, b_ledger);
        for (a, b) in a_states.iter().zip(&b_states) {
            assert_eq!(a.theta, b.theta);
        }
    }
}
