//! Deterministic fault-injection fabric.
//!
//! Wireless-FL deployments lose messages, suffer bursty link
//! degradation, and straggle — failure modes the iteration-granular
//! churn models (`net::churn`, `net::trace`) cannot express. This module
//! provides a seeded fault model shared by every aggregation strategy:
//!
//! * **message loss** — each message is independently lost with
//!   probability `loss`; the sender times out and retries with bounded
//!   exponential backoff (retries are never free: every retransmission
//!   books payload bytes and a control-plane probe, and the timeout +
//!   backoff wall-time lands on the simulated clock);
//! * **link degradation** — a peer's links for one round run at a
//!   fraction of nominal bandwidth with a latency multiplier;
//! * **stragglers** — a peer's simulated compute lanes (local SGD,
//!   distillation) run `straggler_mult`× slower for one iteration;
//! * **crashes** — a peer dies mid-exchange; its group proceeds with a
//!   quorum of survivors and the peer rejoins stale.
//!
//! Determinism contract: every fault is drawn *serially* (in the same
//! schedule phase that draws `DropPlan`s today) before any parallel
//! fan-out, so serial and parallel engines stay bit-identical. With all
//! knobs at their defaults the model draws **zero** random numbers and
//! every code path is bit-identical to the fault-free build.

use crate::rng::Rng;

/// Control-plane bytes booked per timeout probe / retransmit request.
pub const RETRY_CTRL_BYTES: u64 = 64;

/// Fault-model knobs. All probabilities default to 0 — the model is
/// inert (and draw-free) unless explicitly enabled.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// per-message loss probability
    pub loss: f64,
    /// per-peer per-round probability of link degradation
    pub degrade_prob: f64,
    /// bandwidth multiplier while degraded (fraction of nominal)
    pub degrade_bw: f64,
    /// latency multiplier while degraded
    pub degrade_lat: f64,
    /// per-peer per-iteration straggler probability
    pub straggler_prob: f64,
    /// compute-time multiplier for straggling peers
    pub straggler_mult: f64,
    /// per-peer per-round mid-exchange crash probability
    pub crash_prob: f64,
    /// retransmissions attempted per message before giving up
    pub max_retries: u32,
    /// seconds before a lost message is declared timed out
    pub timeout_s: f64,
    /// base backoff delay; attempt `a` waits `backoff_s · 2^a`
    pub backoff_s: f64,
    /// minimum survivors for a group to proceed quorum-degraded
    pub quorum_min: usize,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            loss: 0.0,
            degrade_prob: 0.0,
            degrade_bw: 0.25,
            degrade_lat: 4.0,
            straggler_prob: 0.0,
            straggler_mult: 4.0,
            crash_prob: 0.0,
            max_retries: 3,
            timeout_s: 0.1,
            backoff_s: 0.05,
            quorum_min: 2,
        }
    }
}

impl FaultConfig {
    /// The inert plan — shared by every construction site that does not
    /// inject faults.
    pub const OFF: FaultConfig = FaultConfig {
        loss: 0.0,
        degrade_prob: 0.0,
        degrade_bw: 0.25,
        degrade_lat: 4.0,
        straggler_prob: 0.0,
        straggler_mult: 4.0,
        crash_prob: 0.0,
        max_retries: 3,
        timeout_s: 0.1,
        backoff_s: 0.05,
        quorum_min: 2,
    };

    /// Any fault axis active?
    pub fn enabled(&self) -> bool {
        self.loss > 0.0
            || self.degrade_prob > 0.0
            || self.straggler_prob > 0.0
            || self.crash_prob > 0.0
    }

    /// Any *link-level* axis active (loss or degradation)? Gates the
    /// per-peer link draws so a straggler-only plan stays draw-free on
    /// the exchange path.
    pub fn link_faults_enabled(&self) -> bool {
        self.loss > 0.0 || self.degrade_prob > 0.0
    }

    /// Draw one peer's link state for a round: a degradation draw, then
    /// per-message loss/retry draws for `msgs` planned messages. All
    /// randomness happens here (serial schedule phase) — applying the
    /// resulting [`LinkFault`] is draw-free.
    pub fn draw_link(&self, msgs: usize, rng: &mut Rng) -> LinkFault {
        let mut f = LinkFault::CLEAN;
        if self.degrade_prob > 0.0 && rng.chance(self.degrade_prob) {
            f.bw_mult = self.degrade_bw;
            f.lat_mult = self.degrade_lat;
        }
        if self.loss > 0.0 {
            for _ in 0..msgs {
                for attempt in 0..=self.max_retries {
                    if !rng.chance(self.loss) {
                        break;
                    }
                    if attempt < self.max_retries {
                        f.retries += 1;
                        f.penalty_s += self.timeout_s
                            + self.backoff_s * (1u64 << attempt.min(20)) as f64;
                    } else {
                        f.timeouts += 1;
                        f.penalty_s += self.timeout_s;
                    }
                }
            }
        }
        f
    }

    /// Like [`Self::draw_link`] but the sender never gives up — for
    /// protocols that cannot proceed without delivery (ring steps,
    /// butterfly segments). Only retries, never timeouts; the backoff
    /// exponent is capped at `max_retries`.
    pub fn draw_link_persistent(&self, msgs: usize, rng: &mut Rng) -> LinkFault {
        let mut f = LinkFault::CLEAN;
        if self.degrade_prob > 0.0 && rng.chance(self.degrade_prob) {
            f.bw_mult = self.degrade_bw;
            f.lat_mult = self.degrade_lat;
        }
        if self.loss > 0.0 {
            for _ in 0..msgs {
                let mut attempt = 0u32;
                while rng.chance(self.loss) {
                    f.retries += 1;
                    f.penalty_s += self.timeout_s
                        + self.backoff_s
                            * (1u64 << attempt.min(self.max_retries).min(20)) as f64;
                    attempt += 1;
                }
            }
        }
        f
    }
}

/// One peer's pre-drawn link state for one round: degradation
/// multipliers plus the total retry/timeout outcome of its planned
/// messages. Applying it (via `Fabric::send_faulty` /
/// `Fabric::sequential_faulty`) is deterministic and draw-free.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFault {
    /// bandwidth multiplier (1.0 = nominal)
    pub bw_mult: f64,
    /// latency multiplier (1.0 = nominal)
    pub lat_mult: f64,
    /// retransmissions that eventually succeeded
    pub retries: u64,
    /// messages abandoned after `max_retries` retransmissions
    pub timeouts: u64,
    /// timeout + backoff wall-time accumulated by the loss draws
    pub penalty_s: f64,
}

impl LinkFault {
    pub const CLEAN: LinkFault = LinkFault {
        bw_mult: 1.0,
        lat_mult: 1.0,
        retries: 0,
        timeouts: 0,
        penalty_s: 0.0,
    };

    /// No observable deviation from a fault-free link — the fabric
    /// delegates to its exact legacy cost path in this case.
    pub fn is_clean(&self) -> bool {
        self.retries == 0
            && self.timeouts == 0
            && self.bw_mult == 1.0
            && self.lat_mult == 1.0
    }

    /// Did any message on this link die for good?
    pub fn lost(&self) -> bool {
        self.timeouts > 0
    }

    /// The same link with loss outcomes stripped: degradation
    /// multipliers survive, retries/timeouts/penalty reset. Used when a
    /// recovery path re-plans traffic (quorum-degraded gather) — the
    /// link stays slow but we do not re-roll losses, which would cascade.
    pub fn degraded_only(&self) -> LinkFault {
        LinkFault {
            bw_mult: self.bw_mult,
            lat_mult: self.lat_mult,
            ..LinkFault::CLEAN
        }
    }
}

/// Aggregated fault outcomes for one run / one report. All-`u64` so the
/// containing `AggReport` keeps `Copy + Eq`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// messages that failed at least one transmission (retries + timeouts)
    pub msgs_lost: u64,
    /// retransmissions that eventually delivered
    pub retries: u64,
    /// messages abandoned after the retry budget
    pub timeouts: u64,
    /// groups that proceeded with a survivor quorum
    pub quorum_degraded_rounds: u64,
    /// peers crashed mid-exchange
    pub crashes: u64,
}

impl FaultCounters {
    /// Fold one drawn link into the totals.
    pub fn absorb(&mut self, f: &LinkFault) {
        self.msgs_lost += f.retries + f.timeouts;
        self.retries += f.retries;
        self.timeouts += f.timeouts;
    }

    /// Merge another counter set (e.g. per-round into per-run).
    pub fn add(&mut self, other: FaultCounters) {
        self.msgs_lost += other.msgs_lost;
        self.retries += other.retries;
        self.timeouts += other.timeouts;
        self.quorum_degraded_rounds += other.quorum_degraded_rounds;
        self.crashes += other.crashes;
    }

    pub fn any(&self) -> bool {
        *self != FaultCounters::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert_and_draw_free() {
        let cfg = FaultConfig::default();
        assert!(!cfg.enabled());
        assert!(!cfg.link_faults_enabled());
        let mut rng = Rng::new(1);
        let before = rng.next_u64();
        let mut rng = Rng::new(1);
        let f = cfg.draw_link(10, &mut rng);
        assert!(f.is_clean());
        // zero draws consumed: the next value matches a fresh stream
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn off_const_matches_default() {
        assert_eq!(FaultConfig::OFF, FaultConfig::default());
    }

    #[test]
    fn certain_loss_exhausts_retry_budget() {
        let cfg = FaultConfig { loss: 1.0, ..FaultConfig::default() };
        let mut rng = Rng::new(2);
        let f = cfg.draw_link(3, &mut rng);
        // every message burns max_retries retries then times out
        assert_eq!(f.retries, 3 * cfg.max_retries as u64);
        assert_eq!(f.timeouts, 3);
        assert!(f.lost());
        // penalty: per message, retries wait timeout+backoff·2^a, the
        // final timeout waits timeout only
        let mut expect = 0.0;
        for _ in 0..3 {
            for a in 0..cfg.max_retries {
                expect += cfg.timeout_s + cfg.backoff_s * (1u64 << a) as f64;
            }
            expect += cfg.timeout_s;
        }
        assert!((f.penalty_s - expect).abs() < 1e-12);
    }

    #[test]
    fn persistent_links_never_time_out() {
        let cfg = FaultConfig { loss: 0.6, ..FaultConfig::default() };
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let f = cfg.draw_link_persistent(4, &mut rng);
            assert_eq!(f.timeouts, 0);
            assert!(!f.lost());
        }
    }

    #[test]
    fn degraded_only_strips_loss_outcomes() {
        let cfg = FaultConfig {
            loss: 1.0,
            degrade_prob: 1.0,
            ..FaultConfig::default()
        };
        let mut rng = Rng::new(4);
        let f = cfg.draw_link(2, &mut rng);
        assert!(f.lost());
        let d = f.degraded_only();
        assert_eq!(d.retries, 0);
        assert_eq!(d.timeouts, 0);
        assert_eq!(d.penalty_s, 0.0);
        assert_eq!(d.bw_mult, cfg.degrade_bw);
        assert_eq!(d.lat_mult, cfg.degrade_lat);
        assert!(!d.is_clean());
    }

    #[test]
    fn counters_absorb_and_add() {
        let mut c = FaultCounters::default();
        let f = LinkFault { retries: 2, timeouts: 1, ..LinkFault::CLEAN };
        c.absorb(&f);
        assert_eq!(c.msgs_lost, 3);
        assert_eq!(c.retries, 2);
        assert_eq!(c.timeouts, 1);
        let mut total = FaultCounters::default();
        total.add(c);
        total.add(c);
        assert_eq!(total.msgs_lost, 6);
        assert!(total.any());
        assert!(!FaultCounters::default().any());
    }
}
