//! Telemetry subsystem contract, end to end:
//!
//! 1. the typed metric registry rejects re-registration and kind
//!    mismatches (handles are resolved exactly once);
//! 2. recording a round-event trace is observation only — a traced run
//!    is bit-identical to the same run untraced (states, ledger, curve,
//!    scorecards), so telemetry-off stays bit-identical to the seed;
//! 3. the trace itself is part of the determinism contract — serial and
//!    parallel engines produce byte-for-byte identical JSONL under a
//!    bursty Gilbert–Elliott fault plan with a Byzantine attack active;
//! 4. the JSONL wire format round-trips through a file and rejects a
//!    tampered schema header.

use marfl::attack::{AttackConfig, AttackMode, RobustEstimator};
use marfl::config::{ExperimentConfig, Strategy};
use marfl::fl::{RunSummary, Trainer};
use marfl::models::default_artifact_dir;
use marfl::net::FaultConfig;
use marfl::runtime::Runtime;
use marfl::telemetry::{EventKind, MetricRegistry, RoundTrace, TRACE_SCHEMA};

fn runtime() -> Runtime {
    Runtime::new(&default_artifact_dir()).expect("runtime")
}

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        strategy: Strategy::MarFl,
        model: "head".into(),
        peers: 16,
        group_size: 4,
        mar_rounds: 2, // 16 = 4²
        iterations: 4,
        samples_per_peer: 32,
        test_samples: 250,
        eval_every: 2,
        seed: 2026,
        ..Default::default()
    }
}

/// Lossy/straggler/crash plan used by the bit-identity run.
fn faulty() -> FaultConfig {
    FaultConfig {
        loss: 0.1,
        straggler_prob: 0.3,
        straggler_mult: 3.0,
        crash_prob: 0.05,
        ..FaultConfig::default()
    }
}

/// Bursty Gilbert–Elliott plan (π = p/(p+r) = 0.2) for the cross-engine
/// trace-equality run.
fn bursty() -> FaultConfig {
    FaultConfig {
        loss: 0.02,
        ge_p: 0.075,
        ge_r: 0.3,
        ge_loss: 0.5,
        ge_bw: 0.25,
        ge_lat: 4.0,
        ..FaultConfig::default()
    }
}

/// Bit-exact RunSummary comparison (f64s via `to_bits`, scorecards via
/// their derived equality).
fn assert_summaries_identical(a: &RunSummary, b: &RunSummary, tag: &str) {
    assert_eq!(a.iterations_run, b.iterations_run, "{tag}: iterations");
    assert_eq!(a.comm, b.comm, "{tag}: comm snapshot");
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits(), "{tag}: clock");
    assert_eq!(a.dht_hops, b.dht_hops, "{tag}: dht hops");
    assert_eq!(a.reliability, b.reliability, "{tag}: reliability scorecard");
    assert_eq!(a.faults, b.faults, "{tag}: fault scorecard");
    assert_eq!(a.byzantine, b.byzantine, "{tag}: byzantine scorecard");
    assert_eq!(
        a.dp.epsilon.map(f64::to_bits),
        b.dp.epsilon.map(f64::to_bits),
        "{tag}: dp scorecard"
    );
    assert_eq!(
        a.final_accuracy.to_bits(),
        b.final_accuracy.to_bits(),
        "{tag}: accuracy"
    );
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits(), "{tag}: loss");
    assert_eq!(a.curve.points.len(), b.curve.points.len(), "{tag}: curve len");
    for (i, (p, q)) in a.curve.points.iter().zip(&b.curve.points).enumerate() {
        assert_eq!(p.iteration, q.iteration, "{tag}: point {i} iteration");
        assert_eq!(p.data_bytes, q.data_bytes, "{tag}: point {i} data bytes");
        assert_eq!(
            p.control_bytes, q.control_bytes,
            "{tag}: point {i} control bytes"
        );
        assert_eq!(p.loss.to_bits(), q.loss.to_bits(), "{tag}: point {i} loss");
        assert_eq!(
            p.accuracy.to_bits(),
            q.accuracy.to_bits(),
            "{tag}: point {i} accuracy"
        );
        assert_eq!(
            p.sim_time_s.to_bits(),
            q.sim_time_s.to_bits(),
            "{tag}: point {i} sim time"
        );
    }
}

/// Handles are resolved once at registration: a second registration of
/// the same name fails regardless of kind, and the get-or-register
/// escape hatch refuses to hand a counter handle out for a gauge.
#[test]
fn registry_rejects_re_registration_and_kind_mismatch() {
    let reg = MetricRegistry::new();
    let c = reg.counter("fl.test.counter").unwrap();
    assert!(reg.counter("fl.test.counter").is_err(), "duplicate counter");
    assert!(reg.gauge("fl.test.counter").is_err(), "gauge over counter name");
    assert!(
        reg.histogram("fl.test.counter").is_err(),
        "histogram over counter name"
    );
    // get-or-register returns the SAME underlying cell…
    let c2 = reg.counter_or_existing("fl.test.counter").unwrap();
    c.add(3);
    c2.add(4);
    assert_eq!(reg.counter_value("fl.test.counter"), 7);
    // …and refuses a kind mismatch
    reg.gauge("fl.test.gauge").unwrap();
    assert!(reg.counter_or_existing("fl.test.gauge").is_err());
}

/// Tracing is observation only: the same config run with and without a
/// trace yields bit-identical models, ledger, curve, and scorecards.
/// This is the property that makes telemetry-off bit-identical to the
/// pre-telemetry seed — the registry never touches RNG, clock, or
/// ledger, and the trace is the only gated component.
#[test]
fn traced_run_is_bit_identical_to_untraced() {
    let rt = runtime();
    let cfg = ExperimentConfig { faults: faulty(), ..base_cfg() };

    let mut plain = Trainer::new(cfg.clone(), &rt).unwrap();
    let plain_sum = plain.run().unwrap();
    assert!(plain.trace().is_none(), "default build must not trace");

    let mut traced = Trainer::builder(cfg, &rt).trace(true).build().unwrap();
    let traced_sum = traced.run().unwrap();

    assert_summaries_identical(&plain_sum, &traced_sum, "traced-vs-plain");
    for (i, (a, b)) in plain.states().iter().zip(traced.states()).enumerate() {
        assert_eq!(a.theta, b.theta, "peer {i} theta diverged under tracing");
        assert_eq!(a.momentum, b.momentum, "peer {i} momentum diverged");
    }

    // the timeline itself is well-formed: one IterStart per iteration,
    // one Eval per curve point, events in nondecreasing simulated time
    let tr = traced.trace().unwrap().lock().unwrap().clone();
    assert!(!tr.is_empty());
    let starts = tr
        .events()
        .iter()
        .filter(|e| matches!(e.kind, EventKind::IterStart { .. }))
        .count();
    assert_eq!(starts, traced_sum.iterations_run, "IterStart per iteration");
    let evals = tr
        .events()
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Eval { .. }))
        .count();
    assert_eq!(evals, traced_sum.curve.points.len(), "Eval per curve point");
    for w in tr.events().windows(2) {
        assert!(w[0].iter <= w[1].iter, "iterations must be ordered");
    }
}

/// The trace is pinned across engines: serial and parallel runs under a
/// bursty GE fault plan with an active sign-flip attack (robust
/// aggregation + reputation bans) must serialize byte-for-byte
/// identically — the same guarantee CI checks under MARFL_THREADS=1 vs 4.
#[test]
fn trace_is_byte_identical_across_engines_under_faults_and_attack() {
    let rt = runtime();
    let cfg = ExperimentConfig {
        faults: bursty(),
        attack: AttackConfig {
            frac: 0.25,
            mode: AttackMode::SignFlip,
            scale: 1.0,
            robust: RobustEstimator::TrimmedMean,
            trim: 0.25,
            rep_threshold: 0.4,
            ..AttackConfig::default()
        },
        iterations: 6,
        ..base_cfg()
    };

    let mut serial =
        Trainer::builder(cfg.clone(), &rt).parallel(false).trace(true).build().unwrap();
    let s_sum = serial.run().unwrap();
    let mut par =
        Trainer::builder(cfg, &rt).parallel(true).trace(true).build().unwrap();
    let p_sum = par.run().unwrap();

    assert_summaries_identical(&s_sum, &p_sum, "serial-vs-parallel");
    // the scenario actually exercised both subsystems
    assert!(s_sum.faults.msgs_lost > 0, "bursty plan must lose messages");
    assert!(s_sum.byzantine.attackers_active > 0, "attackers must fire");

    let s_jsonl = serial.trace().unwrap().lock().unwrap().to_jsonl();
    let p_jsonl = par.trace().unwrap().lock().unwrap().to_jsonl();
    assert!(!s_jsonl.is_empty());
    assert_eq!(s_jsonl, p_jsonl, "trace JSONL diverged across engines");
}

/// File round-trip of a real trainer trace, plus schema tampering
/// rejection — what `marfl trace-check` enforces in CI.
#[test]
fn trace_round_trips_through_file_and_rejects_tampered_schema() {
    let rt = runtime();
    let cfg = ExperimentConfig { faults: faulty(), ..base_cfg() };
    let mut trainer = Trainer::builder(cfg, &rt).trace(true).build().unwrap();
    trainer.run().unwrap();

    let dir = std::env::temp_dir()
        .join(format!("marfl_telemetry_test_{}", std::process::id()));
    let path = dir.join("round_trace.jsonl");
    trainer.write_trace(&path).unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let back = RoundTrace::parse_jsonl(&text).unwrap();
    let live = trainer.trace().unwrap().lock().unwrap().clone();
    assert_eq!(back, live, "file round-trip must preserve every event");
    // re-serialization is byte-stable (deterministic writer)
    assert_eq!(back.to_jsonl(), text);

    let tampered = text.replacen(TRACE_SCHEMA, "marfl-trace/v999", 1);
    assert!(
        RoundTrace::parse_jsonl(&tampered).is_err(),
        "tampered schema header must be rejected"
    );

    // an untraced trainer refuses to write
    let rt2 = runtime();
    let plain = Trainer::new(base_cfg(), &rt2).unwrap();
    assert!(plain.write_trace(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
