"""AOT lowering: JAX/Pallas (L2+L1) -> HLO text artifacts for the Rust L3.

Runs ONCE at build time (`make artifacts`); Python never executes on the
training path. Interchange format is HLO *text*, NOT `.serialize()`:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Artifacts written, per model m in {cnn, head}:
  {m}_train_step.hlo.txt   (theta, mom, x, y, eta, mu) -> (theta', mom', loss)
  {m}_eval.hlo.txt         (theta, x, y) -> (loss_sum, correct)
  {m}_logits.hlo.txt       (theta, x) -> z
  {m}_kd_step.hlo.txt      (theta, mom, x, y, zbar, lam, eta, mu) -> (...)
  group_mean_{m}_{k}.hlo.txt  (stack[k, P_pad]) -> mean[P_pad], k in 2..8
  {m}_init.bin             initial flat params, f32 little-endian, P_pad
plus meta.json describing every shape Rust needs, and .stamp for make.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model as M
from compile.kernels.group_mean import group_mean
from compile.kernels.momentum import STRIP

GROUP_SIZES = list(range(2, 9))  # paper uses M in {3, 5}; we lower 2..8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_model(name: str, outdir: str) -> dict:
    spec = M.MODELS[name]
    p, p_pad, _ = M.flat_info(name)
    b = spec.batch
    e = spec.eval_chunk

    theta = _spec((p_pad,))
    mom = _spec((p_pad,))
    x_b = _spec(spec.batched(b))
    y_b = _spec((b,), jnp.int32)
    x_e = _spec(spec.batched(e))
    y_e = _spec((e,), jnp.int32)
    zbar = _spec((b, spec.classes))
    scalar = _spec((1,))

    entries = {
        f"{name}_train_step": (M.make_train_step(name),
                               (theta, mom, x_b, y_b, scalar, scalar)),
        f"{name}_eval": (M.make_eval_step(name), (theta, x_e, y_e)),
        f"{name}_logits": (M.make_logits(name), (theta, x_b)),
        f"{name}_kd_step": (M.make_kd_step(name),
                            (theta, mom, x_b, y_b, zbar, scalar, scalar, scalar)),
    }
    for k in GROUP_SIZES:
        entries[f"group_mean_{name}_{k}"] = (group_mean, (_spec((k, p_pad)),))

    files = {}
    for fname, (fn, args) in entries.items():
        # Wrap so every entry point returns a flat tuple (return_tuple=True
        # then makes the root a single tuple the Rust side unpacks).
        def wrapped(*a, _fn=fn):
            out = _fn(*a)
            return out if isinstance(out, tuple) else (out,)

        text = to_hlo_text(jax.jit(wrapped).lower(*args))
        path = os.path.join(outdir, f"{fname}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        files[fname] = f"{fname}.hlo.txt"
        print(f"  lowered {fname}: {len(text)} chars")

    init = M.init_flat(name)
    import numpy as np
    init_path = os.path.join(outdir, f"{name}_init.bin")
    np.asarray(init, dtype="<f4").tofile(init_path)
    print(f"  wrote {init_path} ({p_pad} f32)")

    return {
        "param_count": int(p),
        "padded_len": int(p_pad),
        "input_shape": list(spec.input_shape),
        "classes": int(spec.classes),
        "batch": int(b),
        "eval_chunk": int(e),
        "init": f"{name}_init.bin",
        "artifacts": files,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifact directory (default: ../artifacts)")
    ap.add_argument("--models", nargs="*", default=list(M.MODELS))
    args = ap.parse_args()
    outdir = os.path.abspath(args.out)
    os.makedirs(outdir, exist_ok=True)

    meta = {
        "strip": STRIP,
        "kd_tau": M.KD_TAU,
        "group_sizes": GROUP_SIZES,
        "models": {},
    }
    for name in args.models:
        print(f"lowering model {name!r} ...")
        meta["models"][name] = lower_model(name, outdir)

    with open(os.path.join(outdir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    with open(os.path.join(outdir, ".stamp"), "w") as f:
        f.write("ok\n")
    print(f"artifacts complete in {outdir}")


if __name__ == "__main__":
    main()
