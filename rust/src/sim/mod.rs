//! Simulated wall clock.
//!
//! The simulation executes serially on one core, but the system it models
//! is parallel: within one round every peer (or group) communicates
//! concurrently. The clock therefore advances by the *maximum* over
//! parallel lanes, and by the sum across sequential phases — giving the
//! simulated round/iteration times reported in EXPERIMENTS.md.

/// Accumulating simulated clock.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    time_s: f64,
    /// cumulative time attributed to reduce-scatter phases
    rs_time_s: f64,
    /// cumulative time attributed to all-gather phases
    ag_time_s: f64,
    /// control-plane (matchmaking) time hidden under a concurrent
    /// data-plane exchange by [`Self::pipelined_two_phase`]
    mm_hidden_s: f64,
    /// control-plane time that extended the exchange (the matchmaking
    /// lane outlasted every data lane)
    mm_exposed_s: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock::default()
    }

    pub fn now(&self) -> f64 {
        self.time_s
    }

    /// A sequential phase of duration `dt`.
    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0, "negative phase duration {dt}");
        self.time_s += dt;
    }

    /// A parallel phase: lanes run concurrently, the phase lasts as long
    /// as the slowest lane.
    pub fn parallel(&mut self, lane_times: impl IntoIterator<Item = f64>) {
        let max = lane_times.into_iter().fold(0.0f64, f64::max);
        self.time_s += max;
    }

    /// A two-phase parallel exchange — each lane is a `(first, second)`
    /// pair (reduce-scatter, then all-gather): within a lane the second
    /// phase starts only after the first completes; lanes are concurrent
    /// with no cross-lane barrier, so the exchange lasts as long as the
    /// slowest lane's phase *sum*. The advance is attributed to the
    /// per-phase accumulators ([`Self::phase_times`]) with the slowest
    /// single first phase as the reduce-scatter share — the breakdown the
    /// reduce-scatter ablation reports. When either phase is all-zero the
    /// advance degenerates to [`Self::parallel`] over the other phase,
    /// bit-exactly (full-gather books its whole duration as the gather
    /// phase this way).
    pub fn parallel_two_phase(
        &mut self,
        lanes: impl IntoIterator<Item = (f64, f64)>,
    ) {
        // the zero-control special case of the pipelined boundary (one
        // body; the bitwise equivalence is pinned by a test below)
        self.pipelined_two_phase(0.0, lanes);
    }

    /// Cumulative `(reduce_scatter_s, all_gather_s)` attribution from
    /// [`Self::parallel_two_phase`] exchanges.
    pub fn phase_times(&self) -> (f64, f64) {
        (self.rs_time_s, self.ag_time_s)
    }

    /// A pipelined round boundary: the *next* round's control-plane
    /// matchmaking (`control_s`, one lane) runs concurrently with the
    /// *current* round's two-phase data exchanges (`lanes`, as in
    /// [`Self::parallel_two_phase`]). The boundary lasts as long as the
    /// slowest of the two planes: matchmaking needs only the key
    /// schedule — known before the exchange starts — so it costs extra
    /// wall-clock only when it outlasts every data lane. Attribution: the
    /// data advance splits into the rs/ag accumulators exactly as in
    /// `parallel_two_phase`; the control lane splits into hidden
    /// (overlapped) and exposed (exchange-extending) shares
    /// ([`Self::matchmaking_times`]). With `control_s == 0` this is
    /// bit-identical to `parallel_two_phase`.
    pub fn pipelined_two_phase(
        &mut self,
        control_s: f64,
        lanes: impl IntoIterator<Item = (f64, f64)>,
    ) {
        assert!(control_s >= 0.0, "negative control lane {control_s}");
        let mut max_total = 0.0f64;
        let mut max_first = 0.0f64;
        for (first, second) in lanes {
            max_total = max_total.max(first + second);
            max_first = max_first.max(first);
        }
        let first_share = max_first.min(max_total);
        self.rs_time_s += first_share;
        self.ag_time_s += max_total - first_share;
        let exposed = (control_s - max_total).max(0.0);
        self.mm_hidden_s += control_s - exposed;
        self.mm_exposed_s += exposed;
        self.time_s += max_total + exposed;
    }

    /// Cumulative `(hidden_s, exposed_s)` control-plane attribution from
    /// [`Self::pipelined_two_phase`] boundaries: how much matchmaking
    /// time the pipeline absorbed under data exchanges vs how much still
    /// extended the critical path.
    pub fn matchmaking_times(&self) -> (f64, f64) {
        (self.mm_hidden_s, self.mm_exposed_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_sum_sequentially() {
        let mut c = SimClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_takes_max() {
        let mut c = SimClock::new();
        c.parallel([0.2, 0.9, 0.4]);
        assert!((c.now() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn empty_parallel_is_free() {
        let mut c = SimClock::new();
        c.parallel([]);
        assert_eq!(c.now(), 0.0);
    }

    #[test]
    fn two_phase_advances_by_slowest_lane_sum() {
        let mut c = SimClock::new();
        // lane 1 has the slowest RS, lane 2 the slowest sum
        c.parallel_two_phase([(0.5, 0.1), (0.2, 0.7)]);
        assert!((c.now() - 0.9).abs() < 1e-12);
        let (rs, ag) = c.phase_times();
        assert!((rs - 0.5).abs() < 1e-12);
        assert!((ag - 0.4).abs() < 1e-12);
    }

    #[test]
    fn two_phase_with_zero_first_matches_parallel_bitwise() {
        let times = [0.25f64, 0.75, 0.5];
        let mut a = SimClock::new();
        a.parallel(times);
        let mut b = SimClock::new();
        b.parallel_two_phase(times.iter().map(|&t| (0.0, t)));
        assert_eq!(a.now().to_bits(), b.now().to_bits());
        assert_eq!(b.phase_times().0, 0.0);
    }

    #[test]
    fn empty_two_phase_is_free() {
        let mut c = SimClock::new();
        c.parallel_two_phase([]);
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.phase_times(), (0.0, 0.0));
    }

    #[test]
    fn pipelined_control_hides_under_the_exchange() {
        let mut c = SimClock::new();
        // exchange lasts 0.9 (slowest lane sum); matchmaking 0.3 hides
        c.pipelined_two_phase(0.3, [(0.5, 0.1), (0.2, 0.7)]);
        assert!((c.now() - 0.9).abs() < 1e-12);
        let (hidden, exposed) = c.matchmaking_times();
        assert!((hidden - 0.3).abs() < 1e-12);
        assert_eq!(exposed, 0.0);
        // phase attribution unchanged by the hidden control lane
        let (rs, ag) = c.phase_times();
        assert!((rs - 0.5).abs() < 1e-12);
        assert!((ag - 0.4).abs() < 1e-12);
    }

    #[test]
    fn pipelined_control_exposes_only_its_overhang() {
        let mut c = SimClock::new();
        // matchmaking 1.0 vs exchange 0.4: 0.4 hides, 0.6 extends
        c.pipelined_two_phase(1.0, [(0.1, 0.3)]);
        assert!((c.now() - 1.0).abs() < 1e-12);
        let (hidden, exposed) = c.matchmaking_times();
        assert!((hidden - 0.4).abs() < 1e-12);
        assert!((exposed - 0.6).abs() < 1e-12);
    }

    #[test]
    fn pipelined_with_zero_control_matches_two_phase_bitwise() {
        let lanes = [(0.25f64, 0.1f64), (0.0, 0.75), (0.5, 0.0)];
        let mut a = SimClock::new();
        a.parallel_two_phase(lanes);
        let mut b = SimClock::new();
        b.pipelined_two_phase(0.0, lanes);
        assert_eq!(a.now().to_bits(), b.now().to_bits());
        assert_eq!(a.phase_times(), b.phase_times());
        assert_eq!(b.matchmaking_times(), (0.0, 0.0));
    }
}
