//! Micro benchmarks of the hot paths (perf instrument for EXPERIMENTS.md
//! §Perf):
//!
//! * runtime step latencies (train / logits / kd / eval) — the compute
//!   floor of whichever backend the build selects (native or PJRT).
//! * Within-group averaging: group-mean kernel vs the strip-mined native
//!   f64 path (ablation: which should `average_group` prefer?).
//! * Full 125-peer MAR aggregation — the coordinator's own cost.
//! * Serial vs parallel round engine at N = 125 / 343 / 1000 — the
//!   scaling sweep behind the parallel-engine acceptance numbers.
//! * Moshpit-KD serial vs student-parallel lanes — the MKD ablation
//!   behind the zero-copy + parallel-MKD acceptance numbers
//!   (`results/BENCH_mkd.json`).
//!
//! Emits `results/BENCH_micro.json` (machine-readable, one row per bench)
//! so the perf trajectory is tracked across PRs.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::{bench_ns, emit_csv, runtime, SynthBundle};
use marfl::aggregation::{
    average_group, average_group_native, owner_stripe_mean, AggCtx, Aggregate,
    GroupExchange, PeerState,
};
use marfl::config::KdConfig;
use marfl::coordinator::{AggOptions, MarAggregator};
use marfl::data::{build as build_data, synth};
use marfl::exec;
use marfl::kd::KdEngine;
use marfl::metrics::CommLedger;
use marfl::net::Fabric;
use marfl::rng::Rng;
use marfl::sim::SimClock;
use marfl::telemetry::{BenchReport, MetricRegistry};
use marfl::util::json::{arr, num, obj, s, Json};

/// Collected (name, µs/op) rows for BENCH_micro.json.
struct Rows(Vec<(String, f64)>);

impl Rows {
    fn bench(&mut self, label: &str, warmup: usize, reps: usize, f: impl FnMut()) {
        let ns = bench_ns(label, warmup, reps, f);
        self.0.push((label.to_string(), ns / 1e3));
    }
}

fn main() {
    let rt = runtime();
    let mut rows = Rows(Vec::new());
    println!(
        "micro_hotpath — backend: {}, MARFL_THREADS={}\n",
        rt.backend_name(),
        exec::threads()
    );
    let m = rt.meta.model("cnn").unwrap().clone();
    let h = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(42);
    let theta = rt.init_params("cnn").unwrap();
    let mom = vec![0.0f32; theta.len()];
    let data = synth::mnist_like(m.batch, &mut rng);
    let idx: Vec<usize> = (0..m.batch).collect();
    let (x, y) = data.gather(&idx);

    let theta_h = rt.init_params("head").unwrap();
    let mom_h = vec![0.0f32; theta_h.len()];
    let data_h = synth::newsgroups_like(h.batch.max(h.eval_chunk), &mut rng);
    let idx_h: Vec<usize> = (0..h.batch).collect();
    let (xh, yh) = data_h.gather(&idx_h);
    let idx_e: Vec<usize> = (0..h.eval_chunk).collect();
    let (xe, ye) = data_h.gather(&idx_e);
    let zbar = vec![0.0f32; h.batch * h.classes];

    rows.bench("cnn train_step (B=64)", 3, 20, || {
        rt.train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
    });
    rows.bench("head train_step (B=16)", 3, 30, || {
        rt.train_step(&h, &theta_h, &mom_h, &xh, &yh, 0.1, 0.9).unwrap();
    });
    rows.bench("head logits (KD teacher fwd)", 3, 30, || {
        rt.logits(&h, &theta_h, &xh).unwrap();
    });
    rows.bench("head kd_step", 3, 30, || {
        rt.kd_step(&h, &theta_h, &mom_h, &xh, &yh, &zbar, 0.5, 0.1, 0.9)
            .unwrap();
    });
    rows.bench("head eval chunk (E=250)", 3, 20, || {
        rt.evaluate(&h, &theta_h, &xe, &ye).unwrap();
    });

    println!("\nallocation-free step kernels: seed (allocating, scalar) vs workspace/in-place\n");
    // Single-thread train-step throughput, native path on both sides:
    // the seed reference allocates every forward cache / gradient /
    // state vector per step and runs the scalar kernels; the in-place
    // path reuses the per-worker workspace and the register-blocked
    // kernels. tests/kernel_equivalence.rs proves the two are
    // bit-identical, so this gap is pure overhead removed.
    let mut kernel_rows: Vec<Json> = Vec::new();
    let mut cnn_kernel_speedup = 0.0f64;
    for (label, meta_m, theta0, xb, yb, reps) in [
        ("cnn", &m, &theta, &x, &y, 20usize),
        ("head", &h, &theta_h, &xh, &yh, 30),
    ] {
        let mom0 = vec![0.0f32; theta0.len()];
        let seed_ns =
            bench_ns(&format!("{label} train_step seed path"), 3, reps, || {
                marfl::runtime::native::reference::train_step(
                    meta_m, theta0, &mom0, xb, yb, 0.1, 0.9,
                )
                .unwrap();
            });
        let mut th = theta0.clone();
        let mut mo = mom0.clone();
        let inplace_ns =
            bench_ns(&format!("{label} train_step in-place"), 3, reps, || {
                marfl::runtime::native::train_step_into(
                    meta_m, &mut th, &mut mo, xb, yb, 0.1, 0.9,
                )
                .unwrap();
            });
        let speedup = seed_ns / inplace_ns;
        println!("  {label}: workspace/in-place step {speedup:.2}x the seed path");
        if label == "cnn" {
            cnn_kernel_speedup = speedup;
        }
        rows.0.push((format!("{label} train_step seed path"), seed_ns / 1e3));
        rows.0.push((format!("{label} train_step in-place"), inplace_ns / 1e3));
        kernel_rows.push(obj(vec![
            ("model", s(label)),
            ("seed_us", num(seed_ns / 1e3)),
            ("inplace_us", num(inplace_ns / 1e3)),
            ("speedup", num(speedup)),
        ]));
    }
    // machine-readable kernel ablation (BENCH_kernels.json, uploaded by
    // CI alongside the other bench artifacts)
    let kernels_path = BenchReport::new("kernels")
        .field("kind", s("kernel_ablation"))
        .field("backend", s("native"))
        .field("threads", num(1.0)) // a step is single-threaded by design
        .field("results", arr(kernel_rows))
        .write(&common::results_dir())
        .expect("write BENCH_kernels.json");
    println!("  -> {}", kernels_path.display());
    // acceptance gate: >=1.5x single-thread cnn step throughput for the
    // workspace/in-place path; MARFL_BENCH_NO_ASSERT=1 downgrades to
    // report-only on hosts too noisy to trust wall-clock ratios
    assert!(
        cnn_kernel_speedup >= 1.5
            || std::env::var_os("MARFL_BENCH_NO_ASSERT").is_some(),
        "workspace/in-place cnn train_step must be >=1.5x the seed path \
         (got {cnn_kernel_speedup:.2}x; set MARFL_BENCH_NO_ASSERT=1 to \
         report without gating)"
    );

    println!("\ngroup averaging ablation (k=5, cnn-size vectors)\n");
    let k = 5usize;
    let stack: Vec<f32> =
        (0..k * m.padded_len).map(|_| rng.normal() as f32).collect();
    rows.bench("group_mean via runtime kernel", 3, 30, || {
        rt.group_mean(&m, &stack, k).unwrap();
    });
    {
        let mut b = SynthBundle::new(m.padded_len);
        let mut states = b.states(k);
        let members: Vec<usize> = (0..k).collect();
        rows.bench("group average native (f64 accumulate)", 3, 30, || {
            let mut ctx = b.ctx();
            average_group(&mut states, &members, &mut ctx).unwrap();
        });
    }

    println!("\nchunk-owned reduce-scatter kernel (M=5, cnn-size vectors)\n");
    {
        // full-vector averaging = what every member computes under
        // full-gather; the owner stripe = what one member computes under
        // chunk ownership (1/M of the elements). The ~M× gap is the
        // per-peer compute saving the reduce-scatter mode models.
        let mut b = SynthBundle::new(m.padded_len);
        let states = b.states(k);
        let members: Vec<usize> = (0..k).collect();
        let mut full_states = states.clone();
        rows.bench("group average full vector (M=5)", 3, 30, || {
            average_group_native(&mut full_states, &members);
        });
        rows.bench("group average chunk-owned stripe (M=5)", 3, 30, || {
            std::hint::black_box(owner_stripe_mean(&states, &members, 2));
        });
        let n_rows = rows.0.len();
        let speedup = rows.0[n_rows - 2].1 / rows.0[n_rows - 1].1;
        println!(
            "  chunk ownership cuts per-member averaging {speedup:.1}x \
             (M=5; acceptance bar: >=2x at M>=4)"
        );
        // acceptance gate; the expected gap is ~M× so the margin is wide,
        // but MARFL_BENCH_NO_ASSERT=1 downgrades it to report-only for
        // hosts too noisy to trust wall-clock ratios
        assert!(
            speedup >= 2.0
                || std::env::var_os("MARFL_BENCH_NO_ASSERT").is_some(),
            "chunk-owned stripe must be >=2x faster than full-vector \
             averaging at M=5 (got {speedup:.2}x; set MARFL_BENCH_NO_ASSERT=1 \
             to report without gating)"
        );
    }

    println!("\ncoordinator-scale operations\n");
    {
        let mut b = SynthBundle::new(m.padded_len);
        let mut states = b.states(125);
        let agg: Vec<usize> = (0..125).collect();
        let mut mar = MarAggregator::new(125, 5, 3, b.ledger.clone(), 5);
        rows.bench("MAR aggregate 125 peers (native, M=5 G=3)", 1, 5, || {
            let mut ctx = b.ctx();
            mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        });
    }
    {
        let mut b = SynthBundle::new(m.padded_len);
        let mut states = b.states(125);
        let agg: Vec<usize> = (0..125).collect();
        let mut mar = MarAggregator::with_options(
            125,
            5,
            3,
            b.ledger.clone(),
            5,
            AggOptions {
                exchange: GroupExchange::ReduceScatter,
                ..AggOptions::default()
            },
        );
        rows.bench("MAR aggregate 125 peers (reduce-scatter, M=5 G=3)", 1, 5, || {
            let mut ctx = b.ctx();
            mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        });
    }
    {
        let mut b = SynthBundle::new(64);
        let mut states = b.states(125);
        let agg: Vec<usize> = (0..125).collect();
        let mut mar = MarAggregator::new(125, 5, 3, b.ledger.clone(), 6);
        rows.bench("MAR matchmaking+avg 125 peers (tiny vectors)", 1, 5, || {
            let mut ctx = b.ctx();
            mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        });
    }

    println!("\nserial vs parallel round engine (perfect grids, G=3)\n");
    let mut scaling_csv = vec![vec![
        "peers".into(),
        "padded_len".into(),
        "serial_us".into(),
        "parallel_us".into(),
        "speedup".into(),
    ]];
    // (N, M, padded_len): 125 = 5³ at full cnn size; the larger sweeps use
    // a reduced vector so the bench stays RAM-friendly at N=1000
    for &(n, m_sz, p) in &[(125usize, 5usize, 18432usize), (343, 7, 4096), (1000, 10, 4096)]
    {
        let reps = if n >= 1000 { 3 } else { 5 };
        let serial_us = {
            let mut b = SynthBundle::new(p);
            let mut states = b.states(n);
            let agg: Vec<usize> = (0..n).collect();
            let mut mar = MarAggregator::with_options(
                n,
                m_sz,
                3,
                b.ledger.clone(),
                5,
                AggOptions { parallel: false, ..AggOptions::default() },
            );
            let ns = bench_ns(
                &format!("MAR aggregate N={n} P={p} serial"),
                1,
                reps,
                || {
                    let mut ctx = b.ctx();
                    mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
                },
            );
            ns / 1e3
        };
        let parallel_us = {
            let mut b = SynthBundle::new(p);
            let mut states = b.states(n);
            let agg: Vec<usize> = (0..n).collect();
            let mut mar = MarAggregator::new(n, m_sz, 3, b.ledger.clone(), 5);
            let ns = bench_ns(
                &format!("MAR aggregate N={n} P={p} parallel"),
                1,
                reps,
                || {
                    let mut ctx = b.ctx();
                    mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
                },
            );
            ns / 1e3
        };
        let speedup = serial_us / parallel_us;
        println!("  N={n:<5} speedup {speedup:.2}x");
        rows.0.push((format!("MAR aggregate N={n} P={p} serial"), serial_us));
        rows.0
            .push((format!("MAR aggregate N={n} P={p} parallel"), parallel_us));
        scaling_csv.push(vec![
            n.to_string(),
            p.to_string(),
            format!("{serial_us:.1}"),
            format!("{parallel_us:.1}"),
            format!("{speedup:.2}"),
        ]);
    }
    emit_csv("micro_scaling.csv", &scaling_csv);

    println!("\nMoshpit-KD: serial vs student-parallel lanes (head task)\n");
    // N=20 students, M=4 candidate-teacher groups, G=2 MKD rounds, E=2
    // distillation epochs: per round every student rates up to 3 teachers
    // (forward passes) and distills — the compute the student lanes fan
    // out. Zero per-group θ clones: snapshots are shared Theta handles.
    let mkd_us = |parallel: bool, label: &str| -> f64 {
        let n_kd = 20usize;
        let model_h = rt.meta.model("head").unwrap().clone();
        let mut rng = Rng::new(0x3D17);
        let mut fl =
            build_data("head", n_kd, 64, 250, true, 1.0, &mut rng.fork(1));
        let theta0 = rt.init_params("head").unwrap();
        let mut states = vec![PeerState::new(theta0); n_kd];
        let agg: Vec<usize> = (0..n_kd).collect();
        let ledger = Arc::new(CommLedger::new());
        let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
        let mut mar = MarAggregator::new(n_kd, 4, 2, ledger.clone(), 5);
        let kd = KdEngine::new(
            KdConfig { enabled: true, k_iterations: 8, rho_ell: 0.4, epochs: 2 },
            rt.meta.kd_tau,
            0.1,
            0.9,
        )
        .with_parallel(parallel);
        let mut clock = SimClock::new();
        let mut kd_rng = rng.fork(2);
        let mut t = 0usize;
        let ns = bench_ns(label, 2, 12, || {
            t += 1;
            let mut ctx = AggCtx {
                fabric: &fabric,
                clock: &mut clock,
                rng: &mut kd_rng,
                runtime: Some(&rt),
                model: &model_h,
                faults: &marfl::net::FaultConfig::OFF,
                links: None,
            };
            kd.run_mkd(
                t,
                &rt,
                &model_h,
                &fl.train,
                &mut fl.shards,
                &mut states,
                &agg,
                &mut mar,
                &mut ctx,
            )
            .unwrap();
        });
        ns / 1e3
    };
    let mkd_serial_us = mkd_us(false, "MKD pass serial (N=20 M=4 G=2 E=2)");
    let mkd_parallel_us =
        mkd_us(true, "MKD pass parallel (N=20 M=4 G=2 E=2)");
    let mkd_speedup = mkd_serial_us / mkd_parallel_us;
    println!(
        "  student-parallel MKD speedup {mkd_speedup:.2}x at \
         {} engine threads (acceptance bar: >=2x at >=4 threads)",
        exec::threads()
    );
    rows.0.push(("MKD pass serial (N=20 M=4 G=2 E=2)".into(), mkd_serial_us));
    rows.0
        .push(("MKD pass parallel (N=20 M=4 G=2 E=2)".into(), mkd_parallel_us));
    // machine-readable MKD ablation (BENCH_mkd.json, uploaded by CI)
    let mkd_path = BenchReport::new("mkd")
        .field("kind", s("mkd_ablation"))
        .field("backend", s(rt.backend_name()))
        .field("threads", num(exec::threads() as f64))
        .field("serial_us", num(mkd_serial_us))
        .field("parallel_us", num(mkd_parallel_us))
        .field("speedup", num(mkd_speedup))
        .write(&common::results_dir())
        .expect("write BENCH_mkd.json");
    println!("  -> {}", mkd_path.display());
    // acceptance gate — only with enough configured workers AND enough
    // real host cores to back them (an oversubscribed pool on a 2-core
    // host is not a code defect); MARFL_BENCH_NO_ASSERT=1 downgrades to
    // report-only for hosts too noisy to trust wall-clock ratios
    let host_cores =
        std::thread::available_parallelism().map_or(1, |n| n.get());
    assert!(
        mkd_speedup >= 2.0
            || exec::threads() < 4
            || host_cores < 4
            || std::env::var_os("MARFL_BENCH_NO_ASSERT").is_some(),
        "student-parallel MKD must be >=2x faster than the serial \
         reference at MARFL_THREADS>=4 (got {mkd_speedup:.2}x; set \
         MARFL_BENCH_NO_ASSERT=1 to report without gating)"
    );

    println!("\ntelemetry overhead ablation (registry handles on the hot loop)\n");
    // The metric registry is always on inside the trainer (it never
    // touches the RNG / clock / ledger, so keeping it live is what makes
    // telemetry-off bit-identity free). That bargain only holds if the
    // handles are effectively invisible on the hot path, so gate the
    // sharded-counter overhead against a registry-free baseline on a
    // trainer-shaped workload: a full-vector reduce plus the ~handful of
    // counter bumps one FL iteration performs.
    let telemetry_overhead = {
        let reg = MetricRegistry::new();
        let ops = reg.counter("ablation.ops").expect("register ablation.ops");
        let items =
            reg.counter("ablation.items").expect("register ablation.items");
        let v: Vec<f32> =
            (0..m.padded_len).map(|_| rng.normal() as f32).collect();
        let reduce = |buf: &[f32]| -> f32 {
            let mut acc = 0.0f32;
            for &x in buf {
                acc += x * x;
            }
            acc
        };
        let off_ns = bench_ns("hot loop, registry off", 10, 60, || {
            std::hint::black_box(reduce(std::hint::black_box(&v)));
        });
        let on_ns = bench_ns("hot loop, registry on", 10, 60, || {
            std::hint::black_box(reduce(std::hint::black_box(&v)));
            ops.inc();
            items.add(4);
        });
        let overhead = on_ns / off_ns;
        println!(
            "  registry-on / registry-off = {overhead:.3}x \
             (acceptance bar: <=1.03x)"
        );
        rows.0.push(("hot loop, registry off".into(), off_ns / 1e3));
        rows.0.push(("hot loop, registry on".into(), on_ns / 1e3));
        // acceptance gate: typed handles must be free on the hot path;
        // MARFL_BENCH_NO_ASSERT=1 downgrades to report-only for hosts too
        // noisy to trust wall-clock ratios
        assert!(
            overhead <= 1.03
                || std::env::var_os("MARFL_BENCH_NO_ASSERT").is_some(),
            "registry-on hot loop must be within 3% of registry-off \
             (got {overhead:.3}x; set MARFL_BENCH_NO_ASSERT=1 to report \
             without gating)"
        );
        overhead
    };

    // machine-readable perf trajectory (BENCH_micro.json)
    let results: Vec<Json> = rows
        .0
        .iter()
        .map(|(name, us)| obj(vec![("name", s(name)), ("us_per_op", num(*us))]))
        .collect();
    let path = BenchReport::new("micro")
        .field("kind", s("micro_hotpath"))
        .field("backend", s(rt.backend_name()))
        .field("threads", num(exec::threads() as f64))
        .field("telemetry_overhead", num(telemetry_overhead))
        .field("results", arr(results))
        .write(&common::results_dir())
        .expect("write BENCH_micro.json");
    println!("\n  -> {}", path.display());
}
