//! Communication ledger: the paper's primary measurement instrument.
//!
//! Counters are sharded per thread (cache-line-padded atomic stripes,
//! merged at snapshot) so the ledger can be shared (`Arc`) between the
//! coordinator, the DHT, the fabric and — since the parallel round engine
//! (`exec`) — many worker threads booking concurrently, without the hot
//! path ever bouncing one contended cache line between cores. Totals are
//! exact: booking is commutative addition, so parallel and serial
//! executions of the same schedule produce identical snapshots.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Which plane a message belongs to. The paper's claim is that control
/// traffic (DHT barriers/announcements, O(N log N) small messages) is
/// negligible next to data traffic (model exchange).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// DHT lookups, stores, barrier metadata.
    Control,
    /// Model / momentum / logits payloads.
    Data,
}

/// Phase of a chunk-owned group exchange (Moshpit-SGD's reduce-scatter
/// wire protocol). Phase traffic **is** data-plane traffic:
/// [`CommLedger::record_phase`] books it into the data counters *and*
/// the per-phase sub-counters, so `data_bytes` stays the single source
/// of truth for total data-plane volume while the ablation harnesses
/// (`scaling_sweep`, `fig11_approx_aggregation`) can report both phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangePhase {
    /// members stream each owner's stripe to that owner, who averages it
    ReduceScatter,
    /// owners broadcast their averaged stripe back to the group
    AllGather,
}

/// Number of counter stripes. Power of two, sized a little above typical
/// core counts; threads hash onto stripes, so two workers only share a
/// stripe (never a problem for correctness) when the pool outgrows it.
const LEDGER_SHARDS: usize = 16;

/// One cache-line-aligned stripe of counters (all eight live on the same
/// 64-byte line so a booking thread touches exactly one line).
#[derive(Default)]
#[repr(align(64))]
struct LedgerShard {
    data_bytes: AtomicU64,
    data_msgs: AtomicU64,
    control_bytes: AtomicU64,
    control_msgs: AtomicU64,
    rs_bytes: AtomicU64,
    rs_msgs: AtomicU64,
    ag_bytes: AtomicU64,
    ag_msgs: AtomicU64,
}

/// Contention-free byte/message accounting.
pub struct CommLedger {
    shards: [LedgerShard; LEDGER_SHARDS],
}

/// A point-in-time merge of the counters. The `rs_*` / `ag_*` fields are
/// sub-accounts of the data plane (chunk-owned exchanges booked through
/// [`CommLedger::record_phase`]); full-gather traffic books none, so
/// `rs_bytes + ag_bytes <= data_bytes` always holds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommSnapshot {
    pub data_bytes: u64,
    pub data_msgs: u64,
    pub control_bytes: u64,
    pub control_msgs: u64,
    pub rs_bytes: u64,
    pub rs_msgs: u64,
    pub ag_bytes: u64,
    pub ag_msgs: u64,
}

/// Stable per-thread stripe assignment (round-robin at first use).
fn shard_index() -> usize {
    crate::exec::thread_stripe(LEDGER_SHARDS)
}

impl CommLedger {
    pub fn new() -> Self {
        CommLedger { shards: std::array::from_fn(|_| LedgerShard::default()) }
    }

    /// Book one message of `bytes` on `plane`.
    pub fn record(&self, plane: Plane, bytes: u64) {
        self.record_many(plane, 1, bytes);
    }

    /// Book `msgs` messages totalling `bytes` on `plane` in one shot —
    /// the batched form the fabric uses for sequential sends (2 atomic
    /// adds instead of 2·k).
    pub fn record_many(&self, plane: Plane, msgs: u64, bytes: u64) {
        let shard = &self.shards[shard_index()];
        match plane {
            Plane::Data => {
                shard.data_bytes.fetch_add(bytes, Ordering::Relaxed);
                shard.data_msgs.fetch_add(msgs, Ordering::Relaxed);
            }
            Plane::Control => {
                shard.control_bytes.fetch_add(bytes, Ordering::Relaxed);
                shard.control_msgs.fetch_add(msgs, Ordering::Relaxed);
            }
        }
    }

    /// Book `msgs` phase messages totalling `bytes` of a chunk-owned
    /// group exchange: the data-plane counters advance (phase traffic is
    /// model payload) and the per-phase sub-counters record which wire
    /// phase moved it.
    pub fn record_phase(&self, phase: ExchangePhase, msgs: u64, bytes: u64) {
        let shard = &self.shards[shard_index()];
        shard.data_bytes.fetch_add(bytes, Ordering::Relaxed);
        shard.data_msgs.fetch_add(msgs, Ordering::Relaxed);
        match phase {
            ExchangePhase::ReduceScatter => {
                shard.rs_bytes.fetch_add(bytes, Ordering::Relaxed);
                shard.rs_msgs.fetch_add(msgs, Ordering::Relaxed);
            }
            ExchangePhase::AllGather => {
                shard.ag_bytes.fetch_add(bytes, Ordering::Relaxed);
                shard.ag_msgs.fetch_add(msgs, Ordering::Relaxed);
            }
        }
    }

    pub fn snapshot(&self) -> CommSnapshot {
        let mut s = CommSnapshot::default();
        for shard in &self.shards {
            s.data_bytes += shard.data_bytes.load(Ordering::Relaxed);
            s.data_msgs += shard.data_msgs.load(Ordering::Relaxed);
            s.control_bytes += shard.control_bytes.load(Ordering::Relaxed);
            s.control_msgs += shard.control_msgs.load(Ordering::Relaxed);
            s.rs_bytes += shard.rs_bytes.load(Ordering::Relaxed);
            s.rs_msgs += shard.rs_msgs.load(Ordering::Relaxed);
            s.ag_bytes += shard.ag_bytes.load(Ordering::Relaxed);
            s.ag_msgs += shard.ag_msgs.load(Ordering::Relaxed);
        }
        s
    }

    pub fn reset(&self) {
        for shard in &self.shards {
            shard.data_bytes.store(0, Ordering::Relaxed);
            shard.data_msgs.store(0, Ordering::Relaxed);
            shard.control_bytes.store(0, Ordering::Relaxed);
            shard.control_msgs.store(0, Ordering::Relaxed);
            shard.rs_bytes.store(0, Ordering::Relaxed);
            shard.rs_msgs.store(0, Ordering::Relaxed);
            shard.ag_bytes.store(0, Ordering::Relaxed);
            shard.ag_msgs.store(0, Ordering::Relaxed);
        }
    }
}

impl Default for CommLedger {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Debug for CommLedger {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CommLedger").field("snapshot", &self.snapshot()).finish()
    }
}

impl CommSnapshot {
    pub fn total_bytes(&self) -> u64 {
        self.data_bytes + self.control_bytes
    }

    /// Delta between two snapshots (e.g. one FL iteration).
    pub fn since(&self, earlier: &CommSnapshot) -> CommSnapshot {
        CommSnapshot {
            data_bytes: self.data_bytes - earlier.data_bytes,
            data_msgs: self.data_msgs - earlier.data_msgs,
            control_bytes: self.control_bytes - earlier.control_bytes,
            control_msgs: self.control_msgs - earlier.control_msgs,
            rs_bytes: self.rs_bytes - earlier.rs_bytes,
            rs_msgs: self.rs_msgs - earlier.rs_msgs,
            ag_bytes: self.ag_bytes - earlier.ag_bytes,
            ag_msgs: self.ag_msgs - earlier.ag_msgs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn records_per_plane() {
        let l = CommLedger::new();
        l.record(Plane::Data, 100);
        l.record(Plane::Data, 50);
        l.record(Plane::Control, 8);
        let s = l.snapshot();
        assert_eq!(s.data_bytes, 150);
        assert_eq!(s.data_msgs, 2);
        assert_eq!(s.control_bytes, 8);
        assert_eq!(s.control_msgs, 1);
        assert_eq!(s.total_bytes(), 158);
    }

    #[test]
    fn record_many_matches_repeated_record() {
        let a = CommLedger::new();
        for _ in 0..7 {
            a.record(Plane::Data, 33);
        }
        let b = CommLedger::new();
        b.record_many(Plane::Data, 7, 7 * 33);
        assert_eq!(a.snapshot(), b.snapshot());
    }

    #[test]
    fn since_computes_deltas() {
        let l = CommLedger::new();
        l.record(Plane::Data, 10);
        let a = l.snapshot();
        l.record(Plane::Data, 32);
        l.record(Plane::Control, 4);
        let d = l.snapshot().since(&a);
        assert_eq!(d.data_bytes, 32);
        assert_eq!(d.data_msgs, 1);
        assert_eq!(d.control_bytes, 4);
    }

    #[test]
    fn concurrent_recording_is_consistent() {
        let l = Arc::new(CommLedger::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        l.record(Plane::Data, 3);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = l.snapshot();
        assert_eq!(s.data_bytes, 12_000);
        assert_eq!(s.data_msgs, 4_000);
    }

    #[test]
    fn pool_parallel_recording_is_exact() {
        use rayon::prelude::*;
        let l = CommLedger::new();
        crate::exec::pool().install(|| {
            (0..1000u64).into_par_iter().for_each(|i| {
                l.record(Plane::Control, i);
            });
        });
        let s = l.snapshot();
        assert_eq!(s.control_msgs, 1000);
        assert_eq!(s.control_bytes, 999 * 1000 / 2);
    }

    #[test]
    fn reset_zeroes() {
        let l = CommLedger::new();
        l.record(Plane::Control, 9);
        l.record_phase(ExchangePhase::AllGather, 1, 5);
        l.reset();
        assert_eq!(l.snapshot(), CommSnapshot::default());
    }

    #[test]
    fn phase_booking_lands_on_data_plane_and_phase_counters() {
        let l = CommLedger::new();
        l.record_phase(ExchangePhase::ReduceScatter, 4, 400);
        l.record_phase(ExchangePhase::AllGather, 2, 100);
        l.record(Plane::Data, 50); // full-gather traffic: no phase
        let s = l.snapshot();
        assert_eq!(s.rs_bytes, 400);
        assert_eq!(s.rs_msgs, 4);
        assert_eq!(s.ag_bytes, 100);
        assert_eq!(s.ag_msgs, 2);
        assert_eq!(s.data_bytes, 550);
        assert_eq!(s.data_msgs, 7);
        assert!(s.rs_bytes + s.ag_bytes <= s.data_bytes);
    }

    #[test]
    fn since_covers_phase_counters() {
        let l = CommLedger::new();
        l.record_phase(ExchangePhase::ReduceScatter, 1, 10);
        let a = l.snapshot();
        l.record_phase(ExchangePhase::ReduceScatter, 2, 30);
        l.record_phase(ExchangePhase::AllGather, 1, 7);
        let d = l.snapshot().since(&a);
        assert_eq!(d.rs_bytes, 30);
        assert_eq!(d.rs_msgs, 2);
        assert_eq!(d.ag_bytes, 7);
        assert_eq!(d.data_bytes, 37);
    }
}
