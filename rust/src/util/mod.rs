//! Small shared substrates: JSON (parse/emit), binary I/O helpers.
//!
//! This environment is fully offline (only the `xla` closure is vendored),
//! so serde/serde_json are reimplemented at the scale this project needs.

pub mod json;

use std::fs::File;
use std::io::{BufReader, Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

/// Read a little-endian f32 binary file (e.g. `artifacts/{m}_init.bin`).
pub fn read_f32_le(path: &Path) -> Result<Vec<f32>> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut bytes = Vec::new();
    r.read_to_end(&mut bytes)?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "{path:?}: length {} not a multiple of 4",
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a little-endian f32 binary file.
pub fn write_f32_le(path: &Path, data: &[f32]) -> Result<()> {
    let mut f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&bytes)?;
    Ok(())
}

/// Euclidean (L2) norm of a vector — used by DP clipping and tests.
pub fn l2_norm(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Mean squared distance between two vectors.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_le_round_trip() {
        let dir = std::env::temp_dir().join("marfl_util_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.bin");
        let data = vec![0.0f32, -1.5, 3.25, f32::MIN_POSITIVE];
        write_f32_le(&path, &data).unwrap();
        assert_eq!(read_f32_le(&path).unwrap(), data);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn l2_norm_matches_hand_computation() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(l2_norm(&[]), 0.0);
    }

    #[test]
    fn mse_zero_for_identical() {
        let v = vec![1.0f32, 2.0, 3.0];
        assert_eq!(mse(&v, &v), 0.0);
    }
}
