//! Byzantine-robustness verification: an inert `attack.*` block must be
//! bit-identical to the seed behaviour (zero extra RNG draws), attacked
//! aggregation must stay bit-identical across the serial and
//! group-parallel engines for every robust estimator (with the
//! reputation ledger agreeing too), the trimmed mean must respect its
//! breakdown point coordinate-wise, and the Trainer must surface the
//! attack/defence scorecard through `RunSummary` deterministically.

use std::sync::Arc;

use marfl::aggregation::robust::{RobustEstimator, RobustPolicy};
use marfl::aggregation::{
    robust_average_group_native, AggCtx, AggReport, GroupExchange, PeerState,
};
use marfl::attack::{AttackConfig, AttackMode, Reputation};
use marfl::config::ExperimentConfig;
use marfl::coordinator::{AggOptions, MarAggregator};
use marfl::fl::Trainer;
use marfl::metrics::{CommLedger, CommSnapshot};
use marfl::net::{BwDist, Fabric, FaultConfig};
use marfl::rng::Rng;
use marfl::runtime::Runtime;
use marfl::sim::SimClock;

fn toy_model(p: usize) -> marfl::models::ModelMeta {
    marfl::models::ModelMeta {
        name: "toy".into(),
        param_count: p,
        padded_len: p,
        input_shape: vec![4],
        classes: 3,
        batch: 8,
        eval_chunk: 8,
        init_file: String::new(),
        artifacts: Default::default(),
    }
}

fn random_states(n: usize, p: usize, seed: u64) -> Vec<PeerState> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| PeerState {
            theta: (0..p).map(|_| rng.normal() as f32).collect(),
            momentum: (0..p).map(|_| rng.normal() as f32 * 0.1).collect(),
        })
        .collect()
}

/// Flip the sign of every attacker's full state — the same corruption
/// `attack::AttackPlan` applies under `sign_flip`, inlined here so the
/// MAR-level tests control exactly who attacks when.
fn flip(states: &mut [PeerState], attackers: &[usize]) {
    for &a in attackers {
        for v in states[a].theta.make_mut_slice() {
            *v = -*v;
        }
        for v in states[a].momentum.make_mut_slice() {
            *v = -*v;
        }
    }
}

/// Three MAR iterations with re-corrupted attackers between calls;
/// returns (states, ledger, clock, reports, reputation ledger).
fn run_attacked_mar(
    est: RobustEstimator,
    exchange: GroupExchange,
    parallel: bool,
) -> (Vec<PeerState>, CommSnapshot, f64, Vec<AggReport>, Reputation) {
    let mut states = random_states(16, 97, 0xB124);
    run_mar_iters(&mut states, est, exchange, parallel, (0.0, 0), 3)
}

/// Drive `iters` MAR iterations over `states` (16 peers, groups of 4,
/// 2 rounds) with sign-flipping attackers 3/7/12 re-corrupted before
/// every call, reputation at 0.4 and the given `(rep_decay,
/// parole_rounds)` pair.
fn run_mar_iters(
    states: &mut [PeerState],
    est: RobustEstimator,
    exchange: GroupExchange,
    parallel: bool,
    parole: (f64, u64),
    iters: usize,
) -> (Vec<PeerState>, CommSnapshot, f64, Vec<AggReport>, Reputation) {
    let (n, m, g) = (16, 4, 2);
    assert_eq!(states.len(), n);
    let p = states[0].theta.len();
    let attackers = [3usize, 7, 12];
    let agg: Vec<usize> = (0..n).collect();
    let ledger = Arc::new(CommLedger::new());
    let fabric = Fabric::new(ledger.clone(), 12.5e6, 0.02);
    let mut clock = SimClock::new();
    let mut rng = Rng::new(404);
    let model = toy_model(p);
    let mut mar = MarAggregator::with_options(
        n,
        m,
        g,
        ledger.clone(),
        7,
        AggOptions {
            exchange,
            parallel,
            robust: RobustPolicy { est, trim: 0.25 },
            rep_threshold: 0.4,
            rep_decay: parole.0,
            parole_rounds: parole.1,
            ..AggOptions::default()
        },
    );
    ledger.reset(); // drop DHT join traffic
    let mut reports = Vec::new();
    for _ in 0..iters {
        flip(states, &attackers);
        let mut ctx = AggCtx {
            fabric: &fabric,
            clock: &mut clock,
            rng: &mut rng,
            runtime: None,
            model: &model,
            faults: &FaultConfig::OFF,
            links: None,
        };
        reports.push(mar.aggregate(states, &agg, &mut ctx).unwrap());
    }
    let rep = mar.reputation().unwrap().clone();
    (states.to_vec(), ledger.snapshot(), clock.now(), reports, rep)
}

/// A tight honest cluster (spread ≪ ‖θ‖) where a sign-flipped attacker
/// is an unambiguous outlier in every ≥3-member group it joins.
fn clustered_states(n: usize, p: usize) -> Vec<PeerState> {
    (0..n)
        .map(|i| PeerState {
            theta: (0..p)
                .map(|j| 1.0 + 1e-4 * (i * p + j) as f32)
                .collect(),
            momentum: (0..p).map(|_| 0.01).collect(),
        })
        .collect()
}

/// (a) Inert attack block ⇒ bit-identical to the seed path: with
/// `frac = 0`, a `mean` estimator and reputation off, every other
/// `attack.*` knob may be set arbitrarily and the run must not change
/// by a single bit (no `AttackPlan`, no fork(4), no score passes).
#[test]
fn inert_attack_config_is_bit_identical_to_seed() {
    let rt = Runtime::new(&marfl::models::default_artifact_dir()).unwrap();
    let base = ExperimentConfig {
        model: "head".into(),
        peers: 9,
        group_size: 3,
        iterations: 4,
        samples_per_peer: 32,
        test_samples: 250,
        eval_every: 4,
        local_batches: 2,
        seed: 991,
        ..Default::default()
    };
    let run = |cfg: ExperimentConfig| {
        let mut t = Trainer::new(cfg, &rt).unwrap();
        let summary = t.run().unwrap();
        let states: Vec<PeerState> = t.states().to_vec();
        (states, summary)
    };
    let (plain_states, plain) = run(base.clone());

    let mut inert = base;
    inert.attack = AttackConfig {
        frac: 0.0, // off — everything below must be dead weight
        mode: AttackMode::Scale,
        scale: 7.0,
        collude: true,
        robust: RobustEstimator::Mean,
        trim: 0.4,
        rep_threshold: 0.0,
        rep_decay: 0.0,
        parole_rounds: 0,
    };
    inert.validate().unwrap();
    let (inert_states, irun) = run(inert);

    for (a, b) in plain_states.iter().zip(&inert_states) {
        assert_eq!(a.theta, b.theta, "inert attack block perturbed states");
        assert_eq!(a.momentum, b.momentum);
    }
    assert_eq!(plain.comm, irun.comm, "inert attack block changed traffic");
    assert_eq!(plain.sim_time_s.to_bits(), irun.sim_time_s.to_bits());
    assert_eq!(
        plain.final_loss.to_bits(),
        irun.final_loss.to_bits(),
        "inert attack block changed the model"
    );
    assert_eq!(irun.byzantine.attackers_active, 0);
    assert_eq!(irun.byzantine.flagged_peers, 0);
    assert_eq!(irun.byzantine.flag_precision, 1.0);
    assert_eq!(irun.byzantine.flag_recall, 1.0);
    assert_eq!(irun.byzantine.paroles_granted, 0);
    assert_eq!(irun.byzantine.reban_count, 0);
}

/// (b) Attacked aggregation stays bit-identical across engines for
/// every estimator: the robust kernels and the outlier-score pass all
/// run (or are folded) in deterministic group order, so serial and
/// group-parallel runs agree on states, ledger, clock, flag counters —
/// and on the reputation ledger itself.
#[test]
fn attacked_aggregation_parallel_matches_serial() {
    for est in [
        RobustEstimator::Mean,
        RobustEstimator::TrimmedMean,
        RobustEstimator::Median,
        RobustEstimator::NormClip,
        RobustEstimator::Krum,
        RobustEstimator::MultiKrum,
    ] {
        for exchange in
            [GroupExchange::FullGather, GroupExchange::ReduceScatter]
        {
            let (s_states, s_snap, s_clock, s_reps, s_rep) =
                run_attacked_mar(est, exchange, false);
            let (p_states, p_snap, p_clock, p_reps, p_rep) =
                run_attacked_mar(est, exchange, true);
            let tag = format!("{}/{exchange:?}", est.name());
            for (i, (a, b)) in s_states.iter().zip(&p_states).enumerate() {
                assert_eq!(a.theta, b.theta, "{tag}: peer {i} theta diverged");
                assert_eq!(a.momentum, b.momentum, "{tag}: peer {i} momentum");
            }
            assert_eq!(s_snap, p_snap, "{tag}: ledger diverged");
            assert_eq!(s_clock.to_bits(), p_clock.to_bits(), "{tag}: clock");
            assert_eq!(s_reps, p_reps, "{tag}: reports diverged");
            assert_eq!(s_rep, p_rep, "{tag}: reputation ledgers diverged");
        }
    }
}

/// (c) Breakdown point: with `f <= drop_count` corrupted rows, the
/// trimmed-mean center stays within the honest rows' coordinate-wise
/// envelope no matter how extreme the corruption — and the plain mean
/// (sanity check) does not.
#[test]
fn trimmed_mean_respects_breakdown_point() {
    let p = 33;
    let members: Vec<usize> = (0..4).collect();
    let build = || {
        let mut states = random_states(4, p, 0xCAFE);
        // one attacker (== drop_count for k=4, trim=0.25), arbitrarily hot
        for (j, v) in states[2].theta.make_mut_slice().iter_mut().enumerate() {
            *v = if j % 2 == 0 { 1e6 } else { -1e6 };
        }
        states
    };
    let honest = [0usize, 1, 3];
    let pristine = build();
    let (lo, hi): (Vec<f32>, Vec<f32>) = (0..p)
        .map(|j| {
            let vals: Vec<f32> =
                honest.iter().map(|&k| pristine[k].theta.as_slice()[j]).collect();
            (
                vals.iter().copied().fold(f32::INFINITY, f32::min),
                vals.iter().copied().fold(f32::NEG_INFINITY, f32::max),
            )
        })
        .unzip();

    let policy =
        RobustPolicy { est: RobustEstimator::TrimmedMean, trim: 0.25 };
    assert_eq!(policy.drop_count(4), 1);
    let mut states = build();
    robust_average_group_native(&mut states, &members, policy, false);
    for (j, &c) in states[0].theta.as_slice().iter().enumerate() {
        assert!(
            c >= lo[j] - 1e-4 && c <= hi[j] + 1e-4,
            "coordinate {j}: trimmed center {c} left honest envelope \
             [{}, {}]",
            lo[j],
            hi[j]
        );
    }

    // the undefended mean is dragged out of the envelope by the same row
    let mut states = build();
    robust_average_group_native(&mut states, &members, RobustPolicy::MEAN, false);
    let escaped = states[0]
        .theta
        .as_slice()
        .iter()
        .enumerate()
        .filter(|&(j, &c)| c < lo[j] - 1e-4 || c > hi[j] + 1e-4)
        .count();
    assert!(escaped > p / 2, "plain mean must be dominated by the attacker");
}

/// (d) End-to-end scorecard determinism: two identical byzantine runs
/// (sign-flip attackers, trimmed mean + reputation, slow bandwidth
/// redraws) report the exact same attack/defence counters and finish in
/// bit-identical states.
#[test]
fn byzantine_trainer_runs_are_reproducible() {
    let rt = Runtime::new(&marfl::models::default_artifact_dir()).unwrap();
    let mut cfg = ExperimentConfig {
        model: "head".into(),
        peers: 9,
        group_size: 3,
        iterations: 6,
        samples_per_peer: 32,
        test_samples: 250,
        eval_every: 6,
        local_batches: 2,
        seed: 2468,
        ..Default::default()
    };
    cfg.attack = AttackConfig {
        frac: 0.3, // round(0.3 * 9) = 3 ground-truth attackers
        robust: RobustEstimator::TrimmedMean,
        trim: 0.25,
        rep_threshold: 0.4,
        ..AttackConfig::default()
    };
    cfg.faults = FaultConfig {
        bw_dist: BwDist::Uniform,
        bw_min: 0.3,
        bw_max: 0.9,
        bw_redraw_rounds: 2,
        ..FaultConfig::default()
    };
    cfg.validate().unwrap();
    let run = |cfg: ExperimentConfig| {
        let mut t = Trainer::new(cfg, &rt).unwrap();
        let summary = t.run().unwrap();
        let states: Vec<PeerState> = t.states().to_vec();
        (states, summary)
    };
    let (a_states, a) = run(cfg.clone());
    let (b_states, b) = run(cfg);

    assert_eq!(a.byzantine.attackers_active, 3, "all 3 planted attackers must fire");
    // redraw schedule: iterations 2 and 4 (t % 2 == 0, t > 0)
    assert_eq!(a.faults.bw_redraws, 2);
    assert_eq!(a.byzantine.attackers_active, b.byzantine.attackers_active);
    assert_eq!(a.byzantine.flagged_peers, b.byzantine.flagged_peers);
    assert_eq!(a.byzantine.flag_precision.to_bits(), b.byzantine.flag_precision.to_bits());
    assert_eq!(a.byzantine.flag_recall.to_bits(), b.byzantine.flag_recall.to_bits());
    assert_eq!(a.faults.bw_redraws, b.faults.bw_redraws);
    assert_eq!(a.comm, b.comm);
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits());
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    for (x, y) in a_states.iter().zip(&b_states) {
        assert_eq!(x.theta, y.theta);
        assert_eq!(x.momentum, y.momentum);
    }
}

/// (e) Krum selection pinned against a hand-computed 5-member group:
/// with `trim = 0.25` the allowance is `f = ⌊0.25·5⌋ = 1`, so every row
/// scores the sum of its `5 − 1 − 2 = 2` nearest squared distances.
///
/// ```text
/// rows: r0 = 0⃗, r1 = 0.25·e0, r2 = 0.25·e1, r3 = 100·(1,1,1,1), r4 = 0.75·e0
/// d²(0,1) = d²(0,2) = 0.0625   d²(1,2) = 0.125
/// d²(0,4) = 0.5625   d²(1,4) = 0.25   d²(2,4) = 0.625
/// scores: s(0) = 0.125 ← unique minimum, s(1) = s(2) = 0.1875,
///         s(4) = 0.8125, s(3) astronomically large
/// ```
///
/// Krum must return exactly `r0` (a bit-for-bit copy of the winner);
/// Multi-Krum averages the `5 − f = 4` lowest-scored rows `{0, 1, 2, 4}`
/// — all coordinates are powers of two, so the expected centers are
/// exact in f32. Both engines must agree.
#[test]
fn krum_selection_pinned_on_a_hand_computed_group() {
    let rows: [[f32; 4]; 5] = [
        [0.0, 0.0, 0.0, 0.0],
        [0.25, 0.0, 0.0, 0.0],
        [0.0, 0.25, 0.0, 0.0],
        [100.0, 100.0, 100.0, 100.0],
        [0.75, 0.0, 0.0, 0.0],
    ];
    let build = || -> Vec<PeerState> {
        rows.iter()
            .map(|r| PeerState {
                theta: r.to_vec().into(),
                momentum: r.iter().map(|&v| 0.5 * v).collect(),
            })
            .collect()
    };
    let members: Vec<usize> = (0..5).collect();
    for parallel in [false, true] {
        let mut st = build();
        robust_average_group_native(
            &mut st,
            &members,
            RobustPolicy { est: RobustEstimator::Krum, trim: 0.25 },
            parallel,
        );
        for &mm in &members {
            assert_eq!(
                st[mm].theta.to_vec(),
                rows[0],
                "Krum (parallel={parallel}) must select r0 verbatim"
            );
            assert_eq!(st[mm].momentum.to_vec(), [0.0f32; 4]);
        }
        let mut st = build();
        robust_average_group_native(
            &mut st,
            &members,
            RobustPolicy { est: RobustEstimator::MultiKrum, trim: 0.25 },
            parallel,
        );
        for &mm in &members {
            assert_eq!(
                st[mm].theta.to_vec(),
                [0.25f32, 0.0625, 0.0, 0.0],
                "Multi-Krum (parallel={parallel}) must average {{0,1,2,4}}"
            );
            assert_eq!(
                st[mm].momentum.to_vec(),
                [0.125f32, 0.03125, 0.0, 0.0]
            );
        }
    }
}

/// (f) Parole round-trip — ban → parole → re-ban — happens and is
/// bit-identical serial-vs-parallel: a tight honest cluster makes the
/// sign-flipped attackers unambiguous outliers, `parole_rounds = 2`
/// cycles them back into matchmaking where the flipped upload re-bans
/// them at the tighter parole threshold, and the whole trajectory
/// (states, ledger, clock, reports, reputation incl. counters) agrees
/// across engines for both a coordinate-wise and a selection estimator.
#[test]
fn parole_round_trip_is_deterministic_across_engines() {
    for est in [RobustEstimator::TrimmedMean, RobustEstimator::MultiKrum] {
        let mut s_init = clustered_states(16, 33);
        let (s_states, s_snap, s_clock, s_reps, s_rep) = run_mar_iters(
            &mut s_init,
            est,
            GroupExchange::FullGather,
            false,
            (0.05, 2),
            8,
        );
        let mut p_init = clustered_states(16, 33);
        let (p_states, p_snap, p_clock, p_reps, p_rep) = run_mar_iters(
            &mut p_init,
            est,
            GroupExchange::FullGather,
            true,
            (0.05, 2),
            8,
        );
        let tag = est.name();
        assert!(
            s_rep.paroles_granted() > 0,
            "{tag}: bans must expire into parole within 8 iterations"
        );
        assert!(
            s_rep.reban_count() > 0,
            "{tag}: a flipped parolee must be re-banned in its window"
        );
        for (i, (a, b)) in s_states.iter().zip(&p_states).enumerate() {
            assert_eq!(a.theta, b.theta, "{tag}: peer {i} theta diverged");
            assert_eq!(a.momentum, b.momentum, "{tag}: peer {i} momentum");
        }
        assert_eq!(s_snap, p_snap, "{tag}: ledger diverged");
        assert_eq!(s_clock.to_bits(), p_clock.to_bits(), "{tag}: clock");
        assert_eq!(s_reps, p_reps, "{tag}: reports diverged");
        assert_eq!(s_rep, p_rep, "{tag}: reputation ledgers diverged");
    }
}

/// (g) Inert-identity pin for the parole knobs: `rep_decay = 0 ∧
/// parole_rounds = 0 ∧ mode = sign_flip` spelled out explicitly must be
/// byte-identical to a config that never mentions them (the PR 8
/// sticky-ban seed path), with both parole counters pinned at zero.
#[test]
fn parole_knobs_off_match_the_sticky_ban_seed() {
    let rt = Runtime::new(&marfl::models::default_artifact_dir()).unwrap();
    let base = ExperimentConfig {
        model: "head".into(),
        peers: 9,
        group_size: 3,
        iterations: 5,
        samples_per_peer: 32,
        test_samples: 250,
        eval_every: 5,
        local_batches: 2,
        seed: 31415,
        ..Default::default()
    };
    let run = |mut cfg: ExperimentConfig, attack: AttackConfig| {
        cfg.attack = attack;
        cfg.validate().unwrap();
        let mut t = Trainer::new(cfg, &rt).unwrap();
        let summary = t.run().unwrap();
        let states: Vec<PeerState> = t.states().to_vec();
        (states, summary)
    };
    // seed path: the parole knobs are never mentioned
    let (a_states, a) = run(
        base.clone(),
        AttackConfig {
            frac: 0.3,
            robust: RobustEstimator::TrimmedMean,
            trim: 0.25,
            rep_threshold: 0.4,
            ..AttackConfig::default()
        },
    );
    // explicit inert values: must take the identical code path
    let (b_states, b) = run(
        base,
        AttackConfig {
            frac: 0.3,
            mode: AttackMode::SignFlip,
            scale: 1.0,
            collude: false,
            robust: RobustEstimator::TrimmedMean,
            trim: 0.25,
            rep_threshold: 0.4,
            rep_decay: 0.0,
            parole_rounds: 0,
        },
    );
    assert_eq!(a.byzantine.paroles_granted, 0, "sticky bans must never parole");
    assert_eq!(a.byzantine.reban_count, 0);
    assert_eq!(a.byzantine.paroles_granted, b.byzantine.paroles_granted);
    assert_eq!(a.byzantine.reban_count, b.byzantine.reban_count);
    assert_eq!(a.byzantine.flagged_peers, b.byzantine.flagged_peers);
    assert_eq!(a.byzantine.flag_precision.to_bits(), b.byzantine.flag_precision.to_bits());
    assert_eq!(a.byzantine.flag_recall.to_bits(), b.byzantine.flag_recall.to_bits());
    assert_eq!(a.comm, b.comm);
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits());
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    for (x, y) in a_states.iter().zip(&b_states) {
        assert_eq!(x.theta, y.theta);
        assert_eq!(x.momentum, y.momentum);
    }
}
