//! AR-FL — naive all-to-all All-Reduce FL (paper baseline).
//!
//! Every aggregator broadcasts its full state to every other aggregator
//! and averages locally: N(N−1) state transfers per iteration, O(N²) — the
//! second baseline whose communication MAR-FL undercuts by ~10× at N=125.

use anyhow::Result;

use super::{mean_of, payload_bytes, AggCtx, AggReport, Aggregate, PeerState, Theta};
use crate::metrics::Plane;
use crate::net::LinkFault;

#[derive(Debug, Default)]
pub struct AllToAll;

impl Aggregate for AllToAll {
    fn name(&self) -> &'static str {
        "arfl"
    }

    fn aggregate(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport> {
        let n = agg.len();
        if n < 2 {
            return Ok(AggReport::default());
        }
        let bytes = payload_bytes(states, agg);
        if ctx.faults.enabled() {
            return self.aggregate_faulty(states, agg, bytes, ctx);
        }
        // each peer sends its state to n-1 others; peers act in parallel,
        // per-peer sends are sequential over its uplink
        let mut lane_times = Vec::with_capacity(n);
        for _ in 0..n {
            lane_times.push(ctx.fabric.sequential(n - 1, bytes, Plane::Data));
        }
        ctx.clock.parallel(lane_times);
        let (theta, mom) = mean_of(states, agg);
        let (theta, mom) = (Theta::new(theta), Theta::new(mom));
        for &i in agg {
            states[i].theta = theta.clone();
            states[i].momentum = mom.clone();
        }
        Ok(AggReport { rounds: 1, groups: 1, ..Default::default() })
    }
}

impl AllToAll {
    /// Fault-plan round: crashed peers never broadcast, and a peer whose
    /// broadcast lost a message (timeout after the retry budget) never
    /// reaches the full set — it is excluded from the consensus mean and
    /// stays stale this round, though every attempt and probe is booked.
    fn aggregate_faulty(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        bytes: u64,
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport> {
        let fp = ctx.faults;
        let mut report =
            AggReport { rounds: 1, groups: 1, ..Default::default() };
        // mid-round crash draws (serial, aggregator order)
        let mut live: Vec<usize> = Vec::with_capacity(agg.len());
        if fp.crash_prob > 0.0 {
            for &i in agg {
                if ctx.rng.chance(fp.crash_prob) {
                    report.faults.crashes += 1;
                } else {
                    live.push(i);
                }
            }
        } else {
            live.extend_from_slice(agg);
        }
        if live.len() < 2 {
            return Ok(report);
        }
        // per-peer link draws for the n-1 outbound broadcasts
        let link_on = fp.link_faults_enabled();
        let links: Vec<LinkFault> = (0..live.len())
            .map(|j| {
                if link_on {
                    // one message to every other live peer; each directed
                    // edge observes its own Gilbert–Elliott chain
                    let dsts: Vec<usize> = live
                        .iter()
                        .copied()
                        .filter(|&p| p != live[j])
                        .collect();
                    let lf = fp.draw_member(
                        live[j],
                        &dsts,
                        1,
                        ctx.links.as_deref_mut(),
                        ctx.rng,
                    );
                    report.faults.absorb(&lf);
                    lf
                } else {
                    LinkFault::CLEAN
                }
            })
            .collect();
        let mut lane_times = Vec::with_capacity(live.len());
        for lf in &links {
            lane_times.push(ctx.fabric.sequential_faulty(
                live.len() - 1,
                bytes,
                Plane::Data,
                lf,
            ));
        }
        ctx.clock.parallel(lane_times);
        let complete: Vec<usize> = live
            .iter()
            .enumerate()
            .filter(|&(j, _)| !links[j].lost())
            .map(|(_, &i)| i)
            .collect();
        if complete.len() < 2 {
            return Ok(report);
        }
        if complete.len() < agg.len() {
            report.faults.quorum_degraded_rounds += 1;
        }
        let (theta, mom) = mean_of(states, &complete);
        let (theta, mom) = (Theta::new(theta), Theta::new(mom));
        for &i in &complete {
            states[i].theta = theta.clone();
            states[i].momentum = mom.clone();
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::test_support::*;

    #[test]
    fn exact_global_average() {
        let mut states = random_states(5, 16, 11);
        let agg: Vec<usize> = (0..5).collect();
        let (want_t, want_m) = mean_of(&states, &agg);
        let mut tc = TestCtx::new(16);
        let mut ctx = tc.ctx();
        AllToAll.aggregate(&mut states, &agg, &mut ctx).unwrap();
        for s in &states {
            crate::testing::assert_allclose(&s.theta, &want_t, 1e-6, 1e-7);
            crate::testing::assert_allclose(&s.momentum, &want_m, 1e-6, 1e-7);
        }
    }

    #[test]
    fn quadratic_transfer_count() {
        let n = 12;
        let mut states = random_states(n, 8, 12);
        let agg: Vec<usize> = (0..n).collect();
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        AllToAll.aggregate(&mut states, &agg, &mut ctx).unwrap();
        assert_eq!(tc.ledger.snapshot().data_msgs as usize, n * (n - 1));
    }

    #[test]
    fn parallel_time_scales_with_n_not_n_squared() {
        // with per-peer parallel lanes, duration ~ (n-1) * transfer, not
        // n(n-1) — the fabric model distinguishes bytes from wall time
        let mut tc = TestCtx::new(8);
        let bytes = crate::aggregation::state_bytes(&tc.model) as f64;
        let per = 0.001 + bytes / 1e6;
        let n = 6;
        let mut states = random_states(n, 8, 13);
        let agg: Vec<usize> = (0..n).collect();
        let mut ctx = tc.ctx();
        AllToAll.aggregate(&mut states, &agg, &mut ctx).unwrap();
        let want = (n - 1) as f64 * per;
        assert!((tc.clock.now() - want).abs() < 1e-9, "{} vs {want}", tc.clock.now());
    }
}
