"""Fused softmax-cross-entropy Pallas kernel (L1).

The training hot-spot of both MAR-FL models is the classification loss:
softmax -> NLL -> gradient w.r.t. logits. Done naively this materializes
softmax probabilities in HBM three times (forward, loss, backward). The
fused kernel computes per-example loss AND dlogits in a single VMEM-resident
pass over a `[block_b, C]` tile.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the row-block tiling keeps
each tile in VMEM; on real hardware `C` would be padded to the 128-lane VPU
register width and `block_b` to the 8-sublane height. Here we run under
`interpret=True` (CPU PJRT cannot execute Mosaic custom-calls), so the tile
shape documents the schedule rather than changing codegen.

Exposed as `softmax_xent(logits, onehot) -> loss[B]` with a custom VJP that
reuses the dlogits computed in the forward pass — the backward pass is free.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block height. 8 divides every batch size we lower (16, 64) and matches
# the TPU sublane count.
BLOCK_B = 8


def _softmax_xent_kernel(z_ref, y_ref, loss_ref, dz_ref):
    """One `[block_b, C]` tile: loss_i = logsumexp(z_i) - <y_i, z_i>,
    dz_i = softmax(z_i) - y_i."""
    z = z_ref[...]
    y = y_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    shifted = z - zmax
    ez = jnp.exp(shifted)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    p = ez / denom
    logp = shifted - jnp.log(denom)
    loss_ref[...] = -jnp.sum(y * logp, axis=-1)
    dz_ref[...] = p - y


def _block_b_for(batch: int) -> int:
    if batch % BLOCK_B == 0:
        return BLOCK_B
    # Fall back to the largest divisor <= BLOCK_B so odd eval shapes work.
    for b in range(min(BLOCK_B, batch), 0, -1):
        if batch % b == 0:
            return b
    return 1


@partial(jax.jit, static_argnames=())
def _fused_fwd(logits: jax.Array, onehot: jax.Array):
    """Run the Pallas kernel over the whole batch; returns (loss[B], dz[B,C])."""
    batch, classes = logits.shape
    bb = _block_b_for(batch)
    grid = (batch // bb,)
    loss, dz = pl.pallas_call(
        _softmax_xent_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, classes), lambda i: (i, 0)),
            pl.BlockSpec((bb, classes), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb, classes), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch,), jnp.float32),
            jax.ShapeDtypeStruct((batch, classes), jnp.float32),
        ],
        interpret=True,
    )(logits, onehot)
    return loss, dz


@jax.custom_vjp
def softmax_xent(logits: jax.Array, onehot: jax.Array) -> jax.Array:
    """Per-example cross-entropy loss of `logits[B,C]` against one-hot
    targets, computed by the fused Pallas kernel."""
    loss, _ = _fused_fwd(logits, onehot)
    return loss


def _softmax_xent_vjp_fwd(logits, onehot):
    loss, dz = _fused_fwd(logits, onehot)
    return loss, dz


def _softmax_xent_vjp_bwd(dz, g):
    # g: cotangent of loss[B]; dlogits computed in the forward pass.
    return g[:, None] * dz, jnp.zeros_like(dz)


softmax_xent.defvjp(_softmax_xent_vjp_fwd, _softmax_xent_vjp_bwd)
