//! Bit-identity pins for the allocation-free training kernels: the
//! workspace-backed, register-blocked, in-place step path
//! (`native::{train_step_into, kd_step_into, logits, eval_chunk}`) must
//! reproduce the seed's allocating scalar path (`native::reference`)
//! exactly — states, momentum and losses, bit for bit — over random
//! batches and multi-epoch schedules on both models, and the blocked
//! kernels must still pass finite-difference gradient checks. The
//! `Runtime` facade shims and the copy-on-write aliasing contract of the
//! in-place API are pinned here too.

use std::path::Path;

use marfl::models::{ArtifactMeta, ModelMeta};
use marfl::params::Theta;
use marfl::rng::Rng;
use marfl::runtime::{native, Runtime};

fn models() -> Vec<ModelMeta> {
    let meta = ArtifactMeta::builtin(Path::new("/nonexistent"));
    vec![
        meta.model("head").unwrap().clone(),
        meta.model("cnn").unwrap().clone(),
    ]
}

fn batch(m: &ModelMeta, b: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
    let x: Vec<f32> =
        (0..b * m.input_elems()).map(|_| rng.normal() as f32 * 0.7).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.below(m.classes) as i32).collect();
    (x, y)
}

/// Multi-epoch training schedule: the in-place path must track the seed
/// reference exactly at every step — theta, momentum AND loss bits —
/// across fresh random batches, both models, several (η, μ) settings.
#[test]
fn train_schedule_bit_identical_to_seed_reference() {
    for m in models() {
        for &(eta, mu) in &[(0.1f32, 0.9f32), (0.5, 0.0), (0.01, 0.99)] {
            let mut rng = Rng::new(0xE0 ^ m.classes as u64);
            let mut t_ref = native::init_params(&m).unwrap();
            let mut m_ref = vec![0.0f32; t_ref.len()];
            let mut t_inp = t_ref.clone();
            let mut m_inp = m_ref.clone();
            // 2 epochs × 3 batches
            for step in 0..6 {
                let (x, y) = batch(&m, 4, &mut rng);
                let out = native::reference::train_step(
                    &m, &t_ref, &m_ref, &x, &y, eta, mu,
                )
                .unwrap();
                let loss =
                    native::train_step_into(&m, &mut t_inp, &mut m_inp, &x, &y, eta, mu)
                        .unwrap();
                t_ref = out.theta;
                m_ref = out.momentum;
                assert_eq!(
                    out.loss.to_bits(),
                    loss.to_bits(),
                    "loss diverged at step {step} ({}, eta={eta}, mu={mu})",
                    m.name
                );
                assert_eq!(t_ref, t_inp, "theta diverged at step {step} ({})", m.name);
                assert_eq!(
                    m_ref, m_inp,
                    "momentum diverged at step {step} ({})",
                    m.name
                );
            }
        }
    }
}

/// Same pin for the KD step: random teacher logits, several λ (including
/// the CE-only λ=0 and pure-KL λ=1 ends), multi-epoch.
#[test]
fn kd_schedule_bit_identical_to_seed_reference() {
    for m in models() {
        for &lam in &[0.0f32, 0.4, 1.0] {
            let mut rng = Rng::new(0x3D ^ m.classes as u64);
            let tau = 3.0f32;
            let mut t_ref = native::init_params(&m).unwrap();
            let mut m_ref = vec![0.0f32; t_ref.len()];
            let mut t_inp = t_ref.clone();
            let mut m_inp = m_ref.clone();
            for step in 0..4 {
                let b = 4usize;
                let (x, y) = batch(&m, b, &mut rng);
                let zbar: Vec<f32> =
                    (0..b * m.classes).map(|_| rng.normal() as f32).collect();
                let out = native::reference::kd_step(
                    &m, &t_ref, &m_ref, &x, &y, &zbar, lam, tau, 0.1, 0.9,
                )
                .unwrap();
                let loss = native::kd_step_into(
                    &m, &mut t_inp, &mut m_inp, &x, &y, &zbar, lam, tau, 0.1, 0.9,
                )
                .unwrap();
                t_ref = out.theta;
                m_ref = out.momentum;
                assert_eq!(
                    out.loss.to_bits(),
                    loss.to_bits(),
                    "KD loss diverged at step {step} ({}, lam={lam})",
                    m.name
                );
                assert_eq!(t_ref, t_inp, "theta diverged ({}, lam={lam})", m.name);
                assert_eq!(m_ref, m_inp, "momentum diverged ({}, lam={lam})", m.name);
            }
        }
    }
}

/// Logits and eval through the workspace match the seed path bitwise
/// (the KD teacher-rating and evaluation routes).
#[test]
fn logits_and_eval_bit_identical_to_seed_reference() {
    for m in models() {
        let mut rng = Rng::new(0x10 ^ m.classes as u64);
        let theta = native::init_params(&m).unwrap();
        for rows in [1usize, 5, 16] {
            let (x, y) = batch(&m, rows, &mut rng);
            let z_ref = native::reference::logits(&m, &theta, &x).unwrap();
            let z_ws = native::logits(&m, &theta, &x).unwrap();
            assert_eq!(z_ref, z_ws, "logits diverged ({}, rows={rows})", m.name);
            let (l_ref, c_ref) =
                native::reference::eval_chunk(&m, &theta, &x, &y).unwrap();
            let (l_ws, c_ws) = native::eval_chunk(&m, &theta, &x, &y).unwrap();
            assert_eq!(l_ref.to_bits(), l_ws.to_bits(), "eval loss ({})", m.name);
            assert_eq!(c_ref.to_bits(), c_ws.to_bits(), "eval correct ({})", m.name);
        }
    }
}

/// Alternating models and batch sizes on ONE thread reuses one workspace
/// arena; stale buffer contents from the previous shape must never leak
/// into a result.
#[test]
fn workspace_reuse_across_models_and_shapes_is_clean() {
    let ms = models();
    let mut rng = Rng::new(0xA17);
    // interleave: head b=4, cnn b=4, head b=9, cnn b=2, head b=4 ...
    for &(mi, b) in &[(0usize, 4usize), (1, 4), (0, 9), (1, 2), (0, 4), (1, 7)] {
        let m = &ms[mi];
        let (x, y) = batch(m, b, &mut rng);
        let theta = native::init_params(m).unwrap();
        let mom = vec![0.1f32; theta.len()];
        // fresh-reference answer for exactly this call
        let want = native::reference::train_step(m, &theta, &mom, &x, &y, 0.2, 0.5)
            .unwrap();
        let mut t = theta.clone();
        let mut mo = mom.clone();
        let loss =
            native::train_step_into(m, &mut t, &mut mo, &x, &y, 0.2, 0.5).unwrap();
        assert_eq!(want.loss.to_bits(), loss.to_bits(), "loss ({} b={b})", m.name);
        assert_eq!(want.theta, t, "stale workspace leaked ({} b={b})", m.name);
        assert_eq!(want.momentum, mo, "stale momentum ({} b={b})", m.name);
    }
}

/// Finite differences against the blocked kernels' analytic gradient,
/// driven through the in-place entry directly (η=1, μ=0 ⇒ θ' = θ − g).
#[test]
fn blocked_kernel_gradients_match_finite_differences() {
    for m in models() {
        let mut rng = Rng::new(0xFD2);
        let theta = native::init_params(&m).unwrap();
        let b = 3usize;
        let (x, y) = batch(&m, b, &mut rng);
        let mut t = theta.clone();
        let mut mo = vec![0.0f32; theta.len()];
        native::train_step_into(&m, &mut t, &mut mo, &x, &y, 1.0, 0.0).unwrap();
        let grad: Vec<f32> = theta.iter().zip(&t).map(|(&a, &b)| a - b).collect();
        let loss_at = |th: &mut Vec<f32>| -> f64 {
            let mut z = vec![0.0f32; th.len()];
            native::train_step_into(&m, th, &mut z, &x, &y, 0.0, 0.0).unwrap() as f64
        };
        let eps = 2e-2f64;
        // probe a spread of parameters across the layout
        for j in (0..m.param_count).step_by(m.param_count / 7) {
            let mut tp = theta.clone();
            tp[j] += eps as f32;
            let lp = loss_at(&mut tp);
            tp[j] = theta[j] - eps as f32;
            let lm = loss_at(&mut tp);
            let fd = (lp - lm) / (2.0 * eps);
            let an = grad[j] as f64;
            assert!(
                (fd - an).abs() <= 2e-3 + 0.08 * an.abs().max(fd.abs()),
                "{} param {j}: fd {fd:.6} vs analytic {an:.6}",
                m.name
            );
        }
    }
}

/// The facade compat shims (`Runtime::train_step` / `kd_step`) are thin
/// wrappers over the in-place path: identical results, and the metrics
/// counters keep the seed's key names without per-step formatting.
#[test]
fn runtime_shims_agree_with_in_place_api_and_count_under_seed_keys() {
    let rt = Runtime::new(Path::new("/nonexistent_marfl_artifacts")).unwrap();
    let m = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(0xFA);
    let x: Vec<f32> =
        (0..m.batch * m.input_elems()).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..m.batch).map(|i| (i % m.classes) as i32).collect();
    let theta = rt.init_params("head").unwrap();
    let mom = vec![0.0f32; theta.len()];

    let out = rt.train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
    let mut t = theta.clone();
    let mut mo = mom.clone();
    let loss = rt.train_step_into(&m, &mut t, &mut mo, &x, &y, 0.1, 0.9).unwrap();
    assert_eq!(out.theta, t);
    assert_eq!(out.momentum, mo);
    assert_eq!(out.loss.to_bits(), loss.to_bits());

    let zbar = vec![0.0f32; m.batch * m.classes];
    let kout =
        rt.kd_step(&m, &theta, &mom, &x, &y, &zbar, 0.5, 0.1, 0.9).unwrap();
    let mut kt = theta.clone();
    let mut kmo = mom.clone();
    let kloss = rt
        .kd_step_into(&m, &mut kt, &mut kmo, &x, &y, &zbar, 0.5, 0.1, 0.9)
        .unwrap();
    assert_eq!(kout.theta, kt);
    assert_eq!(kout.loss.to_bits(), kloss.to_bits());

    let mut zbuf = Vec::new();
    rt.logits_into(&m, &theta, &x, &mut zbuf).unwrap();
    assert_eq!(zbuf, rt.logits(&m, &theta, &x).unwrap());

    // seed-compatible counter keys: shim + in-place both count once
    let counts = rt.call_counts();
    assert_eq!(counts["head_train_step"], 2);
    assert_eq!(counts["head_kd_step"], 2);
    assert_eq!(counts["head_logits"], 2);
}

/// The in-place step through `Theta::make_mut_slice` detaches from
/// aliasing snapshots exactly once and never perturbs them — the
/// copy-on-write contract the MKD teacher snapshots rely on.
#[test]
fn in_place_step_preserves_snapshot_aliasing_contract() {
    let rt = Runtime::new(Path::new("/nonexistent_marfl_artifacts")).unwrap();
    let m = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(0xA5);
    let x: Vec<f32> =
        (0..m.batch * m.input_elems()).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..m.batch).map(|i| (i % m.classes) as i32).collect();

    let mut theta = Theta::new(rt.init_params("head").unwrap());
    let mut momentum = Theta::zeros(theta.len());
    let snapshot = theta.clone();
    let frozen = snapshot.to_vec();
    assert!(theta.shares_storage(&snapshot));

    rt.train_step_into(
        &m,
        theta.make_mut_slice(),
        momentum.make_mut_slice(),
        &x,
        &y,
        0.1,
        0.9,
    )
    .unwrap();
    // the write detached the student; the snapshot is bitwise frozen
    assert!(!theta.shares_storage(&snapshot));
    assert_eq!(snapshot, frozen);
    assert_ne!(theta.as_slice(), frozen.as_slice());

    // a second step mutates the now-unique buffer in place
    let before = theta.as_slice().as_ptr();
    rt.train_step_into(
        &m,
        theta.make_mut_slice(),
        momentum.make_mut_slice(),
        &x,
        &y,
        0.1,
        0.9,
    )
    .unwrap();
    assert_eq!(theta.as_slice().as_ptr(), before, "unique step must not move");
}
