//! Compile-time stub of the vendored `xla` PJRT bindings.
//!
//! The real PJRT backend needs the XLA closure (a multi-GB native
//! toolchain — see EXPERIMENTS.md §Backends). This stub carries just
//! enough of the binding surface that `cargo check --features pjrt`
//! compiles everywhere, so CI can guard `runtime/pjrt.rs` against
//! bit-rot without shipping XLA. Literals are fully functional host-side
//! byte buffers (the `runtime/literal.rs` round-trip tests pass against
//! them); anything that would dispatch to a real PJRT client returns a
//! descriptive error at run time, which the marfl runtime surfaces as a
//! failed backend construction (`MARFL_BACKEND=native` keeps working).
//!
//! To enable real execution, replace this directory with the actual
//! bindings (or `[patch]` the `xla` dependency) — the API below mirrors
//! the subset `runtime/pjrt.rs` uses.

use std::fmt;

/// Binding error; every stubbed dispatch entry point returns one.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Self {
        Error(format!(
            "xla stub: {what} requires the real PJRT bindings \
             (vendor them over rust/vendor/xla to enable execution)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the MAR-FL artifacts use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_size(self) -> usize {
        4
    }
}

/// Host-native scalar types literals convert to.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn from_le_bytes(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

/// A host-side literal: element type + shape + raw little-endian bytes,
/// or a tuple of literals (entry points return tuples).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want: usize = dims.iter().product::<usize>() * ty.byte_size();
        if data.len() != want {
            return Err(Error(format!(
                "literal shape {dims:?} wants {want} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec(), tuple: None })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("to_vec on a tuple literal".into()));
        }
        if self.ty != T::ELEMENT_TYPE {
            return Err(Error(format!(
                "element type mismatch: literal {:?}, requested {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Destructure a tuple literal into its leaves.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple
            .ok_or_else(|| Error("to_tuple on a non-tuple literal".into()))
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Parsed HLO module (stub: retains the artifact text only).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _hlo_text_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _hlo_text_len: proto.text.len() }
    }
}

/// PJRT client. Stub: construction always fails with a descriptive
/// error, so `Runtime::new` reports a missing real backend instead of
/// silently executing nothing.
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert_eq!(lit.element_count(), 3);
        assert!(lit.to_vec::<i32>().is_err(), "dtype mismatch must fail");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let err = Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[2, 2],
            &[0u8; 4],
        );
        assert!(err.is_err());
    }

    #[test]
    fn dispatch_entry_points_report_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla stub"));
    }
}
