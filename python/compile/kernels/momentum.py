"""Fused damped-momentum SGD update Pallas kernel (L1).

MAR-FL peers update locally with the damped momentum rule of Reddi et al.
(2020):

    m' = mu * m + (1 - mu) * g
    theta' = theta - eta * m'

Done as three separate XLA ops this streams theta/m/g from HBM three times;
the fused kernel reads each strip once and writes (theta', m') once.

TPU mapping: parameters live as a flat `f32[P]` vector padded to a multiple
of `STRIP` (1024 = 8 sublanes x 128 lanes); BlockSpec strip-mines P so each
grid step is one VMEM-resident strip — a pure VPU/bandwidth kernel whose
roofline is HBM bandwidth (no MXU work). `interpret=True` on CPU.

`eta`/`mu` ride along as `f32[1]` operands so a single lowered artifact
serves every learning-rate configuration.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Strip width: 8 sublanes x 128 lanes. All flat parameter vectors are padded
# to a multiple of this at flatten time (see model.py).
STRIP = 1024


def _momentum_kernel(theta_ref, m_ref, g_ref, eta_ref, mu_ref, theta_out, m_out):
    mu = mu_ref[0]
    eta = eta_ref[0]
    m_new = mu * m_ref[...] + (1.0 - mu) * g_ref[...]
    m_out[...] = m_new
    theta_out[...] = theta_ref[...] - eta * m_new


def fused_momentum(theta: jax.Array, m: jax.Array, g: jax.Array,
                   eta: jax.Array, mu: jax.Array):
    """Apply the damped momentum update over flat padded vectors.

    Args:
      theta, m, g: `f32[P]` with `P % STRIP == 0`.
      eta, mu:     `f32[1]` scalars (learning rate, momentum).

    Returns `(theta', m')`.
    """
    (p,) = theta.shape
    assert p % STRIP == 0, f"flat vector length {p} not a multiple of {STRIP}"
    grid = (p // STRIP,)
    strip = pl.BlockSpec((STRIP,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    theta2, m2 = pl.pallas_call(
        _momentum_kernel,
        grid=grid,
        in_specs=[strip, strip, strip, scalar, scalar],
        out_specs=[strip, strip],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=True,
    )(theta, m, g, eta, mu)
    return theta2, m2
