//! Model-compute runtime. This is the ONLY place model compute happens at
//! run time — Python is never on the request path.
//!
//! Two interchangeable backends sit behind one facade:
//!
//! * **native** (default build) — a pure-Rust reference implementation of
//!   the model zoo (`native.rs`): the same forward/backward/damped-momentum
//!   semantics `python/compile/model.py` lowers, over the same
//!   flat-parameter ABI. Needs no artifacts and no XLA closure, so
//!   `cargo build && cargo test` work on any machine.
//! * **pjrt** (`--features pjrt`) — loads the AOT HLO-text artifacts and
//!   executes them through a PJRT CPU client (`pjrt.rs`), following
//!   /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//!   `XlaComputation::from_proto` → `client.compile` (cached per entry
//!   point) → `execute`. Selected automatically when the feature is on and
//!   `meta.json` exists; `MARFL_BACKEND=native` forces the fallback.
//!
//! The facade is `Sync`: the peer-parallel trainer (`fl`) drives
//! `train_step` from many `exec` pool workers at once. Native compute is
//! trivially thread-safe; the PJRT executable cache is behind locks and
//! XLA's client/executables support concurrent execution.

#[cfg(feature = "pjrt")]
pub mod literal;
pub mod native;
#[cfg(feature = "pjrt")]
mod pjrt;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use crate::models::{ArtifactMeta, ModelMeta};

/// Stripes for the call-accounting maps: enough that pool workers on the
/// peer-parallel training path effectively never contend on a lock.
const CALL_STRIPES: usize = 8;

/// Backend dispatch + per-entry-point execution accounting.
pub struct Runtime {
    pub meta: ArtifactMeta,
    backend: Backend,
    /// executions per entry point (perf accounting), striped per thread
    /// and merged at read so counting stays off the hot path's locks
    calls: [Mutex<HashMap<String, u64>>; CALL_STRIPES],
}

enum Backend {
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

/// Result of one local training / KD step. The buffers are freshly
/// owned `Vec`s, so callers move them straight into the copy-on-write
/// `params::Theta` peer state (`out.theta.into()`) — one Arc allocation,
/// no buffer copy — which is what keeps a step from ever writing through
/// storage shared with snapshots or groupmates.
#[derive(Clone, Debug)]
pub struct StepOut {
    pub theta: Vec<f32>,
    pub momentum: Vec<f32>,
    pub loss: f32,
}

impl Runtime {
    /// Open a runtime over an artifact directory. When no artifacts have
    /// been lowered there, the builtin model registry + native backend
    /// are used so the full system runs artifact-free. A *present but
    /// unreadable* `meta.json` is still a hard error — silently swapping
    /// in the builtin registry under real artifacts would execute lowered
    /// HLO against mismatched metadata.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let meta = if artifact_dir.join("meta.json").exists() {
            ArtifactMeta::load(artifact_dir)?
        } else {
            log::info!(
                "no artifacts at {artifact_dir:?}; \
                 using builtin model registry + native backend"
            );
            ArtifactMeta::builtin(artifact_dir)
        };
        let backend = Self::pick_backend(&meta)?;
        Ok(Runtime {
            meta,
            backend,
            calls: std::array::from_fn(|_| Mutex::new(HashMap::new())),
        })
    }

    #[cfg(feature = "pjrt")]
    fn pick_backend(meta: &ArtifactMeta) -> Result<Backend> {
        let forced_native = std::env::var_os("MARFL_BACKEND")
            .is_some_and(|v| v.to_str() == Some("native"));
        if !forced_native && meta.dir.join("meta.json").exists() {
            return Ok(Backend::Pjrt(pjrt::PjrtBackend::new(&meta.dir)?));
        }
        Ok(Backend::Native)
    }

    #[cfg(not(feature = "pjrt"))]
    fn pick_backend(_meta: &ArtifactMeta) -> Result<Backend> {
        Ok(Backend::Native)
    }

    /// Which backend executes compute ("native" or "pjrt").
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Native => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Load the shared initial parameters for `model` (paper: every peer
    /// starts from the same randomly initialized θ⁰). With real artifacts
    /// (`meta.json` present) the lowered `{m}_init.bin` is REQUIRED — a
    /// missing file is a hard error, not a silent swap to different
    /// initial weights. Only the builtin artifact-free registry uses the
    /// native backend's deterministic He initialization.
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let m = self.meta.model(model)?;
        if self.meta.dir.join("meta.json").exists() {
            let path = self.meta.artifact_path(&m.init_file);
            let theta = crate::util::read_f32_le(&path)?;
            anyhow::ensure!(
                theta.len() == m.padded_len,
                "{path:?}: expected {} f32, got {}",
                m.padded_len,
                theta.len()
            );
            Ok(theta)
        } else {
            native::init_params(m)
        }
    }

    /// Pre-compile a set of entry points (avoids first-use jitter in
    /// benches). No-op on the native backend.
    pub fn warmup(&self, entries: &[String]) -> Result<()> {
        match &self.backend {
            Backend::Native => Ok(()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.warmup(entries),
        }
    }

    /// Per-entry execution counts (perf diagnostics), merged across the
    /// per-thread stripes.
    pub fn call_counts(&self) -> HashMap<String, u64> {
        let mut merged = HashMap::new();
        for stripe in &self.calls {
            for (entry, n) in stripe.lock().expect("calls lock").iter() {
                *merged.entry(entry.clone()).or_insert(0) += n;
            }
        }
        merged
    }

    fn count(&self, entry: String) {
        let stripe = &self.calls[crate::exec::thread_stripe(CALL_STRIPES)];
        *stripe.lock().expect("calls lock").entry(entry).or_insert(0) += 1;
    }

    // -----------------------------------------------------------------
    // Typed entry points (flat-parameter ABI)
    // -----------------------------------------------------------------

    /// One local momentum-SGD step over a batch.
    pub fn train_step(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        eta: f32,
        mu: f32,
    ) -> Result<StepOut> {
        debug_assert_eq!(theta.len(), m.padded_len);
        debug_assert_eq!(x.len(), m.batch * m.input_elems());
        debug_assert_eq!(y.len(), m.batch);
        self.count(format!("{}_train_step", m.name));
        match &self.backend {
            Backend::Native => native::train_step(m, theta, momentum, x, y, eta, mu),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.train_step(m, theta, momentum, x, y, eta, mu),
        }
    }

    /// One Moshpit-KD student step (Algorithm 2).
    #[allow(clippy::too_many_arguments)]
    pub fn kd_step(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        zbar: &[f32],
        lambda: f32,
        eta: f32,
        mu: f32,
    ) -> Result<StepOut> {
        debug_assert_eq!(zbar.len(), m.batch * m.classes);
        self.count(format!("{}_kd_step", m.name));
        match &self.backend {
            Backend::Native => {
                // τ is baked into the lowered artifact; the native path
                // takes it from the registry
                let tau = self.meta.kd_tau as f32;
                native::kd_step(m, theta, momentum, x, y, zbar, lambda, tau, eta, mu)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => {
                b.kd_step(m, theta, momentum, x, y, zbar, lambda, eta, mu)
            }
        }
    }

    /// Teacher forward pass: logits for one training batch.
    pub fn logits(&self, m: &ModelMeta, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        self.count(format!("{}_logits", m.name));
        match &self.backend {
            Backend::Native => native::logits(m, theta, x),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.logits(m, theta, x),
        }
    }

    /// Evaluate over a full test set (x row-major, len multiple of the
    /// eval chunk). Returns (mean loss, accuracy).
    pub fn evaluate(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(f64, f64)> {
        let n = y.len();
        let elems = m.input_elems();
        anyhow::ensure!(
            n % m.eval_chunk == 0,
            "test set size {n} not a multiple of eval chunk {}",
            m.eval_chunk
        );
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for c in 0..n / m.eval_chunk {
            let xs = &x[c * m.eval_chunk * elems..(c + 1) * m.eval_chunk * elems];
            let ys = &y[c * m.eval_chunk..(c + 1) * m.eval_chunk];
            self.count(format!("{}_eval", m.name));
            let (ls, cr) = match &self.backend {
                Backend::Native => native::eval_chunk(m, theta, xs, ys)?,
                #[cfg(feature = "pjrt")]
                Backend::Pjrt(b) => b.eval_chunk(m, theta, xs, ys)?,
            };
            loss_sum += ls;
            correct += cr;
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }

    /// Average `k` stacked flat vectors through the group-mean kernel.
    /// `stack` is row-major `[k, padded_len]`.
    pub fn group_mean(&self, m: &ModelMeta, stack: &[f32], k: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            self.meta.group_sizes.contains(&k),
            "no group_mean artifact for k={k} (have {:?})",
            self.meta.group_sizes
        );
        debug_assert_eq!(stack.len(), k * m.padded_len);
        self.count(format!("group_mean_{}_{k}", m.name));
        match &self.backend {
            Backend::Native => native::group_mean(m, stack, k),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.group_mean(m, stack, k),
        }
    }
}

// Runtime's own Send/Sync derive automatically from its fields; on pjrt
// builds that hinges on the scoped `unsafe impl Send/Sync for
// PjrtBackend` in pjrt.rs (where the serialization invariant lives), so
// the compiler keeps checking every other Runtime field.

#[cfg(test)]
mod tests {
    // Full runtime execution tests live in rust/tests/runtime_integration.rs
    // (they run against whichever backend the build selects). Unit tests
    // here cover facade-only logic.
    use super::*;

    #[test]
    fn step_out_is_cloneable_value_type() {
        let s = StepOut { theta: vec![1.0], momentum: vec![0.0], loss: 0.5 };
        let t = s.clone();
        assert_eq!(t.loss, 0.5);
    }

    #[test]
    fn artifact_free_runtime_uses_native_backend() {
        let rt = Runtime::new(Path::new("/nonexistent_marfl_artifacts")).unwrap();
        assert_eq!(rt.backend_name(), "native");
        assert!(rt.meta.models.contains_key("cnn"));
        assert!(rt.meta.models.contains_key("head"));
    }

    #[test]
    fn runtime_is_sync() {
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<Runtime>();
    }
}
