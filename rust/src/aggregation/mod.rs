//! Aggregation strategies: the paper's baselines and the shared machinery
//! MAR builds on.
//!
//! All strategies implement [`Aggregate`] over flat peer states
//! (θ ‖ momentum — the paper's Moshpit-AR averages both). Communication is
//! booked byte-exactly on the fabric; one "state transfer" is
//! `2 · P_pad · 4` bytes for every technique, so cross-technique ratios
//! (the paper's headline results) are unit-independent.
//!
//! Per-iteration data-plane cost (N peers, group size M, G MAR rounds):
//!
//! | technique | state transfers | asymptotic |
//! |---|---|---|
//! | FedAvg   | 2N              | O(N)       |
//! | AR-FL    | N(N−1)          | O(N²)      |
//! | RDFL     | N(N−1)          | O(N²)      |
//! | MAR-FL   | ≤ N·G·(M−1)     | O(N log N) |

pub mod alltoall;
pub mod butterfly;
pub mod fedavg;
pub mod gossip;
pub mod ring;
pub mod saps;

pub use alltoall::AllToAll;
pub use butterfly::Butterfly;
pub use fedavg::FedAvgServer;
pub use gossip::Gossip;
pub use ring::RingRdfl;
pub use saps::Saps;

use anyhow::Result;

use crate::metrics::Plane;
use crate::models::ModelMeta;
use crate::net::Fabric;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::sim::SimClock;

/// One peer's aggregatable state: flat parameters + momentum (both length
/// `P_pad`).
#[derive(Clone, Debug)]
pub struct PeerState {
    pub theta: Vec<f32>,
    pub momentum: Vec<f32>,
}

impl PeerState {
    pub fn new(theta: Vec<f32>) -> Self {
        let momentum = vec![0.0; theta.len()];
        PeerState { theta, momentum }
    }
}

/// Wire size of one full state transfer (θ + momentum) for a plain
/// (non-DP) iteration — static per-model accounting used by the analytic
/// benches.
pub fn state_bytes(model: &ModelMeta) -> u64 {
    model.model_bytes() * 2
}

/// Actual wire size of the states being aggregated right now. During DP
/// iterations the momentum vector carries the smoothed delta and the clip
/// indicator (Algorithm 4 averages four quantities through MAR), so the
/// payload is larger than the static `state_bytes`.
pub fn payload_bytes(states: &[PeerState], members: &[usize]) -> u64 {
    let s = &states[members[0]];
    ((s.theta.len() + s.momentum.len()) * 4) as u64
}

/// Shared context threaded through an aggregation call.
pub struct AggCtx<'a> {
    pub fabric: &'a Fabric,
    pub clock: &'a mut SimClock,
    pub rng: &'a mut Rng,
    /// When present, within-group averaging runs through the Pallas
    /// `group_mean` artifact; otherwise the native f64 path is used.
    pub runtime: Option<&'a Runtime>,
    pub model: &'a ModelMeta,
}

/// What an aggregation did (for ledger-independent assertions).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AggReport {
    /// communication rounds executed
    pub rounds: usize,
    /// groups formed across all rounds (MAR) or 1 (global techniques)
    pub groups: usize,
}

/// An aggregation technique. `agg` lists the indices of peers in `A_t`
/// (participants that survived dropout); only their states may be read or
/// written.
pub trait Aggregate {
    fn name(&self) -> &'static str;

    fn aggregate(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport>;
}

// ---------------------------------------------------------------------
// Shared vector math
// ---------------------------------------------------------------------

/// Native mean of the selected peers' (θ, m), f64 accumulation. The
/// momentum vector may be longer than θ (DP packs extra averaged
/// quantities onto it); each vector is averaged at its own length.
pub fn mean_of(states: &[PeerState], members: &[usize]) -> (Vec<f32>, Vec<f32>) {
    assert!(!members.is_empty());
    let p = states[members[0]].theta.len();
    let q = states[members[0]].momentum.len();
    let mut theta = vec![0.0f64; p];
    let mut mom = vec![0.0f64; q];
    for &i in members {
        assert_eq!(states[i].theta.len(), p, "ragged theta lengths");
        assert_eq!(states[i].momentum.len(), q, "ragged momentum lengths");
        for (a, &v) in theta.iter_mut().zip(&states[i].theta) {
            *a += v as f64;
        }
        for (a, &v) in mom.iter_mut().zip(&states[i].momentum) {
            *a += v as f64;
        }
    }
    let inv = 1.0 / members.len() as f64;
    (
        theta.iter().map(|&v| (v * inv) as f32).collect(),
        mom.iter().map(|&v| (v * inv) as f32).collect(),
    )
}

/// Use the Pallas `group_mean` artifact for within-group averaging?
/// Benchmarked ablation (`micro_hotpath`): at this model scale the PJRT
/// call overhead (~0.7 ms literal marshalling + dispatch) outweighs the
/// kernel win, so the native f64 path is the default; set
/// `MARFL_PJRT_GROUP_MEAN=1` to flip (and on a real TPU backend the
/// artifact path is the one that scales). See EXPERIMENTS.md §Perf.
fn prefer_pjrt_group_mean() -> bool {
    static FLAG: once_cell::sync::Lazy<bool> = once_cell::sync::Lazy::new(|| {
        std::env::var_os("MARFL_PJRT_GROUP_MEAN").is_some()
    });
    *FLAG
}

/// Average the states of `members` and write the result back to each of
/// them. Default: native f64 accumulation; the Pallas group-mean artifact
/// is used when `MARFL_PJRT_GROUP_MEAN=1` and the shapes/group size match
/// (see `prefer_pjrt_group_mean`).
pub fn average_group(
    states: &mut [PeerState],
    members: &[usize],
    ctx: &mut AggCtx<'_>,
) -> Result<()> {
    if members.len() < 2 {
        return Ok(());
    }
    let plain_shape = states[members[0]].theta.len() == ctx.model.padded_len
        && states[members[0]].momentum.len() == ctx.model.padded_len;
    let (theta, mom) = match ctx.runtime {
        Some(rt)
            if prefer_pjrt_group_mean()
                && plain_shape
                && rt.meta.group_sizes.contains(&members.len()) =>
        {
            let p = ctx.model.padded_len;
            let mut stack = Vec::with_capacity(members.len() * p);
            for &i in members {
                stack.extend_from_slice(&states[i].theta);
            }
            let theta = rt.group_mean(ctx.model, &stack, members.len())?;
            stack.clear();
            for &i in members {
                stack.extend_from_slice(&states[i].momentum);
            }
            let mom = rt.group_mean(ctx.model, &stack, members.len())?;
            (theta, mom)
        }
        _ => mean_of(states, members),
    };
    for &i in members {
        states[i].theta.copy_from_slice(&theta);
        states[i].momentum.copy_from_slice(&mom);
    }
    Ok(())
}

/// How a Moshpit group moves its states.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupExchange {
    /// Every member sends its full state to every other member:
    /// k(k−1) transfers of `bytes` per group. Matches the accounting the
    /// paper's headline ratios imply (≈10× vs RDFL at N=125).
    FullGather,
    /// Moshpit-SGD's chunked protocol: each member owns 1/k of the
    /// vector; reduce-scatter + all-gather moves 2·(k−1)/k·bytes per
    /// member — a further (k/2)× reduction, exposed as the
    /// `mar.reduce_scatter` ablation.
    ReduceScatter,
}

/// Book one group's exchange; returns the group's simulated duration
/// (each member's sends are sequential; members operate in parallel).
pub fn book_group_exchange_mode(
    group_len: usize,
    bytes: u64,
    mode: GroupExchange,
    ctx: &mut AggCtx<'_>,
) -> f64 {
    if group_len < 2 {
        return 0.0;
    }
    let k = group_len as u64;
    match mode {
        GroupExchange::FullGather => {
            let mut per_member = 0.0f64;
            for _ in 0..group_len {
                per_member = ctx
                    .fabric
                    .sequential(group_len - 1, bytes, Plane::Data)
                    .max(per_member);
            }
            per_member
        }
        GroupExchange::ReduceScatter => {
            // 2(k−1) chunk messages of bytes/k per member
            let chunk = bytes.div_ceil(k);
            let mut per_member = 0.0f64;
            for _ in 0..group_len {
                per_member = ctx
                    .fabric
                    .sequential(2 * (group_len - 1), chunk, Plane::Data)
                    .max(per_member);
            }
            per_member
        }
    }
}

/// Back-compat: full-gather exchange.
pub fn book_group_exchange(group_len: usize, bytes: u64, ctx: &mut AggCtx<'_>) -> f64 {
    book_group_exchange_mode(group_len, bytes, GroupExchange::FullGather, ctx)
}

/// Build an `Aggregate` for a strategy (MAR is constructed separately in
/// `coordinator`, since it owns the DHT).
pub fn baseline_for(
    strategy: crate::config::Strategy,
) -> Option<Box<dyn Aggregate>> {
    use crate::config::Strategy::*;
    match strategy {
        FedAvg => Some(Box::new(FedAvgServer::default())),
        Rdfl => Some(Box::new(RingRdfl::default())),
        ArFl => Some(Box::new(AllToAll::default())),
        Bar => Some(Box::new(Butterfly::default())),
        Gossip => Some(Box::new(gossip::Gossip::default())),
        Saps => Some(Box::new(saps::Saps::default())),
        MarFl => None,
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::metrics::CommLedger;
    use std::sync::Arc;

    /// A self-owning AggCtx bundle for aggregation unit tests.
    pub struct TestCtx {
        pub ledger: Arc<CommLedger>,
        pub fabric: Fabric,
        pub clock: SimClock,
        pub rng: Rng,
        pub model: ModelMeta,
    }

    impl TestCtx {
        pub fn new(padded_len: usize) -> Self {
            let ledger = Arc::new(CommLedger::new());
            let fabric = Fabric::new(ledger.clone(), 1e6, 0.001);
            TestCtx {
                ledger,
                fabric,
                clock: SimClock::new(),
                rng: Rng::new(0xA11CE),
                model: ModelMeta {
                    name: "toy".into(),
                    param_count: padded_len,
                    padded_len,
                    input_shape: vec![4],
                    classes: 3,
                    batch: 8,
                    eval_chunk: 8,
                    init_file: String::new(),
                    artifacts: Default::default(),
                },
            }
        }

        pub fn ctx(&mut self) -> AggCtx<'_> {
            AggCtx {
                fabric: &self.fabric,
                clock: &mut self.clock,
                rng: &mut self.rng,
                runtime: None,
                model: &self.model,
            }
        }
    }

    /// Random peer states for math tests.
    pub fn random_states(n: usize, p: usize, seed: u64) -> Vec<PeerState> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| PeerState {
                theta: (0..p).map(|_| rng.normal() as f32).collect(),
                momentum: (0..p).map(|_| rng.normal() as f32 * 0.1).collect(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;

    #[test]
    fn mean_of_matches_hand_computation() {
        let states = vec![
            PeerState { theta: vec![1.0, 2.0], momentum: vec![0.0, 4.0] },
            PeerState { theta: vec![3.0, 6.0], momentum: vec![2.0, 0.0] },
        ];
        let (t, m) = mean_of(&states, &[0, 1]);
        assert_eq!(t, vec![2.0, 4.0]);
        assert_eq!(m, vec![1.0, 2.0]);
    }

    #[test]
    fn average_group_writes_back_to_all_members() {
        let mut states = random_states(5, 16, 1);
        let mut tc = TestCtx::new(16);
        let mut ctx = tc.ctx();
        let (want_t, want_m) = mean_of(&states, &[1, 3, 4]);
        average_group(&mut states, &[1, 3, 4], &mut ctx).unwrap();
        for &i in &[1, 3, 4] {
            crate::testing::assert_allclose(&states[i].theta, &want_t, 1e-6, 1e-7);
            crate::testing::assert_allclose(&states[i].momentum, &want_m, 1e-6, 1e-7);
        }
        // non-members untouched
        let fresh = random_states(5, 16, 1);
        assert_eq!(states[0].theta, fresh[0].theta);
        assert_eq!(states[2].theta, fresh[2].theta);
    }

    #[test]
    fn singleton_group_is_noop() {
        let mut states = random_states(2, 8, 2);
        let orig = states[0].theta.clone();
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        average_group(&mut states, &[0], &mut ctx).unwrap();
        assert_eq!(states[0].theta, orig);
    }

    #[test]
    fn group_exchange_books_k_times_k_minus_one_transfers() {
        let mut tc = TestCtx::new(32);
        let bytes = state_bytes(&tc.model);
        let mut ctx = tc.ctx();
        let dur = book_group_exchange(5, bytes, &mut ctx);
        assert!(dur > 0.0);
        let snap = tc.ledger.snapshot();
        assert_eq!(snap.data_msgs, 5 * 4);
        assert_eq!(snap.data_bytes, 5 * 4 * 2 * 32 * 4);
    }

    #[test]
    fn payload_bytes_tracks_extended_momentum() {
        let mut states = random_states(2, 16, 14);
        assert_eq!(payload_bytes(&states, &[0, 1]), 2 * 16 * 4);
        // DP iteration: momentum carries Δ̄ and the clip indicator
        states[0].momentum.extend_from_slice(&[0.0; 17]);
        assert_eq!(payload_bytes(&states, &[0]), (16 + 33) * 4);
    }

    #[test]
    fn state_bytes_counts_theta_and_momentum() {
        let tc = TestCtx::new(100);
        assert_eq!(state_bytes(&tc.model), 800);
    }
}
