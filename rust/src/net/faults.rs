//! Deterministic fault-injection fabric.
//!
//! Wireless-FL deployments lose messages, suffer bursty link
//! degradation, and straggle — failure modes the iteration-granular
//! churn models (`net::churn`, `net::trace`) cannot express. This module
//! provides a seeded fault model shared by every aggregation strategy:
//!
//! * **message loss** — each message is independently lost with
//!   probability `loss`; the sender times out and retries with bounded
//!   exponential backoff (retries are never free: every retransmission
//!   books payload bytes and a control-plane probe, and the timeout +
//!   backoff wall-time lands on the simulated clock);
//! * **link degradation** — a peer's links for one round run at a
//!   fraction of nominal bandwidth with a latency multiplier;
//! * **stragglers** — a peer's simulated compute lanes (local SGD,
//!   distillation) run `straggler_mult`× slower for one iteration;
//! * **crashes** — a peer dies mid-exchange; its group proceeds with a
//!   quorum of survivors and the peer rejoins stale;
//! * **bursty (Gilbert–Elliott) links** — each *directed* link carries a
//!   two-state good/bad Markov chain ([`LinkState`], transition
//!   probabilities `ge_p`/`ge_r`); while bad, messages are lost with
//!   `ge_loss` and the link runs at `ge_bw`/`ge_lat` multipliers.
//!   Retransmissions *observe* the chain — each retry advances it, so a
//!   burst must actually end before delivery succeeds (losses are
//!   time-correlated, not re-rolled i.i.d.);
//! * **heterogeneous bandwidths** — each peer draws a static capacity
//!   multiplier once per run (`bw_dist` = lognormal or uniform over
//!   `[bw_min, bw_max]`) that scales every booking it originates.
//!
//! Determinism contract: every fault is drawn *serially* (in the same
//! schedule phase that draws `DropPlan`s today) before any parallel
//! fan-out, so serial and parallel engines stay bit-identical. With all
//! knobs at their defaults the model draws **zero** random numbers and
//! every code path is bit-identical to the fault-free build. The
//! Gilbert–Elliott layer keeps the same contract one level up: with
//! `ge_p = 0` and `bw_dist = "off"`, [`FaultConfig::draw_directed`] and
//! [`FaultConfig::draw_member`] delegate bit-exactly to the i.i.d.
//! [`FaultConfig::draw_link`] / [`FaultConfig::draw_link_persistent`]
//! paths (zero extra draws), so every pre-existing faults-on pin stays
//! green.

use crate::rng::Rng;

/// Control-plane bytes booked per timeout probe / retransmit request.
pub const RETRY_CTRL_BYTES: u64 = 64;

/// Fault-model knobs. All probabilities default to 0 — the model is
/// inert (and draw-free) unless explicitly enabled.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// per-message loss probability
    pub loss: f64,
    /// per-peer per-round probability of link degradation
    pub degrade_prob: f64,
    /// bandwidth multiplier while degraded (fraction of nominal)
    pub degrade_bw: f64,
    /// latency multiplier while degraded
    pub degrade_lat: f64,
    /// per-peer per-iteration straggler probability
    pub straggler_prob: f64,
    /// compute-time multiplier for straggling peers
    pub straggler_mult: f64,
    /// per-peer per-round mid-exchange crash probability
    pub crash_prob: f64,
    /// retransmissions attempted per message before giving up
    pub max_retries: u32,
    /// seconds before a lost message is declared timed out
    pub timeout_s: f64,
    /// base backoff delay; attempt `a` waits `backoff_s · 2^a`
    pub backoff_s: f64,
    /// minimum survivors for a group to proceed quorum-degraded
    pub quorum_min: usize,
    /// Gilbert–Elliott good→bad transition probability per link advance
    /// (0 disables the chain layer entirely — zero extra draws)
    pub ge_p: f64,
    /// Gilbert–Elliott bad→good recovery probability per link advance
    pub ge_r: f64,
    /// per-message loss probability while a link is in the bad state
    /// (the good state uses `loss`)
    pub ge_loss: f64,
    /// bandwidth multiplier while a link is in the bad state
    pub ge_bw: f64,
    /// latency multiplier while a link is in the bad state
    pub ge_lat: f64,
    /// per-peer static bandwidth-capacity distribution ("off" disables)
    pub bw_dist: BwDist,
    /// lognormal shape parameter for `bw_dist = "lognormal"`
    pub bw_sigma: f64,
    /// lower bound of the per-peer capacity multiplier
    pub bw_min: f64,
    /// upper bound of the per-peer capacity multiplier
    pub bw_max: f64,
    /// re-draw the heterogeneous per-peer capacities every this many FL
    /// iterations (0 = one static draw per run, the previous behaviour,
    /// bit-identical). Re-draws come from the [`LinkState`]'s own
    /// dedicated RNG stream, so the schedule streams never move.
    pub bw_redraw_rounds: usize,
}

/// Shape of the per-peer heterogeneous-bandwidth draw. `Off` keeps every
/// peer at nominal capacity and consumes zero draws.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BwDist {
    /// homogeneous links (multiplier 1.0 everywhere, draw-free)
    #[default]
    Off,
    /// lognormal around the geometric midpoint of `[bw_min, bw_max]`,
    /// shape `bw_sigma`, clamped to the range — the classic heavy-tailed
    /// wireless-capacity shape
    LogNormal,
    /// uniform over `[bw_min, bw_max]`
    Uniform,
}

impl BwDist {
    /// Parse the `faults.bw_dist` config value.
    pub fn parse(v: &str) -> Option<BwDist> {
        match v {
            "off" => Some(BwDist::Off),
            "lognormal" => Some(BwDist::LogNormal),
            "uniform" => Some(BwDist::Uniform),
            _ => None,
        }
    }

    /// The config spelling of this variant.
    pub fn as_str(&self) -> &'static str {
        match self {
            BwDist::Off => "off",
            BwDist::LogNormal => "lognormal",
            BwDist::Uniform => "uniform",
        }
    }
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            loss: 0.0,
            degrade_prob: 0.0,
            degrade_bw: 0.25,
            degrade_lat: 4.0,
            straggler_prob: 0.0,
            straggler_mult: 4.0,
            crash_prob: 0.0,
            max_retries: 3,
            timeout_s: 0.1,
            backoff_s: 0.05,
            quorum_min: 2,
            ge_p: 0.0,
            ge_r: 0.25,
            ge_loss: 0.5,
            ge_bw: 0.25,
            ge_lat: 4.0,
            bw_dist: BwDist::Off,
            bw_sigma: 0.5,
            bw_min: 0.1,
            bw_max: 1.0,
            bw_redraw_rounds: 0,
        }
    }
}

impl FaultConfig {
    /// The inert plan — shared by every construction site that does not
    /// inject faults.
    pub const OFF: FaultConfig = FaultConfig {
        loss: 0.0,
        degrade_prob: 0.0,
        degrade_bw: 0.25,
        degrade_lat: 4.0,
        straggler_prob: 0.0,
        straggler_mult: 4.0,
        crash_prob: 0.0,
        max_retries: 3,
        timeout_s: 0.1,
        backoff_s: 0.05,
        quorum_min: 2,
        ge_p: 0.0,
        ge_r: 0.25,
        ge_loss: 0.5,
        ge_bw: 0.25,
        ge_lat: 4.0,
        bw_dist: BwDist::Off,
        bw_sigma: 0.5,
        bw_min: 0.1,
        bw_max: 1.0,
        bw_redraw_rounds: 0,
    };

    /// Any fault axis active?
    pub fn enabled(&self) -> bool {
        self.loss > 0.0
            || self.degrade_prob > 0.0
            || self.straggler_prob > 0.0
            || self.crash_prob > 0.0
            || self.time_correlated()
    }

    /// Any *link-level* axis active (loss or degradation)? Gates the
    /// per-peer link draws so a straggler-only plan stays draw-free on
    /// the exchange path.
    pub fn link_faults_enabled(&self) -> bool {
        self.loss > 0.0 || self.degrade_prob > 0.0 || self.time_correlated()
    }

    /// Gilbert–Elliott chains active? (`ge_p = 0` keeps every link
    /// pinned good with zero chain draws.)
    pub fn ge_enabled(&self) -> bool {
        self.ge_p > 0.0
    }

    /// Heterogeneous per-peer bandwidth draw active?
    pub fn hetero_bw(&self) -> bool {
        self.bw_dist != BwDist::Off
    }

    /// Does this plan need persistent per-run [`LinkState`]? Gates the
    /// state's construction (and its dedicated RNG fork) so plans
    /// without time correlation stay bit-identical to the seed.
    pub fn time_correlated(&self) -> bool {
        self.ge_enabled() || self.hetero_bw()
    }

    /// Draw one peer's link state for a round: a degradation draw, then
    /// per-message loss/retry draws for `msgs` planned messages. All
    /// randomness happens here (serial schedule phase) — applying the
    /// resulting [`LinkFault`] is draw-free.
    pub fn draw_link(&self, msgs: usize, rng: &mut Rng) -> LinkFault {
        let mut f = LinkFault::CLEAN;
        if self.degrade_prob > 0.0 && rng.chance(self.degrade_prob) {
            f.bw_mult = self.degrade_bw;
            f.lat_mult = self.degrade_lat;
        }
        if self.loss > 0.0 {
            for _ in 0..msgs {
                for attempt in 0..=self.max_retries {
                    if !rng.chance(self.loss) {
                        break;
                    }
                    if attempt < self.max_retries {
                        f.retries += 1;
                        f.penalty_s += self.timeout_s
                            + self.backoff_s * (1u64 << attempt.min(20)) as f64;
                    } else {
                        f.timeouts += 1;
                        f.penalty_s += self.timeout_s;
                    }
                }
            }
        }
        f
    }

    /// Like [`Self::draw_link`] but the sender never gives up — for
    /// protocols that cannot proceed without delivery (ring steps,
    /// butterfly segments). Only retries, never timeouts; the backoff
    /// exponent is capped at `max_retries`.
    pub fn draw_link_persistent(&self, msgs: usize, rng: &mut Rng) -> LinkFault {
        let mut f = LinkFault::CLEAN;
        if self.degrade_prob > 0.0 && rng.chance(self.degrade_prob) {
            f.bw_mult = self.degrade_bw;
            f.lat_mult = self.degrade_lat;
        }
        if self.loss > 0.0 {
            for _ in 0..msgs {
                let mut attempt = 0u32;
                while rng.chance(self.loss) {
                    f.retries += 1;
                    f.penalty_s += self.timeout_s
                        + self.backoff_s
                            * (1u64 << attempt.min(self.max_retries).min(20)) as f64;
                    attempt += 1;
                }
            }
        }
        f
    }

    /// Draw the fault outcome of `msgs` messages on the *directed* link
    /// `src → dst`, observing (and advancing) the per-link
    /// Gilbert–Elliott chain in `links` when one is active. Must be
    /// called from the serial schedule phase — it mutates the shared
    /// link state, and call order is part of the determinism contract.
    ///
    /// With `links = None` or no time-correlated axis enabled this
    /// delegates bit-exactly to [`Self::draw_link`] /
    /// [`Self::draw_link_persistent`] (same draws, same outcome), so the
    /// i.i.d. plan and all its pins are unchanged.
    pub fn draw_directed(
        &self,
        src: usize,
        dst: usize,
        msgs: usize,
        persistent: bool,
        links: Option<&mut LinkState>,
        rng: &mut Rng,
    ) -> LinkFault {
        let ls = match links {
            Some(ls) if self.time_correlated() => ls,
            _ => {
                return if persistent {
                    self.draw_link_persistent(msgs, rng)
                } else {
                    self.draw_link(msgs, rng)
                };
            }
        };
        let mut f = LinkFault::CLEAN;
        if self.degrade_prob > 0.0 && rng.chance(self.degrade_prob) {
            f.bw_mult = self.degrade_bw;
            f.lat_mult = self.degrade_lat;
        }
        let bad = self.ge_messages(&mut f, ls, src, dst, msgs, persistent, rng);
        if bad {
            f.bw_mult *= self.ge_bw;
            f.lat_mult *= self.ge_lat;
        }
        f.bw_mult *= ls.peer_bw(src);
        f
    }

    /// Draw one group member's combined link outcome: `msgs_per_dst`
    /// messages to *each* destination in `dsts`, each destination
    /// observing its own directed chain. Used by exchanges that book one
    /// aggregate [`LinkFault`] per member (MAR groups, all-to-all).
    ///
    /// With `links = None` or no time-correlated axis this delegates
    /// bit-exactly to `draw_link(msgs_per_dst · dsts.len())`.
    pub fn draw_member(
        &self,
        src: usize,
        dsts: &[usize],
        msgs_per_dst: usize,
        links: Option<&mut LinkState>,
        rng: &mut Rng,
    ) -> LinkFault {
        let ls = match links {
            Some(ls) if self.time_correlated() => ls,
            _ => return self.draw_link(msgs_per_dst * dsts.len(), rng),
        };
        let mut f = LinkFault::CLEAN;
        if self.degrade_prob > 0.0 && rng.chance(self.degrade_prob) {
            f.bw_mult = self.degrade_bw;
            f.lat_mult = self.degrade_lat;
        }
        let mut any_bad = false;
        for &dst in dsts {
            any_bad |= self
                .ge_messages(&mut f, ls, src, dst, msgs_per_dst, false, rng);
        }
        if any_bad {
            f.bw_mult *= self.ge_bw;
            f.lat_mult *= self.ge_lat;
        }
        f.bw_mult *= ls.peer_bw(src);
        f
    }

    /// Run the loss/retry loop for `msgs` messages on one directed link:
    /// advance the chain once (the round tick), then draw each message
    /// against the *current* state's loss probability, advancing the
    /// chain again after every failed attempt — a retry waits out the
    /// backoff and retransmits into whatever state the link is in by
    /// then. Returns whether the round tick found the link bad (the
    /// caller applies `ge_bw`/`ge_lat` off that observation).
    #[allow(clippy::too_many_arguments)]
    fn ge_messages(
        &self,
        f: &mut LinkFault,
        ls: &mut LinkState,
        src: usize,
        dst: usize,
        msgs: usize,
        persistent: bool,
        rng: &mut Rng,
    ) -> bool {
        let tick_bad = ls.advance(self, src, dst, rng);
        let mut bad = tick_bad;
        for _ in 0..msgs {
            if persistent {
                let mut attempt = 0u32;
                loop {
                    let p = if bad { self.ge_loss } else { self.loss };
                    if p <= 0.0 || !rng.chance(p) {
                        break;
                    }
                    if bad {
                        ls.bursty_losses += 1;
                    }
                    f.retries += 1;
                    f.penalty_s += self.timeout_s
                        + self.backoff_s
                            * (1u64 << attempt.min(self.max_retries).min(20))
                                as f64;
                    attempt += 1;
                    bad = ls.advance(self, src, dst, rng);
                }
            } else {
                for attempt in 0..=self.max_retries {
                    let p = if bad { self.ge_loss } else { self.loss };
                    if p <= 0.0 || !rng.chance(p) {
                        break;
                    }
                    if bad {
                        ls.bursty_losses += 1;
                    }
                    if attempt < self.max_retries {
                        f.retries += 1;
                        f.penalty_s += self.timeout_s
                            + self.backoff_s * (1u64 << attempt.min(20)) as f64;
                        bad = ls.advance(self, src, dst, rng);
                    } else {
                        f.timeouts += 1;
                        f.penalty_s += self.timeout_s;
                    }
                }
            }
        }
        tick_bad
    }
}

/// Per-run time-correlated link state: one two-state Gilbert–Elliott
/// chain per *directed* link plus one static capacity multiplier per
/// peer. Owned by the run (the `Trainer` keeps one across iterations,
/// gated on [`FaultConfig::time_correlated`]) and only ever touched from
/// the serial schedule phase.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkState {
    /// number of peers (chains are indexed `src · n + dst`)
    n: usize,
    /// chain states, row-major by sender; empty when `ge_p = 0`
    bad: Vec<bool>,
    /// per-peer capacity multipliers; empty when `bw_dist = "off"`
    peer_bw: Vec<f64>,
    /// dedicated stream for the slow capacity re-draws, forked only when
    /// `bw_redraw_rounds > 0` (gated — the static schedule constructs
    /// with zero extra draws)
    redraw_rng: Option<Rng>,
    /// slow-schedule capacity re-draws performed
    pub bw_redraws: u64,
    /// good→bad transitions observed (burst onsets)
    pub ge_bad_transitions: u64,
    /// message losses that happened while the link was in the bad state
    pub bursty_losses: u64,
}

impl LinkState {
    /// Initialize all chains from their stationary distribution
    /// (`P(bad) = ge_p / (ge_p + ge_r)`) and draw the per-peer capacity
    /// multipliers. Draw order is fixed (all chains row-major, then all
    /// capacities) — both engines construct the identical state.
    pub fn new(cfg: &FaultConfig, peers: usize, rng: &mut Rng) -> LinkState {
        let bad = if cfg.ge_enabled() {
            let pi_bad = cfg.ge_p / (cfg.ge_p + cfg.ge_r);
            (0..peers * peers).map(|_| rng.chance(pi_bad)).collect()
        } else {
            Vec::new()
        };
        let peer_bw = Self::draw_bw(cfg, peers, rng);
        // the re-draw stream is forked *after* the pinned construction
        // draws and only when the slow schedule is on, so
        // `bw_redraw_rounds = 0` builds the identical state with zero
        // extra draws
        let redraw_rng = (cfg.hetero_bw() && cfg.bw_redraw_rounds > 0)
            .then(|| rng.fork(1));
        LinkState {
            n: peers,
            bad,
            peer_bw,
            redraw_rng,
            bw_redraws: 0,
            ge_bad_transitions: 0,
            bursty_losses: 0,
        }
    }

    /// The per-peer capacity draw — construction and slow re-draws share
    /// it (same distribution, same draw order).
    fn draw_bw(cfg: &FaultConfig, peers: usize, rng: &mut Rng) -> Vec<f64> {
        match cfg.bw_dist {
            BwDist::Off => Vec::new(),
            BwDist::Uniform => {
                (0..peers).map(|_| rng.range_f64(cfg.bw_min, cfg.bw_max)).collect()
            }
            BwDist::LogNormal => {
                let median = (cfg.bw_min * cfg.bw_max).sqrt();
                (0..peers)
                    .map(|_| {
                        (median.ln() + cfg.bw_sigma * rng.normal())
                            .exp()
                            .clamp(cfg.bw_min, cfg.bw_max)
                    })
                    .collect()
            }
        }
    }

    /// Slow-schedule capacity re-draw (`faults.bw_redraw_rounds`): on
    /// iterations that are multiples of the schedule, every peer draws a
    /// fresh capacity multiplier from the state's dedicated stream —
    /// modelling links whose quality shifts over minutes, not per
    /// message. No-op (and draw-free) off-schedule or when the knob is 0.
    pub fn maybe_redraw(&mut self, cfg: &FaultConfig, iter: u64) {
        let every = cfg.bw_redraw_rounds as u64;
        if every == 0 || iter == 0 || iter % every != 0 {
            return;
        }
        if let Some(rng) = self.redraw_rng.as_mut() {
            let bw = Self::draw_bw(cfg, self.n, rng);
            self.peer_bw = bw;
            self.bw_redraws += 1;
        }
    }

    /// Advance the `src → dst` chain one step and return its new state
    /// (`true` = bad). Draw-free (and always good) when `ge_p = 0`.
    pub fn advance(
        &mut self,
        cfg: &FaultConfig,
        src: usize,
        dst: usize,
        rng: &mut Rng,
    ) -> bool {
        if self.bad.is_empty() {
            return false;
        }
        let i = src * self.n + dst;
        let cur = self.bad[i];
        let next =
            if cur { !rng.chance(cfg.ge_r) } else { rng.chance(cfg.ge_p) };
        if !cur && next {
            self.ge_bad_transitions += 1;
        }
        self.bad[i] = next;
        next
    }

    /// Current state of the `src → dst` chain without advancing it.
    pub fn is_bad(&self, src: usize, dst: usize) -> bool {
        !self.bad.is_empty() && self.bad[src * self.n + dst]
    }

    /// Fraction of directed links currently in the bad state.
    pub fn bad_fraction(&self) -> f64 {
        if self.bad.is_empty() {
            return 0.0;
        }
        self.bad.iter().filter(|&&b| b).count() as f64 / self.bad.len() as f64
    }

    /// Peer `src`'s static capacity multiplier (1.0 when `bw_dist` off).
    pub fn peer_bw(&self, src: usize) -> f64 {
        self.peer_bw.get(src).copied().unwrap_or(1.0)
    }

    /// `[p10, p50, p90]` of the per-peer capacity multipliers, `None`
    /// when the heterogeneous-bandwidth draw is off.
    pub fn bw_percentiles(&self) -> Option<[f64; 3]> {
        if self.peer_bw.is_empty() {
            return None;
        }
        let mut v = self.peer_bw.clone();
        v.sort_by(f64::total_cmp);
        let pick = |q: f64| {
            v[((q * (v.len() - 1) as f64).round() as usize).min(v.len() - 1)]
        };
        Some([pick(0.1), pick(0.5), pick(0.9)])
    }
}

/// One peer's pre-drawn link state for one round: degradation
/// multipliers plus the total retry/timeout outcome of its planned
/// messages. Applying it (via `Fabric::send_faulty` /
/// `Fabric::sequential_faulty`) is deterministic and draw-free.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFault {
    /// bandwidth multiplier (1.0 = nominal)
    pub bw_mult: f64,
    /// latency multiplier (1.0 = nominal)
    pub lat_mult: f64,
    /// retransmissions that eventually succeeded
    pub retries: u64,
    /// messages abandoned after `max_retries` retransmissions
    pub timeouts: u64,
    /// timeout + backoff wall-time accumulated by the loss draws
    pub penalty_s: f64,
}

impl LinkFault {
    pub const CLEAN: LinkFault = LinkFault {
        bw_mult: 1.0,
        lat_mult: 1.0,
        retries: 0,
        timeouts: 0,
        penalty_s: 0.0,
    };

    /// No observable deviation from a fault-free link — the fabric
    /// delegates to its exact legacy cost path in this case.
    pub fn is_clean(&self) -> bool {
        self.retries == 0
            && self.timeouts == 0
            && self.bw_mult == 1.0
            && self.lat_mult == 1.0
    }

    /// Did any message on this link die for good?
    pub fn lost(&self) -> bool {
        self.timeouts > 0
    }

    /// The same link with loss outcomes stripped: degradation
    /// multipliers survive, retries/timeouts/penalty reset. Used when a
    /// recovery path re-plans traffic (quorum-degraded gather) — the
    /// link stays slow but we do not re-roll losses, which would cascade.
    pub fn degraded_only(&self) -> LinkFault {
        LinkFault {
            bw_mult: self.bw_mult,
            lat_mult: self.lat_mult,
            ..LinkFault::CLEAN
        }
    }
}

/// Aggregated fault outcomes for one run / one report. All-`u64` so the
/// containing `AggReport` keeps `Copy + Eq`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// messages that failed at least one transmission (retries + timeouts)
    pub msgs_lost: u64,
    /// retransmissions that eventually delivered
    pub retries: u64,
    /// messages abandoned after the retry budget
    pub timeouts: u64,
    /// groups that proceeded with a survivor quorum
    pub quorum_degraded_rounds: u64,
    /// peers crashed mid-exchange
    pub crashes: u64,
    /// Gilbert–Elliott good→bad transitions (burst onsets) observed by
    /// the run's [`LinkState`]
    pub ge_bad_transitions: u64,
    /// message losses that struck while the link was in the bad state
    pub bursty_losses: u64,
}

impl FaultCounters {
    /// Fold one drawn link into the totals.
    pub fn absorb(&mut self, f: &LinkFault) {
        self.msgs_lost += f.retries + f.timeouts;
        self.retries += f.retries;
        self.timeouts += f.timeouts;
    }

    /// Merge another counter set (e.g. per-round into per-run).
    pub fn add(&mut self, other: FaultCounters) {
        self.msgs_lost += other.msgs_lost;
        self.retries += other.retries;
        self.timeouts += other.timeouts;
        self.quorum_degraded_rounds += other.quorum_degraded_rounds;
        self.crashes += other.crashes;
        self.ge_bad_transitions += other.ge_bad_transitions;
        self.bursty_losses += other.bursty_losses;
    }

    pub fn any(&self) -> bool {
        *self != FaultCounters::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert_and_draw_free() {
        let cfg = FaultConfig::default();
        assert!(!cfg.enabled());
        assert!(!cfg.link_faults_enabled());
        let mut rng = Rng::new(1);
        let before = rng.next_u64();
        let mut rng = Rng::new(1);
        let f = cfg.draw_link(10, &mut rng);
        assert!(f.is_clean());
        // zero draws consumed: the next value matches a fresh stream
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn off_const_matches_default() {
        assert_eq!(FaultConfig::OFF, FaultConfig::default());
    }

    #[test]
    fn certain_loss_exhausts_retry_budget() {
        let cfg = FaultConfig { loss: 1.0, ..FaultConfig::default() };
        let mut rng = Rng::new(2);
        let f = cfg.draw_link(3, &mut rng);
        // every message burns max_retries retries then times out
        assert_eq!(f.retries, 3 * cfg.max_retries as u64);
        assert_eq!(f.timeouts, 3);
        assert!(f.lost());
        // penalty: per message, retries wait timeout+backoff·2^a, the
        // final timeout waits timeout only
        let mut expect = 0.0;
        for _ in 0..3 {
            for a in 0..cfg.max_retries {
                expect += cfg.timeout_s + cfg.backoff_s * (1u64 << a) as f64;
            }
            expect += cfg.timeout_s;
        }
        assert!((f.penalty_s - expect).abs() < 1e-12);
    }

    #[test]
    fn persistent_links_never_time_out() {
        let cfg = FaultConfig { loss: 0.6, ..FaultConfig::default() };
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let f = cfg.draw_link_persistent(4, &mut rng);
            assert_eq!(f.timeouts, 0);
            assert!(!f.lost());
        }
    }

    #[test]
    fn degraded_only_strips_loss_outcomes() {
        let cfg = FaultConfig {
            loss: 1.0,
            degrade_prob: 1.0,
            ..FaultConfig::default()
        };
        let mut rng = Rng::new(4);
        let f = cfg.draw_link(2, &mut rng);
        assert!(f.lost());
        let d = f.degraded_only();
        assert_eq!(d.retries, 0);
        assert_eq!(d.timeouts, 0);
        assert_eq!(d.penalty_s, 0.0);
        assert_eq!(d.bw_mult, cfg.degrade_bw);
        assert_eq!(d.lat_mult, cfg.degrade_lat);
        assert!(!d.is_clean());
    }

    #[test]
    fn ge_off_directed_delegates_bit_exactly() {
        // ge_p = 0 and bw_dist off: draw_directed/draw_member must equal
        // the i.i.d. draws bit for bit, whether or not a LinkState is
        // supplied, consuming the identical number of draws
        let cfg = FaultConfig {
            loss: 0.3,
            degrade_prob: 0.2,
            ..FaultConfig::default()
        };
        assert!(!cfg.time_correlated());
        let mut ls = LinkState::new(&cfg, 8, &mut Rng::new(9));
        for persistent in [false, true] {
            let mut a = Rng::new(42);
            let mut b = Rng::new(42);
            for (src, dst) in [(0usize, 1usize), (3, 7), (5, 5)] {
                let legacy = if persistent {
                    cfg.draw_link_persistent(4, &mut a)
                } else {
                    cfg.draw_link(4, &mut a)
                };
                let directed = cfg.draw_directed(
                    src,
                    dst,
                    4,
                    persistent,
                    Some(&mut ls),
                    &mut b,
                );
                assert_eq!(legacy, directed);
            }
            assert_eq!(a.next_u64(), b.next_u64(), "draw counts diverged");
        }
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let legacy = cfg.draw_link(6, &mut a);
        let member = cfg.draw_member(2, &[0, 1, 3], 2, Some(&mut ls), &mut b);
        assert_eq!(legacy, member);
        assert_eq!(a.next_u64(), b.next_u64());
        assert_eq!(ls.ge_bad_transitions, 0);
        assert_eq!(ls.bursty_losses, 0);
    }

    #[test]
    fn ge_chain_reaches_stationary_bad_fraction() {
        let cfg = FaultConfig {
            ge_p: 0.1,
            ge_r: 0.3,
            ..FaultConfig::default()
        };
        let mut rng = Rng::new(11);
        let mut ls = LinkState::new(&cfg, 2, &mut rng);
        let steps = 40_000usize;
        let mut bad_steps = 0usize;
        for _ in 0..steps {
            if ls.advance(&cfg, 0, 1, &mut rng) {
                bad_steps += 1;
            }
        }
        let want = cfg.ge_p / (cfg.ge_p + cfg.ge_r);
        let got = bad_steps as f64 / steps as f64;
        assert!(
            (got - want).abs() < 0.02,
            "empirical bad fraction {got:.3} vs stationary {want:.3}"
        );
        assert!(ls.ge_bad_transitions > 0);
    }

    #[test]
    fn bad_links_are_slow_and_bursty() {
        // a link pinned bad (ge_r ≈ 0 over the horizon) must apply the
        // bad-state multipliers and lose at ge_loss, not loss
        let cfg = FaultConfig {
            loss: 0.0,
            ge_p: 1.0,
            ge_r: 1e-12,
            ge_loss: 1.0,
            ..FaultConfig::default()
        };
        let mut rng = Rng::new(13);
        let mut ls = LinkState::new(&cfg, 2, &mut rng);
        let f = cfg.draw_directed(0, 1, 1, false, Some(&mut ls), &mut rng);
        // certain loss in the bad state: full retry budget then timeout
        assert_eq!(f.retries, cfg.max_retries as u64);
        assert_eq!(f.timeouts, 1);
        assert_eq!(f.bw_mult, cfg.ge_bw);
        assert_eq!(f.lat_mult, cfg.ge_lat);
        assert_eq!(ls.bursty_losses, (cfg.max_retries + 1) as u64);
    }

    #[test]
    fn retries_observe_the_chain_until_the_burst_ends() {
        // bad state loses every message, good state none: a persistent
        // sender keeps retrying exactly until the chain recovers, so
        // every loss is a bursty loss
        let cfg = FaultConfig {
            loss: 0.0,
            ge_p: 0.4,
            ge_r: 0.35,
            ge_loss: 1.0,
            ..FaultConfig::default()
        };
        let mut rng = Rng::new(17);
        let mut ls = LinkState::new(&cfg, 2, &mut rng);
        let mut total = LinkFault::CLEAN;
        for _ in 0..200 {
            let f = cfg.draw_directed(0, 1, 1, true, Some(&mut ls), &mut rng);
            total.retries += f.retries;
            assert_eq!(f.timeouts, 0, "persistent links never give up");
            // delivery only ever happens from the good state, so the
            // chain must be good once the draw returns
            assert!(!ls.is_bad(0, 1));
        }
        assert!(total.retries > 0, "bursts must have forced retries");
        assert_eq!(
            ls.bursty_losses, total.retries,
            "every loss happened inside a burst"
        );
    }

    #[test]
    fn hetero_bw_scales_within_bounds_and_reports_percentiles() {
        for dist in [BwDist::Uniform, BwDist::LogNormal] {
            let cfg = FaultConfig {
                bw_dist: dist,
                bw_min: 0.2,
                bw_max: 0.9,
                ..FaultConfig::default()
            };
            assert!(cfg.time_correlated() && !cfg.ge_enabled());
            let mut rng = Rng::new(19);
            let mut ls = LinkState::new(&cfg, 64, &mut rng);
            for p in 0..64 {
                let bw = ls.peer_bw(p);
                assert!((0.2..=0.9).contains(&bw), "peer {p} bw {bw}");
            }
            let [p10, p50, p90] = ls.bw_percentiles().unwrap();
            assert!(p10 <= p50 && p50 <= p90);
            // a loss-free hetero plan draws nothing per link but still
            // scales the sender's bandwidth
            let f = cfg.draw_directed(3, 4, 5, false, Some(&mut ls), &mut rng);
            assert_eq!(f.bw_mult, ls.peer_bw(3));
            assert_eq!(f.retries + f.timeouts, 0);
            assert!(LinkState::new(&FaultConfig::OFF, 4, &mut rng)
                .bw_percentiles()
                .is_none());
        }
    }

    #[test]
    fn bw_redraw_follows_slow_schedule() {
        let cfg = FaultConfig {
            bw_dist: BwDist::Uniform,
            bw_min: 0.2,
            bw_max: 0.9,
            bw_redraw_rounds: 3,
            ..FaultConfig::default()
        };
        let caps = |ls: &LinkState| (0..16).map(|p| ls.peer_bw(p)).collect::<Vec<_>>();
        let mut ls = LinkState::new(&cfg, 16, &mut Rng::new(23));
        let initial = caps(&ls);
        // off-schedule iterations change nothing (and draw nothing)
        ls.maybe_redraw(&cfg, 1);
        ls.maybe_redraw(&cfg, 2);
        assert_eq!(ls.bw_redraws, 0);
        assert_eq!(caps(&ls), initial);
        // on-schedule: fresh capacities, still within bounds
        ls.maybe_redraw(&cfg, 3);
        assert_eq!(ls.bw_redraws, 1);
        let redrawn = caps(&ls);
        assert_ne!(redrawn, initial);
        for bw in &redrawn {
            assert!((0.2..=0.9).contains(bw));
        }
        // deterministic: a second run replays the identical stream
        let mut ls2 = LinkState::new(&cfg, 16, &mut Rng::new(23));
        ls2.maybe_redraw(&cfg, 3);
        assert_eq!(ls, ls2);
        // static schedule: identical construction draws (the re-draw
        // fork is gated), no re-draws ever
        let static_cfg =
            FaultConfig { bw_redraw_rounds: 0, ..cfg.clone() };
        let mut ls3 = LinkState::new(&static_cfg, 16, &mut Rng::new(23));
        assert_eq!(caps(&ls3), initial);
        ls3.maybe_redraw(&static_cfg, 3);
        assert_eq!(ls3.bw_redraws, 0);
        assert_eq!(caps(&ls3), initial);
    }

    #[test]
    fn bw_dist_parses_and_round_trips() {
        for dist in [BwDist::Off, BwDist::LogNormal, BwDist::Uniform] {
            assert_eq!(BwDist::parse(dist.as_str()), Some(dist));
        }
        assert_eq!(BwDist::parse("pareto"), None);
    }

    #[test]
    fn counters_absorb_and_add() {
        let mut c = FaultCounters::default();
        let f = LinkFault { retries: 2, timeouts: 1, ..LinkFault::CLEAN };
        c.absorb(&f);
        assert_eq!(c.msgs_lost, 3);
        assert_eq!(c.retries, 2);
        assert_eq!(c.timeouts, 1);
        let mut total = FaultCounters::default();
        total.add(c);
        total.add(c);
        assert_eq!(total.msgs_lost, 6);
        assert!(total.any());
        assert!(!FaultCounters::default().any());
    }
}
