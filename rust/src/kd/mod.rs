//! Moshpit-KD (paper §2.2, Algorithms 2 & 3).
//!
//! During the first K FL iterations, each MKD round `g`:
//!
//! 1. forms candidate-teacher groups with the same DHT matchmaking MAR
//!    uses (`MarAggregator::form_groups_once`), exchanging *models* within
//!    each group (θ only — the extra per-iteration load Figure 2 charges);
//! 2. each student rates every candidate teacher by the KL divergence
//!    between their softened output distributions on the student's own
//!    local batch (Algorithm 3) and keeps the top-ℓ (ρ_ℓ = 0.4) — the
//!    selective-sharing defence against non-iid teacher noise (Shao et
//!    al. 2024);
//! 3. the student distills from the averaged top-ℓ ensemble logits over E
//!    local epochs with loss L = (1−λ)·CE + λ·τ²·KL, λ = max(0, 1−(t−1)/K)
//!    decaying linearly so MKD hands over to plain MAR training.

use anyhow::Result;

use crate::aggregation::{AggCtx, PeerState};
use crate::config::KdConfig;
use crate::coordinator::MarAggregator;
use crate::data::{Dataset, Shard};
use crate::metrics::Plane;
use crate::models::ModelMeta;
use crate::runtime::Runtime;

/// What one MKD pass did.
#[derive(Clone, Copy, Debug, Default)]
pub struct KdReport {
    pub rounds: usize,
    /// teacher-model transfers booked on the data plane
    pub teacher_transfers: u64,
    /// distillation steps executed
    pub kd_steps: u64,
    /// mean student loss over the last round (diagnostic)
    pub mean_loss: f64,
}

/// Moshpit-KD engine.
pub struct KdEngine {
    pub cfg: KdConfig,
    tau: f32,
    eta: f32,
    mu: f32,
}

impl KdEngine {
    pub fn new(cfg: KdConfig, tau: f64, eta: f32, mu: f32) -> Self {
        KdEngine { cfg, tau: tau as f32, eta, mu }
    }

    /// Is MKD active in FL iteration `t` (1-based)?
    pub fn active(&self, t: usize) -> bool {
        self.cfg.enabled && t <= self.cfg.k_iterations
    }

    /// KL weight λ_t = max(0, 1 − (t−1)/K) (paper Eq. 4 with
    /// α = λ).
    pub fn lambda(&self, t: usize) -> f32 {
        let k = self.cfg.k_iterations.max(1) as f32;
        (1.0 - (t.saturating_sub(1)) as f32 / k).max(0.0)
    }

    /// Top-ℓ teacher count for `candidates` candidates (at least 1).
    pub fn top_ell(&self, candidates: usize) -> usize {
        ((candidates as f64 * self.cfg.rho_ell).round() as usize)
            .clamp(1, candidates)
    }

    /// Run the full MKD pass for FL iteration `t` (Algorithm 2 over all
    /// MKD rounds). Teacher exchange is booked on the data plane; the DHT
    /// matchmaking books its own control traffic.
    #[allow(clippy::too_many_arguments)]
    pub fn run_mkd(
        &self,
        t: usize,
        rt: &Runtime,
        model: &ModelMeta,
        data: &Dataset,
        shards: &mut [Shard],
        states: &mut [PeerState],
        agg: &[usize],
        mar: &mut MarAggregator,
        ctx: &mut AggCtx<'_>,
    ) -> Result<KdReport> {
        let mut report = KdReport { rounds: mar.rounds, ..Default::default() };
        let lam = self.lambda(t);
        let model_bytes = model.model_bytes();
        for g in 0..mar.rounds {
            let groups =
                mar.form_groups_once(agg, ctx.rng, &format!("kd:{t}:{g}"));
            let mut lane_times = Vec::with_capacity(groups.len());
            let mut loss_acc = 0.0f64;
            let mut loss_n = 0u64;
            for group in &groups {
                if group.len() < 2 {
                    lane_times.push(0.0);
                    continue;
                }
                let members: Vec<usize> =
                    group.iter().map(|&pos| agg[pos]).collect();
                // teacher-model full-gather: θ only, k(k-1) transfers
                let mut lane = 0.0f64;
                for _ in &members {
                    lane = ctx
                        .fabric
                        .sequential(members.len() - 1, model_bytes, Plane::Data)
                        .max(lane);
                }
                lane_times.push(lane);
                report.teacher_transfers +=
                    (members.len() * (members.len() - 1)) as u64;
                // snapshot round-start models (all students distill from
                // the same teacher parameters θ_c^{g-1})
                let snapshot: Vec<Vec<f32>> =
                    members.iter().map(|&p| states[p].theta.clone()).collect();
                for (si, &student) in members.iter().enumerate() {
                    let batch_idx = shards[student].next_batch(model.batch);
                    let (x, y) = data.gather(&batch_idx);
                    let s_logits = rt.logits(model, &snapshot[si], &x)?;
                    // rate candidate teachers by softened KL on this batch
                    // (logits cached for the ensemble average below)
                    let mut rated: Vec<(f64, Vec<f32>)> = Vec::new();
                    for (ci, _c) in members.iter().enumerate() {
                        if ci == si {
                            continue;
                        }
                        let z = rt.logits(model, &snapshot[ci], &x)?;
                        let kl = mean_softened_kl(
                            &z,
                            &s_logits,
                            model.classes,
                            self.tau,
                        );
                        rated.push((kl, z));
                    }
                    rated.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    let ell = self.top_ell(rated.len());
                    rated.truncate(ell);
                    // z̄_b = mean of selected teacher logits
                    let mut zbar = vec![0.0f32; model.batch * model.classes];
                    for (_, z) in &rated {
                        for (a, &v) in zbar.iter_mut().zip(z) {
                            *a += v;
                        }
                    }
                    let inv = 1.0 / rated.len().max(1) as f32;
                    for a in &mut zbar {
                        *a *= inv;
                    }
                    // E local distillation epochs
                    for _ in 0..self.cfg.epochs {
                        let out = rt.kd_step(
                            model,
                            &states[student].theta,
                            &states[student].momentum,
                            &x,
                            &y,
                            &zbar,
                            lam,
                            self.eta,
                            self.mu,
                        )?;
                        states[student].theta = out.theta;
                        states[student].momentum = out.momentum;
                        loss_acc += out.loss as f64;
                        loss_n += 1;
                        report.kd_steps += 1;
                    }
                }
            }
            ctx.clock.parallel(lane_times);
            if loss_n > 0 {
                report.mean_loss = loss_acc / loss_n as f64;
            }
        }
        Ok(report)
    }
}

/// Mean over the batch of KL(softmax(z/τ) ‖ softmax(s/τ)) — Algorithm 3's
/// teacher rating. Computed natively: logits are tiny ([B, C]) and this
/// runs inside the per-student selection loop.
pub fn mean_softened_kl(
    teacher: &[f32],
    student: &[f32],
    classes: usize,
    tau: f32,
) -> f64 {
    assert_eq!(teacher.len(), student.len());
    assert!(classes > 0 && teacher.len() % classes == 0);
    let rows = teacher.len() / classes;
    let mut total = 0.0f64;
    for r in 0..rows {
        let zt = &teacher[r * classes..(r + 1) * classes];
        let zs = &student[r * classes..(r + 1) * classes];
        let lt = log_softmax(zt, tau);
        let ls = log_softmax(zs, tau);
        let mut kl = 0.0f64;
        for c in 0..classes {
            let pt = lt[c].exp();
            kl += pt * (lt[c] - ls[c]);
        }
        total += kl;
    }
    total / rows as f64
}

fn log_softmax(z: &[f32], tau: f32) -> Vec<f64> {
    let scaled: Vec<f64> = z.iter().map(|&v| (v / tau) as f64).collect();
    let max = scaled.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let lse = scaled.iter().map(|&v| (v - max).exp()).sum::<f64>().ln() + max;
    scaled.iter().map(|&v| v - lse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(k: usize, rho: f64) -> KdEngine {
        KdEngine::new(
            KdConfig { enabled: true, k_iterations: k, rho_ell: rho, epochs: 1 },
            3.0,
            0.1,
            0.9,
        )
    }

    #[test]
    fn lambda_decays_linearly_to_zero() {
        let e = engine(8, 0.4);
        assert_eq!(e.lambda(1), 1.0);
        assert!((e.lambda(5) - 0.5).abs() < 1e-6);
        assert_eq!(e.lambda(9), 0.0);
        assert_eq!(e.lambda(100), 0.0);
    }

    #[test]
    fn active_window_is_first_k_iterations() {
        let e = engine(6, 0.4);
        assert!(e.active(1));
        assert!(e.active(6));
        assert!(!e.active(7));
        let disabled = KdEngine::new(KdConfig::default(), 3.0, 0.1, 0.9);
        assert!(!disabled.active(1));
    }

    #[test]
    fn top_ell_matches_paper_ratio() {
        let e = engine(8, 0.4);
        assert_eq!(e.top_ell(4), 2); // 40% of 4 candidates
        assert_eq!(e.top_ell(5), 2);
        assert_eq!(e.top_ell(1), 1); // never zero teachers
        assert_eq!(e.top_ell(10), 4);
    }

    #[test]
    fn kl_zero_for_identical_logits() {
        let z = vec![1.0f32, -2.0, 0.5, 3.0, 0.0, 1.0];
        assert!(mean_softened_kl(&z, &z, 3, 3.0).abs() < 1e-12);
    }

    #[test]
    fn kl_positive_and_orders_similarity() {
        let student = vec![2.0f32, 0.0, 0.0];
        let close = vec![1.8f32, 0.1, 0.0];
        let far = vec![-3.0f32, 4.0, 0.0];
        let kl_close = mean_softened_kl(&close, &student, 3, 3.0);
        let kl_far = mean_softened_kl(&far, &student, 3, 3.0);
        assert!(kl_close > 0.0);
        assert!(kl_far > kl_close, "{kl_far} vs {kl_close}");
    }

    #[test]
    fn higher_temperature_softens_divergence() {
        let a = vec![5.0f32, 0.0];
        let b = vec![0.0f32, 5.0];
        let kl_t1 = mean_softened_kl(&a, &b, 2, 1.0);
        let kl_t5 = mean_softened_kl(&a, &b, 2, 5.0);
        assert!(kl_t5 < kl_t1, "τ=5 {kl_t5} should soften vs τ=1 {kl_t1}");
    }
}
