//! MAR coordination — the paper's system contribution.
//!
//! * [`group_key`] — the Moshpit d-dimensional key schedule (exact grid /
//!   random init, reduced keys, chunk-index updates, no-revisit).
//! * [`mar`] — the [`mar::MarAggregator`]: DHT matchmaking + iterative
//!   group averaging implementing `aggregation::Aggregate`.
//! * [`mixing`] — Eq. 1 mixing model and its Monte-Carlo validation.

pub mod group_key;
pub mod mar;
pub mod mixing;

pub use group_key::{grid_keys, perfect_grid, random_keys, GroupKey};
pub use mar::{AggOptions, MarAggregator};
