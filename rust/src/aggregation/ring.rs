//! RDFL — Ring Decentralized FL (Hu et al. 2020, Galaxy FL).
//!
//! Full models circulate a closed ring: in each of the N−1 ring steps every
//! peer forwards the state it just received to its successor, accumulating
//! a running sum; after the walk each peer holds the exact global average.
//! Total traffic N(N−1) state transfers — the O(N²) cost the paper reports
//! (orders of magnitude above FedAvg) — and the closed topology is why RDFL
//! cannot tolerate churn mid-round (here: the ring is re-formed from `A_t`
//! each iteration; a dropout *during* a walk would stall it, which the
//! paper cites as RDFL's weakness).

use anyhow::Result;

use super::{payload_bytes, AggCtx, AggReport, Aggregate, PeerState};
use crate::metrics::Plane;
use crate::net::FaultCounters;

#[derive(Debug, Default)]
pub struct RingRdfl;

impl Aggregate for RingRdfl {
    fn name(&self) -> &'static str {
        "rdfl"
    }

    fn aggregate(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport> {
        let fp = ctx.faults;
        let mut faults = FaultCounters::default();
        // fault plan: a crashed peer would stall the walk, so the ring
        // re-forms from the survivors before it starts — mid-walk the
        // closed topology has no recovery, which is exactly the
        // churn-intolerance the paper cites (draws gated: the fault-free
        // path consumes no randomness)
        let live: Vec<usize> = if fp.crash_prob > 0.0 {
            agg.iter()
                .copied()
                .filter(|_| {
                    if ctx.rng.chance(fp.crash_prob) {
                        faults.crashes += 1;
                        false
                    } else {
                        true
                    }
                })
                .collect()
        } else {
            agg.to_vec()
        };
        let agg = &live[..];
        let n = agg.len();
        if n < 2 {
            return Ok(AggReport { faults, ..Default::default() });
        }
        let p = states[agg[0]].theta.len();
        let q = states[agg[0]].momentum.len(); // may exceed p under DP
        let bytes = payload_bytes(states, agg);

        // running f64 sums per ring slot; slot r accumulates the states it
        // has seen so far while they travel the ring
        let mut sum_t = vec![vec![0.0f64; p]; n];
        let mut sum_m = vec![vec![0.0f64; q]; n];
        for (slot, &peer) in agg.iter().enumerate() {
            for (a, &v) in sum_t[slot].iter_mut().zip(&states[peer].theta) {
                *a += v as f64;
            }
            for (a, &v) in sum_m[slot].iter_mut().zip(&states[peer].momentum) {
                *a += v as f64;
            }
        }
        // N-1 ring steps: every peer sends its *current carried state* to
        // its successor; all links are active in parallel per step
        let link_on = fp.link_faults_enabled();
        for step in 1..n {
            let mut lane_times = Vec::with_capacity(n);
            for slot in 0..n {
                if link_on {
                    // the ring cannot drop a message — the sender retries
                    // until delivery (persistent link), so losses cost
                    // retransmitted bytes and backoff time, never data.
                    // Every step reuses the same directed successor link,
                    // so a Gilbert–Elliott burst on it stalls consecutive
                    // steps (the chain is observed, not redrawn).
                    let lf = fp.draw_directed(
                        agg[slot],
                        agg[(slot + 1) % n],
                        1,
                        true,
                        ctx.links.as_deref_mut(),
                        ctx.rng,
                    );
                    faults.absorb(&lf);
                    lane_times
                        .push(ctx.fabric.send_faulty(bytes, Plane::Data, &lf));
                } else {
                    lane_times.push(ctx.fabric.send(bytes, Plane::Data));
                }
            }
            ctx.clock.parallel(lane_times);
            // slot r receives the original state of the peer (r - step)
            for slot in 0..n {
                let src = agg[(slot + n - step) % n];
                for (a, &v) in sum_t[slot].iter_mut().zip(&states[src].theta) {
                    *a += v as f64;
                }
                for (a, &v) in sum_m[slot].iter_mut().zip(&states[src].momentum) {
                    *a += v as f64;
                }
            }
        }
        let inv = 1.0 / n as f64;
        for (slot, &peer) in agg.iter().enumerate() {
            // fresh storage per slot: the old handle may be shared (a
            // previous iteration's broadcast), so build rather than CoW
            states[peer].theta =
                sum_t[slot].iter().map(|&s| (s * inv) as f32).collect();
            states[peer].momentum =
                sum_m[slot].iter().map(|&s| (s * inv) as f32).collect();
        }
        Ok(AggReport { rounds: n - 1, groups: 1, faults, ..Default::default() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::test_support::*;
    use crate::aggregation::mean_of;

    #[test]
    fn ring_walk_yields_exact_global_average() {
        let mut states = random_states(7, 24, 7);
        let agg: Vec<usize> = (0..7).collect();
        let (want_t, want_m) = mean_of(&states, &agg);
        let mut tc = TestCtx::new(24);
        let mut ctx = tc.ctx();
        RingRdfl.aggregate(&mut states, &agg, &mut ctx).unwrap();
        for s in &states {
            crate::testing::assert_allclose(&s.theta, &want_t, 1e-5, 1e-6);
            crate::testing::assert_allclose(&s.momentum, &want_m, 1e-5, 1e-6);
        }
    }

    #[test]
    fn books_n_times_n_minus_one_transfers() {
        let n = 9;
        let mut states = random_states(n, 16, 8);
        let agg: Vec<usize> = (0..n).collect();
        let mut tc = TestCtx::new(16);
        let mut ctx = tc.ctx();
        let rep = RingRdfl.aggregate(&mut states, &agg, &mut ctx).unwrap();
        assert_eq!(rep.rounds, n - 1);
        let snap = tc.ledger.snapshot();
        assert_eq!(snap.data_msgs as usize, n * (n - 1));
    }

    #[test]
    fn ring_over_subset_only() {
        let mut states = random_states(6, 8, 9);
        let untouched = states[4].theta.clone();
        let agg = vec![0, 2, 5];
        let (want_t, _) = mean_of(&states, &agg);
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        RingRdfl.aggregate(&mut states, &agg, &mut ctx).unwrap();
        crate::testing::assert_allclose(&states[5].theta, &want_t, 1e-5, 1e-6);
        assert_eq!(states[4].theta, untouched);
    }

    #[test]
    fn two_peer_ring() {
        let mut states = random_states(2, 8, 10);
        let (want_t, _) = mean_of(&states, &[0, 1]);
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        RingRdfl.aggregate(&mut states, &[0, 1], &mut ctx).unwrap();
        crate::testing::assert_allclose(&states[0].theta, &want_t, 1e-5, 1e-6);
    }
}
