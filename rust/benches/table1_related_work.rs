//! Table 1 — related-work feature matrix, made executable.
//!
//! The paper positions MAR-FL against RDFL, SAPS and BrainTorrent on five
//! qualitative axes. All of those systems are implemented in this repo, so
//! the table's *quantitative core* — how fast does one iteration's
//! communication mix information globally? — can be measured: for every
//! strategy, run one aggregation round over 125 dispersed peers and report
//! (bytes spent, distortion removed, bytes per decade of mixing).
//!
//! Shapes asserted: gossip/SAPS spend little but barely mix (no global
//! aggregation — their Table-1 gap); RDFL/AR-FL mix exactly but at O(N²)
//! cost; MAR-FL mixes exactly at O(N log N); BAR is byte-optimal but
//! leaves the non-2^k remainder entirely unmixed.

#[path = "common/mod.rs"]
mod common;

use common::{SynthBundle, assert_stable_columns, emit_bench_report, emit_csv, mib};
use marfl::aggregation::{
    Aggregate, AllToAll, Butterfly, FedAvgServer, Gossip, RingRdfl, Saps,
};
use marfl::coordinator::mixing::avg_distortion;
use marfl::coordinator::MarAggregator;

const N: usize = 125;
const P: usize = 18432;

fn run(which: &str) -> (u64, f64, f64) {
    let mut b = SynthBundle::new(P);
    let mut states = b.states(N);
    let agg: Vec<usize> = (0..N).collect();
    let thetas = |st: &[marfl::aggregation::PeerState]| {
        st.iter().map(|s| s.theta.clone()).collect::<Vec<_>>()
    };
    let before = avg_distortion(&thetas(&states));
    let mut mar;
    let mut fedavg;
    let mut gossip = Gossip::default();
    let mut saps = Saps::default();
    let aggregator: &mut dyn Aggregate = match which {
        "marfl" => {
            mar = MarAggregator::new(N, 5, 3, b.ledger.clone(), 80);
            b.ledger.reset();
            &mut mar
        }
        "fedavg" => {
            fedavg = FedAvgServer::default();
            &mut fedavg
        }
        "rdfl" => &mut RingRdfl,
        "arfl" => &mut AllToAll,
        "bar" => &mut Butterfly,
        "gossip" => &mut gossip,
        "saps" => &mut saps,
        _ => unreachable!(),
    };
    let mut ctx = b.ctx();
    aggregator.aggregate(&mut states, &agg, &mut ctx).unwrap();
    let after = avg_distortion(&thetas(&states));
    let bytes = b.ledger.snapshot().data_bytes;
    (bytes, before, after)
}

fn main() {
    println!(
        "Table 1 (executable) — one aggregation round over {N} dispersed peers\n"
    );
    println!(
        "{:<10} {:>12} {:>14} {:>14} {:>18}",
        "strategy", "data(MiB)", "distortion", "residual %", "global agg?"
    );
    let mut rows = vec![vec![
        "strategy".into(),
        "data_bytes".into(),
        "distortion_before".into(),
        "distortion_after".into(),
    ]];
    let mut residual = std::collections::BTreeMap::new();
    let mut bytes_map = std::collections::BTreeMap::new();
    for which in ["fedavg", "marfl", "bar", "rdfl", "arfl", "gossip", "saps"] {
        let (bytes, before, after) = run(which);
        let resid = after / before * 100.0;
        println!(
            "{which:<10} {:>12.1} {before:>7.3}→{after:<6.3} {resid:>13.2}% {:>18}",
            mib(bytes),
            if resid < 1.0 { "exact/near" } else { "NO (local only)" }
        );
        rows.push(vec![
            which.into(),
            bytes.to_string(),
            format!("{before:.5}"),
            format!("{after:.5}"),
        ]);
        residual.insert(which, resid);
        bytes_map.insert(which, bytes);
    }
    assert_stable_columns(
        "table1_related_work.csv",
        &rows,
        &[
            "strategy",
            "data_bytes",
            "distortion_before",
            "distortion_after",
        ],
    );
    emit_csv("table1_related_work.csv", &rows);
    emit_bench_report("related_work", "related_work", &rows);

    // ---- Table-1 shape assertions ------------------------------------
    // global-aggregation systems: near-zero residual in ONE iteration
    for s in ["marfl", "fedavg", "rdfl", "arfl"] {
        assert!(residual[s] < 0.1, "{s} should mix (near-)exactly: {}", residual[s]);
    }
    // gossip & SAPS: cheap but no global aggregation — large residual
    for s in ["gossip", "saps"] {
        assert!(
            residual[s] > 20.0,
            "{s} must show the no-global-aggregation gap: {}",
            residual[s]
        );
        assert!(bytes_map[s] < bytes_map["marfl"], "{s} should be cheap");
    }
    // BAR: exact for its 2^k subset, but 61/125 peers keep full distortion
    assert!(
        residual["bar"] > 5.0,
        "BAR leaves the non-power-of-two remainder unmixed: {}",
        residual["bar"]
    );
    println!(
        "\nTable 1 shape holds: only MAR-FL combines global aggregation with \
         sub-quadratic bytes ({}x below RDFL).",
        bytes_map["rdfl"] / bytes_map["marfl"].max(1)
    );
}
