//! Rényi-DP accountant (Mironov 2017) for the Gaussian mechanism.
//!
//! Each DP iteration releases a clipped, noised delta with sensitivity
//! C and noise std σ_mult·C — a Gaussian mechanism with effective noise
//! multiplier σ_mult, whose RDP is ε(α) = α / (2σ²). Iterations compose
//! additively in RDP; conversion to (ε, δ)-DP uses
//! ε = min_α [ ε_RDP(α) + log(1/δ)/(α−1) ].
//!
//! The paper fixes the peer-sampling rate at 100%, so no subsampling
//! amplification applies (its discussion of reducing ε via lower sampling
//! rates is future work there and here).

/// Accumulates RDP over iterations (supports per-iteration σ).
#[derive(Clone, Debug, Default)]
pub struct RdpAccountant {
    /// accumulated ε_RDP(α) per α in `ALPHAS`
    rdp: Vec<f64>,
    steps: usize,
}

/// Evaluation orders: dense low range + geometric high range.
fn alphas() -> Vec<f64> {
    let mut a: Vec<f64> = (2..64).map(|i| 1.0 + i as f64 * 0.25).collect();
    let mut x = 20.0;
    while x <= 2048.0 {
        a.push(x);
        x *= 1.5;
    }
    a
}

impl RdpAccountant {
    pub fn new() -> Self {
        RdpAccountant { rdp: vec![0.0; alphas().len()], steps: 0 }
    }

    /// Account one Gaussian release with noise multiplier `sigma`.
    pub fn step(&mut self, sigma: f64) {
        assert!(sigma > 0.0);
        for (acc, alpha) in self.rdp.iter_mut().zip(alphas()) {
            *acc += alpha / (2.0 * sigma * sigma);
        }
        self.steps += 1;
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Convert accumulated RDP to (ε, δ)-DP.
    pub fn epsilon(&self, delta: f64) -> f64 {
        assert!(delta > 0.0 && delta < 1.0);
        self.rdp
            .iter()
            .zip(alphas())
            .map(|(&rdp, alpha)| rdp + (1.0 / delta).ln() / (alpha - 1.0))
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_zero_steps_is_conversion_overhead_only() {
        let acc = RdpAccountant::new();
        // with no releases, ε is just min_α log(1/δ)/(α−1) — small but > 0
        let eps = acc.epsilon(1e-5);
        assert!(eps > 0.0 && eps < 0.01, "{eps}");
    }

    #[test]
    fn epsilon_decreases_with_noise() {
        let mut low = RdpAccountant::new();
        let mut high = RdpAccountant::new();
        for _ in 0..50 {
            low.step(0.5);
            high.step(2.0);
        }
        assert!(high.epsilon(1e-5) < low.epsilon(1e-5));
    }

    #[test]
    fn epsilon_grows_sublinearly_in_iterations() {
        // RDP composition: ε(T) ~ sqrt(T) for fixed δ (strong composition)
        let mut a = RdpAccountant::new();
        for _ in 0..100 {
            a.step(1.0);
        }
        let e100 = a.epsilon(1e-5);
        for _ in 0..300 {
            a.step(1.0);
        }
        let e400 = a.epsilon(1e-5);
        assert!(e400 > e100);
        assert!(
            e400 < 4.0 * e100,
            "composition should be sublinear: {e100} -> {e400}"
        );
        assert!(
            e400 > 1.5 * e100,
            "quadrupling iterations must raise ε substantially"
        );
    }

    #[test]
    fn known_magnitude_sanity() {
        // σ=1.0, T=100, δ=1e-5, sampling rate 1 (no amplification):
        // ε = min_α [ 50α + ln(1e5)/(α−1) ] ≈ 50·1.48 + 11.5/0.48 ≈ 98
        let mut a = RdpAccountant::new();
        for _ in 0..100 {
            a.step(1.0);
        }
        let eps = a.epsilon(1e-5);
        assert!(eps > 90.0 && eps < 110.0, "ε = {eps}");
    }
}
