"""Pure-jnp oracles for every Pallas kernel (L1 correctness references).

pytest checks each kernel against these under hypothesis-driven shape/seed
sweeps (python/tests/test_kernels.py). Keep these boring and obviously
correct — they are the ground truth.
"""

import jax
import jax.numpy as jnp


def softmax_xent_ref(logits: jax.Array, onehot: jax.Array):
    """Per-example cross-entropy and dlogits, plain jnp."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.sum(onehot * logp, axis=-1)
    dz = jax.nn.softmax(logits, axis=-1) - onehot
    return loss, dz


def momentum_ref(theta, m, g, eta, mu):
    """Damped momentum update (Reddi et al. 2020), plain jnp."""
    m_new = mu * m + (1.0 - mu) * g
    return theta - eta * m_new, m_new


def group_mean_ref(stack):
    """Mean over the peer axis of a [k, S] stack."""
    return jnp.mean(stack, axis=0)
