//! Moshpit group keys (Ryabinin et al. 2021, adopted by MAR-FL §2.2).
//!
//! Each aggregating peer holds a d-dimensional index vector
//! `C_i ∈ [M]^d`. In MAR round `g`, peers whose keys agree on every
//! coordinate *except* position `g` form a group; after the group
//! averages, each member overwrites coordinate `g` with its chunk index
//! (its rank inside the group). Two consequences:
//!
//! * **no-revisit** — members of a round-`g` group get pairwise-distinct
//!   `c_g`, so they can never share a group again this iteration;
//! * **exactness** — when `|A_t| = M^d` and keys are initialized as the
//!   base-M digits of each peer's rank, the G = d rounds realize a
//!   d-dimensional hypercube/torus all-reduce: every peer ends with the
//!   exact global average (paper: 125 = 5³ ⇒ 3 rounds).
//!
//! For general `|A_t|` keys are drawn uniformly from `[M]^d`; groups that
//! collide beyond size M are split, averaging becomes approximate and
//! converges across iterations per Eq. 1 (see `mixing.rs`).

use crate::rng::Rng;

/// One peer's d-dimensional group key.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GroupKey {
    coords: Vec<u16>,
    m: usize,
}

impl GroupKey {
    pub fn new(coords: Vec<u16>, m: usize) -> Self {
        assert!(coords.iter().all(|&c| (c as usize) < m));
        GroupKey { coords, m }
    }

    pub fn dims(&self) -> usize {
        self.coords.len()
    }

    pub fn coord(&self, i: usize) -> u16 {
        self.coords[i]
    }

    /// The matchmaking key for `round`: every coordinate except position
    /// `round mod d`, rendered as a stable string for DHT content
    /// addressing.
    pub fn reduced(&self, round: usize) -> String {
        let skip = round % self.dims();
        let mut s = String::with_capacity(self.dims() * 3);
        for (i, c) in self.coords.iter().enumerate() {
            if i == skip {
                s.push_str("*.");
            } else {
                s.push_str(&format!("{c}."));
            }
        }
        s
    }

    /// Post-averaging update: coordinate `round mod d` becomes the peer's
    /// chunk index within its group.
    pub fn set_chunk(&mut self, round: usize, chunk: usize) {
        assert!(chunk < self.m, "chunk {chunk} out of range (M={})", self.m);
        let d = self.dims();
        self.coords[round % d] = chunk as u16;
    }
}

/// Exact-grid key assignment: peer `rank`'s key is the base-M digit
/// expansion of `rank` (least significant digit first). Valid whenever
/// `count <= M^d`.
pub fn grid_keys(count: usize, m: usize, d: usize) -> Vec<GroupKey> {
    assert!(m >= 2 && d >= 1);
    assert!(
        count <= m.pow(d as u32),
        "{count} peers do not fit an {m}^{d} grid"
    );
    (0..count)
        .map(|rank| {
            let mut coords = Vec::with_capacity(d);
            let mut r = rank;
            for _ in 0..d {
                coords.push((r % m) as u16);
                r /= m;
            }
            GroupKey::new(coords, m)
        })
        .collect()
}

/// Uniform random key assignment for imperfect peer counts.
pub fn random_keys(count: usize, m: usize, d: usize, rng: &mut Rng) -> Vec<GroupKey> {
    (0..count)
        .map(|_| {
            GroupKey::new((0..d).map(|_| rng.below(m) as u16).collect(), m)
        })
        .collect()
}

/// Is an exact M^d grid available for this aggregator count?
pub fn perfect_grid(count: usize, m: usize, d: usize) -> bool {
    m.checked_pow(d as u32).map_or(false, |c| c == count)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_keys_enumerate_digits() {
        let keys = grid_keys(8, 2, 3);
        assert_eq!(keys[0].coords, vec![0, 0, 0]);
        assert_eq!(keys[1].coords, vec![1, 0, 0]);
        assert_eq!(keys[5].coords, vec![1, 0, 1]);
        assert_eq!(keys[7].coords, vec![1, 1, 1]);
    }

    #[test]
    fn grid_round_g_groups_have_m_members() {
        // group peers by reduced key for each round; every group must have
        // exactly M members on a perfect grid
        let m = 3;
        let d = 3;
        let keys = grid_keys(27, m, d);
        for round in 0..d {
            let mut by_key = std::collections::BTreeMap::<String, usize>::new();
            for k in &keys {
                *by_key.entry(k.reduced(round)).or_default() += 1;
            }
            assert_eq!(by_key.len(), 9);
            assert!(by_key.values().all(|&c| c == m));
        }
    }

    #[test]
    fn reduced_key_masks_exactly_one_coordinate() {
        let k = GroupKey::new(vec![1, 2, 3], 5);
        assert_eq!(k.reduced(0), "*.2.3.");
        assert_eq!(k.reduced(1), "1.*.3.");
        assert_eq!(k.reduced(2), "1.2.*.");
        assert_eq!(k.reduced(3), "*.2.3."); // wraps mod d
    }

    #[test]
    fn set_chunk_changes_only_target_round() {
        let mut k = GroupKey::new(vec![4, 0, 2], 5);
        k.set_chunk(1, 3);
        assert_eq!(k.coords, vec![4, 3, 2]);
    }

    #[test]
    fn perfect_grid_detection() {
        assert!(perfect_grid(125, 5, 3));
        assert!(perfect_grid(16, 4, 2));
        assert!(!perfect_grid(125, 3, 4));
        assert!(!perfect_grid(124, 5, 3));
    }

    #[test]
    fn random_keys_in_range() {
        let mut rng = Rng::new(1);
        let keys = random_keys(100, 3, 4, &mut rng);
        for k in keys {
            assert_eq!(k.dims(), 4);
            assert!(k.coords.iter().all(|&c| c < 3));
        }
    }

    #[test]
    #[should_panic(expected = "do not fit")]
    fn grid_overflow_rejected() {
        grid_keys(9, 2, 3);
    }
}
