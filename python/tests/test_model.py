"""L2 correctness: model entry points (shapes, learning behaviour, KD, ABI)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.momentum import STRIP


def _toy_batch(name, n, seed=0):
    """Linearly separable-ish synthetic batch for learning-sanity tests."""
    spec = M.MODELS[name]
    r = np.random.default_rng(seed)
    y = r.integers(0, spec.classes, n)
    if name == "cnn":
        x = r.normal(0, 0.3, (n, 16, 16, 1))
        for i, c in enumerate(y):
            x[i, c, :, 0] += 2.0  # class-indexed bright row
    else:
        x = r.normal(0, 0.3, (n, 64))
        for i, c in enumerate(y):
            x[i, c % 64] += 3.0
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


@pytest.mark.parametrize("name", ["cnn", "head"])
def test_flat_info_padding(name):
    p, p_pad, unflatten = M.flat_info(name)
    assert p_pad % STRIP == 0
    assert p <= p_pad < p + STRIP
    # round-trip
    flat = M.init_flat(name)
    assert flat.shape == (p_pad,)
    params = unflatten(flat[:p])
    flat2, _ = jax.flatten_util.ravel_pytree(params)
    np.testing.assert_array_equal(np.asarray(flat[:p]), np.asarray(flat2))
    # padding is zero
    np.testing.assert_array_equal(np.asarray(flat[p:]), 0.0)


@pytest.mark.parametrize("name", ["cnn", "head"])
def test_forward_shapes(name):
    spec = M.MODELS[name]
    params = M.init_params(name)
    x, _ = _toy_batch(name, spec.batch)
    z = M.forward(name, params, x)
    assert z.shape == (spec.batch, spec.classes)
    assert np.isfinite(np.asarray(z)).all()


@pytest.mark.parametrize("name", ["cnn", "head"])
def test_train_step_reduces_loss(name):
    spec = M.MODELS[name]
    step = jax.jit(M.make_train_step(name))
    theta = M.init_flat(name)
    mom = jnp.zeros_like(theta)
    x, y = _toy_batch(name, spec.batch)
    eta = jnp.asarray([0.1], jnp.float32)
    mu = jnp.asarray([0.9], jnp.float32)
    losses = []
    for _ in range(25):
        theta, mom, loss = step(theta, mom, x, y, eta, mu)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses
    assert np.isfinite(losses).all()


@pytest.mark.parametrize("name", ["cnn", "head"])
def test_train_step_keeps_padding_zero(name):
    """Gradient padding is zero, so the padded tail must never move —
    the Rust aggregation layer relies on this (flat-ABI invariant)."""
    spec = M.MODELS[name]
    p, p_pad, _ = M.flat_info(name)
    step = jax.jit(M.make_train_step(name))
    theta = M.init_flat(name)
    mom = jnp.zeros_like(theta)
    x, y = _toy_batch(name, spec.batch)
    for _ in range(3):
        theta, mom, _ = step(theta, mom, x, y,
                             jnp.asarray([0.1], jnp.float32),
                             jnp.asarray([0.9], jnp.float32))
    np.testing.assert_array_equal(np.asarray(theta[p:]), 0.0)
    np.testing.assert_array_equal(np.asarray(mom[p:]), 0.0)


@pytest.mark.parametrize("name", ["cnn", "head"])
def test_eval_step_counts(name):
    spec = M.MODELS[name]
    ev = jax.jit(M.make_eval_step(name))
    theta = M.init_flat(name)
    x, y = _toy_batch(name, spec.eval_chunk)
    loss_sum, correct = ev(theta, x, y)
    assert 0.0 <= float(correct) <= spec.eval_chunk
    assert float(loss_sum) > 0.0
    # untrained model ~ chance accuracy
    assert float(correct) / spec.eval_chunk < 0.5


@pytest.mark.parametrize("name", ["cnn", "head"])
def test_logits_matches_forward(name):
    spec = M.MODELS[name]
    lg = jax.jit(M.make_logits(name))
    theta = M.init_flat(name)
    x, _ = _toy_batch(name, spec.batch)
    p, _, unflatten = M.flat_info(name)
    np.testing.assert_allclose(
        np.asarray(lg(theta, x)),
        np.asarray(M.forward(name, unflatten(theta[:p]), x)),
        rtol=1e-5, atol=1e-5,
    )


@pytest.mark.parametrize("name", ["head"])
def test_kd_step_lam_zero_equals_train_step(name):
    """With lam = 0 the KD loss collapses to plain CE: kd_step must
    reproduce train_step bit-for-bit-ish."""
    spec = M.MODELS[name]
    train = jax.jit(M.make_train_step(name))
    kd = jax.jit(M.make_kd_step(name))
    theta = M.init_flat(name)
    mom = jnp.zeros_like(theta)
    x, y = _toy_batch(name, spec.batch)
    zbar = jnp.zeros((spec.batch, spec.classes), jnp.float32)
    eta = jnp.asarray([0.1], jnp.float32)
    mu = jnp.asarray([0.9], jnp.float32)
    t1, m1, l1 = train(theta, mom, x, y, eta, mu)
    t2, m2, l2 = kd(theta, mom, x, y, zbar, jnp.asarray([0.0], jnp.float32),
                    eta, mu)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_kd_step_pulls_student_toward_teacher():
    """With lam = 1 (pure distillation) the student's logits move toward
    the teacher ensemble distribution."""
    name = "head"
    spec = M.MODELS[name]
    kd = jax.jit(M.make_kd_step(name))
    lg = jax.jit(M.make_logits(name))
    theta = M.init_flat(name)
    mom = jnp.zeros_like(theta)
    x, y = _toy_batch(name, spec.batch, seed=5)
    # teacher prefers class 7 strongly
    zbar = jnp.zeros((spec.batch, spec.classes), jnp.float32).at[:, 7].set(8.0)
    tau = M.KD_TAU

    def kl_to_teacher(theta):
        s = lg(theta, x)
        pt = jax.nn.softmax(zbar / tau, -1)
        return float(jnp.mean(jnp.sum(
            pt * (jax.nn.log_softmax(zbar / tau, -1) -
                  jax.nn.log_softmax(s / tau, -1)), -1)))

    before = kl_to_teacher(theta)
    for _ in range(10):
        theta, mom, _ = kd(theta, mom, x, y, zbar,
                           jnp.asarray([1.0], jnp.float32),
                           jnp.asarray([0.1], jnp.float32),
                           jnp.asarray([0.9], jnp.float32))
    after = kl_to_teacher(theta)
    assert after < before * 0.8, (before, after)


def test_models_registry_consistent():
    for name, spec in M.MODELS.items():
        assert spec.name == name
        assert spec.batch % 8 == 0, "batch must align with kernel BLOCK_B"
