//! Figure 9 extension — Byzantine resilience of robust group
//! aggregation.
//!
//! Sweeps the ground-truth attacker fraction {0, 0.1, 0.2, 0.3} under a
//! per-iteration sign-flip attack (attack::AttackPlan) across the four
//! group-center estimators (aggregation::robust): plain `mean` (the
//! bit-exact legacy path, no defence), coordinate-wise `trimmed_mean`
//! and `median`, and `norm_clip`. Robust estimators additionally run
//! reputation-gated matchmaking (coordinator::mar bans persistent
//! outliers from future groups); the undefended mean runs without it,
//! as the vulnerable baseline.
//!
//! Emits `fig9_byzantine.csv` and `BENCH_byz.json`. The shape gate
//! encodes the robustness claim: at 30% sign-flip the trimmed-mean +
//! reputation run keeps its final loss within 2x the attack-free run
//! while the plain mean ends up measurably worse than the defended run.
//! `MARFL_BENCH_FULL=1` lengthens the sweep; `MARFL_BENCH_NO_ASSERT=1`
//! records results without enforcing the gate.

#[path = "common/mod.rs"]
mod common;

use common::{emit_csv, iters, mib, results_dir, runtime, timed};
use marfl::aggregation::robust::RobustEstimator;
use marfl::attack::{AttackConfig, AttackMode};
use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;
use marfl::metrics::write_json;
use marfl::util::json::{arr, num, obj, s};

/// EWMA reputation ban threshold used by every defended cell.
const REP: f64 = 0.4;

fn attack_plan(frac: f64, est: RobustEstimator) -> AttackConfig {
    AttackConfig {
        frac,
        mode: AttackMode::SignFlip,
        scale: 1.0,
        robust: est,
        trim: 0.25,
        // plain mean is the undefended baseline; every robust estimator
        // also gets reputation-gated matchmaking. Attack-free rows run
        // without reputation so the mean cell stays on the bit-exact
        // legacy path and the zero-counter gate below is meaningful.
        rep_threshold: if est == RobustEstimator::Mean || frac == 0.0 {
            0.0
        } else {
            REP
        },
        ..AttackConfig::default()
    }
}

fn main() {
    let peers = 16; // 4^2 MAR grid; 30% -> 5 ground-truth attackers
    let t = iters(10, 30);
    println!(
        "Byzantine resilience — sign-flip fraction sweep x estimator \
         (peers={peers}, T={t})\n"
    );
    let rt = runtime();
    let base = ExperimentConfig {
        model: "head".into(),
        peers,
        group_size: 4,
        mar_rounds: 2, // 16 = 4^2
        iterations: t,
        samples_per_peer: 32,
        test_samples: 1000,
        eval_every: t,
        seed: 20261,
        ..Default::default()
    };

    let estimators = [
        RobustEstimator::Mean,
        RobustEstimator::TrimmedMean,
        RobustEstimator::Median,
        RobustEstimator::NormClip,
    ];
    let fracs = [0.0f64, 0.1, 0.2, 0.3];

    let mut rows = vec![vec![
        "estimator".into(),
        "frac".into(),
        "rep_threshold".into(),
        "attackers_active".into(),
        "flagged_peers".into(),
        "flag_precision".into(),
        "flag_recall".into(),
        "data_mib".into(),
        "final_accuracy".into(),
        "final_loss".into(),
        "loss_ratio".into(),
    ]];
    let mut json_rows = Vec::new();
    // (estimator, frac) -> final loss, for the shape gate
    let mut losses = std::collections::BTreeMap::new();
    let mut clean_loss = f64::NAN;

    for &est in &estimators {
        for &frac in &fracs {
            let atk = attack_plan(frac, est);
            let label = format!("{} frac={frac}", est.name());
            let cfg = ExperimentConfig { attack: atk.clone(), ..base.clone() };
            let run = timed(&label, || {
                Trainer::new(cfg, &rt).unwrap().run().unwrap()
            });
            if est == RobustEstimator::Mean && frac == 0.0 {
                clean_loss = run.final_loss;
            }
            let ratio = run.final_loss / clean_loss;
            println!(
                "    acc {:.3}  loss {:.3} ({ratio:.2}x clean)  \
                 attackers {}  flagged {} (P {:.2} R {:.2})",
                run.final_accuracy,
                run.final_loss,
                run.attackers_active,
                run.flagged_peers,
                run.flag_precision,
                run.flag_recall
            );
            rows.push(vec![
                est.name().into(),
                frac.to_string(),
                atk.rep_threshold.to_string(),
                run.attackers_active.to_string(),
                run.flagged_peers.to_string(),
                format!("{:.4}", run.flag_precision),
                format!("{:.4}", run.flag_recall),
                format!("{:.3}", mib(run.comm.data_bytes)),
                format!("{:.4}", run.final_accuracy),
                format!("{:.4}", run.final_loss),
                format!("{ratio:.4}"),
            ]);
            json_rows.push(obj(vec![
                ("estimator", s(est.name())),
                ("frac", num(frac)),
                ("rep_threshold", num(atk.rep_threshold)),
                ("attackers_active", num(run.attackers_active as f64)),
                ("flagged_peers", num(run.flagged_peers as f64)),
                ("flag_precision", num(run.flag_precision)),
                ("flag_recall", num(run.flag_recall)),
                ("data_bytes", num(run.comm.data_bytes as f64)),
                ("final_accuracy", num(run.final_accuracy)),
                ("final_loss", num(run.final_loss)),
                ("loss_ratio", num(ratio)),
            ]));
            // attack-off rows must be indistinguishable from the seed:
            // no ground-truth attackers, nothing flagged. This is the
            // zero-overhead contract CI pins at fixed seeds.
            if frac == 0.0 {
                assert_eq!(
                    run.attackers_active, 0,
                    "attack-off row recorded attackers ({label})"
                );
                assert_eq!(
                    run.flagged_peers, 0,
                    "attack-off row flagged peers ({label})"
                );
            } else {
                assert!(
                    run.attackers_active > 0,
                    "attacked row recorded no active attackers ({label})"
                );
            }
            losses
                .insert((est.name(), (frac * 10.0).round() as u32), run.final_loss);
        }
    }
    emit_csv("fig9_byzantine.csv", &rows);

    let doc = obj(vec![
        ("bench", s("byzantine")),
        ("peers", num(peers as f64)),
        ("iterations", num(t as f64)),
        ("mode", s("sign_flip")),
        ("rep_threshold", num(REP)),
        ("results", arr(json_rows)),
    ]);
    let path = results_dir().join("BENCH_byz.json");
    write_json(&path, &doc).expect("write BENCH_byz.json");
    println!("  -> {}", path.display());

    // ---- paper-shape assertion -------------------------------------
    // At 30% sign-flip the defended run (trimmed mean + reputation)
    // must stay within 2x the attack-free loss, and the undefended
    // plain mean must end up strictly worse than the defended run —
    // the distortion the robust path exists to remove.
    let mean_03 = losses[&("mean", 3)];
    let trimmed_03 = losses[&("trimmed_mean", 3)];
    println!(
        "\nloss at frac=0.3: clean {clean_loss:.3} | trimmed+rep \
         {trimmed_03:.3} | plain mean {mean_03:.3}"
    );
    if std::env::var("MARFL_BENCH_NO_ASSERT").is_err() {
        assert!(
            trimmed_03 <= 2.0 * clean_loss,
            "trimmed mean under 30% sign-flip must stay within 2x the \
             attack-free loss (got {trimmed_03:.4} vs clean {clean_loss:.4})"
        );
        assert!(
            mean_03 > trimmed_03,
            "plain mean under 30% sign-flip must be worse than the \
             defended trimmed mean (mean {mean_03:.4} vs trimmed \
             {trimmed_03:.4})"
        );
    }
}
