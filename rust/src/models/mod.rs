//! Artifact metadata registry: the Rust-side view of the flat-parameter
//! ABI contract (DESIGN.md). Parses `artifacts/meta.json` emitted by
//! `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

/// Static description of one lowered model.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    /// true parameter count P
    pub param_count: usize,
    /// padded flat length P_pad (multiple of the kernel STRIP)
    pub padded_len: usize,
    /// per-example input shape (e.g. [16,16,1] or [64])
    pub input_shape: Vec<usize>,
    pub classes: usize,
    /// local-update minibatch size
    pub batch: usize,
    /// eval chunk size (test set must be a multiple)
    pub eval_chunk: usize,
    pub init_file: String,
    /// entry-point name -> artifact file name
    pub artifacts: BTreeMap<String, String>,
}

impl ModelMeta {
    /// Feature elements per example.
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Size of one model transfer on the wire (f32 payload of the padded
    /// flat vector) — the unit of the paper's communication accounting.
    pub fn model_bytes(&self) -> u64 {
        (self.padded_len * 4) as u64
    }

    /// Bytes of a logits payload for one training batch (KD teacher
    /// exchange).
    pub fn logits_bytes(&self) -> u64 {
        (self.batch * self.classes * 4) as u64
    }
}

/// Registry over every model in the artifact directory.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub dir: PathBuf,
    pub strip: usize,
    pub kd_tau: f64,
    pub group_sizes: Vec<usize>,
    pub models: BTreeMap<String, ModelMeta>,
}

impl ArtifactMeta {
    /// Load `dir/meta.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {meta_path:?} — run `make artifacts`"))?;
        let j = parse(&text).map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;

        let strip = req_usize(&j, "strip")?;
        let kd_tau = j
            .get("kd_tau")
            .and_then(Json::as_f64)
            .context("meta.json: kd_tau")?;
        let group_sizes = j
            .get("group_sizes")
            .and_then(Json::as_arr)
            .context("meta.json: group_sizes")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();

        let mut models = BTreeMap::new();
        let model_obj = j
            .get("models")
            .and_then(Json::as_obj)
            .context("meta.json: models")?;
        for (name, m) in model_obj {
            let artifacts = m
                .get("artifacts")
                .and_then(Json::as_obj)
                .context("model artifacts")?
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect();
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    param_count: req_usize(m, "param_count")?,
                    padded_len: req_usize(m, "padded_len")?,
                    input_shape: m
                        .get("input_shape")
                        .and_then(Json::as_arr)
                        .context("input_shape")?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                    classes: req_usize(m, "classes")?,
                    batch: req_usize(m, "batch")?,
                    eval_chunk: req_usize(m, "eval_chunk")?,
                    init_file: m
                        .get("init")
                        .and_then(Json::as_str)
                        .context("init")?
                        .to_string(),
                    artifacts,
                },
            );
        }
        Ok(ArtifactMeta { dir: dir.to_path_buf(), strip, kd_tau, group_sizes, models })
    }

    /// Builtin registry mirroring `python/compile/model.py` — used with
    /// the native runtime backend when no artifacts have been lowered
    /// (pjrt-less builds and artifact-free machines). Parameter counts
    /// and padded lengths match the JAX `ravel_pytree` layouts exactly
    /// (see `runtime::native`), so artifact-backed and builtin runs share
    /// one wire-accounting model.
    pub fn builtin(dir: &Path) -> ArtifactMeta {
        let strip = 1024;
        let pad = |p: usize| p.div_ceil(strip) * strip;
        let mut models = BTreeMap::new();
        models.insert(
            "cnn".to_string(),
            ModelMeta {
                name: "cnn".into(),
                param_count: crate::runtime::native::CNN_PARAMS,
                padded_len: pad(crate::runtime::native::CNN_PARAMS),
                input_shape: vec![16, 16, 1],
                classes: 10,
                batch: 64,
                eval_chunk: 250,
                init_file: "cnn_init.bin".into(),
                artifacts: BTreeMap::new(),
            },
        );
        models.insert(
            "head".to_string(),
            ModelMeta {
                name: "head".into(),
                param_count: crate::runtime::native::HEAD_PARAMS,
                padded_len: pad(crate::runtime::native::HEAD_PARAMS),
                input_shape: vec![64],
                classes: 20,
                batch: 16,
                eval_chunk: 250,
                init_file: "head_init.bin".into(),
                artifacts: BTreeMap::new(),
            },
        );
        ArtifactMeta {
            dir: dir.to_path_buf(),
            strip,
            kd_tau: 3.0,
            // aot.py lowers group_mean for M in 2..=8
            group_sizes: (2..=8).collect(),
            models,
        }
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in artifacts"))
    }

    /// Path of one artifact file.
    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("meta.json: missing/invalid {key:?}"))
}

/// Default artifact directory: `$MARFL_ARTIFACTS` or `artifacts/`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("MARFL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_meta(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{
              "strip": 1024, "kd_tau": 3.0, "group_sizes": [2,3],
              "models": {
                "cnn": {
                  "param_count": 18346, "padded_len": 18432,
                  "input_shape": [16,16,1], "classes": 10,
                  "batch": 64, "eval_chunk": 250, "init": "cnn_init.bin",
                  "artifacts": {"cnn_eval": "cnn_eval.hlo.txt"}
                }
              }
            }"#,
        )
        .unwrap();
    }

    #[test]
    fn parses_meta_document() {
        let dir = std::env::temp_dir().join("marfl_models_test");
        write_meta(&dir);
        let meta = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(meta.strip, 1024);
        let cnn = meta.model("cnn").unwrap();
        assert_eq!(cnn.param_count, 18346);
        assert_eq!(cnn.input_elems(), 256);
        assert_eq!(cnn.model_bytes(), 18432 * 4);
        assert_eq!(cnn.logits_bytes(), 64 * 10 * 4);
        assert!(meta.model("vit").is_err());
    }

    #[test]
    fn missing_dir_is_actionable_error() {
        let err = ArtifactMeta::load(Path::new("/nonexistent_xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn builtin_registry_matches_python_model_zoo() {
        let meta = ArtifactMeta::builtin(Path::new("/nowhere"));
        assert_eq!(meta.strip, 1024);
        assert_eq!(meta.kd_tau, 3.0);
        assert_eq!(meta.group_sizes, vec![2, 3, 4, 5, 6, 7, 8]);
        let cnn = meta.model("cnn").unwrap();
        assert_eq!(cnn.param_count, 18_346);
        assert_eq!(cnn.padded_len, 18_432);
        assert_eq!(cnn.input_elems(), 256);
        assert_eq!(cnn.batch, 64);
        let head = meta.model("head").unwrap();
        assert_eq!(head.param_count, 10_900);
        assert_eq!(head.padded_len, 11_264);
        assert_eq!(head.classes, 20);
        assert_eq!(head.batch, 16);
        for m in meta.models.values() {
            assert_eq!(m.padded_len % meta.strip, 0);
        }
    }
}
