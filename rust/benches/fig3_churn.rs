//! Figures 3, 6 & 7 — partial participation and network churn.
//!
//! Paper claims: (a) partial participation degrades model utility; (b)
//! sudden dropouts (peer did local update, misses aggregation) cause no
//! *additional* degradation; (c) all baselines show the same pattern; (d)
//! even at 50% participation + 20% dropout MAR-FL keeps a >5× comm
//! advantage over RDFL/AR-FL.
//!
//! Default: 20NG-like (Fig. 3 / 7). MARFL_DATASET=cnn gives the MNIST-like
//! series (Fig. 6).

#[path = "common/mod.rs"]
mod common;

use common::{assert_stable_columns, emit_csv, iters, mib, results_dir, runtime, timed};
use marfl::config::{ExperimentConfig, Strategy};
use marfl::fl::Trainer;
use marfl::net::FaultConfig;
use marfl::telemetry::BenchReport;
use marfl::util::json::{arr, num, obj, s};

fn main() {
    let dataset =
        std::env::var("MARFL_DATASET").unwrap_or_else(|_| "head".into());
    let peers = 64;
    let t = iters(24, 60);
    println!("Figure 3/6/7 — participation & churn on {dataset} (peers={peers}, T={t})\n");
    let rt = runtime();
    let base = ExperimentConfig {
        model: dataset.clone(),
        peers,
        group_size: 4,
        mar_rounds: 3, // 64 = 4^3
        iterations: t,
        samples_per_peer: 64,
        test_samples: 1000,
        eval_every: 4,
        seed: 777,
        ..Default::default()
    };

    // (label, strategy, participation, dropout)
    let scenarios: Vec<(&str, Strategy, f64, f64)> = vec![
        ("marfl p=100% d=0%", Strategy::MarFl, 1.0, 0.0),
        ("marfl p=100% d=20%", Strategy::MarFl, 1.0, 0.2),
        ("marfl p=75% d=0%", Strategy::MarFl, 0.75, 0.0),
        ("marfl p=50% d=0%", Strategy::MarFl, 0.5, 0.0),
        ("marfl p=50% d=20%", Strategy::MarFl, 0.5, 0.2),
        ("rdfl  p=50% d=20%", Strategy::Rdfl, 0.5, 0.2),
        ("arfl  p=50% d=20%", Strategy::ArFl, 0.5, 0.2),
        ("fedavg p=50% d=20%", Strategy::FedAvg, 0.5, 0.2),
    ];

    let mut rows = vec![vec![
        "scenario".into(),
        "strategy".into(),
        "participation".into(),
        "dropout".into(),
        "final_accuracy".into(),
        "data_bytes".into(),
    ]];
    let mut acc = std::collections::BTreeMap::new();
    let mut bytes = std::collections::BTreeMap::new();
    for (label, strategy, part, drop) in &scenarios {
        let cfg = ExperimentConfig {
            strategy: *strategy,
            participation: *part,
            dropout: *drop,
            ..base.clone()
        };
        let run = timed(label, || Trainer::new(cfg, &rt).unwrap().run().unwrap());
        println!(
            "    acc {:.3}  data {:.0} MiB",
            run.final_accuracy,
            mib(run.comm.data_bytes)
        );
        rows.push(vec![
            label.to_string(),
            strategy.name().into(),
            part.to_string(),
            drop.to_string(),
            format!("{:.4}", run.final_accuracy),
            run.comm.data_bytes.to_string(),
        ]);
        acc.insert(label.to_string(), run.final_accuracy);
        bytes.insert(label.to_string(), run.comm.data_bytes);
    }
    // ---- Gilbert–Elliott (markov) churn row -------------------------
    // `churn.model = "markov"` swaps the i.i.d. Bernoulli participation
    // draw for per-peer Up/Down chains (bursty wireless availability —
    // the `configs/churn_markov.toml` preset). Stationary availability
    // p_up/(p_up+p_down) = 0.75 makes this row comparable to p=75%.
    {
        let cfg = ExperimentConfig {
            strategy: Strategy::MarFl,
            churn_model: "markov".into(),
            markov_p_down: 0.15,
            markov_p_up: 0.45,
            ..base.clone()
        };
        let label = "marfl markov GE(.15,.45)";
        let run =
            timed(label, || Trainer::new(cfg, &rt).unwrap().run().unwrap());
        println!(
            "    acc {:.3}  data {:.0} MiB  revivals {}  rescues {}",
            run.final_accuracy,
            mib(run.comm.data_bytes),
            run.reliability.markov_revivals,
            run.reliability.churn_rescues
        );
        rows.push(vec![
            label.to_string(),
            "marfl".into(),
            "markov(0.15,0.45)".into(),
            "0".into(),
            format!("{:.4}", run.final_accuracy),
            run.comm.data_bytes.to_string(),
        ]);
        acc.insert(label.to_string(), run.final_accuracy);
    }
    assert_stable_columns(
        "fig3_churn.csv",
        &rows,
        &[
            "scenario",
            "strategy",
            "participation",
            "dropout",
            "final_accuracy",
            "data_bytes",
        ],
    );
    emit_csv("fig3_churn.csv", &rows);

    // ---- fault-injection matrix (BENCH_churn.json) ------------------
    // The seeded fault plan rides on the same fixed-seed configuration:
    // a faults-off row — which must report all-zero counters, the
    // determinism contract CI asserts — plus two loss/straggler settings
    // showing what the recovery machinery (retries, quorum-degraded
    // groups, straggler exposure) costs as conditions worsen.
    println!("\nfault-injection matrix (loss × stragglers × bursts, fixed seeds)\n");
    let mut fault_rows = Vec::new();
    let mut fault_csv = vec![vec![
        "scenario".into(),
        "loss".into(),
        "straggler_prob".into(),
        "ge_p".into(),
        "msgs_lost".into(),
        "retries".into(),
        "timeouts".into(),
        "quorum_degraded".into(),
        "crashes".into(),
        "ge_bad_transitions".into(),
        "bursty_losses".into(),
        "straggler_exposed_s".into(),
        "final_accuracy".into(),
        "data_bytes".into(),
    ]];
    for &(label, loss, straggler, ge_p) in &[
        ("faults-off", 0.0f64, 0.0f64, 0.0f64),
        ("mild loss=0.05 strag=0.1", 0.05, 0.1, 0.0),
        ("harsh loss=0.2 strag=0.3", 0.2, 0.3, 0.0),
        // bursty row: the mild plan with a Gilbert–Elliott chain layered
        // on — same mean loss while a link is good, bursts while bad
        ("bursty loss=0.05 GE(.1,.3)", 0.05, 0.1, 0.1),
    ] {
        let off = label == "faults-off";
        let cfg = ExperimentConfig {
            strategy: Strategy::MarFl,
            faults: FaultConfig {
                loss,
                straggler_prob: straggler,
                degrade_prob: if off { 0.0 } else { 0.1 },
                crash_prob: if off { 0.0 } else { 0.01 },
                ge_p,
                ge_r: 0.3,
                ..FaultConfig::default()
            },
            ..base.clone()
        };
        let run =
            timed(label, || Trainer::new(cfg, &rt).unwrap().run().unwrap());
        // the run's own counters are authoritative — no loss-rate
        // arithmetic over the ledger here
        let f = run.faults;
        println!(
            "    lost {}  retries {}  timeouts {}  degraded {}  crashes {}  \
             bursts {}  strag {:.1}s  acc {:.3}",
            f.msgs_lost,
            f.retries,
            f.timeouts,
            f.quorum_degraded_rounds,
            f.crashes,
            f.ge_bad_transitions,
            f.straggler_exposed_s,
            run.final_accuracy
        );
        if off {
            assert!(
                !f.any() && f.straggler_exposed_s == 0.0,
                "faults-off row must report all-zero fault counters"
            );
        } else {
            assert!(f.msgs_lost > 0, "loss must lose messages ({label})");
            assert!(
                f.straggler_exposed_s > 0.0,
                "stragglers must surface exposed time ({label})"
            );
        }
        if ge_p > 0.0 {
            assert!(
                f.ge_bad_transitions > 0 && f.bursty_losses > 0,
                "an active chain must surface burst counters ({label})"
            );
        } else {
            assert_eq!(f.ge_bad_transitions, 0, "chains off ⇒ no bursts");
        }
        fault_csv.push(vec![
            label.to_string(),
            loss.to_string(),
            straggler.to_string(),
            ge_p.to_string(),
            f.msgs_lost.to_string(),
            f.retries.to_string(),
            f.timeouts.to_string(),
            f.quorum_degraded_rounds.to_string(),
            f.crashes.to_string(),
            f.ge_bad_transitions.to_string(),
            f.bursty_losses.to_string(),
            format!("{:.3}", f.straggler_exposed_s),
            format!("{:.4}", run.final_accuracy),
            run.comm.data_bytes.to_string(),
        ]);
        fault_rows.push(obj(vec![
            ("scenario", s(label)),
            ("loss", num(loss)),
            ("straggler_prob", num(straggler)),
            ("ge_p", num(ge_p)),
            ("msgs_lost", num(f.msgs_lost as f64)),
            ("retries", num(f.retries as f64)),
            ("timeouts", num(f.timeouts as f64)),
            ("quorum_degraded_rounds", num(f.quorum_degraded_rounds as f64)),
            ("crashes", num(f.crashes as f64)),
            ("ge_bad_transitions", num(f.ge_bad_transitions as f64)),
            ("bursty_losses", num(f.bursty_losses as f64)),
            ("straggler_exposed_s", num(f.straggler_exposed_s)),
            ("final_accuracy", num(run.final_accuracy)),
            ("data_bytes", num(run.comm.data_bytes as f64)),
        ]));
    }
    assert_stable_columns(
        "fig3_fault_matrix.csv",
        &fault_csv,
        &[
            "scenario",
            "loss",
            "straggler_prob",
            "ge_p",
            "msgs_lost",
            "retries",
            "timeouts",
            "quorum_degraded",
            "crashes",
            "ge_bad_transitions",
            "bursty_losses",
            "straggler_exposed_s",
            "final_accuracy",
            "data_bytes",
        ],
    );
    emit_csv("fig3_fault_matrix.csv", &fault_csv);
    let churn_path = BenchReport::new("churn")
        .field("kind", s("churn_fault_matrix"))
        .field("peers", num(peers as f64))
        .field("iterations", num(t as f64))
        .field("results", arr(fault_rows))
        .write(&results_dir())
        .expect("write BENCH_churn.json");
    println!("  -> {}", churn_path.display());

    // ---- reduce-scatter reliability vs owner-drop rate --------------
    // Chunk ownership makes every member load-bearing: `mar.rs_drop`
    // injects mid-exchange owner losses. With `mar.rs_retry_budget=0`
    // (seed behavior) the group falls back to a survivors-only full
    // gather; with a budget it defers to the next round's matchmaking
    // instead, trading averaging progress for recovery bytes.
    // `RunSummary::reliability.{rs_fallbacks, rs_retries}` surface both
    // counts, so reliability is plottable against drop rate and budget.
    println!("\nreduce-scatter reliability vs mar.rs_drop × mar.rs_retry_budget\n");
    let mut rs_rows = vec![vec![
        "rs_drop".into(),
        "rs_retry_budget".into(),
        "rs_fallbacks".into(),
        "rs_retries".into(),
        "fallbacks_per_iter".into(),
        "final_accuracy".into(),
        "data_bytes".into(),
    ]];
    let mut fallbacks = std::collections::BTreeMap::new();
    let mut retried = std::collections::BTreeMap::new();
    for &budget in &[0usize, 2] {
        for &drop in &[0.0f64, 0.05, 0.1, 0.2] {
            let cfg = ExperimentConfig {
                strategy: Strategy::MarFl,
                reduce_scatter: true,
                rs_drop: drop,
                rs_retry_budget: budget,
                ..base.clone()
            };
            let run = timed(&format!("marfl rs_drop={drop} budget={budget}"), || {
                Trainer::new(cfg, &rt).unwrap().run().unwrap()
            });
            let rel = run.reliability;
            let per_iter =
                rel.rs_fallbacks as f64 / run.iterations_run.max(1) as f64;
            println!(
                "    fallbacks {} ({per_iter:.2}/iter)  retries {}  acc {:.3}  data {:.0} MiB",
                rel.rs_fallbacks,
                rel.rs_retries,
                run.final_accuracy,
                mib(run.comm.data_bytes)
            );
            rs_rows.push(vec![
                drop.to_string(),
                budget.to_string(),
                rel.rs_fallbacks.to_string(),
                rel.rs_retries.to_string(),
                format!("{per_iter:.3}"),
                format!("{:.4}", run.final_accuracy),
                run.comm.data_bytes.to_string(),
            ]);
            if budget == 0 {
                fallbacks.insert((drop * 100.0) as u64, rel.rs_fallbacks);
            } else {
                retried.insert((drop * 100.0) as u64, rel.rs_retries);
            }
        }
    }
    assert_stable_columns(
        "fig3_rs_reliability.csv",
        &rs_rows,
        &[
            "rs_drop",
            "rs_retry_budget",
            "rs_fallbacks",
            "rs_retries",
            "fallbacks_per_iter",
            "final_accuracy",
            "data_bytes",
        ],
    );
    emit_csv("fig3_rs_reliability.csv", &rs_rows);
    assert_eq!(
        fallbacks[&0], 0,
        "no owner drops may occur at rs_drop=0"
    );
    assert!(
        fallbacks[&20] > fallbacks[&0],
        "rs_drop=0.2 must produce observable fallbacks"
    );
    assert_eq!(retried[&0], 0, "no retries may occur at rs_drop=0");
    assert!(
        retried[&20] > 0,
        "a retry budget must absorb drops at rs_drop=0.2"
    );

    // ---- paper-shape assertions ------------------------------------
    let full = acc["marfl p=100% d=0%"];
    let dropped = acc["marfl p=100% d=20%"];
    let half = acc["marfl p=50% d=0%"];
    println!("\nfull {full:.3} | +20% dropout {dropped:.3} | 50% participation {half:.3}");
    assert!(
        dropped > full - 0.10,
        "dropout alone must not cause a large accuracy drop ({full:.3} -> {dropped:.3})"
    );
    let comm_ratio =
        bytes["rdfl  p=50% d=20%"] as f64 / bytes["marfl p=50% d=20%"] as f64;
    println!(
        "RDFL/MAR comm under 50% participation + 20% dropout: {comm_ratio:.1}x (paper: >5x at 125 peers)"
    );
    assert!(
        comm_ratio > 3.0,
        "MAR-FL must keep a clear comm advantage under churn"
    );
}
