"""AOT lowering smoke tests: HLO-text interchange invariants.

Full artifact generation is exercised by `make artifacts`; here we lower a
representative subset and assert the properties the Rust loader depends on.
"""

import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile.kernels.group_mean import group_mean


def _lower(fn, *specs):
    def wrapped(*a):
        out = fn(*a)
        return out if isinstance(out, tuple) else (out,)
    return aot.to_hlo_text(jax.jit(wrapped).lower(*specs))


def test_head_logits_hlo_text():
    p, p_pad, _ = M.flat_info("head")
    spec = M.MODELS["head"]
    text = _lower(M.make_logits("head"),
                  jax.ShapeDtypeStruct((p_pad,), jnp.float32),
                  jax.ShapeDtypeStruct(spec.batched(spec.batch), jnp.float32))
    assert "ENTRY" in text
    # root must be a tuple (return_tuple=True) so Rust can unpack uniformly
    assert re.search(r"ROOT .* tuple", text), text[-500:]
    # no custom-calls: interpret-mode pallas lowers to plain HLO the CPU
    # PJRT client can run (Mosaic would be a custom-call)
    assert "custom-call" not in text


def test_group_mean_hlo_text_no_custom_call():
    _, p_pad, _ = M.flat_info("head")
    text = _lower(group_mean, jax.ShapeDtypeStruct((3, p_pad), jnp.float32))
    assert "ENTRY" in text
    assert "custom-call" not in text


def test_train_step_hlo_is_tuple_of_three():
    p, p_pad, _ = M.flat_info("head")
    spec = M.MODELS["head"]
    text = _lower(
        M.make_train_step("head"),
        jax.ShapeDtypeStruct((p_pad,), jnp.float32),
        jax.ShapeDtypeStruct((p_pad,), jnp.float32),
        jax.ShapeDtypeStruct(spec.batched(spec.batch), jnp.float32),
        jax.ShapeDtypeStruct((spec.batch,), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )
    # inspect the ENTRY computation's ROOT (inner computations also have
    # ROOT tuples — e.g. loop bodies — so scope the search)
    entry = text[text.rindex("ENTRY"):]
    root = re.search(r"ROOT [^=]*= \((.*?)\) tuple", entry)
    # three leaves: theta', mom', loss
    assert root is not None and root.group(1).count("f32") == 3, entry[:800]


def test_meta_shapes_consistent():
    for name in M.MODELS:
        p, p_pad, _ = M.flat_info(name)
        assert p_pad % aot.STRIP == 0
        assert all(2 <= k <= 8 for k in aot.GROUP_SIZES)
