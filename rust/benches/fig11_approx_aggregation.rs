//! Figure 11 — approximate aggregation: trading exactness for bytes.
//!
//! Paper claim: on 125 peers, relaxing the exact configuration (M=5, G=3,
//! 5³=125) to M=3, G=4 yields only approximate per-iteration averages but
//! cuts communication by up to 33% with no substantial loss in model
//! utility — approximations converge to near-exact global averages over
//! iterations (Eq. 1).

#[path = "common/mod.rs"]
mod common;

use common::{emit_csv, iters, mib, runtime, timed};
use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;

fn main() {
    let rt = runtime();
    let t = iters(20, 50);
    let peers = 125;
    println!("Figure 11 — approximate aggregation (peers={peers}, T={t})\n");
    let base = ExperimentConfig {
        model: "head".into(),
        peers,
        iterations: t,
        samples_per_peer: 64,
        test_samples: 1000,
        eval_every: 4,
        seed: 1111,
        ..Default::default()
    };

    // (label, M, G): exact 5^3 grid vs the paper's approximate relaxation
    let variants = [("exact M=5 G=3", 5usize, 3usize), ("approx M=3 G=4", 3, 4)];
    let mut rows = vec![vec![
        "variant".into(),
        "group_size".into(),
        "mar_rounds".into(),
        "data_bytes".into(),
        "final_accuracy".into(),
    ]];
    let mut out = Vec::new();
    for (label, m, g) in variants {
        let cfg = ExperimentConfig {
            group_size: m,
            mar_rounds: g,
            ..base.clone()
        };
        let run = timed(label, || Trainer::new(cfg, &rt).unwrap().run().unwrap());
        println!(
            "    data {:.0} MiB  acc {:.3}",
            mib(run.comm.data_bytes),
            run.final_accuracy
        );
        rows.push(vec![
            label.into(),
            m.to_string(),
            g.to_string(),
            run.comm.data_bytes.to_string(),
            format!("{:.4}", run.final_accuracy),
        ]);
        out.push((label, run));
    }
    emit_csv("fig11_approx_aggregation.csv", &rows);

    let exact = &out[0].1;
    let approx = &out[1].1;
    let saving = 1.0 - approx.comm.data_bytes as f64 / exact.comm.data_bytes as f64;
    println!(
        "\ncommunication saving: {:.0}% (paper: up to 33%)",
        saving * 100.0
    );
    println!(
        "accuracy: exact {:.3} vs approx {:.3}",
        exact.final_accuracy, approx.final_accuracy
    );
    assert!(
        saving > 0.15,
        "approximate mode must reduce communication meaningfully"
    );
    assert!(
        approx.final_accuracy > exact.final_accuracy - 0.08,
        "approximate aggregation must preserve model utility"
    );
}
