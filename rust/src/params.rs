//! Copy-on-write model parameters.
//!
//! [`Theta`] is the flat parameter vector every peer carries, backed by an
//! `Arc<Vec<f32>>` so the hot paths that used to clone full vectors now
//! share storage instead:
//!
//! * **MKD teacher snapshots** — `KdEngine::run_mkd` snapshots every group
//!   member's round-start θ; with `Theta` that is one refcount bump per
//!   member instead of an O(k·|θ|) allocation storm per group.
//! * **Group-average broadcast** — after a group averages, every member
//!   holds the *same* canonical mean; `write_all` hands each member a
//!   clone of one shared allocation instead of copying the buffer k times.
//! * **DP reference models** — `DpEngine` keeps each peer's last global
//!   model (`θ̄_i^{t-1}`) as a shared handle on the state the peer already
//!   holds.
//!
//! Mutation goes through [`Theta::make_mut`] (clone-on-write: unique
//! handles mutate in place, shared ones detach first), so a student
//! distilling on its own θ can never perturb a teacher snapshot that
//! aliases it — the aliasing-safety tests pin this down.

use std::ops::Deref;
use std::sync::Arc;

/// A flat `f32` parameter (or momentum) vector with shared, copy-on-write
/// storage. Dereferences to `&[f32]`, so read-side call sites treat it
/// exactly like the `Vec<f32>` it replaced.
#[derive(Clone, Debug, Default)]
pub struct Theta {
    data: Arc<Vec<f32>>,
}

impl Theta {
    pub fn new(v: Vec<f32>) -> Self {
        Theta { data: Arc::new(v) }
    }

    pub fn zeros(len: usize) -> Self {
        Theta::new(vec![0.0; len])
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn to_vec(&self) -> Vec<f32> {
        self.data.as_ref().clone()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Mutable access with clone-on-write semantics: a uniquely held
    /// vector is mutated in place (no allocation); a shared one is
    /// detached into a private copy first, leaving every other handle —
    /// snapshots, DP references, groupmates — untouched.
    pub fn make_mut(&mut self) -> &mut Vec<f32> {
        Arc::make_mut(&mut self.data)
    }

    /// [`Self::make_mut`] as an exclusive slice — the buffer the
    /// in-place step API (`Runtime::train_step_into` /
    /// `Runtime::kd_step_into`) writes the fused momentum update
    /// through. On a unique handle this detaches nothing and allocates
    /// nothing, so a peer's local-SGD schedule mutates one buffer for
    /// its whole lifetime; the first write through a handle shared with
    /// a snapshot or groupmate detaches exactly once.
    pub fn make_mut_slice(&mut self) -> &mut [f32] {
        Arc::make_mut(&mut self.data).as_mut_slice()
    }

    /// Do two handles share the same backing allocation? (The zero-copy
    /// assertions: group members share one mean, snapshots alias their
    /// source until the first write.)
    pub fn shares_storage(&self, other: &Theta) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Is this the only handle on the allocation? (`make_mut` on a unique
    /// handle is in-place and allocation-free.)
    pub fn is_unique(&self) -> bool {
        Arc::strong_count(&self.data) == 1
    }
}

impl Deref for Theta {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl AsRef<[f32]> for Theta {
    fn as_ref(&self) -> &[f32] {
        &self.data
    }
}

impl From<Vec<f32>> for Theta {
    fn from(v: Vec<f32>) -> Self {
        Theta::new(v)
    }
}

impl FromIterator<f32> for Theta {
    fn from_iter<I: IntoIterator<Item = f32>>(iter: I) -> Self {
        Theta::new(iter.into_iter().collect())
    }
}

impl<'a> IntoIterator for &'a Theta {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;

    fn into_iter(self) -> Self::IntoIter {
        self.data.iter()
    }
}

impl PartialEq for Theta {
    fn eq(&self, other: &Theta) -> bool {
        // deliberate content comparison with NO ptr_eq short-circuit:
        // equality must match `Vec<f32>` semantics exactly (NaN != NaN,
        // and an assertion against an aliased handle still reads the
        // payload), so the bit-identity tests can never pass vacuously
        *self.data == *other.data
    }
}

impl PartialEq<Vec<f32>> for Theta {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Theta> for Vec<f32> {
    fn eq(&self, other: &Theta) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[f32]> for Theta {
    fn eq(&self, other: &[f32]) -> bool {
        self.as_slice() == other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_shares_storage_without_copying() {
        let a = Theta::new(vec![1.0, 2.0, 3.0]);
        let b = a.clone();
        assert!(a.shares_storage(&b));
        assert!(!a.is_unique());
        assert_eq!(a, b);
    }

    #[test]
    fn make_mut_detaches_shared_storage() {
        let mut student = Theta::new(vec![1.0, 2.0, 3.0]);
        let snapshot = student.clone();
        student.make_mut()[0] = 99.0;
        // the write detached the student; the snapshot is untouched
        assert!(!student.shares_storage(&snapshot));
        assert_eq!(snapshot, vec![1.0, 2.0, 3.0]);
        assert_eq!(student[0], 99.0);
    }

    #[test]
    fn make_mut_is_in_place_when_unique() {
        let mut a = Theta::new(vec![0.0; 8]);
        assert!(a.is_unique());
        let before = a.as_slice().as_ptr();
        a.make_mut()[3] = 1.0;
        assert_eq!(a.as_slice().as_ptr(), before, "unique mutation must not move");
    }

    #[test]
    fn make_mut_slice_detaches_aliases_once_then_stays_in_place() {
        let mut student = Theta::new(vec![1.0, 2.0, 3.0]);
        let snapshot = student.clone();
        // first in-place write detaches from the snapshot
        student.make_mut_slice()[0] = 9.0;
        assert!(!student.shares_storage(&snapshot));
        assert_eq!(snapshot, vec![1.0, 2.0, 3.0]);
        // subsequent writes mutate the now-unique buffer without moving
        let before = student.as_slice().as_ptr();
        student.make_mut_slice()[1] = 8.0;
        assert_eq!(student.as_slice().as_ptr(), before);
        assert_eq!(student, vec![9.0, 8.0, 3.0]);
    }

    #[test]
    fn replacement_does_not_perturb_aliases() {
        let mut state = Theta::new(vec![1.0, 1.0]);
        let snapshot = state.clone();
        state = Theta::new(vec![2.0, 2.0]);
        assert_eq!(snapshot, vec![1.0, 1.0]);
        assert!(!state.shares_storage(&snapshot));
    }

    #[test]
    fn equality_against_vec_and_slice() {
        let t = Theta::new(vec![1.0, 2.0]);
        assert_eq!(t, vec![1.0, 2.0]);
        assert_eq!(vec![1.0, 2.0], t);
        assert!(t == *[1.0, 2.0].as_slice());
        assert!(t != vec![1.0, 3.0]);
    }

    #[test]
    fn collects_and_iterates_like_a_vec() {
        let t: Theta = (0..4).map(|i| i as f32).collect();
        assert_eq!(t.len(), 4);
        let mut sum = 0.0f32;
        for &v in &t {
            sum += v;
        }
        assert_eq!(sum, 6.0);
        assert_eq!(t.to_vec(), vec![0.0, 1.0, 2.0, 3.0]);
        assert!(!t.is_empty());
        assert!(Theta::zeros(0).is_empty());
    }
}
