//! CSV / JSON result emission (results/ directory convention).

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Write rows (first row = header) as CSV. Fields containing commas or
/// quotes are quoted per RFC 4180.
pub fn write_csv(path: &Path, rows: &[Vec<String>]) -> Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut out = String::new();
    for row in rows {
        let encoded: Vec<String> = row.iter().map(|f| escape_field(f)).collect();
        out.push_str(&encoded.join(","));
        out.push('\n');
    }
    fs::write(path, out).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

fn escape_field(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

/// Write a JSON document.
pub fn write_json(path: &Path, value: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    fs::write(path, value.to_string()).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

/// Write a JSON Lines document: one compact JSON value per line. The
/// serializer is deterministic (BTreeMap-ordered keys, shortest-round-trip
/// floats), so identical value sequences produce byte-identical files —
/// the property the round-trace writer relies on.
pub fn write_jsonl(path: &Path, lines: &[Json]) -> Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut out = String::new();
    for v in lines {
        out.push_str(&v.to_string());
        out.push('\n');
    }
    fs::write(path, out).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj, parse};

    #[test]
    fn csv_round_trip_simple() {
        let dir = tempdir();
        let path = dir.join("t.csv");
        write_csv(
            &path,
            &[
                vec!["a".into(), "b".into()],
                vec!["1".into(), "x,y".into()],
            ],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n");
    }

    #[test]
    fn json_writes_parseable_document() {
        let dir = tempdir();
        let path = dir.join("t.json");
        let v = obj(vec![("n", num(5.0))]);
        write_json(&path, &v).unwrap();
        let back = parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back, v);
    }

    fn tempdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "marfl_writer_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }
}
