//! Shared bench harness (offline environment: no criterion — each bench is
//! a `harness = false` binary that prints the paper-figure table it
//! regenerates and writes `results/<fig>.csv`).

#![allow(dead_code)]

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use marfl::aggregation::{AggCtx, PeerState};
use marfl::metrics::{write_csv, CommLedger};
use marfl::models::{default_artifact_dir, ModelMeta};
use marfl::net::Fabric;
use marfl::rng::Rng;
use marfl::runtime::Runtime;
use marfl::sim::SimClock;
use marfl::telemetry::BenchReport;
use marfl::util::json::{arr, num, obj, s, Json};

/// Where figure CSVs land.
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    std::fs::create_dir_all(&dir).expect("create results/");
    dir
}

pub fn runtime() -> Runtime {
    // artifacts + PJRT when available, native backend otherwise
    let rt = Runtime::new(&default_artifact_dir()).expect("runtime");
    println!("[bench] compute backend: {}", rt.backend_name());
    rt
}

pub fn mib(bytes: u64) -> f64 {
    bytes as f64 / (1 << 20) as f64
}

/// Reduced-iteration mode for CI-speed runs; set MARFL_BENCH_FULL=1 for
/// paper-scale sweeps.
pub fn full_mode() -> bool {
    std::env::var_os("MARFL_BENCH_FULL").is_some()
}

pub fn iters(quick: usize, full: usize) -> usize {
    if full_mode() {
        full
    } else {
        quick
    }
}

/// Write a CSV and echo where it went.
pub fn emit_csv(name: &str, rows: &[Vec<String>]) {
    let path = results_dir().join(name);
    write_csv(&path, rows).expect("write csv");
    println!("  -> {}", path.display());
}

/// Pin a table's header row. Bench CSV/JSON column names are a public
/// interface — plot scripts and CI consume them — so a drift (e.g. from
/// an internal rename like the `RunSummary` scorecard cutover) must fail
/// the bench loudly instead of silently breaking downstream readers.
pub fn assert_stable_columns(csv: &str, rows: &[Vec<String>], expected: &[&str]) {
    assert!(!rows.is_empty(), "{csv}: table has no header row");
    let got: Vec<&str> = rows[0].iter().map(|c| c.as_str()).collect();
    assert_eq!(got, expected, "{csv}: column names drifted");
}

/// Emit a bench's result table as `BENCH_<name>.json` through the shared
/// `marfl-bench/v1` envelope ([`BenchReport`]): one object per data row,
/// keyed by the header row, numeric where the cell parses as a number.
/// Keeps every bench's JSON inside the one schema `marfl trajectory`
/// folds.
pub fn emit_bench_report(name: &str, kind: &str, rows: &[Vec<String>]) {
    assert!(!rows.is_empty(), "BENCH_{name}: table has no header row");
    let header = &rows[0];
    let json_rows: Vec<Json> = rows[1..]
        .iter()
        .map(|r| {
            obj(header
                .iter()
                .zip(r)
                .map(|(k, v)| {
                    // non-finite parses ("inf", "nan") stay strings —
                    // they have no JSON number representation
                    let cell = match v.parse::<f64>() {
                        Ok(n) if n.is_finite() => num(n),
                        _ => s(v),
                    };
                    (k.as_str(), cell)
                })
                .collect())
        })
        .collect();
    let path = BenchReport::new(name)
        .field("kind", s(kind))
        .field("results", arr(json_rows))
        .write(&results_dir())
        .unwrap_or_else(|e| panic!("write BENCH_{name}.json: {e}"));
    println!("  -> {}", path.display());
}

/// Time a closure (single shot, for coarse stage timing).
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("  [{label}] {:.2}s", t0.elapsed().as_secs_f64());
    out
}

/// Median-of-runs micro timer (ns per op).
pub fn bench_ns(label: &str, warmup: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    println!("  {label:<44} {:>12.1} µs/op (median of {reps})", med / 1e3);
    med
}

/// A self-owning aggregation context over synthetic states (comm-only
/// benches need no PJRT).
pub struct SynthBundle {
    pub ledger: Arc<CommLedger>,
    pub fabric: Fabric,
    pub clock: SimClock,
    pub rng: Rng,
    pub model: ModelMeta,
}

impl SynthBundle {
    pub fn new(padded_len: usize) -> Self {
        let ledger = Arc::new(CommLedger::new());
        SynthBundle {
            fabric: Fabric::new(ledger.clone(), 12.5e6, 0.02),
            ledger,
            clock: SimClock::new(),
            rng: Rng::new(0xBE9C4),
            model: ModelMeta {
                name: "cnn".into(),
                param_count: padded_len,
                padded_len,
                input_shape: vec![16, 16, 1],
                classes: 10,
                batch: 64,
                eval_chunk: 250,
                init_file: String::new(),
                artifacts: Default::default(),
            },
        }
    }

    pub fn ctx(&mut self) -> AggCtx<'_> {
        AggCtx {
            fabric: &self.fabric,
            clock: &mut self.clock,
            rng: &mut self.rng,
            runtime: None,
            model: &self.model,
            faults: &marfl::net::FaultConfig::OFF,
            links: None,
        }
    }

    pub fn states(&mut self, n: usize) -> Vec<PeerState> {
        (0..n)
            .map(|_| PeerState {
                theta: (0..self.model.padded_len)
                    .map(|_| self.rng.normal() as f32)
                    .collect(),
                momentum: marfl::params::Theta::zeros(self.model.padded_len),
            })
            .collect()
    }
}
